"""RecSys models: DLRM, DCN-v2, Wide&Deep, SASRec + EmbeddingBag.

JAX has no nn.EmbeddingBag — `embedding_bag` below is jnp.take +
reduction (DESIGN.md §3), and the huge tables are row-sharded over the
`model` axis (vocab padded to a shardable multiple at init; configs keep the
true published cardinalities).

The `retrieval_cand` regime (1 query x 1M candidates) supports two scoring
backends:
  * exact  — user tower dot candidate embeddings (baseline),
  * pq     — the paper's technique: ADC over PQ codes of the candidate
             embeddings + full-precision re-rank of the top candidates
             (AiSAQ-style storage-tier candidate store).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RecsysConfig
from repro.models.layers import init_dense, mlp_apply, mlp_stack, truncnorm_init

VOCAB_PAD = 2048  # pad table rows so any mesh axis up to 2048 shards evenly


def padded_vocab(v: int) -> int:
    return max(VOCAB_PAD, (v + VOCAB_PAD - 1) // VOCAB_PAD * VOCAB_PAD)


def embedding_bag(table: jax.Array, idx: jax.Array, combiner: str = "sum"
                  ) -> jax.Array:
    """table (V, D), idx (..., hot) int -> (..., D)."""
    e = jnp.take(table, idx, axis=0)            # (..., hot, D)
    if combiner == "sum":
        return e.sum(axis=-2)
    if combiner == "mean":
        return e.mean(axis=-2)
    raise ValueError(combiner)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_recsys(rng: jax.Array, cfg: RecsysConfig) -> dict:
    keys = jax.random.split(rng, cfg.n_sparse + 8)
    D = cfg.embed_dim
    p: dict = {"tables": [
        truncnorm_init(keys[i], (padded_vocab(v), D), 0.05, jnp.float32)
        for i, v in enumerate(cfg.vocab_sizes)]}
    kk = keys[cfg.n_sparse:]
    if cfg.kind == "dlrm":
        p["bot"] = mlp_stack(kk[0], (cfg.n_dense,) + cfg.bot_mlp, jnp.float32)
        n_f = cfg.n_sparse + 1
        d_int = n_f * (n_f - 1) // 2 + cfg.bot_mlp[-1]
        p["top"] = mlp_stack(kk[1], (d_int,) + cfg.top_mlp, jnp.float32)
    elif cfg.kind == "dcnv2":
        d0 = cfg.n_dense + cfg.n_sparse * D
        p["cross"] = [{"w": init_dense(k, (d0, d0), jnp.float32),
                       "b": jnp.zeros((d0,), jnp.float32)}
                      for k in jax.random.split(kk[0], cfg.n_cross_layers)]
        p["mlp"] = mlp_stack(kk[1], (d0,) + cfg.mlp + (1,), jnp.float32)
    elif cfg.kind == "widedeep":
        p["wide"] = [
            truncnorm_init(k, (padded_vocab(v), 1), 0.01, jnp.float32)
            for k, v in zip(jax.random.split(kk[0], cfg.n_sparse),
                            cfg.vocab_sizes)]
        p["mlp"] = mlp_stack(kk[1], (cfg.n_sparse * D,) + cfg.mlp + (1,),
                             jnp.float32)
    elif cfg.kind == "sasrec":
        S, H = cfg.seq_len, cfg.n_heads
        p["pos"] = truncnorm_init(kk[0], (S, D), 0.05, jnp.float32)
        blocks = []
        for k in jax.random.split(kk[1], cfg.n_blocks):
            k1, k2, k3, k4 = jax.random.split(k, 4)
            blocks.append({
                "wq": init_dense(k1, (D, D), jnp.float32),
                "wk": init_dense(k2, (D, D), jnp.float32),
                "wv": init_dense(k3, (D, D), jnp.float32),
                "ln1": jnp.ones((D,), jnp.float32),
                "ln2": jnp.ones((D,), jnp.float32),
                "ff": mlp_stack(k4, (D, D, D), jnp.float32),
            })
        p["blocks"] = blocks
    else:
        raise ValueError(cfg.kind)
    # retrieval tower: project item embeddings into the user space
    p["item_proj"] = init_dense(kk[4], (D, D), jnp.float32)
    return p


# ---------------------------------------------------------------------------
# forwards
# ---------------------------------------------------------------------------


def _sparse_embs(p, batch, cfg) -> jax.Array:
    """-> (B, n_sparse, D)."""
    embs = [embedding_bag(t, batch["sparse"][:, i, :])
            for i, t in enumerate(p["tables"])]
    return jnp.stack(embs, axis=1)


def rec_forward(p: dict, batch: dict, cfg: RecsysConfig) -> jax.Array:
    """CTR forward -> logits (B,). batch: dense (B,nd) f32, sparse (B,ns,hot)."""
    if cfg.kind == "dlrm":
        d = mlp_apply(p["bot"], batch["dense"], final_act=True)   # (B, D)
        s = _sparse_embs(p, batch, cfg)                            # (B, ns, D)
        z = jnp.concatenate([d[:, None, :], s], axis=1)            # (B, F, D)
        zz = jnp.einsum("bfd,bgd->bfg", z, z)
        f = z.shape[1]
        iu, ju = jnp.triu_indices(f, k=1)
        inter = zz[:, iu, ju]                                      # (B, F(F-1)/2)
        return mlp_apply(p["top"], jnp.concatenate([d, inter], -1))[:, 0]
    if cfg.kind == "dcnv2":
        s = _sparse_embs(p, batch, cfg).reshape(batch["sparse"].shape[0], -1)
        x0 = jnp.concatenate([batch["dense"], s], axis=-1)
        x = x0
        for c in p["cross"]:
            x = x0 * (x @ c["w"] + c["b"]) + x                     # DCNv2 cross
        return mlp_apply(p["mlp"], x)[:, 0]
    if cfg.kind == "widedeep":
        s = _sparse_embs(p, batch, cfg)
        deep = mlp_apply(p["mlp"], s.reshape(s.shape[0], -1))[:, 0]
        wide = sum(embedding_bag(w, batch["sparse"][:, i, :])[:, 0]
                   for i, w in enumerate(p["wide"]))
        return deep + wide
    if cfg.kind == "sasrec":
        h = sasrec_hidden(p, batch["seq"], cfg)                    # (B, S, D)
        tgt = jnp.take(p["tables"][0], batch["target"], axis=0)    # (B, D)
        return jnp.einsum("bd,bd->b", h[:, -1], tgt)
    raise ValueError(cfg.kind)


def sasrec_hidden(p: dict, seq: jax.Array, cfg: RecsysConfig) -> jax.Array:
    B, S = seq.shape
    D = cfg.embed_dim
    x = jnp.take(p["tables"][0], seq, axis=0) + p["pos"][None, :S]
    mask = jnp.tril(jnp.ones((S, S), bool))
    for b in p["blocks"]:
        xn = _ln(x, b["ln1"])
        q, k, v = xn @ b["wq"], xn @ b["wk"], xn @ b["wv"]
        s = jnp.einsum("bqd,bkd->bqk", q, k) / jnp.sqrt(D)
        s = jnp.where(mask[None], s, -1e30)
        x = x + jnp.einsum("bqk,bkd->bqd", jax.nn.softmax(s, axis=-1), v)
        x = x + mlp_apply(b["ff"], _ln(x, b["ln2"]))
    return x


def _ln(x, scale, eps=1e-6):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * scale


def rec_loss(p: dict, batch: dict, cfg: RecsysConfig):
    if cfg.kind == "sasrec":
        # next-item BCE with one sampled negative per position (paper §3.4)
        h = sasrec_hidden(p, batch["seq"], cfg)                    # (B, S, D)
        pos = jnp.take(p["tables"][0], batch["pos_items"], axis=0)
        neg = jnp.take(p["tables"][0], batch["neg_items"], axis=0)
        sp = jnp.einsum("bsd,bsd->bs", h, pos)
        sn = jnp.einsum("bsd,bsd->bs", h, neg)
        m = batch["seq_mask"]
        loss = -(jnp.log(jax.nn.sigmoid(sp) + 1e-9)
                 + jnp.log(1 - jax.nn.sigmoid(sn) + 1e-9))
        loss = (loss * m).sum() / jnp.maximum(m.sum(), 1.0)
        return loss, {"pos_score": (sp * m).sum() / jnp.maximum(m.sum(), 1.0)}
    logits = rec_forward(p, batch, cfg)
    y = batch["label"].astype(jnp.float32)
    loss = jnp.mean(
        jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits))))
    return loss, {"mean_logit": logits.mean()}


# ---------------------------------------------------------------------------
# retrieval scoring (the paper's regime)
# ---------------------------------------------------------------------------


def user_tower(p: dict, batch: dict, cfg: RecsysConfig) -> jax.Array:
    """-> (B, D) user representation for retrieval."""
    if cfg.kind == "sasrec":
        return sasrec_hidden(p, batch["seq"], cfg)[:, -1]
    if cfg.kind == "dlrm":
        return mlp_apply(p["bot"], batch["dense"], final_act=True) + \
            _sparse_embs(p, batch, cfg).mean(axis=1)
    # dcnv2 / widedeep: mean-pooled sparse embeddings as the query vector
    return _sparse_embs(p, batch, cfg).mean(axis=1)


def retrieval_scores(p: dict, batch: dict, cfg: RecsysConfig) -> jax.Array:
    """Exact scoring: (B, n_cand). Candidates = rows of table 0, projected."""
    u = user_tower(p, batch, cfg)                                 # (B, D)
    cand = jnp.take(p["tables"][0], batch["cand_ids"], axis=0)    # (C, D)
    return jnp.einsum("bd,cd->bc", u, cand @ p["item_proj"])


def retrieval_topk(p: dict, batch: dict, cfg: RecsysConfig, k: int = 100):
    s = retrieval_scores(p, batch, cfg)
    vals, idx = jax.lax.top_k(s, k)
    return jnp.take(batch["cand_ids"], idx, axis=0), vals


def retrieval_topk_pq(p: dict, batch: dict, cfg: RecsysConfig,
                      pq_codes: jax.Array, centroids: jax.Array,
                      k: int = 100, rerank_mult: int = 4):
    """AiSAQ-mode retrieval: ADC over PQ codes of (projected) candidate
    embeddings, then exact re-rank of the top k*rerank_mult."""
    from repro.kernels import ops
    u = user_tower(p, batch, cfg)                                 # (B, D)
    lut = ops.build_lut(u, centroids, metric="mips")
    d_pq = ops.adc(lut, pq_codes)                                 # (B, C)
    _, pre = jax.lax.top_k(-d_pq, k * rerank_mult)
    cand = jnp.take(p["tables"][0], pre[0], axis=0) @ p["item_proj"]
    exact = jnp.einsum("d,cd->c", u[0], cand)
    vals, idx = jax.lax.top_k(exact, k)
    return jnp.take(pre[0], idx, axis=0)[None], vals[None]
