"""GraphSAGE (Hamilton et al., 2017) in JAX: full-batch, sampled-minibatch,
and batched-small-graph regimes.

Message passing is segment-ops over an edge list (JAX has no CSR SpMM —
DESIGN.md §3): gather source features by edge, segment-reduce onto
destinations. Under pjit the edge list shards over the data axes; partial
segment sums all-reduce automatically.

The minibatch path consumes fanout-sampled neighbor tensors produced by the
host-side `NeighborSampler` (a *real* sampler over CSR adjacency, not a
stub).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GNNConfig
from repro.models.layers import init_dense


def pad_edges(edges: np.ndarray, multiple: int, n_nodes: int) -> np.ndarray:
    """Pad the edge list to a shardable multiple with (n, n) dummy edges.

    Out-of-range segment ids are dropped by jax.ops.segment_sum and the
    clamped source gather contributes only to those dropped segments, so
    dummies are exact no-ops."""
    e = edges.shape[0]
    target = -(-e // multiple) * multiple
    if target == e:
        return edges
    pad = np.full((target - e, 2), n_nodes, edges.dtype)
    return np.concatenate([edges, pad], axis=0)


def init_gnn(rng, cfg: GNNConfig, d_feat: int) -> dict:
    dims = [d_feat] + [cfg.d_hidden] * cfg.n_layers
    keys = jax.random.split(rng, cfg.n_layers + 1)
    layers = []
    for i in range(cfg.n_layers):
        k1, k2 = jax.random.split(keys[i])
        layers.append({
            "w_self": init_dense(k1, (dims[i], dims[i + 1]), jnp.float32),
            "w_neigh": init_dense(k2, (dims[i], dims[i + 1]), jnp.float32),
            "b": jnp.zeros((dims[i + 1],), jnp.float32),
        })
    return {"layers": layers,
            "w_out": init_dense(keys[-1], (cfg.d_hidden, cfg.n_classes),
                                jnp.float32)}


def _aggregate(x_src: jax.Array, dst: jax.Array, n_nodes: int, kind: str,
               dst_degree: Optional[jax.Array] = None) -> jax.Array:
    if kind == "sum":
        return jax.ops.segment_sum(x_src, dst, num_segments=n_nodes)
    if kind == "mean":
        s = jax.ops.segment_sum(x_src, dst, num_segments=n_nodes)
        if dst_degree is None:
            dst_degree = jax.ops.segment_sum(
                jnp.ones_like(dst, jnp.float32), dst, num_segments=n_nodes)
        return s / jnp.maximum(dst_degree, 1.0)[:, None]
    if kind == "max":
        return jax.ops.segment_max(x_src, dst, num_segments=n_nodes)
    raise ValueError(kind)


def gnn_full_forward(params: dict, feats: jax.Array, edges: jax.Array,
                     cfg: GNNConfig) -> jax.Array:
    """feats (N, F), edges (E, 2) [src, dst] -> logits (N, classes)."""
    x = feats
    n = feats.shape[0]
    deg = jax.ops.segment_sum(jnp.ones((edges.shape[0],), jnp.float32),
                              edges[:, 1], num_segments=n)
    for lp in params["layers"]:
        msg = x[edges[:, 0]]                                # gather by edge
        agg = _aggregate(msg, edges[:, 1], n, cfg.aggregator, deg)
        x = jax.nn.relu(x @ lp["w_self"] + agg @ lp["w_neigh"] + lp["b"])
        x = x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-6)
    return x @ params["w_out"]


def gnn_full_loss(params: dict, batch: dict, cfg: GNNConfig):
    logits = gnn_full_forward(params, batch["feats"], batch["edges"], cfg)
    labels, mask = batch["labels"], batch["mask"]
    ls = jax.nn.log_softmax(logits.astype(jnp.float32))
    nll = -jnp.take_along_axis(ls, labels[:, None], axis=1)[:, 0]
    loss = jnp.sum(nll * mask) / jnp.maximum(mask.sum(), 1.0)
    return loss, {"acc": jnp.sum((logits.argmax(-1) == labels) * mask)
                  / jnp.maximum(mask.sum(), 1.0)}


# ---------------------------------------------------------------------------
# sampled minibatch (fanout blocks)
# ---------------------------------------------------------------------------


def gnn_minibatch_forward(params: dict, blocks: dict, cfg: GNNConfig
                          ) -> jax.Array:
    """2-layer fanout forward.

    blocks: seed_feats (B,F); nbr1_feats (B,f1,F); nbr2_feats (B,f1,f2,F).
    (Deeper fanouts generalize the same pattern; cfg fixes 2 layers.)
    """
    l1, l2 = params["layers"][0], params["layers"][1]
    # layer 1 applied at depth-1 nodes: aggregate their depth-2 neighbors
    h_n1 = jax.nn.relu(
        blocks["nbr1_feats"] @ l1["w_self"]
        + blocks["nbr2_feats"].mean(axis=2) @ l1["w_neigh"] + l1["b"])
    h_seed = jax.nn.relu(
        blocks["seed_feats"] @ l1["w_self"]
        + blocks["nbr1_feats"].mean(axis=1) @ l1["w_neigh"] + l1["b"])
    h_n1 = h_n1 / jnp.maximum(jnp.linalg.norm(h_n1, axis=-1, keepdims=True), 1e-6)
    h_seed = h_seed / jnp.maximum(jnp.linalg.norm(h_seed, axis=-1, keepdims=True), 1e-6)
    # layer 2 at seeds: aggregate depth-1 hidden states
    h = jax.nn.relu(h_seed @ l2["w_self"]
                    + h_n1.mean(axis=1) @ l2["w_neigh"] + l2["b"])
    h = h / jnp.maximum(jnp.linalg.norm(h, axis=-1, keepdims=True), 1e-6)
    return h @ params["w_out"]


def gnn_minibatch_loss(params: dict, batch: dict, cfg: GNNConfig):
    logits = gnn_minibatch_forward(params, batch, cfg)
    ls = jax.nn.log_softmax(logits.astype(jnp.float32))
    nll = -jnp.take_along_axis(ls, batch["labels"][:, None], axis=1)[:, 0]
    return nll.mean(), {"acc": (logits.argmax(-1) == batch["labels"]).mean()}


def gnn_batched_forward(params: dict, feats: jax.Array, edges: jax.Array,
                        cfg: GNNConfig) -> jax.Array:
    """Batched small graphs: feats (G, n, F), edges (G, e, 2) -> (G, classes).

    Graph-level readout = mean over nodes (molecule property regime).
    """
    def one(f, e):
        x = f
        n = f.shape[0]
        for lp in params["layers"]:
            agg = _aggregate(x[e[:, 0]], e[:, 1], n, cfg.aggregator)
            x = jax.nn.relu(x @ lp["w_self"] + agg @ lp["w_neigh"] + lp["b"])
        return x.mean(axis=0) @ params["w_out"]
    return jax.vmap(one)(feats, edges)


def gnn_batched_loss(params: dict, batch: dict, cfg: GNNConfig):
    logits = gnn_batched_forward(params, batch["feats"], batch["edges"], cfg)
    ls = jax.nn.log_softmax(logits.astype(jnp.float32))
    nll = -jnp.take_along_axis(ls, batch["labels"][:, None], axis=1)[:, 0]
    return nll.mean(), {"acc": (logits.argmax(-1) == batch["labels"]).mean()}


# ---------------------------------------------------------------------------
# host-side neighbor sampler (real, CSR-based)
# ---------------------------------------------------------------------------


class NeighborSampler:
    """Uniform fanout sampling over CSR adjacency (GraphSAGE §3.1)."""

    def __init__(self, indptr: np.ndarray, indices: np.ndarray, seed: int = 0):
        self.indptr = indptr
        self.indices = indices
        self.rng = np.random.default_rng(seed)

    @classmethod
    def from_edges(cls, edges: np.ndarray, n_nodes: int, seed: int = 0):
        order = np.argsort(edges[:, 1], kind="stable")
        src = edges[order, 0].astype(np.int64)
        dst = edges[order, 1]
        indptr = np.zeros(n_nodes + 1, np.int64)
        np.add.at(indptr, dst + 1, 1)
        indptr = np.cumsum(indptr)
        return cls(indptr, src, seed)

    def sample(self, nodes: np.ndarray, fanout: int) -> np.ndarray:
        """(B,) -> (B, fanout) sampled in-neighbors (self-loop if isolated)."""
        out = np.empty((nodes.shape[0], fanout), np.int64)
        for i, v in enumerate(nodes):
            lo, hi = self.indptr[v], self.indptr[v + 1]
            if hi == lo:
                out[i] = v
            else:
                out[i] = self.indices[
                    lo + self.rng.integers(0, hi - lo, size=fanout)]
        return out

    def sample_blocks(self, seeds: np.ndarray, fanouts: Tuple[int, ...],
                      feats: np.ndarray):
        """Build the 2-hop block tensors for gnn_minibatch_forward."""
        f1, f2 = fanouts
        n1 = self.sample(seeds, f1)                       # (B, f1)
        n2 = self.sample(n1.reshape(-1), f2).reshape(
            seeds.shape[0], f1, f2)                        # (B, f1, f2)
        return {
            "seed_feats": jnp.asarray(feats[seeds]),
            "nbr1_feats": jnp.asarray(feats[n1]),
            "nbr2_feats": jnp.asarray(feats[n2]),
        }
