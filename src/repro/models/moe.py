"""Top-k routed MoE with capacity-factored index dispatch (GShard-style) and
expert parallelism over the `model` mesh axis.

Dispatch is index-based (gather/scatter), NOT dense one-hot einsum: the
(T, E, C) dispatch tensor of the classic GShard formulation is O(T·E·C) and
does not scale to T=65k tokens per device. We compute each (token, slot)'s
position-in-expert with a cumsum over the one-hot assignment — O(T·k·E) int
work — then gather tokens into the (E, C, D) expert batch.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MoEConfig
from repro.distributed.act_sharding import constrain
from repro.models.layers import init_swiglu, truncnorm_init


def init_moe(rng, d_model: int, cfg: MoEConfig, dtype, n_pad_experts: int = 0
             ) -> dict:
    """Router + stacked expert FFNs (+ shared expert)."""
    E = cfg.n_experts + n_pad_experts
    k_r, k_e, k_s = jax.random.split(rng, 3)
    ke = jax.random.split(k_e, 3)
    s_in, s_out = d_model ** -0.5, cfg.d_expert ** -0.5
    p = {
        "router": truncnorm_init(k_r, (d_model, E), s_in, jnp.float32),
        "w_gate": truncnorm_init(ke[0], (E, d_model, cfg.d_expert), s_in, dtype),
        "w_up": truncnorm_init(ke[1], (E, d_model, cfg.d_expert), s_in, dtype),
        "w_down": truncnorm_init(ke[2], (E, cfg.d_expert, d_model), s_out, dtype),
    }
    if cfg.d_shared:
        p["shared"] = init_swiglu(k_s, d_model, cfg.d_shared, dtype)
    return p


def moe_apply(p: dict, x: jax.Array, cfg: MoEConfig, *, capacity: int | None
              = None, n_pad_experts: int = 0, deterministic_capacity: bool = True):
    """x: (T, D) token-major. Returns (out (T, D), aux_loss scalar).

    Padding experts (to make E divisible by the EP axis) are masked to
    -inf router logits so they never receive tokens.
    """
    T, D = x.shape
    E = cfg.n_experts + n_pad_experts
    k = cfg.top_k
    if capacity is None:
        capacity = max(8, int(cfg.capacity_factor * T * k / cfg.n_experts))
    logits = (x.astype(jnp.float32) @ p["router"])          # (T, E)
    if n_pad_experts:
        pad_mask = jnp.arange(E) >= cfg.n_experts
        logits = jnp.where(pad_mask[None, :], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, top_ids = jax.lax.top_k(probs, k)             # (T, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    # ---- aux load-balancing loss (Switch) --------------------------------
    me = probs.mean(axis=0)                                  # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[top_ids.reshape(-1)].add(1.0) / (T * k)
    aux = cfg.router_aux_weight * cfg.n_experts * jnp.sum(me * ce)
    # ---- position-in-expert via cumsum over one-hot ----------------------
    flat_e = top_ids.reshape(-1)                             # (T*k,)
    oh = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)          # (T*k, E)
    pos = jnp.cumsum(oh, axis=0) - oh                        # entries before me
    pos_in_e = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    keep = pos_in_e < capacity
    # ---- dispatch: (E, C) slot -> token row ------------------------------
    # init -1; dropped pairs write -1 (no-op under max); empty slots then
    # point at the zero pad row T.
    tok_of_slot = jnp.full((E, capacity), -1, jnp.int32)
    src_rows = jnp.arange(T * k, dtype=jnp.int32) // k
    tok_of_slot = tok_of_slot.at[
        jnp.where(keep, flat_e, E - 1),
        jnp.where(keep, pos_in_e, capacity - 1)].max(
        jnp.where(keep, src_rows, -1))
    tok_of_slot = jnp.where(tok_of_slot < 0, T, tok_of_slot)
    xpad = constrain(
        jnp.concatenate([x, jnp.zeros((1, D), x.dtype)], axis=0),
        "moe_tokens")
    xe = constrain(xpad[tok_of_slot], "moe_expert")          # (E, C, D)
    # ---- expert FFN (einsum over stacked experts; EP-sharded on E) -------
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"]))
    u = jnp.einsum("ecd,edf->ecf", xe, p["w_up"])
    y = jnp.einsum("ecf,efd->ecd", g * u, p["w_down"])       # (E, C, D)
    # ---- combine: gather slots back per (token, k) -----------------------
    slot_of_tok = jnp.where(keep, flat_e * capacity + pos_in_e, E * capacity)
    ypad = jnp.concatenate(
        [y.reshape(E * capacity, D), jnp.zeros((1, D), y.dtype)], axis=0)
    yk = ypad[slot_of_tok].reshape(T, k, D)
    out = jnp.einsum("tkd,tk->td", yk.astype(jnp.float32),
                     gate_vals).astype(x.dtype)
    if "shared" in p:
        from repro.models.layers import swiglu
        out = out + swiglu(p["shared"], x)
    return out, aux


# ---------------------------------------------------------------------------
# explicit expert parallelism (§Perf "moe-ep")
# ---------------------------------------------------------------------------


def moe_apply_ep(p: dict, x: jax.Array, cfg: MoEConfig, *,
                 n_pad_experts: int = 0):
    """Replicated-dispatch EP via shard_map (REPRO_MOE=ep).

    The GSPMD global-dispatch formulation gathers the full token tensor per
    expert shard (pathological once the `pod` axis exists — see §Perf
    "moe-disp"). Here tokens stay in their dp shard (replicated across
    `model`), each `model` rank dispatches ONLY its own experts' capacity
    buffers locally, and the single collective is one psum of the (T_loc, D)
    combined output per layer. Bitwise-equal to moe_apply when nothing is
    dropped (same routing, same capacity semantics per dp group).

    Falls back to moe_apply when no mesh policy is installed.
    """
    from repro.distributed import act_sharding
    mesh = act_sharding._MESH
    if mesh is None or "model" not in mesh.axis_names:
        return moe_apply(p, x, cfg, n_pad_experts=n_pad_experts)
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    mways = mesh.shape["model"]
    E = cfg.n_experts + n_pad_experts
    T, D = x.shape
    k = cfg.top_k
    capacity = max(8, int(cfg.capacity_factor * (T // max(
        1, np.prod([mesh.shape[a] for a in dp]))) * k / cfg.n_experts))

    def local(xl, router, wg, wu, wd, shared):
        # xl (T_loc, D); router (D, E); wg/wu (E_loc, D, F); wd (E_loc, F, D)
        rank = jax.lax.axis_index("model")
        E_loc = wg.shape[0]
        Tl = xl.shape[0]
        logits = xl.astype(jnp.float32) @ router
        if n_pad_experts:
            logits = jnp.where(jnp.arange(E) >= cfg.n_experts, -1e30, logits)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, top_ids = jax.lax.top_k(probs, k)
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(-1, keepdims=True), 1e-9)
        me = probs.mean(axis=0)
        ce = jnp.zeros((E,), jnp.float32).at[top_ids.reshape(-1)].add(1.0) \
            / (Tl * k)
        aux = cfg.router_aux_weight * cfg.n_experts * jnp.sum(me * ce)
        aux = jax.lax.pmean(aux, dp) if dp else aux
        # position-in-expert over GLOBAL expert ids (identical on all
        # model ranks — xl is replicated across `model`)
        flat_e = top_ids.reshape(-1)
        oh = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
        pos = jnp.cumsum(oh, axis=0) - oh
        pos_in_e = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
        mine = (flat_e >= rank * E_loc) & (flat_e < (rank + 1) * E_loc)
        keep = (pos_in_e < capacity) & mine
        e_loc = jnp.where(keep, flat_e - rank * E_loc, E_loc - 1)
        tok_of_slot = jnp.full((E_loc, capacity), -1, jnp.int32)
        src_rows = jnp.arange(Tl * k, dtype=jnp.int32) // k
        tok_of_slot = tok_of_slot.at[
            jnp.where(keep, e_loc, E_loc - 1),
            jnp.where(keep, pos_in_e, capacity - 1)].max(
            jnp.where(keep, src_rows, -1))
        tok_of_slot = jnp.where(tok_of_slot < 0, Tl, tok_of_slot)
        xpad = jnp.concatenate([xl, jnp.zeros((1, D), xl.dtype)], axis=0)
        xe = xpad[tok_of_slot]                           # (E_loc, C, D)
        g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, wg))
        u = jnp.einsum("ecd,edf->ecf", xe, wu)
        y = jnp.einsum("ecf,efd->ecd", g * u, wd)        # (E_loc, C, D)
        slot = jnp.where(keep, e_loc * capacity + pos_in_e, E_loc * capacity)
        ypad = jnp.concatenate(
            [y.reshape(E_loc * capacity, D), jnp.zeros((1, D), y.dtype)], 0)
        yk = ypad[slot].reshape(Tl, k, D)
        out = jnp.einsum("tkd,tk->td", yk.astype(jnp.float32), gate_vals)
        out = jax.lax.psum(out.astype(jnp.float32), "model").astype(xl.dtype)
        if shared is not None:
            from repro.models.layers import swiglu
            out = out + swiglu(shared, xl)
        return out, aux

    shared = p.get("shared")
    sh_specs = jax.tree.map(lambda _: P(), shared) if shared is not None \
        else None
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(dp, None), P(), P("model", None, None),
                  P("model", None, None), P("model", None, None), sh_specs),
        out_specs=(P(dp, None), P()),
        check_rep=False)
    return fn(x, p["router"], p["w_gate"], p["w_up"], p["w_down"], shared)
