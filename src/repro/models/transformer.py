"""Decoder-only LM: dense or MoE, full/sliding/chunked-global attention.

Layout decisions that matter at scale:
  * layer params are stacked (L, ...) and the forward is a lax.scan over
    layers -> HLO stays O(1) in depth (compile time on 512-way SPMD).
  * remat (jax.checkpoint) wraps the scan body.
  * the LM loss is computed in sequence chunks (scan) so the (B, S, V)
    logits tensor is never materialized — V=150k-200k vocabs make the full
    tensor 10s of GB at 4k sequence.
  * decode keeps a (L, B, T, KVH, hd) KV cache, updated inside the layer
    scan; the T dim may be sharded over the `model` axis (SP decode).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

import os as _os

from repro.configs.base import LMConfig
from repro.distributed.act_sharding import constrain
from repro.models import layers as L
from repro.models.moe import init_moe, moe_apply, moe_apply_ep


def _moe_fn():
    """Global-dispatch (GSPMD) vs explicit shard_map EP (REPRO_MOE=ep)."""
    return moe_apply_ep if _os.environ.get("REPRO_MOE") == "ep" \
        else moe_apply


def _dt(cfg: LMConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_lm(rng: jax.Array, cfg: LMConfig, *, ep: int = 1) -> dict:
    """ep: size of the expert-parallel axis (experts padded to multiple)."""
    dt = _dt(cfg)
    k_e, k_l, k_h = jax.random.split(rng, 3)
    D, Hhd, KVhd = cfg.d_model, cfg.q_dim, cfg.kv_dim

    def init_layer(k):
        ks = jax.random.split(k, 8)
        s = D ** -0.5
        attn = {
            "w_q": L.truncnorm_init(ks[0], (D, Hhd), s, dt),
            "w_k": L.truncnorm_init(ks[1], (D, KVhd), s, dt),
            "w_v": L.truncnorm_init(ks[2], (D, KVhd), s, dt),
            "w_o": L.truncnorm_init(ks[3], (Hhd, D), Hhd ** -0.5, dt),
        }
        if cfg.qkv_bias:
            attn["b_q"] = jnp.zeros((Hhd,), dt)
            attn["b_k"] = jnp.zeros((KVhd,), dt)
            attn["b_v"] = jnp.zeros((KVhd,), dt)
        if cfg.qk_norm:
            attn["q_norm"] = jnp.ones((cfg.head_dim,), jnp.float32)
            attn["k_norm"] = jnp.ones((cfg.head_dim,), jnp.float32)
        p = {"attn": attn,
             "ln1": jnp.ones((D,), jnp.float32),
             "ln2": jnp.ones((D,), jnp.float32)}
        if cfg.moe is None:
            p["ffn"] = L.init_swiglu(ks[4], D, cfg.d_ff, dt)
        else:
            n_pad = cfg.moe.padded_experts(ep) - cfg.moe.n_experts
            p["moe"] = init_moe(ks[5], D, cfg.moe, dt, n_pad_experts=n_pad)
        return p

    params = {
        "embed": L.truncnorm_init(k_e, (cfg.vocab_size, D), 0.02, dt),
        "layers": jax.vmap(init_layer)(jax.random.split(k_l, cfg.n_layers)),
        "final_norm": jnp.ones((D,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.truncnorm_init(k_h, (D, cfg.vocab_size),
                                             D ** -0.5, dt)
    return params


def _is_global_layer(cfg: LMConfig, li: jax.Array) -> jax.Array:
    """llama4 iRoPE: every `global_every`-th layer attends globally (NoPE)."""
    return (li % cfg.global_every) == (cfg.global_every - 1)


# ---------------------------------------------------------------------------
# attention wrapper (one layer)
# ---------------------------------------------------------------------------


def _attn(p: dict, x: jax.Array, cfg: LMConfig, *, positions: jax.Array,
          li: jax.Array, cache: Optional[Tuple[jax.Array, jax.Array]] = None,
          cache_pos: Optional[jax.Array] = None, train: bool = False):
    # dynamic-trip-count block skipping is not reverse-differentiable:
    # training takes the masked full scan (see EXPERIMENTS.md §Perf for the
    # custom-VJP flash iteration), inference skips out-of-band blocks.
    skip = not train
    B, S, D = x.shape
    H, KVH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = x @ p["w_q"]
    k = x @ p["w_k"]
    v = x @ p["w_v"]
    if cfg.qkv_bias:
        q, k, v = q + p["b_q"], k + p["b_k"], v + p["b_v"]
    q = constrain(q.reshape(B, S, H, hd), "qkv")
    k = constrain(k.reshape(B, S, KVH, hd), "qkv")
    v = constrain(v.reshape(B, S, KVH, hd), "qkv")
    if cfg.qk_norm:
        q = L.rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = L.rms_norm(k, p["k_norm"], cfg.norm_eps)

    glob = _is_global_layer(cfg, li) if cfg.attention == "chunked_global" \
        else jnp.array(False)

    def roped(qk):
        qq, kk = qk
        return (L.rope(qq, positions, cfg.rope_theta),
                L.rope(kk, positions, cfg.rope_theta))

    if cfg.attention == "chunked_global":
        # global layers are NoPE (llama4): skip rope there
        q, k = jax.lax.cond(glob, lambda qk: qk, roped, (q, k))
    else:
        q, k = roped((q, k))

    if cache is None:
        import os as _os
        from repro.distributed.act_sharding import cp_attention_wrap
        use_vjp = train and _os.environ.get("REPRO_FLASH", "vjp") == "vjp"

        def attend(qkv, window=0, chunked=False):
            def fn(qq, kk, vv, off):
                # adapt block sizes: CP shards may hold < 512 q rows
                bq = min(512, qq.shape[1])
                bk = min(1024, kk.shape[1])
                return L.flash_attention_vjp(qq, kk, vv, off, True, window,
                                             chunked, bq, bk)
            # context-parallel attention: q sequence sharded over `model`
            # (§Perf "cp-attn"); applies to train AND prefill
            cp = cp_attention_wrap(fn, qkv[0].shape[1])
            if cp is not None:
                return cp(*qkv)
            if use_vjp:
                # custom-VJP flash: O(S) residuals + block skipping in both
                # passes (EXPERIMENTS.md §Perf "flash-vjp")
                return fn(*qkv, jnp.int32(0))
            return L.flash_attention(qkv[0], qkv[1], qkv[2], causal=True,
                                     window=window, chunked=chunked,
                                     skip_blocks=skip)

        if cfg.attention == "full":
            o = attend((q, k, v))
        elif cfg.attention == "sliding":
            o = attend((q, k, v), window=cfg.window)
        else:  # chunked_global
            o = jax.lax.cond(
                glob,
                lambda qkv: attend(qkv),
                lambda qkv: attend(qkv, window=cfg.window, chunked=True),
                (q, k, v))
        new_cache = None
    else:
        kc, vc = cache                                   # (B, T, KVH, hd)
        kc = jax.lax.dynamic_update_slice(kc, k, (0, cache_pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v, (0, cache_pos, 0, 0))
        clen = jnp.full((B,), cache_pos + 1, jnp.int32)
        if cfg.attention == "full":
            o = L.decode_attention(q, kc, vc, clen)
        elif cfg.attention == "sliding":
            o = L.decode_attention(q, kc, vc, clen, window=cfg.window)
        else:
            o = jax.lax.cond(
                glob,
                lambda a: L.decode_attention(a[0], a[1], a[2], clen),
                lambda a: L.decode_attention(a[0], a[1], a[2], clen,
                                             window=cfg.window, chunked=True),
                (q, kc, vc))
        new_cache = (kc, vc)
    out = o.reshape(B, S, H * hd) @ p["w_o"]
    return constrain(out.astype(x.dtype), "hidden"), new_cache


# ---------------------------------------------------------------------------
# forward / loss / decode
# ---------------------------------------------------------------------------


def lm_hidden(params: dict, tokens: jax.Array, cfg: LMConfig, *,
              train: bool = False) -> tuple:
    """(B, S) -> final hidden states (B, S, D) + total aux loss."""
    x = constrain(params["embed"][tokens].astype(_dt(cfg)), "hidden")
    B, S, D = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def block(carry, scanned):
        x, aux = carry
        lp, li = scanned
        h, _ = _attn(lp["attn"], L.rms_norm(x, lp["ln1"], cfg.norm_eps), cfg,
                     positions=positions, li=li, train=train)
        x = constrain(x + h, "hidden")
        y = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
        if cfg.moe is None:
            f = L.swiglu(lp["ffn"], y)
            a = jnp.float32(0)
        else:
            f, a = _moe_fn()(lp["moe"], y.reshape(B * S, D), cfg.moe,
                             n_pad_experts=lp["moe"]["router"].shape[-1]
                             - cfg.moe.n_experts)
            f = f.reshape(B, S, D)
        return (constrain(x + f, "hidden"), aux + a), None

    body = jax.checkpoint(block) if cfg.remat else block
    (x, aux), _ = jax.lax.scan(
        body, (x, jnp.float32(0)),
        (params["layers"], jnp.arange(cfg.n_layers)))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, aux


def _unembed(params: dict, cfg: LMConfig):
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


def chunked_xent(hidden: jax.Array, w_out: jax.Array, labels: jax.Array,
                 *, chunk: int = 512) -> jax.Array:
    """Mean token cross-entropy without materializing (B, S, V) logits."""
    B, S, D = hidden.shape
    nc = max(1, S // chunk)
    hc = hidden.reshape(B, nc, S // nc, D).swapaxes(0, 1)
    lc = labels.reshape(B, nc, S // nc).swapaxes(0, 1)

    def one(chunk_in):
        h, lab = chunk_in
        logits = constrain((h @ w_out).astype(jnp.float32), "logits_v")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
        return (lse - gold).sum()

    # remat: never keep a chunk's (B, c, V) logits as backward residuals
    tot = jax.lax.map(jax.checkpoint(one), (hc, lc)).sum()
    return tot / (B * S)


def lm_loss(params: dict, batch: dict, cfg: LMConfig) -> tuple:
    """batch: {'tokens': (B,S), 'labels': (B,S)} -> (loss, metrics)."""
    hidden, aux = lm_hidden(params, batch["tokens"], cfg, train=True)
    xent = chunked_xent(hidden, _unembed(params, cfg), batch["labels"])
    return xent + aux, {"xent": xent, "aux": aux}


def lm_prefill(params: dict, tokens: jax.Array, cfg: LMConfig) -> jax.Array:
    """Prefill forward -> next-token logits at the last position (B, V)."""
    hidden, _ = lm_hidden(params, tokens, cfg)
    return (hidden[:, -1] @ _unembed(params, cfg)).astype(jnp.float32)


class DecodeCache(NamedTuple):
    k: jax.Array          # (L, B, T, KVH, hd)
    v: jax.Array


def init_cache(cfg: LMConfig, batch: int, max_len: int, dtype=None
               ) -> DecodeCache:
    dt = dtype or _dt(cfg)
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return DecodeCache(jnp.zeros(shape, dt), jnp.zeros(shape, dt))


def lm_decode_step(params: dict, cache: DecodeCache, token: jax.Array,
                   pos: jax.Array, cfg: LMConfig):
    """One decode step. token: (B,) int32; pos: scalar int32 (append index).

    Returns (logits (B, V) f32, updated cache).
    """
    x = params["embed"][token][:, None, :].astype(_dt(cfg))   # (B, 1, D)
    B = x.shape[0]
    positions = jnp.broadcast_to(pos[None, None], (B, 1)).astype(jnp.int32)

    # the cache rides in the CARRY (not xs/ys): scan xs->ys stacking double-
    # buffers the (L,B,T,KVH,hd) array, which alone blew the decode memory
    # budget at 500k context; carried buffers update in place.
    def block(carry, scanned):
        x, kfull, vfull = carry
        lp, li = scanned
        kc = jax.lax.dynamic_index_in_dim(kfull, li, 0, keepdims=False)
        vc = jax.lax.dynamic_index_in_dim(vfull, li, 0, keepdims=False)
        h, new_cache = _attn(lp["attn"], L.rms_norm(x, lp["ln1"], cfg.norm_eps),
                             cfg, positions=positions, li=li,
                             cache=(kc, vc), cache_pos=pos)
        kfull = jax.lax.dynamic_update_index_in_dim(kfull, new_cache[0], li, 0)
        vfull = jax.lax.dynamic_update_index_in_dim(vfull, new_cache[1], li, 0)
        x = x + h
        y = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
        if cfg.moe is None:
            f = L.swiglu(lp["ffn"], y)
        else:
            f, _ = _moe_fn()(lp["moe"], y.reshape(B, -1), cfg.moe,
                             n_pad_experts=lp["moe"]["router"].shape[-1]
                             - cfg.moe.n_experts)
            f = f.reshape(B, 1, -1)
        return (x + f, kfull, vfull), None

    (x, nk, nv), _ = jax.lax.scan(
        block, (x, cache.k, cache.v),
        (params["layers"], jnp.arange(cfg.n_layers)))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (x[:, 0] @ _unembed(params, cfg)).astype(jnp.float32)
    return logits, DecodeCache(nk, nv)
