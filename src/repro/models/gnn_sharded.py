"""Locality-aware sharded full-batch GraphSAGE (§Perf iteration "gnn-part").

Baseline gnn_full_forward keeps node states replicated: every layer's
aggregation ends in an all-reduce of the full (N, H) state — the dominant
roofline term for ogb_products. This version:

  * partitions nodes into contiguous ranges, one per device (over the
    combined (data, model) axes),
  * pre-partitions EDGES by destination shard (host-side, exact —
    `partition_edges`), so segment-sum aggregation is purely LOCAL,
  * keeps only one collective per layer: the all-gather of the (N_local, H)
    hidden states needed for the next layer's source gathers (bf16 on the
    wire — §Perf iteration "gnn-bf16").

Collective bytes per layer drop from ~2·N·H·4 (all-reduce, f32) to
N·H·2 (all-gather, bf16): ~4x.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.configs.base import GNNConfig


def partition_edges(edges: np.ndarray, n_nodes: int, ways: int
                    ) -> Tuple[np.ndarray, int]:
    """Group edges by destination shard; pad shards to equal length with
    (n, n) dummies (dropped by segment ops). Returns ((ways, E_pad, 2), n_local)."""
    n_local = -(-n_nodes // ways)
    shard = edges[:, 1] // n_local
    order = np.argsort(shard, kind="stable")
    edges = edges[order]
    shard = shard[order]
    counts = np.bincount(shard, minlength=ways)
    e_pad = -(-int(counts.max()) // 8) * 8
    out = np.full((ways, e_pad, 2), n_nodes, dtype=np.int32)
    pos = 0
    for s in range(ways):
        c = counts[s]
        out[s, :c] = edges[pos:pos + c]
        pos += c
    return out, n_local


def sharded_full_loss_fn(mesh, cfg: GNNConfig, n_nodes: int,
                         axes=("data", "model"), wire_dtype=jnp.bfloat16):
    """Returns loss_fn(params, batch) with batch['edges'] pre-partitioned
    (ways, E_pad, 2); feats/labels/mask replicated."""
    ways = 1
    for a in axes:
        ways *= mesh.shape[a]
    n_local = -(-n_nodes // ways)
    n_pad = n_local * ways

    def local(params, feats, edges, labels, mask):
        edges = edges[0]                                 # (E_pad, 2)
        rank = jax.lax.axis_index(axes)
        lo = rank * n_local
        src, dst = edges[:, 0], edges[:, 1]
        dst_local = jnp.where(dst < n_nodes, dst - lo, n_local)
        x_glob = feats                                   # (N, F) replicated
        h_local = None
        deg = jax.ops.segment_sum(
            (dst < n_nodes).astype(jnp.float32), dst_local,
            num_segments=n_local)
        for li, lp in enumerate(params["layers"]):
            msg = jnp.take(x_glob, jnp.clip(src, 0, n_nodes - 1), axis=0)
            msg = jnp.where((src < n_nodes)[:, None], msg, 0.0)
            agg = jax.ops.segment_sum(msg, dst_local, num_segments=n_local)
            if cfg.aggregator == "mean":
                agg = agg / jnp.maximum(deg, 1.0)[:, None]
            x_self = jax.lax.dynamic_slice_in_dim(
                jnp.pad(x_glob, ((0, n_pad - x_glob.shape[0]), (0, 0))),
                lo, n_local, axis=0)
            h_local = jax.nn.relu(x_self @ lp["w_self"]
                                  + agg @ lp["w_neigh"] + lp["b"])
            h_local = h_local / jnp.maximum(
                jnp.linalg.norm(h_local, axis=-1, keepdims=True), 1e-6)
            if li + 1 < len(params["layers"]):
                # ONE collective: all-gather next layer's inputs (bf16 wire)
                x_glob = jax.lax.all_gather(
                    h_local.astype(wire_dtype), axes, axis=0, tiled=True
                ).astype(jnp.float32)[:n_nodes]
        logits_local = h_local @ params["w_out"]         # (n_local, C)
        lab_pad = jnp.pad(labels, (0, n_pad - labels.shape[0]))
        msk_pad = jnp.pad(mask, (0, n_pad - mask.shape[0]))
        lab_l = jax.lax.dynamic_slice_in_dim(lab_pad, lo, n_local)
        msk_l = jax.lax.dynamic_slice_in_dim(msk_pad, lo, n_local)
        ls = jax.nn.log_softmax(logits_local.astype(jnp.float32))
        nll = -jnp.take_along_axis(ls, lab_l[:, None], axis=1)[:, 0]
        loss_num = jax.lax.psum(jnp.sum(nll * msk_l), axes)
        loss_den = jax.lax.psum(jnp.sum(msk_l), axes)
        acc_num = jax.lax.psum(
            jnp.sum((logits_local.argmax(-1) == lab_l) * msk_l), axes)
        return loss_num / jnp.maximum(loss_den, 1.0), \
            acc_num / jnp.maximum(loss_den, 1.0)

    smapped = shard_map(
        local, mesh=mesh,
        in_specs=(P(), P(), P(axes, None, None), P(), P()),
        out_specs=(P(), P()),
        check_rep=False)

    def loss_fn(params, batch):
        loss, acc = smapped(params, batch["feats"], batch["edges"],
                            batch["labels"], batch["mask"])
        return loss, {"acc": acc}

    return loss_fn
