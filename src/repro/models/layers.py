"""Shared NN layers: norms, RoPE, GQA attention (full / sliding / chunked),
blockwise flash-style attention in pure jnp, SwiGLU MLP.

Parameters are plain nested dicts; every init_* has a matching spec_* in
repro/distributed/sharding.py giving its PartitionSpec.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def truncnorm_init(rng, shape, scale, dtype):
    return (scale * jax.random.truncated_normal(rng, -2.0, 2.0, shape,
                                                jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# norms / rope
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dt)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd), positions: (..., S) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None, None].astype(jnp.float32) * freq  # (...,S,1,half)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = x[..., :half], x[..., half:]
    dt = x.dtype
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([x1f * cos - x2f * sin, x2f * cos + x1f * sin],
                           axis=-1).astype(dt)


# ---------------------------------------------------------------------------
# attention masks
# ---------------------------------------------------------------------------


def _block_mask(q_pos: jax.Array, k_pos: jax.Array, *, causal: bool,
                window: int, chunked: bool) -> jax.Array:
    """(bq,), (bk,) position vectors -> (bq, bk) bool allowed-mask."""
    qp = q_pos[:, None]
    kp = k_pos[None, :]
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m &= kp <= qp
    if window > 0 and not chunked:
        m &= qp - kp < window          # sliding window
    if window > 0 and chunked:
        m &= (qp // window) == (kp // window)   # llama4 local chunks
    return m


# ---------------------------------------------------------------------------
# blockwise "flash" attention (pure jnp, O(S*block) memory)
# ---------------------------------------------------------------------------


NEG_INF = -1e30


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "chunked", "block_q", "block_kv",
                     "skip_blocks"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    chunked: bool = False, block_q: int = 512,
                    block_kv: int = 1024, q_offset: int = 0,
                    skip_blocks: bool = True) -> jax.Array:
    """Memory-efficient GQA attention.

    q: (B, Sq, H, hd); k, v: (B, Skv, KVH, hd) with H % KVH == 0.
    Lazy-softmax scan over KV blocks per Q block; never materializes the
    (Sq, Skv) score matrix. `skip_blocks` skips fully-masked KV blocks via a
    dynamic-trip-count fori_loop (causal/banded block pruning).
    """
    B, Sq, H, hd = q.shape
    _, Skv, KVH, _ = k.shape
    G = H // KVH
    scale = hd ** -0.5
    nq = -(-Sq // block_q)
    nk = -(-Skv // block_kv)
    qpad, kpad = nq * block_q - Sq, nk * block_kv - Skv
    qf = jnp.pad(q, ((0, 0), (0, qpad), (0, 0), (0, 0)))
    kf = jnp.pad(k, ((0, 0), (0, kpad), (0, 0), (0, 0)))
    vf = jnp.pad(v, ((0, 0), (0, kpad), (0, 0), (0, 0)))
    # GQA convention: q head h serves kv head h // G (kv-major layout)
    qf = qf.reshape(B, nq, block_q, KVH, G, hd)
    kf = kf.reshape(B, nk, block_kv, KVH, hd)
    vf = vf.reshape(B, nk, block_kv, KVH, hd)

    def q_block(qi):
        qb = qf[:, qi]                                # (B, bq, KVH, G, hd)
        qb = jnp.einsum("bqkgd->bkgqd", qb) * scale
        q_pos = q_offset + qi * block_q + jnp.arange(block_q)

        def kv_step(carry, ki):
            m_run, l_run, acc = carry
            kb = kf[:, ki]                            # (B, bk, KVH, hd)
            vb = vf[:, ki]
            k_pos = ki * block_kv + jnp.arange(block_kv)
            s = jnp.einsum("bkgqd,btkd->bkgqt", qb, kb,
                           preferred_element_type=jnp.float32)
            mask = _block_mask(q_pos, k_pos, causal=causal, window=window,
                               chunked=chunked)
            mask = mask & (k_pos < Skv)[None, :] & (q_pos < Sq + q_offset)[:, None]
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m_run, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            # fully-masked blocks: exp(NEG_INF - NEG_INF) = 1 — zero it out
            p = jnp.where(mask[None, None, None], p, 0.0)
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + p.sum(-1)
            pv = jnp.einsum("bkgqt,btkd->bkgqd", p.astype(vb.dtype), vb,
                            preferred_element_type=jnp.float32)
            acc = acc * corr[..., None] + pv
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, KVH, G, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KVH, G, block_q), jnp.float32)
        a0 = jnp.zeros((B, KVH, G, block_q, hd), jnp.float32)
        if skip_blocks and causal and Sq == Skv and q_offset == 0:
            # only kv blocks intersecting the allowed band contribute
            hi = jnp.minimum(
                (qi * block_q + block_q + block_kv - 1) // block_kv, nk)
            lo = jnp.maximum(
                0, (qi * block_q - (window - 1)) // block_kv) if window > 0 \
                else jnp.int32(0)
            if window > 0 and chunked:
                lo = (qi * block_q) // window * window // block_kv

            def body(i, carry):
                c, _ = kv_step(carry, i)
                return c
            m1, l1, acc = jax.lax.fori_loop(lo, hi, body, (m0, l0, a0))
        else:
            (m1, l1, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                            jnp.arange(nk))
        out = acc / jnp.maximum(l1, 1e-30)[..., None]
        return jnp.einsum("bkgqd->bqkgd", out)        # (B, bq, KVH, G, hd)

    # remat each q block: backward recomputes the block's score tiles instead
    # of saving the (B, H, bq, Skv) residuals of every block simultaneously
    out = jax.lax.map(jax.checkpoint(q_block), jnp.arange(nq))
    out = jnp.moveaxis(out, 0, 1).reshape(B, nq * block_q, KVH, G, hd)
    return out[:, :Sq].reshape(B, Sq, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# custom-VJP flash attention (FA2-style): O(S) residuals, block-skipping in
# BOTH passes (the backward is hand-written, so dynamic-trip-count loops are
# fine). This is §Perf iteration "flash-vjp"; REPRO_FLASH=naive selects the
# differentiated masked-scan baseline above.
# ---------------------------------------------------------------------------


def _band_bounds(qi: jax.Array, q_off, *, causal, window, chunked, block_q,
                 block_kv, nk, Skv_valid):
    """kv-block range [lo, hi) intersecting q block `qi`'s allowed band.

    `q_off` is the GLOBAL position offset of this shard's q rows (context-
    parallel attention shards the q sequence over the `model` axis)."""
    q0 = qi * block_q + q_off
    hi = jnp.minimum((q0 + block_q + block_kv - 1) // block_kv, nk)
    if not causal:
        hi = jnp.int32(nk)
    lo = jnp.int32(0)
    if window > 0 and not chunked:
        lo = jnp.maximum(0, (q0 - (window - 1)) // block_kv)
    if window > 0 and chunked:
        lo = q0 // window * window // block_kv
    return lo, hi


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8))
def flash_attention_vjp(q, k, v, q_off, causal=True, window=0, chunked=False,
                        block_q=512, block_kv=1024):
    out, _ = _flash_fwd(q, k, v, q_off, causal, window, chunked, block_q,
                        block_kv)
    return out


def _flash_body(q, k, v, q_off, causal, window, chunked, block_q, block_kv):
    """Shared fwd: returns out (B,Sq,H,hd) and lse (B,KVH,G,nqb*bq).

    q_off: scalar int — global offset of q row 0 (0 unless context-parallel).
    """
    B, Sq, H, hd = q.shape
    _, Skv, KVH, _ = k.shape
    G = H // KVH
    scale = hd ** -0.5
    nq, nk = -(-Sq // block_q), -(-Skv // block_kv)
    qf = jnp.pad(q, ((0, 0), (0, nq * block_q - Sq), (0, 0), (0, 0)))
    kf = jnp.pad(k, ((0, 0), (0, nk * block_kv - Skv), (0, 0), (0, 0)))
    vf = jnp.pad(v, ((0, 0), (0, nk * block_kv - Skv), (0, 0), (0, 0)))
    qf = qf.reshape(B, nq, block_q, KVH, G, hd)
    kf = kf.reshape(B, nk, block_kv, KVH, hd)
    vf = vf.reshape(B, nk, block_kv, KVH, hd)

    def q_block(qi):
        qb = jnp.einsum("bqkgd->bkgqd", qf[:, qi]) * scale
        q_pos = q_off + qi * block_q + jnp.arange(block_q)

        def kv_step(ki, carry):
            m_run, l_run, acc = carry
            k_pos = ki * block_kv + jnp.arange(block_kv)
            s = jnp.einsum("bkgqd,btkd->bkgqt", qb, kf[:, ki],
                           preferred_element_type=jnp.float32)
            mask = _block_mask(q_pos, k_pos, causal=causal, window=window,
                               chunked=chunked)
            mask &= (k_pos < Skv)[None, :] & \
                (q_pos - q_off < Sq)[:, None]
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m_run, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            p = jnp.where(mask[None, None, None], p, 0.0)
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + p.sum(-1)
            pv = jnp.einsum("bkgqt,btkd->bkgqd", p.astype(vf.dtype),
                            vf[:, ki], preferred_element_type=jnp.float32)
            return m_new, l_new, acc * corr[..., None] + pv

        m0 = jnp.full((B, KVH, G, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KVH, G, block_q), jnp.float32)
        a0 = jnp.zeros((B, KVH, G, block_q, hd), jnp.float32)
        lo, hi = _band_bounds(qi, q_off, causal=causal, window=window,
                              chunked=chunked, block_q=block_q,
                              block_kv=block_kv, nk=nk, Skv_valid=Skv)
        m1, l1, acc = jax.lax.fori_loop(lo, hi, kv_step, (m0, l0, a0))
        o = acc / jnp.maximum(l1, 1e-30)[..., None]
        lse = m1 + jnp.log(jnp.maximum(l1, 1e-30))
        return jnp.einsum("bkgqd->bqkgd", o), lse

    outs, lses = jax.lax.map(q_block, jnp.arange(nq))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, nq * block_q, KVH, G, hd)
    out = out[:, :Sq].reshape(B, Sq, H, hd).astype(q.dtype)
    lse = jnp.moveaxis(lses, 0, 3).reshape(B, KVH, G, nq * block_q)
    return out, lse


def _flash_fwd(q, k, v, q_off, causal, window, chunked, block_q, block_kv):
    out, lse = _flash_body(q, k, v, q_off, causal, window, chunked, block_q,
                           block_kv)
    return out, (q, k, v, q_off, out, lse)


def _flash_bwd(causal, window, chunked, block_q, block_kv, res, dout):
    q, k, v, q_off, out, lse = res
    B, Sq, H, hd = q.shape
    _, Skv, KVH, _ = k.shape
    G = H // KVH
    scale = hd ** -0.5
    nq, nk = -(-Sq // block_q), -(-Skv // block_kv)
    qf = jnp.pad(q, ((0, 0), (0, nq * block_q - Sq), (0, 0), (0, 0)))
    kf = jnp.pad(k, ((0, 0), (0, nk * block_kv - Skv), (0, 0), (0, 0)))
    vf = jnp.pad(v, ((0, 0), (0, nk * block_kv - Skv), (0, 0), (0, 0)))
    dof = jnp.pad(dout.astype(jnp.float32),
                  ((0, 0), (0, nq * block_q - Sq), (0, 0), (0, 0)))
    of = jnp.pad(out.astype(jnp.float32),
                 ((0, 0), (0, nq * block_q - Sq), (0, 0), (0, 0)))
    qf = qf.reshape(B, nq, block_q, KVH, G, hd)
    kf = kf.reshape(B, nk, block_kv, KVH, hd)
    vf = vf.reshape(B, nk, block_kv, KVH, hd)
    # (B, nq, bq, KVH, G, hd) -> (B, KVH, G, nq, bq, hd)
    dof = jnp.transpose(dof.reshape(B, nq, block_q, KVH, G, hd),
                        (0, 3, 4, 1, 2, 5))
    of = jnp.transpose(of.reshape(B, nq, block_q, KVH, G, hd),
                       (0, 3, 4, 1, 2, 5))
    # D_i = rowsum(dout * out)  (B,KVH,G,nq,bq)
    Drow = jnp.sum(dof * of, axis=-1)
    lse_b = lse.reshape(B, KVH, G, nq, block_q)

    def q_block(carry, qi):
        dk_acc, dv_acc = carry
        qb = jnp.einsum("bqkgd->bkgqd", qf[:, qi]).astype(jnp.float32) * scale
        dob = dof[:, :, :, qi]                      # (B,KVH,G,bq,hd)
        Db = Drow[:, :, :, qi]                      # (B,KVH,G,bq)
        lseb = lse_b[:, :, :, qi]
        q_pos = q_off + qi * block_q + jnp.arange(block_q)

        def kv_step(ki, carry2):
            dq_b, dk_acc, dv_acc = carry2
            k_pos = ki * block_kv + jnp.arange(block_kv)
            s = jnp.einsum("bkgqd,btkd->bkgqt", qb, kf[:, ki],
                           preferred_element_type=jnp.float32)
            mask = _block_mask(q_pos, k_pos, causal=causal, window=window,
                               chunked=chunked)
            mask &= (k_pos < Skv)[None, :] & \
                (q_pos - q_off < Sq)[:, None]
            p = jnp.where(mask[None, None, None],
                          jnp.exp(s - lseb[..., None]), 0.0)
            dv_blk = jnp.einsum("bkgqt,bkgqd->btkd", p, dob)
            dp = jnp.einsum("bkgqd,btkd->bkgqt", dob, vf[:, ki],
                            preferred_element_type=jnp.float32)
            ds = p * (dp - Db[..., None])           # (B,KVH,G,bq,bkv)
            dq_b = dq_b + jnp.einsum("bkgqt,btkd->bkgqd", ds, kf[:, ki],
                                     preferred_element_type=jnp.float32)
            dk_blk = jnp.einsum("bkgqt,bkgqd->btkd", ds, qb)
            dk_acc = jax.lax.dynamic_update_index_in_dim(
                dk_acc, dk_acc[ki] + dk_blk, ki, axis=0)
            dv_acc = jax.lax.dynamic_update_index_in_dim(
                dv_acc, dv_acc[ki] + dv_blk, ki, axis=0)
            return dq_b, dk_acc, dv_acc

        lo, hi = _band_bounds(qi, q_off, causal=causal, window=window,
                              chunked=chunked, block_q=block_q,
                              block_kv=block_kv, nk=nk, Skv_valid=Skv)
        dq0 = jnp.zeros((B, KVH, G, block_q, hd), jnp.float32)
        dq_b, dk_acc, dv_acc = jax.lax.fori_loop(
            lo, hi, kv_step, (dq0, dk_acc, dv_acc))
        return (dk_acc, dv_acc), jnp.einsum("bkgqd->bqkgd", dq_b) * scale

    dkv0 = (jnp.zeros((nk, B, block_kv, KVH, hd), jnp.float32),
            jnp.zeros((nk, B, block_kv, KVH, hd), jnp.float32))
    (dk_acc, dv_acc), dq_blocks = jax.lax.scan(q_block, dkv0,
                                               jnp.arange(nq))
    dq = jnp.moveaxis(dq_blocks, 0, 1).reshape(B, nq * block_q, KVH, G, hd)
    dq = dq[:, :Sq].reshape(B, Sq, H, hd).astype(q.dtype)
    dk = jnp.moveaxis(dk_acc, 0, 1).reshape(B, nk * block_kv, KVH, hd)
    dk = dk[:, :Skv].astype(k.dtype)
    dv = jnp.moveaxis(dv_acc, 0, 1).reshape(B, nk * block_kv, KVH, hd)
    dv = dv[:, :Skv].astype(v.dtype)
    return dq, dk, dv, None


flash_attention_vjp.defvjp(_flash_fwd, _flash_bwd)


@functools.partial(jax.jit, static_argnames=("window", "chunked"))
def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len: jax.Array, *, window: int = 0,
                     chunked: bool = False) -> jax.Array:
    """Single-token decode. q: (B, 1, H, hd); caches: (B, T, KVH, hd).

    Works with the cache sharded over its T dim (sequence-parallel decode):
    GSPMD inserts the max/sum all-reduces for the softmax automatically.
    """
    B, _, H, hd = q.shape
    _, T, KVH, _ = k_cache.shape
    G = H // KVH
    qr = q.reshape(B, KVH, G, hd) * hd ** -0.5
    s = jnp.einsum("bkgd,btkd->bkgt", qr, k_cache,
                   preferred_element_type=jnp.float32)
    pos = jnp.arange(T)
    qpos = cache_len - 1                                 # position of new token
    ok = pos[None, :] < cache_len[:, None]
    if window > 0 and not chunked:
        ok &= qpos[:, None] - pos[None, :] < window
    if window > 0 and chunked:
        ok &= (pos[None, :] // window) == (qpos[:, None] // window)
    s = jnp.where(ok[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# mlp
# ---------------------------------------------------------------------------


def init_swiglu(rng, d_model: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(rng, 3)
    s_in = d_model ** -0.5
    s_out = d_ff ** -0.5
    return {
        "w_gate": truncnorm_init(k1, (d_model, d_ff), s_in, dtype),
        "w_up": truncnorm_init(k2, (d_model, d_ff), s_in, dtype),
        "w_down": truncnorm_init(k3, (d_ff, d_model), s_out, dtype),
    }


def swiglu(p: dict, x: jax.Array) -> jax.Array:
    g = jax.nn.silu(x @ p["w_gate"])
    return ((g * (x @ p["w_up"])) @ p["w_down"]).astype(x.dtype)


def init_dense(rng, shape, dtype, scale=None):
    scale = scale if scale is not None else shape[0] ** -0.5
    return truncnorm_init(rng, shape, scale, dtype)


def mlp_stack(rng, dims, dtype):
    """[(d0->d1), (d1->d2), ...] relu MLP params."""
    keys = jax.random.split(rng, len(dims) - 1)
    return [{"w": init_dense(k, (dims[i], dims[i + 1]), dtype),
             "b": jnp.zeros((dims[i + 1],), dtype)}
            for i, k in enumerate(keys)]


def mlp_apply(layers, x, final_act=False):
    for i, l in enumerate(layers):
        x = x @ l["w"] + l["b"]
        if i < len(layers) - 1 or final_act:
            x = jax.nn.relu(x)
    return x
