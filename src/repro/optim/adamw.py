"""Optimizers: AdamW (fp32 master) + row-wise Adagrad for huge embeddings,
selected per-parameter by tree path. Global-norm clipping, warmup-cosine LR.

Row-wise Adagrad keeps ONE accumulator scalar per embedding row (the
industry-standard memory trick for 1e8-row tables: state is V floats, not
V*D), making the recsys train_step fit the per-device HBM budget.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jax.Array
    m: Any           # AdamW 1st moment  (zeros-like for adagrad params)
    v: Any           # AdamW 2nd moment / adagrad row accumulator
    master: Any      # fp32 master copy (None leaves if param already fp32)


def warmup_cosine(base_lr: float, warmup: int, total: int,
                  min_frac: float = 0.1) -> Callable:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac) * 0.5
                         * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def _is_embedding(path: str) -> bool:
    return "tables" in path or path.endswith("embed") or "/wide/" in path


def _path_tree(tree):
    paths, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in kp) for kp, _ in paths]
    return jax.tree_util.tree_unflatten(treedef, names)


def global_norm(tree) -> jax.Array:
    sq = jax.tree.map(lambda g: jnp.sum(g.astype(jnp.float32) ** 2), tree)
    return jnp.sqrt(jax.tree.reduce(jnp.add, sq))


def make_optimizer(lr_fn: Callable, *, b1: float = 0.9, b2: float = 0.95,
                   eps: float = 1e-8, weight_decay: float = 0.1,
                   clip_norm: float = 1.0,
                   embedding_rule: str = "row_adagrad"):
    """Returns (init_fn(params) -> OptState, update_fn(grads, state, params)
    -> (new_params, new_state, stats))."""

    def rule_for(path: str) -> str:
        return embedding_rule if _is_embedding(path) else "adamw"

    def init(params) -> OptState:
        names = _path_tree(params)

        def init_m(p, n):
            if rule_for(n) == "row_adagrad":
                return jnp.zeros((1,), jnp.float32)     # unused placeholder
            return jnp.zeros(p.shape, jnp.float32)

        def init_v(p, n):
            if rule_for(n) == "row_adagrad":
                return jnp.zeros(p.shape[:1], jnp.float32)  # per-row accum
            return jnp.zeros(p.shape, jnp.float32)

        def init_master(p, n):
            # zero-size sentinel == "param already fp32, no master needed"
            return p.astype(jnp.float32) if p.dtype != jnp.float32 \
                else jnp.zeros((0,), jnp.float32)

        m = jax.tree.map(init_m, params, names)
        v = jax.tree.map(init_v, params, names)
        master = jax.tree.map(init_master, params, names)
        return OptState(jnp.zeros((), jnp.int32), m, v, master)

    def update(grads, state: OptState, params):
        names = _path_tree(params)
        gn = global_norm(grads)
        scale = jnp.minimum(1.0, clip_norm / (gn + 1e-9)) if clip_norm else 1.0
        step = state.step + 1
        lr = lr_fn(step)

        def upd(g, m, v, master, p, n):
            g = g.astype(jnp.float32) * scale
            has_master = master.size != 0        # static at trace time
            x = master if has_master else p.astype(jnp.float32)
            if rule_for(n) == "row_adagrad":
                row_sq = jnp.mean(g * g, axis=tuple(range(1, g.ndim)))
                v2 = v + row_sq
                denom = jnp.sqrt(v2) + eps
                x2 = x - lr * g / denom.reshape((-1,) + (1,) * (g.ndim - 1))
                m2 = m
            else:
                m2 = b1 * m + (1 - b1) * g
                v2 = b2 * v + (1 - b2) * g * g
                mh = m2 / (1 - b1 ** step.astype(jnp.float32))
                vh = v2 / (1 - b2 ** step.astype(jnp.float32))
                x2 = x - lr * (mh / (jnp.sqrt(vh) + eps) + weight_decay * x)
            new_master = x2 if has_master else master
            return x2.astype(p.dtype), m2, v2, new_master

        out = jax.tree.map(upd, grads, state.m, state.v, state.master, params,
                           names)
        # unzip the 4-tuples
        new_p = jax.tree.map(lambda t: t[0], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda t: t[2], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_ma = jax.tree.map(lambda t: t[3], out,
                              is_leaf=lambda x: isinstance(x, tuple))
        return new_p, OptState(step, new_m, new_v, new_ma), \
            {"grad_norm": gn, "lr": lr}

    return init, update
