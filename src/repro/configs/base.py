"""Config dataclasses for every architecture family + ANN index configs.

Everything is a frozen dataclass so configs hash/compare cleanly and can be
used as static args to jit. Each assigned architecture gets one module in
``repro/configs`` exposing ``ARCH`` (an :class:`ArchConfig`); the registry
resolves ``--arch <id>`` strings to those objects.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# model-family configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    """GShard-style top-k routed MoE with optional shared experts."""

    n_experts: int
    top_k: int
    d_expert: int                 # hidden width of each routed expert
    n_shared_experts: int = 0
    d_shared: int = 0             # total hidden width of the shared expert(s)
    capacity_factor: float = 1.25
    router_aux_weight: float = 1e-3
    # experts are sharded over the `model` mesh axis; pad count up to a
    # multiple of the axis size so the expert dim shards evenly.
    def padded_experts(self, ep: int) -> int:
        return ((self.n_experts + ep - 1) // ep) * ep


@dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    qk_norm: bool = False
    qkv_bias: bool = False
    attention: str = "full"       # full | sliding | chunked_global
    window: int = 0               # sliding window size / local chunk size
    global_every: int = 0         # chunked_global: every k-th layer is global
    rope_theta: float = 1_000_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    dtype: str = "bfloat16"
    remat: bool = True

    # -- derived ---------------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def n_params(self) -> int:
        """Total parameter count (embeddings included)."""
        d, L = self.d_model, self.n_layers
        attn = d * (self.q_dim + 2 * self.kv_dim) + self.q_dim * d
        if self.moe is None:
            ffn = 3 * d * self.d_ff
        else:
            m = self.moe
            ffn = m.n_experts * 3 * d * m.d_expert + 3 * d * m.d_shared
            ffn += d * m.n_experts  # router
        norms = 2 * d
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return L * (attn + ffn + norms) + emb + d

    def n_active_params(self) -> int:
        """Parameters touched per token (MoE counts only routed top-k)."""
        if self.moe is None:
            return self.n_params()
        d, L, m = self.d_model, self.n_layers, self.moe
        attn = d * (self.q_dim + 2 * self.kv_dim) + self.q_dim * d
        ffn = m.top_k * 3 * d * m.d_expert + 3 * d * m.d_shared + d * m.n_experts
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return L * (attn + ffn + 2 * d) + emb + d

    def scaled(self, **kw) -> "LMConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class GNNConfig:
    name: str
    n_layers: int = 2
    d_hidden: int = 128
    aggregator: str = "mean"        # mean | max | sum
    sample_sizes: Tuple[int, ...] = (25, 10)
    n_classes: int = 41             # reddit has 41 classes
    pq_features: bool = False       # beyond-paper: PQ-compressed feature store
    dtype: str = "float32"

    def scaled(self, **kw) -> "GNNConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class RecsysConfig:
    name: str
    kind: str                       # dlrm | dcnv2 | sasrec | widedeep
    embed_dim: int
    vocab_sizes: Tuple[int, ...]    # rows per sparse table
    n_dense: int = 0
    multi_hot: int = 1              # lookups per field (EmbeddingBag bag size)
    bot_mlp: Tuple[int, ...] = ()
    top_mlp: Tuple[int, ...] = ()
    mlp: Tuple[int, ...] = ()
    n_cross_layers: int = 0
    # sasrec
    seq_len: int = 0
    n_blocks: int = 0
    n_heads: int = 0
    interaction: str = "dot"        # dot | cross | concat | self-attn-seq
    dtype: str = "float32"

    @property
    def n_sparse(self) -> int:
        return len(self.vocab_sizes)

    def n_embedding_rows(self) -> int:
        return sum(self.vocab_sizes)

    def scaled(self, **kw) -> "RecsysConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class IndexConfig:
    """AiSAQ / DiskANN index build + search parameters (paper Table 1)."""

    name: str
    n_vectors: int
    dim: int
    data_dtype: str = "float32"     # float32 | uint8 (SIFT1B is uint8)
    metric: str = "l2"              # l2 | mips
    R: int = 56                     # max outdegree
    pq_m: int = 128                 # number of PQ subvectors == b_pq bytes
    pq_ks: int = 256                # centroids per subquantizer (1 byte codes)
    n_ep: int = 1                   # entry points kept resident
    block_bytes: int = 4096         # LBA block size B
    beamwidth: int = 4              # paper fixes w=4
    build_L: int = 96               # candidate list size during build
    alpha: float = 1.2              # RobustPrune distance slack
    max_hops: int = 256             # while_loop bound on device backend
    mode: str = "aisaq"             # aisaq | diskann (placement policy)

    @property
    def b_full(self) -> int:
        itemsize = 1 if self.data_dtype == "uint8" else 4
        return self.dim * itemsize

    def scaled(self, **kw) -> "IndexConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# shapes
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell. `kind` selects which step function is lowered."""

    name: str
    kind: str
    # lm
    seq_len: int = 0
    global_batch: int = 0
    # gnn
    n_nodes: int = 0
    n_edges: int = 0
    d_feat: int = 0
    batch_nodes: int = 0
    fanout: Tuple[int, ...] = ()
    batch_graphs: int = 0
    # recsys / ann
    batch: int = 0
    n_candidates: int = 0


# canonical LM shape set (assigned to every LM arch)
LM_SHAPES = (
    ShapeConfig("train_4k", "lm_train", seq_len=4096, global_batch=256),
    ShapeConfig("prefill_32k", "lm_prefill", seq_len=32768, global_batch=32),
    ShapeConfig("decode_32k", "lm_decode", seq_len=32768, global_batch=128),
    ShapeConfig("long_500k", "lm_decode", seq_len=524288, global_batch=1),
)

GNN_SHAPES = (
    ShapeConfig("full_graph_sm", "gnn_full", n_nodes=2708, n_edges=10556, d_feat=1433),
    ShapeConfig("minibatch_lg", "gnn_minibatch", n_nodes=232965, n_edges=114615892,
                batch_nodes=1024, fanout=(15, 10), d_feat=602),
    ShapeConfig("ogb_products", "gnn_full", n_nodes=2449029, n_edges=61859140, d_feat=100),
    ShapeConfig("molecule", "gnn_batched", n_nodes=30, n_edges=64, batch_graphs=128,
                d_feat=64),
)

REC_SHAPES = (
    ShapeConfig("train_batch", "rec_train", batch=65536),
    ShapeConfig("serve_p99", "rec_serve", batch=512),
    ShapeConfig("serve_bulk", "rec_serve", batch=262144),
    ShapeConfig("retrieval_cand", "rec_retrieval", batch=1, n_candidates=1_000_000),
)

ANN_SHAPES = (
    ShapeConfig("serve_q32", "ann_search", batch=32),
    ShapeConfig("serve_q1k", "ann_search", batch=1024),
)


# ---------------------------------------------------------------------------
# arch container
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ArchConfig:
    arch_id: str
    family: str                     # lm | gnn | recsys | ann
    model: object
    shapes: Tuple[ShapeConfig, ...]
    skip_shapes: Tuple[str, ...] = ()
    skip_reason: str = ""
    source: str = ""

    def shape(self, name: str) -> ShapeConfig:
        for s in self.shapes:
            if s.name == name:
                return s
        raise KeyError(f"{self.arch_id} has no shape {name!r}")

    def active_shapes(self) -> Tuple[ShapeConfig, ...]:
        return tuple(s for s in self.shapes if s.name not in self.skip_shapes)
