"""graphsage-reddit [arXiv:1706.02216; paper] — 2-layer mean aggregator."""
from repro.configs.base import ArchConfig, GNNConfig, GNN_SHAPES

MODEL = GNNConfig(
    name="graphsage-reddit",
    n_layers=2,
    d_hidden=128,
    aggregator="mean",
    sample_sizes=(25, 10),
    n_classes=41,
)

ARCH = ArchConfig(
    arch_id="graphsage-reddit",
    family="gnn",
    model=MODEL,
    shapes=GNN_SHAPES,
    source="arXiv:1706.02216; paper",
)
