from repro.configs.base import (  # noqa: F401
    ArchConfig, GNNConfig, IndexConfig, LMConfig, MoEConfig, RecsysConfig,
    ShapeConfig, LM_SHAPES, GNN_SHAPES, REC_SHAPES, ANN_SHAPES,
)
from repro.configs.registry import get_arch, list_archs, ASSIGNED_ARCHS  # noqa: F401
