"""The paper's own index configs (Table 1) + reduced variants for CPU runs.

These are registered as extra `ann`-family architectures so the dry-run and
benchmarks can exercise the paper's core contribution end-to-end on the same
mesh as the assigned architectures.
"""
from repro.configs.base import ArchConfig, IndexConfig, ANN_SHAPES

# Table 1, column SIFT1M: float32, d=128, R=56, b_pq=128 (=> B_AiSAQ fills 4KiB*N)
SIFT1M = IndexConfig(
    name="sift1m", n_vectors=1_000_000, dim=128, data_dtype="float32",
    metric="l2", R=56, pq_m=128,
)

# Table 1, column SIFT1B: uint8, d=128, R=52, b_pq=32 (B_AiSAQ == B_DiskANN == 4KiB? no:
# b_full=128, chunk fits one 4 KiB block either way — the case where AiSAQ is
# latency-neutral or faster, per paper §4.3)
SIFT1B = IndexConfig(
    name="sift1b", n_vectors=1_000_000_000, dim=128, data_dtype="uint8",
    metric="l2", R=52, pq_m=32,
)

# Table 1, column KILT E5 22M: float32, d=1024, MIPS, R=69, b_pq=128
KILT_E5_22M = IndexConfig(
    name="kilt-e5-22m", n_vectors=22_220_792, dim=1024, data_dtype="float32",
    metric="mips", R=69, pq_m=128,
)

ARCH_SIFT1M = ArchConfig(
    arch_id="aisaq-sift1m", family="ann", model=SIFT1M, shapes=ANN_SHAPES,
    source="paper Table 1",
)
ARCH_SIFT1B = ArchConfig(
    arch_id="aisaq-sift1b", family="ann", model=SIFT1B, shapes=ANN_SHAPES,
    source="paper Table 1",
)
ARCH_KILT = ArchConfig(
    arch_id="aisaq-kilt-e5", family="ann", model=KILT_E5_22M, shapes=ANN_SHAPES,
    source="paper Table 1",
)
