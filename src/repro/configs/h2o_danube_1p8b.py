"""h2o-danube-1.8b [arXiv:2401.16818; hf] — llama+mistral mix with SWA."""
from repro.configs.base import ArchConfig, LMConfig, LM_SHAPES

MODEL = LMConfig(
    name="h2o-danube-1.8b",
    n_layers=24,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    head_dim=80,
    d_ff=6912,
    vocab_size=32000,
    attention="sliding",
    window=4096,                    # mistral-style sliding window
    rope_theta=10_000.0,
)

ARCH = ArchConfig(
    arch_id="h2o-danube-1.8b",
    family="lm",
    model=MODEL,
    shapes=LM_SHAPES,
    # sliding window => O(S*W) compute and window-bounded KV: long_500k runs.
    source="arXiv:2401.16818; hf",
)
