"""llama4-scout-17b-a16e [hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

MoE 16 routed top-1 + 1 shared expert per layer; iRoPE attention: chunked
local attention (chunk 8192) with every 4th layer global (NoPE).
"""
from repro.configs.base import ArchConfig, LMConfig, MoEConfig, LM_SHAPES

MODEL = LMConfig(
    name="llama4-scout-17b-a16e",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    attention="chunked_global",
    window=8192,                    # local-attention chunk length
    global_every=4,                 # every 4th layer attends globally
    rope_theta=500_000.0,
    moe=MoEConfig(
        n_experts=16,
        top_k=1,
        d_expert=8192,
        n_shared_experts=1,
        d_shared=8192,
        capacity_factor=1.25,
    ),
)

ARCH = ArchConfig(
    arch_id="llama4-scout-17b-a16e",
    family="lm",
    model=MODEL,
    shapes=LM_SHAPES,
    # chunked-local + SP-decoded sparse global layers => long_500k runs.
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
)
