"""qwen3-1.7b [hf:Qwen/Qwen3-8B family; hf] — dense, GQA kv=8, qk_norm."""
from repro.configs.base import ArchConfig, LMConfig, LM_SHAPES

MODEL = LMConfig(
    name="qwen3-1.7b",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=6144,
    vocab_size=151936,
    qk_norm=True,
    attention="full",
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)

ARCH = ArchConfig(
    arch_id="qwen3-1.7b",
    family="lm",
    model=MODEL,
    shapes=LM_SHAPES,
    skip_shapes=("long_500k",),
    skip_reason="pure full attention: 500k decode is quadratic-KV with no "
                "published sub-quadratic variant for this checkpoint "
                "(DESIGN.md §4)",
    source="hf:Qwen/Qwen3-8B; hf",
)
