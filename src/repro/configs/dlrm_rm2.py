"""dlrm-rm2 [arXiv:1906.00091; paper] — Criteo-1TB-class embedding tables.

Vocab sizes are the MLPerf/Criteo-Terabyte cardinalities (26 sparse fields,
~882M total rows -> ~226 GB of fp32 embeddings at dim 64: a genuinely
storage-tier table set, which is where the paper's PQ-offload applies).
"""
from repro.configs.base import ArchConfig, RecsysConfig, REC_SHAPES

CRITEO_TB_VOCABS = (
    39884406, 39043, 17289, 7420, 20263, 3, 7120, 1543, 63, 38532951,
    2953546, 403346, 10, 2208, 11938, 155, 4, 976, 14, 39979771,
    25641295, 39664984, 585935, 12972, 108, 36,
)

MODEL = RecsysConfig(
    name="dlrm-rm2",
    kind="dlrm",
    embed_dim=64,
    vocab_sizes=CRITEO_TB_VOCABS,
    n_dense=13,
    bot_mlp=(512, 256, 64),
    top_mlp=(512, 512, 256, 1),
    interaction="dot",
)

ARCH = ArchConfig(
    arch_id="dlrm-rm2",
    family="recsys",
    model=MODEL,
    shapes=REC_SHAPES,
    source="arXiv:1906.00091; paper",
)
