"""qwen2-1.5b [arXiv:2407.10671; hf] — dense GQA kv=2 with QKV bias."""
from repro.configs.base import ArchConfig, LMConfig, LM_SHAPES

MODEL = LMConfig(
    name="qwen2-1.5b",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    qkv_bias=True,
    attention="full",
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)

ARCH = ArchConfig(
    arch_id="qwen2-1.5b",
    family="lm",
    model=MODEL,
    shapes=LM_SHAPES,
    skip_shapes=("long_500k",),
    skip_reason="pure full attention (DESIGN.md §4)",
    source="arXiv:2407.10671; hf",
)
