"""dcn-v2 [arXiv:2008.13535; paper] — 3 cross layers + deep MLP."""
from repro.configs.base import ArchConfig, RecsysConfig, REC_SHAPES
from repro.configs.dlrm_rm2 import CRITEO_TB_VOCABS

MODEL = RecsysConfig(
    name="dcn-v2",
    kind="dcnv2",
    embed_dim=16,
    vocab_sizes=CRITEO_TB_VOCABS,
    n_dense=13,
    mlp=(1024, 1024, 512),
    n_cross_layers=3,
    interaction="cross",
)

ARCH = ArchConfig(
    arch_id="dcn-v2",
    family="recsys",
    model=MODEL,
    shapes=REC_SHAPES,
    source="arXiv:2008.13535; paper",
)
