"""qwen2-moe-a2.7b [hf:Qwen/Qwen1.5-MoE-A2.7B; hf] — 60 routed top-4 + 4 shared."""
from repro.configs.base import ArchConfig, LMConfig, MoEConfig, LM_SHAPES

MODEL = LMConfig(
    name="qwen2-moe-a2.7b",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,                      # routed-expert hidden (per spec line)
    vocab_size=151936,
    qkv_bias=True,
    attention="full",
    rope_theta=1_000_000.0,
    moe=MoEConfig(
        n_experts=60,
        top_k=4,
        d_expert=1408,
        n_shared_experts=4,
        d_shared=5632,              # 4 shared experts x 1408
        capacity_factor=1.25,
    ),
)

ARCH = ArchConfig(
    arch_id="qwen2-moe-a2.7b",
    family="lm",
    model=MODEL,
    shapes=LM_SHAPES,
    skip_shapes=("long_500k",),
    skip_reason="pure full attention (DESIGN.md §4)",
    source="hf:Qwen/Qwen1.5-MoE-A2.7B; hf",
)
