"""wide-deep [arXiv:1606.07792; paper] — 40 sparse fields, concat interaction."""
from repro.configs.base import ArchConfig, RecsysConfig, REC_SHAPES

# 40 fields spanning 1e3..1e7 rows (deterministic synthetic cardinalities in
# the spirit of the paper's app-store features; total ~88M rows).
WD_VOCABS = tuple(10 ** (3 + (i % 5)) for i in range(40))

MODEL = RecsysConfig(
    name="wide-deep",
    kind="widedeep",
    embed_dim=32,
    vocab_sizes=WD_VOCABS,
    n_dense=0,
    mlp=(1024, 512, 256),
    multi_hot=2,                    # wide&deep uses multi-hot cross features
    interaction="concat",
)

ARCH = ArchConfig(
    arch_id="wide-deep",
    family="recsys",
    model=MODEL,
    shapes=REC_SHAPES,
    source="arXiv:1606.07792; paper",
)
