"""sasrec [arXiv:1808.09781; paper] — self-attentive sequential recommender.

Item vocabulary is set to 1M so retrieval_cand (1 query x 1e6 candidates)
scores against the full catalogue — the paper's (AiSAQ's) retrieval regime.
"""
from repro.configs.base import ArchConfig, RecsysConfig, REC_SHAPES

MODEL = RecsysConfig(
    name="sasrec",
    kind="sasrec",
    embed_dim=50,
    vocab_sizes=(1_000_000,),       # item catalogue
    seq_len=50,
    n_blocks=2,
    n_heads=1,
    interaction="self-attn-seq",
)

ARCH = ArchConfig(
    arch_id="sasrec",
    family="recsys",
    model=MODEL,
    shapes=REC_SHAPES,
    source="arXiv:1808.09781; paper",
)
