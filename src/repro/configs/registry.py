"""``--arch <id>`` resolution. Import is lazy so configs stay cheap."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ArchConfig

# arch_id -> (module, attr)
_REGISTRY: Dict[str, tuple] = {
    # LM family (assigned)
    "qwen3-1.7b": ("repro.configs.qwen3_1p7b", "ARCH"),
    "h2o-danube-1.8b": ("repro.configs.h2o_danube_1p8b", "ARCH"),
    "qwen2-1.5b": ("repro.configs.qwen2_1p5b", "ARCH"),
    "qwen2-moe-a2.7b": ("repro.configs.qwen2_moe_a2p7b", "ARCH"),
    "llama4-scout-17b-a16e": ("repro.configs.llama4_scout_17b_a16e", "ARCH"),
    # GNN (assigned)
    "graphsage-reddit": ("repro.configs.graphsage_reddit", "ARCH"),
    # RecSys (assigned)
    "dlrm-rm2": ("repro.configs.dlrm_rm2", "ARCH"),
    "sasrec": ("repro.configs.sasrec", "ARCH"),
    "dcn-v2": ("repro.configs.dcn_v2", "ARCH"),
    "wide-deep": ("repro.configs.wide_deep", "ARCH"),
    # paper's own indices (extra)
    "aisaq-sift1m": ("repro.configs.aisaq_indices", "ARCH_SIFT1M"),
    "aisaq-sift1b": ("repro.configs.aisaq_indices", "ARCH_SIFT1B"),
    "aisaq-kilt-e5": ("repro.configs.aisaq_indices", "ARCH_KILT"),
}

ASSIGNED_ARCHS: List[str] = [
    "qwen3-1.7b", "h2o-danube-1.8b", "qwen2-1.5b", "qwen2-moe-a2.7b",
    "llama4-scout-17b-a16e", "graphsage-reddit", "dlrm-rm2", "sasrec",
    "dcn-v2", "wide-deep",
]


def get_arch(arch_id: str) -> ArchConfig:
    try:
        mod_name, attr = _REGISTRY[arch_id]
    except KeyError:
        raise KeyError(
            f"unknown arch {arch_id!r}; known: {sorted(_REGISTRY)}") from None
    return getattr(importlib.import_module(mod_name), attr)


def list_archs(include_extra: bool = True) -> List[str]:
    return list(_REGISTRY) if include_extra else list(ASSIGNED_ARCHS)
