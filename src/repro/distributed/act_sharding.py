"""Activation sharding constraints (GSPMD guidance).

Without explicit constraints GSPMD is free to pick intermediate layouts from
weight shardings alone — on the production mesh it chose to REPLICATE the
global batch per device and shard d_model instead (observed: 30+ GB of
f32[256,4096,·] temps). `constrain(x, name)` pins the batch/dp sharding at
the few points that anchor propagation.

The policy is process-global and set by the launcher (dryrun/train/serve)
via `set_policy(mesh, ...)`; model code stays mesh-agnostic. When no policy
is active (CPU unit tests), constrain() is the identity.
"""
from __future__ import annotations

import os
from typing import Dict, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_POLICY: Dict[str, NamedSharding] = {}
_MESH: Optional[Mesh] = None
_CP_ATTENTION = False       # context-parallel attention over `model`


def set_policy(mesh: Optional[Mesh], cp_attention: bool = False,
               **overrides) -> None:
    """Install the default LM/GNN/recsys activation policy for `mesh`.

    Pass mesh=None to clear (unit-test mode). `cp_attention` enables
    sequence-sharded flash attention over the `model` axis (§Perf
    iteration "cp-attn")."""
    global _POLICY, _MESH, _CP_ATTENTION
    _POLICY = {}
    _MESH = mesh
    _CP_ATTENTION = cp_attention and mesh is not None \
        and "model" in (mesh.axis_names if mesh else ())
    if mesh is None:
        return
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    specs = {
        "hidden": P(dp, None, None),            # (B, S, D)
        "qkv": P(dp, None, None, None),         # (B, S, H, hd) heads local
        "tokens2d": P(dp, None),                # (B, S)
        "vec": P(dp),                           # (B,)
        "logits_v": P(dp, None, "model"),       # (B, c, V)
        # (E, C, D): E over model (EP). REPRO_MOE_DISP=dp additionally
        # shards capacity slots over dp (§Perf "moe-disp" experiment)
        "moe_expert": (P("model", dp, None)
                       if os.environ.get("REPRO_MOE_DISP") == "dp"
                       else P("model", None, None)),
        "moe_tokens": P(dp, None),              # (T, D) token-major
        "table_rows": P("model", None),         # gathered embedding rows
        "edges": P(dp, None),                   # (E, 2)
        "cache": P(None, dp, "model", None, None),
    }
    specs.update({k: v for k, v in overrides.items()})
    _POLICY = {k: NamedSharding(mesh, v) for k, v in specs.items()}


def constrain(x: jax.Array, name: str) -> jax.Array:
    ns = _POLICY.get(name)
    if ns is None:
        return x
    return jax.lax.with_sharding_constraint(x, ns)


def cp_attention_wrap(flash_fn, seq_len: int):
    """Context-parallel attention: shard the q sequence over `model`.

    flash_fn(q, k, v, q_off) with q (B, S_local, H, hd), k/v full-sequence.
    Returns a shard_map'd fn(q, k, v) -> out, or None if CP is inapplicable
    (policy off, or S not divisible by the axis)."""
    if not _CP_ATTENTION or _MESH is None:
        return None
    ways = _MESH.shape["model"]
    if seq_len % ways or seq_len // ways < 128:
        return None
    from jax.experimental.shard_map import shard_map
    dp = tuple(a for a in ("pod", "data") if a in _MESH.axis_names)
    s_local = seq_len // ways

    def local(q, k, v):
        off = jax.lax.axis_index("model") * s_local
        return flash_fn(q, k, v, off)

    return shard_map(
        local, mesh=_MESH,
        in_specs=(P(dp, "model", None, None), P(dp, None, None, None),
                  P(dp, None, None, None)),
        out_specs=P(dp, "model", None, None),
        check_rep=False)
