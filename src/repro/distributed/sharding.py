"""Sharding rules: path+shape -> PartitionSpec, per model family.

Strategy (DESIGN.md §5):
  * `model` axis: TP over d_ff / vocab / attention projections, EP over MoE
    experts, row-sharding over recsys embedding tables, index shards for ANN.
  * `data` axis: batch DP + FSDP (parameter dim0/dim1 sharding -> ZeRO-3
    style all-gather at use, inserted by GSPMD).
  * `pod`  axis: pure DP across pods (gradient all-reduce over DCN); FSDP is
    kept intra-pod so per-layer all-gathers stay on ICI.

Specs are derived from jax.eval_shape of the init fn, so they track the real
param tree structure.
"""
from __future__ import annotations

import re
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def fsdp_axis(mesh: Mesh) -> str:
    return "data"


def _named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def _path_str(kp) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)


def spec_tree(shapes, rule: Callable[[str, tuple], P]):
    """shapes: pytree of ShapeDtypeStruct -> pytree of PartitionSpec."""
    return jax.tree_util.tree_map_with_path(
        lambda kp, s: rule(_path_str(kp), s.shape), shapes)


# ---------------------------------------------------------------------------
# per-family parameter rules
# ---------------------------------------------------------------------------


def lm_param_rule(mesh: Mesh) -> Callable:
    fs = fsdp_axis(mesh)

    def rule(path: str, shape: tuple) -> P:
        nd = len(shape)
        if path.endswith("embed"):
            return P("model", fs)
        if path.endswith("lm_head"):
            return P(fs, "model")
        if re.search(r"attn/w_[qkv]$", path):
            return P(None, fs, "model")
        if path.endswith("attn/w_o"):
            return P(None, "model", fs)
        if re.search(r"attn/b_[qkv]$", path):
            return P(None, "model")
        if "moe/router" in path:
            return P(None, fs, None)
        if "moe/w_gate" in path or "moe/w_up" in path:
            if nd == 4:                       # (L, E, D, F): EP on experts
                return P(None, "model", fs, None)
            return P(None, fs, "model")       # shared expert (L, D, F)
        if "moe/w_down" in path:
            if nd == 4:
                return P(None, "model", None, fs)
            return P(None, "model", fs)
        if "shared/w_gate" in path or "shared/w_up" in path:
            return P(None, fs, "model")
        if "shared/w_down" in path:
            return P(None, "model", fs)
        if re.search(r"ffn/w_(gate|up)$", path):
            return P(None, fs, "model")
        if path.endswith("ffn/w_down"):
            return P(None, "model", fs)
        return P(*([None] * nd))              # norms, scales

    return rule


def rec_param_rule(mesh: Mesh, replicate_small_mb: float = 64.0,
                   tablewise: bool = False) -> Callable:
    """Embedding tables: row-shard over `model`; with `tablewise`, small
    tables replicate instead (§Perf "tablewise") — a replicated table's
    lookups are local, removing its cross-`model` gather. SERVE-ONLY:
    measured 3.7x collective cut on dlrm serve_bulk but a 1.5x REGRESSION
    on wide-deep train (replicated-table grads all-reduce across all
    devices), so training keeps row-sharding."""
    thresh = replicate_small_mb * 1e6

    def rule(path: str, shape: tuple) -> P:
        nd = len(shape)
        if "tables/" in path or "/wide/" in path or path.startswith("wide"):
            import numpy as _np
            nbytes = float(_np.prod(shape)) * 4
            if tablewise and nbytes < thresh:
                return P(*([None] * nd))              # replicated small table
            return P("model", *([None] * (nd - 1)))   # row-sharded table
        return P(*([None] * nd))
    return rule


def gnn_param_rule(mesh: Mesh) -> Callable:
    def rule(path: str, shape: tuple) -> P:
        return P(*([None] * len(shape)))
    return rule


# ---------------------------------------------------------------------------
# optimizer-state specs (mirror param specs; see optim/adamw.py layouts)
# ---------------------------------------------------------------------------


def opt_state_specs(param_specs, param_shapes, opt_shapes):
    """Build OptState spec tuple matching (step, m, v, master) trees."""

    def m_spec(ps, pshape, mshape):
        if mshape.shape == (1,):                   # adagrad placeholder
            return P(None)
        return ps

    def v_spec(ps, pshape, vshape):
        if vshape.shape == pshape.shape:
            return ps
        # row-adagrad accumulator: (V,) — keep dim0 sharding
        first = ps[0] if len(ps) else None
        return P(first)

    def master_spec(ps, pshape, mshape):
        if mshape.shape == (0,):                   # fp32 sentinel
            return P(None)
        return ps

    from repro.optim.adamw import OptState
    return OptState(
        step=P(),
        m=jax.tree.map(m_spec, param_specs, param_shapes, opt_shapes.m),
        v=jax.tree.map(v_spec, param_specs, param_shapes, opt_shapes.v),
        master=jax.tree.map(master_spec, param_specs, param_shapes,
                            opt_shapes.master))


# ---------------------------------------------------------------------------
# batch specs per shape-kind
# ---------------------------------------------------------------------------


def batch_specs(kind: str, mesh: Mesh) -> dict:
    dp = dp_axes(mesh)
    if kind == "lm_train":
        return {"tokens": P(dp, None), "labels": P(dp, None)}
    if kind == "lm_prefill":
        return {"tokens": P(dp, None)}
    if kind == "lm_decode":
        return {"token": P(dp), "pos": P()}
    if kind in ("gnn_full",):
        return {"feats": P(), "edges": P(dp, None), "labels": P(),
                "mask": P()}
    if kind == "gnn_minibatch":
        return {"seed_feats": P(dp, None), "nbr1_feats": P(dp, None, None),
                "nbr2_feats": P(dp, None, None, None), "labels": P(dp)}
    if kind == "gnn_batched":
        return {"feats": P(dp, None, None), "edges": P(dp, None, None),
                "labels": P(dp)}
    if kind == "rec_train":
        return {"dense": P(dp, None), "sparse": P(dp, None, None),
                "label": P(dp), "seq": P(dp, None), "pos_items": P(dp, None),
                "neg_items": P(dp, None), "seq_mask": P(dp, None),
                "target": P(dp)}
    if kind == "rec_serve":
        return {"dense": P(dp, None), "sparse": P(dp, None, None),
                "seq": P(dp, None), "target": P(dp)}
    if kind == "rec_retrieval":
        return {"dense": P(None, None), "sparse": P(None, None, None),
                "seq": P(None, None), "cand_ids": P(None)}
    raise ValueError(kind)


def cache_spec(mesh: Mesh) -> P:
    """Decode KV cache (L, B, T, KVH, hd): batch over dp, seq over model."""
    return P(None, dp_axes(mesh), "model", None, None)
