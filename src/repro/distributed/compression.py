"""Gradient compression for the data-parallel all-reduce.

int8 stochastic-free symmetric quantization with a two-phase exchange:
  1. psum the per-tensor max-abs (scalar — negligible wire bytes),
  2. quantize to int8 against the GLOBAL scale, sum as int32, dequantize.

Wire-format note (DESIGN.md §6): XLA exposes no int8 ring all-reduce, so we
express the exchange as int32 psum of int8-valued payloads; on TPU runtimes
with int8 collective support this lowers to a 4x-smaller transfer. The
numerics (what training actually sees) are exactly int8-grade either way,
so convergence claims made with this module transfer to real deployments.
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array, scale: jax.Array) -> jax.Array:
    q = jnp.clip(jnp.round(x / jnp.maximum(scale, 1e-20) * 127.0),
                 -127, 127)
    return q.astype(jnp.int8)


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale / 127.0


def compressed_psum(grads: Any, axis_name: str) -> Any:
    """Per-tensor int8-quantized gradient all-reduce over `axis_name`.

    Must run inside shard_map/pmap with `axis_name` bound. Small tensors
    (<1024 elems: norms, biases) skip compression — their bytes don't matter
    and they are precision-critical.
    """
    n = jax.lax.psum(1, axis_name)

    def one(g):
        g = g.astype(jnp.float32)
        if g.size < 1024:
            return jax.lax.psum(g, axis_name) / n
        scale = jax.lax.pmax(jnp.max(jnp.abs(g)), axis_name)
        q = quantize_int8(g, scale)
        tot = jax.lax.psum(q.astype(jnp.int32), axis_name)
        return dequantize_int8(tot, scale) / n

    return jax.tree.map(one, grads)


def make_compressed_dp_grads(loss_fn, mesh, batch_example,
                             dp_axis: str = "data"):
    """Explicit-DP gradient fn: params replicated, batch sharded over
    dp_axis, grads exchanged via compressed_psum (replacing the implicit
    GSPMD fp32 all-reduce). `batch_example` fixes the batch pytree
    structure for the in_specs."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    def local(params, batch):
        (loss, aux), g = jax.value_and_grad(loss_fn, has_aux=True)(params,
                                                                   batch)
        g = compressed_psum(g, dp_axis)
        loss = jax.lax.pmean(loss, dp_axis)
        return loss, g

    bspecs = jax.tree.map(lambda _: P(dp_axis), batch_example)
    return shard_map(
        local, mesh=mesh,
        in_specs=(P(), bspecs),
        out_specs=(P(), P()),
        check_rep=False)
