"""Pipeline parallelism: GPipe-style microbatch schedule via shard_map +
collective_permute over a `pp` mesh axis.

The production mesh uses (pod, data, model); PP is the alternative layout
for bandwidth-poor inter-pod links — `make_pp_mesh` maps pipeline stages
onto the pod axis. Layers are stacked (L, ...) and split into S stages of
L/S layers; each device scans its own stage slice. The schedule below is
the classic GPipe loop: M microbatches flow through S stages in S+M-1 ticks,
activations hop stages via ppermute.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def make_pp_mesh(n_stages: int, n_data: int = 1):
    from repro.launch.mesh import make_mesh_compat
    return make_mesh_compat((n_stages, n_data), ("pp", "data"))


def pipeline_forward(mesh: Mesh, stage_fn: Callable, n_microbatches: int):
    """Build fn(stage_params, x) running the GPipe schedule.

    stage_fn(params_slice, x_mb) -> y_mb, applied by each device to its
    stage's layer slice. stage_params: (S * L_per_stage, ...) stacked layer
    params sharded over 'pp'; x: (M * mb, ...) microbatched inputs,
    replicated (stage 0 reads them; other stages ignore).
    Returns outputs of the LAST stage, replicated.
    """
    S = mesh.shape["pp"]
    M = n_microbatches

    def local(params, x):
        # params arrive as (1, L_per_stage, ...) shards: squeeze stage dim
        params = jax.tree.map(lambda a: a[0], params)
        stage = jax.lax.axis_index("pp")
        mb_shape = x.shape[1:]
        buf = jnp.zeros(mb_shape, x.dtype)              # current activation
        outs = jnp.zeros((M,) + mb_shape, x.dtype)

        def tick(carry, t):
            buf, outs = carry
            # stage 0 ingests microbatch t (if in range)
            feed = jnp.where(t < M, t, M - 1)
            buf = jnp.where(stage == 0, x[feed], buf)
            y = stage_fn(params, buf)
            # last stage banks its result for microbatch t - (S - 1)
            out_idx = jnp.clip(t - (S - 1), 0, M - 1)
            bank = (stage == S - 1) & (t >= S - 1)
            outs = jax.lax.dynamic_update_index_in_dim(
                outs, jnp.where(bank, y, outs[out_idx]), out_idx, axis=0)
            # shift activations downstream: stage i -> i+1 (ring permute)
            y_next = jax.lax.ppermute(
                y, "pp", [(i, (i + 1) % S) for i in range(S)])
            return (y_next, outs), None

        (buf, outs), _ = jax.lax.scan(tick, (buf, outs),
                                      jnp.arange(S + M - 1))
        # broadcast final outputs from the last stage (masked all-reduce)
        outs = jax.lax.psum(
            jnp.where(stage == S - 1, outs, jnp.zeros_like(outs)), "pp")
        return outs

    fn = shard_map(local, mesh=mesh,
                   in_specs=(P("pp"), P(None)),
                   out_specs=P(None),
                   check_rep=False)
    return fn
