"""Fault tolerance: heartbeats, restart-from-checkpoint, elastic re-meshing.

The launcher contract (launch/train.py):
  * every worker writes a heartbeat file each step; a coordinator (or the
    cluster manager) declares a worker dead after `timeout_s` silence,
  * on failure the job restarts from the newest complete checkpoint —
    checkpoints are topology-agnostic (checkpoint/ckpt.py), so the restart
    may use FEWER hosts (elastic downscale) as long as the new mesh divides
    the sharded dims,
  * data pipelines are (seed, step)-deterministic, so the resumed run
    consumes exactly the batches the failed run would have.

`run_with_restarts` drives that loop in-process (the unit-testable core the
real cluster launcher wraps); failures are surfaced as exceptions from
train_segment (a real deployment maps SIGTERM/ICI errors onto the same
path).
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Optional


class Heartbeat:
    def __init__(self, path: str, worker: int = 0):
        self.file = os.path.join(path, f"heartbeat_{worker}.json")
        os.makedirs(path, exist_ok=True)

    def beat(self, step: int):
        tmp = self.file + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"step": step, "time": time.time()}, f)
        os.replace(tmp, self.file)

    @staticmethod
    def dead_workers(path: str, timeout_s: float) -> list:
        now = time.time()
        dead = []
        for fn in os.listdir(path):
            if fn.startswith("heartbeat_") and fn.endswith(".json"):
                with open(os.path.join(path, fn)) as f:
                    hb = json.load(f)
                if now - hb["time"] > timeout_s:
                    dead.append(fn)
        return dead


class WorkerFailure(RuntimeError):
    pass


def run_with_restarts(train_segment: Callable[[Optional[int]], int], *,
                      max_restarts: int = 3,
                      on_restart: Optional[Callable[[int], None]] = None
                      ) -> int:
    """train_segment(resume_step|None) -> final_step; raises WorkerFailure
    on simulated/real worker death. Restarts up to max_restarts times,
    resuming from the step it reports via checkpoint discovery."""
    restarts = 0
    resume: Optional[int] = None
    while True:
        try:
            return train_segment(resume)
        except WorkerFailure as e:
            restarts += 1
            if restarts > max_restarts:
                raise
            resume = getattr(e, "last_step", None)
            if on_restart:
                on_restart(restarts)
