"""Train-state container + train/serve step factories for every family."""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.optim.adamw import OptState, make_optimizer, warmup_cosine


class TrainState(NamedTuple):
    params: Any
    opt: OptState


def default_optimizer(total_steps: int = 10000, base_lr: float = 3e-4):
    return make_optimizer(warmup_cosine(base_lr, min(2000, total_steps // 10),
                                        total_steps))


def make_loss_fn(arch: ArchConfig, shape: ShapeConfig) -> Callable:
    fam = arch.family
    if fam == "lm":
        from repro.models.transformer import lm_loss
        return lambda p, b: lm_loss(p, b, arch.model)
    if fam == "gnn":
        import os
        from repro.models import gnn as G
        if shape.kind == "gnn_minibatch":
            return lambda p, b: G.gnn_minibatch_loss(p, b, arch.model)
        if shape.kind == "gnn_batched":
            return lambda p, b: G.gnn_batched_loss(p, b, arch.model)
        if os.environ.get("REPRO_GNN") == "sharded":
            # §Perf "gnn-part": locality-aware partitioned aggregation
            from repro.distributed import act_sharding
            from repro.models.gnn_sharded import sharded_full_loss_fn
            mesh = act_sharding._MESH
            if mesh is not None:
                return sharded_full_loss_fn(mesh, arch.model, shape.n_nodes,
                                            axes=tuple(mesh.axis_names))
        return lambda p, b: G.gnn_full_loss(p, b, arch.model)
    if fam == "recsys":
        from repro.models.recsys import rec_loss
        return lambda p, b: rec_loss(p, b, arch.model)
    raise ValueError(fam)


def make_train_step(arch: ArchConfig, shape: ShapeConfig, optimizer=None,
                    microbatches: int = 1) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    Grad DP all-reduce is implicit from sharding (params replicated over dp
    axes). `microbatches` > 1 scans over batch slices accumulating fp32
    grads — bounds activation residency AND amortizes the DP all-reduce to
    once per step (compute/comm overlap lever, DESIGN.md §5)."""
    loss_fn = make_loss_fn(arch, shape)
    _, opt_update = optimizer or default_optimizer()

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)

    def train_step(state: TrainState, batch: dict):
        if microbatches == 1:
            (loss, metrics), grads = grads_of(state.params, batch)
        else:
            mb = jax.tree.map(
                lambda x: x.reshape((microbatches, x.shape[0] // microbatches)
                                    + x.shape[1:]), batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)

            def acc_step(carry, b):
                g_acc, l_acc = carry
                (loss, metrics), g = grads_of(state.params, b)
                g_acc = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + loss), metrics

            (g_sum, l_sum), metrics = jax.lax.scan(
                acc_step, (zeros, jnp.float32(0)), mb)
            grads = jax.tree.map(lambda g: g / microbatches, g_sum)
            loss = l_sum / microbatches
            metrics = jax.tree.map(lambda m: m[-1], metrics)
        new_p, new_opt, stats = opt_update(grads, state.opt, state.params)
        return TrainState(new_p, new_opt), {"loss": loss, **metrics, **stats}

    return train_step


def make_serve_step(arch: ArchConfig, shape: ShapeConfig) -> Callable:
    fam = arch.family
    if fam == "lm":
        from repro.models import transformer as T
        if shape.kind == "lm_prefill":
            return lambda p, b: T.lm_prefill(p, b["tokens"], arch.model)
        if shape.kind == "lm_decode":
            def step(p, cache, b):
                return T.lm_decode_step(p, cache, b["token"], b["pos"],
                                        arch.model)
            return step
    if fam == "gnn":
        from repro.models import gnn as G
        if shape.kind == "gnn_full":
            return lambda p, b: G.gnn_full_forward(p, b["feats"], b["edges"],
                                                   arch.model)
        if shape.kind == "gnn_batched":
            return lambda p, b: G.gnn_batched_forward(p, b["feats"],
                                                      b["edges"], arch.model)
        return lambda p, b: G.gnn_minibatch_forward(p, b, arch.model)
    if fam == "recsys":
        from repro.models import recsys as R
        if shape.kind == "rec_retrieval":
            return lambda p, b: R.retrieval_topk(p, b, arch.model, k=100)
        return lambda p, b: R.rec_forward(p, b, arch.model)
    raise ValueError((fam, shape.kind))
