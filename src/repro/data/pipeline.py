"""Synthetic data pipelines with host sharding + background prefetch.

Every generator is deterministic in (seed, step) so a restarted worker
resumes mid-stream bit-identically — the data side of the fault-tolerance
contract. On multi-host deployments each process takes its
`process_index`-th slice of the global batch.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional, Tuple

import jax
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig


class TokenStream:
    """Zipf-distributed synthetic token stream (LM pretraining stand-in)."""

    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 seed: int = 0, zipf_a: float = 1.2):
        self.vocab = vocab_size
        self.seq = seq_len
        self.batch = global_batch
        self.seed = seed
        self.a = zipf_a

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        toks = rng.zipf(self.a, size=(self.batch, self.seq + 1)) % self.vocab
        toks = toks.astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class ClickStream:
    """Synthetic CTR clickstream with learnable structure (not pure noise):
    label depends on a hidden weight over the sparse ids so models can fit."""

    def __init__(self, cfg, batch: int, seed: int = 0):
        self.cfg = cfg
        self.batch = batch
        self.seed = seed
        rng = np.random.default_rng(seed)
        self._w = {i: rng.normal(size=min(v, 4096)).astype(np.float32)
                   for i, v in enumerate(cfg.vocab_sizes)}

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((self.seed, step))
        out: Dict[str, np.ndarray] = {}
        score = np.zeros(self.batch, np.float32)
        sparse = np.zeros((self.batch, cfg.n_sparse, cfg.multi_hot), np.int32)
        for i, v in enumerate(cfg.vocab_sizes):
            ids = rng.zipf(1.1, size=(self.batch, cfg.multi_hot)) % v
            sparse[:, i, :] = ids
            score += self._w[i][ids[:, 0] % len(self._w[i])]
        out["sparse"] = sparse
        if cfg.n_dense:
            dense = rng.normal(size=(self.batch, cfg.n_dense)).astype(np.float32)
            score += dense[:, 0]
            out["dense"] = dense
        out["label"] = (score > 0).astype(np.int32)
        return out


class SasrecStream:
    def __init__(self, cfg, batch: int, seed: int = 0):
        self.cfg, self.batch, self.seed = cfg, batch, seed

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((self.seed, step))
        V, S = cfg.vocab_sizes[0], cfg.seq_len
        # markov-ish sequences: next item correlated with previous
        base = rng.integers(0, V, size=(self.batch, 1))
        steps = rng.integers(-50, 50, size=(self.batch, S + 1))
        seq = (base + np.cumsum(steps, axis=1)) % V
        return {"seq": seq[:, :-1].astype(np.int32),
                "pos_items": seq[:, 1:].astype(np.int32),
                "neg_items": rng.integers(0, V, size=(self.batch, S)
                                          ).astype(np.int32),
                "seq_mask": np.ones((self.batch, S), np.float32)}


def make_graph(n_nodes: int, avg_degree: int, d_feat: int, n_classes: int,
               seed: int = 0) -> dict:
    """Power-law community graph with label-correlated features."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, n_classes, size=n_nodes)
    n_edges = n_nodes * avg_degree
    # preferential-attachment-ish: sample dst by zipf rank
    src = rng.zipf(1.3, size=n_edges) % n_nodes
    dst = rng.integers(0, n_nodes, size=n_edges)
    # homophily: rewire half the edges to same-label nodes
    same = rng.random(n_edges) < 0.5
    perm = rng.permutation(n_nodes)
    by_label = {c: np.flatnonzero(labels == c) for c in range(n_classes)}
    for i in np.flatnonzero(same)[:n_edges // 2]:
        pool = by_label[labels[src[i]]]
        dst[i] = pool[rng.integers(0, len(pool))]
    feats = rng.normal(size=(n_nodes, d_feat)).astype(np.float32) * 0.5
    centers = rng.normal(size=(n_classes, d_feat)).astype(np.float32)
    feats += centers[labels]
    edges = np.stack([src, dst], axis=1).astype(np.int32)
    return {"feats": feats, "edges": edges,
            "labels": labels.astype(np.int32),
            "mask": np.ones(n_nodes, np.float32)}


def host_slice(batch: Dict[str, np.ndarray], process_index: Optional[int]
               = None, process_count: Optional[int] = None):
    """Per-host slice of the global batch (data-loader sharding)."""
    pi = jax.process_index() if process_index is None else process_index
    pc = jax.process_count() if process_count is None else process_count
    def sl(x):
        per = x.shape[0] // pc
        return x[pi * per:(pi + 1) * per]
    return {k: sl(v) for k, v in batch.items()}


class Prefetcher:
    """Background-thread prefetch of generator batches onto device."""

    def __init__(self, gen_fn, depth: int = 2, shardings=None):
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self.shardings = shardings
        self._stop = False

        def work():
            step = 0
            while not self._stop:
                b = gen_fn(step)
                if self.shardings is not None:
                    b = {k: jax.device_put(v, self.shardings.get(k))
                         for k, v in b.items()}
                self.q.put(b)
                step += 1

        self._t = threading.Thread(target=work, daemon=True)
        self._t.start()

    def __iter__(self):
        return self

    def __next__(self):
        return self.q.get()

    def stop(self):
        self._stop = True
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
