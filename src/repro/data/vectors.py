"""Synthetic vector corpora for ANN experiments (SIFT-like cluster structure).

Real SIFT descriptors are strongly clustered; a plain gaussian makes ANN
trivially hard/uninformative. We sample a gaussian mixture with power-law
cluster weights, which reproduces the recall-vs-L behaviour shape of Fig. 3.
"""
from __future__ import annotations

import numpy as np


def make_clustered(n: int, d: int, *, n_clusters: int = 64, seed: int = 0,
                   dtype: str = "float32", spread: float = 0.15) -> np.ndarray:
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(n_clusters, d)).astype(np.float32)
    w = 1.0 / np.arange(1, n_clusters + 1) ** 0.7
    w = w / w.sum()
    assign = rng.choice(n_clusters, size=n, p=w)
    x = centers[assign] + spread * rng.normal(size=(n, d)).astype(np.float32)
    if dtype == "uint8":
        lo, hi = x.min(), x.max()
        return np.clip((x - lo) / (hi - lo) * 255, 0, 255).astype(np.uint8)
    return x.astype(np.float32)


def make_queries(n_q: int, base: np.ndarray, *, seed: int = 1,
                 noise: float = 0.05) -> np.ndarray:
    """Queries near base points (realistic ANN regime)."""
    rng = np.random.default_rng(seed)
    idx = rng.choice(base.shape[0], size=n_q, replace=False)
    q = base[idx].astype(np.float32)
    q = q + noise * rng.normal(size=q.shape).astype(np.float32) * (
        np.abs(q).mean() + 1e-6)
    return q.astype(np.float32)
