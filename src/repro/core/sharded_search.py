"""Multi-device sharded ANN search — the paper's Fig. 5 multi-server system.

Each device owns one dataset shard with its OWN sub-index (subgraph + entry
point), exactly like the paper's per-server indices. A query fans out to all
shards (replicated over the shard axes), each runs the local AiSAQ beam
search, and local top-k results merge via all-gather + global top-k.

Mesh mapping (DESIGN.md §2):
  query batch  -> ('pod', 'data')   (paper: request load-balancer)
  index shards -> ('model',)        (paper: servers on the ethernet/Lustre tier)

This is the DEVICE-tier fan-out.  The storage-backed host tier it mirrors
lives in the three-layer core (``core.adc`` numerics, ``core.traversal``
pipelined beam engine, ``core.index_io`` format/lifecycle); per-shard
device search has no storage pipeline to overlap, so the host-only
``pipeline=``/``prefetch=`` knobs do not appear here.

The shard MATH — which vector belongs to which shard, and how partial
per-shard top-k lists merge — is shared with the process-level storage
tier (``serving.cluster`` / ``serving.router``) via ``core.shard_math``:
``ShardAssignment`` / ``contiguous_shards`` produce the same
(offset, count) splits ``stack_shards`` consumes here, and
``merge_topk`` is the host twin of this module's all-gather +
``lax.top_k`` merge.  They are re-exported below so either tier can
import them from either module.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.chunk_layout import ChunkLayout
from repro.core.device_index import DeviceIndex, beam_search_device
from repro.core.shard_math import (          # noqa: F401  (re-exported)
    ShardAssignment, contiguous_shards, merge_topk)


class ShardedIndexArrays(NamedTuple):
    """Stacked per-shard index arrays; leading dim = shard."""

    chunk_words: jax.Array    # (S_h, N_s, W) int32
    centroids: jax.Array      # (m, ks, dsub) f32 — replicated
    ep_ids: jax.Array         # (S_h, n_ep) int32 (shard-local ids)
    ep_codes: jax.Array       # (S_h, n_ep, m) int32
    offsets: jax.Array        # (S_h,) int32 global-id offset per shard


def stack_shards(shards: Sequence[Tuple[int, "np.ndarray", "np.ndarray"]],
                 centroids: np.ndarray, codes_full: np.ndarray,
                 layout: ChunkLayout) -> ShardedIndexArrays:
    """shards: list of (global_offset, shard_vectors, shard_graph)."""
    from repro.core.chunk_layout import pack_chunks_device
    words, eps, epc, offs = [], [], [], []
    n_max = max(v.shape[0] for _, v, _ in shards)
    for off, vecs, graph in shards:
        n = vecs.shape[0]
        codes = codes_full[off:off + n]
        dev = pack_chunks_device(vecs, graph, codes, layout)
        w = np.ascontiguousarray(dev).view(np.int32).reshape(n, -1)
        if n < n_max:  # pad ragged shards with unreachable nodes
            w = np.pad(w, ((0, n_max - n), (0, 0)))
        words.append(w)
        mean = vecs.astype(np.float32).mean(axis=0)
        dd = ((vecs.astype(np.float32) - mean) ** 2).sum(axis=1)
        ep = np.argsort(dd)[:1].astype(np.int32)
        eps.append(ep)
        epc.append(codes[ep].astype(np.int32))
        offs.append(off)
    return ShardedIndexArrays(
        chunk_words=jnp.asarray(np.stack(words)),
        centroids=jnp.asarray(centroids, jnp.float32),
        ep_ids=jnp.asarray(np.stack(eps)),
        ep_codes=jnp.asarray(np.stack(epc)),
        offsets=jnp.asarray(np.array(offs, np.int32)))


def sharded_search_fn(mesh, *, k: int, L: int, w: int, max_hops: int,
                      layout: ChunkLayout, metric: str, backend: str = "auto",
                      query_axes: Tuple[str, ...] = ("data",),
                      shard_axes: Tuple[str, ...] = ("model",),
                      query_chunk: int = 0, adc_dtype: str = "f32"):
    """Returns a jit-able fn(arrays: ShardedIndexArrays, queries) -> ids, d.

    queries: (B, d) sharded over query_axes (may be empty => replicated —
    "mode B", index sharded over every axis for billion-scale tables);
    index shards over shard_axes. Output: (B, k) ids + dists like queries.

    query_chunk > 0 processes queries in chunks inside lax.map, bounding the
    per-query visited-bitmap working set (nq_chunk x N_shard bools).
    """
    query_axes = _norm_axes(query_axes)
    qspec = P(query_axes, None) if query_axes else P(None, None)
    sspec = P(shard_axes, None, None)

    def local_search(words, cents, ep_ids, ep_codes, offset, queries):
        # shapes inside shard_map: words (1, N_s, W), queries (B_l, d)
        idx = DeviceIndex(chunk_words=words[0], centroids=cents,
                          ep_ids=ep_ids[0], ep_codes=ep_codes[0])

        def one_chunk(qc):
            ids, d, hops = beam_search_device(
                idx, qc, k=k, L=L, w=w, max_hops=max_hops, layout=layout,
                metric=metric, backend=backend, adc_dtype=adc_dtype)
            return ids, d

        nq = queries.shape[0]
        if query_chunk and nq > query_chunk:
            nc = nq // query_chunk
            ids, d = jax.lax.map(
                one_chunk, queries.reshape(nc, query_chunk, -1))
            ids, d = ids.reshape(nq, k), d.reshape(nq, k)
        else:
            ids, d = one_chunk(queries)
        gids = jnp.where(ids >= 0, ids + offset[0], -1)
        d = jnp.where(ids >= 0, d, jnp.inf)
        # merge across shards: (S, B_l, k) -> top-k per query
        all_ids = jax.lax.all_gather(gids, shard_axes, axis=0, tiled=False)
        all_d = jax.lax.all_gather(d, shard_axes, axis=0, tiled=False)
        S = all_ids.shape[0]
        all_ids = jnp.moveaxis(all_ids, 0, 1).reshape(queries.shape[0], S * k)
        all_d = jnp.moveaxis(all_d, 0, 1).reshape(queries.shape[0], S * k)
        negd, pos = jax.lax.top_k(-all_d, k)
        return jnp.take_along_axis(all_ids, pos, axis=1), -negd

    fn = shard_map(
        local_search, mesh=mesh,
        in_specs=(sspec, P(), P(shard_axes, None), P(shard_axes, None, None),
                  P(shard_axes), qspec),
        out_specs=(qspec, qspec),
        check_rep=False)

    def search(arrays: ShardedIndexArrays, queries: jax.Array):
        return fn(arrays.chunk_words, arrays.centroids, arrays.ep_ids,
                  arrays.ep_codes, arrays.offsets, queries)

    return search


def _norm_axes(axes) -> Tuple[str, ...]:
    """Drop None placeholders: (None,) means 'replicated', which older JAX
    only accepts as an empty spec (P(None) rather than P((None,)))."""
    return tuple(a for a in (axes or ()) if a is not None)


def input_sharding(mesh, query_axes=("data",), shard_axes=("model",)):
    """NamedShardings for placing ShardedIndexArrays + queries on the mesh."""
    query_axes = _norm_axes(query_axes)
    qspec = P(query_axes, None) if query_axes else P(None, None)
    return ShardedIndexArrays(
        chunk_words=NamedSharding(mesh, P(shard_axes, None, None)),
        centroids=NamedSharding(mesh, P()),
        ep_ids=NamedSharding(mesh, P(shard_axes, None)),
        ep_codes=NamedSharding(mesh, P(shard_axes, None, None)),
        offsets=NamedSharding(mesh, P(shard_axes)),
    ), NamedSharding(mesh, qspec)
