"""Hybrid in-memory navigation tier: query-sensitive entry points.

Every cold-path lever so far (relabel, prefetch, pipelining) makes each
storage hop CHEAPER; this module makes queries take FEWER hops.  At pack
time ``write_index(nav=True)`` selects ~1-4% of nodes as *pivots*
(seed-stable k-means medoids by default), builds a small in-RAM k-NN
graph over them, and persists pivot ids + pivot PQ codes + the pivot
graph as an optional ``nav_graph.npz`` sidecar.  At query time a
vectorized beam over that pivot graph — pure ADC against RAM-resident
codes, ZERO storage I/O — drops each query deep into the on-disk graph:
the beam's best pivots replace the fixed ``meta["entry_points"]`` medoid
seed (the SPANN navigation-tier + DiskANN++ entry-vertex idea).

Bit-identity discipline: `nav_seed_batch` is the ONLY implementation of
the nav beam and every operation in it is row-independent (per-query
gathers, last-axis reductions, per-row stable argsorts), so the scalar
Algorithm-1 oracle calling it with a batch of one computes bit-identical
seeds to the vectorized hot path calling it with the full batch.  The
seed ADC distances are RETURNED (not recomputed by the callers), so both
paths initialize their candidate lists from literally the same floats.

Compatibility: the sidecar is OPTIONAL.  v1/v2 dirs (no ``nav`` meta
key) load with the tier disabled; a dir whose meta promises nav but
whose sidecar is missing/corrupt/truncated loads WITH A WARNING and nav
disabled — ``CorruptIndexError`` stays reserved for damage to the core
index (docs/navigation.md, docs/failure_model.md).
"""
from __future__ import annotations

import os
import warnings
from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

__all__ = [
    "NAV_SIDECAR", "DEFAULT_FRACTION", "DEFAULT_DEGREE", "DEFAULT_METHOD",
    "NavGraph", "select_pivots", "build_nav", "save_nav", "load_nav",
    "resolve_entry", "nav_seed_batch",
]

#: sidecar filename inside an index directory (format_version >= 3).
NAV_SIDECAR = "nav_graph.npz"

#: pack-time defaults: ~2% pivots (the SPANN-style few-MB tier — well
#: inside AiSAQ's ~10 MB budget), degree-8 k-NN pivot graph, k-means
#: pivot selection.  All recorded in ``meta["nav"]`` by the writer.
DEFAULT_FRACTION = 0.02
DEFAULT_DEGREE = 8
DEFAULT_METHOD = "kmeans"
KMEANS_ITERS = 5
#: k-means runs on at most this many (seeded) sample rows so pivot
#: selection stays O(sample * pivots) at any corpus size.
KMEANS_SAMPLE = 20000

#: query-time beam shape.  Constants (not knobs): the scalar oracle and
#: the batched path must walk the pivot graph identically, and the tier's
#: public knob surface is ``entry=`` alone.
NAV_BEAM_W = 4
NAV_BEAM_L = 8


@dataclass
class NavGraph:
    """The RAM-resident navigation tier of one index.

    All ids in ``pivot_ids`` are STORAGE-space node ids (the writer
    builds the tier after any relabel permutation), so beam output feeds
    the on-disk search directly.  ``graph`` holds pivot-LOCAL indices
    (-1 padded); ``entry_pivots`` are pivot-local beam start indices.
    """

    pivot_ids: np.ndarray      # (P,) int64, storage-space node ids
    codes: np.ndarray          # (P, m) uint8 PQ codes of the pivots
    graph: np.ndarray          # (P, degree) int32 pivot-local knn, -1 pad
    entry_pivots: np.ndarray   # (e,) int32 pivot-local beam entries
    params: dict               # fraction/seed/method/degree/pivots

    def resident_nbytes(self) -> int:
        """RAM the tier pins — charged into ``HostIndex.resident_bytes``
        and therefore against the ``WarmIndexPool`` DRAM budget."""
        return int(self.pivot_ids.nbytes + self.codes.nbytes
                   + self.graph.nbytes + self.entry_pivots.nbytes)


# ---------------------------------------------------------------------------
# pack time: pivot selection + pivot graph
# ---------------------------------------------------------------------------


def _sq_dists(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """(na, nb) squared L2 via the quadratic form (no (na, nb, d) blowup)."""
    a = np.asarray(a, dtype=np.float32)
    b = np.asarray(b, dtype=np.float32)
    d = (a * a).sum(1)[:, None] + (b * b).sum(1)[None, :] - 2.0 * (a @ b.T)
    return np.maximum(d, 0.0)


def select_pivots(vectors: np.ndarray, fraction: float = DEFAULT_FRACTION,
                  seed: int = 0, method: str = DEFAULT_METHOD) -> np.ndarray:
    """Seed-stable pivot selection: sorted unique node ids, ~fraction*n
    of them.  ``method="kmeans"`` (default) runs a few seeded k-means
    iterations on a bounded sample and snaps each centroid to its nearest
    actual node (a medoid per region — coverage-driven); ``"random"`` is
    the seeded uniform baseline.  Deterministic in (vectors, fraction,
    seed, method)."""
    v = np.ascontiguousarray(vectors, dtype=np.float32)
    n = v.shape[0]
    p = max(1, min(n, int(round(n * float(fraction)))))
    rng = np.random.default_rng(seed)
    if method == "random":
        ids = rng.choice(n, size=p, replace=False)
        return np.sort(ids.astype(np.int64))
    if method != "kmeans":
        raise ValueError(f"unknown pivot-selection method {method!r} "
                         "(expected 'kmeans' or 'random')")
    if n <= KMEANS_SAMPLE:
        sample_ids = np.arange(n, dtype=np.int64)
    else:
        sample_ids = np.sort(rng.choice(n, KMEANS_SAMPLE, replace=False)
                             .astype(np.int64))
    s = v[sample_ids]
    centers = s[rng.choice(s.shape[0], size=p, replace=False)].copy()
    for _ in range(KMEANS_ITERS):
        asn = np.argmin(_sq_dists(s, centers), axis=1)
        sums = np.zeros_like(centers, dtype=np.float64)
        np.add.at(sums, asn, s.astype(np.float64))
        cnt = np.bincount(asn, minlength=p).astype(np.float64)
        nonempty = cnt > 0
        centers[nonempty] = (sums[nonempty]
                             / cnt[nonempty, None]).astype(np.float32)
    ids = np.unique(sample_ids[np.argmin(_sq_dists(centers, s), axis=1)])
    if ids.size < p:
        # centroid collisions: top up with seeded picks outside the set
        free = np.ones(n, bool)
        free[ids] = False
        pool = np.flatnonzero(free)
        extra = pool[rng.choice(pool.size, size=p - ids.size, replace=False)]
        ids = np.concatenate([ids, extra.astype(np.int64)])
    return np.sort(ids.astype(np.int64))


def _pivot_medoid(pv: np.ndarray, metric: str) -> int:
    mean = pv.mean(axis=0)
    if metric == "mips":
        return int(np.argmax(pv @ mean))
    return int(np.argmin(((pv - mean) ** 2).sum(axis=1)))


def build_nav(vectors: np.ndarray, codes: np.ndarray, *,
              fraction: float = DEFAULT_FRACTION,
              degree: int = DEFAULT_DEGREE, seed: int = 0,
              method: str = DEFAULT_METHOD,
              metric: str = "l2") -> NavGraph:
    """Build the tier from pack-time arrays (AFTER any relabel
    permutation: ``vectors``/``codes`` must already be in storage
    order, so pivot ids land in storage space)."""
    pivot_ids = select_pivots(vectors, fraction, seed, method)
    pv = np.ascontiguousarray(vectors[pivot_ids], dtype=np.float32)
    P = pivot_ids.size
    degree = max(1, int(degree))
    graph = np.full((P, degree), -1, np.int32)
    if 1 < P <= degree + 1:
        # tiny tier: fully connected (the beam sees everything in 1 hop)
        idx = np.arange(P)
        full = np.tile(idx, (P, 1))
        graph[:, :P - 1] = full[full != idx[:, None]] \
            .reshape(P, P - 1).astype(np.int32)
    elif P > 1:
        # a NAVIGABLE graph, not a plain k-NN graph: pure k-NN over
        # clustered data fragments into per-cluster components and the
        # beam gets trapped in the entry pivot's component.  Vamana's
        # robust pruning keeps long-range edges (alpha > 1), and the
        # pivot set is small so the build is cheap.
        from repro.core.vamana import build_vamana
        g = build_vamana(pv, R=degree, L=max(2 * degree, 16), alpha=1.2,
                         metric=metric, seed=seed)
        graph[:, :g.shape[1]] = g.astype(np.int32)
    entry = np.array([_pivot_medoid(pv, metric)], np.int32)
    params = dict(pivots=int(P), degree=int(degree),
                  fraction=float(fraction), seed=int(seed), method=method)
    return NavGraph(pivot_ids=pivot_ids,
                    codes=np.ascontiguousarray(codes[pivot_ids],
                                               dtype=np.uint8),
                    graph=graph, entry_pivots=entry, params=params)


def save_nav(path: str, nav: NavGraph):
    """Write the sidecar (fsynced).  Callers write into the index's tmp
    sibling before atomic publication, so no rename dance is needed
    here — crash-safety rides on `write_index`'s whole-dir recipe."""
    with open(path, "wb") as f:
        np.savez(f, pivot_ids=nav.pivot_ids.astype(np.int64),
                 codes=nav.codes.astype(np.uint8),
                 graph=nav.graph.astype(np.int32),
                 entry_pivots=nav.entry_pivots.astype(np.int32))
        f.flush()
        os.fsync(f.fileno())


def load_nav(path: str, meta: dict) -> Optional[NavGraph]:
    """Tolerant sidecar loader: the nav tier is an ACCELERATOR, never a
    correctness dependency.  Returns None (tier disabled) when the dir
    has no nav (v1/v2 dirs: no ``nav`` meta key), and WARNS + returns
    None when meta promises nav but the sidecar is missing, truncated,
    corrupt, or inconsistent with the core index.  Never raises:
    ``CorruptIndexError`` is reserved for core-index damage."""
    info = meta.get("nav")
    if not isinstance(info, dict):
        return None
    fpath = os.path.join(path, NAV_SIDECAR)

    def _disabled(why: str) -> None:
        warnings.warn(
            f"{path!r}: navigation sidecar unusable ({why}); serving "
            "with nav disabled (entry='auto' falls back to medoid "
            "seeding)", RuntimeWarning, stacklevel=2)
        return None

    try:
        with np.load(fpath) as z:
            pivot_ids = np.asarray(z["pivot_ids"], dtype=np.int64)
            codes = np.asarray(z["codes"], dtype=np.uint8)
            graph = np.asarray(z["graph"], dtype=np.int32)
            entry = np.asarray(z["entry_pivots"], dtype=np.int32)
    except Exception as e:  # noqa: BLE001 — any unreadable sidecar
        return _disabled(f"{type(e).__name__}: {e}")
    P = pivot_ids.shape[0]
    n = int(meta["n"])
    m = int(meta["pq_m"])
    if pivot_ids.ndim != 1 or P == 0:
        return _disabled(f"pivot_ids shape {pivot_ids.shape}")
    if pivot_ids.min() < 0 or pivot_ids.max() >= n:
        return _disabled(f"pivot ids outside [0, {n})")
    if codes.shape != (P, m):
        return _disabled(f"codes shape {codes.shape} != ({P}, {m})")
    if graph.ndim != 2 or graph.shape[0] != P or graph.max(initial=-1) >= P:
        return _disabled(f"pivot graph shape {graph.shape} inconsistent "
                         f"with {P} pivots")
    if entry.ndim != 1 or entry.size == 0 or entry.min() < 0 \
            or entry.max() >= P:
        return _disabled(f"entry_pivots {entry!r} outside [0, {P})")
    if int(info.get("pivots", P)) != P:
        return _disabled(f"meta promises {info.get('pivots')} pivots, "
                         f"sidecar holds {P}")
    return NavGraph(pivot_ids=pivot_ids, codes=codes, graph=graph,
                    entry_pivots=entry, params=dict(info))


# ---------------------------------------------------------------------------
# query time: entry resolution + the vectorized in-RAM nav beam
# ---------------------------------------------------------------------------


def resolve_entry(host, entry: str) -> str:
    """``"auto"`` -> ``"nav"`` iff the index carries a loaded tier, else
    ``"medoid"``; explicit ``"nav"`` on a nav-less index is a usage
    error (ValueError), while ``"medoid"`` always works."""
    if entry not in ("auto", "nav", "medoid"):
        raise ValueError(f"entry must be 'auto', 'nav' or 'medoid', "
                         f"got {entry!r}")
    nav = getattr(host, "nav", None)
    if entry == "auto":
        return "nav" if nav is not None else "medoid"
    if entry == "nav" and nav is None:
        raise ValueError(
            "entry='nav' requested but this index has no navigation tier "
            "(built without nav, or its sidecar failed to load — see the "
            "load warning); use entry='auto' to fall back silently")
    return entry


def _group_rank(group_ids: np.ndarray) -> np.ndarray:
    """Rank within consecutive groups (core.traversal's helper, local
    copy: traversal imports this module, so the edge must point here)."""
    if group_ids.size == 0:
        return group_ids
    starts = np.flatnonzero(
        np.concatenate([[True], group_ids[1:] != group_ids[:-1]]))
    return np.arange(group_ids.size) - np.repeat(
        starts, np.diff(np.concatenate([starts, [group_ids.size]])))


def nav_seed_batch(nav: NavGraph, lut_g: np.ndarray,
                   dq: Optional[np.ndarray], n_seeds: int
                   ) -> Tuple[np.ndarray, np.ndarray,
                              np.ndarray, np.ndarray]:
    """The in-RAM nav beam: per-query entry vertices for the on-disk
    search.  Pure ADC against the RAM-resident pivot codes — zero
    storage I/O.

    ``lut_g`` is the caller's per-query LUT stack — (nq, m, ks) f32, or
    int8 with ``dq`` = (nq, m) f32 dequant factors (``np_host_lut_int8``
    scale * 1/127), EXACTLY as `core.traversal` gathers neighbor codes —
    so beam distances live in the same quantization regime as the main
    search.  Every operation is row-independent: a batch of one computes
    bit-identical output rows to the full batch (the scalar-oracle
    guarantee).

    Returns ``(seed_ids (nq, s) int64 STORAGE-space (-1 padded),
    seed_d (nq, s) f32 ADC dists (+inf on padding), hops (nq,),
    adc_evals (nq,))``; rows are sorted best-first, so ``seed_d[:, 0]``
    is the per-query entry distance.
    """
    nq, m = lut_g.shape[0], lut_g.shape[1]
    jj = np.arange(m)
    P = nav.pivot_ids.shape[0]
    eps = nav.entry_pivots.astype(np.int64)
    e = eps.size
    n_seeds = max(1, int(n_seeds))
    beam_L = max(NAV_BEAM_L, n_seeds, e)
    width = max(beam_L, e)
    cand_i = np.full((nq, width), -1, np.int64)
    cand_d = np.full((nq, width), np.inf, np.float32)
    cand_exp = np.ones((nq, width), bool)
    # entry distances through the SAME 2-d (rows, m) gather+sum shape as
    # the in-loop compute below: numpy's last-axis reduction order can
    # differ between 3-d (nq, e, m) and 2-d arrays by 1 ULP depending on
    # nq, which would break the batch-of-one == full-batch guarantee
    e_q = np.repeat(np.arange(nq), e)
    e_i = np.tile(eps, nq)
    g = lut_g[e_q[:, None], jj[None, :],
              nav.codes[e_i].astype(np.int64)]              # (nq*e, m)
    e_d = (g.astype(np.float32) * dq[e_q]).sum(-1) \
        if dq is not None else g.sum(-1).astype(np.float32)
    cand_d[:, :e] = e_d.reshape(nq, e)
    cand_i[:, :e] = eps
    cand_exp[:, :e] = False
    order = np.argsort(cand_d, axis=1, kind="stable")[:, :beam_L]
    cand_i = np.take_along_axis(cand_i, order, 1)
    cand_d = np.take_along_axis(cand_d, order, 1)
    cand_exp = np.take_along_axis(cand_exp, order, 1)
    hops = np.zeros(nq, np.int64)
    evals = np.full(nq, e, np.int64)
    bits = np.zeros((nq, -(-P // 64)), np.uint64)
    np.bitwise_or.at(
        bits, (np.repeat(np.arange(nq), e), np.tile(eps >> 6, nq)),
        np.tile(np.uint64(1) << (eps & 63).astype(np.uint64), nq))
    R = nav.graph.shape[1]
    while True:
        sel = ~cand_exp & np.isfinite(cand_d)
        fmask = sel & (np.cumsum(sel, axis=1) <= NAV_BEAM_W)
        if not fmask.any():
            break
        qf, cols = np.nonzero(fmask)
        cand_exp |= fmask
        nf = cand_i[qf, cols]
        np.add.at(hops, np.unique(qf), 1)
        nbr = nav.graph[nf].astype(np.int64)                # (F, R)
        q_rep = np.repeat(qf, R)
        ids_f = nbr.reshape(-1)
        valid = ids_f >= 0
        safe = np.where(valid, ids_f, 0)
        seen = (bits[q_rep, safe >> 6] >>
                (safe & 63).astype(np.uint64)) & np.uint64(1)
        first_occ = np.zeros(ids_f.size, bool)
        key = np.where(valid, q_rep * P + safe,
                       nq * P + np.arange(ids_f.size))
        first_occ[np.unique(key, return_index=True)[1]] = True
        fresh = valid & (seen == 0) & first_occ
        f_q = q_rep[fresh]
        f_i = ids_f[fresh]
        if not f_i.size:
            continue
        cg = lut_g[f_q[:, None], jj[None, :],
                   nav.codes[f_i].astype(np.int64)]
        f_d = (cg.astype(np.float32) * dq[f_q]).sum(-1) \
            if dq is not None else cg.sum(-1).astype(np.float32)
        np.add.at(evals, f_q, 1)
        np.bitwise_or.at(bits, (f_q, f_i >> 6),
                         np.uint64(1) << (f_i & 63).astype(np.uint64))
        counts = np.bincount(f_q, minlength=nq)
        K = int(counts.max())
        nrank = _group_rank(f_q)
        new_i = np.full((nq, K), -1, np.int64)
        new_d = np.full((nq, K), np.inf, np.float32)
        new_i[f_q, nrank] = f_i
        new_d[f_q, nrank] = f_d
        all_i = np.concatenate([cand_i, new_i], axis=1)
        all_d = np.concatenate([cand_d, new_d], axis=1)
        all_exp = np.concatenate([cand_exp, ~np.isfinite(new_d)], axis=1)
        order = np.argsort(all_d, axis=1, kind="stable")[:, :beam_L]
        cand_i = np.take_along_axis(all_i, order, 1)
        cand_d = np.take_along_axis(all_d, order, 1)
        cand_exp = np.take_along_axis(all_exp, order, 1)
    s = min(n_seeds, cand_i.shape[1])
    out_i = cand_i[:, :s]
    out_d = cand_d[:, :s].copy()
    pad = ~np.isfinite(out_d)
    seed_ids = np.where(pad, np.int64(-1),
                        nav.pivot_ids[np.where(out_i >= 0, out_i, 0)])
    out_d[pad] = np.inf
    return seed_ids.astype(np.int64), out_d.astype(np.float32), hops, evals
