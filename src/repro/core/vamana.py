"""Vamana graph construction (DiskANN's build algorithm).

Numpy orchestration with vectorized distance math — index *construction* is
the offline/"training" phase of this paper's system; query-time code paths
live in beam_search.py / aisaq_search.py / device_index.py.

Faithful to Subramanya et al. (NeurIPS'19):
  1. start from a random R-regular digraph, entry point = medoid
  2. for each point p in random order: greedy-search(medoid -> p) collecting
     the visited set V; N_out(p) = RobustPrune(p, V, alpha, R); add reverse
     edges, pruning any node whose degree exceeds R
  3. two passes: alpha=1.0 then alpha=cfg.alpha
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


def _dists(data: np.ndarray, q: np.ndarray, ids: np.ndarray, metric: str
           ) -> np.ndarray:
    sub = data[ids]
    if metric == "mips":
        return -(sub @ q)
    diff = sub - q
    return np.einsum("nd,nd->n", diff, diff)


def medoid(data: np.ndarray, metric: str = "l2") -> int:
    mean = data.mean(axis=0)
    if metric == "mips":
        return int(np.argmax(data @ mean))
    d = ((data - mean) ** 2).sum(axis=1)
    return int(np.argmin(d))


def greedy_search(data: np.ndarray, graph: np.ndarray, q: np.ndarray,
                  start: int, L: int, metric: str = "l2",
                  ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Returns (topL_ids, topL_dists, visited_ids_in_expansion_order)."""
    cand_ids = np.array([start], dtype=np.int64)
    cand_d = _dists(data, q, cand_ids, metric)
    inserted = {start}
    expanded: list[int] = []
    expanded_set = set()
    while True:
        # closest unexpanded among top-L
        order = np.argsort(cand_d, kind="stable")
        cand_ids, cand_d = cand_ids[order][:L], cand_d[order][:L]
        nxt = -1
        for i in range(cand_ids.shape[0]):
            if int(cand_ids[i]) not in expanded_set:
                nxt = int(cand_ids[i])
                break
        if nxt < 0:
            break
        expanded.append(nxt)
        expanded_set.add(nxt)
        nbrs = graph[nxt]
        nbrs = nbrs[nbrs >= 0]
        fresh = np.array([v for v in nbrs if int(v) not in inserted],
                         dtype=np.int64)
        if fresh.size:
            inserted.update(int(v) for v in fresh)
            fd = _dists(data, q, fresh, metric)
            cand_ids = np.concatenate([cand_ids, fresh])
            cand_d = np.concatenate([cand_d, fd])
    return cand_ids, cand_d, np.array(expanded, dtype=np.int64)


def robust_prune(data: np.ndarray, p: int, cand: np.ndarray, alpha: float,
                 R: int, metric: str = "l2") -> np.ndarray:
    """RobustPrune: diversified neighbor selection. Returns <=R ids."""
    cand = np.unique(cand)
    cand = cand[cand != p]
    if cand.size == 0:
        return cand
    d_p = _dists(data, data[p], cand, metric)
    order = np.argsort(d_p, kind="stable")
    cand, d_p = cand[order], d_p[order]
    alive = np.ones(cand.size, dtype=bool)
    out = []
    for _ in range(R):
        idx = np.flatnonzero(alive)
        if idx.size == 0:
            break
        star = idx[0]
        out.append(int(cand[star]))
        alive[star] = False
        rest = np.flatnonzero(alive)
        if rest.size == 0:
            break
        d_star = _dists(data, data[cand[star]], cand[rest], metric)
        # occlusion rule: drop v if alpha * d(p*, v) <= d(p, v)
        alive[rest[alpha * d_star <= d_p[rest]]] = False
    return np.array(out, dtype=np.int64)


def build_vamana(data: np.ndarray, *, R: int, L: int, alpha: float = 1.2,
                 metric: str = "l2", seed: int = 0, two_pass: bool = True,
                 log_every: int = 0) -> np.ndarray:
    """Returns adjacency (N, R) int32, -1 padded. data: (N, d)."""
    data = np.ascontiguousarray(data, dtype=np.float32)
    n = data.shape[0]
    rng = np.random.default_rng(seed)
    # random init graph
    graph = np.full((n, R), -1, dtype=np.int32)
    init_deg = min(R, max(1, min(R, n - 1)))
    for i in range(n):
        nb = rng.choice(n - 1, size=init_deg, replace=n - 1 < init_deg)
        nb = nb + (nb >= i)          # skip self
        graph[i, :init_deg] = nb
    ep = medoid(data, metric)
    passes = ([1.0, alpha] if two_pass else [alpha])
    for a in passes:
        order = rng.permutation(n)
        for step, p in enumerate(order):
            p = int(p)
            _, _, _ = 0, 0, 0
            topl, topd, expanded = greedy_search(data, graph, data[p], ep, L,
                                                 metric)
            cand = np.concatenate([expanded, graph[p][graph[p] >= 0]])
            nbrs = robust_prune(data, p, cand, a, R, metric)
            graph[p, :] = -1
            graph[p, :nbrs.size] = nbrs
            # reverse edges
            for j in nbrs:
                j = int(j)
                row = graph[j]
                if p in row:
                    continue
                slot = np.flatnonzero(row < 0)
                if slot.size:
                    row[slot[0]] = p
                else:
                    merged = np.concatenate([row[row >= 0], [p]])
                    pruned = robust_prune(data, j, merged, a, R, metric)
                    graph[j, :] = -1
                    graph[j, :pruned.size] = pruned
            if log_every and step % log_every == 0:
                print(f"  vamana pass(alpha={a}) {step}/{n}", flush=True)
    return graph


def build_sharded(data: np.ndarray, n_shards: int, **kw):
    """Paper Fig. 5: independent per-shard sub-indices over a dataset split.

    Returns list of (global_id_offset, shard_data, shard_graph).
    """
    n = data.shape[0]
    bounds = np.linspace(0, n, n_shards + 1).astype(int)
    shards = []
    for s in range(n_shards):
        lo, hi = bounds[s], bounds[s + 1]
        g = build_vamana(data[lo:hi], **kw)
        shards.append((int(lo), data[lo:hi], g))
    return shards
