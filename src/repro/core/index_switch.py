"""Multi-corpus index management + fast switching (paper §2.2, §4.4).

The RAG scenario: one retriever process serves requests that may target any
of several corpora. DiskANN must reload N-proportional PQ tables per switch;
AiSAQ reloads only entry-point codes (+ centroids unless shared).
"""
from __future__ import annotations

import time
from typing import Dict, Optional

import numpy as np

from repro.core.index_io import HostIndex


class IndexManager:
    """Holds one active HostIndex; switches between registered corpora."""

    def __init__(self, paths: Dict[str, str], mode: Optional[str] = None):
        self.paths = dict(paths)
        self.mode = mode
        self.active_name: Optional[str] = None
        self.active: Optional[HostIndex] = None
        self._centroids_hash: Optional[int] = None
        self._centroids: Optional[np.ndarray] = None

    def switch(self, name: str, share_centroids: bool = True) -> float:
        """Activate corpus `name`. Returns switch wall-time in seconds.

        If the target index was built with the same PQ centroids as the
        currently-loaded ones (hash match in meta.json) and
        `share_centroids`, skip the centroid load — paper Table 4's 0.3 ms
        row, where only ~4 KiB of metadata moves.
        """
        if name == self.active_name:
            return 0.0
        path = self.paths[name]
        t0 = time.perf_counter()
        shared = None
        if share_centroids and self._centroids is not None:
            import json, os
            with open(os.path.join(path, "meta.json")) as f:
                meta_peek = json.load(f)
            if meta_peek.get("centroids_hash") == self._centroids_hash:
                shared = self._centroids
        old = self.active
        self.active = HostIndex.load(path, mode=self.mode,
                                     shared_centroids=shared)
        self.active_name = name
        self._centroids = self.active.centroids
        self._centroids_hash = self.active.meta.get("centroids_hash")
        dt = time.perf_counter() - t0
        if old is not None:
            old.close()
        return dt

    def search(self, q, k: int, L: int, w: int = 4):
        assert self.active is not None, "switch() to a corpus first"
        return self.active.search(q, k, L, w)

    def search_batch(self, Q, k: int, L: int, w: int = 4):
        assert self.active is not None, "switch() to a corpus first"
        return self.active.search_batch(Q, k, L, w)

    def resident_bytes(self) -> int:
        return 0 if self.active is None else self.active.resident_bytes()

    def close(self):
        if self.active is not None:
            self.active.close()
            self.active = None
