"""Multi-corpus index management + fast switching (paper §2.2, §4.4).

The RAG scenario: one retriever process serves requests that may target any
of several corpora. DiskANN must reload N-proportional PQ tables per switch;
AiSAQ reloads only entry-point codes (+ centroids unless shared).

Since the multi-tenant serving PR this is a thin compat wrapper over a
budget-for-one `serving.pool.WarmIndexPool` (`max_open=1`): the pool owns
the open handle, the shared-centroid dedup and the eviction of the
previous corpus.  New code should use `WarmIndexPool` / `RetrievalService`
directly — they hold MANY corpora warm and serve them concurrently.
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np  # noqa: F401  (kept: public module surface since seed)

from repro.core.index_io import HostIndex


class IndexManager:
    """Holds one active HostIndex; switches between registered corpora."""

    def __init__(self, paths: Dict[str, str], mode: Optional[str] = None):
        from repro.serving.pool import WarmIndexPool
        self.pool = WarmIndexPool(paths, max_open=1, mode=mode)
        self.mode = mode
        self.active_name: Optional[str] = None

    @property
    def paths(self) -> Dict[str, str]:
        return self.pool.paths

    @property
    def active(self) -> Optional[HostIndex]:
        if self.active_name is None:
            return None
        return self.pool.peek(self.active_name)

    @property
    def _centroids(self) -> Optional[np.ndarray]:
        idx = self.active
        return None if idx is None else idx.centroids

    def switch(self, name: str, share_centroids: bool = True) -> float:
        """Activate corpus `name`. Returns switch wall-time in seconds.

        If the target index was built with the same PQ centroids as the
        currently-loaded ones (hash match in meta.json) and
        `share_centroids`, skip the centroid load — paper Table 4's 0.3 ms
        row, where only ~4 KiB of metadata moves.  Raises a `KeyError`
        naming the known corpora when `name` was never registered.
        """
        if name == self.active_name:
            return 0.0
        dt = self.pool.ensure(name, share_centroids=share_centroids)
        self.active_name = name
        return dt

    def search(self, q, k: int, L: int, w: int = 4):
        assert self.active is not None, "switch() to a corpus first"
        return self.active.search(q, k, L, w)

    def search_batch(self, Q, k: int, L: int, w: int = 4):
        assert self.active is not None, "switch() to a corpus first"
        return self.active.search_batch(Q, k, L, w)

    def resident_bytes(self) -> int:
        idx = self.active
        return 0 if idx is None else idx.resident_bytes()

    def close(self):
        self.pool.close()
        self.active_name = None
