"""On-disk index format + the host (storage-backed) search backend.

This is the *faithful reproduction* path: real files, real ``os.pread`` per
node expansion, real resident-set accounting. Directory format:

  meta.json          layout + search metadata (entry points, metric, ...)
  chunks.bin         block-aligned node chunks (chunk_layout.pack_chunks_file)
  pq_centroids.npy   (m, ks, dsub) f32 — the "PQ centroid" metadata
  pq_codes.npy       (N, m) u8 — loaded to RAM only in diskann mode
  ep_codes.npy       (n_ep, m) u8 — the ONLY per-node codes AiSAQ keeps in RAM
  groundtruth.npy    optional, for evaluation only (never loaded at serve)

``HostIndex.load`` measures wall-clock load time; ``resident_bytes`` reports
exactly which arrays are RAM-resident, which is the paper's Table 2 metric.
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.block_cache import BlockCache
from repro.core.chunk_layout import B_NUM, ChunkLayout, pack_chunks_file, parse_chunk


# ---------------------------------------------------------------------------
# numpy twins of pq.build_lut / pq.adc (host backend must not pay jit costs)
# ---------------------------------------------------------------------------


def np_build_lut(centroids: np.ndarray, q: np.ndarray, metric: str) -> np.ndarray:
    """centroids (m, ks, dsub), q (d,) -> (m, ks) f32 LUT."""
    m, ks, dsub = centroids.shape
    qs = q.astype(np.float32).reshape(m, 1, dsub)
    if metric == "mips":
        return -np.einsum("mkd,mxd->mk", centroids, qs)
    diff = centroids - qs
    return np.einsum("mkd,mkd->mk", diff, diff)


def np_build_lut_batch(centroids: np.ndarray, Q: np.ndarray,
                       metric: str) -> np.ndarray:
    """centroids (m, ks, dsub), Q (nq, d) -> (nq, m, ks) f32 LUTs."""
    m, ks, dsub = centroids.shape
    qs = Q.astype(np.float32).reshape(Q.shape[0], m, 1, dsub)
    if metric == "mips":
        return -np.einsum("mkd,qmxd->qmk", centroids, qs)
    diff = centroids[None] - qs
    return np.einsum("qmkd,qmkd->qmk", diff, diff)


def np_adc(lut: np.ndarray, codes: np.ndarray) -> np.ndarray:
    """lut (m, ks), codes (..., m) -> (...,) f32."""
    m = lut.shape[0]
    return lut[np.arange(m), codes.astype(np.int64)].sum(axis=-1)


def np_quantize_lut(lut: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """numpy twin of ``kernels.chunk_adc.quantize_lut`` — the SAME recipe
    (symmetric per-query int8, scale = max|lut|, dequant = q8 * scale/127),
    kept jax-free so the host backend never pays jit costs. A parity test
    pins the two implementations together.

    lut (..., m, ks) f32 -> (lut_q8 (..., m, ks) int8, scale (...,) f32).
    """
    lut = np.asarray(lut, dtype=np.float32)
    scale = np.abs(lut).max(axis=(-2, -1))
    lut_q8 = np.clip(np.round(
        lut / np.maximum(scale[..., None, None], np.float32(1e-20))
        * np.float32(127.0)), -127, 127).astype(np.int8)
    return lut_q8, scale.astype(np.float32)


def np_adc_int8(lut_q8: np.ndarray, scale: np.ndarray,
                codes: np.ndarray) -> np.ndarray:
    """Host int8 ADC over a quantized LUT.

    lut_q8 (m, ks) int8, codes (..., m) -> (...,) f32. A scalar `scale`
    reproduces the device int8 fused-hop numerics exactly (int32
    accumulation + ONE rescale — what the MXU one-hot contraction needs);
    a per-subspace (m,) `scale` is the finer host granularity (gathers on
    the host aren't tied to a single-scale contraction).
    """
    m = lut_q8.shape[0]
    g = lut_q8[np.arange(m), codes.astype(np.int64)]
    scale = np.asarray(scale, dtype=np.float32)
    if scale.ndim == 0:
        return g.astype(np.int32).sum(axis=-1).astype(np.float32) \
            * (scale * np.float32(1 / 127))
    return (g.astype(np.float32) * (scale * np.float32(1 / 127))).sum(axis=-1)


def np_host_lut_int8(lut: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """The host search path's int8 LUT: per-(query, subspace) mid-centered
    symmetric quantization through the SAME clip/round recipe as the
    device ``quantize_lut`` (np_quantize_lut applied per subspace row).

    Range-reduction (subtract the per-subspace minimum, center on the
    half-range) shifts every ADC distance of a query by one constant —
    ranking-invariant, so beam search is unaffected — while shrinking the
    quantization step from max|lut|/127 to (subspace range)/254.

    lut (..., m, ks) f32 -> (lut_q8 (..., m, ks) int8, scale (..., m) f32).
    """
    lut = np.asarray(lut, dtype=np.float32)
    res = lut - lut.min(axis=-1, keepdims=True)
    mid = res - res.max(axis=-1, keepdims=True) * np.float32(0.5)
    q8, scale = np_quantize_lut(mid[..., None, :])
    return q8[..., 0, :], scale


# ---------------------------------------------------------------------------
# writer
# ---------------------------------------------------------------------------


def write_index(path: str, *, vectors: np.ndarray, graph: np.ndarray,
                centroids: np.ndarray, codes: np.ndarray, metric: str,
                mode: str, block_bytes: int = 4096, n_ep: int = 1,
                entry_points: Optional[np.ndarray] = None,
                relabel: bool = False,
                extra_meta: Optional[dict] = None) -> dict:
    """Serialize one index. Returns the meta dict.

    ``relabel=True`` applies the graph-locality permutation at pack time
    (``core.relabel``): chunks.bin, pq_codes.npy, ep_codes.npy and the
    entry points are all written in NEW-id space; meta.json records
    ``relabeled: true`` and the old->new map lands in ``id_map.npy`` so
    loaders map results back to the ORIGINAL labels — relabeling is
    invisible above the storage layer.
    """
    os.makedirs(path, exist_ok=True)
    n, d = vectors.shape
    data_dtype = "uint8" if vectors.dtype == np.uint8 else "float32"
    layout = ChunkLayout(mode=mode, dim=d, data_dtype=data_dtype,
                         R=graph.shape[1], pq_m=codes.shape[1],
                         block_bytes=block_bytes)
    if entry_points is None:
        mean = vectors.astype(np.float32).mean(axis=0)
        dd = ((vectors.astype(np.float32) - mean) ** 2).sum(axis=1)
        entry_points = np.argsort(dd)[:n_ep]
    entry_points = np.asarray(entry_points, dtype=np.int64)[:n_ep]
    id_map = None
    if relabel:
        from repro.core.relabel import apply_permutation, \
            locality_permutation
        id_map = locality_permutation(graph, layout.nodes_per_block,
                                      entry_points)
        vectors, graph, codes, entry_points = apply_permutation(
            id_map, vectors, graph, codes, entry_points)
    with open(os.path.join(path, "chunks.bin"), "wb") as f:
        f.write(pack_chunks_file(vectors, graph, codes, layout))
    np.save(os.path.join(path, "pq_centroids.npy"),
            centroids.astype(np.float32))
    np.save(os.path.join(path, "pq_codes.npy"), codes.astype(np.uint8))
    np.save(os.path.join(path, "ep_codes.npy"),
            codes[entry_points].astype(np.uint8))
    cent_hash = int(np.abs(centroids.astype(np.float64)).sum() * 1e6) & 0xFFFFFFFF
    meta = dict(
        n=int(n), dim=int(d), data_dtype=data_dtype, metric=metric, mode=mode,
        R=int(graph.shape[1]), pq_m=int(codes.shape[1]),
        pq_ks=int(centroids.shape[1]), block_bytes=int(block_bytes),
        entry_points=[int(e) for e in entry_points],
        chunk_bytes=layout.chunk_bytes, io_bytes=layout.io_bytes,
        centroids_hash=cent_hash, **(extra_meta or {}))
    if id_map is not None:
        # O(N) sidecar, NOT inline json: meta.json must stay ~4 KiB so the
        # shared-centroids index switch (paper §4.4) stays near-free
        np.save(os.path.join(path, "id_map.npy"), id_map.astype(np.int64))
        meta["relabeled"] = True
    with open(os.path.join(path, "meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    return meta


# ---------------------------------------------------------------------------
# host search backend
# ---------------------------------------------------------------------------


@dataclass
class SearchStats:
    hops: int = 0
    ios: int = 0            # logical chunk reads (cache hit or miss)
    bytes_read: int = 0     # bytes actually pulled from storage
    pq_dists: int = 0
    latency_s: float = 0.0
    syscalls: int = 0       # batched preadv calls issued for this query
    cache_hits: int = 0
    cache_misses: int = 0
    # speculative next-hop prefetch accounting (whole-batch deltas, folded
    # into the batch's lead query like syscall attribution)
    prefetch_issued: int = 0    # blocks landed by the background thread
    prefetch_hits: int = 0      # prefetched blocks a demand fetch consumed
    prefetch_wasted: int = 0    # prefetched blocks dropped unused
    rerank_ios: int = 0     # chunk reads issued by the exact rerank tier


class HostIndex:
    """Storage-backed index: DiskANN mode (codes in RAM) or AiSAQ mode."""

    def __init__(self):
        self.meta: dict = {}
        self.layout: Optional[ChunkLayout] = None
        self.centroids: Optional[np.ndarray] = None
        self.ep_codes: Optional[np.ndarray] = None
        self.pq_codes: Optional[np.ndarray] = None     # diskann mode only
        self.fd: int = -1
        self.path: str = ""
        self.load_time_s: float = 0.0
        self.cache: Optional[BlockCache] = None
        self.new_to_old: Optional[np.ndarray] = None   # relabeled indices

    # -- lifecycle ---------------------------------------------------------
    @classmethod
    def load(cls, path: str, mode: Optional[str] = None,
             shared_centroids: Optional[np.ndarray] = None,
             cache_bytes: int = 10 << 20) -> "HostIndex":
        """Open an index. `mode` may force diskann/aisaq residency policy.

        `shared_centroids`: paper §4.4 — when switching between indices built
        with the same PQ centroids, skip the centroid load entirely (only the
        4 KiB meta.json + entry-point codes move).

        `cache_bytes`: DRAM budget for the LRU block cache on the search hot
        path (0 disables retention but keeps batched reads). This budget is
        deliberately NOT part of `resident_bytes`: the paper's Table 2 counts
        the *algorithmic* residency that scales with N (code tables), while
        the cache is a fixed, tunable knob — report it via `cache_bytes_used`.
        """
        t0 = time.perf_counter()
        self = cls()
        self.path = path
        with open(os.path.join(path, "meta.json")) as f:
            self.meta = json.load(f)
        mode = mode or self.meta["mode"]
        self.mode = mode
        self.layout = ChunkLayout(
            mode=self.meta["mode"], dim=self.meta["dim"],
            data_dtype=self.meta["data_dtype"], R=self.meta["R"],
            pq_m=self.meta["pq_m"], block_bytes=self.meta["block_bytes"])
        if shared_centroids is not None:
            self.centroids = shared_centroids
        else:
            self.centroids = np.load(os.path.join(path, "pq_centroids.npy"))
        self.ep_codes = np.load(os.path.join(path, "ep_codes.npy"))
        if self.meta.get("relabeled"):
            # graph-locality relabeled index: storage is in new-id space;
            # results must be mapped back to the original labels
            from repro.core.relabel import invert_permutation
            self.new_to_old = invert_permutation(
                np.load(os.path.join(path, "id_map.npy")))
        if mode == "diskann":
            # DiskANN residency policy: ALL pq codes pinned in RAM.
            self.pq_codes = np.load(os.path.join(path, "pq_codes.npy"))
        self.fd = os.open(os.path.join(path, "chunks.bin"), os.O_RDONLY)
        self.cache = BlockCache(self.fd, self.layout.io_bytes,
                                capacity_bytes=cache_bytes)
        self.load_time_s = time.perf_counter() - t0
        return self

    def close(self):
        if self.cache is not None:
            self.cache.stop()        # join the prefetch thread first
            self.cache.clear()
        if self.fd >= 0:
            os.close(self.fd)
            self.fd = -1

    def _map_out(self, ids: np.ndarray) -> np.ndarray:
        """Internal (storage) ids -> original labels (-1 padding kept)."""
        if self.new_to_old is None:
            return ids
        valid = ids >= 0
        return np.where(valid, self.new_to_old[np.where(valid, ids, 0)], -1)

    def cache_bytes_used(self) -> int:
        return 0 if self.cache is None else self.cache.used_bytes

    def resident_bytes(self, include_centroids: bool = True) -> int:
        """RAM held by the index (paper Table 2's algorithmic portion)."""
        total = self.ep_codes.nbytes
        if include_centroids:
            total += self.centroids.nbytes
        if self.pq_codes is not None:
            total += self.pq_codes.nbytes
        return int(total)

    # -- I/O -----------------------------------------------------------------
    def _read_chunk(self, node: int, stats: SearchStats) -> np.ndarray:
        lay = self.layout
        off = lay.file_offset(node)
        # OS reads whole blocks: model that faithfully for stats.
        blk_start = off // lay.block_bytes * lay.block_bytes
        nbytes = lay.io_bytes
        raw = os.pread(self.fd, nbytes, blk_start)
        stats.ios += 1
        stats.syscalls += 1
        stats.bytes_read += nbytes
        inner = off - blk_start
        return np.frombuffer(raw, dtype=np.uint8)[inner:inner + lay.chunk_bytes]

    # -- Algorithm 1 (faithful scalar reference) -----------------------------
    def search_ref(self, q: np.ndarray, k: int, L: int, w: int = 4, *,
                   adc_dtype: str = "f32", rerank: Optional[int] = None
                   ) -> Tuple[np.ndarray, SearchStats]:
        """Scalar DiskANN beam search (paper Algorithm 1), one pread per
        node expansion. Kept as the semantics oracle for the vectorized
        hot path — `search` must return bit-identical ids (per adc_dtype:
        the int8 oracle pins the int8 hot path).

        ``rerank`` selects the result tier (see `search_batch`): None is
        the traversal pool, 0 is PQ-only, r > 0 the exact rerank tier."""
        assert adc_dtype in ("f32", "int8"), adc_dtype
        t0 = time.perf_counter()
        q = np.asarray(q, dtype=np.float32)   # same arithmetic as `search`
        stats = SearchStats()
        lay = self.layout
        metric = self.meta["metric"]
        lut = np_build_lut(self.centroids, q.astype(np.float32), metric)
        if adc_dtype == "int8":
            lut_q8, scale = np_host_lut_int8(lut)
            adc = lambda codes: np_adc_int8(lut_q8, scale, codes)  # noqa: E731
        else:
            adc = lambda codes: np_adc(lut, codes)                 # noqa: E731
        eps = np.asarray(self.meta["entry_points"], dtype=np.int64)
        # candidate list: ids, pq-dists, expanded?
        cand_ids = eps.copy()
        cand_d = adc(self.ep_codes)                          # entry codes: RAM
        stats.pq_dists += len(eps)
        expanded: Dict[int, float] = {}                      # id -> exact dist
        inserted = set(int(e) for e in eps)
        while True:
            order = np.argsort(cand_d, kind="stable")[:L]
            cand_ids, cand_d = cand_ids[order], cand_d[order]
            frontier = [int(i) for i in cand_ids if int(i) not in expanded][:w]
            if not frontier:
                break
            stats.hops += 1
            new_ids: List[np.ndarray] = []
            new_d: List[np.ndarray] = []
            for p in frontier:
                raw = self._read_chunk(p, stats)
                vec, ids, inline_codes = parse_chunk(raw, lay)
                # full-precision distance from the chunk (re-rank pool V)
                vf = vec.astype(np.float32)
                if metric == "mips":
                    expanded[p] = float(-(vf @ q))
                else:
                    expanded[p] = float(((vf - q) ** 2).sum())
                valid = ids >= 0
                ids = ids[valid]
                fresh = np.array([i for i in ids if int(i) not in inserted],
                                 dtype=np.int64)
                if fresh.size == 0:
                    continue
                if self.mode == "aisaq":
                    # THE AiSAQ step: neighbor codes come from the chunk we
                    # just read — no N-sized RAM table is ever touched.
                    codes = inline_codes[valid][
                        [int(np.flatnonzero(ids == f)[0]) for f in fresh]]
                else:
                    codes = self.pq_codes[fresh]
                d = adc(codes)
                stats.pq_dists += int(fresh.size)
                inserted.update(int(f) for f in fresh)
                new_ids.append(fresh)
                new_d.append(d)
            if new_ids:
                cand_ids = np.concatenate([cand_ids] + new_ids)
                cand_d = np.concatenate([cand_d] + new_d)
        if rerank is None:
            # re-rank by full-precision distances collected along the path
            vids = np.array(list(expanded.keys()), dtype=np.int64)
            vd = np.array(list(expanded.values()), dtype=np.float32)
            topk = vids[np.argsort(vd, kind="stable")[:k]]
        else:
            topk = self._rerank_tail_ref(q, k, rerank, cand_ids, expanded,
                                         stats)
        stats.latency_s = time.perf_counter() - t0
        return self._map_out(topk), stats

    def _rerank_tail_ref(self, q: np.ndarray, k: int, rerank: int,
                         cand_ids: np.ndarray, expanded: Dict[int, float],
                         stats: SearchStats) -> np.ndarray:
        """Scalar oracle of the exact rerank tier: rescore the final
        (PQ-sorted) candidate list with full-precision vectors. Expanded
        candidates reuse the exact distance computed during traversal;
        unexpanded ones cost one chunk read each (accounted as
        ``rerank_ios``). ``rerank == 0`` returns the PQ-only ranking."""
        limit = max(int(rerank), k) if rerank else k
        sel = cand_ids[:limit]
        if not rerank:                   # PQ-only tier: no rescoring
            return sel[:k].copy()
        metric = self.meta["metric"]
        d = np.empty(sel.size, np.float32)
        for j, p in enumerate(int(x) for x in sel):
            if p in expanded:
                d[j] = expanded[p]
                continue
            raw = self._read_chunk(p, stats)
            stats.rerank_ios += 1
            vec, _, _ = parse_chunk(raw, self.layout)
            vf = vec.astype(np.float32)
            d[j] = -(vf @ q) if metric == "mips" else ((vf - q) ** 2).sum()
        return sel[np.argsort(d, kind="stable")[:k]]

    # -- vectorized hot path -------------------------------------------------
    def _frontier_offsets(self, nodes: np.ndarray
                          ) -> Tuple[np.ndarray, np.ndarray]:
        """node ids -> (block-aligned file offsets, inner chunk offsets)."""
        lay = self.layout
        if lay.nodes_per_block:
            blk, slot = np.divmod(nodes, lay.nodes_per_block)
            return blk * lay.block_bytes, slot * lay.chunk_bytes
        per = lay.blocks_per_chunk * lay.block_bytes
        return nodes * per, np.zeros_like(nodes)

    def search(self, q: np.ndarray, k: int, L: int, w: int = 4, *,
               prefetch: int = 0, adc_dtype: str = "f32",
               rerank: Optional[int] = None
               ) -> Tuple[np.ndarray, SearchStats]:
        """Vectorized beam search (single query). Bit-identical results to
        `search_ref`; all per-hop work batched (one preadv fetch, one ADC)."""
        ids, stats = self.search_batch(q[None], k, L, w, prefetch=prefetch,
                                       adc_dtype=adc_dtype, rerank=rerank)
        return ids[0], stats[0]

    def search_batch(self, Q: np.ndarray, k: int, L: int, w: int = 4, *,
                     prefetch: int = 0, adc_dtype: str = "f32",
                     rerank: Optional[int] = None):
        """Batched vectorized beam search over all queries at once.

        All queries hop together (per-hop frontier interleaving): each hop
        gathers the union of every active query's frontier blocks in ONE
        cache fetch, parses all chunks as a single matrix, and ADCs all
        fresh neighbor codes of all queries as one (F, m) batch against the
        shared per-query LUT stack. Returns (ids (nq, k), [SearchStats]).

        ``prefetch=p`` (p > 0) speculatively queues, per query and hop, the
        blocks of its p closest fresh neighbors for background reading —
        the likely next frontier — so they land while this hop's candidate
        bookkeeping runs. Results are unaffected (the cache is exact);
        only the blocking-syscall count drops. ``adc_dtype="int8"`` runs
        neighbor ADC through the quantized host path (np_quantize_lut /
        np_adc_int8 — the numpy twin of the device int8 kernel); exact
        re-rank distances stay f32.

        ``rerank`` selects the result tier, bit-identical to `search_ref`:
          * None (default) — top-k by the exact distances of nodes expanded
            during traversal (the historical behavior),
          * 0 — PQ-only: top-k of the final candidate list ranked by ADC
            distance alone (no full-precision rescoring — the DiskANN
            no-rerank baseline),
          * r > 0 — the exact rerank tier: the top-max(r, k) candidates of
            the final PQ-sorted list are rescored with full-precision
            vectors. Expanded candidates reuse the distance their chunk
            already yielded; unexpanded ones are fetched through the block
            cache in one batched read (``rerank_ios`` in SearchStats).
            The candidate list holds at most L entries, so the effective
            depth is min(r, L) — pass L >= r for the full depth (the
            serving-tier factories do this automatically).
        """
        assert adc_dtype in ("f32", "int8"), adc_dtype
        t0 = time.perf_counter()
        Q = np.asarray(Q, dtype=np.float32)
        nq = Q.shape[0]
        lay = self.layout
        metric = self.meta["metric"]
        n = int(self.meta["n"])
        lut = np_build_lut_batch(self.centroids, Q, metric)   # (nq, m, ks)
        m = lut.shape[1]
        jj = np.arange(m)
        if adc_dtype == "int8":
            # same quantization as search_ref (np_host_lut_int8): the
            # batch arithmetic below must match np_adc_int8 bit-for-bit
            lut_q8, scale8 = np_host_lut_int8(lut)
            lut_g = lut_q8                                    # int8 gather
            dq = scale8 * np.float32(1 / 127)                 # (nq, m) f32
        else:
            lut_g, dq = lut, None
        pf0 = None
        if self.cache is not None:
            c = self.cache.counters
            pf0 = (c.prefetch_issued, c.prefetch_hits, c.prefetch_wasted)
        eps = np.asarray(self.meta["entry_points"], dtype=np.int64)
        n_ep = len(eps)
        # per-query counters (numpy-resident; folded into SearchStats at end)
        hops_a = np.zeros(nq, np.int64)
        ios_a = np.zeros(nq, np.int64)
        bytes_a = np.zeros(nq, np.int64)
        pq_a = np.zeros(nq, np.int64)
        sys_a = np.zeros(nq, np.int64)
        hit_a = np.zeros(nq, np.int64)
        miss_a = np.zeros(nq, np.int64)
        rr_a = np.zeros(nq, np.int64)
        # candidate lists (sorted by PQ distance, stable; inf-padded to L)
        width = max(L, n_ep)
        cand_ids = np.full((nq, width), -1, np.int64)
        cand_d = np.full((nq, width), np.inf, np.float32)
        cand_exp = np.ones((nq, width), bool)
        cand_ids[:, :n_ep] = eps
        ep_g = lut_g[:, jj, self.ep_codes.astype(np.int64)]   # (nq, n_ep, m)
        cand_d[:, :n_ep] = (ep_g.astype(np.float32)
                            * dq[:, None, :]).sum(-1) \
            if dq is not None else ep_g.sum(-1)
        cand_exp[:, :n_ep] = False
        pq_a += n_ep
        order = np.argsort(cand_d, axis=1, kind="stable")[:, :L]
        cand_ids = np.take_along_axis(cand_ids, order, 1)
        cand_d = np.take_along_axis(cand_d, order, 1)
        cand_exp = np.take_along_axis(cand_exp, order, 1)
        # visited set: packed uint64 bitset, one row per query
        bits = np.zeros((nq, -(-n // 64)), np.uint64)
        np.bitwise_or.at(
            bits, (np.repeat(np.arange(nq), n_ep), np.tile(eps >> 6, nq)),
            np.tile(np.uint64(1) << (eps & 63).astype(np.uint64), nq))
        pool_ids_cols: List[np.ndarray] = []
        pool_d_cols: List[np.ndarray] = []
        while True:
            # 1. frontier = first w unexpanded candidates per query
            sel = ~cand_exp & np.isfinite(cand_d)
            fmask = sel & (np.cumsum(sel, axis=1) <= w)
            if not fmask.any():
                break
            qf, cols = np.nonzero(fmask)       # row-major: grouped by query
            cand_exp |= fmask
            nf = cand_ids[qf, cols]
            np.add.at(hops_a, np.unique(qf), 1)
            np.add.at(ios_a, qf, 1)
            # 2. ONE batched fetch for every frontier chunk this hop; with
            # prefetch on, miss runs tolerate `prefetch`-block holes and
            # read them along (readahead into the cache)
            blk_off, inner = self._frontier_offsets(nf)
            blocks, hit_mask, n_sys = self.cache.fetch(blk_off, gap=prefetch)
            # attribute unique-block hits/misses/bytes to the first query
            # that asked for each block (hit_mask is in first-appearance
            # order, matching sorted first-occurrence indices); syscalls to
            # the hop's lead query
            uq = qf[np.sort(np.unique(blk_off, return_index=True)[1])]
            np.add.at(hit_a, uq[hit_mask], 1)
            np.add.at(miss_a, uq[~hit_mask], 1)
            np.add.at(bytes_a, uq[~hit_mask], lay.io_bytes)
            sys_a[qf[0]] += n_sys
            P = nf.size
            # chunk slice-out: `inner` takes only nodes_per_block distinct
            # values, so per-slot basic slicing beats a fancy-index gather
            chunk = np.empty((P, lay.chunk_bytes), np.uint8)
            for s in np.unique(inner):
                rows = inner == s
                chunk[rows] = blocks[rows, s:s + lay.chunk_bytes]
            # 3. parse all chunks as one matrix
            if lay.data_dtype == "uint8":
                vf = chunk[:, :lay.b_full].astype(np.float32)
            else:
                vf = np.ascontiguousarray(chunk[:, :lay.b_full]) \
                    .view(np.float32).reshape(P, -1)
            nbr = np.ascontiguousarray(
                chunk[:, lay.off_ids:lay.off_ids + lay.R * B_NUM]) \
                .view(np.int32).reshape(P, lay.R).astype(np.int64)
            qv = Q[qf]
            if metric == "mips":
                exact = -np.einsum("pd,pd->p", vf, qv)
            else:
                exact = ((vf - qv) ** 2).sum(axis=1)
            # 4. fresh neighbors: valid, unvisited, first occurrence per query
            q_rep = np.repeat(qf, lay.R)
            ids_f = nbr.reshape(-1)
            valid = ids_f >= 0
            safe = np.where(valid, ids_f, 0)
            seen = (bits[q_rep, safe >> 6] >>
                    (safe & 63).astype(np.uint64)) & np.uint64(1)
            first_occ = np.zeros(ids_f.size, bool)
            key = np.where(valid, q_rep * n + safe,
                           nq * n + np.arange(ids_f.size))
            first_occ[np.unique(key, return_index=True)[1]] = True
            fresh = valid & (seen == 0) & first_occ
            f_q = q_rep[fresh]
            f_ids = ids_f[fresh]
            if lay.mode == "aisaq":
                # THE AiSAQ step: neighbor codes come from the chunks we just
                # fetched — no N-sized RAM table is ever touched.
                codes = chunk[:, lay.off_pq:lay.off_pq + lay.R * lay.pq_m] \
                    .reshape(P * lay.R, lay.pq_m)[fresh]
            else:
                codes = self.pq_codes[f_ids]
            f_g = lut_g[f_q[:, None], jj[None, :], codes.astype(np.int64)]
            f_d = (f_g.astype(np.float32) * dq[f_q]).sum(-1) \
                if dq is not None else f_g.sum(-1).astype(np.float32)
            np.add.at(pq_a, f_q, 1)
            np.bitwise_or.at(bits, (f_q, f_ids >> 6),
                             np.uint64(1) << (f_ids & 63).astype(np.uint64))
            # 5. insert fresh neighbors, re-sort, trim to L
            counts = np.bincount(f_q, minlength=nq)
            K = int(counts.max()) if counts.size else 0
            if K:
                nrank = _group_rank(f_q)
                new_ids = np.full((nq, K), -1, np.int64)
                new_d = np.full((nq, K), np.inf, np.float32)
                new_ids[f_q, nrank] = f_ids
                new_d[f_q, nrank] = f_d
                all_ids = np.concatenate([cand_ids, new_ids], axis=1)
                all_d = np.concatenate([cand_d, new_d], axis=1)
                all_exp = np.concatenate(
                    [cand_exp, ~np.isfinite(new_d)], axis=1)
                order = np.argsort(all_d, axis=1, kind="stable")[:, :L]
                cand_ids = np.take_along_axis(all_ids, order, 1)
                cand_d = np.take_along_axis(all_d, order, 1)
                cand_exp = np.take_along_axis(all_exp, order, 1)
            # 6. async next-hop prefetch (double-buffering): the candidate
            # list the NEXT hop will select its frontier from is final
            # here, so the top `prefetch` unexpanded candidates per query
            # are its exact frontier (depth > w adds margin for later
            # hops). Queue their blocks now — the background thread reads
            # them while the pool bookkeeping below and the next hop's
            # frontier selection run on this thread, turning next hop's
            # blocking misses into prefetch hits. Results are unaffected.
            if prefetch > 0:
                psel = ~cand_exp & np.isfinite(cand_d)
                pn = cand_ids[psel & (np.cumsum(psel, axis=1) <= prefetch)]
                if pn.size:
                    self.cache.prefetch_async(
                        self._frontier_offsets(pn)[0])
            # 7. pool the exact distances of expanded nodes (re-rank pool)
            frank = _group_rank(qf)
            pcol_i = np.full((nq, w), -1, np.int64)
            pcol_d = np.full((nq, w), np.inf, np.float32)
            pcol_i[qf, frank] = nf
            pcol_d[qf, frank] = exact
            pool_ids_cols.append(pcol_i)
            pool_d_cols.append(pcol_d)
        out = np.full((nq, k), -1, np.int64)
        if rerank is not None:
            # -- exact rerank tier over the FINAL candidate list ------------
            # (the scalar twin is _rerank_tail_ref; both must stay
            # bit-identical). The final list is PQ-sorted with inf padding.
            r_eff = max(int(rerank), k) if rerank else 0
            exp_map: List[Dict[int, float]] = [{} for _ in range(nq)]
            if r_eff and pool_ids_cols:
                pool_ids = np.concatenate(pool_ids_cols, axis=1)
                pool_d = np.concatenate(pool_d_cols, axis=1)
                for i in range(nq):
                    vmask = pool_ids[i] >= 0
                    exp_map[i] = dict(zip(pool_ids[i][vmask].tolist(),
                                          pool_d[i][vmask].tolist()))
            sel_ids: List[np.ndarray] = []
            sel_d: List[Optional[np.ndarray]] = []
            need_pairs: List[Tuple[int, int]] = []
            need_nodes: List[int] = []
            for i in range(nq):
                vmask = (cand_ids[i] >= 0) & np.isfinite(cand_d[i])
                sel = cand_ids[i][vmask][:max(r_eff, k)]
                sel_ids.append(sel)
                if not r_eff:            # PQ-only tier: keep ADC ranking
                    sel_d.append(None)
                    continue
                d = np.full(sel.size, np.inf, np.float32)
                for j, p in enumerate(sel.tolist()):
                    e = exp_map[i].get(p)
                    if e is None:
                        need_pairs.append((i, j))
                        need_nodes.append(p)
                    else:
                        d[j] = e
                sel_d.append(d)
            if need_nodes:
                # one batched cache fetch for every unexpanded candidate
                nodes = np.asarray(need_nodes, dtype=np.int64)
                nqi = np.asarray([pr[0] for pr in need_pairs], dtype=np.int64)
                blk_off, inner = self._frontier_offsets(nodes)
                blocks, hit_mask, n_sys = self.cache.fetch(blk_off)
                uq = nqi[np.sort(np.unique(blk_off, return_index=True)[1])]
                np.add.at(hit_a, uq[hit_mask], 1)
                np.add.at(miss_a, uq[~hit_mask], 1)
                np.add.at(bytes_a, uq[~hit_mask], lay.io_bytes)
                sys_a[nqi[0]] += n_sys
                np.add.at(ios_a, nqi, 1)
                np.add.at(rr_a, nqi, 1)
                P2 = nodes.size
                chunk = np.empty((P2, lay.chunk_bytes), np.uint8)
                for s in np.unique(inner):
                    rows = inner == s
                    chunk[rows] = blocks[rows, s:s + lay.chunk_bytes]
                if lay.data_dtype == "uint8":
                    vf = chunk[:, :lay.b_full].astype(np.float32)
                else:
                    vf = np.ascontiguousarray(chunk[:, :lay.b_full]) \
                        .view(np.float32).reshape(P2, -1)
                qv = Q[nqi]
                if metric == "mips":
                    ex = -np.einsum("pd,pd->p", vf, qv)
                else:
                    ex = ((vf - qv) ** 2).sum(axis=1)
                for (i, j), e in zip(need_pairs, ex):
                    sel_d[i][j] = e
            for i in range(nq):
                if r_eff:
                    top = sel_ids[i][
                        np.argsort(sel_d[i], kind="stable")[:k]]
                else:
                    top = sel_ids[i][:k]
                out[i, :top.size] = top
        elif pool_ids_cols:
            # re-rank over every expanded node, in expansion order
            # (stable ties) — the traversal-pool tier
            pool_ids = np.concatenate(pool_ids_cols, axis=1)
            pool_d = np.concatenate(pool_d_cols, axis=1)
            for i in range(nq):
                vmask = pool_ids[i] >= 0
                vids, vd = pool_ids[i][vmask], pool_d[i][vmask]
                top = vids[np.argsort(vd, kind="stable")[:k]]
                out[i, :top.size] = top
        wall = time.perf_counter() - t0
        stats = []
        for i in range(nq):
            stats.append(SearchStats(
                hops=int(hops_a[i]), ios=int(ios_a[i]),
                bytes_read=int(bytes_a[i]), pq_dists=int(pq_a[i]),
                latency_s=wall / nq, syscalls=int(sys_a[i]),
                cache_hits=int(hit_a[i]), cache_misses=int(miss_a[i]),
                rerank_ios=int(rr_a[i])))
        if pf0 is not None:
            # whole-batch prefetch deltas, attributed to the lead query
            c = self.cache.counters
            stats[0].prefetch_issued = c.prefetch_issued - pf0[0]
            stats[0].prefetch_hits = c.prefetch_hits - pf0[1]
            stats[0].prefetch_wasted = c.prefetch_wasted - pf0[2]
        return self._map_out(out), stats

    def search_batch_ref(self, Q: np.ndarray, k: int, L: int, w: int = 4, *,
                         adc_dtype: str = "f32",
                         rerank: Optional[int] = None):
        """Scalar reference loop (the seed implementation's search_batch)."""
        ids = np.zeros((Q.shape[0], k), dtype=np.int64)
        stats = []
        for i in range(Q.shape[0]):
            ids[i], s = self.search_ref(Q[i], k, L, w, adc_dtype=adc_dtype,
                                        rerank=rerank)
            stats.append(s)
        return ids, stats


def _group_rank(group_ids: np.ndarray) -> np.ndarray:
    """Rank within consecutive groups: [3,3,5,5,5,7] -> [0,1,0,1,2,0].
    `group_ids` must be non-decreasing (row-major np.nonzero guarantees it).
    """
    if group_ids.size == 0:
        return group_ids
    starts = np.flatnonzero(
        np.concatenate([[True], group_ids[1:] != group_ids[:-1]]))
    return np.arange(group_ids.size) - np.repeat(
        starts, np.diff(np.concatenate([starts, [group_ids.size]])))


def recall_at(ids: np.ndarray, gt: np.ndarray, k: int) -> float:
    """k-recall@k over a batch: |pred_k ∩ gt_k| / k averaged (vectorized)."""
    p, g = ids[:, :k], gt[:, :k]
    srt = np.sort(p, axis=1)
    if k > 1 and (srt[:, 1:] == srt[:, :-1]).any():
        # duplicate predictions: fall back to exact set semantics
        hits = sum(len(set(map(int, rp)) & set(map(int, rg)))
                   for rp, rg in zip(p, g))
        return hits / (ids.shape[0] * k)
    hits = (p[:, :, None] == g[:, None, :]).any(axis=2).sum()
    return float(hits) / (ids.shape[0] * k)
