"""On-disk index format + the host (storage-backed) search backend.

This is the *faithful reproduction* path: real files, real ``os.pread`` per
node expansion, real resident-set accounting. Directory format:

  meta.json          layout + search metadata (entry points, metric, ...)
  chunks.bin         block-aligned node chunks (chunk_layout.pack_chunks_file)
  pq_centroids.npy   (m, ks, dsub) f32 — the "PQ centroid" metadata
  pq_codes.npy       (N, m) u8 — loaded to RAM only in diskann mode
  ep_codes.npy       (n_ep, m) u8 — the ONLY per-node codes AiSAQ keeps in RAM
  groundtruth.npy    optional, for evaluation only (never loaded at serve)

``HostIndex.load`` measures wall-clock load time; ``resident_bytes`` reports
exactly which arrays are RAM-resident, which is the paper's Table 2 metric.
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.chunk_layout import B_NUM, ChunkLayout, pack_chunks_file, parse_chunk


# ---------------------------------------------------------------------------
# numpy twins of pq.build_lut / pq.adc (host backend must not pay jit costs)
# ---------------------------------------------------------------------------


def np_build_lut(centroids: np.ndarray, q: np.ndarray, metric: str) -> np.ndarray:
    """centroids (m, ks, dsub), q (d,) -> (m, ks) f32 LUT."""
    m, ks, dsub = centroids.shape
    qs = q.astype(np.float32).reshape(m, 1, dsub)
    if metric == "mips":
        return -np.einsum("mkd,mxd->mk", centroids, qs)
    diff = centroids - qs
    return np.einsum("mkd,mkd->mk", diff, diff)


def np_adc(lut: np.ndarray, codes: np.ndarray) -> np.ndarray:
    """lut (m, ks), codes (..., m) -> (...,) f32."""
    m = lut.shape[0]
    return lut[np.arange(m), codes.astype(np.int64)].sum(axis=-1)


# ---------------------------------------------------------------------------
# writer
# ---------------------------------------------------------------------------


def write_index(path: str, *, vectors: np.ndarray, graph: np.ndarray,
                centroids: np.ndarray, codes: np.ndarray, metric: str,
                mode: str, block_bytes: int = 4096, n_ep: int = 1,
                entry_points: Optional[np.ndarray] = None,
                extra_meta: Optional[dict] = None) -> dict:
    """Serialize one index. Returns the meta dict."""
    os.makedirs(path, exist_ok=True)
    n, d = vectors.shape
    data_dtype = "uint8" if vectors.dtype == np.uint8 else "float32"
    layout = ChunkLayout(mode=mode, dim=d, data_dtype=data_dtype,
                         R=graph.shape[1], pq_m=codes.shape[1],
                         block_bytes=block_bytes)
    if entry_points is None:
        mean = vectors.astype(np.float32).mean(axis=0)
        dd = ((vectors.astype(np.float32) - mean) ** 2).sum(axis=1)
        entry_points = np.argsort(dd)[:n_ep]
    entry_points = np.asarray(entry_points, dtype=np.int64)[:n_ep]
    with open(os.path.join(path, "chunks.bin"), "wb") as f:
        f.write(pack_chunks_file(vectors, graph, codes, layout))
    np.save(os.path.join(path, "pq_centroids.npy"),
            centroids.astype(np.float32))
    np.save(os.path.join(path, "pq_codes.npy"), codes.astype(np.uint8))
    np.save(os.path.join(path, "ep_codes.npy"),
            codes[entry_points].astype(np.uint8))
    cent_hash = int(np.abs(centroids.astype(np.float64)).sum() * 1e6) & 0xFFFFFFFF
    meta = dict(
        n=int(n), dim=int(d), data_dtype=data_dtype, metric=metric, mode=mode,
        R=int(graph.shape[1]), pq_m=int(codes.shape[1]),
        pq_ks=int(centroids.shape[1]), block_bytes=int(block_bytes),
        entry_points=[int(e) for e in entry_points],
        chunk_bytes=layout.chunk_bytes, io_bytes=layout.io_bytes,
        centroids_hash=cent_hash, **(extra_meta or {}))
    with open(os.path.join(path, "meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    return meta


# ---------------------------------------------------------------------------
# host search backend
# ---------------------------------------------------------------------------


@dataclass
class SearchStats:
    hops: int = 0
    ios: int = 0
    bytes_read: int = 0
    pq_dists: int = 0
    latency_s: float = 0.0


class HostIndex:
    """Storage-backed index: DiskANN mode (codes in RAM) or AiSAQ mode."""

    def __init__(self):
        self.meta: dict = {}
        self.layout: Optional[ChunkLayout] = None
        self.centroids: Optional[np.ndarray] = None
        self.ep_codes: Optional[np.ndarray] = None
        self.pq_codes: Optional[np.ndarray] = None     # diskann mode only
        self.fd: int = -1
        self.path: str = ""
        self.load_time_s: float = 0.0

    # -- lifecycle ---------------------------------------------------------
    @classmethod
    def load(cls, path: str, mode: Optional[str] = None,
             shared_centroids: Optional[np.ndarray] = None) -> "HostIndex":
        """Open an index. `mode` may force diskann/aisaq residency policy.

        `shared_centroids`: paper §4.4 — when switching between indices built
        with the same PQ centroids, skip the centroid load entirely (only the
        4 KiB meta.json + entry-point codes move).
        """
        t0 = time.perf_counter()
        self = cls()
        self.path = path
        with open(os.path.join(path, "meta.json")) as f:
            self.meta = json.load(f)
        mode = mode or self.meta["mode"]
        self.mode = mode
        self.layout = ChunkLayout(
            mode=self.meta["mode"], dim=self.meta["dim"],
            data_dtype=self.meta["data_dtype"], R=self.meta["R"],
            pq_m=self.meta["pq_m"], block_bytes=self.meta["block_bytes"])
        if shared_centroids is not None:
            self.centroids = shared_centroids
        else:
            self.centroids = np.load(os.path.join(path, "pq_centroids.npy"))
        self.ep_codes = np.load(os.path.join(path, "ep_codes.npy"))
        if mode == "diskann":
            # DiskANN residency policy: ALL pq codes pinned in RAM.
            self.pq_codes = np.load(os.path.join(path, "pq_codes.npy"))
        self.fd = os.open(os.path.join(path, "chunks.bin"), os.O_RDONLY)
        self.load_time_s = time.perf_counter() - t0
        return self

    def close(self):
        if self.fd >= 0:
            os.close(self.fd)
            self.fd = -1

    def resident_bytes(self, include_centroids: bool = True) -> int:
        """RAM held by the index (paper Table 2's algorithmic portion)."""
        total = self.ep_codes.nbytes
        if include_centroids:
            total += self.centroids.nbytes
        if self.pq_codes is not None:
            total += self.pq_codes.nbytes
        return int(total)

    # -- I/O -----------------------------------------------------------------
    def _read_chunk(self, node: int, stats: SearchStats) -> np.ndarray:
        lay = self.layout
        off = lay.file_offset(node)
        # OS reads whole blocks: model that faithfully for stats.
        blk_start = off // lay.block_bytes * lay.block_bytes
        nbytes = lay.io_bytes
        raw = os.pread(self.fd, nbytes, blk_start)
        stats.ios += 1
        stats.bytes_read += nbytes
        inner = off - blk_start
        return np.frombuffer(raw, dtype=np.uint8)[inner:inner + lay.chunk_bytes]

    # -- Algorithm 1 (faithful) ----------------------------------------------
    def search(self, q: np.ndarray, k: int, L: int, w: int = 4
               ) -> Tuple[np.ndarray, SearchStats]:
        """DiskANN beam search with re-ranking (paper Algorithm 1)."""
        t0 = time.perf_counter()
        stats = SearchStats()
        lay = self.layout
        metric = self.meta["metric"]
        lut = np_build_lut(self.centroids, q.astype(np.float32), metric)
        eps = np.asarray(self.meta["entry_points"], dtype=np.int64)
        # candidate list: ids, pq-dists, expanded?
        cand_ids = eps.copy()
        cand_d = np_adc(lut, self.ep_codes)                  # entry codes: RAM
        stats.pq_dists += len(eps)
        expanded: Dict[int, float] = {}                      # id -> exact dist
        inserted = set(int(e) for e in eps)
        while True:
            order = np.argsort(cand_d, kind="stable")[:L]
            cand_ids, cand_d = cand_ids[order], cand_d[order]
            frontier = [int(i) for i in cand_ids if int(i) not in expanded][:w]
            if not frontier:
                break
            stats.hops += 1
            new_ids: List[np.ndarray] = []
            new_d: List[np.ndarray] = []
            for p in frontier:
                raw = self._read_chunk(p, stats)
                vec, ids, inline_codes = parse_chunk(raw, lay)
                # full-precision distance from the chunk (re-rank pool V)
                vf = vec.astype(np.float32)
                if metric == "mips":
                    expanded[p] = float(-(vf @ q))
                else:
                    expanded[p] = float(((vf - q) ** 2).sum())
                valid = ids >= 0
                ids = ids[valid]
                fresh = np.array([i for i in ids if int(i) not in inserted],
                                 dtype=np.int64)
                if fresh.size == 0:
                    continue
                if self.mode == "aisaq":
                    # THE AiSAQ step: neighbor codes come from the chunk we
                    # just read — no N-sized RAM table is ever touched.
                    codes = inline_codes[valid][
                        [int(np.flatnonzero(ids == f)[0]) for f in fresh]]
                else:
                    codes = self.pq_codes[fresh]
                d = np_adc(lut, codes)
                stats.pq_dists += int(fresh.size)
                inserted.update(int(f) for f in fresh)
                new_ids.append(fresh)
                new_d.append(d)
            if new_ids:
                cand_ids = np.concatenate([cand_ids] + new_ids)
                cand_d = np.concatenate([cand_d] + new_d)
        # re-rank by full-precision distances collected along the path
        vids = np.array(list(expanded.keys()), dtype=np.int64)
        vd = np.array(list(expanded.values()), dtype=np.float32)
        topk = vids[np.argsort(vd, kind="stable")[:k]]
        stats.latency_s = time.perf_counter() - t0
        return topk, stats

    def search_batch(self, Q: np.ndarray, k: int, L: int, w: int = 4):
        ids = np.zeros((Q.shape[0], k), dtype=np.int64)
        stats = []
        for i in range(Q.shape[0]):
            ids[i], s = self.search(Q[i], k, L, w)
            stats.append(s)
        return ids, stats


def recall_at(ids: np.ndarray, gt: np.ndarray, k: int) -> float:
    """k-recall@k over a batch: |pred_k ∩ gt_k| / k averaged."""
    hits = 0
    for row_p, row_g in zip(ids[:, :k], gt[:, :k]):
        hits += len(set(map(int, row_p)) & set(map(int, row_g)))
    return hits / (ids.shape[0] * k)
