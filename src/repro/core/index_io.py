"""On-disk index format + the host (storage-backed) index lifecycle.

This is the storage layer of the three-layer host search core:

  ``core.adc``        LUT/ADC numerics (numpy twins of the device kernels)
  ``core.traversal``  the beam-search engine (frontier selection, candidate
                      bookkeeping, rerank tail, SearchStats, pipelining)
  ``core.index_io``   THIS module — on-disk format, ``HostIndex`` lifecycle
                      (fd + block cache + residency accounting); search
                      methods delegate to the engine

For backwards compatibility every pre-split public symbol (``np_*``,
``SearchStats``, ``recall_at``) is re-exported here — external users of
the old monolith keep working.

This is the *faithful reproduction* path: real files, real ``os.pread`` per
node expansion, real resident-set accounting. Directory format:

  meta.json          layout + search metadata (entry points, metric, ...)
  chunks.bin         block-aligned node chunks (chunk_layout.pack_chunks_file)
  pq_centroids.npy   (m, ks, dsub) f32 — the "PQ centroid" metadata
  pq_codes.npy       (N, m) u8 — loaded to RAM only in diskann mode
  ep_codes.npy       (n_ep, m) u8 — the ONLY per-node codes AiSAQ keeps in RAM
  groundtruth.npy    optional, for evaluation only (never loaded at serve)

``HostIndex.load`` measures wall-clock load time; ``resident_bytes`` reports
exactly which arrays are RAM-resident, which is the paper's Table 2 metric.
"""
from __future__ import annotations

import json
import os
import shutil
import time
from typing import Callable, Optional, Tuple, Union

import numpy as np

# compat re-exports: the pre-split monolith exposed these names here ------
from repro.core.adc import (np_adc, np_adc_int8, np_build_lut,  # noqa: F401
                            np_build_lut_batch, np_host_lut_int8,
                            np_quantize_lut)
from repro.core.block_cache import BlockCache, RetryPolicy  # noqa: F401
from repro.core.chunk_layout import ChunkLayout, pack_chunks_file
from repro.core.integrity import (CRC_SIDECAR, FORMAT_VERSION,
                                  CorruptIndexError, PREFERRED_ALGO,
                                  block_checksums, resolve_crc)
from repro.core import nav as _nav
from repro.core import traversal as _traversal
from repro.core.traversal import SearchStats, recall_at  # noqa: F401

__all__ = [
    "write_index", "HostIndex", "SearchStats", "recall_at",
    "CorruptIndexError", "FORMAT_VERSION",
    "np_build_lut", "np_build_lut_batch", "np_adc", "np_quantize_lut",
    "np_adc_int8", "np_host_lut_int8",
]

#: meta.json keys a loadable index directory must carry — validated up
#: front so a truncated/corrupt dir fails with CorruptIndexError, not a
#: KeyError deep inside layout construction.
_REQUIRED_META = ("n", "dim", "data_dtype", "metric", "mode", "R",
                  "pq_m", "block_bytes", "entry_points")


# ---------------------------------------------------------------------------
# writer
# ---------------------------------------------------------------------------


def _fsync_dir(path: str):
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _write_file(path: str, payload: bytes):
    """Write + fsync one data file (durability half of crash-safety)."""
    with open(path, "wb") as f:
        f.write(payload)
        f.flush()
        os.fsync(f.fileno())


def _save_npy(path: str, arr: np.ndarray):
    with open(path, "wb") as f:
        np.save(f, arr)
        f.flush()
        os.fsync(f.fileno())


def _atomic_json(path: str, obj):
    """Crash-safe single-file JSON rewrite: tmp sibling + fsync + atomic
    rename + directory fsync — the per-file version of `write_index`'s
    whole-directory recipe, for in-place mutation (`DynamicHostIndex
    .flush`).  A crash leaves either the old file or the new one, never a
    truncated one the robust loader would reject."""
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(obj, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    _fsync_dir(os.path.dirname(os.path.abspath(path)))


def _atomic_npy(path: str, arr: np.ndarray):
    """`_atomic_json`'s .npy twin."""
    tmp = path + ".tmp"
    _save_npy(tmp, arr)
    os.replace(tmp, path)
    _fsync_dir(os.path.dirname(os.path.abspath(path)))


def write_index(path: str, *, vectors: np.ndarray, graph: np.ndarray,
                centroids: np.ndarray, codes: np.ndarray, metric: str,
                mode: str, block_bytes: int = 4096, n_ep: int = 1,
                entry_points: Optional[np.ndarray] = None,
                relabel: bool = False,
                labels: Optional[np.ndarray] = None,
                nav: bool = False,
                nav_fraction: float = _nav.DEFAULT_FRACTION,
                nav_degree: int = _nav.DEFAULT_DEGREE,
                nav_seed: int = 0,
                nav_method: str = _nav.DEFAULT_METHOD,
                extra_meta: Optional[dict] = None) -> dict:
    """Serialize one index. Returns the meta dict.

    ``relabel=True`` applies the graph-locality permutation at pack time
    (``core.relabel``): chunks.bin, pq_codes.npy, ep_codes.npy and the
    entry points are all written in NEW-id space; meta.json records
    ``relabeled: true`` and the old->new map lands in ``id_map.npy`` so
    loaders map results back to the ORIGINAL labels — relabeling is
    invisible above the storage layer.

    ``labels`` (optional, shape (n,)) assigns each input vector an
    explicit external label instead of its positional id — the dynamic
    tier's compactor uses this so labels survive tombstone reclaim (the
    surviving labels are no longer a permutation of range(n), which the
    ``id_map`` mechanism cannot express).  The labels land, permuted to
    storage order when ``relabel`` is on, in a ``labels.npy`` sidecar
    with ``meta["label_map"] = "direct"``; loaders map results through it
    in preference to the ``id_map`` inversion.

    Crash-safety: every file is written into a ``path + ".tmp"`` sibling,
    fsynced, and the tmp dir is atomically renamed into place — a crash
    mid-write leaves either the old index or the new one, never a dir
    with a meta.json describing half-written chunks.  Integrity: one
    checksum per I/O unit of chunks.bin lands in the ``block_crc.npy``
    sidecar; loaders verify every block read against it.

    ``nav=True`` additionally builds the in-memory navigation tier
    (``core.nav``): ~``nav_fraction`` of nodes become pivots
    (``nav_method`` selection, seed-stable in ``nav_seed``), a
    degree-``nav_degree`` pivot k-NN graph plus the pivots' PQ codes
    land in the OPTIONAL ``nav_graph.npz`` sidecar (``format_version``
    3, ``meta["nav"]`` records the params), and query-time searches can
    use ``entry="nav"`` for per-query entry vertices.  The tier is
    built AFTER the relabel permutation, so pivot ids are storage-space
    ids.  See ``docs/navigation.md``.
    """
    path = os.path.normpath(path)
    tmp = path + ".tmp"
    shutil.rmtree(tmp, ignore_errors=True)
    os.makedirs(tmp)
    n, d = vectors.shape
    data_dtype = "uint8" if vectors.dtype == np.uint8 else "float32"
    layout = ChunkLayout(mode=mode, dim=d, data_dtype=data_dtype,
                         R=graph.shape[1], pq_m=codes.shape[1],
                         block_bytes=block_bytes)
    if entry_points is None:
        mean = vectors.astype(np.float32).mean(axis=0)
        dd = ((vectors.astype(np.float32) - mean) ** 2).sum(axis=1)
        entry_points = np.argsort(dd)[:n_ep]
    entry_points = np.asarray(entry_points, dtype=np.int64)[:n_ep]
    id_map = None
    if labels is not None:
        labels = np.asarray(labels, dtype=np.int64)
        if labels.shape[0] != n:
            raise ValueError(
                f"labels has {labels.shape[0]} entries for {n} vectors")
    if relabel:
        from repro.core.relabel import apply_permutation, \
            invert_permutation, locality_permutation
        id_map = locality_permutation(graph, layout.nodes_per_block,
                                      entry_points)
        vectors, graph, codes, entry_points = apply_permutation(
            id_map, vectors, graph, codes, entry_points)
        if labels is not None:
            # storage slot i now holds input row new_to_old[i]
            labels = labels[invert_permutation(id_map)]
    payload = pack_chunks_file(vectors, graph, codes, layout)
    _write_file(os.path.join(tmp, "chunks.bin"), payload)
    _save_npy(os.path.join(tmp, CRC_SIDECAR),
              block_checksums(payload, layout.io_bytes,
                              resolve_crc(PREFERRED_ALGO)))
    _save_npy(os.path.join(tmp, "pq_centroids.npy"),
              centroids.astype(np.float32))
    _save_npy(os.path.join(tmp, "pq_codes.npy"), codes.astype(np.uint8))
    _save_npy(os.path.join(tmp, "ep_codes.npy"),
              codes[entry_points].astype(np.uint8))
    nav_meta = None
    if nav:
        # after the relabel block: vectors/codes are in storage order, so
        # pivot ids land directly in storage-id space
        nav_obj = _nav.build_nav(vectors, codes, fraction=nav_fraction,
                                 degree=nav_degree, seed=nav_seed,
                                 method=nav_method, metric=metric)
        _nav.save_nav(os.path.join(tmp, _nav.NAV_SIDECAR), nav_obj)
        nav_meta = nav_obj.params
    cent_hash = int(np.abs(centroids.astype(np.float64)).sum() * 1e6) & 0xFFFFFFFF
    meta = dict(
        n=int(n), dim=int(d), data_dtype=data_dtype, metric=metric, mode=mode,
        R=int(graph.shape[1]), pq_m=int(codes.shape[1]),
        pq_ks=int(centroids.shape[1]), block_bytes=int(block_bytes),
        entry_points=[int(e) for e in entry_points],
        chunk_bytes=layout.chunk_bytes, io_bytes=layout.io_bytes,
        centroids_hash=cent_hash, format_version=FORMAT_VERSION,
        crc_algo=PREFERRED_ALGO,
        **({"nav": nav_meta} if nav_meta is not None else {}),
        **(extra_meta or {}))
    if id_map is not None:
        # O(N) sidecar, NOT inline json: meta.json must stay ~4 KiB so the
        # shared-centroids index switch (paper §4.4) stays near-free
        _save_npy(os.path.join(tmp, "id_map.npy"), id_map.astype(np.int64))
        meta["relabeled"] = True
    if labels is not None:
        _save_npy(os.path.join(tmp, "labels.npy"), labels)
        meta["label_map"] = "direct"
    # meta.json lands LAST: its presence marks the dir complete
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    _fsync_dir(tmp)
    # atomic publication: move any previous index aside, rename the tmp
    # sibling into place, then reclaim the old dir
    old = path + ".old"
    if os.path.exists(path):
        shutil.rmtree(old, ignore_errors=True)
        os.rename(path, old)
    try:
        os.rename(tmp, path)
    except OSError:
        if os.path.exists(old):          # restore the previous index
            os.rename(old, path)
        raise
    shutil.rmtree(old, ignore_errors=True)
    parent = os.path.dirname(os.path.abspath(path))
    _fsync_dir(parent)
    return meta


# ---------------------------------------------------------------------------
# host index lifecycle (search delegates to core.traversal)
# ---------------------------------------------------------------------------


def load_meta(path: str) -> dict:
    """Read + validate an index dir's meta.json.  Missing, truncated, or
    key-incomplete metadata raises CorruptIndexError with the failure
    spelled out — never a raw JSONDecodeError/KeyError traceback."""
    mpath = os.path.join(path, "meta.json")
    try:
        with open(mpath) as f:
            meta = json.load(f)
    except FileNotFoundError:
        raise CorruptIndexError(
            f"{path!r} is not a loadable index: meta.json is missing "
            "(incomplete write or wrong directory)") from None
    except (json.JSONDecodeError, UnicodeDecodeError) as e:
        raise CorruptIndexError(
            f"{path!r} has a truncated/corrupt meta.json: {e}") from None
    if not isinstance(meta, dict):
        raise CorruptIndexError(
            f"{path!r} meta.json holds {type(meta).__name__}, not an "
            "index description")
    missing = [k for k in _REQUIRED_META if k not in meta]
    if missing:
        raise CorruptIndexError(
            f"{path!r} meta.json is missing required keys {missing} "
            "(truncated write?)")
    fmt = int(meta.get("format_version", 1))
    if fmt > FORMAT_VERSION:
        raise CorruptIndexError(
            f"{path!r} has format_version {fmt}; this build understands "
            f"up to {FORMAT_VERSION} — rebuild or upgrade")
    return meta


class HostIndex:
    """Storage-backed index: DiskANN mode (codes in RAM) or AiSAQ mode."""

    def __init__(self):
        self.meta: dict = {}
        self.layout: Optional[ChunkLayout] = None
        self.centroids: Optional[np.ndarray] = None
        self.ep_codes: Optional[np.ndarray] = None
        self.pq_codes: Optional[np.ndarray] = None     # diskann mode only
        self.fd: int = -1
        self.path: str = ""
        self.load_time_s: float = 0.0
        self.cache: Optional[BlockCache] = None
        self.new_to_old: Optional[np.ndarray] = None   # relabeled indices
        self.nav = None                # optional navigation tier (core.nav)

    # -- lifecycle ---------------------------------------------------------
    @classmethod
    def load(cls, path: str, mode: Optional[str] = None,
             shared_centroids: Optional[np.ndarray] = None,
             cache_bytes: int = 10 << 20, *,
             preadv: Optional[Callable] = None,
             retry: Optional[RetryPolicy] = None,
             verify_checksums: Optional[bool] = None) -> "HostIndex":
        """Open an index. `mode` may force diskann/aisaq residency policy.

        `shared_centroids`: paper §4.4 — when switching between indices built
        with the same PQ centroids, skip the centroid load entirely (only the
        4 KiB meta.json + entry-point codes move).

        `cache_bytes`: DRAM budget for the LRU block cache on the search hot
        path (0 disables retention but keeps batched reads). This budget is
        deliberately NOT part of `resident_bytes`: the paper's Table 2 counts
        the *algorithmic* residency that scales with N (code tables), while
        the cache is a fixed, tunable knob — report it via `cache_bytes_used`.

        Fault-tolerance knobs: `preadv` swaps the raw read syscall the
        block cache issues (fault injection); `retry` overrides the
        transient-error RetryPolicy (default 3 attempts, capped
        exponential backoff); `verify_checksums` forces per-block CRC
        verification on (CorruptIndexError if the dir has no sidecar) or
        off — None means "verify iff the dir carries a block_crc.npy
        sidecar", which is how legacy format-v1 dirs keep loading.
        """
        t0 = time.perf_counter()
        self = cls()
        self.path = path
        self.meta = load_meta(path)
        wal_path = os.path.join(path, "wal.log")
        if not getattr(cls, "_allows_wal", False) \
                and os.path.exists(wal_path) and os.path.getsize(wal_path):
            # a non-empty write-ahead journal means unflushed (possibly
            # half-applied) mutations: the npy/meta files here do NOT
            # describe chunks.bin.  Only the dynamic loader knows how to
            # reconcile them — serving this dir read-only would silently
            # answer from an inconsistent graph.
            raise CorruptIndexError(
                f"{path!r} carries a non-empty write-ahead journal "
                "(wal.log): unrecovered dynamic mutations. Open it with "
                "DynamicHostIndex.load to recover, or flush the writer.")
        mode = mode or self.meta["mode"]
        self.mode = mode
        self.layout = ChunkLayout(
            mode=self.meta["mode"], dim=self.meta["dim"],
            data_dtype=self.meta["data_dtype"], R=self.meta["R"],
            pq_m=self.meta["pq_m"], block_bytes=self.meta["block_bytes"])
        if shared_centroids is not None:
            self.centroids = shared_centroids
        else:
            self.centroids = np.load(os.path.join(path, "pq_centroids.npy"))
        self.ep_codes = np.load(os.path.join(path, "ep_codes.npy"))
        # optional navigation tier: v1/v2 dirs (no "nav" meta key) and
        # dirs with a damaged sidecar load with the tier disabled —
        # load_nav warns instead of raising (accelerator, not a
        # correctness dependency)
        self.nav = _nav.load_nav(path, self.meta)
        if self.meta.get("label_map") == "direct":
            # explicit per-slot labels (compacted dynamic index): the map
            # is stored directly — it is generally NOT a permutation of
            # range(n) (tombstone reclaim leaves label holes), so it takes
            # precedence over any id_map inversion
            self.new_to_old = np.load(os.path.join(path, "labels.npy"))
        elif self.meta.get("relabeled"):
            # graph-locality relabeled index: storage is in new-id space;
            # results must be mapped back to the original labels
            from repro.core.relabel import invert_permutation
            self.new_to_old = invert_permutation(
                np.load(os.path.join(path, "id_map.npy")))
        if mode == "diskann":
            # DiskANN residency policy: ALL pq codes pinned in RAM.
            self.pq_codes = np.load(os.path.join(path, "pq_codes.npy"))
        cbin = os.path.join(path, "chunks.bin")
        try:
            self.fd = os.open(cbin, os.O_RDONLY)
        except FileNotFoundError:
            raise CorruptIndexError(
                f"{path!r} meta.json exists but chunks.bin is missing "
                "(torn write?)") from None
        block_crc, crc_fn = self._load_crc_sidecar(path, verify_checksums)
        self.cache = BlockCache(self.fd, self.layout.io_bytes,
                                capacity_bytes=cache_bytes,
                                preadv=preadv, retry=retry,
                                block_crc=block_crc, crc=crc_fn,
                                path=cbin)
        self.load_time_s = time.perf_counter() - t0
        return self

    def _load_crc_sidecar(self, path: str,
                          verify: Optional[bool]
                          ) -> Tuple[Optional[np.ndarray],
                                     Optional[Callable]]:
        """Resolve the per-block checksum sidecar: (crc array, crc fn) or
        (None, None) when verification is off.  verify=None auto-enables
        iff the sidecar exists (legacy v1 dirs load unverified)."""
        spath = os.path.join(path, CRC_SIDECAR)
        have = os.path.exists(spath)
        if verify is None:
            verify = have
        if not verify:
            return None, None
        if not have:
            raise CorruptIndexError(
                f"{path!r}: checksum verification requested but the "
                f"{CRC_SIDECAR} sidecar is missing")
        block_crc = np.load(spath)
        fsize = os.fstat(self.fd).st_size
        io = self.layout.io_bytes
        if block_crc.size * io > fsize:
            raise CorruptIndexError(
                f"{path!r}: chunks.bin holds {fsize // io} I/O units but "
                f"{CRC_SIDECAR} describes {block_crc.size} — chunks.bin "
                "is truncated")
        return block_crc.astype(np.uint32), \
            resolve_crc(self.meta.get("crc_algo", "crc32"))

    def close(self):
        if self.cache is not None:
            self.cache.stop()        # join the prefetch thread first
            self.cache.clear()
        if self.fd >= 0:
            os.close(self.fd)
            self.fd = -1

    def _map_out(self, ids: np.ndarray) -> np.ndarray:
        """Internal (storage) ids -> original labels (-1 padding kept)."""
        if self.new_to_old is None:
            return ids
        valid = ids >= 0
        return np.where(valid, self.new_to_old[np.where(valid, ids, 0)], -1)

    def cache_bytes_used(self) -> int:
        return 0 if self.cache is None else self.cache.used_bytes

    def resident_bytes(self, include_centroids: bool = True) -> int:
        """RAM held by the index (paper Table 2's algorithmic portion)."""
        total = self.ep_codes.nbytes
        if include_centroids:
            total += self.centroids.nbytes
        if self.pq_codes is not None:
            total += self.pq_codes.nbytes
        if self.nav is not None:
            # the navigation tier pins pivot ids/codes/graph in RAM; it
            # scales with N (fraction * n) so it IS algorithmic residency
            # and is charged against the WarmIndexPool DRAM budget
            total += self.nav.resident_nbytes()
        return int(total)

    # -- I/O -----------------------------------------------------------------
    def _read_chunk(self, node: int, stats: SearchStats) -> np.ndarray:
        lay = self.layout
        off = lay.file_offset(node)
        # OS reads whole blocks: model that faithfully for stats.
        blk_start = off // lay.block_bytes * lay.block_bytes
        nbytes = lay.io_bytes
        raw = os.pread(self.fd, nbytes, blk_start)
        stats.ios += 1
        stats.syscalls += 1
        stats.bytes_read += nbytes
        inner = off - blk_start
        return np.frombuffer(raw, dtype=np.uint8)[inner:inner + lay.chunk_bytes]

    def _frontier_offsets(self, nodes: np.ndarray
                          ) -> Tuple[np.ndarray, np.ndarray]:
        """node ids -> (block-aligned file offsets, inner chunk offsets)."""
        lay = self.layout
        if lay.nodes_per_block:
            blk, slot = np.divmod(nodes, lay.nodes_per_block)
            return blk * lay.block_bytes, slot * lay.chunk_bytes
        per = lay.blocks_per_chunk * lay.block_bytes
        return nodes * per, np.zeros_like(nodes)

    # -- search (delegates to the core.traversal engine) --------------------
    def search_ref(self, q: np.ndarray, k: int, L: int, w: int = 4, *,
                   adc_dtype: str = "f32", rerank: Optional[int] = None,
                   entry: str = "auto"
                   ) -> Tuple[np.ndarray, SearchStats]:
        """Scalar DiskANN beam search (paper Algorithm 1) — the semantics
        oracle the vectorized hot path must match bit-for-bit (per
        adc_dtype, per entry mode).  See ``core.traversal.search_ref``."""
        ids, stats = _traversal.search_ref(self, q, k, L, w,
                                           adc_dtype=adc_dtype,
                                           rerank=rerank, entry=entry)
        return self._map_out(ids), stats

    def search_batch_ref(self, Q: np.ndarray, k: int, L: int, w: int = 4, *,
                         adc_dtype: str = "f32",
                         rerank: Optional[int] = None,
                         entry: str = "auto"):
        """Scalar reference loop (the seed implementation's search_batch)."""
        ids, stats = _traversal.search_batch_ref(self, Q, k, L, w,
                                                 adc_dtype=adc_dtype,
                                                 rerank=rerank, entry=entry)
        return self._map_out(ids), stats

    def search(self, q: np.ndarray, k: int, L: int, w: int = 4, *,
               prefetch: int = 0, adc_dtype: str = "f32",
               rerank: Optional[int] = None,
               pipeline: Optional[bool] = None,
               gap: Optional[Union[int, str]] = None,
               entry: str = "auto"
               ) -> Tuple[np.ndarray, SearchStats]:
        """Vectorized beam search (single query). Bit-identical results to
        `search_ref`; all per-hop work batched (one preadv fetch, one ADC).
        See `search_batch` for the knobs."""
        ids, stats = self.search_batch(q[None], k, L, w, prefetch=prefetch,
                                       adc_dtype=adc_dtype, rerank=rerank,
                                       pipeline=pipeline, gap=gap,
                                       entry=entry)
        return ids[0], stats[0]

    def search_batch(self, Q: np.ndarray, k: int, L: int, w: int = 4, *,
                     prefetch: int = 0, adc_dtype: str = "f32",
                     rerank: Optional[int] = None,
                     pipeline: Optional[bool] = None,
                     gap: Optional[Union[int, str]] = None,
                     entry: str = "auto"):
        """Batched vectorized beam search over all queries at once, with
        optional two-hop pipelining (``pipeline``, default on whenever
        ``prefetch > 0``), readahead-gap control (``gap``, including
        ``"auto"``), and entry seeding (``entry="nav"|"medoid"|"auto"``:
        per-query entry vertices from the in-RAM navigation tier vs the
        fixed medoid — "auto" uses nav iff the index carries the tier).
        Full knob documentation: ``core.traversal.search_batch``.
        Returns (ids (nq, k) in ORIGINAL labels, [SearchStats])."""
        ids, stats = _traversal.search_batch(self, Q, k, L, w,
                                             prefetch=prefetch,
                                             adc_dtype=adc_dtype,
                                             rerank=rerank,
                                             pipeline=pipeline, gap=gap,
                                             entry=entry)
        return self._map_out(ids), stats
