"""Byte-budgeted LRU block cache + batched block I/O + async prefetch.

The paper's host tier reads one ``io_bytes`` unit (>= one 4 KiB LBA block)
per node expansion. The seed implementation paid one ``os.pread`` syscall
per *node*; this cache turns the per-hop frontier into ONE batched fetch:

  * cache hits are served from an LRU dict of resident blocks whose total
    size is capped by an explicit byte budget — the DRAM knob the disk-ANNS
    literature tunes (DiskANN++ hot-vertex caching; the paper's ~10 MB
    host budget made explicit),
  * cache misses are sorted, deduplicated, coalesced into contiguous runs,
    and each run is read with a single ``os.preadv`` — one syscall fills
    every block buffer of the run. ``gap`` > 0 additionally merges runs
    separated by up to that many absent blocks and reads the hole blocks
    along (readahead): with a graph-locality-relabeled layout the per-hop
    miss set is clustered, so a handful of gap-tolerant runs replaces
    dozens of exact ones, and the hole blocks land in the cache as
    speculative residents that later hops hit,
  * ``prefetch_async`` moves speculative next-hop reads off the demand
    path: a background thread reads queued blocks with the same coalesced
    preadv discipline and lands them in the LRU. A demand fetch that wants
    a block already *in flight* WAITS for the background read instead of
    duplicating it (condition-variable handoff — the double-buffer
    discipline), so every block is read from storage at most once.

Speculation is accounted honestly: ``prefetch_syscalls``/``prefetch_bytes``
count background I/O, ``prefetch_issued`` counts speculatively landed
blocks (background reads + readahead holes), ``prefetch_hits`` counts
those a demand fetch actually consumed, ``prefetch_wasted`` those evicted,
cleared, or invalidated unused. Counters feed ``SearchStats`` and the
bench_search report.

Storage fault tolerance: every raw read — demand, fallback, and
background — funnels through one ``_read_run`` that (a) retries
transient errors and short reads under a ``RetryPolicy`` with capped
exponential backoff, and (b) verifies each block against the per-block
CRC sidecar (``block_crc``) when the index carries one, with a
mismatch-triggers-one-reread policy before declaring the bytes corrupt
(``CorruptBlockError``).  The raw syscall is a pluggable ``preadv``
hook so ``core.faults.FaultInjector`` can drive a deterministic fault
schedule through the REAL read path.
"""
from __future__ import annotations

import errno
import os
import threading
import time
from collections import OrderedDict
from dataclasses import dataclass
from queue import Queue
from typing import Callable, Dict, List, Optional, Set, Tuple, Union

import numpy as np

from repro.obs import trace as obs_trace

from repro.core.integrity import CorruptBlockError, _crc32

_PENDING_WAIT_S = 0.5       # bound on waiting for an in-flight prefetch
_AUTO_GAP_MAX = 8           # largest gap "auto" will ever pick
_AUTO_GAP_MIN_OBS = 8       # holes observed before "auto" trusts the data
_GAP_HIST_MAX = 64          # holes larger than this aren't coalescible


@dataclass(frozen=True)
class RetryPolicy:
    """Transient-error retry knob for every storage read the cache issues
    (demand AND background).  A read failing with a retryable errno — or
    returning fewer bytes than the run's buffers hold, which the
    block-multiple file format makes equally transient — is retried up to
    ``attempts`` total tries with capped exponential backoff.  The final
    failure propagates unchanged."""
    attempts: int = 3
    backoff_s: float = 0.002        # sleep before the first retry
    backoff_mult: float = 2.0
    backoff_max_s: float = 0.05
    retryable: Tuple[int, ...] = (errno.EIO, errno.EAGAIN, errno.EINTR,
                                  errno.ETIMEDOUT)


@dataclass
class CacheCounters:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    syscalls: int = 0        # demand-path preadv calls (block the search)
    bytes_read: int = 0      # demand-path bytes pulled from storage
    fetch_calls: int = 0     # batched fetch() invocations (one per hop)
    prefetch_issued: int = 0     # speculative blocks landed (async + holes)
    prefetch_syscalls: int = 0   # preadv calls issued off the demand path
    prefetch_bytes: int = 0      # bytes read off the demand path
    prefetch_hits: int = 0       # speculative blocks a demand fetch consumed
    prefetch_wasted: int = 0     # speculative blocks dropped unused
    prefetch_errors: int = 0     # background read batches that raised
    auto_gap: int = 0            # last gap chosen by fetch(gap="auto")
    read_retries: int = 0        # transient read failures absorbed by retry
    crc_mismatches: int = 0      # block reads whose checksum mismatched
    crc_rereads: int = 0         # policy rereads issued after a mismatch

    def snapshot(self) -> Tuple[int, ...]:
        return (self.hits, self.misses, self.evictions, self.syscalls,
                self.bytes_read, self.fetch_calls, self.prefetch_issued,
                self.prefetch_syscalls, self.prefetch_bytes,
                self.prefetch_hits, self.prefetch_wasted,
                self.prefetch_errors, self.auto_gap, self.read_retries,
                self.crc_mismatches, self.crc_rereads)

    def reset(self):
        """Zero every counter in place (phase boundaries in benchmarks)."""
        for f in self.__dataclass_fields__:
            setattr(self, f, 0)


class BlockCache:
    """LRU over fixed-size I/O units of one open file descriptor.

    capacity_bytes == 0 disables retention but keeps the batched coalesced
    read path (every fetch is a miss); the syscall batching win remains.
    All mutation of the resident set is guarded by one condition variable
    so the background prefetcher and the demand path compose safely.
    """

    def __init__(self, fd: int, io_bytes: int,
                 capacity_bytes: int = 10 << 20, *,
                 preadv: Optional[Callable] = None,
                 retry: Optional[RetryPolicy] = None,
                 block_crc: Optional[np.ndarray] = None,
                 crc: Optional[Callable] = None,
                 path: str = ""):
        self.fd = fd
        self.io_bytes = int(io_bytes)
        self.capacity_bytes = max(0, int(capacity_bytes))
        # the fault-tolerance hooks: `preadv` swaps the raw read syscall
        # (fault injection / alternative transports), `retry` bounds the
        # transient-error retry loop, `block_crc` (uint32 per io unit)
        # enables per-block verification of every demand and prefetch
        # read with a mismatch-triggers-one-reread policy
        self._preadv = preadv if preadv is not None else os.preadv
        self.retry = retry if retry is not None else RetryPolicy()
        self.block_crc = block_crc
        self._crc = crc if crc is not None else _crc32
        self._path = path               # error-message context only
        self.max_entries = self.capacity_bytes // self.io_bytes
        self._blocks: "OrderedDict[int, np.ndarray]" = OrderedDict()
        self.counters = CacheCounters()
        # demand-miss run structure, recorded on every fetch regardless of
        # the gap in use: lengths of contiguous miss runs and the hole
        # sizes separating consecutive runs (both in blocks).  gap="auto"
        # picks its coalescing gap from the hole distribution.
        self.miss_run_hist: Dict[int, int] = {}
        self.miss_gap_hist: Dict[int, int] = {}
        self._cond = threading.Condition()
        self._prefetched: Set[int] = set()   # resident but not yet demanded
        self._inflight: Set[int] = set()     # queued for background read
        self._pf_queue: Optional[Queue] = None
        self._pf_thread: Optional[threading.Thread] = None
        # invalidation epoch: bumped by invalidate()/clear().  A reader
        # snapshots it BEFORE its preadv and only inserts speculative
        # (hole) buffers if it is unchanged at landing time — hole blocks
        # have no _inflight claim token to cancel, so without this a
        # buffer read before an in-place chunk write could land stale
        # data in the cache after the write invalidated the range.
        self._epoch = 0

    # -- accounting ----------------------------------------------------------
    @property
    def used_bytes(self) -> int:
        return len(self._blocks) * self.io_bytes

    def hit_rate(self) -> float:
        """Demand-path hit rate; 0.0 (not NaN/ZeroDivisionError) when no
        fetch has happened yet."""
        c = self.counters
        total = c.hits + c.misses
        return float(c.hits) / total if total > 0 else 0.0

    def clear(self):
        with self._cond:
            self.counters.prefetch_wasted += len(self._prefetched)
            self._prefetched.clear()
            self._inflight.clear()           # in-flight reads land nowhere
            self._blocks.clear()
            self._epoch += 1                 # in-flight buffers are stale
            self._cond.notify_all()

    def invalidate(self, start: int, nbytes: int):
        """Drop any cached I/O unit overlapping [start, start+nbytes) —
        required after in-place chunk writes (dynamic index mutation).
        Handles ranges that straddle block boundaries: every block touched
        by ANY byte of the range is dropped, including the partial first
        and last blocks. nbytes <= 0 is a no-op. Pending prefetches of the
        range are cancelled so a stale in-flight read can never land."""
        if nbytes <= 0:
            return
        io = self.io_bytes
        first = start // io * io
        last = (start + nbytes - 1) // io * io
        with self._cond:
            for off in range(first, last + io, io):
                self._blocks.pop(off, None)
                self._inflight.discard(off)
                if off in self._prefetched:
                    self._prefetched.discard(off)
                    self.counters.prefetch_wasted += 1
            self._epoch += 1     # buffers read before this must not land
            self._cond.notify_all()

    # -- coalesced preadv ----------------------------------------------------
    def _iter_read_runs(self, offs: np.ndarray, gap: int):
        """Segment sorted unique block offsets into coalesced runs (runs
        separated by <= `gap` absent blocks are merged and the hole blocks
        read along) and yield, per ONE-preadv run:
        ({off: buf} for every block of the run, asked-offset set, bytes).
        The single copy of the run-segmentation algorithm — both the
        demand path (_read_runs) and the incremental background reader
        (_pf_read) drive it."""
        io = self.io_bytes
        span = (max(0, int(gap)) + 1) * io
        run_start = 0
        for i in range(1, offs.size + 1):
            if i < offs.size and offs[i] - offs[i - 1] <= span:
                continue
            lo, hi = int(offs[run_start]), int(offs[i - 1])
            nblk = (hi - lo) // io + 1
            bufs = [np.empty(io, np.uint8) for _ in range(nblk)]
            got = self._read_run(bufs, lo)
            yield ({lo + j * io: bufs[j] for j in range(nblk)},
                   set(offs[run_start:i].tolist()), int(got))
            run_start = i

    # -- fault-tolerant raw read (retry + verify) ---------------------------
    def _read_run(self, bufs: List[np.ndarray], lo: int) -> int:
        """One coalesced run read: retried preadv, then per-block checksum
        verification when the cache holds a CRC sidecar.  Every storage
        read — demand, fallback, and background — funnels through here."""
        got = self._preadv_retry(bufs, lo)
        if self.block_crc is not None:
            self._verify_run(bufs, lo)
        return got

    def _preadv_retry(self, bufs: List[np.ndarray], lo: int) -> int:
        """`self._preadv` with the RetryPolicy's capped exponential
        backoff.  A short read is treated as transient too: chunks.bin is
        always a whole multiple of io_bytes, so a run can never legally
        end mid-buffer."""
        pol = self.retry
        expect = len(bufs) * self.io_bytes
        delay = pol.backoff_s
        attempts = max(1, pol.attempts)
        for attempt in range(attempts):
            try:
                got = int(self._preadv(self.fd, bufs, lo))
                if got < expect:
                    raise OSError(
                        errno.EIO,
                        f"short read: {got}/{expect} bytes @ {lo}"
                        f"{' of ' + self._path if self._path else ''}")
                return got
            except OSError as e:
                if e.errno not in pol.retryable \
                        or attempt == attempts - 1:
                    raise
                self.counters.read_retries += 1
                time.sleep(delay)
                delay = min(delay * pol.backoff_mult, pol.backoff_max_s)
        raise AssertionError("unreachable")

    def _verify_run(self, bufs: List[np.ndarray], lo: int):
        """Check every block of a just-read run against the CRC sidecar.
        A mismatch triggers exactly ONE reread of that block (a transient
        in-flight corruption heals); a second mismatch means the bytes on
        storage are wrong -> CorruptBlockError (errno EIO)."""
        io = self.io_bytes
        crc = self.block_crc
        c = self.counters
        for j, buf in enumerate(bufs):
            off = lo + j * io
            bi = off // io
            if bi >= crc.shape[0]:
                continue        # block appended after the sidecar was cut
            want = int(crc[bi])
            if self._crc(buf) == want:
                continue
            c.crc_mismatches += 1
            c.crc_rereads += 1
            self._preadv_retry([buf], off)
            got = self._crc(buf)
            if got != want:
                raise CorruptBlockError(off, want, got, self._path)

    def refresh_crc(self, start: int, nbytes: int):
        """Recompute sidecar entries for every I/O unit overlapping
        [start, start+nbytes) after an in-place write (dynamic index
        mutation), growing the sidecar when an append opened new units.
        Reads raw bytes (no verification — the point is to re-anchor the
        checksums to what the write just put on storage)."""
        if self.block_crc is None or nbytes <= 0:
            return
        io = self.io_bytes
        first = start // io
        last = (start + nbytes - 1) // io
        with self._cond:
            if last >= self.block_crc.shape[0]:
                grown = np.zeros(last + 1, np.uint32)
                grown[:self.block_crc.shape[0]] = self.block_crc
                self.block_crc = grown
            buf = np.empty(io, np.uint8)
            for bi in range(first, last + 1):
                os.preadv(self.fd, [buf], bi * io)
                self.block_crc[bi] = self._crc(buf)

    def trim_crc(self, nblocks: int):
        """Shrink the CRC sidecar to `nblocks` entries after the backing
        file was truncated (crash-recovery rollback of an appended node):
        entries past the new end describe bytes that no longer exist and
        would poison `refresh_crc`'s growth arithmetic."""
        with self._cond:
            if self.block_crc is not None \
                    and nblocks < self.block_crc.shape[0]:
                self.block_crc = self.block_crc[:max(0, nblocks)].copy()

    def _read_runs(self, offs: np.ndarray, gap: int
                   ) -> Tuple[Dict[int, np.ndarray], Dict[int, np.ndarray],
                              int, int]:
        """preadv over sorted unique block offsets, one call per run.
        Returns (wanted off->buf, holes off->buf, syscalls, bytes)."""
        want: Dict[int, np.ndarray] = {}
        holes: Dict[int, np.ndarray] = {}
        n_sys = 0
        nbytes = 0
        for blocks, asked, got in self._iter_read_runs(offs, gap):
            n_sys += 1
            nbytes += got
            for o, buf in blocks.items():
                (want if o in asked else holes)[o] = buf
        return want, holes, n_sys, nbytes

    # -- readahead gap autotuning -------------------------------------------
    def _record_miss_runs(self, offs: np.ndarray):
        """Fold one fetch's sorted unique demand-miss offsets into the
        run-length / hole-size histograms (caller holds self._cond)."""
        if offs.size == 0:
            return
        steps = np.diff(offs) // self.io_bytes
        run = 1
        for step in steps.tolist():
            if step == 1:
                run += 1
                continue
            self.miss_run_hist[run] = self.miss_run_hist.get(run, 0) + 1
            hole = int(step) - 1
            if hole <= _GAP_HIST_MAX:
                self.miss_gap_hist[hole] = \
                    self.miss_gap_hist.get(hole, 0) + 1
            run = 1
        self.miss_run_hist[run] = self.miss_run_hist.get(run, 0) + 1

    def auto_gap(self) -> int:
        """Coalescing gap chosen from the observed demand-miss structure:
        the MEDIAN hole between consecutive miss runs, clamped to
        [0, _AUTO_GAP_MAX].  Rationale: merging a hole of g blocks costs g
        extra block reads but saves one syscall, so holes at or below the
        typical (median) size — the ones a graph-locality layout produces
        in bulk — are worth reading through, while a median beyond the
        clamp means the misses are genuinely scattered and coalescing
        would mostly read garbage (returns 0).  Needs
        ``_AUTO_GAP_MIN_OBS`` observed holes before trusting the data."""
        with self._cond:
            obs = sorted(self.miss_gap_hist.items())
        total = sum(c for _, c in obs)
        if total < _AUTO_GAP_MIN_OBS:
            return 0
        cum = 0
        for g, cnt in obs:
            cum += cnt
            if 2 * cum >= total:
                return g if g <= _AUTO_GAP_MAX else 0
        return 0

    # -- the batched demand fetch -------------------------------------------
    def fetch(self, offsets: np.ndarray, gap: Union[int, str] = 0,
              ) -> Tuple[np.ndarray, np.ndarray, int]:
        """Fetch the I/O units starting at `offsets` (block-aligned, may
        repeat). Returns (data (B, io_bytes) uint8, hit mask over the
        *unique* offsets in first-appearance order, syscalls issued).

        A unique offset counts as a hit when it was served without demand
        I/O — resident, or landed by an in-flight background prefetch this
        fetch waited on. `gap` > 0 enables readahead coalescing of the
        miss runs (see class docstring); `gap="auto"` picks the gap from
        the demand-miss histograms (`auto_gap`) and reports the choice in
        ``counters.auto_gap``."""
        offsets = np.asarray(offsets, dtype=np.int64)
        c = self.counters
        c.fetch_calls += 1
        # read span: one per fetch when a query trace is active on this
        # thread (untraced traffic pays one thread-local read)
        _sp = obs_trace.begin("cache.fetch")
        if _sp is not None:
            try:
                return self._fetch_traced(_sp, offsets, gap)
            finally:
                _sp.end()
        return self._fetch_inner(offsets, gap)

    def _fetch_traced(self, sp, offsets, gap):
        out, hit_mask, n_sys = self._fetch_inner(offsets, gap)
        sp.annotate(blocks=int(offsets.size),
                    misses=int((~hit_mask).sum()), syscalls=int(n_sys),
                    bytes=int(offsets.size) * self.io_bytes)
        return out, hit_mask, n_sys

    def _fetch_inner(self, offsets: np.ndarray, gap: Union[int, str]
                     ) -> Tuple[np.ndarray, np.ndarray, int]:
        c = self.counters
        uniq, first = np.unique(offsets, return_index=True)
        # first-appearance order (np.unique sorts; undo for caller attribution)
        order = np.argsort(first, kind="stable")
        uniq = uniq[order]
        local: Dict[int, np.ndarray] = {}
        pending: List[int] = []
        miss: List[int] = []
        with self._cond:
            for o in uniq.tolist():
                buf = self._blocks.get(o)
                if buf is not None:
                    self._blocks.move_to_end(o)
                    local[o] = buf
                    if o in self._prefetched:
                        self._prefetched.discard(o)
                        c.prefetch_hits += 1
                elif o in self._inflight:
                    pending.append(o)        # background read is coming
                else:
                    miss.append(o)
            # histogram over EVERY demanded non-resident block (pending
            # included): under the pipelined path most frontier blocks are
            # in flight at demand time, and recording only the leftovers
            # would teach gap="auto" from a biased scatter sample
            self._record_miss_runs(
                np.asarray(sorted(miss + pending), dtype=np.int64))
            epoch0 = self._epoch
        if gap == "auto":
            gap = self.auto_gap()
            c.auto_gap = gap
        want, holes, n_sys, nbytes = self._read_runs(
            np.asarray(sorted(miss), dtype=np.int64), gap)
        local.update(want)
        c.syscalls += n_sys
        c.bytes_read += nbytes
        # wait for in-flight prefetches instead of duplicating their I/O
        if pending:
            deadline = time.monotonic() + _PENDING_WAIT_S
            with self._cond:
                while True:
                    still = [o for o in pending if o not in local]
                    for o in still:
                        buf = self._blocks.get(o)
                        if buf is not None:
                            self._blocks.move_to_end(o)
                            local[o] = buf
                            if o in self._prefetched:
                                self._prefetched.discard(o)
                                c.prefetch_hits += 1
                    still = [o for o in pending if o not in local
                             and o in self._inflight]
                    if not still:
                        break
                    left = deadline - time.monotonic()
                    if left <= 0 or not self._cond.wait(timeout=left):
                        break
            # cancelled (invalidate/clear/stop) or timed out: read directly
            fallback = np.asarray(sorted(o for o in pending
                                         if o not in local), dtype=np.int64)
            if fallback.size:
                fb, fb_holes, fb_sys, fb_bytes = self._read_runs(fallback, 0)
                local.update(fb)
                miss.extend(fallback.tolist())
                n_sys += fb_sys
                c.syscalls += fb_sys
                c.bytes_read += fb_bytes
        missed = set(miss)
        hit_mask = np.asarray([o not in missed for o in uniq.tolist()],
                              dtype=bool)
        c.hits += int(hit_mask.sum())
        c.misses += len(missed)
        # assemble BEFORE inserting: inserting misses may evict blocks this
        # very fetch still needs when the budget is smaller than the batch
        out = np.empty((offsets.size, self.io_bytes), np.uint8)
        for i, off in enumerate(offsets.tolist()):
            out[i] = local[off]
        with self._cond:
            # epoch gate: if invalidate()/clear() ran while our buffers
            # were in flight, they may hold pre-write bytes — return them
            # to the caller (it demanded the pre-write view) but never
            # RETAIN them past the invalidation
            fresh = self._epoch == epoch0
            for off in miss:
                self._inflight.discard(off)  # demand read beat the prefetch
                if fresh:
                    self._insert(off, local[off])
            # readahead holes: speculative insert (skipped entirely under
            # zero retention — an unretainable block is not speculation)
            if self.max_entries and fresh:
                for off, buf in holes.items():
                    # the demand read covered it: cancel any queued
                    # background read so storage sees each block once
                    self._inflight.discard(off)
                    if off not in self._blocks:
                        c.prefetch_issued += 1
                        self._prefetched.add(off)
                        self._insert(off, buf)
        return out, hit_mask, n_sys

    # -- async prefetch ------------------------------------------------------
    def prefetch_async(self, offsets: np.ndarray,
                       gap: Union[int, str] = 0) -> int:
        """Queue speculative background reads of block-aligned `offsets`.

        Already-resident and already-queued blocks are skipped; returns the
        number of blocks actually queued. No-op when retention is disabled
        (a zero-budget cache could never serve the prefetched block) and
        when a backlog of unprocessed batches exists (stale speculation is
        worse than none: it evicts useful residents).

        `gap` gives the background reader the same run-coalescing the
        demand path enjoys ("auto" resolves through `auto_gap`): fewer,
        larger preadv calls shrink the worker's time-to-land — which is
        exactly what a demand fetch waiting on an in-flight block pays."""
        if self.max_entries == 0:
            return 0
        if gap == "auto":
            gap = self.auto_gap()
            self.counters.auto_gap = gap
        gap = max(0, int(gap))
        if self._pf_queue is not None and self._pf_queue.qsize() > 2:
            return 0
        offsets = np.unique(np.asarray(offsets, dtype=np.int64))
        with self._cond:
            todo = [int(o) for o in offsets.tolist()
                    if o not in self._blocks and o not in self._inflight]
            self._inflight.update(todo)
        if not todo:
            return 0
        self._ensure_worker()
        # the gap travels WITH the batch: queued batches keep the knob
        # their caller set (no shared mutable state to race on)
        self._pf_queue.put((np.asarray(todo, dtype=np.int64), gap))
        return len(todo)

    def wait_prefetch(self):
        """Block until every queued prefetch batch has landed (used by
        tests and phase boundaries in benchmarks)."""
        if self._pf_queue is not None:
            self._pf_queue.join()

    def stop(self):
        """Join the background thread (idempotent; called by close())."""
        if self._pf_thread is not None and self._pf_thread.is_alive():
            self._pf_queue.put(None)
            self._pf_thread.join(timeout=10.0)
        self._pf_thread = None
        self._pf_queue = None
        with self._cond:
            self._inflight.clear()           # nothing can land any more
            self._cond.notify_all()

    def _ensure_worker(self):
        if self._pf_thread is None or not self._pf_thread.is_alive():
            self._pf_queue = Queue()
            self._pf_thread = threading.Thread(
                target=self._pf_loop, name="blockcache-prefetch", daemon=True)
            self._pf_thread.start()

    def _pf_loop(self):
        q = self._pf_queue
        while True:
            item = q.get()
            if item is None:
                q.task_done()
                return
            batch, gap = item
            try:
                self._pf_read(batch, gap)
            except Exception:       # noqa: BLE001 — a failing background
                # read must DEGRADE the pipeline, never deadlock it:
                # un-claim the batch so demand fetches stop waiting on
                # blocks that will never land and read them directly
                with self._cond:
                    self.counters.prefetch_errors += 1
                    for o in batch.tolist():
                        self._inflight.discard(int(o))
                    self._cond.notify_all()
            finally:
                q.task_done()

    def _pf_read(self, batch: np.ndarray, gap: int = 0):
        """Read one queued batch and land it INCREMENTALLY, run by run: a
        demand fetch waiting on an in-flight block wakes as soon as that
        block's run is read, not after the whole batch — the wait a
        pipelined traversal pays is one coalesced preadv, not ~a hop's
        worth of them."""
        with self._cond:                     # drop cancelled offsets cheaply
            offs = np.asarray(sorted(int(o) for o in batch.tolist()
                                     if o in self._inflight), dtype=np.int64)
            epoch0 = self._epoch
        if not offs.size:
            return
        for blocks, asked, got in self._iter_read_runs(offs, gap):
            with self._cond:
                c = self.counters
                c.prefetch_syscalls += 1
                c.prefetch_bytes += got
                # asked blocks carry an _inflight claim that invalidate()
                # cancels; HOLE blocks have no claim token, so they are
                # gated on the invalidation epoch instead — a hole buffer
                # read before an in-place write must never land after it
                fresh = self._epoch == epoch0
                epoch0 = self._epoch   # next run's preadv starts after this
                for o, buf in blocks.items():
                    if o in asked:
                        if o not in self._inflight:
                            continue         # invalidated/cleared mid-flight
                        self._inflight.discard(o)
                        if o in self._blocks:
                            continue         # a demand read got there first
                    elif not fresh or o in self._blocks \
                            or o in self._inflight:
                        continue             # stale/resident/claimed hole
                    c.prefetch_issued += 1
                    self._prefetched.add(o)
                    self._insert(o, buf)
                self._cond.notify_all()      # wake demand fetches waiting

    # -- LRU internals (caller holds self._cond) -----------------------------
    def _insert(self, off: int, buf: np.ndarray):
        if self.max_entries == 0:
            return
        if off in self._blocks:
            self._blocks.move_to_end(off)
            return
        while len(self._blocks) >= self.max_entries:
            old, _ = self._blocks.popitem(last=False)
            self.counters.evictions += 1
            if old in self._prefetched:
                self._prefetched.discard(old)
                self.counters.prefetch_wasted += 1
        self._blocks[off] = buf
