"""Byte-budgeted LRU block cache + batched block I/O for the host backend.

The paper's host tier reads one ``io_bytes`` unit (>= one 4 KiB LBA block)
per node expansion. The seed implementation paid one ``os.pread`` syscall
per *node*; this cache turns the per-hop frontier into ONE batched fetch:

  * cache hits are served from an LRU dict of resident blocks whose total
    size is capped by an explicit byte budget — the DRAM knob the disk-ANNS
    literature tunes (DiskANN++ hot-vertex caching; the paper's ~10 MB
    host budget made explicit),
  * cache misses are sorted, deduplicated, coalesced into contiguous runs,
    and each run is read with a single ``os.preadv`` — one syscall fills
    every block buffer of the run (``preadv`` scatters a contiguous file
    range across buffers; discontiguous runs need one call each, which the
    syscall counter reports honestly).

Counters (`hits`, `misses`, `evictions`, `syscalls`, `bytes_read`) feed
``SearchStats`` and the bench_search report.
"""
from __future__ import annotations

import os
from collections import OrderedDict
from dataclasses import dataclass
from typing import Tuple

import numpy as np


@dataclass
class CacheCounters:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    syscalls: int = 0
    bytes_read: int = 0
    fetch_calls: int = 0     # batched fetch() invocations (one per hop)

    def snapshot(self) -> Tuple[int, int, int, int, int, int]:
        return (self.hits, self.misses, self.evictions, self.syscalls,
                self.bytes_read, self.fetch_calls)


class BlockCache:
    """LRU over fixed-size I/O units of one open file descriptor.

    capacity_bytes == 0 disables retention but keeps the batched coalesced
    read path (every fetch is a miss); the syscall batching win remains.
    """

    def __init__(self, fd: int, io_bytes: int,
                 capacity_bytes: int = 10 << 20):
        self.fd = fd
        self.io_bytes = int(io_bytes)
        self.capacity_bytes = max(0, int(capacity_bytes))
        self.max_entries = self.capacity_bytes // self.io_bytes
        self._blocks: "OrderedDict[int, np.ndarray]" = OrderedDict()
        self.counters = CacheCounters()

    # -- accounting ----------------------------------------------------------
    @property
    def used_bytes(self) -> int:
        return len(self._blocks) * self.io_bytes

    def hit_rate(self) -> float:
        c = self.counters
        total = c.hits + c.misses
        return c.hits / total if total else 0.0

    def clear(self):
        self._blocks.clear()

    def invalidate(self, start: int, nbytes: int):
        """Drop any cached I/O unit overlapping [start, start+nbytes) —
        required after in-place chunk writes (dynamic index mutation)."""
        io = self.io_bytes
        first = start // io * io
        for off in range(first, start + max(1, nbytes), io):
            self._blocks.pop(off, None)

    # -- the batched fetch ---------------------------------------------------
    def fetch(self, offsets: np.ndarray,
              ) -> Tuple[np.ndarray, np.ndarray, int]:
        """Fetch the I/O units starting at `offsets` (block-aligned, may
        repeat). Returns (data (B, io_bytes) uint8, hit mask over the
        *unique* offsets in first-appearance order, syscalls issued)."""
        offsets = np.asarray(offsets, dtype=np.int64)
        self.counters.fetch_calls += 1
        uniq, first = np.unique(offsets, return_index=True)
        # first-appearance order (np.unique sorts; undo for caller attribution)
        order = np.argsort(first, kind="stable")
        uniq = uniq[order]
        c = self.counters
        hit_mask = np.array([int(o) in self._blocks for o in uniq],
                            dtype=bool)
        miss_offs = np.sort(uniq[~hit_mask])
        n_sys = 0
        stash = {}
        if miss_offs.size:
            io = self.io_bytes
            run_start = 0
            for i in range(1, miss_offs.size + 1):
                if i == miss_offs.size or \
                        miss_offs[i] != miss_offs[i - 1] + io:
                    run = miss_offs[run_start:i]
                    run_bufs = [np.empty(io, np.uint8) for _ in run]
                    got = os.preadv(self.fd, run_bufs, int(run[0]))
                    n_sys += 1
                    c.bytes_read += int(got)
                    stash.update(zip(run.tolist(), run_bufs))
                    run_start = i
        c.syscalls += n_sys
        c.hits += int(hit_mask.sum())
        c.misses += int(miss_offs.size)
        # assemble BEFORE inserting: inserting misses may evict blocks this
        # very fetch still needs when the budget is smaller than the batch
        out = np.empty((offsets.size, self.io_bytes), np.uint8)
        for i, off in enumerate(offsets.tolist()):
            out[i] = stash[off] if off in stash else self._get(off)
        for off, buf in stash.items():
            self._insert(off, buf)
        return out, hit_mask, n_sys

    # -- LRU internals -------------------------------------------------------
    def _get(self, off: int) -> np.ndarray:
        blk = self._blocks[off]
        self._blocks.move_to_end(off)
        return blk

    def _insert(self, off: int, buf: np.ndarray):
        if self.max_entries == 0:
            return
        if off in self._blocks:
            self._blocks.move_to_end(off)
            return
        while len(self._blocks) >= self.max_entries:
            self._blocks.popitem(last=False)
            self.counters.evictions += 1
        self._blocks[off] = buf
