"""Node-chunk layout math + packing (paper §2.3/§3.1, Figs 1-2).

A node chunk holds everything beam search needs when it expands node v:

  DiskANN : [ full_vec | n_nbrs | nbr_ids[R] ]
  AiSAQ   : [ full_vec | n_nbrs | nbr_ids[R] | nbr_pq_codes[R] ]

  B_DiskANN = b_full + b_num * (R + 1)
  B_AiSAQ   = B_DiskANN + R * b_pq

Two physical disciplines (DESIGN.md §2):
  * file layout — 4 KiB LBA blocks; a chunk never straddles a block boundary
    unless chunk > block, in which case it starts block-aligned and uses
    ceil(chunk/B) blocks (paper Fig. 1a/1b).
  * device layout — one (N, stride) uint8 HBM array with stride padded to a
    multiple of 128 bytes (dense lane-aligned DMA per chunk row) and every
    field 4-byte aligned so bitcasts are free.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import numpy as np

B_NUM = 4  # bytes per node id / degree field (paper: "usually 4 bytes")


def _align(x: int, a: int) -> int:
    return (x + a - 1) // a * a


@dataclass(frozen=True)
class ChunkLayout:
    mode: str                 # "aisaq" | "diskann"
    dim: int
    data_dtype: str           # "float32" | "uint8"
    R: int
    pq_m: int                 # b_pq bytes per code
    block_bytes: int = 4096

    # ---- sizes (paper formulas) -----------------------------------------
    @property
    def b_full(self) -> int:
        return self.dim * (1 if self.data_dtype == "uint8" else 4)

    @property
    def chunk_bytes(self) -> int:
        base = self.b_full + B_NUM * (self.R + 1)
        if self.mode == "aisaq":
            base += self.R * self.pq_m
        return base

    # ---- field offsets (raw, unpadded) ----------------------------------
    @property
    def off_vec(self) -> int:
        return 0

    @property
    def off_deg(self) -> int:
        return self.b_full

    @property
    def off_ids(self) -> int:
        return self.b_full + B_NUM

    @property
    def off_pq(self) -> int:
        assert self.mode == "aisaq"
        return self.off_ids + self.R * B_NUM

    # ---- file (LBA) placement -------------------------------------------
    @property
    def nodes_per_block(self) -> int:
        """>0 when chunk <= block (Fig 1a); 0 when multi-block (Fig 1b)."""
        return self.block_bytes // self.chunk_bytes if self.chunk_bytes <= self.block_bytes else 0

    @property
    def blocks_per_chunk(self) -> int:
        return 1 if self.nodes_per_block else -(-self.chunk_bytes // self.block_bytes)

    @property
    def io_bytes(self) -> int:
        """Bytes read from storage per node expansion (paper §2.3)."""
        return self.blocks_per_chunk * self.block_bytes

    def file_offset(self, node: int) -> int:
        if self.nodes_per_block:
            blk, slot = divmod(node, self.nodes_per_block)
            return blk * self.block_bytes + slot * self.chunk_bytes
        return node * self.blocks_per_chunk * self.block_bytes

    def file_size(self, n: int) -> int:
        if self.nodes_per_block:
            return -(-n // self.nodes_per_block) * self.block_bytes
        return n * self.blocks_per_chunk * self.block_bytes

    # ---- device (HBM) placement -----------------------------------------
    @property
    def device_stride(self) -> int:
        """Chunk stride in the (N, stride) uint8 HBM array: 128-B aligned."""
        # keep ids 4-B aligned: b_full is already 4-aligned for f32; for uint8
        # vectors pad the vector field up to 4.
        return _align(self.padded_vec_bytes + B_NUM * (1 + self.R)
                      + (self.R * self.pq_m if self.mode == "aisaq" else 0), 128)

    @property
    def padded_vec_bytes(self) -> int:
        return _align(self.b_full, 4)

    @property
    def dev_off_deg(self) -> int:
        return self.padded_vec_bytes

    @property
    def dev_off_ids(self) -> int:
        return self.padded_vec_bytes + B_NUM

    @property
    def dev_off_pq(self) -> int:
        return self.dev_off_ids + self.R * B_NUM

    # ---- summary ----------------------------------------------------------
    def describe(self) -> dict:
        return dict(mode=self.mode, chunk_bytes=self.chunk_bytes,
                    block_bytes=self.block_bytes,
                    nodes_per_block=self.nodes_per_block,
                    blocks_per_chunk=self.blocks_per_chunk,
                    io_bytes=self.io_bytes, device_stride=self.device_stride)


def layout_for(index_cfg, mode: str | None = None) -> ChunkLayout:
    """Build a ChunkLayout from an :class:`repro.configs.base.IndexConfig`."""
    return ChunkLayout(
        mode=mode or index_cfg.mode, dim=index_cfg.dim,
        data_dtype=index_cfg.data_dtype, R=index_cfg.R, pq_m=index_cfg.pq_m,
        block_bytes=index_cfg.block_bytes)


# ---------------------------------------------------------------------------
# packing (numpy; build-time only)
# ---------------------------------------------------------------------------


def _vec_bytes(vectors: np.ndarray, layout: ChunkLayout) -> np.ndarray:
    if layout.data_dtype == "uint8":
        return vectors.astype(np.uint8)
    return vectors.astype(np.float32).view(np.uint8).reshape(vectors.shape[0], -1)


def pack_chunks_file(vectors: np.ndarray, adjacency: np.ndarray,
                     codes: np.ndarray, layout: ChunkLayout) -> bytes:
    """Produce the block-aligned chunks.bin payload (file layout).

    adjacency: (N, R) int32, -1 padded. codes: (N, m) uint8 (ignored for
    diskann mode). Neighbor slots for -1 edges store id=-1 and zero codes.
    """
    n = vectors.shape[0]
    buf = np.zeros(layout.file_size(n), dtype=np.uint8)
    vb = _vec_bytes(vectors, layout)
    adj = adjacency.astype(np.int32)
    deg = (adj >= 0).sum(axis=1).astype(np.int32)
    nbr_codes = None
    if layout.mode == "aisaq":
        safe = np.where(adj >= 0, adj, 0)
        nbr_codes = codes[safe]                      # (N, R, m)
        nbr_codes = np.where((adj >= 0)[:, :, None], nbr_codes, 0).astype(np.uint8)
    for i in range(n):
        off = layout.file_offset(i)
        c = buf[off:off + layout.chunk_bytes]
        c[layout.off_vec:layout.off_vec + layout.b_full] = vb[i]
        c[layout.off_deg:layout.off_deg + B_NUM] = deg[i:i + 1].view(np.uint8)
        c[layout.off_ids:layout.off_ids + layout.R * B_NUM] = adj[i].view(np.uint8)
        if layout.mode == "aisaq":
            c[layout.off_pq:layout.off_pq + layout.R * layout.pq_m] = \
                nbr_codes[i].reshape(-1)
    return buf.tobytes()


def pack_chunks_device(vectors: np.ndarray, adjacency: np.ndarray,
                       codes: np.ndarray, layout: ChunkLayout) -> np.ndarray:
    """(N, device_stride) uint8 array — the HBM-resident 'storage' tier."""
    n = vectors.shape[0]
    out = np.zeros((n, layout.device_stride), dtype=np.uint8)
    vb = _vec_bytes(vectors, layout)
    out[:, :vb.shape[1]] = vb
    adj = adjacency.astype(np.int32)
    deg = (adj >= 0).sum(axis=1).astype(np.int32)
    out[:, layout.dev_off_deg:layout.dev_off_deg + B_NUM] = \
        deg[:, None].view(np.uint8)
    out[:, layout.dev_off_ids:layout.dev_off_ids + layout.R * B_NUM] = \
        adj.view(np.uint8).reshape(n, -1)
    if layout.mode == "aisaq":
        safe = np.where(adj >= 0, adj, 0)
        nc = np.where((adj >= 0)[:, :, None], codes[safe], 0).astype(np.uint8)
        out[:, layout.dev_off_pq:layout.dev_off_pq + layout.R * layout.pq_m] = \
            nc.reshape(n, -1)
    return out


# ---------------------------------------------------------------------------
# unpacking (numpy host path; the jnp path lives in kernels/ref.py)
# ---------------------------------------------------------------------------


def chunk_matrix(raw: np.ndarray, layout: ChunkLayout, n: int) -> np.ndarray:
    """Whole-file uint8 buffer -> (n, chunk_bytes) matrix of node chunks.

    The strided twin of calling ``parse_chunk`` n times: one reshape peels
    the block padding off, so downstream field slices are plain 2-D views.
    """
    if layout.nodes_per_block:
        npb = layout.nodes_per_block
        nblk = -(-n // npb)
        blocks = raw[:nblk * layout.block_bytes] \
            .reshape(nblk, layout.block_bytes)
        return blocks[:, :npb * layout.chunk_bytes] \
            .reshape(nblk * npb, layout.chunk_bytes)[:n]
    per = layout.blocks_per_chunk * layout.block_bytes
    return raw[:n * per].reshape(n, per)[:, :layout.chunk_bytes]


def parse_chunk(raw: np.ndarray, layout: ChunkLayout):
    """raw: (chunk_bytes,) uint8 -> (vec f32/u8, nbr_ids (R,) i32, nbr_codes)."""
    if layout.data_dtype == "uint8":
        vec = raw[:layout.b_full].copy()
    else:
        vec = raw[:layout.b_full].view(np.float32).copy()
    ids = raw[layout.off_ids:layout.off_ids + layout.R * B_NUM].view(np.int32).copy()
    pq = None
    if layout.mode == "aisaq":
        pq = raw[layout.off_pq:layout.off_pq + layout.R * layout.pq_m] \
            .reshape(layout.R, layout.pq_m).copy()
    return vec, ids, pq
