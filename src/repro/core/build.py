"""End-to-end index construction: PQ training + Vamana + serialization."""
from __future__ import annotations

import os
import time
from typing import Optional

import jax
import numpy as np

from repro.configs.base import IndexConfig
from repro.core import pq
from repro.core.index_io import write_index
from repro.core.vamana import build_vamana, medoid


def build_index(path: str, vectors: np.ndarray, cfg: IndexConfig, *,
                mode: Optional[str] = None, seed: int = 0,
                shared_centroids: Optional[np.ndarray] = None,
                graph: Optional[np.ndarray] = None, verbose: bool = False,
                relabel: bool = False, nav: bool = False,
                nav_fraction: Optional[float] = None,
                nav_degree: Optional[int] = None,
                nav_seed: int = 0,
                nav_method: Optional[str] = None) -> dict:
    """Build one index directory from raw vectors.

    `shared_centroids` lets multiple corpora in the same vector space share
    PQ centroids (paper §4.4). `relabel=True` applies the graph-locality
    page-packing permutation at pack time (core.relabel) — cold-path reads
    per hop drop because co-expanded neighbors share I/O blocks; search
    results still come back under the original vector labels. `nav=True`
    also builds the in-memory navigation tier (`core.nav` — per-query
    entry vertices via `entry="nav"`; the `nav_*` knobs default to
    `core.nav`'s DEFAULT_* constants). Returns the meta dict (plus timing
    fields).
    """
    from repro.core import nav as _nav
    nav_kw = dict(nav=nav,
                  nav_fraction=_nav.DEFAULT_FRACTION
                  if nav_fraction is None else nav_fraction,
                  nav_degree=_nav.DEFAULT_DEGREE
                  if nav_degree is None else nav_degree,
                  nav_seed=nav_seed,
                  nav_method=_nav.DEFAULT_METHOD
                  if nav_method is None else nav_method)
    mode = mode or cfg.mode
    t0 = time.perf_counter()
    vec_f = vectors.astype(np.float32)
    n = vectors.shape[0]
    rng = jax.random.PRNGKey(seed)
    if shared_centroids is not None:
        centroids = shared_centroids
    else:
        sample = vec_f if n <= 100_000 else vec_f[
            np.random.default_rng(seed).choice(n, 100_000, replace=False)]
        cb = pq.train_codebooks(rng, sample, m=cfg.pq_m, ks=cfg.pq_ks)
        centroids = np.asarray(cb.centroids)
    codes = np.asarray(pq.encode(pq.PQCodebooks(centroids), vec_f))
    t_pq = time.perf_counter() - t0
    if graph is None:
        graph = build_vamana(vec_f, R=cfg.R, L=cfg.build_L, alpha=cfg.alpha,
                             metric=cfg.metric, seed=seed,
                             log_every=2000 if verbose else 0)
    t_graph = time.perf_counter() - t0 - t_pq
    ep = np.array([medoid(vec_f, cfg.metric)])
    meta = write_index(path, vectors=vectors, graph=graph,
                       centroids=centroids, codes=codes, metric=cfg.metric,
                       mode=mode, block_bytes=cfg.block_bytes, n_ep=cfg.n_ep,
                       entry_points=ep, relabel=relabel, **nav_kw,
                       extra_meta=dict(build_pq_s=t_pq, build_graph_s=t_graph))
    if verbose:
        print(f"built {path}: n={n} pq={t_pq:.1f}s graph={t_graph:.1f}s")
    return meta
