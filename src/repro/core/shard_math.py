"""Shard assignment + partial top-k merge, shared by BOTH serving tiers.

One piece of math decides which shard owns which vector and how partial
per-shard answers merge into a global top-k.  Before this module the
device-mesh tier (``core.sharded_search``) and the storage tier each
carried their own copy; now the device tier re-exports these names and
the process-level router (``serving.router``) imports them directly —
one router's merge is bit-identical to the device mesh's all-gather +
``lax.top_k`` merge and to the single-process reference the cluster
drill compares against.

Deliberately jax-free: cluster workers spawn with ``import
repro.serving`` only, and pulling jax into that chain would turn a
~0.3 s worker start into tens of seconds.
"""
from __future__ import annotations

from typing import List, NamedTuple, Sequence, Tuple

import numpy as np

__all__ = ["ShardAssignment", "contiguous_shards", "merge_topk"]


class ShardAssignment(NamedTuple):
    """Contiguous partition of global label space [0, n) into shards.

    ``offsets[s]`` is the first global label owned by shard ``s`` and
    ``counts[s]`` how many it owns — the same (offset, count) pairs
    ``sharded_search.stack_shards`` feeds the device mesh, so a corpus
    split once serves both tiers.
    """

    n: int                    # total vectors across all shards
    offsets: np.ndarray       # (S,) int64, first global label per shard
    counts: np.ndarray        # (S,) int64, vectors per shard

    @property
    def n_shards(self) -> int:
        return len(self.offsets)

    def shard_of(self, label: int) -> int:
        """Which shard owns a global label."""
        if not 0 <= label < self.n:
            raise ValueError(f"label {label} outside [0, {self.n})")
        return int(np.searchsorted(self.offsets, label, side="right") - 1)

    def bounds(self, shard: int) -> Tuple[int, int]:
        """[lo, hi) global-label range owned by ``shard``."""
        lo = int(self.offsets[shard])
        return lo, lo + int(self.counts[shard])


def contiguous_shards(n: int, n_shards: int) -> ShardAssignment:
    """Split [0, n) into ``n_shards`` near-equal contiguous ranges.

    The first ``n % n_shards`` shards get one extra vector, matching
    ``np.array_split`` — and therefore matching every existing caller
    that split a corpus that way before handing it to ``stack_shards``.
    """
    if n_shards < 1:
        raise ValueError("n_shards must be >= 1")
    if n < n_shards:
        raise ValueError(f"cannot split {n} vectors into {n_shards} shards")
    base, extra = divmod(n, n_shards)
    counts = np.full(n_shards, base, dtype=np.int64)
    counts[:extra] += 1
    offsets = np.zeros(n_shards, dtype=np.int64)
    np.cumsum(counts[:-1], out=offsets[1:])
    return ShardAssignment(n=n, offsets=offsets, counts=counts)


def merge_topk(ids_parts: Sequence[np.ndarray],
               dists_parts: Sequence[np.ndarray],
               k: int) -> Tuple[np.ndarray, np.ndarray]:
    """Merge per-shard partial top-k lists into one global top-k.

    The host twin of the device mesh's all-gather + ``lax.top_k`` merge:
    concatenate every shard's (ids, dists), sort by (dist, id) — the id
    tie-break makes the merge DETERMINISTIC regardless of shard arrival
    order, which is what lets the cluster drill demand bit-identical
    answers against a single-process reference — and keep the best k.
    Entries with id < 0 (per-shard padding when a shard holds fewer
    than k vectors) are dropped.  Short inputs yield a short output
    padded back to k with id -1 / dist +inf so the result shape is
    always (k,).
    """
    ids = np.concatenate([np.asarray(p, dtype=np.int64).ravel()
                          for p in ids_parts]) if ids_parts else \
        np.empty(0, np.int64)
    dists = np.concatenate([np.asarray(p, dtype=np.float32).ravel()
                            for p in dists_parts]) if dists_parts else \
        np.empty(0, np.float32)
    if ids.shape != dists.shape:
        raise ValueError(f"ids/dists shape mismatch: "
                         f"{ids.shape} vs {dists.shape}")
    live = ids >= 0
    ids, dists = ids[live], dists[live]
    order = np.lexsort((ids, dists))[:k]
    out_ids = np.full(k, -1, dtype=np.int64)
    out_dists = np.full(k, np.inf, dtype=np.float32)
    out_ids[:order.size] = ids[order]
    out_dists[:order.size] = dists[order]
    return out_ids, out_dists
