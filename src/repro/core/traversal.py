"""The beam-search traversal engine: frontier selection, candidate
bookkeeping, the exact-rerank tail, and ``SearchStats``.

This is the middle layer of the three-layer host search core:

  ``core.adc``        pure LUT/ADC numerics (numpy twins of the kernels)
  ``core.traversal``  THIS module — the engine that walks the graph
  ``core.index_io``   on-disk format + ``HostIndex`` lifecycle (fd, cache,
                      residency accounting); delegates search to here

The engine functions are duck-typed over a ``HostIndex``-like object: they
use ``host.layout``, ``host.meta``, ``host.centroids``, ``host.ep_codes``,
``host.mode`` / ``host.pq_codes``, ``host.cache``, ``host._read_chunk``
and ``host._frontier_offsets``, and return ids in STORAGE space — the
caller (``HostIndex``) maps them back to original labels.

Pipelined traversal — the two-hop in-flight invariant
-----------------------------------------------------

The all-in-storage regime only avoids the paper's "critical latency
degradation" if traversal compute hides behind SSD I/O.  The serial loop
pays ``compute + I/O`` per hop: the demand fetch for hop *t* completes,
THEN ADC scoring runs, THEN the next-frontier prefetch is issued — the
background thread idles during scoring and the scoring thread idles
during reads.

With ``pipeline=True`` (the default whenever ``prefetch > 0``) the loop
keeps TWO hops in flight at every instant:

  1. the moment hop *t*'s frontier is SELECTED (before its blocks are
     even fetched), the engine issues an async prefetch for the PREDICTED
     hop *t+1* frontier — the next ``prefetch`` best unexpanded
     candidates of the current list.  These are exactly hop *t+1*'s
     frontier unless a neighbor discovered during hop *t* out-ranks them;
  2. hop *t*'s demand fetch then runs (waiting only on blocks not already
     resident or in flight), and hop *t*'s ADC scoring + candidate
     bookkeeping run on the main thread WHILE the background thread reads
     hop *t+1*'s predicted blocks;
  3. after insertion/re-sort, the EXACT next frontier is known; a
     catch-up prefetch covers any block the prediction missed (already
     in-flight or resident blocks are skipped — each block is read from
     storage at most once, so total I/O is conserved).

Blocking wait per hop therefore approaches ``max(compute, I/O) −
min(compute, I/O)`` instead of their sum.  Mis-predicted blocks are
ordinary speculative residents: they are accounted (``prefetch_wasted``),
never hidden, and — because the block cache is exact — they can never
change a result.  The pipelined path stays bit-identical to the scalar
Algorithm-1 oracle; the overlap is OBSERVABLE (``SearchStats.compute_s``
vs ``SearchStats.blocked_wait_s``), not asserted.

If the background thread stalls or dies, the demand fetch falls back to
direct reads after a bounded wait (``block_cache._PENDING_WAIT_S``): the
pipeline degrades to the serial path — same results, no deadlock.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple, Union

import numpy as np

from repro.obs import trace as obs_trace
from repro.core import nav as _nav
from repro.core.adc import (np_adc, np_adc_int8, np_build_lut,
                            np_build_lut_batch, np_host_lut_int8)
from repro.core.chunk_layout import B_NUM, parse_chunk

#: consecutive hops with failed background reads before search_batch
#: auto-disables its pipelined/prefetch path for the rest of the search —
#: a sick device should see the serial demand path (whose own RetryPolicy
#: still applies), not a speculative read storm.
DEGRADE_AFTER_FAILED_HOPS = 3


@dataclass
class SearchStats:
    hops: int = 0
    ios: int = 0            # logical chunk reads (cache hit or miss)
    bytes_read: int = 0     # bytes actually pulled from storage
    pq_dists: int = 0
    latency_s: float = 0.0
    syscalls: int = 0       # batched preadv calls issued for this query
    cache_hits: int = 0
    cache_misses: int = 0
    # speculative next-hop prefetch accounting (whole-batch deltas, folded
    # into the batch's lead query like syscall attribution)
    prefetch_issued: int = 0    # blocks landed by the background thread
    prefetch_hits: int = 0      # prefetched blocks a demand fetch consumed
    prefetch_wasted: int = 0    # prefetched blocks dropped unused
    rerank_ios: int = 0     # chunk reads issued by the exact rerank tier
    # pipeline overlap accounting (whole-batch totals on the lead query):
    # blocked_wait_s is time the traversal thread spent INSIDE demand
    # fetches (syscalls + waiting on in-flight background reads);
    # compute_s is everything else in the hop loop (parse/ADC/bookkeeping).
    # Overlap shows up as blocked_wait_s << the serial path's, at equal
    # total I/O — observable, never asserted by the engine itself.
    blocked_wait_s: float = 0.0
    compute_s: float = 0.0
    pipelined: int = 0      # 1 when the two-hop in-flight path was active
    # graceful degradation (whole-batch flag on the lead query): 1 when
    # DEGRADE_AFTER_FAILED_HOPS consecutive hops saw background-read
    # failures and the engine fell back to the serial demand path for
    # the remainder of this search
    degraded: int = 0
    # entry seeding: entry_dist is the best seed's ADC distance (how deep
    # the seeding dropped this query into the graph); with entry="nav",
    # nav_hops/nav_dists count the in-RAM pivot beam's hops and ADC
    # evaluations (zero storage I/O) and nav_s is the beam's wall time
    # (whole-batch total on the lead query, like syscall attribution)
    entry_dist: float = 0.0
    nav_hops: int = 0
    nav_dists: int = 0
    nav_s: float = 0.0
    # the hop (1-based) at which the LAST member of the returned top-k
    # entered the search: expansion hop for the traversal-pool tier,
    # candidate-insertion hop for the rerank/PQ tiers; 0 when the result
    # came entirely from the entry seeds.  hops - convergence_hop is the
    # verification tail (bounded by ~L/w): entry seeding shrinks the
    # TRAVEL phase, which this metric isolates.
    convergence_hop: int = 0


# ---------------------------------------------------------------------------
# scalar reference (paper Algorithm 1) — the semantics oracle
# ---------------------------------------------------------------------------


def search_ref(host, q: np.ndarray, k: int, L: int, w: int = 4, *,
               adc_dtype: str = "f32", rerank: Optional[int] = None,
               entry: str = "auto"
               ) -> Tuple[np.ndarray, SearchStats]:
    """Scalar DiskANN beam search, one pread per node expansion.  Kept as
    the semantics oracle for the vectorized hot path — `search_batch` must
    return bit-identical ids (per adc_dtype: the int8 oracle pins the int8
    hot path; per entry mode: the nav-seeded oracle pins the nav-seeded
    hot path).  ``entry`` selects the seeding (see `search_batch`); nav
    seeds come from the SAME `core.nav.nav_seed_batch` call the batched
    path makes (batch of one), so seed ids AND seed ADC distances are
    bit-identical by construction.  Returns STORAGE-space ids."""
    assert adc_dtype in ("f32", "int8"), adc_dtype
    t0 = time.perf_counter()
    q = np.asarray(q, dtype=np.float32)   # same arithmetic as `search`
    stats = SearchStats()
    lay = host.layout
    metric = host.meta["metric"]
    n = int(host.meta["n"])       # snapshot: ids >= n are clamped below
    lut = np_build_lut(host.centroids, q.astype(np.float32), metric)
    if adc_dtype == "int8":
        lut_q8, scale = np_host_lut_int8(lut)
        adc = lambda codes: np_adc_int8(lut_q8, scale, codes)  # noqa: E731
    else:
        adc = lambda codes: np_adc(lut, codes)                 # noqa: E731
    entry_mode = _nav.resolve_entry(host, entry)
    if entry_mode == "nav":
        t_nav = time.perf_counter()
        if adc_dtype == "int8":
            sid, sd, nh, nd = _nav.nav_seed_batch(
                host.nav, lut_q8[None],
                (scale * np.float32(1 / 127))[None], w)
        else:
            sid, sd, nh, nd = _nav.nav_seed_batch(host.nav, lut[None],
                                                  None, w)
        stats.nav_s = time.perf_counter() - t_nav
        stats.nav_hops = int(nh[0])
        stats.nav_dists = int(nd[0])
        svalid = sid[0] >= 0
        eps = sid[0][svalid]
        cand_ids = eps.copy()
        cand_d = sd[0][svalid]
    else:
        eps = np.asarray(host.meta["entry_points"], dtype=np.int64)
        # candidate list: ids, pq-dists, expanded?
        cand_ids = eps.copy()
        cand_d = adc(host.ep_codes)                      # entry codes: RAM
        stats.pq_dists += len(eps)
    stats.entry_dist = float(cand_d.min()) if cand_d.size else 0.0
    expanded: Dict[int, float] = {}                      # id -> exact dist
    exp_hop: Dict[int, int] = {}                         # id -> hop expanded
    ins_hop = {int(e): 0 for e in eps}                   # id -> hop inserted
    inserted = set(int(e) for e in eps)
    while True:
        order = np.argsort(cand_d, kind="stable")[:L]
        cand_ids, cand_d = cand_ids[order], cand_d[order]
        frontier = [int(i) for i in cand_ids if int(i) not in expanded][:w]
        if not frontier:
            break
        stats.hops += 1
        new_ids: List[np.ndarray] = []
        new_d: List[np.ndarray] = []
        for p in frontier:
            raw = host._read_chunk(p, stats)
            vec, ids, inline_codes = parse_chunk(raw, lay)
            # full-precision distance from the chunk (re-rank pool V)
            vf = vec.astype(np.float32)
            if metric == "mips":
                expanded[p] = float(-(vf @ q))
            else:
                expanded[p] = float(((vf - q) ** 2).sum())
            exp_hop[p] = stats.hops
            # clamp to the n snapshot exactly like the -1 padding: under a
            # concurrent insert a patched chunk may surface an edge to a
            # node past this search's view of the index — following it
            # would read past EOF / index the visited bitset out of range
            valid = (ids >= 0) & (ids < n)
            ids = ids[valid]
            fresh = np.array([i for i in ids if int(i) not in inserted],
                             dtype=np.int64)
            if fresh.size == 0:
                continue
            if host.mode == "aisaq":
                # THE AiSAQ step: neighbor codes come from the chunk we
                # just read — no N-sized RAM table is ever touched.
                codes = inline_codes[valid][
                    [int(np.flatnonzero(ids == f)[0]) for f in fresh]]
            else:
                codes = host.pq_codes[fresh]
            d = adc(codes)
            stats.pq_dists += int(fresh.size)
            inserted.update(int(f) for f in fresh)
            for f in fresh:
                ins_hop[int(f)] = stats.hops
            new_ids.append(fresh)
            new_d.append(d)
        if new_ids:
            cand_ids = np.concatenate([cand_ids] + new_ids)
            cand_d = np.concatenate([cand_d] + new_d)
    if rerank is None:
        # re-rank by full-precision distances collected along the path
        vids = np.array(list(expanded.keys()), dtype=np.int64)
        vd = np.array(list(expanded.values()), dtype=np.float32)
        topk = vids[np.argsort(vd, kind="stable")[:k]]
        if topk.size:
            stats.convergence_hop = max(exp_hop[int(t)] for t in topk)
    else:
        topk = _rerank_tail_ref(host, q, k, rerank, cand_ids, expanded,
                                stats)
        if topk.size:
            stats.convergence_hop = max(ins_hop[int(t)] for t in topk)
    stats.latency_s = time.perf_counter() - t0
    return topk, stats


def _rerank_tail_ref(host, q: np.ndarray, k: int, rerank: int,
                     cand_ids: np.ndarray, expanded: Dict[int, float],
                     stats: SearchStats) -> np.ndarray:
    """Scalar oracle of the exact rerank tier: rescore the final
    (PQ-sorted) candidate list with full-precision vectors. Expanded
    candidates reuse the exact distance computed during traversal;
    unexpanded ones cost one chunk read each (accounted as
    ``rerank_ios``). ``rerank == 0`` returns the PQ-only ranking."""
    limit = max(int(rerank), k) if rerank else k
    sel = cand_ids[:limit]
    if not rerank:                   # PQ-only tier: no rescoring
        return sel[:k].copy()
    metric = host.meta["metric"]
    d = np.empty(sel.size, np.float32)
    for j, p in enumerate(int(x) for x in sel):
        if p in expanded:
            d[j] = expanded[p]
            continue
        raw = host._read_chunk(p, stats)
        stats.rerank_ios += 1
        vec, _, _ = parse_chunk(raw, host.layout)
        vf = vec.astype(np.float32)
        d[j] = -(vf @ q) if metric == "mips" else ((vf - q) ** 2).sum()
    return sel[np.argsort(d, kind="stable")[:k]]


def search_batch_ref(host, Q: np.ndarray, k: int, L: int, w: int = 4, *,
                     adc_dtype: str = "f32", rerank: Optional[int] = None,
                     entry: str = "auto"):
    """Scalar reference loop (the seed implementation's search_batch).
    Returns STORAGE-space ids."""
    ids = np.zeros((Q.shape[0], k), dtype=np.int64)
    stats = []
    for i in range(Q.shape[0]):
        ids[i], s = search_ref(host, Q[i], k, L, w, adc_dtype=adc_dtype,
                               rerank=rerank, entry=entry)
        stats.append(s)
    return ids, stats


# ---------------------------------------------------------------------------
# vectorized batched engine (the hot path; optionally pipelined)
# ---------------------------------------------------------------------------


def search_batch(host, Q: np.ndarray, k: int, L: int, w: int = 4, *,
                 prefetch: int = 0, adc_dtype: str = "f32",
                 rerank: Optional[int] = None,
                 pipeline: Optional[bool] = None,
                 gap: Optional[Union[int, str]] = None,
                 entry: str = "auto"):
    """Batched vectorized beam search over all queries at once.

    All queries hop together (per-hop frontier interleaving): each hop
    gathers the union of every active query's frontier blocks in ONE
    cache fetch, parses all chunks as a single matrix, and ADCs all
    fresh neighbor codes of all queries as one (F, m) batch against the
    shared per-query LUT stack.  Returns (ids (nq, k) in STORAGE space,
    [SearchStats]).

    ``prefetch=p`` (p > 0) speculatively queues, per query and hop, the
    blocks of its p best unexpanded candidates for background reading.
    ``pipeline`` (None = auto: on iff prefetch > 0) additionally issues
    the PREDICTED next frontier the moment this hop's frontier is
    selected, so this hop's ADC scoring overlaps the next hop's reads —
    the two-hop in-flight discipline (module docstring).  Results are
    unaffected in every mode (the cache is exact); only when and where
    blocks are read changes.  ``gap`` controls miss-run readahead
    coalescing (None = prefetch depth, the historical default; an int
    fixes it; "auto" lets the cache pick from its observed demand-miss
    run-length histogram).

    ``adc_dtype="int8"`` runs neighbor ADC through the quantized host
    path (np_host_lut_int8 / np_adc_int8 — the numpy twin of the device
    int8 kernel); exact re-rank distances stay f32.

    ``entry`` selects how the on-disk search is seeded:
      * "medoid" — the fixed pack-time ``meta["entry_points"]`` (the
        historical behavior; always available),
      * "nav" — per-query entry vertices from the in-RAM navigation
        tier (``core.nav``): a vectorized beam over the pivot graph,
        pure ADC against RAM-resident pivot codes, zero storage I/O,
        replaces the fixed seed with the w best pivots for THIS query —
        fewer on-disk hops, not just faster ones.  Raises ValueError if
        the index carries no (loadable) tier,
      * "auto" (default) — "nav" iff the index carries the tier.
    The nav beam's ADC runs in the selected ``adc_dtype`` regime, and
    the scalar oracle consumes the identical `nav_seed_batch` output, so
    bit-identity to `search_ref` holds in every entry mode.

    ``rerank`` selects the result tier, bit-identical to `search_ref`:
      * None (default) — top-k by the exact distances of nodes expanded
        during traversal (the historical behavior),
      * 0 — PQ-only: top-k of the final candidate list ranked by ADC
        distance alone (no full-precision rescoring — the DiskANN
        no-rerank baseline),
      * r > 0 — the exact rerank tier: the top-max(r, k) candidates of
        the final PQ-sorted list are rescored with full-precision
        vectors. Expanded candidates reuse the distance their chunk
        already yielded; unexpanded ones are fetched through the block
        cache in one batched read (``rerank_ios`` in SearchStats).
        The candidate list holds at most L entries, so the effective
        depth is min(r, L) — pass L >= r for the full depth (the
        serving-tier factories do this automatically).
    """
    assert adc_dtype in ("f32", "int8"), adc_dtype
    t0 = time.perf_counter()
    Q = np.asarray(Q, dtype=np.float32)
    nq = Q.shape[0]
    lay = host.layout
    metric = host.meta["metric"]
    n = int(host.meta["n"])
    cache = host.cache
    if pipeline is None:
        pipeline = prefetch > 0
    pipeline = bool(pipeline) and prefetch > 0 and cache is not None
    gap_eff: Union[int, str] = prefetch if gap is None else gap
    blocked_s = 0.0
    compute_s = 0.0
    lut = np_build_lut_batch(host.centroids, Q, metric)   # (nq, m, ks)
    m = lut.shape[1]
    jj = np.arange(m)
    if adc_dtype == "int8":
        # same quantization as search_ref (np_host_lut_int8): the
        # batch arithmetic below must match np_adc_int8 bit-for-bit
        lut_q8, scale8 = np_host_lut_int8(lut)
        lut_g = lut_q8                                    # int8 gather
        dq = scale8 * np.float32(1 / 127)                 # (nq, m) f32
    else:
        lut_g, dq = lut, None
    pf0 = None
    if cache is not None:
        c = cache.counters
        pf0 = (c.prefetch_issued, c.prefetch_hits, c.prefetch_wasted)
    # tracing state resolved ONCE: the disabled hot path pays one
    # thread-local read here and a single `is None` branch per hop
    _tracing = obs_trace.current_span() is not None
    # graceful degradation state: consecutive hops whose background reads
    # failed (prefetch_errors delta observed at end of hop)
    pf_err_last = cache.counters.prefetch_errors if cache is not None else 0
    pf_fail_hops = 0
    degraded = False
    was_pipelined = pipeline            # report the mode the search BEGAN in
    entry_mode = _nav.resolve_entry(host, entry)
    nav_s = 0.0
    nav_hops_a = nav_dists_a = None
    seed_ids = seed_d = None
    if entry_mode == "nav":
        # the in-RAM nav beam (zero storage I/O): per-query entry
        # vertices + their ADC distances in the current adc_dtype regime.
        # The scalar oracle consumes this SAME function's output, so the
        # on-disk search below starts from bit-identical state.
        t_nav = time.perf_counter()
        seed_ids, seed_d, nav_hops_a, nav_dists_a = \
            _nav.nav_seed_batch(host.nav, lut_g, dq, w)
        nav_s = time.perf_counter() - t_nav
    # per-query counters (numpy-resident; folded into SearchStats at end)
    hops_a = np.zeros(nq, np.int64)
    ios_a = np.zeros(nq, np.int64)
    bytes_a = np.zeros(nq, np.int64)
    pq_a = np.zeros(nq, np.int64)
    sys_a = np.zeros(nq, np.int64)
    hit_a = np.zeros(nq, np.int64)
    miss_a = np.zeros(nq, np.int64)
    rr_a = np.zeros(nq, np.int64)
    # candidate lists (sorted by PQ distance, stable; inf-padded to L)
    bits = np.zeros((nq, -(-n // 64)), np.uint64)  # visited uint64 bitset
    if entry_mode == "nav":
        # per-QUERY seeds: each query gets its own entry vertices and
        # their already-computed beam distances (-1 / inf padding rows
        # start expanded so they are never selected)
        n_ep = seed_ids.shape[1]
        width = max(L, n_ep)
        cand_ids = np.full((nq, width), -1, np.int64)
        cand_d = np.full((nq, width), np.inf, np.float32)
        cand_exp = np.ones((nq, width), bool)
        svalid = seed_ids >= 0
        cand_ids[:, :n_ep] = seed_ids
        cand_d[:, :n_ep] = seed_d
        cand_exp[:, :n_ep] = ~svalid
        rows, vcols = np.nonzero(svalid)
        sid_v = seed_ids[rows, vcols]
        np.bitwise_or.at(bits, (rows, sid_v >> 6),
                         np.uint64(1) << (sid_v & 63).astype(np.uint64))
    else:
        # fixed pack-time seeds, SHARED by every query in the batch
        eps = np.asarray(host.meta["entry_points"], dtype=np.int64)
        n_ep = len(eps)
        width = max(L, n_ep)
        cand_ids = np.full((nq, width), -1, np.int64)
        cand_d = np.full((nq, width), np.inf, np.float32)
        cand_exp = np.ones((nq, width), bool)
        cand_ids[:, :n_ep] = eps
        ep_g = lut_g[:, jj, host.ep_codes.astype(np.int64)]  # (nq,n_ep,m)
        cand_d[:, :n_ep] = (ep_g.astype(np.float32)
                            * dq[:, None, :]).sum(-1) \
            if dq is not None else ep_g.sum(-1)
        cand_exp[:, :n_ep] = False
        pq_a += n_ep
        np.bitwise_or.at(
            bits, (np.repeat(np.arange(nq), n_ep), np.tile(eps >> 6, nq)),
            np.tile(np.uint64(1) << (eps & 63).astype(np.uint64), nq))
    # candidate-insertion hop (1-based; seeds are hop 0) — feeds
    # convergence_hop for the rerank/PQ result tiers
    cand_hop = np.zeros((nq, width), np.int32)
    order = np.argsort(cand_d, axis=1, kind="stable")[:, :L]
    cand_ids = np.take_along_axis(cand_ids, order, 1)
    cand_d = np.take_along_axis(cand_d, order, 1)
    cand_exp = np.take_along_axis(cand_exp, order, 1)
    cand_hop = np.take_along_axis(cand_hop, order, 1)
    entry_d0 = cand_d[:, 0].copy()     # best seed per query (entry_dist)
    conv_a = np.zeros(nq, np.int64)
    hop_no = 0                         # global loop iteration (1-based in
    #                                    use; per-query prefix == its hops)
    pool_ids_cols: List[np.ndarray] = []
    pool_d_cols: List[np.ndarray] = []

    def _issue_prefetch(depth: int, exclude: Optional[np.ndarray] = None):
        """Queue the top `depth` unexpanded candidates per query for
        background reading (resident / already-queued blocks skipped).
        `exclude` drops blocks the CURRENT hop is about to demand-fetch:
        several nodes share one block, and queueing a block the demand
        path needs RIGHT NOW would turn its fast inline read into a wait
        on the background thread."""
        psel = ~cand_exp & np.isfinite(cand_d)
        pn = cand_ids[psel & (np.cumsum(psel, axis=1) <= depth)]
        if not pn.size:
            return
        offs = host._frontier_offsets(pn)[0]
        if exclude is not None:
            offs = np.setdiff1d(offs, exclude)
        if offs.size:
            # same run coalescing as the demand path: a faster background
            # read is a shorter wait for any fetch that lands on it
            cache.prefetch_async(offs, gap=gap_eff)

    while True:
        t_hop = time.perf_counter()
        # 1. frontier = first w unexpanded candidates per query
        sel = ~cand_exp & np.isfinite(cand_d)
        fmask = sel & (np.cumsum(sel, axis=1) <= w)
        if not fmask.any():
            break
        qf, cols = np.nonzero(fmask)       # row-major: grouped by query
        cand_exp |= fmask
        nf = cand_ids[qf, cols]
        hop_no += 1
        np.add.at(hops_a, np.unique(qf), 1)
        np.add.at(ios_a, qf, 1)
        # 1b. PIPELINE: the predicted hop-(t+1) frontier — the next best
        # unexpanded candidates after this hop's — goes to the background
        # thread NOW, before this hop's fetch and scoring, so its reads
        # overlap this hop's ADC (the two-hop in-flight invariant).  The
        # exact catch-up issue at step 6 covers any mis-prediction.
        blk_off, inner = host._frontier_offsets(nf)
        hop_sp = obs_trace.begin("traversal.hop", frontier=int(nf.size)) \
            if _tracing else None
        if pipeline:
            _issue_prefetch(prefetch, exclude=blk_off)
        # 2. ONE batched fetch for every frontier chunk this hop; with
        # prefetch on, miss runs tolerate `gap`-block holes and read
        # them along (readahead into the cache)
        t_f = time.perf_counter()
        if hop_sp is None:
            blocks, hit_mask, n_sys = cache.fetch(blk_off, gap=gap_eff)
        else:
            with obs_trace.activate(hop_sp):
                blocks, hit_mask, n_sys = cache.fetch(blk_off, gap=gap_eff)
        blocked_s += time.perf_counter() - t_f
        # attribute unique-block hits/misses/bytes to the first query
        # that asked for each block (hit_mask is in first-appearance
        # order, matching sorted first-occurrence indices); syscalls to
        # the hop's lead query
        uq = qf[np.sort(np.unique(blk_off, return_index=True)[1])]
        np.add.at(hit_a, uq[hit_mask], 1)
        np.add.at(miss_a, uq[~hit_mask], 1)
        np.add.at(bytes_a, uq[~hit_mask], lay.io_bytes)
        sys_a[qf[0]] += n_sys
        P = nf.size
        # chunk slice-out: `inner` takes only nodes_per_block distinct
        # values, so per-slot basic slicing beats a fancy-index gather
        chunk = np.empty((P, lay.chunk_bytes), np.uint8)
        for s in np.unique(inner):
            rows = inner == s
            chunk[rows] = blocks[rows, s:s + lay.chunk_bytes]
        # 3. parse all chunks as one matrix
        if lay.data_dtype == "uint8":
            vf = chunk[:, :lay.b_full].astype(np.float32)
        else:
            vf = np.ascontiguousarray(chunk[:, :lay.b_full]) \
                .view(np.float32).reshape(P, -1)
        nbr = np.ascontiguousarray(
            chunk[:, lay.off_ids:lay.off_ids + lay.R * B_NUM]) \
            .view(np.int32).reshape(P, lay.R).astype(np.int64)
        qv = Q[qf]
        if metric == "mips":
            exact = -np.einsum("pd,pd->p", vf, qv)
        else:
            exact = ((vf - qv) ** 2).sum(axis=1)
        # 4. fresh neighbors: valid, unvisited, first occurrence per query
        q_rep = np.repeat(qf, lay.R)
        ids_f = nbr.reshape(-1)
        # ids >= n clamp mirrors search_ref: a concurrent insert's patched
        # edge must not index the n-sized bitset or read past EOF
        valid = (ids_f >= 0) & (ids_f < n)
        safe = np.where(valid, ids_f, 0)
        seen = (bits[q_rep, safe >> 6] >>
                (safe & 63).astype(np.uint64)) & np.uint64(1)
        first_occ = np.zeros(ids_f.size, bool)
        key = np.where(valid, q_rep * n + safe,
                       nq * n + np.arange(ids_f.size))
        first_occ[np.unique(key, return_index=True)[1]] = True
        fresh = valid & (seen == 0) & first_occ
        f_q = q_rep[fresh]
        f_ids = ids_f[fresh]
        if lay.mode == "aisaq":
            # THE AiSAQ step: neighbor codes come from the chunks we just
            # fetched — no N-sized RAM table is ever touched.
            codes = chunk[:, lay.off_pq:lay.off_pq + lay.R * lay.pq_m] \
                .reshape(P * lay.R, lay.pq_m)[fresh]
        else:
            codes = host.pq_codes[f_ids]
        f_g = lut_g[f_q[:, None], jj[None, :], codes.astype(np.int64)]
        f_d = (f_g.astype(np.float32) * dq[f_q]).sum(-1) \
            if dq is not None else f_g.sum(-1).astype(np.float32)
        np.add.at(pq_a, f_q, 1)
        np.bitwise_or.at(bits, (f_q, f_ids >> 6),
                         np.uint64(1) << (f_ids & 63).astype(np.uint64))
        # 5. insert fresh neighbors, re-sort, trim to L
        counts = np.bincount(f_q, minlength=nq)
        K = int(counts.max()) if counts.size else 0
        if K:
            nrank = _group_rank(f_q)
            new_ids = np.full((nq, K), -1, np.int64)
            new_d = np.full((nq, K), np.inf, np.float32)
            new_ids[f_q, nrank] = f_ids
            new_d[f_q, nrank] = f_d
            all_ids = np.concatenate([cand_ids, new_ids], axis=1)
            all_d = np.concatenate([cand_d, new_d], axis=1)
            all_exp = np.concatenate(
                [cand_exp, ~np.isfinite(new_d)], axis=1)
            all_hop = np.concatenate(
                [cand_hop, np.full((nq, K), hop_no, np.int32)], axis=1)
            order = np.argsort(all_d, axis=1, kind="stable")[:, :L]
            cand_ids = np.take_along_axis(all_ids, order, 1)
            cand_d = np.take_along_axis(all_d, order, 1)
            cand_exp = np.take_along_axis(all_exp, order, 1)
            cand_hop = np.take_along_axis(all_hop, order, 1)
        # 6. async next-hop prefetch: the candidate list the NEXT hop
        # will select its frontier from is final here, so the top
        # `prefetch` unexpanded candidates per query are its exact
        # frontier (depth > w adds margin for later hops).  Under the
        # pipeline this is the CATCH-UP issue — only blocks the step-1b
        # prediction missed are still absent; serially it is the sole
        # issue point (background reads overlap only the bookkeeping
        # below).  Either way results are unaffected.
        if prefetch > 0:
            _issue_prefetch(prefetch)
        # 6b. graceful degradation: when several consecutive hops see the
        # background thread's reads FAIL (prefetch_errors climbing), stop
        # feeding it — disable the pipelined/prefetch path for the rest
        # of this search and let the serial demand path (with its own
        # RetryPolicy) carry the traversal.  Results are unaffected: the
        # cache is exact and speculation never changes what is read, only
        # when; the fallback is observable via SearchStats.degraded.
        if (prefetch > 0 or pipeline) and cache is not None:
            cur = cache.counters.prefetch_errors
            if cur > pf_err_last:
                pf_fail_hops += 1
                if pf_fail_hops >= DEGRADE_AFTER_FAILED_HOPS:
                    degraded = True
                    prefetch = 0
                    pipeline = False
            else:
                pf_fail_hops = 0
            pf_err_last = cur
        # 7. pool the exact distances of expanded nodes (re-rank pool)
        frank = _group_rank(qf)
        pcol_i = np.full((nq, w), -1, np.int64)
        pcol_d = np.full((nq, w), np.inf, np.float32)
        pcol_i[qf, frank] = nf
        pcol_d[qf, frank] = exact
        pool_ids_cols.append(pcol_i)
        pool_d_cols.append(pcol_d)
        compute_s += time.perf_counter() - t_hop
        if hop_sp is not None:
            hop_sp.annotate(syscalls=int(n_sys),
                            misses=int((~hit_mask).sum()),
                            fresh=int(f_ids.size))
            hop_sp.end()
    # the hop loop's compute_s included the fetch waits; carve them out
    compute_s = max(0.0, compute_s - blocked_s)
    out = np.full((nq, k), -1, np.int64)
    if rerank is not None:
        # -- exact rerank tier over the FINAL candidate list ------------
        # (the scalar twin is _rerank_tail_ref; both must stay
        # bit-identical). The final list is PQ-sorted with inf padding.
        r_eff = max(int(rerank), k) if rerank else 0
        exp_map: List[Dict[int, float]] = [{} for _ in range(nq)]
        if r_eff and pool_ids_cols:
            pool_ids = np.concatenate(pool_ids_cols, axis=1)
            pool_d = np.concatenate(pool_d_cols, axis=1)
            for i in range(nq):
                vmask = pool_ids[i] >= 0
                exp_map[i] = dict(zip(pool_ids[i][vmask].tolist(),
                                      pool_d[i][vmask].tolist()))
        sel_ids: List[np.ndarray] = []
        sel_d: List[Optional[np.ndarray]] = []
        sel_hops: List[np.ndarray] = []
        need_pairs: List[Tuple[int, int]] = []
        need_nodes: List[int] = []
        for i in range(nq):
            vmask = (cand_ids[i] >= 0) & np.isfinite(cand_d[i])
            sel = cand_ids[i][vmask][:max(r_eff, k)]
            sel_ids.append(sel)
            sel_hops.append(cand_hop[i][vmask][:max(r_eff, k)])
            if not r_eff:            # PQ-only tier: keep ADC ranking
                sel_d.append(None)
                continue
            d = np.full(sel.size, np.inf, np.float32)
            for j, p in enumerate(sel.tolist()):
                e = exp_map[i].get(p)
                if e is None:
                    need_pairs.append((i, j))
                    need_nodes.append(p)
                else:
                    d[j] = e
            sel_d.append(d)
        if need_nodes:
            # one batched cache fetch for every unexpanded candidate
            nodes = np.asarray(need_nodes, dtype=np.int64)
            nqi = np.asarray([pr[0] for pr in need_pairs], dtype=np.int64)
            blk_off, inner = host._frontier_offsets(nodes)
            rr_sp = obs_trace.begin("traversal.rerank",
                                    nodes=int(nodes.size)) \
                if _tracing else None
            t_f = time.perf_counter()
            if rr_sp is None:
                blocks, hit_mask, n_sys = cache.fetch(blk_off)
            else:
                with obs_trace.activate(rr_sp):
                    blocks, hit_mask, n_sys = cache.fetch(blk_off)
                rr_sp.end()
            blocked_s += time.perf_counter() - t_f
            uq = nqi[np.sort(np.unique(blk_off, return_index=True)[1])]
            np.add.at(hit_a, uq[hit_mask], 1)
            np.add.at(miss_a, uq[~hit_mask], 1)
            np.add.at(bytes_a, uq[~hit_mask], lay.io_bytes)
            sys_a[nqi[0]] += n_sys
            np.add.at(ios_a, nqi, 1)
            np.add.at(rr_a, nqi, 1)
            P2 = nodes.size
            chunk = np.empty((P2, lay.chunk_bytes), np.uint8)
            for s in np.unique(inner):
                rows = inner == s
                chunk[rows] = blocks[rows, s:s + lay.chunk_bytes]
            if lay.data_dtype == "uint8":
                vf = chunk[:, :lay.b_full].astype(np.float32)
            else:
                vf = np.ascontiguousarray(chunk[:, :lay.b_full]) \
                    .view(np.float32).reshape(P2, -1)
            qv = Q[nqi]
            if metric == "mips":
                ex = -np.einsum("pd,pd->p", vf, qv)
            else:
                ex = ((vf - qv) ** 2).sum(axis=1)
            for (i, j), e in zip(need_pairs, ex):
                sel_d[i][j] = e
        for i in range(nq):
            if r_eff:
                oi = np.argsort(sel_d[i], kind="stable")[:k]
            else:
                oi = np.arange(min(k, sel_ids[i].size))
            top = sel_ids[i][oi]
            out[i, :top.size] = top
            if top.size:
                conv_a[i] = int(sel_hops[i][oi].max())
    elif pool_ids_cols:
        # re-rank over every expanded node, in expansion order
        # (stable ties) — the traversal-pool tier
        pool_ids = np.concatenate(pool_ids_cols, axis=1)
        pool_d = np.concatenate(pool_d_cols, axis=1)
        for i in range(nq):
            vmask = pool_ids[i] >= 0
            vids, vd = pool_ids[i][vmask], pool_d[i][vmask]
            oi = np.argsort(vd, kind="stable")[:k]
            top = vids[oi]
            out[i, :top.size] = top
            if top.size:
                # pool column c was appended on loop iteration c // w,
                # and a query's active iterations are exactly its first
                # `hops` — so this matches the oracle's expansion hop
                conv_a[i] = int(np.flatnonzero(vmask)[oi].max() // w) + 1
    wall = time.perf_counter() - t0
    stats = []
    for i in range(nq):
        stats.append(SearchStats(
            hops=int(hops_a[i]), ios=int(ios_a[i]),
            bytes_read=int(bytes_a[i]), pq_dists=int(pq_a[i]),
            latency_s=wall / nq, syscalls=int(sys_a[i]),
            cache_hits=int(hit_a[i]), cache_misses=int(miss_a[i]),
            rerank_ios=int(rr_a[i]),
            convergence_hop=int(conv_a[i]),
            entry_dist=float(entry_d0[i]),
            nav_hops=int(nav_hops_a[i]) if nav_hops_a is not None else 0,
            nav_dists=int(nav_dists_a[i])
            if nav_dists_a is not None else 0))
    if pf0 is not None:
        # whole-batch prefetch deltas, attributed to the lead query
        c = cache.counters
        stats[0].prefetch_issued = c.prefetch_issued - pf0[0]
        stats[0].prefetch_hits = c.prefetch_hits - pf0[1]
        stats[0].prefetch_wasted = c.prefetch_wasted - pf0[2]
    # whole-batch overlap accounting, attributed to the lead query
    stats[0].blocked_wait_s = blocked_s
    stats[0].compute_s = compute_s
    stats[0].pipelined = int(was_pipelined)
    stats[0].degraded = int(degraded)
    stats[0].nav_s = nav_s
    # SearchStats -> histograms: a pool-attached handle publishes hop /
    # I/O / blocked-vs-compute DISTRIBUTIONS per corpus (obs.metrics
    # SearchMetrics); bare HostIndex loads skip this with one getattr
    sm = getattr(host, "metrics", None)
    if sm is not None:
        sm.observe_batch(stats, wall, blocked_s, compute_s)
    return out, stats


def _group_rank(group_ids: np.ndarray) -> np.ndarray:
    """Rank within consecutive groups: [3,3,5,5,5,7] -> [0,1,0,1,2,0].
    `group_ids` must be non-decreasing (row-major np.nonzero guarantees it).
    """
    if group_ids.size == 0:
        return group_ids
    starts = np.flatnonzero(
        np.concatenate([[True], group_ids[1:] != group_ids[:-1]]))
    return np.arange(group_ids.size) - np.repeat(
        starts, np.diff(np.concatenate([starts, [group_ids.size]])))


def recall_at(ids: np.ndarray, gt: np.ndarray, k: int) -> float:
    """k-recall@k over a batch: |pred_k ∩ gt_k| / k averaged (vectorized)."""
    p, g = ids[:, :k], gt[:, :k]
    srt = np.sort(p, axis=1)
    if k > 1 and (srt[:, 1:] == srt[:, :-1]).any():
        # duplicate predictions: fall back to exact set semantics
        hits = sum(len(set(map(int, rp)) & set(map(int, rg)))
                   for rp, rg in zip(p, g))
        return hits / (ids.shape[0] * k)
    hits = (p[:, :, None] == g[:, None, :]).any(axis=2).sum()
    return float(hits) / (ids.shape[0] * k)
