"""Product Quantization (Jegou et al., TPAMI'11) in pure JAX.

This is the compression layer both DiskANN and AiSAQ build on:
  * ``train_codebooks`` — per-subspace Lloyd k-means (vmapped over subspaces)
  * ``encode`` / ``decode`` — vector <-> (m,) uint8 codes
  * ``build_lut`` — per-query asymmetric distance lookup table (m, ks)
  * ``adc`` — asymmetric distance computation: sum LUT entries over codes

These jnp versions are the *reference semantics*; ``repro.kernels`` holds the
Pallas TPU kernels that mirror them (validated by tests/test_kernels.py).

Distance conventions (smaller is better everywhere):
  l2   -> squared euclidean, decomposed exactly over subspaces
  mips -> negative inner product, decomposed exactly over subspaces
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class PQCodebooks(NamedTuple):
    """(m, ks, dsub) float32 centroids. `m` subquantizers, `ks` centroids."""

    centroids: jax.Array

    @property
    def m(self) -> int:
        return self.centroids.shape[0]

    @property
    def ks(self) -> int:
        return self.centroids.shape[1]

    @property
    def dsub(self) -> int:
        return self.centroids.shape[2]

    @property
    def dim(self) -> int:
        return self.m * self.dsub

    def nbytes(self) -> int:
        return int(np.prod(self.centroids.shape)) * 4


def split_subspaces(x: jax.Array, m: int) -> jax.Array:
    """(n, d) -> (m, n, dsub)."""
    n, d = x.shape
    assert d % m == 0, f"dim {d} not divisible by m={m}"
    return jnp.moveaxis(x.reshape(n, m, d // m), 1, 0)


def _pairwise_sqdist(x: jax.Array, c: jax.Array) -> jax.Array:
    """(n, dsub) x (ks, dsub) -> (n, ks) squared L2 (matmul form for MXU)."""
    xn = jnp.sum(x * x, axis=-1, keepdims=True)          # (n, 1)
    cn = jnp.sum(c * c, axis=-1)                          # (ks,)
    return xn - 2.0 * (x @ c.T) + cn[None, :]


@functools.partial(jax.jit, static_argnames=("m", "ks", "iters", "batch"))
def train_codebooks(rng: jax.Array, data: jax.Array, *, m: int, ks: int = 256,
                    iters: int = 12, batch: int = 65536) -> PQCodebooks:
    """Per-subspace Lloyd k-means. data: (n, d) float. Returns PQCodebooks."""
    data = data.astype(jnp.float32)
    n = data.shape[0]
    subs = split_subspaces(data, m)                       # (m, n, dsub)
    init_idx = jax.random.choice(rng, n, shape=(ks,), replace=n < ks)
    cent = subs[:, init_idx, :]                           # (m, ks, dsub)

    def assign_chunked(sub: jax.Array, cb: jax.Array) -> jax.Array:
        """(n, dsub), (ks, dsub) -> (n,) nearest-centroid ids, chunked."""
        nb = (n + batch - 1) // batch
        pad = nb * batch - n
        subp = jnp.pad(sub, ((0, pad), (0, 0)))
        chunks = subp.reshape(nb, batch, -1)
        ids = jax.lax.map(lambda c: jnp.argmin(_pairwise_sqdist(c, cb), axis=-1),
                          chunks)
        return ids.reshape(-1)[:n]

    def lloyd_step(cent, _):
        def per_sub(sub, cb):
            ids = assign_chunked(sub, cb)
            sums = jax.ops.segment_sum(sub, ids, num_segments=ks)
            cnts = jax.ops.segment_sum(jnp.ones((n,), jnp.float32), ids,
                                       num_segments=ks)
            new = sums / jnp.maximum(cnts, 1.0)[:, None]
            # keep old centroid for empty clusters
            new = jnp.where((cnts > 0)[:, None], new, cb)
            return new
        return jax.vmap(per_sub)(subs, cent), None

    cent, _ = jax.lax.scan(lloyd_step, cent, None, length=iters)
    return PQCodebooks(cent)


@functools.partial(jax.jit, static_argnames=("batch",))
def encode(codebooks: PQCodebooks, data: jax.Array, *, batch: int = 65536
           ) -> jax.Array:
    """(n, d) -> (n, m) uint8 codes."""
    data = data.astype(jnp.float32)
    n = data.shape[0]
    m = codebooks.m
    subs = split_subspaces(data, m)                       # (m, n, dsub)
    nb = (n + batch - 1) // batch
    pad = nb * batch - n
    subsp = jnp.pad(subs, ((0, 0), (0, pad), (0, 0)))
    subsp = subsp.reshape(m, nb, batch, -1).transpose(1, 0, 2, 3)

    def chunk_codes(chunk):                                # (m, batch, dsub)
        def per_sub(sub, cb):
            return jnp.argmin(_pairwise_sqdist(sub, cb), axis=-1)
        return jax.vmap(per_sub)(chunk, codebooks.centroids)

    codes = jax.lax.map(chunk_codes, subsp)                # (nb, m, batch)
    codes = codes.transpose(0, 2, 1).reshape(nb * batch, m)[:n]
    return codes.astype(jnp.uint8)


@jax.jit
def decode(codebooks: PQCodebooks, codes: jax.Array) -> jax.Array:
    """(n, m) uint8 -> (n, d) float32 reconstruction."""
    n, m = codes.shape
    # gather per subspace: centroids (m, ks, dsub), codes (n, m)
    rec = jnp.take_along_axis(
        codebooks.centroids[None],                         # (1, m, ks, dsub)
        codes.astype(jnp.int32).T[None, :, :, None]        # (1, m, n, 1)
        .transpose(0, 1, 2, 3),
        axis=2,
    )                                                      # (1, m, n, dsub)
    return rec[0].transpose(1, 0, 2).reshape(n, m * codebooks.dsub)


@functools.partial(jax.jit, static_argnames=("metric",))
def build_lut(codebooks: PQCodebooks, queries: jax.Array, *, metric: str = "l2"
              ) -> jax.Array:
    """(q, d) -> (q, m, ks) float32 LUT.

    l2:   lut[q, j, c] = ||q_j - cent[j, c]||^2
    mips: lut[q, j, c] = -<q_j, cent[j, c]>
    """
    queries = queries.astype(jnp.float32)
    qs = split_subspaces(queries, codebooks.m)             # (m, q, dsub)
    if metric == "l2":
        lut = jax.vmap(_pairwise_sqdist)(qs, codebooks.centroids)  # (m, q, ks)
    elif metric == "mips":
        lut = -jnp.einsum("mqd,mkd->mqk", qs, codebooks.centroids)
    else:
        raise ValueError(f"unknown metric {metric!r}")
    return lut.transpose(1, 0, 2)                          # (q, m, ks)


@jax.jit
def adc(lut: jax.Array, codes: jax.Array) -> jax.Array:
    """Asymmetric distances. lut: (q, m, ks) or (m, ks); codes: (..., m).

    Returns (q, ...) or (...,) float32 distances = sum_j lut[j, codes[..., j]].
    """
    single = lut.ndim == 2
    if single:
        lut = lut[None]
    q, m, ks = lut.shape
    flat = lut.reshape(q, m * ks)                          # (q, m*ks)
    idx = codes.astype(jnp.int32) + (jnp.arange(m) * ks)   # (..., m)
    gathered = flat[:, idx.reshape(-1, m)]                 # (q, n, m)
    out = gathered.sum(-1).reshape((q,) + codes.shape[:-1])
    return out[0] if single else out


@jax.jit
def adc_onehot(lut: jax.Array, codes: jax.Array) -> jax.Array:
    """MXU-friendly ADC: one-hot(codes) @ lut. Same contract as :func:`adc`.

    This is the TPU-native reformulation (DESIGN.md §2): a (n*m, ks) one-hot
    times (m*ks,) LUT contraction instead of scalar gathers.
    """
    single = lut.ndim == 2
    if single:
        lut = lut[None]
    q, m, ks = lut.shape
    oh = jax.nn.one_hot(codes.astype(jnp.int32), ks, dtype=lut.dtype)  # (...,m,ks)
    out = jnp.einsum("...mk,qmk->q...", oh, lut)
    return out[0] if single else out


def exact_distances(queries: jax.Array, base: jax.Array, *, metric: str = "l2"
                    ) -> jax.Array:
    """(q, d) x (n, d) -> (q, n) full-precision distances (smaller=better)."""
    queries = queries.astype(jnp.float32)
    base = base.astype(jnp.float32)
    if metric == "l2":
        return _pairwise_sqdist(queries, base)
    if metric == "mips":
        return -(queries @ base.T)
    raise ValueError(f"unknown metric {metric!r}")


def groundtruth(queries: jax.Array, base: jax.Array, k: int, *,
                metric: str = "l2", batch: int = 262144) -> np.ndarray:
    """Brute-force top-k ids, chunked over the base set. Returns (q, k) int."""
    queries = jnp.asarray(queries, jnp.float32)
    n = base.shape[0]
    best_d = None
    best_i = None
    for s in range(0, n, batch):
        blk = jnp.asarray(base[s:s + batch], jnp.float32)
        d = exact_distances(queries, blk, metric=metric)
        i = jnp.arange(s, s + blk.shape[0])[None, :].repeat(queries.shape[0], 0)
        if best_d is None:
            best_d, best_i = d, i
        else:
            best_d = jnp.concatenate([best_d, d], axis=1)
            best_i = jnp.concatenate([best_i, i], axis=1)
        # keep running top-k to bound memory
        kk = min(k, best_d.shape[1])
        nd, pos = jax.lax.top_k(-best_d, kk)
        best_d = -nd
        best_i = jnp.take_along_axis(best_i, pos, axis=1)
    return np.asarray(best_i[:, :k])
