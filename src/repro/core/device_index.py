"""Device (TPU-target) AiSAQ index: HBM chunk table + while_loop beam search.

The HBM-resident `(N, stride/4)` int32 chunk table is the "storage tier"
(DESIGN.md §2). Per-hop work — chunk gather, parse, inline-PQ ADC — is
`kernels.ops.fused_hop` (Pallas on TPU, jnp ref elsewhere). Nothing
N-proportional is ever needed in VMEM: the only per-query fast-tier state is
the (L,) candidate list, the (m, ks) LUT and the re-rank pool — the paper's
`(R + n_ep)·b_pq` residency invariant, tier-shifted.

The search loop is batched: all queries hop together; finished queries pad
their frontier with -1 (the hop kernel emits +inf for those lanes).
"""
from __future__ import annotations

import functools
import json
import os
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.chunk_layout import ChunkLayout, chunk_matrix, \
    pack_chunks_device
from repro.kernels import ops


class DeviceIndex(NamedTuple):
    chunk_words: jax.Array        # (N, stride/4) int32 — HBM storage tier
    centroids: jax.Array          # (m, ks, dsub) f32
    ep_ids: jax.Array             # (n_ep,) int32
    ep_codes: jax.Array           # (n_ep, m) int32
    pq_codes: Optional[jax.Array] = None   # (N, m) — diskann mode ONLY

    @property
    def n(self) -> int:
        return self.chunk_words.shape[0]

    def fast_tier_bytes(self, n_queries: int, L: int) -> int:
        """Bytes that must live in the fast tier during search (paper T2)."""
        m, ks = self.centroids.shape[0], self.centroids.shape[1]
        per_q = 4 * (m * ks + 3 * L)          # LUT + candidate list + pool
        resident = self.centroids.size * 4 + self.ep_codes.size * 4
        if self.pq_codes is not None:         # DiskANN keeps ALL codes hot
            resident += self.pq_codes.size * self.pq_codes.dtype.itemsize
        return int(resident + per_q * n_queries)


def from_arrays(vectors: np.ndarray, graph: np.ndarray, centroids: np.ndarray,
                codes: np.ndarray, *, mode: str = "aisaq",
                block_bytes: int = 4096) -> Tuple[DeviceIndex, ChunkLayout]:
    n, d = vectors.shape
    layout = ChunkLayout(
        mode=mode, dim=d,
        data_dtype="uint8" if vectors.dtype == np.uint8 else "float32",
        R=graph.shape[1], pq_m=codes.shape[1], block_bytes=block_bytes)
    dev = pack_chunks_device(vectors, graph, codes, layout)
    words = np.ascontiguousarray(dev).view(np.int32).reshape(n, -1)
    mean = vectors.astype(np.float32).mean(axis=0)
    dd = ((vectors.astype(np.float32) - mean) ** 2).sum(axis=1)
    ep = np.argsort(dd)[:1].astype(np.int32)
    idx = DeviceIndex(
        chunk_words=jnp.asarray(words),
        centroids=jnp.asarray(centroids, jnp.float32),
        ep_ids=jnp.asarray(ep),
        ep_codes=jnp.asarray(codes[ep].astype(np.int32)),
        pq_codes=jnp.asarray(codes) if mode == "diskann" else None)
    return idx, layout


def load_device_index(path: str) -> Tuple[DeviceIndex, ChunkLayout, str]:
    """Load a host-format index dir into device arrays (rebuild words)."""
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    codes = np.load(os.path.join(path, "pq_codes.npy"))
    centroids = np.load(os.path.join(path, "pq_centroids.npy"))
    # reconstruct vectors+graph from chunks.bin (vectorized: one strided
    # reshape to an (n, chunk_bytes) view of all chunks, then field slices)
    layout = ChunkLayout(mode=meta["mode"], dim=meta["dim"],
                         data_dtype=meta["data_dtype"], R=meta["R"],
                         pq_m=meta["pq_m"], block_bytes=meta["block_bytes"])
    raw = np.fromfile(os.path.join(path, "chunks.bin"), dtype=np.uint8)
    n = meta["n"]
    chunks = chunk_matrix(raw, layout, n)
    if meta["data_dtype"] == "uint8":
        vecs = chunks[:, :layout.b_full].copy()
    else:
        vecs = np.ascontiguousarray(
            chunks[:, :layout.b_full]).view(np.float32).reshape(n, -1)
    graph = np.ascontiguousarray(
        chunks[:, layout.off_ids:layout.off_ids + layout.R * 4]) \
        .view(np.int32).reshape(n, layout.R)
    if meta.get("relabeled"):
        # locality-relabeled index: undo the pack-time permutation so the
        # device tier works (and returns ids) in ORIGINAL label space —
        # HBM gathers don't care about file-page locality anyway
        from repro.core.relabel import invert_permutation
        old_to_new = np.load(os.path.join(path, "id_map.npy"))
        new_to_old = invert_permutation(old_to_new)
        vecs = vecs[old_to_new]
        codes = codes[old_to_new]
        g = graph[old_to_new]
        graph = np.where(g >= 0, new_to_old[np.where(g >= 0, g, 0)],
                         -1).astype(np.int32)
    idx, layout = from_arrays(vecs, graph, centroids, codes,
                              mode=meta["mode"],
                              block_bytes=meta["block_bytes"])
    return idx, layout, meta["metric"]


# ---------------------------------------------------------------------------
# batched beam search (Algorithm 1 on device)
# ---------------------------------------------------------------------------


def _mask_intra_dups(ids: jax.Array) -> jax.Array:
    """(nq, K) int -> bool mask of duplicate (non-first) occurrences."""
    order = jnp.argsort(ids, axis=1)
    srt = jnp.take_along_axis(ids, order, axis=1)
    dup_sorted = jnp.concatenate(
        [jnp.zeros_like(srt[:, :1], dtype=bool), srt[:, 1:] == srt[:, :-1]],
        axis=1)
    dup = jnp.zeros_like(dup_sorted)
    qi = jnp.arange(ids.shape[0])[:, None]
    return dup.at[qi, order].set(dup_sorted)


@functools.partial(
    jax.jit,
    static_argnames=("k", "L", "w", "max_hops", "layout", "metric", "backend",
                     "adc_dtype"))
def beam_search_device(index: DeviceIndex, queries: jax.Array, *, k: int,
                       L: int, w: int = 4, max_hops: int = 128,
                       layout: ChunkLayout, metric: str = "l2",
                       backend: str = "auto", adc_dtype: str = "f32"):
    """Batched DiskANN/AiSAQ beam search. Returns (topk_ids, topk_d, hops).

    adc_dtype="int8" runs neighbor ADC through the int8 fused-hop kernel
    (2x MXU rate); the exact re-rank distances stay f32, so end recall is
    within quantization noise of the f32 path (aisaq mode only).
    """
    nq = queries.shape[0]
    N = index.n
    R = layout.R
    lut = ops.build_lut(queries, index.centroids, metric=metric,
                        backend=backend)
    n_ep = index.ep_ids.shape[0]
    ep_ids = jnp.broadcast_to(index.ep_ids[None, :], (nq, n_ep))
    ep_d = jax.vmap(lambda l: jnp.sum(
        jnp.take(l.reshape(-1),
                 index.ep_codes + jnp.arange(lut.shape[1]) * lut.shape[2]),
        axis=-1))(lut)                                    # (nq, n_ep)
    pad = L - n_ep
    cand_ids = jnp.concatenate(
        [ep_ids, jnp.full((nq, pad), -1, jnp.int32)], axis=1)
    cand_d = jnp.concatenate(
        [ep_d, jnp.full((nq, pad), jnp.inf, jnp.float32)], axis=1)
    cand_exp = jnp.concatenate(
        [jnp.zeros((nq, n_ep), bool), jnp.ones((nq, pad), bool)], axis=1)
    # visited set as a PACKED bitmask (N/32 uint32 words per query, §Perf
    # "bitmask"): ids are pre-deduplicated before insertion, so each bit is
    # added at most once and scatter-add == bitwise OR.
    n_words = -(-N // 32)
    qi = jnp.arange(nq)[:, None]
    inserted = jnp.zeros((nq, n_words), jnp.uint32)
    inserted = inserted.at[qi, ep_ids >> 5].add(
        (jnp.uint32(1) << (ep_ids & 31).astype(jnp.uint32)))
    pool_ids = jnp.full((nq, L), -1, jnp.int32)
    pool_d = jnp.full((nq, L), jnp.inf, jnp.float32)

    def cond(state):
        cand_ids, cand_d, cand_exp, inserted, pool_ids, pool_d, hops = state
        active = jnp.any(~cand_exp & jnp.isfinite(cand_d))
        return active & (hops < max_hops)

    def body(state):
        cand_ids, cand_d, cand_exp, inserted, pool_ids, pool_d, hops = state
        # 1. frontier: top-w unexpanded by PQ distance
        sel = jnp.where(cand_exp, jnp.inf, cand_d)
        negd, pos = jax.lax.top_k(-sel, w)                 # (nq, w)
        fvalid = jnp.isfinite(negd)
        fids = jnp.where(fvalid,
                         jnp.take_along_axis(cand_ids, pos, axis=1), -1)
        cand_exp = cand_exp.at[qi, pos].max(fvalid)
        # 2. expand: chunk gather + parse + exact dist + neighbor ADC
        if layout.mode == "aisaq":
            exact, nids, nd = ops.fused_hop(
                index.chunk_words, fids, lut, queries, layout=layout,
                metric=metric, backend=backend, adc_dtype=adc_dtype)
        else:
            # DiskANN-on-device: ids from chunks, codes from the resident
            # (N, m) table — the memory-hungry baseline placement.
            from repro.kernels import ref as _ref
            exact, nids, _ = jax.vmap(functools.partial(
                _ref.fused_hop_ref, index.chunk_words, layout=layout,
                metric=metric))(fids, lut, queries)
            flat = jnp.clip(nids.reshape(nq, -1), 0, N - 1)
            codes = index.pq_codes[flat]                   # (nq, w*R, m)
            m, ks = lut.shape[1], lut.shape[2]
            idxs = codes.astype(jnp.int32) + jnp.arange(m) * ks
            nd = jax.vmap(lambda l, ii: jnp.take(l.reshape(-1), ii).sum(-1)
                          )(lut, idxs).reshape(nq, w, R)
            nd = jnp.where(nids >= 0, nd, jnp.inf)
        # 3. re-rank pool (exact distances of expanded nodes)
        pool_ids = jnp.concatenate([pool_ids, fids], axis=1)
        pool_d = jnp.concatenate([pool_d, exact], axis=1)
        npd, ppos = jax.lax.top_k(-pool_d, L)
        pool_d = -npd
        pool_ids = jnp.take_along_axis(pool_ids, ppos, axis=1)
        # 4. neighbor insertion with dedup (packed-bitmask membership)
        nids_f = nids.reshape(nq, w * R)
        nd_f = nd.reshape(nq, w * R)
        safe = jnp.clip(nids_f, 0, N - 1)
        words = jnp.take_along_axis(inserted, safe >> 5, axis=1)
        seen = ((words >> (safe & 31).astype(jnp.uint32)) & 1).astype(bool)
        bad = (nids_f < 0) | seen | _mask_intra_dups(nids_f)
        nd_f = jnp.where(bad, jnp.inf, nd_f)
        nids_f = jnp.where(bad, -1, nids_f)
        safe = jnp.clip(nids_f, 0, N - 1)
        bits = jnp.where(bad, jnp.uint32(0),
                         jnp.uint32(1) << (safe & 31).astype(jnp.uint32))
        inserted = inserted.at[qi, safe >> 5].add(bits)
        # 5. trim candidate list to L by PQ distance
        all_ids = jnp.concatenate([cand_ids, nids_f], axis=1)
        all_d = jnp.concatenate([cand_d, nd_f], axis=1)
        all_exp = jnp.concatenate(
            [cand_exp, jnp.ones_like(nids_f, bool) & ~jnp.isfinite(nd_f)],
            axis=1)
        negd2, cpos = jax.lax.top_k(-all_d, L)
        cand_d = -negd2
        cand_ids = jnp.take_along_axis(all_ids, cpos, axis=1)
        cand_exp = jnp.take_along_axis(all_exp, cpos, axis=1)
        return cand_ids, cand_d, cand_exp, inserted, pool_ids, pool_d, hops + 1

    state = (cand_ids, cand_d, cand_exp, inserted, pool_ids, pool_d,
             jnp.array(0, jnp.int32))
    state = jax.lax.while_loop(cond, body, state)
    _, _, _, _, pool_ids, pool_d, hops = state
    negd, pos = jax.lax.top_k(-pool_d, k)
    return jnp.take_along_axis(pool_ids, pos, axis=1), -negd, hops
