"""On-disk integrity: per-block checksums + typed corruption errors.

The all-in-storage regime makes every search hop a storage read, so media
errors and torn writes are *correctness* hazards, not just latency ones.
This module is the leaf of the fault-tolerance layer — pure helpers with
no repro.core imports, so every other core module can depend on it:

  * ``block_checksums`` computes one 32-bit checksum per I/O unit of a
    packed chunks file; the writer stores them in a ``block_crc.npy``
    sidecar next to ``chunks.bin`` and ``BlockCache`` verifies every
    demand and prefetch read against them,
  * ``resolve_crc`` picks the checksum implementation by name: CRC32C
    (Castagnoli) via the optional ``crc32c`` package when the environment
    has it, else zlib's C-speed CRC32 — both record their name in
    meta.json so a dir written on one machine verifies on another,
  * ``CorruptIndexError`` — a load-time rejection (missing/truncated
    meta.json, sidecar/file size mismatch, unknown format version),
  * ``CorruptBlockError`` — a read-time verification failure that
    SURVIVED the one-reread policy.  It subclasses OSError with errno
    EIO so the serving tier's health tracking classifies it as an I/O
    failure without special-casing.
"""
from __future__ import annotations

import errno
import zlib
from typing import Callable

import numpy as np

#: bump when the on-disk directory layout changes.  Version history:
#:   1 — (implicit; meta.json had no format_version key) the original
#:       chunks.bin + npy sidecars layout
#:   2 — adds the block_crc.npy checksum sidecar, ``format_version`` and
#:       ``crc_algo`` meta keys.  v1 dirs still load, with verification
#:       off (there is nothing to verify against).
#:   3 — adds the OPTIONAL nav_graph.npz navigation-tier sidecar and the
#:       ``nav`` meta key (pivot-selection params).  v1/v2 dirs still
#:       load, with the nav tier disabled; a v3 dir whose sidecar is
#:       damaged also loads nav-disabled (with a warning) — only core
#:       index damage raises CorruptIndexError.
FORMAT_VERSION = 3

#: sidecar filename: one uint32 checksum per ``io_bytes`` unit of
#: chunks.bin, in file order.
CRC_SIDECAR = "block_crc.npy"

try:                                    # optional accelerated Castagnoli
    import crc32c as _crc32c_mod        # noqa: F401
    _HAVE_CRC32C = True
except ImportError:                     # pragma: no cover - env dependent
    _HAVE_CRC32C = False


class CorruptIndexError(RuntimeError):
    """An index directory failed load-time validation (missing or
    truncated meta.json, checksum sidecar inconsistent with chunks.bin,
    or a format_version newer than this code understands)."""


class CorruptBlockError(OSError):
    """A block's checksum mismatched on read AND on the policy reread —
    the bytes on storage are wrong, not merely a transient transfer
    error.  errno is EIO so generic I/O-failure handling applies."""

    def __init__(self, offset: int, expected: int, actual: int,
                 path: str = ""):
        super().__init__(
            errno.EIO,
            f"persistent checksum mismatch at block offset {offset}"
            f"{' of ' + path if path else ''}: "
            f"expected {expected:#010x}, read {actual:#010x}")
        self.offset = offset
        self.expected = expected
        self.actual = actual
        self.path = path


def _crc32(data) -> int:
    return zlib.crc32(data) & 0xFFFFFFFF


def _crc32c(data) -> int:               # pragma: no cover - env dependent
    return _crc32c_mod.crc32c(bytes(data)) & 0xFFFFFFFF


#: algorithm recorded in meta.json by write_index on THIS machine.
PREFERRED_ALGO = "crc32c" if _HAVE_CRC32C else "crc32"


def resolve_crc(name: str) -> Callable[[bytes], int]:
    """Checksum function for the algo name recorded in meta.json."""
    if name == "crc32":
        return _crc32
    if name == "crc32c":
        if not _HAVE_CRC32C:            # pragma: no cover - env dependent
            raise CorruptIndexError(
                "index was written with crc32c checksums but the crc32c "
                "package is unavailable; reload with verification off or "
                "rebuild the index")
        return _crc32c
    raise CorruptIndexError(f"unknown checksum algorithm {name!r}")


def block_checksums(payload, io_bytes: int,
                    crc: Callable[[bytes], int] = _crc32) -> np.ndarray:
    """One checksum per ``io_bytes`` unit of `payload` (whose length must
    be a whole multiple — pack_chunks_file guarantees it)."""
    buf = np.frombuffer(memoryview(payload), dtype=np.uint8)
    if buf.size % io_bytes:
        raise ValueError(
            f"payload of {buf.size} bytes is not a multiple of the "
            f"{io_bytes}-byte I/O unit")
    n = buf.size // io_bytes
    out = np.empty(n, np.uint32)
    for i in range(n):
        out[i] = crc(buf[i * io_bytes:(i + 1) * io_bytes])
    return out
