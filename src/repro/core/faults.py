"""Deterministic, seeded fault injection for the storage read path.

The fault-tolerance layer (retrying reads, checksum rereads, traversal
degradation, corpus quarantine) is only testable if storage failures are
*reproducible*.  ``FaultInjector`` is a drop-in replacement for the
``os.preadv`` callable ``BlockCache`` uses: every read consults a seeded
schedule and may

  * raise a transient ``OSError`` (EIO or EAGAIN),
  * return a short read (fewer bytes than the buffers hold),
  * sleep (latency spike) before serving,
  * flip one bit in a served buffer (corruption — caught by the CRC
    layer, or silently wrong on an unchecksummed index).

Determinism discipline
----------------------
Faults are keyed by ``hash(seed, kind, offset, attempt)`` where
``attempt`` is a per-offset call counter.  Two properties follow:

  * a retry of the same offset is a NEW draw — so a schedule with
    ``eio_rate=r`` makes an n-attempt retry loop fail with probability
    ~``r^n``, exactly the behavior the retry layer is designed for, and
  * the schedule does not depend on wall clock or on global call order
    across offsets, so demand reads and background prefetch reads racing
    each other cannot change WHICH faults an offset sees, only when.

Persistent corruption is separate from the rate-based schedule:
``FaultPlan.corrupt_blocks`` maps a block index to how many reads of it
serve flipped bits (-1 = forever).  A finite count models a transiently
sick region that later heals — the substrate for quarantine-and-recover
drills.

Write-path crash injection
--------------------------
The write-side twin of the read schedule: ``KillSwitch`` is a
deterministic crash trigger the mutation path (``DynamicHostIndex`` +
``core.wal``) ticks at every durability-relevant write step — journal
frame halves, chunk pwrites, fsyncs, each atomic-flush stage.  Counting
mode (``at=None``) enumerates a workload's crash points; ``at=k`` raises
``CrashPoint`` at the k-th tick, freezing the on-storage state exactly
there.  The kill-at-every-offset drill replays a seeded workload once
per crash point and asserts recovery-on-load restores a consistent
index — see ``benchmarks/bench_ingest.py``.
"""
from __future__ import annotations

import errno
import hashlib
import os
import signal
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional


class CrashPoint(Exception):
    """Raised by ``KillSwitch.tick`` to simulate a process crash at an
    exact point of the write path.  Deliberately an ``Exception`` (not
    BaseException): the mutation path must not swallow it, and the drill
    harness catches it at the workload boundary."""

    def __init__(self, label: str, op: int):
        super().__init__(f"injected crash at write op {op} ({label})")
        self.label = label
        self.op = op


class KillSwitch:
    """Deterministic crash trigger for the mutation path.

    Every durability-relevant write step calls ``tick(label)``.  With
    ``at=None`` the switch only counts (enumeration pass: ``count`` after
    a workload is the number of distinct crash points).  With ``at=k``
    the k-th tick raises ``CrashPoint`` exactly once — everything written
    before the tick stays on storage, nothing after it happens, which is
    precisely the state a power cut at that instant leaves behind."""

    def __init__(self, at: Optional[int] = None):
        self.at = at
        self.count = 0
        self.fired = False
        self.labels: list = []      # tick labels in order (enumeration aid)

    def tick(self, label: str):
        self.count += 1
        self.labels.append(label)
        if self.at is not None and not self.fired and self.count >= self.at:
            self.fired = True
            raise CrashPoint(label, self.count)


@dataclass
class FaultPlan:
    """Seeded fault schedule. All rates are per (offset, attempt) draw."""
    seed: int = 0
    eio_rate: float = 0.0           # transient EIO probability
    eagain_rate: float = 0.0        # transient EAGAIN probability
    short_read_rate: float = 0.0    # probability of returning a short read
    latency_rate: float = 0.0       # probability of a latency spike
    latency_s: float = 0.002        # spike duration
    #: block index -> number of reads served with one flipped bit
    #: (-1 = corrupted forever).  Block index = file_offset // io_bytes.
    corrupt_blocks: Dict[int, int] = field(default_factory=dict)
    #: stop injecting rate-based faults after this many (None = unlimited);
    #: lets a test script exact fault counts ("fail the first read only").
    max_faults: Optional[int] = None


class FaultInjector:
    """A ``preadv``-shaped callable wrapping ``os.preadv`` with the plan's
    deterministic fault schedule.  Pass ``injector.preadv`` (or the
    injector itself) as the BlockCache / HostIndex ``preadv`` hook."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._lock = threading.Lock()
        self._attempts: Dict[int, int] = {}       # offset -> reads so far
        self._corrupt_served: Dict[int, int] = {}  # block -> corrupt reads
        self.calls = 0
        self.injected_eio = 0
        self.injected_eagain = 0
        self.injected_short = 0
        self.injected_latency = 0
        self.injected_corrupt = 0

    def __call__(self, fd: int, bufs, offset: int) -> int:
        return self.preadv(fd, bufs, offset)

    # -- deterministic draws -------------------------------------------------
    def _u(self, kind: str, offset: int, attempt: int) -> float:
        h = hashlib.blake2b(
            f"{self.plan.seed}:{kind}:{offset}:{attempt}".encode(),
            digest_size=8).digest()
        return int.from_bytes(h, "big") / 2.0 ** 64

    def _budget_ok(self) -> bool:
        m = self.plan.max_faults
        if m is None:
            return True
        injected = (self.injected_eio + self.injected_eagain
                    + self.injected_short + self.injected_latency)
        return injected < m

    # -- the hook ------------------------------------------------------------
    def preadv(self, fd: int, bufs, offset: int) -> int:
        p = self.plan
        with self._lock:
            self.calls += 1
            attempt = self._attempts.get(offset, 0)
            self._attempts[offset] = attempt + 1
            budget = self._budget_ok()
            if budget and self._u("eio", offset, attempt) < p.eio_rate:
                self.injected_eio += 1
                raise OSError(errno.EIO,
                              f"injected transient EIO @ {offset}")
            if budget and self._u("eagain", offset, attempt) < p.eagain_rate:
                self.injected_eagain += 1
                raise OSError(errno.EAGAIN,
                              f"injected transient EAGAIN @ {offset}")
            spike = budget and \
                self._u("lat", offset, attempt) < p.latency_rate
            short = budget and \
                self._u("short", offset, attempt) < p.short_read_rate
            if spike:
                self.injected_latency += 1
            if short:
                self.injected_short += 1
        if spike:
            time.sleep(p.latency_s)
        got = os.preadv(fd, bufs, offset)
        io = len(bufs[0]) if bufs else 0
        if p.corrupt_blocks and io:
            with self._lock:
                for j, buf in enumerate(bufs):
                    blk = (offset + j * io) // io
                    limit = p.corrupt_blocks.get(blk)
                    if limit is None:
                        continue
                    served = self._corrupt_served.get(blk, 0)
                    if limit >= 0 and served >= limit:
                        continue        # healed: served its corrupt quota
                    pos = int(self._u("pos", blk, served) * io) % io
                    buf[pos] ^= 1 << (served % 8)
                    self._corrupt_served[blk] = served + 1
                    self.injected_corrupt += 1
        if short and got > io:
            # the buffers are fully populated, but a short return value
            # tells the caller the tail is garbage — a correct reader
            # must retry, an incorrect one silently consumes stale bytes
            return got - io
        return got

    def stats(self) -> dict:
        with self._lock:
            return dict(calls=self.calls,
                        injected_eio=self.injected_eio,
                        injected_eagain=self.injected_eagain,
                        injected_short=self.injected_short,
                        injected_latency=self.injected_latency,
                        injected_corrupt=self.injected_corrupt)


# ---------------------------------------------------------------------------
# process-level crash injection (the cluster tier's KillSwitch)
# ---------------------------------------------------------------------------


class ProcessKiller:
    """SIGKILL a chosen worker PROCESS at a deterministic tick.

    The cluster drill's twin of ``KillSwitch``: the drill loop calls
    ``tick()`` once per routed request, and at the ``at``-th tick the
    armed pid receives SIGKILL — no warning, no cleanup, exactly the
    failure a hardware fault or the OOM killer delivers.  ``arm`` takes
    a pid or a zero-arg callable resolving to one at fire time (the
    supervisor may have respawned the worker since arming, so the
    drill kills whoever owns the slot WHEN the tick lands).

    Thread-safe: concurrent router threads may tick; exactly one fires.
    """

    def __init__(self, at: Optional[int] = None,
                 sig: int = signal.SIGKILL):
        self.at = at
        self.sig = sig
        self.count = 0
        self.fired = False
        self.killed_pid: Optional[int] = None
        self._victim: Optional[Callable[[], Optional[int]]] = None
        self._lock = threading.Lock()

    def arm(self, victim) -> "ProcessKiller":
        """``victim``: a pid (int) or a zero-arg callable -> pid/None."""
        with self._lock:
            self._victim = victim if callable(victim) \
                else (lambda pid=int(victim): pid)
        return self

    def tick(self) -> bool:
        """Count one drill event; fire at the configured tick.  Returns
        True iff THIS call delivered the signal."""
        with self._lock:
            self.count += 1
            if (self.at is None or self.fired or self._victim is None
                    or self.count < self.at):
                return False
            self.fired = True
            pid = self._victim()
        if pid is None:
            return False
        try:
            os.kill(pid, self.sig)
        except ProcessLookupError:
            return False                 # already dead: the drill still ran
        self.killed_pid = pid
        return True


# ---------------------------------------------------------------------------
# socket-level fault shims (the wire's FaultInjector)
# ---------------------------------------------------------------------------


@dataclass
class SocketFaultPlan:
    """Deterministic fault schedule for one wrapped connection.  All
    rates are per-operation draws keyed by ``hash(seed, op, call#)`` —
    reconnecting resets the call counter, so a retry sees a fresh
    schedule exactly like ``FaultInjector``'s per-attempt draws."""
    seed: int = 0
    corrupt_rate: float = 0.0     # flip one bit in the bytes that flow
    drop_rate: float = 0.0        # sever the connection instead of serving
    delay_rate: float = 0.0       # sleep before serving
    delay_s: float = 0.002
    max_faults: Optional[int] = None


class FlakySocket:
    """Wraps a connected socket with the plan's deterministic faults.

    ``sendall``/``recv`` may (a) raise ``ConnectionResetError`` and close
    the underlying socket (severed wire), (b) flip one bit in the bytes
    that pass (the framed protocol's CRC must catch it), or (c) sleep
    (congestion).  Everything else proxies through, so the shim drops
    into ``serving.protocol`` helpers and router clients unchanged."""

    def __init__(self, sock, plan: SocketFaultPlan):
        self._sock = sock
        self.plan = plan
        self._calls = 0
        self._lock = threading.Lock()
        self.injected_corrupt = 0
        self.injected_drop = 0
        self.injected_delay = 0

    # -- deterministic draws -------------------------------------------------
    def _u(self, kind: str, call: int) -> float:
        h = hashlib.blake2b(
            f"{self.plan.seed}:{kind}:{call}".encode(),
            digest_size=8).digest()
        return int.from_bytes(h, "big") / 2.0 ** 64

    def _draw(self):
        """(corrupt, drop, delay) for this call, honoring max_faults."""
        p = self.plan
        with self._lock:
            call = self._calls
            self._calls += 1
            injected = (self.injected_corrupt + self.injected_drop
                        + self.injected_delay)
            if p.max_faults is not None and injected >= p.max_faults:
                return False, False, False
            corrupt = self._u("corrupt", call) < p.corrupt_rate
            drop = self._u("drop", call) < p.drop_rate
            delay = self._u("delay", call) < p.delay_rate
            self.injected_corrupt += corrupt
            self.injected_drop += drop
            self.injected_delay += delay
            return corrupt, drop, delay

    def _flip(self, data: bytes, call: int) -> bytes:
        if not data:
            return data
        pos = int(self._u("pos", call) * len(data)) % len(data)
        b = bytearray(data)
        b[pos] ^= 1 << (call % 8)
        return bytes(b)

    # -- the shimmed surface -------------------------------------------------
    def sendall(self, data: bytes):
        corrupt, drop, delay = self._draw()
        if delay:
            time.sleep(self.plan.delay_s)
        if drop:
            self._sock.close()
            raise ConnectionResetError(errno.ECONNRESET,
                                       "injected wire drop (send)")
        if corrupt:
            data = self._flip(data, self._calls)
        return self._sock.sendall(data)

    def recv(self, n: int) -> bytes:
        corrupt, drop, delay = self._draw()
        if delay:
            time.sleep(self.plan.delay_s)
        if drop:
            self._sock.close()
            raise ConnectionResetError(errno.ECONNRESET,
                                       "injected wire drop (recv)")
        data = self._sock.recv(n)
        if corrupt and data:
            data = self._flip(data, self._calls)
        return data

    def __getattr__(self, name):
        return getattr(self._sock, name)
