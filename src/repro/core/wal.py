"""Write-ahead journal for dynamic index mutation (the write-path twin
of the read-side fault-tolerance layer).

``DynamicHostIndex.insert`` appends a node chunk and patches up to R
reverse-edge chunks with in-place ``pwrite``s — a crash anywhere in that
sequence used to leave neighbors pointing at a node whose PQ code only
ever lived in RAM, a silently corrupt graph the CRC layer happily
verifies.  The journal closes that hole with the classic WAL discipline:

  * before ANY byte of ``chunks.bin`` changes, an ``INSERT_BEGIN`` frame
    records the intent — new id + label, the PQ code, the chosen
    neighbors, the file size, and the PRE-IMAGES of every reverse-edge
    chunk the insert will patch — and is fsynced,
  * after the chunk writes land (and ``chunks.bin`` is fdatasynced), an
    ``INSERT_COMMIT`` frame marks the insert durable,
  * deletes journal a ``DELETE`` frame before the tombstone enters RAM,
  * a successful ``flush()`` persists everything to the main files and
    truncates the journal to empty (the checkpoint).

Recovery (``DynamicHostIndex.load``) scans the journal, truncates it at
the first torn frame, rolls the uncommitted tail insert BACK from its
pre-images (restoring the file size), rolls committed-but-unflushed
inserts FORWARD (re-deriving ``meta["n"]``, pending codes, labels),
re-applies journaled deletes, and re-anchors the CRC sidecar — every
crash point lands on a bit-consistent index equal to a pre- or
post-insert oracle state.

Frame format (all little-endian)::

  magic   u32   0x314C4157 ("WAL1")
  type    u8    record type
  hlen    u32   JSON header length
  blen    u32   binary blob length
  header  bytes (JSON, UTF-8)
  blob    bytes (pre-images / codes, raw)
  crc     u32   CRC32 over type|hlen|blen|header|blob

A frame whose magic, bounds, or CRC fails validation ends the scan —
everything after it is a torn tail and is truncated.  Frames are
self-delimiting, so the journal needs no index and no compaction beyond
the flush-time truncate.

Crash injection: pass a ``core.faults.KillSwitch`` and every append
ticks before the frame, mid-frame (the torn-write state), and after —
the kill-at-every-offset drill enumerates exactly these points.
"""
from __future__ import annotations

import json
import os
import struct
import zlib
from typing import List, Optional, Tuple

# record types
T_INSERT_BEGIN = 1
T_INSERT_COMMIT = 2
T_DELETE = 3

_MAGIC = 0x314C4157                       # "WAL1"
_HDR = struct.Struct("<IBII")             # magic, type, hlen, blen
_CRC = struct.Struct("<I")

WAL_NAME = "wal.log"


class WalRecord:
    __slots__ = ("rtype", "header", "blob", "offset")

    def __init__(self, rtype: int, header: dict, blob: bytes, offset: int):
        self.rtype = rtype
        self.header = header
        self.blob = blob
        self.offset = offset


def _frame(rtype: int, header: dict, blob: bytes) -> bytes:
    hj = json.dumps(header, separators=(",", ":")).encode()
    body = _HDR.pack(_MAGIC, rtype, len(hj), len(blob)) + hj + blob
    crc = zlib.crc32(body[4:]) & 0xFFFFFFFF   # over type|lens|header|blob
    return body + _CRC.pack(crc)


class WriteAheadLog:
    """CRC-framed, fsync'd journal over one file.  Single-writer: the
    owning ``DynamicHostIndex`` serializes appends; ``scan`` is safe on
    any byte prefix of a valid journal (that is the recovery contract).

    ``sync=False`` skips the per-append fdatasync (the ingest-throughput
    knob): a crash may then lose the *latest* journaled-but-unsynced
    mutations, but recovery still lands on a consistent earlier state —
    durability weakens, consistency does not."""

    def __init__(self, path: str, *, kill=None, sync: bool = True):
        self.path = path
        self.kill = kill          # Optional[core.faults.KillSwitch]
        self.sync = sync
        self.fd = os.open(path, os.O_RDWR | os.O_CREAT, 0o644)
        self.appended = 0

    # -- crash injection -----------------------------------------------------
    def _tick(self, label: str):
        if self.kill is not None:
            self.kill.tick(label)

    # -- append --------------------------------------------------------------
    def append(self, rtype: int, header: dict, blob: bytes = b"") -> int:
        """Append one frame at the end; returns its start offset.  With a
        KillSwitch attached the frame is written in two halves with a
        tick between them, so the enumeration drill visits the torn-frame
        state of every record."""
        frame = _frame(rtype, header, blob)
        off = os.lseek(self.fd, 0, os.SEEK_END)
        self._tick(f"wal.pre.{rtype}")
        if self.kill is not None:
            half = len(frame) // 2
            os.pwrite(self.fd, frame[:half], off)
            self._tick(f"wal.mid.{rtype}")
            os.pwrite(self.fd, frame[half:], off + half)
        else:
            os.pwrite(self.fd, frame, off)
        self._tick(f"wal.post.{rtype}")
        if self.sync:
            os.fdatasync(self.fd)
        self.appended += 1
        return off

    def fsync(self):
        os.fdatasync(self.fd)

    # -- scan / recovery -----------------------------------------------------
    def scan(self) -> Tuple[List[WalRecord], int, bool]:
        """Parse the journal from byte 0.  Returns (records, valid_end,
        torn): ``valid_end`` is the offset just past the last whole valid
        frame; ``torn`` is True when trailing bytes past it exist (a
        partially written frame, or garbage)."""
        size = os.fstat(self.fd).st_size
        buf = os.pread(self.fd, size, 0)
        records: List[WalRecord] = []
        pos = 0
        while pos + _HDR.size + _CRC.size <= len(buf):
            magic, rtype, hlen, blen = _HDR.unpack_from(buf, pos)
            if magic != _MAGIC:
                break
            end = pos + _HDR.size + hlen + blen + _CRC.size
            if hlen > len(buf) or blen > len(buf) or end > len(buf):
                break                       # torn tail frame
            body = buf[pos + 4:end - _CRC.size]
            (crc,) = _CRC.unpack_from(buf, end - _CRC.size)
            if zlib.crc32(body) & 0xFFFFFFFF != crc:
                break                       # bit-rot or torn write
            try:
                header = json.loads(
                    buf[pos + _HDR.size:pos + _HDR.size + hlen])
            except ValueError:
                break
            blob = buf[pos + _HDR.size + hlen:end - _CRC.size]
            records.append(WalRecord(rtype, header, blob, pos))
            pos = end
        return records, pos, pos != len(buf)

    def truncate(self, size: int = 0):
        """Cut the journal at ``size`` (0 = the flush-time checkpoint)
        and make the cut durable."""
        os.ftruncate(self.fd, size)
        os.fdatasync(self.fd)

    @property
    def size(self) -> int:
        return os.fstat(self.fd).st_size

    def close(self):
        if self.fd >= 0:
            os.close(self.fd)
            self.fd = -1
