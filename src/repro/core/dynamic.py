"""Dynamic index maintenance: in-place insertion + tombstone deletion.

The paper's conclusion: "[near-zero load time] will enable LLMs with RAG to
employ more simple index addition or filter search algorithms." This module
implements exactly that enablement on the host backend:

  * insert(vec): FreshDiskANN-style — greedy-search for neighbor candidates,
    RobustPrune, APPEND a new node chunk to chunks.bin, patch the reverse
    edges' chunks in place (pwrite). AiSAQ's inline codes mean patching a
    neighbor's chunk also writes the new node's PQ code into it — the
    placement invariant is preserved under mutation.
  * delete(id): tombstone — removed from results and from future traversal
    expansion targets; space reclaimed offline (compaction is a rebuild).
  * filtered search: per-query predicate over node ids (label bitmap) —
    candidates failing the filter still ROUTE (graph stays navigable) but
    never enter the re-rank pool.
"""
from __future__ import annotations

import json
import os
from typing import Callable, Optional, Set

import numpy as np

from repro.core.adc import np_adc, np_build_lut  # noqa: F401  (public
# surface of this module since the monolith era; kept through the split)
from repro.core.chunk_layout import B_NUM
from repro.core.index_io import HostIndex
from repro.core.traversal import SearchStats  # noqa: F401


class DynamicHostIndex(HostIndex):
    """HostIndex + insert/delete/filtered-search (aisaq mode)."""

    @classmethod
    def load(cls, path: str, **kw) -> "DynamicHostIndex":
        self = super().load(path, **kw)  # type: ignore[misc]
        assert self.meta["mode"] == "aisaq", "dynamic ops need inline codes"
        assert self.new_to_old is None, \
            "dynamic ops need original-id layout (rebuild without relabel)"
        os.close(self.fd)
        self.fd = os.open(os.path.join(path, "chunks.bin"), os.O_RDWR)
        if self.cache is not None:
            self.cache.fd = self.fd      # cache must read via the new fd
        # lazy (mmap) code table for build-time neighbor-code fetches; new
        # codes accumulate in RAM until flush()
        self._codes_mm = np.load(os.path.join(path, "pq_codes.npy"),
                                 mmap_mode="r")
        self._new_codes: list = []
        self.n = self.meta["n"]
        tomb = os.path.join(path, "tombstones.json")
        self.tombstones: Set[int] = set(
            json.load(open(tomb))) if os.path.exists(tomb) else set()
        return self

    # -- helpers -------------------------------------------------------------
    def _code_of(self, node: int) -> np.ndarray:
        base = self._codes_mm.shape[0]
        if node < base:
            return np.asarray(self._codes_mm[node])
        return self._new_codes[node - base]

    def _encode(self, vec: np.ndarray) -> np.ndarray:
        c = self.centroids                      # (m, ks, dsub)
        m, ks, dsub = c.shape
        sub = vec.astype(np.float32).reshape(m, 1, dsub)
        d = ((c - sub) ** 2).sum(-1)            # (m, ks)
        return d.argmin(-1).astype(np.uint8)

    def _read_node(self, node: int):
        from repro.core.chunk_layout import parse_chunk
        lay = self.layout
        raw = os.pread(self.fd, lay.chunk_bytes, lay.file_offset(node))
        return parse_chunk(np.frombuffer(raw, np.uint8), lay)

    def _write_node(self, node: int, vec, nbr_ids: np.ndarray,
                    nbr_codes: np.ndarray):
        lay = self.layout
        chunk = np.zeros(lay.chunk_bytes, np.uint8)
        vb = vec.astype(np.uint8) if lay.data_dtype == "uint8" else \
            vec.astype(np.float32).view(np.uint8)
        chunk[:lay.b_full] = vb
        ids = np.full(lay.R, -1, np.int32)
        ids[:len(nbr_ids)] = nbr_ids
        deg = np.int32(len(nbr_ids))
        chunk[lay.off_deg:lay.off_deg + B_NUM] = \
            deg.reshape(1).view(np.uint8)
        chunk[lay.off_ids:lay.off_ids + lay.R * B_NUM] = ids.view(np.uint8)
        pq_block = np.zeros((lay.R, lay.pq_m), np.uint8)
        pq_block[:len(nbr_ids)] = nbr_codes
        chunk[lay.off_pq:lay.off_pq + lay.R * lay.pq_m] = pq_block.reshape(-1)
        off = lay.file_offset(node)
        # extend the file to a whole block if the node opens a new one
        end = off - off % lay.block_bytes + lay.io_bytes
        cur = os.fstat(self.fd).st_size
        if end > cur:
            os.pwrite(self.fd, b"\0" * (end - cur), cur)
        os.pwrite(self.fd, chunk.tobytes(), off)
        if self.cache is not None:       # in-place write: drop stale blocks
            self.cache.invalidate(off, lay.chunk_bytes)
            # re-anchor the checksum sidecar to the new on-storage bytes
            # (grows it when the append opened a new block) so verified
            # reads keep passing under mutation
            self.cache.refresh_crc(off, lay.chunk_bytes)

    def _dist(self, a: np.ndarray, b: np.ndarray) -> float:
        a, b = a.astype(np.float32), b.astype(np.float32)
        if self.meta["metric"] == "mips":
            return float(-(a @ b))
        return float(((a - b) ** 2).sum())

    # -- insertion -------------------------------------------------------------
    def insert(self, vec: np.ndarray, *, L: int = 48, alpha: float = 1.2
               ) -> int:
        """Add one vector; returns its node id. O(search + R chunk writes)."""
        new_id = self.n
        code = self._encode(vec)
        # candidate pool: the expanded set of a search for `vec`
        _, stats = self.search(vec.astype(np.float32), k=1, L=L)
        cand_ids, cand_vecs = [], []
        # re-walk: collect expanded nodes + their vectors via chunk reads
        ids, _ = self.search(vec.astype(np.float32), k=min(L, 16), L=L)
        pool = list(dict.fromkeys(int(i) for i in ids))
        extra = []
        for p in pool:
            _, nbrs, _ = self._read_node(p)
            extra += [int(x) for x in nbrs[nbrs >= 0]]
        pool = list(dict.fromkeys(pool + extra))[:4 * self.layout.R]
        pool = [p for p in pool if p not in self.tombstones]
        vecs = {p: self._read_node(p)[0] for p in pool}
        # RobustPrune over the pool
        dists = sorted(pool, key=lambda p: self._dist(vec, vecs[p]))
        chosen: list = []
        alive = dict.fromkeys(dists, True)
        for p in dists:
            if len(chosen) >= self.layout.R:
                break
            if not alive[p]:
                continue
            chosen.append(p)
            for q in dists:
                if alive[q] and q != p and \
                        alpha * self._dist(vecs[p], vecs[q]) <= \
                        self._dist(vec, vecs[q]):
                    alive[q] = False
        nbr_codes = np.stack([self._code_of(p) for p in chosen]) if chosen \
            else np.zeros((0, self.layout.pq_m), np.uint8)
        self._write_node(new_id, vec, np.asarray(chosen, np.int32), nbr_codes)
        self._new_codes.append(code)
        self.n += 1
        self.meta["n"] = self.n
        # reverse edges: patch each chosen neighbor's chunk in place
        for p in chosen:
            pvec, pids, pcodes = self._read_node(p)
            valid = pids[pids >= 0]
            if new_id in valid:
                continue
            if len(valid) < self.layout.R:
                ids2 = np.concatenate([valid, [new_id]]).astype(np.int32)
                codes2 = np.concatenate(
                    [pcodes[:len(valid)], code[None]], axis=0)
            else:
                # over-degree: RobustPrune p's neighborhood ∪ {new}
                npool = [int(x) for x in valid] + [new_id]
                nvecs = {new_id: vec}
                for q in valid:
                    nvecs[int(q)] = self._read_node(int(q))[0]
                order = sorted(npool, key=lambda q: self._dist(pvec, nvecs[q]))
                keep: list = []
                alive2 = dict.fromkeys(order, True)
                for q in order:
                    if len(keep) >= self.layout.R:
                        break
                    if not alive2[q]:
                        continue
                    keep.append(q)
                    for r in order:
                        if alive2[r] and r != q and \
                                alpha * self._dist(nvecs[q], nvecs[r]) <= \
                                self._dist(pvec, nvecs[r]):
                            alive2[r] = False
                ids2 = np.asarray(keep, np.int32)
                codes2 = np.stack([self._code_of(q) for q in keep])
            self._write_node(p, pvec, ids2, codes2)
        return new_id

    # -- deletion --------------------------------------------------------------
    def delete(self, node: int):
        self.tombstones.add(int(node))

    def flush(self):
        """Persist appended codes + tombstones + meta."""
        if self._new_codes:
            codes = np.concatenate(
                [np.asarray(self._codes_mm),
                 np.stack(self._new_codes)], axis=0)
            np.save(os.path.join(self.path, "pq_codes.npy"), codes)
            self._codes_mm = np.load(os.path.join(self.path, "pq_codes.npy"),
                                     mmap_mode="r")
            self._new_codes = []
        with open(os.path.join(self.path, "tombstones.json"), "w") as f:
            json.dump(sorted(self.tombstones), f)
        if self.cache is not None and self.cache.block_crc is not None:
            # persist the mutation-refreshed checksums so a reload of the
            # grown chunks.bin verifies cleanly
            from repro.core.integrity import CRC_SIDECAR
            np.save(os.path.join(self.path, CRC_SIDECAR),
                    self.cache.block_crc)
        with open(os.path.join(self.path, "meta.json"), "w") as f:
            json.dump(self.meta, f, indent=1)

    # -- filtered + tombstone-aware search --------------------------------------
    def search(self, q, k, L, w=4,
               predicate: Optional[Callable[[int], bool]] = None):
        ids, stats = super().search(q, k, L, w)
        drop = self.tombstones
        ok = [i for i in ids if int(i) >= 0 and int(i) not in drop
              and (predicate is None or predicate(int(i)))]
        if len(ok) < k and (drop or predicate is not None):
            # widen once: tombstones/filters thin the pool
            ids2, s2 = super().search(q, k * 4, max(L, 2 * k * 4), w)
            stats.ios += s2.ios
            stats.bytes_read += s2.bytes_read
            ok = [i for i in ids2 if int(i) >= 0 and int(i) not in drop
                  and (predicate is None or predicate(int(i)))]
        return np.asarray(ok[:k], np.int64), stats
