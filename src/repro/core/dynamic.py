"""Dynamic index maintenance: crash-safe in-place insertion, tombstone
deletion, and background compaction.

The paper's conclusion: "[near-zero load time] will enable LLMs with RAG to
employ more simple index addition or filter search algorithms." This module
implements exactly that enablement on the host backend:

  * insert(vec): FreshDiskANN-style — greedy-search for neighbor candidates,
    RobustPrune, APPEND a new node chunk to chunks.bin, patch the reverse
    edges' chunks in place (pwrite). AiSAQ's inline codes mean patching a
    neighbor's chunk also writes the new node's PQ code into it — the
    placement invariant is preserved under mutation.
  * delete(label): tombstone — removed from results and from future
    traversal expansion targets; space reclaimed by ``compact``.
  * filtered search: per-query predicate over result labels — candidates
    failing the filter still ROUTE (graph stays navigable) but never enter
    the re-rank pool.
  * compact(dst): re-pack the live nodes (tombstone reclaim + optional
    graph-locality relabel) into a sibling version directory published
    with ``write_index``'s atomic recipe — the input to
    ``WarmIndexPool.swap``'s zero-downtime version switch.

Crash-safety (the write-path twin of the PR-6 read-path layer): every
mutation is journaled in ``core.wal`` BEFORE it touches ``chunks.bin`` —
an insert's intent record carries the new id, its code, the chosen
neighbors, and the PRE-IMAGES of every reverse-edge chunk it will patch;
a commit record lands only after the data writes are fdatasynced.
``load`` recovers: the journal is scanned (truncated at the first torn
frame), the uncommitted tail insert is rolled back from its pre-images,
committed-but-unflushed inserts are rolled forward (``meta["n"]``,
pending codes, and labels re-derived), journaled deletes re-applied, the
CRC sidecar re-anchored, and a full durable flush checkpoints the result
and empties the journal.  Every crash point lands on a state equal to a
pre- or post-insert oracle — ``benchmarks/bench_ingest.py`` proves it by
killing the writer at every journal offset.

Concurrency: one writer (``insert``/``delete``/``flush``/``compact`` are
serialized by an internal mutex) and any number of searching readers.  A
writer-priority RW lock makes each chunk write atomic with respect to
in-process readers (no torn chunk is ever observed), and the traversal
engine clamps neighbor ids to its ``meta["n"]`` snapshot, so an edge
patched toward a node a search has not yet admitted is simply invisible
to it — searches always see a consistent pre- or post-insert graph.

Label discipline: a relabeled (graph-locality packed) directory stores
nodes in NEW-id space with an external-label map.  Insertion appends the
new node at the tail (page-locality order: fresh nodes share fresh
blocks) and extends the label map; ``compact`` re-packs with explicit
labels (``write_index(labels=...)``) so external labels survive
tombstone reclaim and re-relabeling.
"""
from __future__ import annotations

import json
import os
import threading
from contextlib import contextmanager
from typing import Callable, Dict, List, Optional, Set

import numpy as np

from repro.core.adc import np_adc, np_build_lut  # noqa: F401  (public
# surface of this module since the monolith era; kept through the split)
from repro.core.chunk_layout import B_NUM
from repro.core.index_io import (HostIndex, _atomic_json, _atomic_npy,
                                 write_index)
from repro.core.integrity import CRC_SIDECAR, resolve_crc
from repro.core.traversal import SearchStats  # noqa: F401
from repro.core import wal as _wal

__all__ = ["DynamicHostIndex", "DynamicIndexError"]


class DynamicIndexError(RuntimeError):
    """A directory or argument unusable for dynamic (mutating) operation.
    Typed — never ``assert`` — so the refusal survives ``python -O``."""


class _RWLock:
    """Writer-priority readers-writer lock.

    Readers (searches) hold it across a whole traversal; the writer holds
    it per chunk write, so a reader can never observe a torn chunk.
    Writer priority keeps a stream of searches from starving the ingest
    path."""

    def __init__(self):
        self._cond = threading.Condition()
        self._readers = 0
        self._writer = False
        self._writers_waiting = 0

    @contextmanager
    def read(self):
        with self._cond:
            while self._writer or self._writers_waiting:
                self._cond.wait()
            self._readers += 1
        try:
            yield
        finally:
            with self._cond:
                self._readers -= 1
                if not self._readers:
                    self._cond.notify_all()

    @contextmanager
    def write(self):
        with self._cond:
            self._writers_waiting += 1
            while self._writer or self._readers:
                self._cond.wait()
            self._writers_waiting -= 1
            self._writer = True
        try:
            yield
        finally:
            with self._cond:
                self._writer = False
                self._cond.notify_all()


class DynamicHostIndex(HostIndex):
    """HostIndex + journaled insert/delete/compaction (aisaq mode)."""

    #: HostIndex.load refuses dirs with a pending journal; THIS loader is
    #: the one that knows how to recover them.
    _allows_wal = True

    @classmethod
    def load(cls, path: str, *, kill=None, wal_sync: bool = True,
             **kw) -> "DynamicHostIndex":
        """Open for mutation.  Runs journal recovery if a previous writer
        crashed (see module docstring); the outcome lands in
        ``self.recovery`` (a stats dict; ``journaled == 0`` means clean).

        ``kill`` attaches a ``core.faults.KillSwitch`` to every subsequent
        durability-relevant write step (crash drills) — recovery itself
        always runs un-instrumented.  ``wal_sync=False`` skips the
        per-record journal fdatasync (throughput knob: consistency is
        kept, the latest unsynced mutations may be lost on crash)."""
        self = super().load(path, **kw)  # type: ignore[misc]
        if self.meta["mode"] != "aisaq":
            self.close()
            raise DynamicIndexError(
                f"{path!r} is mode={self.meta['mode']!r}: dynamic ops need "
                "inline neighbor codes (aisaq mode) so reverse-edge "
                "patches can carry the new node's code")
        os.close(self.fd)
        self.fd = os.open(os.path.join(path, "chunks.bin"), os.O_RDWR)
        if self.cache is not None:
            self.cache.fd = self.fd      # cache must read via the new fd
        # lazy (mmap) code table for build-time neighbor-code fetches; new
        # codes accumulate in RAM until flush()
        self._codes_mm = np.load(os.path.join(path, "pq_codes.npy"),
                                 mmap_mode="r")
        self._new_codes: List[np.ndarray] = []
        self.n = int(self.meta["n"])
        tomb = os.path.join(path, "tombstones.json")
        self.tombstones: Set[int] = set(
            json.load(open(tomb))) if os.path.exists(tomb) else set()
        if "next_label" in self.meta:
            self._next_label = int(self.meta["next_label"])
        elif self.new_to_old is None:
            self._next_label = self.n            # labels ARE ids
        else:
            self._next_label = int(self.new_to_old.max()) + 1 \
                if len(self.new_to_old) else 0
        self._label_to_int: Optional[Dict[int, int]] = None  # built lazily
        self._rw = _RWLock()
        self._mut = threading.Lock()      # serializes the mutation API
        self.kill = None                  # armed AFTER recovery
        self.wal = _wal.WriteAheadLog(
            os.path.join(path, _wal.WAL_NAME), sync=wal_sync)
        self.recovery = self._recover()
        self.wal.kill = kill
        self.kill = kill
        return self

    def _load_crc_sidecar(self, path, verify):
        """Sidecar load tolerant of a pending journal: recovery may have
        been interrupted after truncating chunks.bin but before rewriting
        the sidecar, so 'sidecar longer than the file' is a RECOVERABLE
        state here (the base loader treats it as a truncated chunks.bin
        and refuses).  Recovery re-anchors every touched block before any
        search runs."""
        spath = os.path.join(path, CRC_SIDECAR)
        wpath = os.path.join(path, _wal.WAL_NAME)
        if verify is not False and os.path.exists(spath) \
                and os.path.exists(wpath) and os.path.getsize(wpath):
            block_crc = np.load(spath).astype(np.uint32)
            nblk = os.fstat(self.fd).st_size // self.layout.io_bytes
            return block_crc[:nblk], \
                resolve_crc(self.meta.get("crc_algo", "crc32"))
        return super()._load_crc_sidecar(path, verify)

    # -- crash injection ----------------------------------------------------
    def _tick(self, label: str):
        if self.kill is not None:
            self.kill.tick(label)

    # -- label mapping -------------------------------------------------------
    def _label_of(self, node: int) -> int:
        return int(node) if self.new_to_old is None \
            else int(self.new_to_old[node])

    def _to_internal(self, label: int) -> int:
        if self.new_to_old is None:
            return int(label)
        if self._label_to_int is None:
            self._label_to_int = {
                int(l): i for i, l in enumerate(self.new_to_old)}
        return self._label_to_int[int(label)]

    # -- helpers -------------------------------------------------------------
    def _code_of(self, node: int) -> np.ndarray:
        base = self._codes_mm.shape[0]
        if node < base:
            return np.asarray(self._codes_mm[node])
        return self._new_codes[node - base]

    def _encode(self, vec: np.ndarray) -> np.ndarray:
        c = self.centroids                      # (m, ks, dsub)
        m, ks, dsub = c.shape
        sub = vec.astype(np.float32).reshape(m, 1, dsub)
        d = ((c - sub) ** 2).sum(-1)            # (m, ks)
        return d.argmin(-1).astype(np.uint8)

    def _read_node(self, node: int):
        from repro.core.chunk_layout import parse_chunk
        lay = self.layout
        raw = os.pread(self.fd, lay.chunk_bytes, lay.file_offset(node))
        return parse_chunk(np.frombuffer(raw, np.uint8), lay)

    def _write_node(self, node: int, vec, nbr_ids: np.ndarray,
                    nbr_codes: np.ndarray):
        lay = self.layout
        chunk = np.zeros(lay.chunk_bytes, np.uint8)
        vb = vec.astype(np.uint8) if lay.data_dtype == "uint8" else \
            vec.astype(np.float32).view(np.uint8)
        chunk[:lay.b_full] = vb
        ids = np.full(lay.R, -1, np.int32)
        ids[:len(nbr_ids)] = nbr_ids
        deg = np.int32(len(nbr_ids))
        chunk[lay.off_deg:lay.off_deg + B_NUM] = \
            deg.reshape(1).view(np.uint8)
        chunk[lay.off_ids:lay.off_ids + lay.R * B_NUM] = ids.view(np.uint8)
        pq_block = np.zeros((lay.R, lay.pq_m), np.uint8)
        pq_block[:len(nbr_ids)] = nbr_codes
        chunk[lay.off_pq:lay.off_pq + lay.R * lay.pq_m] = pq_block.reshape(-1)
        off = lay.file_offset(node)
        payload = chunk.tobytes()
        # the write lock makes the chunk write atomic w.r.t. in-process
        # readers: a search can observe the chunk before or after the
        # patch, never mid-pwrite (and never a half-refreshed sidecar)
        with self._rw.write():
            # extend the file to a whole block if the node opens a new one
            end = off - off % lay.block_bytes + lay.io_bytes
            cur = os.fstat(self.fd).st_size
            if end > cur:
                os.pwrite(self.fd, b"\0" * (end - cur), cur)
            self._tick(f"chunk.pre.{node}")
            if self.kill is not None:
                # two-half write: the drill visits the torn-chunk state
                half = len(payload) // 2
                os.pwrite(self.fd, payload[:half], off)
                self._tick(f"chunk.mid.{node}")
                os.pwrite(self.fd, payload[half:], off + half)
            else:
                os.pwrite(self.fd, payload, off)
            self._tick(f"chunk.post.{node}")
            if self.cache is not None:   # in-place write: drop stale blocks
                self.cache.invalidate(off, lay.chunk_bytes)
                # re-anchor the checksum sidecar to the new on-storage
                # bytes (grows it when the append opened a new block) so
                # verified reads keep passing under mutation
                self.cache.refresh_crc(off, lay.chunk_bytes)

    def _dist(self, a: np.ndarray, b: np.ndarray) -> float:
        a, b = a.astype(np.float32), b.astype(np.float32)
        if self.meta["metric"] == "mips":
            return float(-(a @ b))
        return float(((a - b) ** 2).sum())

    # -- insertion -----------------------------------------------------------
    def insert(self, vec: np.ndarray, *, L: int = 48, alpha: float = 1.2
               ) -> int:
        """Add one vector; returns its LABEL (== node id on an unmapped
        dir).  O(search + R chunk writes), journaled: a crash at any point
        either rolls the insert back completely or (after the commit
        record) preserves it completely."""
        with self._mut:
            return self._insert_locked(np.asarray(vec), L, alpha)

    def _insert_locked(self, vec: np.ndarray, L: int, alpha: float) -> int:
        lay = self.layout
        new_id = self.n
        label = self._next_label
        code = self._encode(vec)
        # candidate pool: the expanded set of a search for `vec` (labels
        # out -> internal ids), widened by one hop of neighbor expansion
        ids, _ = self.search(vec.astype(np.float32), k=min(L, 16), L=L)
        pool = list(dict.fromkeys(
            self._to_internal(int(i)) for i in ids))
        extra = []
        for p in pool:
            _, nbrs, _ = self._read_node(p)
            extra += [int(x) for x in nbrs[(nbrs >= 0) & (nbrs < self.n)]]
        pool = list(dict.fromkeys(pool + extra))[:4 * lay.R]
        pool = [p for p in pool
                if self._label_of(p) not in self.tombstones]
        vecs = {p: self._read_node(p)[0] for p in pool}
        # RobustPrune over the pool
        dists = sorted(pool, key=lambda p: self._dist(vec, vecs[p]))
        chosen: list = []
        alive = dict.fromkeys(dists, True)
        for p in dists:
            if len(chosen) >= lay.R:
                break
            if not alive[p]:
                continue
            chosen.append(p)
            for q in dists:
                if alive[q] and q != p and \
                        alpha * self._dist(vecs[p], vecs[q]) <= \
                        self._dist(vec, vecs[q]):
                    alive[q] = False
        nbr_codes = np.stack([self._code_of(p) for p in chosen]) if chosen \
            else np.zeros((0, lay.pq_m), np.uint8)
        # ---- journal the intent BEFORE any byte of chunks.bin changes ----
        # pre-images cover every chunk the reverse-edge pass MAY patch
        # (the chosen set); rollback restores them and the file size
        file_end = os.fstat(self.fd).st_size
        pre = b"".join(os.pread(self.fd, lay.chunk_bytes,
                                lay.file_offset(p)) for p in chosen)
        self.wal.append(_wal.T_INSERT_BEGIN, dict(
            id=new_id, label=label, n_before=self.n, file_end=file_end,
            chunk_bytes=lay.chunk_bytes,
            chosen=[int(p) for p in chosen]), code.tobytes() + pre)
        # ---- data writes ----
        self._write_node(new_id, vec, np.asarray(chosen, np.int32),
                         nbr_codes)
        self._new_codes.append(code)
        if self.new_to_old is not None:
            if self._label_to_int is not None:
                self._label_to_int[label] = new_id
            self.new_to_old = np.append(self.new_to_old, label)
        self._next_label = label + 1
        self.n += 1
        self.meta["n"] = self.n
        # reverse edges: patch each chosen neighbor's chunk in place
        for p in chosen:
            pvec, pids, pcodes = self._read_node(p)
            valid = pids[(pids >= 0) & (pids < new_id)]
            if len(valid) < lay.R:
                ids2 = np.concatenate([valid, [new_id]]).astype(np.int32)
                codes2 = np.concatenate(
                    [pcodes[:len(valid)], code[None]], axis=0)
            else:
                # over-degree: RobustPrune p's neighborhood ∪ {new}
                npool = [int(x) for x in valid] + [new_id]
                nvecs = {new_id: vec}
                for q in valid:
                    nvecs[int(q)] = self._read_node(int(q))[0]
                order = sorted(npool, key=lambda q: self._dist(pvec, nvecs[q]))
                keep: list = []
                alive2 = dict.fromkeys(order, True)
                for q in order:
                    if len(keep) >= lay.R:
                        break
                    if not alive2[q]:
                        continue
                    keep.append(q)
                    for r in order:
                        if alive2[r] and r != q and \
                                alpha * self._dist(nvecs[q], nvecs[r]) <= \
                                self._dist(pvec, nvecs[r]):
                            alive2[r] = False
                ids2 = np.asarray(keep, np.int32)
                codes2 = np.stack([self._code_of(q) for q in keep])
            self._write_node(p, pvec, ids2, codes2)
        # ---- durability point: data synced, then the commit record ----
        self._tick("data.sync")
        os.fdatasync(self.fd)
        self.wal.append(_wal.T_INSERT_COMMIT, dict(id=new_id, label=label))
        return label

    # -- deletion ------------------------------------------------------------
    def delete(self, node: int):
        """Tombstone one LABEL.  Journaled: the delete survives a crash
        without waiting for a flush."""
        with self._mut:
            self.wal.append(_wal.T_DELETE, dict(label=int(node)))
            self.tombstones.add(int(node))

    # -- flush (the journal checkpoint) --------------------------------------
    def flush(self):
        """Persist appended codes + labels + tombstones + sidecar + meta,
        then truncate the journal.  Every file is rewritten atomically
        (tmp sibling + fsync + rename): a crash mid-flush leaves a
        loadable directory plus a journal that re-derives whatever the
        flush had not yet persisted."""
        with self._mut:
            self._flush_locked()

    def _flush_locked(self):
        self._tick("flush.codes")
        if self._new_codes:
            codes = np.concatenate(
                [np.asarray(self._codes_mm),
                 np.stack(self._new_codes)], axis=0)
            _atomic_npy(os.path.join(self.path, "pq_codes.npy"),
                        codes.astype(np.uint8))
            self._codes_mm = np.load(os.path.join(self.path, "pq_codes.npy"),
                                     mmap_mode="r")
            self._new_codes = []
        self._tick("flush.labels")
        if self.new_to_old is not None:
            # insertion extends the map beyond a permutation of range(n):
            # persist it directly (labels.npy supersedes the id_map branch)
            _atomic_npy(os.path.join(self.path, "labels.npy"),
                        np.asarray(self.new_to_old, np.int64))
            self.meta["label_map"] = "direct"
        self._tick("flush.tombstones")
        _atomic_json(os.path.join(self.path, "tombstones.json"),
                     sorted(self.tombstones))
        self._tick("flush.crc")
        if self.cache is not None and self.cache.block_crc is not None:
            # persist the mutation-refreshed checksums so a reload of the
            # grown chunks.bin verifies cleanly
            _atomic_npy(os.path.join(self.path, CRC_SIDECAR),
                        self.cache.block_crc)
        self._tick("flush.meta")
        self.meta["next_label"] = self._next_label
        _atomic_json(os.path.join(self.path, "meta.json"), self.meta)
        self._tick("flush.wal")
        self.wal.truncate(0)

    # -- journal recovery ----------------------------------------------------
    def _recover(self) -> dict:
        """Reconcile the directory with its journal (load time).  Safe to
        crash at any point DURING recovery too: every step is idempotent
        and the journal is only truncated after the checkpoint flush."""
        records, valid_end, torn = self.wal.scan()
        stats = dict(journaled=len(records), torn=bool(torn),
                     truncated_bytes=0,
                     rolled_back=0, rolled_forward=0, deletes=0)
        if torn:
            # bytes of torn tail dropped from the journal — serving
            # telemetry (WarmIndexPool.stats()["recoveries"]) surfaces
            # this so operators see how much of a crash was unwound
            stats["truncated_bytes"] = max(0, self.wal.size - valid_end)
            self.wal.truncate(valid_end)
        if not records:
            return stats
        lay = self.layout
        committed = {r.header["id"] for r in records
                     if r.rtype == _wal.T_INSERT_COMMIT}
        begins = [r for r in records if r.rtype == _wal.T_INSERT_BEGIN]
        touched: Set[int] = set()        # node ids needing a CRC re-anchor
        # 1. roll the uncommitted tail back from its pre-images (newest
        # first: a later insert's pre-images embed earlier inserts' edges)
        for r in reversed(begins):
            h = r.header
            if h["id"] in committed:
                continue
            cb = int(h["chunk_bytes"])
            pre = r.blob[lay.pq_m:]
            for j, p in enumerate(h["chosen"]):
                img = pre[j * cb:(j + 1) * cb]
                if len(img) == cb:
                    os.pwrite(self.fd, img, lay.file_offset(p))
                    touched.add(int(p))
            os.ftruncate(self.fd, int(h["file_end"]))
            # the aborted node's chunk may live in a block the file
            # ALREADY covered (file_size is whole blocks): truncation
            # leaves its half-written bytes behind, disagreeing with the
            # flushed sidecar — zero the region and re-anchor it
            noff = lay.file_offset(int(h["id"]))
            if noff + cb <= int(h["file_end"]):
                os.pwrite(self.fd, b"\0" * cb, noff)
                touched.add(int(h["id"]))
            stats["rolled_back"] += 1
        # 2. roll committed-but-unflushed inserts forward.  Reconciliation
        # is by-id so a partially completed flush (codes persisted, meta
        # not, or vice versa) replays as a set of no-ops:
        #   code pending  iff id >= rows(pq_codes.npy) + already-pending
        #   label pending iff id >= len(label map)
        #   n             = max(disk n, max committed id + 1)
        base = self._codes_mm.shape[0]
        for r in begins:
            h = r.header
            if h["id"] not in committed:
                continue
            nid = int(h["id"])
            if nid >= base + len(self._new_codes):
                self._new_codes.append(
                    np.frombuffer(r.blob[:lay.pq_m], np.uint8).copy())
            if self.new_to_old is not None \
                    and nid >= len(self.new_to_old):
                self.new_to_old = np.append(self.new_to_old,
                                            int(h["label"]))
            self.n = max(self.n, nid + 1)
            self._next_label = max(self._next_label, int(h["label"]) + 1)
            touched.add(nid)
            touched.update(int(p) for p in h["chosen"])
            stats["rolled_forward"] += 1
        # 3. journaled deletes (set union: idempotent vs tombstones.json)
        for r in records:
            if r.rtype == _wal.T_DELETE:
                self.tombstones.add(int(r.header["label"]))
                stats["deletes"] += 1
        self.meta["n"] = self.n
        # 4. re-anchor the CRC sidecar: the on-disk sidecar describes the
        # pre-crash flush; every chunk recovery restored or rolled forward
        # gets a fresh checksum, and entries past the (possibly truncated)
        # file end are trimmed
        if self.cache is not None:
            fsize = os.fstat(self.fd).st_size
            self.cache.trim_crc(fsize // lay.io_bytes)
            for p in sorted(touched):
                off = lay.file_offset(p)
                if off < fsize:
                    self.cache.invalidate(off, lay.chunk_bytes)
                    self.cache.refresh_crc(off, lay.chunk_bytes)
        # 5. checkpoint: one durable flush, then the journal is history
        self._flush_locked()
        return stats

    # -- compaction ----------------------------------------------------------
    def compact(self, dst: str, *, relabel: bool = True) -> dict:
        """Re-pack the live (un-tombstoned) nodes into a NEW index dir at
        ``dst``: tombstone reclaim, edge remap (edges into dead nodes are
        dropped), optional graph-locality relabel, external labels
        preserved via ``write_index(labels=...)``.  The source directory
        is untouched; ``dst`` is published atomically — hand it to
        ``WarmIndexPool.swap`` for a zero-downtime version switch.
        Returns the new directory's meta dict."""
        with self._mut:
            lay = self.layout
            n = self.n
            labels = np.array([self._label_of(i) for i in range(n)],
                              np.int64)
            live = [i for i in range(n)
                    if int(labels[i]) not in self.tombstones]
            if not live:
                raise DynamicIndexError(
                    "compaction would produce an empty index "
                    "(every node is tombstoned)")
            old_to_new = {p: j for j, p in enumerate(live)}
            dt = np.uint8 if lay.data_dtype == "uint8" else np.float32
            vectors = np.empty((len(live), self.meta["dim"]), dt)
            graph = np.full((len(live), lay.R), -1, np.int32)
            codes = np.empty((len(live), lay.pq_m), np.uint8)
            for j, p in enumerate(live):
                vec, nbrs, _ = self._read_node(p)
                vectors[j] = vec
                codes[j] = self._code_of(p)
                kept = [old_to_new[int(x)] for x in nbrs
                        if 0 <= int(x) < n and int(x) in old_to_new]
                graph[j, :len(kept)] = kept
            return write_index(
                dst, vectors=vectors, graph=graph,
                centroids=self.centroids, codes=codes,
                metric=self.meta["metric"], mode=self.meta["mode"],
                block_bytes=self.meta["block_bytes"],
                n_ep=len(self.meta["entry_points"]),
                relabel=relabel, labels=labels[live],
                extra_meta=dict(next_label=self._next_label))

    # -- lifecycle -----------------------------------------------------------
    def close(self):
        super().close()
        if getattr(self, "wal", None) is not None:
            self.wal.close()

    def abandon(self):
        """Drop the handle WITHOUT flushing — the crash-drill teardown
        (and the honest way to model a dead process: nothing in RAM
        survives, only what the journal and fdatasync made durable)."""
        if self.cache is not None:
            self.cache.stop()
            self.cache.clear()
        if self.fd >= 0:
            os.close(self.fd)
            self.fd = -1
        if getattr(self, "wal", None) is not None:
            self.wal.close()

    # -- filtered + tombstone-aware search -----------------------------------
    def search(self, q, k, L, w=4,
               predicate: Optional[Callable[[int], bool]] = None):
        # the read lock pairs with _write_node's write lock: no torn chunk
        with self._rw.read():
            ids, stats = super().search(q, k, L, w)
            drop = self.tombstones
            ok = [i for i in ids if int(i) >= 0 and int(i) not in drop
                  and (predicate is None or predicate(int(i)))]
            if len(ok) < k and (drop or predicate is not None):
                # widen once: tombstones/filters thin the pool
                ids2, s2 = super().search(q, k * 4, max(L, 2 * k * 4), w)
                stats.ios += s2.ios
                stats.bytes_read += s2.bytes_read
                ok = [i for i in ids2 if int(i) >= 0 and int(i) not in drop
                      and (predicate is None or predicate(int(i)))]
            return np.asarray(ok[:k], np.int64), stats

    def search_batch(self, Q, k, L, w=4, **kw):
        with self._rw.read():
            return super().search_batch(Q, k, L, w, **kw)

    def search_ref(self, q, k, L, w=4, **kw):
        with self._rw.read():
            return super().search_ref(q, k, L, w, **kw)
