"""Graph-locality node relabeling (PAGE / DiskANN++-style page packing).

The cold-path cost of storage-backed beam search is dominated by how many
distinct ``io_bytes`` units each hop touches: neighbor ids assigned in build
order are scattered across chunks.bin, so every frontier expansion pulls
blocks from all over the file. Relabeling assigns new node ids so that

  * graph neighbors land in the SAME block whenever ``nodes_per_block > 1``
    (greedy page packing: each block is seeded by the next BFS node and
    filled with its unassigned out-neighbors), and
  * BFS order makes ids of nodes expanded in consecutive hops *numerically
    close*, so the per-hop miss set coalesces into few contiguous preadv
    runs even when a block holds a single chunk.

The permutation is applied once at pack time (``index_io.write_index``,
``relabeled: true`` in meta.json + the old->new map in ``id_map.npy``);
search backends map result ids back to the original labels, so relabeling
is invisible to callers (groundtruth, recall, serving all keep original
ids).
"""
from __future__ import annotations

from collections import deque
from typing import Optional, Tuple

import numpy as np


def locality_permutation(graph: np.ndarray, nodes_per_block: int,
                         entry_points: Optional[np.ndarray] = None
                         ) -> np.ndarray:
    """Compute the old->new id permutation for a Vamana graph.

    graph: (n, R) int adjacency, -1 padded. nodes_per_block: chunks that
    share one I/O unit (ChunkLayout.nodes_per_block; 0 -> multi-block
    chunks, plain BFS order still helps run contiguity). Returns
    old_to_new (n,) int64 with ``old_to_new[old_id] == new_id``.
    """
    graph = np.asarray(graph)
    n = graph.shape[0]
    npb = nodes_per_block if nodes_per_block and nodes_per_block > 0 else 1
    taken = np.zeros(n, dtype=bool)
    order = np.empty(n, dtype=np.int64)        # order[new] = old
    pos = 0
    queue: deque = deque()
    if entry_points is not None:
        queue.extend(int(e) for e in np.atleast_1d(entry_points))
    scan = 0                                   # covers disconnected nodes
    while pos < n:
        while queue and taken[queue[0]]:
            queue.popleft()
        if queue:
            u = int(queue.popleft())
        else:
            while taken[scan]:
                scan += 1
            u = scan
        if taken[u]:
            continue
        taken[u] = True
        order[pos] = u
        pos += 1
        # pack u's block: local BFS from u fills the remaining slots with
        # a connected cluster (neighbors, then neighbors-of-neighbors)
        room = (-pos) % npb
        local = deque([u])
        while room and local:
            v = local.popleft()
            for x in graph[v]:
                x = int(x)
                if x < 0 or taken[x]:
                    continue
                taken[x] = True
                order[pos] = x
                pos += 1
                local.append(x)
                queue.append(x)
                room -= 1
                if not room:
                    break
        queue.extend(int(v) for v in graph[u] if v >= 0)  # BFS continues
    old_to_new = np.empty(n, dtype=np.int64)
    old_to_new[order] = np.arange(n, dtype=np.int64)
    return old_to_new


def invert_permutation(old_to_new: np.ndarray) -> np.ndarray:
    """old->new map -> new->old map (both are permutations of arange(n))."""
    old_to_new = np.asarray(old_to_new, dtype=np.int64)
    new_to_old = np.empty_like(old_to_new)
    new_to_old[old_to_new] = np.arange(old_to_new.size, dtype=np.int64)
    return new_to_old


def apply_permutation(old_to_new: np.ndarray, vectors: np.ndarray,
                      graph: np.ndarray, codes: np.ndarray,
                      entry_points: np.ndarray
                      ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                 np.ndarray]:
    """Reorder all build arrays into new-id space.

    Row i of each output describes the node whose NEW id is i; neighbor ids
    inside the graph are rewritten to new labels (-1 padding preserved).
    """
    new_to_old = invert_permutation(old_to_new)
    vectors_p = np.ascontiguousarray(vectors[new_to_old])
    codes_p = np.ascontiguousarray(codes[new_to_old])
    g = graph[new_to_old]
    graph_p = np.where(g >= 0, old_to_new[np.where(g >= 0, g, 0)],
                       -1).astype(graph.dtype)
    eps_p = old_to_new[np.asarray(entry_points, dtype=np.int64)]
    return vectors_p, graph_p, codes_p, eps_p


def block_locality_score(graph: np.ndarray, old_to_new: Optional[np.ndarray],
                         nodes_per_block: int) -> float:
    """Mean fraction of each node's neighbors co-resident in its block.

    The direct objective page packing maximizes; used by tests and the
    cold-path benchmark to show the relabeled layout actually co-locates.
    """
    if not nodes_per_block:
        return 0.0
    graph = np.asarray(graph)
    n = graph.shape[0]
    ids = np.arange(n, dtype=np.int64) if old_to_new is None \
        else np.asarray(old_to_new, dtype=np.int64)
    valid = graph >= 0
    safe = np.where(valid, graph, 0)
    same = (ids[safe] // nodes_per_block) == \
        (ids[:, None] // nodes_per_block)
    deg = valid.sum(axis=1)
    frac = (same & valid).sum(axis=1) / np.maximum(deg, 1)
    return float(frac[deg > 0].mean()) if (deg > 0).any() else 0.0
