"""Host-side PQ LUT / ADC numerics (numpy twins of the device kernels).

This is the numerics layer of the three-layer host search core
(``core.adc`` -> ``core.traversal`` -> ``core.index_io``): pure functions
over numpy arrays, no file or cache state, kept jax-free so the
storage-backed backend never pays jit costs.  The int8 twins mirror the
device quantized-LUT path (``kernels.chunk_adc.quantize_lut``) — a parity
test pins the two implementations together.

Every symbol here is re-exported from ``repro.core.index_io`` for
backwards compatibility with pre-split imports.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def np_build_lut(centroids: np.ndarray, q: np.ndarray, metric: str) -> np.ndarray:
    """centroids (m, ks, dsub), q (d,) -> (m, ks) f32 LUT."""
    m, ks, dsub = centroids.shape
    qs = q.astype(np.float32).reshape(m, 1, dsub)
    if metric == "mips":
        return -np.einsum("mkd,mxd->mk", centroids, qs)
    diff = centroids - qs
    return np.einsum("mkd,mkd->mk", diff, diff)


def np_build_lut_batch(centroids: np.ndarray, Q: np.ndarray,
                       metric: str) -> np.ndarray:
    """centroids (m, ks, dsub), Q (nq, d) -> (nq, m, ks) f32 LUTs."""
    m, ks, dsub = centroids.shape
    qs = Q.astype(np.float32).reshape(Q.shape[0], m, 1, dsub)
    if metric == "mips":
        return -np.einsum("mkd,qmxd->qmk", centroids, qs)
    diff = centroids[None] - qs
    return np.einsum("qmkd,qmkd->qmk", diff, diff)


def np_adc(lut: np.ndarray, codes: np.ndarray) -> np.ndarray:
    """lut (m, ks), codes (..., m) -> (...,) f32."""
    m = lut.shape[0]
    return lut[np.arange(m), codes.astype(np.int64)].sum(axis=-1)


def np_quantize_lut(lut: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """numpy twin of ``kernels.chunk_adc.quantize_lut`` — the SAME recipe
    (symmetric per-query int8, scale = max|lut|, dequant = q8 * scale/127),
    kept jax-free so the host backend never pays jit costs. A parity test
    pins the two implementations together.

    lut (..., m, ks) f32 -> (lut_q8 (..., m, ks) int8, scale (...,) f32).
    """
    lut = np.asarray(lut, dtype=np.float32)
    scale = np.abs(lut).max(axis=(-2, -1))
    lut_q8 = np.clip(np.round(
        lut / np.maximum(scale[..., None, None], np.float32(1e-20))
        * np.float32(127.0)), -127, 127).astype(np.int8)
    return lut_q8, scale.astype(np.float32)


def np_adc_int8(lut_q8: np.ndarray, scale: np.ndarray,
                codes: np.ndarray) -> np.ndarray:
    """Host int8 ADC over a quantized LUT.

    lut_q8 (m, ks) int8, codes (..., m) -> (...,) f32. A scalar `scale`
    reproduces the device int8 fused-hop numerics exactly (int32
    accumulation + ONE rescale — what the MXU one-hot contraction needs);
    a per-subspace (m,) `scale` is the finer host granularity (gathers on
    the host aren't tied to a single-scale contraction).
    """
    m = lut_q8.shape[0]
    g = lut_q8[np.arange(m), codes.astype(np.int64)]
    scale = np.asarray(scale, dtype=np.float32)
    if scale.ndim == 0:
        return g.astype(np.int32).sum(axis=-1).astype(np.float32) \
            * (scale * np.float32(1 / 127))
    return (g.astype(np.float32) * (scale * np.float32(1 / 127))).sum(axis=-1)


def np_host_lut_int8(lut: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """The host search path's int8 LUT: per-(query, subspace) mid-centered
    symmetric quantization through the SAME clip/round recipe as the
    device ``quantize_lut`` (np_quantize_lut applied per subspace row).

    Range-reduction (subtract the per-subspace minimum, center on the
    half-range) shifts every ADC distance of a query by one constant —
    ranking-invariant, so beam search is unaffected — while shrinking the
    quantization step from max|lut|/127 to (subspace range)/254.

    lut (..., m, ks) f32 -> (lut_q8 (..., m, ks) int8, scale (..., m) f32).
    """
    lut = np.asarray(lut, dtype=np.float32)
    res = lut - lut.min(axis=-1, keepdims=True)
    mid = res - res.max(axis=-1, keepdims=True) * np.float32(0.5)
    q8, scale = np_quantize_lut(mid[..., None, :])
    return q8[..., 0, :], scale
