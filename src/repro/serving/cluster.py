"""Supervised multi-process shard serving: N workers, one supervisor.

The GIL caps one Python process at roughly one core of host-side search;
AiSAQ's ~10 MB-per-index residency means one box WANTS to run many
shards.  This module is the process tier:

  * each shard worker is a separate OS process wrapping the existing
    single-process stack — a `WarmIndexPool` + `RetrievalService` over
    that shard's corpora — and serves the CRC-framed protocol
    (``serving.protocol``) on a Unix socket,
  * workers are started with the multiprocessing **spawn** context: the
    parent may carry jax/BLAS threads, and forking a threaded process
    inherits locked locks; `repro.serving`'s import chain is jax-free so
    a spawned worker starts in ~0.3 s,
  * the supervisor treats failure as the default case: a monitor thread
    watches liveness (`Process.is_alive`) AND responsiveness (heartbeat
    pings over the socket — a wedged worker that still has a pid gets
    SIGKILLed), respawns dead workers with capped exponential backoff,
    and QUARANTINES a worker that crash-loops (dies repeatedly within
    its stabilization window) the way `WarmIndexPool` quarantines a sick
    corpus — the router then serves partial answers from the survivors
    instead of feeding a crash loop,
  * SIGTERM to a worker runs `RetrievalService.close()`: queued requests
    drain or fail with the typed `ServiceClosedError`, never silently
    abandoned.

Global labels: shard indices are built with `write_index(labels=...)`
carrying each vector's GLOBAL id, so worker answers merge without any
per-shard offset arithmetic in the protocol.
"""
from __future__ import annotations

import json
import os
import signal
import socket
import sys
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.obs.metrics import merge_snapshots
from repro.serving import protocol as proto

__all__ = ["WorkerSpec", "ShardCluster", "serve_worker"]


@dataclass
class WorkerSpec:
    """Everything one shard worker needs, picklable for spawn."""
    shard_id: int
    socket_path: str
    corpora: Dict[str, str]            # corpus name -> index dir
    cache_bytes: int = 10 << 20
    budget_bytes: Optional[int] = None
    threads: int = 2                   # RetrievalService worker threads
    max_batch: int = 16
    max_wait_ms: float = 2.0
    max_queue_depth: int = 256
    L: int = 48
    w: int = 4
    rerank: Optional[int] = None
    adc_dtype: str = "f32"
    prefetch: int = 0
    pipeline: Optional[bool] = None
    gap: Optional[object] = None
    entry: str = "auto"                # nav-tier entry seeding (docs/navigation.md)
    drain_s: float = 2.0               # SIGTERM queue-drain budget
    default_deadline_s: float = 30.0   # requests that carry no deadline
    # observability knobs (see docs/observability.md)
    trace_sample: float = 1.0          # fraction of REMOTE traces served;
    #                                    router-side sampling is the primary
    #                                    knob, this one sheds worker cost
    slow_query_s: Optional[float] = None   # slow-query log threshold
    slow_log_path: Optional[str] = None    # JSONL file for slow span trees


def _json_safe(obj):
    """stats() dicts hold plain ints/floats/bools already; anything
    exotic degrades to str rather than failing the frame."""
    if isinstance(obj, dict):
        return {str(k): _json_safe(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_json_safe(v) for v in obj]
    if isinstance(obj, (str, int, float, bool)) or obj is None:
        return obj
    return str(obj)


# ---------------------------------------------------------------------------
# worker process
# ---------------------------------------------------------------------------


def serve_worker(spec: WorkerSpec):          # pragma: no cover — subprocess
    """Entry point of one shard worker process (spawn target).

    Binds the Unix socket FIRST (readiness = connectable), then serves
    frames until SIGTERM/T_SHUTDOWN.  Each accepted connection gets a
    thread; requests on one connection are served in order (the router
    opens one connection per router thread for parallelism)."""
    import numpy as np  # closed over by the handlers below

    from repro.obs.metrics import MetricsRegistry
    from repro.obs.trace import Tracer
    from repro.serving.engine import make_host_search_dist_fn
    from repro.serving.pool import CorpusUnhealthyError, WarmIndexPool
    from repro.serving.service import (BackpressureError, RetrievalService,
                                       ServiceClosedError)

    stop_ev = threading.Event()
    signal.signal(signal.SIGTERM, lambda *_: stop_ev.set())
    signal.signal(signal.SIGINT, signal.SIG_IGN)   # supervisor owns ctrl-C

    try:
        os.unlink(spec.socket_path)
    except FileNotFoundError:
        pass
    listener = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
    listener.bind(spec.socket_path)
    listener.listen(64)
    listener.settimeout(0.2)

    registry = MetricsRegistry()
    tracer = Tracer(sample=spec.trace_sample,
                    slow_threshold_s=spec.slow_query_s,
                    slow_log_path=spec.slow_log_path)
    pool = WarmIndexPool(spec.corpora, budget_bytes=spec.budget_bytes,
                         cache_bytes=spec.cache_bytes, registry=registry)
    service = RetrievalService(
        pool, num_workers=spec.threads, max_batch=spec.max_batch,
        max_wait_ms=spec.max_wait_ms, max_queue_depth=spec.max_queue_depth,
        L=spec.L, w=spec.w, rerank=spec.rerank, adc_dtype=spec.adc_dtype,
        prefetch=spec.prefetch, pipeline=spec.pipeline, gap=spec.gap,
        entry=spec.entry,
        # exact distances ride along with every answer: the router's
        # cross-shard merge needs comparable scores
        search_fn=lambda idx, q, k: make_host_search_dist_fn(
            idx, L=spec.L, w=spec.w, prefetch=spec.prefetch,
            adc_dtype=spec.adc_dtype, rerank=spec.rerank,
            pipeline=spec.pipeline, gap=spec.gap,
            entry=spec.entry)(q, k))

    def handle_search(conn, header, blob):
        req_id = int(header.get("req_id", -1))
        wspan = None
        try:
            q = proto.decode_query(header, blob)
            tctx = proto.trace_context(header)
            if tctx is not None and tracer.sampled():
                # continue the router's trace: this span + everything the
                # service/traversal nests under it ships back on T_RESULT
                wspan = tracer.start_remote(
                    "worker.serve", tctx,
                    annotations=dict(shard=spec.shard_id,
                                     pid=os.getpid()))
            deadline = header.get("deadline_s")
            wait_s = float(deadline) if deadline is not None \
                else spec.default_deadline_s
            r = service.submit(q, corpus=header.get("corpus", "default"),
                               k=int(header["k"]), deadline_s=wait_s,
                               span=wspan)
            if not r.event.wait(wait_s + 0.05):
                raise TimeoutError(
                    f"request not served within {wait_s}s")
            if r.error is not None:
                raise r.error
            ids = np.asarray(r.result, dtype=np.int64)
            dists = r.dists if r.dists is not None \
                else np.full(ids.shape, np.inf, np.float32)
            spans = None
            if wspan is not None:
                wspan.end()
                spans = tracer.take(wspan.trace_id)
                wspan = None
            h, b = proto.encode_result(ids, dists, req_id=req_id,
                                       spans=spans)
            proto.send_frame(conn, proto.T_RESULT, h, b)
        except (BackpressureError, CorpusUnhealthyError,
                ServiceClosedError, TimeoutError, KeyError,
                ValueError, OSError) as e:
            if wspan is not None:      # error frames carry no spans;
                wspan.end()            # discard rather than leak
                tracer.take(wspan.trace_id)
            # clean per-request rejection: the request RESOLVES with a
            # typed error frame — the never-silently-short contract
            proto.send_frame(conn, proto.T_ERROR,
                             dict(req_id=req_id, etype=type(e).__name__,
                                  msg=str(e)[:512]))

    def handle_conn(conn):
        conn.settimeout(None)          # workers wait for work; router
        try:                           # deadlines live on the ROUTER side
            while not stop_ev.is_set():
                try:
                    rtype, header, blob = proto.recv_frame(conn)
                except proto.ConnectionClosed:
                    return
                except proto.ProtocolError:
                    return             # poisoned stream: drop it
                if rtype == proto.T_SEARCH:
                    handle_search(conn, header, blob)
                elif rtype == proto.T_PING:
                    proto.send_frame(conn, proto.T_PONG,
                                     dict(pid=os.getpid(),
                                          shard_id=spec.shard_id))
                elif rtype == proto.T_STATS:
                    proto.send_frame(conn, proto.T_STATS_REPLY,
                                     _json_safe(service.stats()))
                elif rtype == proto.T_SHUTDOWN:
                    stop_ev.set()
                    proto.send_frame(conn, proto.T_PONG,
                                     dict(pid=os.getpid(),
                                          shard_id=spec.shard_id))
                    return
        except OSError:
            return
        finally:
            try:
                conn.close()
            except OSError:
                pass

    try:
        while not stop_ev.is_set():
            try:
                conn, _ = listener.accept()
            except socket.timeout:
                continue
            except OSError:
                break
            threading.Thread(target=handle_conn, args=(conn,),
                             daemon=True).start()
    finally:
        listener.close()
        # graceful drain: answer or typed-fail everything queued
        service.close(drain_s=spec.drain_s)
        pool.close()
        try:
            os.unlink(spec.socket_path)
        except OSError:
            pass
    sys.exit(0)


# ---------------------------------------------------------------------------
# supervisor
# ---------------------------------------------------------------------------


@dataclass
class _WorkerState:
    spec: WorkerSpec
    proc: Optional[object] = None      # multiprocessing.Process
    state: str = "down"                # down | serving | quarantined
    restarts: int = 0                  # total respawns over the lifetime
    crash_streak: int = 0              # consecutive fast deaths
    spawned_at: float = 0.0
    respawn_at: float = 0.0            # earliest next spawn (backoff)
    hb_misses: int = 0
    hb_sock: Optional[socket.socket] = None
    lock: threading.Lock = field(default_factory=threading.Lock)


class ShardCluster:
    """Spawns and supervises one worker per shard.

    `shards` is a list of corpus->index-dir dicts, one per shard.  The
    monitor thread restarts dead or wedged workers with capped
    exponential backoff (`backoff_s` doubling per consecutive fast
    crash up to `backoff_max_s`); a worker that dies `max_restarts`
    times in a row within `stable_s` of each spawn is quarantined.
    `endpoints()` is what the router polls — a quarantined or down
    shard shows `None` and the router degrades to partial answers."""

    def __init__(self, shards: List[Dict[str, str]], *,
                 socket_dir: str,
                 heartbeat_s: float = 0.25,
                 heartbeat_misses: int = 3,
                 ping_timeout_s: float = 1.0,
                 backoff_s: float = 0.05,
                 backoff_max_s: float = 2.0,
                 max_restarts: int = 5,
                 stable_s: float = 5.0,
                 **spec_kw):
        os.makedirs(socket_dir, exist_ok=True)
        self.heartbeat_s = float(heartbeat_s)
        self.heartbeat_misses = int(heartbeat_misses)
        self.ping_timeout_s = float(ping_timeout_s)
        self.backoff_s = float(backoff_s)
        self.backoff_max_s = float(backoff_max_s)
        self.max_restarts = int(max_restarts)
        self.stable_s = float(stable_s)
        self._workers = [
            _WorkerState(spec=WorkerSpec(
                shard_id=i,
                socket_path=os.path.join(socket_dir, f"shard{i}.sock"),
                corpora=dict(corpora), **spec_kw))
            for i, corpora in enumerate(shards)]
        self._ctx = None
        self._stop = False
        self._monitor_t: Optional[threading.Thread] = None
        self._lock = threading.Lock()
        self.events: deque = deque(maxlen=256)   # (t, shard, what)

    # -- lifecycle -----------------------------------------------------------
    def _log(self, shard: int, what: str):
        self.events.append((time.monotonic(), shard, what))

    def _spawn(self, ws: _WorkerState):
        import multiprocessing as mp
        if self._ctx is None:
            self._ctx = mp.get_context("spawn")
        ws.proc = self._ctx.Process(target=serve_worker, args=(ws.spec,),
                                    daemon=True,
                                    name=f"shard-worker-{ws.spec.shard_id}")
        ws.proc.start()
        ws.spawned_at = time.monotonic()
        ws.state = "serving"
        ws.hb_misses = 0
        self._close_hb(ws)
        self._log(ws.spec.shard_id, f"spawned pid={ws.proc.pid}")

    def start(self, ready_timeout_s: float = 30.0):
        """Spawn every worker and wait until each answers a ping."""
        for ws in self._workers:
            self._spawn(ws)
        deadline = time.monotonic() + ready_timeout_s
        for ws in self._workers:
            while not self._ping(ws):
                if time.monotonic() > deadline:
                    raise TimeoutError(
                        f"shard {ws.spec.shard_id} not ready within "
                        f"{ready_timeout_s}s")
                time.sleep(0.05)
        self._monitor_t = threading.Thread(target=self._monitor,
                                           name="cluster-monitor",
                                           daemon=True)
        self._monitor_t.start()
        return self

    def stop(self, timeout: float = 10.0):
        with self._lock:
            self._stop = True
        if self._monitor_t is not None:
            self._monitor_t.join(timeout=self.heartbeat_s * 4 + 1.0)
        for ws in self._workers:
            self._close_hb(ws)
            p = ws.proc
            if p is None or not p.is_alive():
                continue
            p.terminate()              # SIGTERM -> service.close() drain
        deadline = time.monotonic() + timeout
        for ws in self._workers:
            p = ws.proc
            if p is None:
                continue
            p.join(max(0.1, deadline - time.monotonic()))
            if p.is_alive():
                p.kill()               # drain budget exhausted
                p.join(5.0)
            ws.state = "down"
            try:
                os.unlink(ws.spec.socket_path)
            except OSError:
                pass

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.stop()

    # -- heartbeats ----------------------------------------------------------
    def _close_hb(self, ws: _WorkerState):
        if ws.hb_sock is not None:
            try:
                ws.hb_sock.close()
            except OSError:
                pass
            ws.hb_sock = None

    def _ping(self, ws: _WorkerState) -> bool:
        """One heartbeat over a persistent per-worker connection."""
        with ws.lock:
            try:
                if ws.hb_sock is None:
                    s = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
                    s.settimeout(self.ping_timeout_s)
                    s.connect(ws.spec.socket_path)
                    ws.hb_sock = s
                proto.send_frame(ws.hb_sock, proto.T_PING, {})
                rtype, header, _ = proto.recv_frame(ws.hb_sock)
                return rtype == proto.T_PONG
            except (proto.ProtocolError, OSError):
                self._close_hb(ws)
                return False

    # -- monitor loop --------------------------------------------------------
    def _monitor(self):
        while True:
            with self._lock:
                if self._stop:
                    return
            for ws in self._workers:
                self._check(ws)
            time.sleep(self.heartbeat_s)

    def _check(self, ws: _WorkerState):
        if ws.state == "quarantined":
            return
        now = time.monotonic()
        alive = ws.proc is not None and ws.proc.is_alive()
        if alive and ws.state == "serving":
            if self._ping(ws):
                ws.hb_misses = 0
                if now - ws.spawned_at > self.stable_s:
                    ws.crash_streak = 0      # survived: streak over
                return
            ws.hb_misses += 1
            if ws.hb_misses < self.heartbeat_misses:
                return
            # responsive never, pid alive: wedged — treat as dead
            self._log(ws.spec.shard_id,
                      f"wedged after {ws.hb_misses} missed heartbeats; "
                      "killing")
            try:
                ws.proc.kill()
            except (OSError, AttributeError):
                pass
            ws.proc.join(2.0)
            alive = False
        if not alive and ws.state == "serving":
            # death detected: schedule a respawn with backoff
            fast = (now - ws.spawned_at) < self.stable_s
            ws.crash_streak = ws.crash_streak + 1 if fast else 1
            if ws.crash_streak > self.max_restarts:
                ws.state = "quarantined"
                self._close_hb(ws)
                self._log(ws.spec.shard_id,
                          f"quarantined after {ws.crash_streak} "
                          "consecutive fast crashes")
                return
            backoff = min(self.backoff_s * (2.0 ** (ws.crash_streak - 1)),
                          self.backoff_max_s)
            ws.state = "down"
            ws.respawn_at = now + backoff
            self._close_hb(ws)
            self._log(ws.spec.shard_id,
                      f"died (streak={ws.crash_streak}); respawn in "
                      f"{backoff:.2f}s")
        if ws.state == "down" and now >= ws.respawn_at:
            ws.restarts += 1
            self._spawn(ws)

    # -- router / drill surface ----------------------------------------------
    @property
    def n_shards(self) -> int:
        return len(self._workers)

    def endpoints(self) -> List[Optional[str]]:
        """Socket path per shard, None when the shard is down or
        quarantined — the router's scatter set."""
        return [ws.spec.socket_path if ws.state == "serving" else None
                for ws in self._workers]

    def pid(self, shard_id: int) -> Optional[int]:
        """Live pid of one worker (ProcessKiller drills arm on this)."""
        ws = self._workers[shard_id]
        p = ws.proc
        return p.pid if p is not None and p.is_alive() else None

    def wait_healthy(self, timeout_s: float = 30.0) -> bool:
        """Block until every non-quarantined shard answers a ping —
        the drill's respawn-restored-full-coverage check."""
        deadline = time.monotonic() + timeout_s
        while time.monotonic() < deadline:
            if all(ws.state == "quarantined" or
                   (ws.state == "serving" and self._ping(ws))
                   for ws in self._workers):
                return True
            time.sleep(0.05)
        return False

    def worker_stats(self, shard_id: int) -> Optional[dict]:
        """Fetch one worker's RetrievalService.stats() over the wire."""
        ws = self._workers[shard_id]
        if ws.state != "serving":
            return None
        try:
            s = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
            s.settimeout(self.ping_timeout_s)
            s.connect(ws.spec.socket_path)
            try:
                proto.send_frame(s, proto.T_STATS, {})
                rtype, header, _ = proto.recv_frame(s)
                return header if rtype == proto.T_STATS_REPLY else None
            finally:
                s.close()
        except (proto.ProtocolError, OSError):
            return None

    def stats(self) -> dict:
        """Supervisor telemetry: per-shard state machine + respawn
        accounting, plus ONE cluster-wide metrics view — each serving
        worker's registry snapshot rides T_STATS and is merged here
        (counters sum, histogram buckets add, percentiles recomputed),
        so `stats()["registry"]` reads like a single process served the
        whole cluster."""
        regs = []
        for ws in self._workers:
            w = self.worker_stats(ws.spec.shard_id)
            if w and isinstance(w.get("registry"), dict):
                regs.append(w["registry"])
        return dict(
            n_shards=self.n_shards,
            serving=sum(ws.state == "serving" for ws in self._workers),
            quarantined=sum(ws.state == "quarantined"
                            for ws in self._workers),
            shards={ws.spec.shard_id: dict(
                state=ws.state,
                pid=(ws.proc.pid if ws.proc is not None
                     and ws.proc.is_alive() else None),
                restarts=ws.restarts,
                crash_streak=ws.crash_streak,
                hb_misses=ws.hb_misses,
            ) for ws in self._workers},
            events=[dict(t=t, shard=s, what=w)
                    for t, s, w in list(self.events)],
            registry=merge_snapshots(regs) if regs else None,
        )
