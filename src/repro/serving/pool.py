"""Warm-index pool: a byte-budgeted LRU of OPEN `HostIndex` handles.

The paper's headline serving claim (§2.2, §4.4) is that ~10 MB-resident
AiSAQ indices make it cheap to hold *many* corpora warm simultaneously —
the RAG-retriever scenario.  The pool is that layer:

  * every open handle is charged for the DRAM it actually holds — the
    algorithmic residency (`HostIndex.resident_bytes`, paper Table 2) plus
    its block-cache capacity — and an LRU walk evicts (closes) the
    least-recently-used unpinned handle once the byte budget overflows,
  * indices built with the same PQ centroids (hash match in meta.json) are
    deduplicated: one centroid array is shared by every open handle and
    charged ONCE — the paper's Table-4 shared-centroid trick, promoted
    from "fast switch" to "cheap co-residency",
  * in-flight searches pin their handle (refcounted) so eviction can never
    close an index mid-read; a pinned-over-budget pool overflows rather
    than deadlocks and reports it (`budget_overflow`),
  * hit / miss / eviction / shared-centroid counters feed `stats()`,
  * per-corpus HEALTH: consecutive I/O failures (reported by the serving
    layer via `record_io_failure`) quarantine a corpus — `admit` then
    fails fast with `CorpusUnhealthyError` instead of queueing doomed
    work — and a half-open probe (one admitted request after the
    cooldown) recovers it; each failed probe doubles the cooldown up to
    a cap.  The state machine is the classic circuit breaker:
    healthy -> quarantined -> probing -> healthy | quarantined.

`IndexManager` (core.index_switch) is now a thin compat wrapper over a
budget-for-one pool (`max_open=1`).
"""
from __future__ import annotations

import json
import os
import threading
import time
from collections import OrderedDict
from contextlib import contextmanager
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.index_io import HostIndex
from repro.core.wal import WAL_NAME
from repro.obs.metrics import MetricsRegistry, SearchMetrics


class CorpusUnhealthyError(RuntimeError):
    """Raised by `WarmIndexPool.admit` (and so by `RetrievalService
    .submit`) for a quarantined corpus — fail fast instead of queueing
    work onto storage that keeps failing."""

    def __init__(self, corpus: str, state: str, retry_in_s: float):
        super().__init__(
            f"corpus {corpus!r} is {state} after repeated I/O failures; "
            f"retry in ~{max(0.0, retry_in_s):.2f}s")
        self.corpus = corpus
        self.state = state
        self.retry_in_s = max(0.0, retry_in_s)


class _Health:
    """Per-corpus circuit-breaker state (pool lock held for all access)."""
    __slots__ = ("state", "consec_failures", "quarantines", "recoveries",
                 "cooldown_s", "until", "probe_at")

    def __init__(self, cooldown_s: float):
        self.state = "healthy"          # healthy | quarantined | probing
        self.consec_failures = 0
        self.quarantines = 0            # transitions INTO quarantined
        self.recoveries = 0             # successful half-open probes
        self.cooldown_s = cooldown_s
        self.until = 0.0                # monotonic time quarantine lifts
        self.probe_at = 0.0             # when the in-flight probe was armed


class _Entry:
    __slots__ = ("index", "pins", "cent_hash", "load_s")

    def __init__(self, index: HostIndex, cent_hash: Optional[int],
                 load_s: float):
        self.index = index
        self.pins = 0
        self.cent_hash = cent_hash   # None when the entry OWNS its centroids
        self.load_s = load_s


class WarmIndexPool:
    """LRU pool of open `HostIndex` handles under an explicit byte budget.

    `budget_bytes=None` means unbounded; `max_open` additionally caps the
    handle count (the budget-for-one compat mode).  `cache_bytes` is the
    per-handle block-cache budget passed to `HostIndex.load` and charged
    to the pool (an open handle's cache IS DRAM the pool holds).
    """

    def __init__(self, paths: Optional[Dict[str, str]] = None, *,
                 budget_bytes: Optional[int] = None,
                 max_open: Optional[int] = None,
                 mode: Optional[str] = None,
                 cache_bytes: int = 10 << 20,
                 strict: bool = False,
                 quarantine_after: int = 3,
                 quarantine_cooldown_s: float = 1.0,
                 quarantine_cooldown_max_s: float = 30.0,
                 probe_timeout_s: float = 10.0,
                 preadv_factory: Optional[Callable] = None,
                 registry: Optional[MetricsRegistry] = None):
        # one registry per process side by default; a RetrievalService
        # built over this pool shares it, and every open handle gets a
        # SearchMetrics bundle into it (per-corpus traversal histograms)
        self.registry = registry or MetricsRegistry()
        self._h_load = self.registry.histogram(
            "pool_load_seconds",
            help="cold index open / swap load time", unit="seconds")
        self.paths: Dict[str, str] = dict(paths or {})
        self.budget_bytes = budget_bytes
        self.max_open = max_open
        self.mode = mode
        self.cache_bytes = int(cache_bytes)
        # health knobs: `quarantine_after` consecutive I/O failures open
        # the breaker; the cooldown doubles on every failed probe up to
        # the cap; a probe unresolved for `probe_timeout_s` (e.g. its
        # request expired unserved) is re-armed rather than wedging the
        # corpus in `probing` forever
        self.quarantine_after = int(quarantine_after)
        self.quarantine_cooldown_s = float(quarantine_cooldown_s)
        self.quarantine_cooldown_max_s = float(quarantine_cooldown_max_s)
        self.probe_timeout_s = float(probe_timeout_s)
        # preadv_factory(name) -> preadv hook (or None) per corpus: the
        # fault-injection seam for drills — each corpus's BlockCache reads
        # through its own injector
        self.preadv_factory = preadv_factory
        self._health: Dict[str, _Health] = {}
        # strict=True: `pin` BLOCKS until the budget genuinely fits instead
        # of overflowing past pinned handles — the DRAM cap becomes a hard
        # admission resource (a budget-for-one pool then truly serializes
        # cross-corpus serving, like the single-active IndexManager did).
        # Waiting only happens while someone holds a pin (progress is
        # guaranteed: pins are release-after-search); with no pins
        # outstanding the pool overflows rather than deadlocks.
        self.strict = strict
        self._lock = threading.RLock()
        self._cond = threading.Condition(self._lock)
        self._entries: "OrderedDict[str, _Entry]" = OrderedDict()
        # centroid dedup pool: hash -> (array, set of corpus names using it)
        self._cents: Dict[int, Tuple[np.ndarray, set]] = {}
        self._sizes: Dict[str, int] = {}   # last known entry bytes per name
        self._loading: set = set()         # names with a load in flight
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.budget_overflow = 0     # evict walks that could not fit budget
        self.centroid_shares = 0     # loads that reused a pooled array
        self.strict_waits = 0        # strict-mode pin acquisitions that slept
        self.swaps = 0               # zero-downtime version switches
        # handles replaced by swap() while searches still pinned them:
        # they serve their in-flight readers to completion, then close.
        # Excluded from the LRU and the byte budget — a retired handle is
        # transient by construction (bounded by in-flight search latency).
        self._retired: List[Tuple[str, _Entry]] = []
        # post-crash journal recoveries performed at open time, by corpus:
        # the DynamicHostIndex.load stats dict (rolled_back /
        # rolled_forward / truncated_bytes ...), surfaced via stats() so
        # operators see crash recoveries in serving telemetry
        self._recoveries: Dict[str, dict] = {}

    # -- registration --------------------------------------------------------
    def register(self, name: str, path: str):
        with self._lock:
            self.paths[name] = path

    def _resolve(self, name: str) -> str:
        try:
            return self.paths[name]
        except KeyError:
            raise KeyError(
                f"unknown corpus {name!r}; known corpora: "
                f"{sorted(self.paths)}") from None

    # -- accounting ----------------------------------------------------------
    def _entry_bytes(self, e: _Entry) -> int:
        """DRAM charged to one handle: algorithmic residency plus its
        block-cache capacity.  Centroids in the dedup pool are charged once
        at pool level; an entry that OWNS a private centroid copy
        (share_centroids=False, or no hash in meta) is charged for it."""
        return e.index.resident_bytes(include_centroids=e.cent_hash is None) \
            + e.index.cache.capacity_bytes

    def used_bytes(self) -> int:
        with self._lock:
            total = sum(self._entry_bytes(e) for e in self._entries.values())
            total += sum(a.nbytes for a, _ in self._cents.values())
            return int(total)

    def entry_bytes(self, name: str) -> int:
        with self._lock:
            return self._entry_bytes(self._entries[name])

    def centroid_bytes(self) -> int:
        with self._lock:
            return int(sum(a.nbytes for a, _ in self._cents.values()))

    # -- open / evict --------------------------------------------------------
    def _peek_shared(self, path: str, share_centroids: bool):
        """Pooled centroid array matching `path`'s meta hash, or None.
        Unreadable/corrupt meta just skips sharing — the real load below
        raises the typed CorruptIndexError."""
        if not share_centroids:
            return None
        try:
            with open(os.path.join(path, "meta.json")) as f:
                peek_hash = json.load(f).get("centroids_hash")
        except (OSError, ValueError, AttributeError):
            peek_hash = None
        if peek_hash is None:
            return None
        with self._lock:
            if peek_hash in self._cents:
                return self._cents[peek_hash][0]
        return None

    def _load_handle(self, name: str, path: str, shared) -> HostIndex:
        """Open one index handle (called OUTSIDE the pool lock).

        A directory carrying a non-empty write-ahead journal means the
        previous writer crashed mid-mutation; `HostIndex.load` refuses
        it, so the pool routes through `DynamicHostIndex.load`, which
        recovers (rollback / roll-forward / torn-tail truncation) before
        serving.  The recovery outcome is remembered per corpus and
        surfaced in `stats()["recoveries"]` — a post-crash restart shows
        up in serving telemetry, not just in the worker's local log."""
        preadv = self.preadv_factory(name) if self.preadv_factory else None
        wal_path = os.path.join(path, WAL_NAME)
        try:
            pending = os.path.getsize(wal_path) > 0
        except OSError:
            pending = False
        if pending:
            from repro.core.dynamic import DynamicHostIndex
            idx = DynamicHostIndex.load(path, mode=self.mode,
                                        shared_centroids=shared,
                                        cache_bytes=self.cache_bytes,
                                        preadv=preadv)
            with self._lock:
                self._recoveries[name] = dict(idx.recovery)
            return idx
        return HostIndex.load(path, mode=self.mode, shared_centroids=shared,
                              cache_bytes=self.cache_bytes, preadv=preadv)

    def _acquire(self, name: str, share_centroids: bool, do_pin: bool
                 ) -> Tuple[HostIndex, float]:
        """Hit-or-load a handle.  The disk I/O of a cold load runs OUTSIDE
        the pool lock (guarded by an in-flight `_loading` claim) so one
        miss never stalls pins of already-warm corpora; concurrent callers
        of the SAME corpus wait for the in-flight load instead of
        duplicating it."""
        path = self._resolve(name)    # KeyError before any waiting
        with self._lock:
            waited = False
            while True:
                e = self._entries.get(name)
                if e is not None:
                    self._entries.move_to_end(name)
                    self.hits += 1
                    if do_pin:
                        e.pins += 1
                    return e.index, 0.0
                if name in self._loading:      # someone is loading it now
                    self._cond.wait(0.05)
                    continue
                if do_pin and self.strict \
                        and self._must_wait_for_budget(name):
                    waited = True
                    self._cond.wait(0.05)
                    continue
                self._loading.add(name)
                break
            if waited:
                self.strict_waits += 1
            self.misses += 1
        try:
            t0 = time.perf_counter()
            shared = self._peek_shared(path, share_centroids)
            idx = self._load_handle(name, path, shared)
            load_s = time.perf_counter() - t0
            self._h_load.observe(load_s)
            # per-corpus traversal histograms (hops, blocked vs compute,
            # batch latency): core.traversal feeds them when the handle
            # carries this bundle
            idx.metrics = SearchMetrics(self.registry, name)
        except BaseException:
            with self._lock:
                self._loading.discard(name)
                self._cond.notify_all()
            raise
        with self._lock:
            cent_hash = idx.meta.get("centroids_hash") \
                if share_centroids else None
            e = _Entry(idx, cent_hash, load_s)
            if shared is not None:
                self.centroid_shares += 1
            if cent_hash is not None:
                if cent_hash not in self._cents:
                    self._cents[cent_hash] = (idx.centroids, set())
                elif idx.centroids is not self._cents[cent_hash][0]:
                    # two concurrent cold loads of the same hash: the loser
                    # loaded a private copy before the winner published —
                    # swap to the pooled array so dedup identity AND the
                    # charged-once accounting stay true
                    idx.centroids = self._cents[cent_hash][0]
                    self.centroid_shares += 1
                self._cents[cent_hash][1].add(name)
            self._entries[name] = e
            self._entries.move_to_end(name)
            self._sizes[name] = self._entry_bytes(e)
            if do_pin:
                e.pins += 1
            self._evict_to_budget()
            self._loading.discard(name)
            self._cond.notify_all()
            return e.index, load_s

    def _close_entry(self, name: str, e: _Entry):
        if e.cent_hash is not None and e.cent_hash in self._cents:
            _, users = self._cents[e.cent_hash]
            cur = self._entries.get(name)
            # a swapped-in successor with the SAME centroid hash still
            # uses the pooled array under this corpus name: closing the
            # retired predecessor must not drop the name's membership
            if cur is None or cur is e or cur.cent_hash != e.cent_hash:
                users.discard(name)
            if not users:
                del self._cents[e.cent_hash]
        e.index.close()

    def _over_budget(self) -> bool:
        if self.max_open is not None and len(self._entries) > self.max_open:
            return True
        if self.budget_bytes is None:
            return False
        total = sum(self._entry_bytes(e) for e in self._entries.values())
        total += sum(a.nbytes for a, _ in self._cents.values())
        return total > self.budget_bytes

    def _evict_to_budget(self):
        while self._over_budget():
            # never evict the MRU entry: it is the handle the caller is
            # acquiring RIGHT NOW (possibly pre-pin) — closing it would
            # hand out a dead fd
            names = list(self._entries)
            victim = next((n for n in names[:-1]
                           if self._entries[n].pins == 0), None)
            if victim is None:           # everything evictable is pinned:
                self.budget_overflow += 1  # overflow, don't deadlock
                return
            e = self._entries.pop(victim)
            self._close_entry(victim, e)
            self.evictions += 1

    # -- public acquisition --------------------------------------------------
    def ensure(self, name: str, share_centroids: bool = True) -> float:
        """Open corpus `name` if not already warm.  Returns the load
        wall-time in seconds (0.0 on a pool hit) — the paper's switch-time
        metric."""
        return self._acquire(name, share_centroids, do_pin=False)[1]

    def _must_wait_for_budget(self, name: str) -> bool:
        """strict-mode admission predicate (lock held): would opening
        `name` — after evicting every unpinned handle — still overflow?
        Only meaningful to wait while a pin is outstanding (its release is
        what frees memory); otherwise overflowing is the only way to make
        progress."""
        pinned = [e for e in self._entries.values() if e.pins > 0]
        if not pinned:
            return False
        est = self._sizes.get(name)
        if est is None:
            known = [self._entry_bytes(e) for e in self._entries.values()]
            est = int(sum(known) / len(known)) if known else 0
        if self.max_open is not None and len(pinned) + 1 > self.max_open:
            return True
        if self.budget_bytes is None:
            return False
        keep = sum(self._entry_bytes(e) for e in pinned)
        keep += sum(a.nbytes for a, _ in self._cents.values())
        return keep + est > self.budget_bytes

    def pin(self, name: str, share_centroids: bool = True
            ) -> Tuple[HostIndex, float]:
        """Acquire a handle for an in-flight search: opens (or touches) the
        corpus and increments its pin count so eviction cannot close it.
        Returns (index, load_seconds) — load_seconds is 0.0 on a hit.
        In a `strict` pool a miss blocks until the budget can fit the new
        handle (see __init__)."""
        return self._acquire(name, share_centroids, do_pin=True)

    def unpin(self, name: str, index: Optional[HostIndex] = None):
        """Release one pin.  `index` identifies WHICH handle the pin was
        taken on: after a `swap`, a lease acquired on the old version must
        decrement the retired entry, not its successor under the same
        name.  `index=None` keeps the legacy name-keyed behavior (correct
        whenever no swap raced the lease)."""
        with self._lock:
            e = self._entries.get(name)
            if e is not None and (index is None or e.index is index):
                e.pins = max(0, e.pins - 1)
                if e.pins == 0:
                    self._evict_to_budget()  # deferred eviction possible
                self._cond.notify_all()  # strict waiters re-check budget
                return
            for i, (rname, re_) in enumerate(self._retired):
                if rname == name and (index is None
                                      or re_.index is index):
                    re_.pins = max(0, re_.pins - 1)
                    if re_.pins == 0:        # last reader drained: retire
                        del self._retired[i]
                        self._close_entry(rname, re_)
                    self._cond.notify_all()
                    return
            # neither live nor retired: evicted under overflow — no-op

    @contextmanager
    def lease(self, name: str, share_centroids: bool = True):
        """Context-managed pin: `with pool.lease(c) as (idx, load_s): ...`
        Unpins by handle identity, so a lease that straddles a `swap`
        releases the version it actually searched."""
        idx, load_s = self.pin(name, share_centroids)
        try:
            yield idx, load_s
        finally:
            self.unpin(name, index=idx)

    # -- zero-downtime version switch ----------------------------------------
    def swap(self, name: str, new_path: str,
             share_centroids: bool = True) -> float:
        """Atomically repoint corpus `name` at the index directory
        `new_path` (e.g. a freshly published compaction) with ZERO dropped
        or wrong-answer requests:

          * the new handle is loaded OUTSIDE the pool lock (searches on
            the old version keep running throughout),
          * under the lock the name is repointed — every lease acquired
            after this instant pins the new version,
          * the old handle is closed immediately if idle, else parked on
            the retired list where in-flight leases drain it (identity-
            keyed `unpin` closes it with the last reader).

        Returns the new handle's load wall-time in seconds.  If `name`
        was not warm this is just `register` + cold `ensure`."""
        with self._lock:
            # wait out any in-flight cold load of the same name: two
            # handles for one name must serialize through _loading
            while name in self._loading:
                self._cond.wait(0.05)
            self._loading.add(name)
            self.paths[name] = new_path
        try:
            t0 = time.perf_counter()
            shared = self._peek_shared(new_path, share_centroids)
            idx = self._load_handle(name, new_path, shared)
            load_s = time.perf_counter() - t0
            self._h_load.observe(load_s)
            idx.metrics = SearchMetrics(self.registry, name)
        except BaseException:
            with self._lock:
                self._loading.discard(name)
                self._cond.notify_all()
            raise
        with self._lock:
            old = self._entries.pop(name, None)
            cent_hash = idx.meta.get("centroids_hash") \
                if share_centroids else None
            e = _Entry(idx, cent_hash, load_s)
            if shared is not None:
                self.centroid_shares += 1
            if cent_hash is not None:
                if cent_hash not in self._cents:
                    self._cents[cent_hash] = (idx.centroids, set())
                elif idx.centroids is not self._cents[cent_hash][0]:
                    idx.centroids = self._cents[cent_hash][0]
                    self.centroid_shares += 1
                self._cents[cent_hash][1].add(name)
            self._entries[name] = e
            self._entries.move_to_end(name)
            self._sizes[name] = self._entry_bytes(e)
            self.swaps += 1
            if old is not None:
                if old.pins == 0:
                    self._close_entry(name, old)
                else:
                    self._retired.append((name, old))
            self._evict_to_budget()
            self._loading.discard(name)
            self._cond.notify_all()
            return load_s

    def peek(self, name: str) -> Optional[HostIndex]:
        """The open handle for `name`, or None — no LRU touch, no load."""
        with self._lock:
            e = self._entries.get(name)
            return None if e is None else e.index

    def open_corpora(self) -> List[str]:
        with self._lock:
            return list(self._entries)

    def pinned(self, name: str) -> int:
        with self._lock:
            e = self._entries.get(name)
            return 0 if e is None else e.pins

    # -- per-corpus health (circuit breaker) ---------------------------------
    def _health_of(self, name: str) -> _Health:
        h = self._health.get(name)
        if h is None:
            h = self._health[name] = _Health(self.quarantine_cooldown_s)
        return h

    def admit(self, name: str):
        """Admission gate for new work on `name`.  Healthy corpora pass;
        a quarantined corpus whose cooldown has elapsed transitions to
        `probing` and admits THIS caller as the half-open probe; anything
        else raises CorpusUnhealthyError (fail fast, don't queue doomed
        work).  A probe left unresolved past `probe_timeout_s` (its
        request expired or was abandoned) is re-armed."""
        self._resolve(name)
        with self._lock:
            h = self._health.get(name)
            if h is None or h.state == "healthy":
                return
            now = time.monotonic()
            if h.state == "quarantined":
                if now >= h.until:
                    h.state = "probing"
                    h.probe_at = now
                    return               # this caller IS the probe
                raise CorpusUnhealthyError(name, "quarantined",
                                           h.until - now)
            # probing: one request is already out testing the waters
            if now - h.probe_at > self.probe_timeout_s:
                h.probe_at = now         # stale probe: re-arm with this one
                return
            raise CorpusUnhealthyError(
                name, "probing", self.probe_timeout_s - (now - h.probe_at))

    def record_io_failure(self, name: str):
        """An admitted request on `name` failed with an I/O error.  Opens
        the breaker after `quarantine_after` consecutive failures; a
        failing probe re-quarantines with a doubled cooldown."""
        with self._lock:
            h = self._health_of(name)
            h.consec_failures += 1
            now = time.monotonic()
            if h.state == "probing":
                # the half-open probe failed: back off harder
                h.cooldown_s = min(h.cooldown_s * 2.0,
                                   self.quarantine_cooldown_max_s)
                h.state = "quarantined"
                h.until = now + h.cooldown_s
                h.quarantines += 1
            elif h.state == "healthy" \
                    and h.consec_failures >= self.quarantine_after:
                h.state = "quarantined"
                h.until = now + h.cooldown_s
                h.quarantines += 1
            # already quarantined: stale in-flight failures change nothing

    def record_success(self, name: str):
        """An admitted request on `name` completed.  A successful probe
        closes the breaker (cooldown resets); successes that raced into a
        quarantine window are stale evidence and are ignored."""
        with self._lock:
            h = self._health.get(name)
            if h is None:
                return
            if h.state == "probing":
                h.state = "healthy"
                h.recoveries += 1
                h.cooldown_s = self.quarantine_cooldown_s
                h.consec_failures = 0
            elif h.state == "healthy":
                h.consec_failures = 0

    def health(self, name: str) -> dict:
        """Health snapshot for one corpus (fresh corpora are healthy)."""
        with self._lock:
            h = self._health.get(name)
            if h is None:
                return dict(state="healthy", consec_failures=0,
                            quarantines=0, recoveries=0)
            return dict(state=h.state,
                        consec_failures=h.consec_failures,
                        quarantines=h.quarantines,
                        recoveries=h.recoveries,
                        cooldown_s=h.cooldown_s)

    # -- stats / lifecycle ---------------------------------------------------
    def stats(self) -> dict:
        """One CONSISTENT snapshot of the pool, taken in a single pass
        under the pool lock.  Every per-handle figure (bytes charged,
        cache counters, pins) is read from ONE capture of the entry
        table, and each handle's counters come from one
        `CacheCounters.snapshot()` call — a `swap` racing this method
        sees either the old handle's report or the new one's, never a
        row mixing both, and a handle's counter row is internally
        coherent rather than attributes sampled at different instants."""
        with self._lock:
            entries = list(self._entries.items())
            cent_bytes = int(sum(a.nbytes for a, _ in self._cents.values()))
            used = cent_bytes
            caches = {}
            pinned = {}
            nav_bytes = {}
            for n, e in entries:
                used += self._entry_bytes(e)
                if e.pins:
                    pinned[n] = e.pins
                # navigation-tier residency is part of resident_bytes and
                # hence of `used`; broken out so operators can see what
                # the pivot graph costs against the budget
                if getattr(e.index, "nav", None) is not None:
                    nav_bytes[n] = int(e.index.nav.resident_nbytes())
                cache = e.index.cache
                if cache is None:
                    continue
                # per-handle I/O-engine telemetry: each open handle's
                # block cache carries the pipelined-traversal counters
                # (demand vs background syscalls, speculation accounting,
                # the histogram-chosen readahead gap) — surfaced here so
                # a multi-tenant operator sees which corpus is I/O-bound
                (hits, misses, _evic, syscalls, _bytes, _fetch,
                 _pf_issued, pf_syscalls, _pf_bytes, pf_hits, pf_wasted,
                 pf_errors, auto_gap, retries, crc_mm, crc_rr) = \
                    cache.counters.snapshot()
                total = hits + misses
                caches[n] = dict(
                    hit_rate=float(hits) / total if total else 0.0,
                    demand_syscalls=syscalls,
                    prefetch_syscalls=pf_syscalls,
                    prefetch_hits=pf_hits,
                    prefetch_wasted=pf_wasted,
                    prefetch_errors=pf_errors,
                    auto_gap=auto_gap,
                    read_retries=retries,
                    crc_mismatches=crc_mm,
                    crc_rereads=crc_rr,
                )
                # CacheCounters -> registry: published at snapshot time
                # as gauges (the counters object stays the hot-path
                # store; the registry is the exposition surface)
                lbl = {"corpus": n}
                for g, v in (("cache_hit_rate", caches[n]["hit_rate"]),
                             ("cache_demand_syscalls", syscalls),
                             ("cache_prefetch_syscalls", pf_syscalls),
                             ("cache_prefetch_hits", pf_hits),
                             ("cache_prefetch_wasted", pf_wasted),
                             ("cache_prefetch_errors", pf_errors),
                             ("cache_read_retries", retries),
                             ("cache_crc_mismatches", crc_mm)):
                    self.registry.gauge(g, lbl).set(v)
            for g, v in (("pool_open", len(entries)),
                         ("pool_hits", self.hits),
                         ("pool_misses", self.misses),
                         ("pool_evictions", self.evictions),
                         ("pool_swaps", self.swaps),
                         ("pool_used_bytes", used),
                         ("pool_nav_bytes", sum(nav_bytes.values())),
                         ("pool_retired", len(self._retired))):
                self.registry.gauge(g).set(v)
            return dict(
                open=len(entries),
                registered=len(self.paths),
                hits=self.hits,
                misses=self.misses,
                evictions=self.evictions,
                budget_overflow=self.budget_overflow,
                centroid_shares=self.centroid_shares,
                strict_waits=self.strict_waits,
                swaps=self.swaps,
                retired=len(self._retired),
                used_bytes=int(used),
                budget_bytes=self.budget_bytes,
                max_open=self.max_open,
                centroid_bytes=cent_bytes,
                nav_bytes=nav_bytes,
                nav_bytes_total=int(sum(nav_bytes.values())),
                pinned=pinned,
                caches=caches,
                health={n: dict(state=h.state,
                                consec_failures=h.consec_failures,
                                quarantines=h.quarantines,
                                recoveries=h.recoveries)
                        for n, h in self._health.items()},
                # journal recoveries performed at open time (see
                # _load_handle): corpus -> DynamicHostIndex.load stats
                recoveries={n: dict(r)
                            for n, r in self._recoveries.items()},
            )

    def close(self, timeout: float = 5.0):
        """Close every open handle.  Waits (bounded) for outstanding pins
        first — closing an fd under an in-flight search would turn the
        'pins protect readers' guarantee into an EBADF at teardown."""
        deadline = time.monotonic() + timeout
        with self._lock:
            while self._loading \
                    or any(e.pins > 0 for e in self._entries.values()) \
                    or any(e.pins > 0 for _, e in self._retired):
                # in-flight loads must publish first, else their handle
                # would land in the pool (open fd) after close() returns
                left = deadline - time.monotonic()
                if left <= 0:
                    break                # give up: teardown wins
                self._cond.wait(min(left, 0.05))
            for name, e in list(self._entries.items()):
                self._close_entry(name, e)
            self._entries.clear()
            for name, e in self._retired:
                e.index.close()          # centroids pool is cleared below
            self._retired.clear()
            self._cents.clear()
