"""Warm-index pool: a byte-budgeted LRU of OPEN `HostIndex` handles.

The paper's headline serving claim (§2.2, §4.4) is that ~10 MB-resident
AiSAQ indices make it cheap to hold *many* corpora warm simultaneously —
the RAG-retriever scenario.  The pool is that layer:

  * every open handle is charged for the DRAM it actually holds — the
    algorithmic residency (`HostIndex.resident_bytes`, paper Table 2) plus
    its block-cache capacity — and an LRU walk evicts (closes) the
    least-recently-used unpinned handle once the byte budget overflows,
  * indices built with the same PQ centroids (hash match in meta.json) are
    deduplicated: one centroid array is shared by every open handle and
    charged ONCE — the paper's Table-4 shared-centroid trick, promoted
    from "fast switch" to "cheap co-residency",
  * in-flight searches pin their handle (refcounted) so eviction can never
    close an index mid-read; a pinned-over-budget pool overflows rather
    than deadlocks and reports it (`budget_overflow`),
  * hit / miss / eviction / shared-centroid counters feed `stats()`.

`IndexManager` (core.index_switch) is now a thin compat wrapper over a
budget-for-one pool (`max_open=1`).
"""
from __future__ import annotations

import json
import os
import threading
import time
from collections import OrderedDict
from contextlib import contextmanager
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.index_io import HostIndex


class _Entry:
    __slots__ = ("index", "pins", "cent_hash", "load_s")

    def __init__(self, index: HostIndex, cent_hash: Optional[int],
                 load_s: float):
        self.index = index
        self.pins = 0
        self.cent_hash = cent_hash   # None when the entry OWNS its centroids
        self.load_s = load_s


class WarmIndexPool:
    """LRU pool of open `HostIndex` handles under an explicit byte budget.

    `budget_bytes=None` means unbounded; `max_open` additionally caps the
    handle count (the budget-for-one compat mode).  `cache_bytes` is the
    per-handle block-cache budget passed to `HostIndex.load` and charged
    to the pool (an open handle's cache IS DRAM the pool holds).
    """

    def __init__(self, paths: Optional[Dict[str, str]] = None, *,
                 budget_bytes: Optional[int] = None,
                 max_open: Optional[int] = None,
                 mode: Optional[str] = None,
                 cache_bytes: int = 10 << 20,
                 strict: bool = False):
        self.paths: Dict[str, str] = dict(paths or {})
        self.budget_bytes = budget_bytes
        self.max_open = max_open
        self.mode = mode
        self.cache_bytes = int(cache_bytes)
        # strict=True: `pin` BLOCKS until the budget genuinely fits instead
        # of overflowing past pinned handles — the DRAM cap becomes a hard
        # admission resource (a budget-for-one pool then truly serializes
        # cross-corpus serving, like the single-active IndexManager did).
        # Waiting only happens while someone holds a pin (progress is
        # guaranteed: pins are release-after-search); with no pins
        # outstanding the pool overflows rather than deadlocks.
        self.strict = strict
        self._lock = threading.RLock()
        self._cond = threading.Condition(self._lock)
        self._entries: "OrderedDict[str, _Entry]" = OrderedDict()
        # centroid dedup pool: hash -> (array, set of corpus names using it)
        self._cents: Dict[int, Tuple[np.ndarray, set]] = {}
        self._sizes: Dict[str, int] = {}   # last known entry bytes per name
        self._loading: set = set()         # names with a load in flight
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.budget_overflow = 0     # evict walks that could not fit budget
        self.centroid_shares = 0     # loads that reused a pooled array
        self.strict_waits = 0        # strict-mode pin acquisitions that slept

    # -- registration --------------------------------------------------------
    def register(self, name: str, path: str):
        with self._lock:
            self.paths[name] = path

    def _resolve(self, name: str) -> str:
        try:
            return self.paths[name]
        except KeyError:
            raise KeyError(
                f"unknown corpus {name!r}; known corpora: "
                f"{sorted(self.paths)}") from None

    # -- accounting ----------------------------------------------------------
    def _entry_bytes(self, e: _Entry) -> int:
        """DRAM charged to one handle: algorithmic residency plus its
        block-cache capacity.  Centroids in the dedup pool are charged once
        at pool level; an entry that OWNS a private centroid copy
        (share_centroids=False, or no hash in meta) is charged for it."""
        return e.index.resident_bytes(include_centroids=e.cent_hash is None) \
            + e.index.cache.capacity_bytes

    def used_bytes(self) -> int:
        with self._lock:
            total = sum(self._entry_bytes(e) for e in self._entries.values())
            total += sum(a.nbytes for a, _ in self._cents.values())
            return int(total)

    def entry_bytes(self, name: str) -> int:
        with self._lock:
            return self._entry_bytes(self._entries[name])

    def centroid_bytes(self) -> int:
        with self._lock:
            return int(sum(a.nbytes for a, _ in self._cents.values()))

    # -- open / evict --------------------------------------------------------
    def _acquire(self, name: str, share_centroids: bool, do_pin: bool
                 ) -> Tuple[HostIndex, float]:
        """Hit-or-load a handle.  The disk I/O of a cold load runs OUTSIDE
        the pool lock (guarded by an in-flight `_loading` claim) so one
        miss never stalls pins of already-warm corpora; concurrent callers
        of the SAME corpus wait for the in-flight load instead of
        duplicating it."""
        path = self._resolve(name)    # KeyError before any waiting
        with self._lock:
            waited = False
            while True:
                e = self._entries.get(name)
                if e is not None:
                    self._entries.move_to_end(name)
                    self.hits += 1
                    if do_pin:
                        e.pins += 1
                    return e.index, 0.0
                if name in self._loading:      # someone is loading it now
                    self._cond.wait(0.05)
                    continue
                if do_pin and self.strict \
                        and self._must_wait_for_budget(name):
                    waited = True
                    self._cond.wait(0.05)
                    continue
                self._loading.add(name)
                break
            if waited:
                self.strict_waits += 1
            self.misses += 1
        try:
            t0 = time.perf_counter()
            shared = None
            if share_centroids:
                try:
                    with open(os.path.join(path, "meta.json")) as f:
                        peek_hash = json.load(f).get("centroids_hash")
                except OSError:
                    peek_hash = None
                if peek_hash is not None:
                    with self._lock:
                        if peek_hash in self._cents:
                            shared = self._cents[peek_hash][0]
            idx = HostIndex.load(path, mode=self.mode,
                                 shared_centroids=shared,
                                 cache_bytes=self.cache_bytes)
            load_s = time.perf_counter() - t0
        except BaseException:
            with self._lock:
                self._loading.discard(name)
                self._cond.notify_all()
            raise
        with self._lock:
            cent_hash = idx.meta.get("centroids_hash") \
                if share_centroids else None
            e = _Entry(idx, cent_hash, load_s)
            if shared is not None:
                self.centroid_shares += 1
            if cent_hash is not None:
                if cent_hash not in self._cents:
                    self._cents[cent_hash] = (idx.centroids, set())
                elif idx.centroids is not self._cents[cent_hash][0]:
                    # two concurrent cold loads of the same hash: the loser
                    # loaded a private copy before the winner published —
                    # swap to the pooled array so dedup identity AND the
                    # charged-once accounting stay true
                    idx.centroids = self._cents[cent_hash][0]
                    self.centroid_shares += 1
                self._cents[cent_hash][1].add(name)
            self._entries[name] = e
            self._entries.move_to_end(name)
            self._sizes[name] = self._entry_bytes(e)
            if do_pin:
                e.pins += 1
            self._evict_to_budget()
            self._loading.discard(name)
            self._cond.notify_all()
            return e.index, load_s

    def _close_entry(self, name: str, e: _Entry):
        if e.cent_hash is not None and e.cent_hash in self._cents:
            _, users = self._cents[e.cent_hash]
            users.discard(name)
            if not users:
                del self._cents[e.cent_hash]
        e.index.close()

    def _over_budget(self) -> bool:
        if self.max_open is not None and len(self._entries) > self.max_open:
            return True
        if self.budget_bytes is None:
            return False
        total = sum(self._entry_bytes(e) for e in self._entries.values())
        total += sum(a.nbytes for a, _ in self._cents.values())
        return total > self.budget_bytes

    def _evict_to_budget(self):
        while self._over_budget():
            # never evict the MRU entry: it is the handle the caller is
            # acquiring RIGHT NOW (possibly pre-pin) — closing it would
            # hand out a dead fd
            names = list(self._entries)
            victim = next((n for n in names[:-1]
                           if self._entries[n].pins == 0), None)
            if victim is None:           # everything evictable is pinned:
                self.budget_overflow += 1  # overflow, don't deadlock
                return
            e = self._entries.pop(victim)
            self._close_entry(victim, e)
            self.evictions += 1

    # -- public acquisition --------------------------------------------------
    def ensure(self, name: str, share_centroids: bool = True) -> float:
        """Open corpus `name` if not already warm.  Returns the load
        wall-time in seconds (0.0 on a pool hit) — the paper's switch-time
        metric."""
        return self._acquire(name, share_centroids, do_pin=False)[1]

    def _must_wait_for_budget(self, name: str) -> bool:
        """strict-mode admission predicate (lock held): would opening
        `name` — after evicting every unpinned handle — still overflow?
        Only meaningful to wait while a pin is outstanding (its release is
        what frees memory); otherwise overflowing is the only way to make
        progress."""
        pinned = [e for e in self._entries.values() if e.pins > 0]
        if not pinned:
            return False
        est = self._sizes.get(name)
        if est is None:
            known = [self._entry_bytes(e) for e in self._entries.values()]
            est = int(sum(known) / len(known)) if known else 0
        if self.max_open is not None and len(pinned) + 1 > self.max_open:
            return True
        if self.budget_bytes is None:
            return False
        keep = sum(self._entry_bytes(e) for e in pinned)
        keep += sum(a.nbytes for a, _ in self._cents.values())
        return keep + est > self.budget_bytes

    def pin(self, name: str, share_centroids: bool = True
            ) -> Tuple[HostIndex, float]:
        """Acquire a handle for an in-flight search: opens (or touches) the
        corpus and increments its pin count so eviction cannot close it.
        Returns (index, load_seconds) — load_seconds is 0.0 on a hit.
        In a `strict` pool a miss blocks until the budget can fit the new
        handle (see __init__)."""
        return self._acquire(name, share_centroids, do_pin=True)

    def unpin(self, name: str):
        with self._lock:
            e = self._entries.get(name)
            if e is None:
                return                   # already evicted under overflow
            e.pins = max(0, e.pins - 1)
            if e.pins == 0:
                self._evict_to_budget()  # deferred eviction now possible
            self._cond.notify_all()      # strict waiters re-check the budget

    @contextmanager
    def lease(self, name: str, share_centroids: bool = True):
        """Context-managed pin: `with pool.lease(c) as (idx, load_s): ...`"""
        idx, load_s = self.pin(name, share_centroids)
        try:
            yield idx, load_s
        finally:
            self.unpin(name)

    def peek(self, name: str) -> Optional[HostIndex]:
        """The open handle for `name`, or None — no LRU touch, no load."""
        with self._lock:
            e = self._entries.get(name)
            return None if e is None else e.index

    def open_corpora(self) -> List[str]:
        with self._lock:
            return list(self._entries)

    def pinned(self, name: str) -> int:
        with self._lock:
            e = self._entries.get(name)
            return 0 if e is None else e.pins

    # -- stats / lifecycle ---------------------------------------------------
    def stats(self) -> dict:
        with self._lock:
            return dict(
                open=len(self._entries),
                registered=len(self.paths),
                hits=self.hits,
                misses=self.misses,
                evictions=self.evictions,
                budget_overflow=self.budget_overflow,
                centroid_shares=self.centroid_shares,
                strict_waits=self.strict_waits,
                used_bytes=self.used_bytes(),
                budget_bytes=self.budget_bytes,
                max_open=self.max_open,
                centroid_bytes=self.centroid_bytes(),
                pinned={n: e.pins for n, e in self._entries.items()
                        if e.pins},
                # per-handle I/O-engine telemetry: each open handle's block
                # cache carries the pipelined-traversal counters (demand vs
                # background syscalls, speculation accounting, the
                # histogram-chosen readahead gap) — surfaced here so a
                # multi-tenant operator sees which corpus is I/O-bound
                caches={n: dict(
                    hit_rate=e.index.cache.hit_rate(),
                    demand_syscalls=e.index.cache.counters.syscalls,
                    prefetch_syscalls=e.index.cache.counters
                    .prefetch_syscalls,
                    prefetch_hits=e.index.cache.counters.prefetch_hits,
                    prefetch_wasted=e.index.cache.counters.prefetch_wasted,
                    prefetch_errors=e.index.cache.counters.prefetch_errors,
                    auto_gap=e.index.cache.counters.auto_gap,
                ) for n, e in self._entries.items()
                    if e.index.cache is not None},
            )

    def close(self, timeout: float = 5.0):
        """Close every open handle.  Waits (bounded) for outstanding pins
        first — closing an fd under an in-flight search would turn the
        'pins protect readers' guarantee into an EBADF at teardown."""
        deadline = time.monotonic() + timeout
        with self._lock:
            while self._loading \
                    or any(e.pins > 0 for e in self._entries.values()):
                # in-flight loads must publish first, else their handle
                # would land in the pool (open fd) after close() returns
                left = deadline - time.monotonic()
                if left <= 0:
                    break                # give up: teardown wins
                self._cond.wait(min(left, 0.05))
            for name, e in list(self._entries.items()):
                self._close_entry(name, e)
            self._entries.clear()
            self._cents.clear()
