"""Multi-tenant retrieval service: per-corpus queues + worker scheduling.

`ServingEngine` (serving.engine) serializes every corpus through one FIFO
and one loop thread, so two tenants ping-ponging corpora destroy each
other's throughput.  This service is the scheduling layer the paper's
many-warm-corpora claim needs:

  * one queue PER corpus — a burst on one tenant can never reorder or
    starve another tenant's requests (each corpus stays strictly FIFO),
  * N workers pick corpora round-robin among the non-empty queues; a
    corpus is served by at most one worker at a time (per-corpus batches
    stay FIFO) while DIFFERENT corpora serve concurrently,
  * indices come from a `WarmIndexPool` lease — pinned for the duration of
    the batch so eviction can never close an index mid-search, and the
    pool-miss load time is recorded as that corpus's switch cost,
  * admission control: a queue deeper than `max_queue_depth` rejects the
    submit with `BackpressureError` (bounded memory, bounded tail) and
    counts it,
  * per-corpus telemetry — completed / rejected / batches / switches /
    latency percentiles / QPS — exported as one `stats()` dict.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.obs import trace as obs_trace
from repro.obs.metrics import COUNT_BUCKETS, MetricsRegistry, merged_quantile
from repro.serving.engine import Request, make_host_search_fn
from repro.serving.pool import CorpusUnhealthyError, WarmIndexPool

__all__ = ["BackpressureError", "CorpusUnhealthyError",
           "ServiceClosedError", "RetrievalService"]


class BackpressureError(RuntimeError):
    """Raised by `submit` when a corpus queue is at max_queue_depth."""

    def __init__(self, corpus: str, depth: int, limit: int):
        super().__init__(
            f"corpus {corpus!r} queue at admission limit "
            f"({depth}/{limit}); retry later")
        self.corpus = corpus
        self.depth = depth
        self.limit = limit


class ServiceClosedError(RuntimeError):
    """The service is shutting down: raised by `submit` once `close()`
    (or `stop()`) has begun, and set on requests still queued when the
    drain deadline passes.  A RuntimeError subclass so callers that
    guarded the old untyped `RuntimeError("service stopped")` keep
    working; cluster workers map it to a clean per-request error frame
    instead of a dropped connection."""


class _CorpusTelemetry:
    """Per-corpus series handles into the service's MetricsRegistry.

    The registry is the single source of truth (bounded memory by
    construction: fixed-bucket histograms, no per-request state);
    `stats()` renders the legacy dict shape as a thin view over these
    handles, and percentiles are bucket-derived instead of sampled from
    a latency ring."""

    __slots__ = ("completed", "rejected", "errors", "expired",
                 "unhealthy_rejected", "batches", "latency", "batch_size",
                 "switch", "queue_depth", "first_submit", "last_done")

    def __init__(self, reg: MetricsRegistry, corpus: str):
        lbl = {"corpus": corpus}
        def outcome(o):
            return reg.counter("service_requests_total",
                               {**lbl, "outcome": o},
                               help="request outcomes per corpus")
        self.completed = outcome("completed")
        self.rejected = outcome("rejected")            # backpressure
        self.errors = outcome("error")
        self.expired = outcome("expired")              # deadline at assembly
        self.unhealthy_rejected = outcome("unhealthy")  # breaker fail-fast
        self.batches = reg.counter("service_batches_total", lbl,
                                   help="batches served per corpus")
        self.latency = reg.histogram(
            "service_latency_seconds", lbl,
            help="submit-to-done request latency", unit="seconds")
        self.batch_size = reg.histogram(
            "service_batch_size", lbl, buckets=COUNT_BUCKETS,
            help="requests per served batch")
        self.switch = reg.histogram(
            "service_switch_seconds", lbl,
            help="pool-miss index load (switch) cost", unit="seconds")
        self.queue_depth = reg.gauge("service_queue_depth", lbl,
                                     help="queued requests at snapshot")
        self.first_submit: Optional[float] = None
        self.last_done: Optional[float] = None


class RetrievalService:
    """search_fn(index, queries (B, d), k) -> ids (B, k); the default runs
    `HostIndex.search_batch` with this service's L/w/rerank/adc knobs."""

    def __init__(self, pool: WarmIndexPool, *, num_workers: int = 2,
                 max_batch: int = 16, max_wait_ms: float = 2.0,
                 max_queue_depth: int = 256, L: int = 48, w: int = 4,
                 rerank: Optional[int] = None, adc_dtype: str = "f32",
                 prefetch: int = 0, pipeline: Optional[bool] = None,
                 gap=None, entry: str = "auto",
                 search_fn: Optional[Callable] = None,
                 registry: Optional[MetricsRegistry] = None):
        self.pool = pool
        # share the pool's registry by default so one snapshot carries
        # the whole process (service + pool + per-corpus search/cache)
        self.registry = registry or getattr(pool, "registry", None) \
            or MetricsRegistry()
        self.max_batch = max_batch
        self.max_wait = max_wait_ms / 1e3
        self.max_queue_depth = max_queue_depth
        self.L, self.w = L, w
        self.rerank = rerank
        self.adc_dtype = adc_dtype
        self.prefetch = prefetch
        # pipeline=None: auto — two-hop in-flight traversal whenever
        # prefetch > 0 (core.traversal); gap=None: readahead follows the
        # prefetch depth, "auto" tunes it from the miss histogram
        self.pipeline = pipeline
        self.gap = gap
        # entry="auto": per-query nav entry vertices whenever the served
        # index carries a navigation tier, fixed medoid otherwise —
        # mixed pools (nav and nav-less corpora) serve correctly
        self.entry = entry
        self._search_fn = search_fn or self._default_search
        self._cond = threading.Condition()
        self._queues: Dict[str, deque] = {}
        self._busy: set = set()
        self._rr: List[str] = []         # round-robin corpus order
        self._rr_next = 0
        self._tel: Dict[str, _CorpusTelemetry] = {}
        self._stop = False
        self._closing = False    # close() begun: reject new, drain queued
        self._t0 = time.perf_counter()
        self._workers = [
            threading.Thread(target=self._worker, name=f"retrieval-w{i}",
                             daemon=True)
            for i in range(max(1, num_workers))]
        for t in self._workers:
            t.start()

    # -- client API ----------------------------------------------------------
    def _default_search(self, index, queries: np.ndarray, k: int
                        ) -> np.ndarray:
        # delegate to the factory so the beam-width-covers-rerank-depth
        # rule lives in exactly one place (engine.make_host_search_fn)
        return make_host_search_fn(
            index, L=self.L, w=self.w, prefetch=self.prefetch,
            adc_dtype=self.adc_dtype, rerank=self.rerank,
            pipeline=self.pipeline, gap=self.gap,
            entry=self.entry)(queries, k)

    def submit(self, query: np.ndarray, corpus: str = "default", k: int = 10,
               deadline_s: Optional[float] = None,
               span: Optional[object] = None) -> Request:
        """Queue one request.  `deadline_s` (seconds from now) attaches a
        drop-dead time: a worker assembling a batch skips the request once
        it has passed (TimeoutError on the request, `expired` telemetry)
        instead of serving it into the void.  `span` (obs.trace.Span)
        ties the request to a query trace: the serving batch, traversal
        hops, and cache reads parent onto it.  Raises
        CorpusUnhealthyError when the corpus is quarantined (fail fast)
        and BackpressureError at the admission depth."""
        self.pool._resolve(corpus)       # one source of the naming KeyError
        r = Request(query=query, corpus=corpus, k=k, span=span)
        if deadline_s is not None:
            r.deadline = r.t_submit + float(deadline_s)
        with self._cond:
            if self._stop or self._closing:
                raise ServiceClosedError("service stopped")
            q = self._queues.get(corpus)
            if q is None:
                q = self._queues[corpus] = deque()
                self._rr.append(corpus)
                self._tel[corpus] = _CorpusTelemetry(self.registry, corpus)
            tel = self._tel[corpus]
            try:
                self.pool.admit(corpus)  # circuit breaker: fail fast
            except CorpusUnhealthyError:
                tel.unhealthy_rejected.inc()
                raise
            if len(q) >= self.max_queue_depth:
                tel.rejected.inc()
                raise BackpressureError(corpus, len(q), self.max_queue_depth)
            if tel.first_submit is None:
                tel.first_submit = r.t_submit
            q.append(r)
            self._cond.notify()
        return r

    def submit_wait(self, query, corpus: str = "default", k: int = 10,
                    timeout: float = 30.0) -> Request:
        # the wait timeout doubles as the request deadline: if the caller
        # gives up, no worker should burn a search slot on the orphan
        r = self.submit(query, corpus, k, deadline_s=timeout)
        if not r.event.wait(timeout):
            raise TimeoutError(
                f"request to corpus {corpus!r} not served in {timeout}s")
        if r.error is not None:
            raise r.error
        return r

    def swap(self, corpus: str, new_path: str,
             share_centroids: bool = True) -> float:
        """Zero-downtime version switch: repoint `corpus` at `new_path`
        (e.g. a freshly compacted index) while this service keeps
        serving.  Requests already leased onto the old version finish on
        it; every later request sees the new one.  Returns the new
        handle's load time in seconds (the paper's switch-time metric)."""
        return self.pool.swap(corpus, new_path,
                              share_centroids=share_centroids)

    # -- scheduling ----------------------------------------------------------
    def _pick_corpus(self) -> Optional[str]:
        """Next non-empty, non-busy corpus, round-robin (lock held)."""
        n = len(self._rr)
        for off in range(n):
            c = self._rr[(self._rr_next + off) % n]
            if self._queues[c] and c not in self._busy:
                self._rr_next = (self._rr_next + off + 1) % n
                return c
        return None

    def _expire(self, r: Request, now: float):
        """Fail one deadline-passed request (lock held): the submitter
        already gave up — serving it would burn a search slot into the
        void AND count it `completed` (the abandoned-request bug)."""
        self._tel[r.corpus].expired.inc()
        r.error = TimeoutError(
            f"request to corpus {r.corpus!r} expired before service")
        r.t_done = now
        r.event.set()

    def _pop_live(self, corpus: str) -> Optional[Request]:
        """Pop the next non-expired request (lock held), failing expired
        entries along the way.  None when the queue drains."""
        q = self._queues[corpus]
        now = time.perf_counter()
        while q:
            r = q.popleft()
            if r.expired(now):
                self._expire(r, now)
                continue
            return r
        return None

    def _worker(self):
        while True:
            with self._cond:
                corpus = self._pick_corpus()
                while corpus is None:
                    if self._stop:
                        return
                    self._cond.wait(0.1)
                    corpus = self._pick_corpus()
                self._busy.add(corpus)
                first = self._pop_live(corpus)
            try:
                if first is None:
                    continue             # every queued request had expired
                batch = [first]
                # linger up to max_wait for the batch to fill
                deadline = time.perf_counter() + self.max_wait
                while len(batch) < self.max_batch:
                    with self._cond:
                        if self._queues[corpus]:
                            r = self._pop_live(corpus)
                            if r is not None:
                                batch.append(r)
                            continue
                        left = deadline - time.perf_counter()
                        if left <= 0 or self._stop:
                            break
                        self._cond.wait(left)
                self._serve(corpus, batch)
            finally:
                with self._cond:
                    self._busy.discard(corpus)
                    self._cond.notify_all()

    def _serve(self, corpus: str, batch: List[Request]):
        err: Optional[Exception] = None
        ids = None
        dists = None
        load_s = 0.0
        # one batch serves at most one trace's spans: the first traced
        # request wins (mixed batches annotate how many rode along)
        tspan = next((r.span for r in batch if r.span is not None), None)
        bspan = None
        if tspan is not None:
            bspan = tspan.tracer.start_span(
                "service.batch", parent=tspan,
                annotations=dict(
                    corpus=corpus, batch=len(batch),
                    traced=sum(r.span is not None for r in batch),
                    queue_wait_s=time.perf_counter() - batch[0].t_submit))
        try:
            # inside the try: a malformed query (ragged dims) must fail the
            # batch, not kill the worker thread
            queries = np.stack([r.query for r in batch])
            k = max(r.k for r in batch)
            with obs_trace.activate(bspan):
                with self.pool.lease(corpus) as (idx, load_s):
                    out = self._search_fn(idx, queries, k)
            # a search_fn may return (ids, dists) — cluster shard workers
            # do, because the scatter-gather merge needs exact scores
            if isinstance(out, tuple):
                ids, dists = out
                dists = np.asarray(dists)
            else:
                ids = out
            ids = np.asarray(ids)        # malformed returns fail the batch
            if ids.ndim != 2 or ids.shape[0] != len(batch):
                raise ValueError(
                    f"search_fn returned shape {ids.shape}, expected "
                    f"({len(batch)}, k)")
            if dists is not None and dists.shape != ids.shape:
                raise ValueError(
                    f"search_fn dists shape {dists.shape} != ids shape "
                    f"{ids.shape}")
        except Exception as e:           # noqa: BLE001 — fail the batch,
            err = e                      # never kill the worker thread
        # feed the pool's circuit breaker: OSError covers raw I/O errors,
        # injected faults that exhausted their retries, and persistent
        # checksum failures (CorruptBlockError is an OSError with EIO) —
        # the failures that mean THIS CORPUS'S STORAGE is sick, as opposed
        # to e.g. a malformed query, which says nothing about the disk
        if err is None:
            self.pool.record_success(corpus)
        elif isinstance(err, OSError):
            self.pool.record_io_failure(corpus)
        if bspan is not None:
            bspan.annotate(load_s=load_s,
                           error=(type(err).__name__ if err else None))
            bspan.end()                  # before event.set(): the worker
        now = time.perf_counter()        # ships spans once the event fires
        with self._cond:
            tel = self._tel[corpus]
            tel.batches.inc()
            tel.batch_size.observe(len(batch))
            if load_s:
                tel.switch.observe(load_s)
            for i, r in enumerate(batch):
                r.t_done = now
                if err is not None:
                    r.error = err
                    tel.errors.inc()
                else:
                    r.result = ids[i, :r.k]
                    if dists is not None:
                        r.dists = dists[i, :r.k]
                    tel.completed.inc()
                    tel.latency.observe(r.latency_s)
                tel.last_done = now
                r.event.set()

    # -- telemetry -----------------------------------------------------------
    def stats(self) -> dict:
        """Legacy dict shape, rendered as a thin view over the metrics
        registry (percentiles are histogram-bucket-derived), plus the
        full registry snapshot under ``"registry"`` — the mergeable form
        T_STATS carries to the cluster supervisor."""
        with self._cond:
            corpora = {}
            for c, tel in self._tel.items():
                completed = int(tel.completed.value)
                batches = int(tel.batches.value)
                span = None
                if tel.first_submit is not None and tel.last_done is not None:
                    span = max(tel.last_done - tel.first_submit, 1e-9)
                tel.queue_depth.set(len(self._queues.get(c, ())))
                lat = tel.latency
                # the pool's per-handle SearchMetrics feeds these series
                # into the same registry; the idempotent getter returns
                # the live series (or an empty one, skipped below)
                hops = self.registry.histogram(
                    "traversal_hops", {"corpus": c}, buckets=COUNT_BUCKETS)
                conv = self.registry.histogram(
                    "traversal_convergence_hops", {"corpus": c},
                    buckets=COUNT_BUCKETS)
                corpora[c] = dict(
                    completed=completed,
                    rejected=int(tel.rejected.value),
                    errors=int(tel.errors.value),
                    expired=int(tel.expired.value),
                    unhealthy_rejected=int(tel.unhealthy_rejected.value),
                    batches=batches,
                    mean_batch=(completed / batches if batches else 0.0),
                    switches=tel.switch.count,
                    switch_ms_total=tel.switch.sum * 1e3,
                    qps=(completed / span if span else 0.0),
                    queued=len(self._queues.get(c, ())),
                    **({"p50_ms": lat.quantile(0.50) * 1e3,
                        "p95_ms": lat.quantile(0.95) * 1e3,
                        "p99_ms": lat.quantile(0.99) * 1e3}
                       if lat.count else {}),
                    **({"hops_p50": hops.quantile(0.50),
                        "hops_p95": hops.quantile(0.95),
                        "hops_p99": hops.quantile(0.99)}
                       if hops.count else {}),
                    **({"convergence_hops_p50": conv.quantile(0.50)}
                       if conv.count else {}))
            tels = list(self._tel.values())
            p50 = merged_quantile([t.latency for t in tels], 0.50)
            p99 = merged_quantile([t.latency for t in tels], 0.99)
            out = dict(
                corpora=corpora,
                total_completed=sum(int(t.completed.value) for t in tels),
                total_rejected=sum(int(t.rejected.value) for t in tels),
                total_expired=sum(int(t.expired.value) for t in tels),
                total_unhealthy_rejected=sum(
                    int(t.unhealthy_rejected.value) for t in tels),
                total_switches=sum(t.switch.count for t in tels),
                uptime_s=time.perf_counter() - self._t0,
                **({"p50_ms": p50 * 1e3, "p99_ms": p99 * 1e3}
                   if p50 is not None else {}))
        # pool snapshot taken OUTSIDE the service lock: the pool does its
        # own single-pass consistent capture under its own lock, and the
        # service never holds both locks at once (no ordering to get
        # wrong against serve-path pool calls).  The pool publishes its
        # gauges into the shared registry during stats(), so the registry
        # snapshot below already carries them.
        out["pool"] = self.pool.stats()
        out["registry"] = self.registry.snapshot()
        return out

    # -- lifecycle -----------------------------------------------------------
    def close(self, drain_s: float = 5.0, timeout: float = 5.0):
        """Graceful shutdown: stop admitting new requests (submits raise
        `ServiceClosedError` immediately), let the workers DRAIN what is
        already queued for up to `drain_s`, then fail whatever remains
        with the same typed error and join the workers.  This is what a
        cluster worker runs on SIGTERM — in-flight callers get answers
        or a typed rejection, never an abandoned request."""
        with self._cond:
            if self._stop:
                return
            self._closing = True
            self._cond.notify_all()
        deadline = time.perf_counter() + max(0.0, drain_s)
        while time.perf_counter() < deadline:
            with self._cond:
                if not any(self._queues.values()) and not self._busy:
                    break
            time.sleep(0.005)
        self.stop(timeout)

    def stop(self, timeout: float = 5.0):
        with self._cond:
            self._stop = True
            self._closing = True
            # fail whatever is still queued — nobody will serve it
            leftovers = [r for q in self._queues.values() for r in q]
            for q in self._queues.values():
                q.clear()
            self._cond.notify_all()
        err = ServiceClosedError("service stopped")
        for r in leftovers:
            r.error = err
            r.event.set()
        for t in self._workers:
            t.join(timeout)
