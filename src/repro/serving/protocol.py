"""Length-prefixed, CRC-framed request protocol over Unix sockets.

The multi-process serving tier (``serving.cluster`` workers +
``serving.router``) speaks this wire format.  It deliberately reuses the
write-ahead journal's framing discipline (``core.wal``): a fixed header
with magic + lengths, a JSON header, a raw binary blob, and a CRC32
trailer over everything but the magic.  The failure contract mirrors the
journal's too:

  * a frame whose magic, bounds, or CRC fails validation raises the
    typed ``ProtocolError`` — the receiver treats the CONNECTION as
    poisoned (a stream protocol cannot resynchronize past a corrupt
    length field) and drops it; the router counts a failed shard attempt
    and retries or degrades, it never consumes garbage,
  * a clean EOF between frames raises ``ConnectionClosed`` (the peer
    went away — for a worker socket that usually means SIGKILL),
  * an EOF or timeout *mid-frame* is a torn frame: also
    ``ConnectionClosed`` — the caller cannot tell a crash from a torn
    write, and must not need to,
  * a declared payload larger than ``MAX_FRAME_BYTES`` is rejected
    before any allocation happens (a flipped length bit must not turn
    into a multi-GB allocation).

Every socket operation the helpers issue honors the socket's configured
timeout — the never-hang half of the router's degradation contract.

Query/result payloads ride as raw little-endian arrays in the blob with
dtype/shape in the JSON header (``encode_query``/``decode_query``,
``encode_result``/``decode_result``) so no pickle ever crosses a
process boundary.
"""
from __future__ import annotations

import json
import struct
import zlib
from typing import Optional, Tuple

import numpy as np

__all__ = [
    "ProtocolError", "ConnectionClosed",
    "T_SEARCH", "T_RESULT", "T_ERROR", "T_PING", "T_PONG",
    "T_STATS", "T_STATS_REPLY", "T_SHUTDOWN",
    "pack_frame", "send_frame", "recv_frame",
    "encode_query", "decode_query", "encode_result", "decode_result",
    "trace_context",
]

# frame types
T_SEARCH = 1        # router -> worker: one query
T_RESULT = 2        # worker -> router: ids + dists
T_ERROR = 3         # worker -> router: typed failure for one request
T_PING = 4          # supervisor/router -> worker: heartbeat probe
T_PONG = 5          # worker -> prober
T_STATS = 6         # -> worker: telemetry snapshot request
T_STATS_REPLY = 7
T_SHUTDOWN = 8      # -> worker: graceful drain + exit

_MAGIC = 0x31515341                     # "ASQ1"
_HDR = struct.Struct("<IBII")           # magic, type, hlen, blen
_CRC = struct.Struct("<I")

#: upper bound on header+blob of one frame — a corrupt length field must
#: fail loudly, not allocate gigabytes
MAX_FRAME_BYTES = 64 << 20


class ProtocolError(RuntimeError):
    """Corrupt or malformed frame — the connection is unrecoverable."""


class ConnectionClosed(ProtocolError):
    """Peer closed the connection (cleanly between frames or mid-frame —
    the reader cannot distinguish a crash from a torn write)."""


def pack_frame(rtype: int, header: dict, blob: bytes = b"") -> bytes:
    hj = json.dumps(header, separators=(",", ":")).encode()
    body = _HDR.pack(_MAGIC, rtype, len(hj), len(blob)) + hj + blob
    crc = zlib.crc32(body[4:]) & 0xFFFFFFFF    # over type|lens|header|blob
    return body + _CRC.pack(crc)


def send_frame(sock, rtype: int, header: dict, blob: bytes = b""):
    """One sendall — the frame is small enough to serialize in memory and
    a partial send on a blocking socket surfaces as the socket error the
    caller already handles."""
    sock.sendall(pack_frame(rtype, header, blob))


def _recv_exact(sock, n: int) -> bytes:
    """Read exactly n bytes; ConnectionClosed on EOF (clean or torn)."""
    chunks = []
    got = 0
    while got < n:
        b = sock.recv(min(n - got, 1 << 20))
        if not b:
            raise ConnectionClosed(
                f"peer closed with {n - got} of {n} bytes outstanding")
        chunks.append(b)
        got += len(b)
    return b"".join(chunks)


def recv_frame(sock) -> Tuple[int, dict, bytes]:
    """Read one whole frame.  Raises ConnectionClosed on EOF,
    ProtocolError on a corrupt frame, socket.timeout/OSError pass
    through from the socket layer."""
    head = _recv_exact(sock, _HDR.size)
    magic, rtype, hlen, blen = _HDR.unpack(head)
    if magic != _MAGIC:
        raise ProtocolError(f"bad frame magic 0x{magic:08x}")
    if hlen + blen > MAX_FRAME_BYTES:
        raise ProtocolError(
            f"frame declares {hlen + blen} payload bytes "
            f"(> {MAX_FRAME_BYTES}); corrupt length field")
    payload = _recv_exact(sock, hlen + blen + _CRC.size)
    body = head[4:] + payload[:hlen + blen]
    (crc,) = _CRC.unpack_from(payload, hlen + blen)
    if zlib.crc32(body) & 0xFFFFFFFF != crc:
        raise ProtocolError("frame CRC mismatch (corrupt stream)")
    try:
        header = json.loads(payload[:hlen])
    except ValueError as e:
        raise ProtocolError(f"frame header is not JSON: {e}") from None
    if not isinstance(header, dict):
        raise ProtocolError("frame header is not an object")
    return rtype, header, payload[hlen:hlen + blen]


# -- payload codecs ----------------------------------------------------------


def encode_query(q: np.ndarray, *, corpus: str, k: int, req_id: int,
                 deadline_s: Optional[float],
                 trace: Optional[dict] = None) -> Tuple[dict, bytes]:
    """`trace` is an optional {tid, sid} span context (obs.trace): it
    rides the JSON header, so old receivers ignore it and old senders
    simply never trace — the frame format itself is unchanged."""
    q = np.ascontiguousarray(q, dtype=np.float32)
    header = dict(req_id=req_id, corpus=corpus, k=int(k),
                  dim=int(q.shape[-1]),
                  deadline_s=(None if deadline_s is None
                              else float(deadline_s)))
    if trace is not None:
        header["trace"] = dict(tid=str(trace["tid"]),
                               sid=str(trace["sid"]))
    return header, q.tobytes()


def trace_context(header: dict) -> Optional[dict]:
    """The span context a query frame carries, or None.  Malformed
    contexts (wrong shape, non-string ids) are treated as absent — a
    corrupted optional field must degrade to an untraced query, never
    fail it."""
    ctx = header.get("trace")
    if not isinstance(ctx, dict):
        return None
    tid, sid = ctx.get("tid"), ctx.get("sid")
    if not (isinstance(tid, str) and isinstance(sid, str) and tid and sid):
        return None
    return dict(tid=tid, sid=sid)


def decode_query(header: dict, blob: bytes) -> np.ndarray:
    dim = int(header["dim"])
    q = np.frombuffer(blob, dtype=np.float32)
    if q.size != dim:
        raise ProtocolError(
            f"query blob holds {q.size} floats, header says {dim}")
    return q


def encode_result(ids: np.ndarray, dists: np.ndarray, *, req_id: int,
                  spans: Optional[list] = None) -> Tuple[dict, bytes]:
    """`spans` is the worker's finished span list for this request's
    trace (obs.trace dicts) — it rides the JSON header back to the
    router, which ingests it into the query's trace."""
    ids = np.ascontiguousarray(ids, dtype=np.int64)
    dists = np.ascontiguousarray(dists, dtype=np.float32)
    header = dict(req_id=req_id, k=int(ids.shape[-1]))
    if spans:
        header["spans"] = list(spans)
    return header, ids.tobytes() + dists.tobytes()


def decode_result(header: dict, blob: bytes
                  ) -> Tuple[np.ndarray, np.ndarray]:
    k = int(header["k"])
    need = k * (8 + 4)
    if len(blob) != need:
        raise ProtocolError(
            f"result blob holds {len(blob)} bytes, header implies {need}")
    ids = np.frombuffer(blob[:k * 8], dtype=np.int64)
    dists = np.frombuffer(blob[k * 8:], dtype=np.float32)
    return ids, dists
