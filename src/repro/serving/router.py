"""Scatter-gather shard router with explicit partial-result degradation.

One query fans out to every shard, each shard answers its local top-k
with EXACT distances, and `core.shard_math.merge_topk` folds the partial
lists into a global top-k — the same merge the device mesh performs with
all-gather + `lax.top_k`, so a full-coverage routed answer is
bit-identical to a single-process reference over the same shards.

Failure is a first-class outcome, with a strict contract:

  * NEVER HANG — every shard attempt carries `shard_deadline_s`; a
    worker that doesn't answer in time counts as failed for this query,
  * NEVER SILENTLY SHORT — a result that lacks any shard's coverage is
    flagged `partial=True` with `shards_answered`/`shards_failed`
    telemetry; the caller decides whether a partial answer is
    acceptable, the router never passes one off as complete,
  * one HEDGED RETRY — a failed shard gets exactly one more attempt
    against a freshly resolved endpoint (the supervisor may have
    respawned the worker since the first try); retry storms are capped
    by construction,
  * QUORUM — fewer than `min_shards` answers raises the typed
    `DegradedServiceError` (a clean rejection, distinguishable from
    both success and partial success).

`ShardClient` is the transport abstraction: `SocketShardClient` speaks
the CRC-framed protocol to cluster workers (one connection per router
thread — connections are not multiplexed, parallelism comes from
threads); `LocalShardClient` wraps any in-process callable, which is how
the single-process reference for drills and the DEVICE-tier per-shard
search mount under the same router.
"""
from __future__ import annotations

import socket
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.shard_math import merge_topk
from repro.serving import protocol as proto

__all__ = ["ShardUnavailableError", "DegradedServiceError", "ShardClient",
           "SocketShardClient", "LocalShardClient", "RouterResult",
           "ShardRouter"]


class ShardUnavailableError(RuntimeError):
    """One shard attempt failed (connect/timeout/protocol/worker error).
    Router-internal: surfaces to callers only in aggregate, as partial
    results or DegradedServiceError."""


class DegradedServiceError(RuntimeError):
    """Fewer than `min_shards` shards answered — the router rejects the
    query cleanly rather than return an answer below quorum."""

    def __init__(self, answered: int, total: int, min_shards: int):
        super().__init__(
            f"only {answered}/{total} shards answered "
            f"(quorum min_shards={min_shards})")
        self.answered = answered
        self.total = total
        self.min_shards = min_shards


class ShardClient:
    """Transport to one shard: `search` returns (ids, dists) or raises
    ShardUnavailableError.  Implementations must be thread-safe."""

    def search(self, query: np.ndarray, k: int, *, corpus: str = "default",
               deadline_s: Optional[float] = None
               ) -> Tuple[np.ndarray, np.ndarray]:
        raise NotImplementedError

    def reset(self):
        """Drop cached transport state (e.g. reconnect after a respawn)."""

    def close(self):
        pass


class SocketShardClient(ShardClient):
    """CRC-framed protocol client over a Unix socket.

    Connections are per-thread (`threading.local`): the worker serves
    one connection sequentially, so router-side parallelism maps each
    scatter thread to its own connection.  Any transport or protocol
    failure closes the connection (a framed stream cannot resync past
    corruption) and raises ShardUnavailableError; the next call
    reconnects — which is exactly what a hedged retry to a respawned
    worker needs."""

    def __init__(self, socket_path: str, *,
                 connect_timeout_s: float = 1.0):
        self.socket_path = socket_path
        self.connect_timeout_s = connect_timeout_s
        self._tls = threading.local()
        self._epoch = 0                # bumped by reset(): force reconnect
        self._next_id = 0
        self._id_lock = threading.Lock()

    def _conn(self, deadline_s: Optional[float]) -> socket.socket:
        tls = self._tls
        if getattr(tls, "sock", None) is None \
                or getattr(tls, "epoch", -1) != self._epoch:
            self._drop()
            s = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
            s.settimeout(self.connect_timeout_s)
            s.connect(self.socket_path)
            tls.sock = s
            tls.epoch = self._epoch
        tls.sock.settimeout(deadline_s)
        return tls.sock

    def _drop(self):
        s = getattr(self._tls, "sock", None)
        if s is not None:
            try:
                s.close()
            except OSError:
                pass
        self._tls.sock = None

    def _req_id(self) -> int:
        with self._id_lock:
            self._next_id += 1
            return self._next_id

    def search(self, query, k, *, corpus="default", deadline_s=None):
        rid = self._req_id()
        try:
            sock = self._conn(deadline_s)
            h, b = proto.encode_query(np.asarray(query), corpus=corpus,
                                      k=k, req_id=rid,
                                      deadline_s=deadline_s)
            proto.send_frame(sock, proto.T_SEARCH, h, b)
            rtype, header, blob = proto.recv_frame(sock)
        except (proto.ProtocolError, OSError, socket.timeout) as e:
            self._drop()
            raise ShardUnavailableError(
                f"{self.socket_path}: {type(e).__name__}: {e}") from e
        if rtype == proto.T_ERROR:
            # worker answered with a typed rejection — the connection is
            # still good, only this request failed
            raise ShardUnavailableError(
                f"{self.socket_path}: worker error "
                f"{header.get('etype')}: {header.get('msg')}")
        if rtype != proto.T_RESULT or header.get("req_id") != rid:
            self._drop()               # desynchronized: poison the conn
            raise ShardUnavailableError(
                f"{self.socket_path}: unexpected frame type {rtype}")
        try:
            return proto.decode_result(header, blob)
        except proto.ProtocolError as e:
            self._drop()
            raise ShardUnavailableError(str(e)) from e

    def reset(self):
        self._epoch += 1               # every thread reconnects lazily

    def close(self):
        self._drop()


class LocalShardClient(ShardClient):
    """In-process shard: wraps `fn(query, k) -> (ids, dists)`.

    Mounts anything callable under the router — the single-process
    reference in drills, a device-tier per-shard search, a stub in
    tests.  Exceptions map to ShardUnavailableError like a dead
    worker's socket would."""

    def __init__(self, fn: Callable, name: str = "local"):
        self.fn = fn
        self.name = name

    def search(self, query, k, *, corpus="default", deadline_s=None):
        try:
            ids, dists = self.fn(np.asarray(query), k)
            return np.asarray(ids, np.int64), np.asarray(dists, np.float32)
        except Exception as e:         # noqa: BLE001 — any local failure
            raise ShardUnavailableError(
                f"{self.name}: {type(e).__name__}: {e}") from e


@dataclass
class RouterResult:
    """One routed answer with its coverage telemetry."""
    ids: np.ndarray                    # (k,) global labels, -1 padding
    dists: np.ndarray                  # (k,) exact f32, +inf padding
    partial: bool                      # True: >=1 shard missing
    shards_answered: int
    shards_failed: int
    failed_shards: List[int] = field(default_factory=list)
    retried_shards: List[int] = field(default_factory=list)
    latency_s: float = 0.0


class ShardRouter:
    """Scatter-gather over a fixed shard set.

    `clients`: one ShardClient per shard (index = shard id).
    `endpoints_fn`: optional `() -> [socket_path | None per shard]`
    (e.g. `ShardCluster.endpoints`) consulted before the hedged retry so
    the retry targets the CURRENT worker, not the corpse the first
    attempt hit; shards currently reported None skip their retry (no
    point knocking on a quarantined door).
    """

    def __init__(self, clients: Sequence[ShardClient], *,
                 min_shards: int = 1,
                 shard_deadline_s: float = 2.0,
                 hedge_retry: bool = True,
                 endpoints_fn: Optional[Callable[[], List[Optional[str]]]]
                 = None):
        if not clients:
            raise ValueError("router needs at least one shard client")
        self.clients = list(clients)
        self.min_shards = int(min_shards)
        if not 1 <= self.min_shards <= len(self.clients):
            raise ValueError(
                f"min_shards={min_shards} outside [1, {len(self.clients)}]")
        self.shard_deadline_s = float(shard_deadline_s)
        self.hedge_retry = hedge_retry
        self.endpoints_fn = endpoints_fn
        self._pool = ThreadPoolExecutor(
            max_workers=max(2, len(self.clients)),
            thread_name_prefix="router-scatter")
        self._lock = threading.Lock()
        self._tel = dict(queries=0, full=0, partial=0, rejected=0,
                         shard_attempts=0, shard_failures=0, retries=0,
                         retry_successes=0)

    # -- per-shard attempt ---------------------------------------------------
    def _ask(self, shard: int, query, k, corpus
             ) -> Tuple[Optional[Tuple[np.ndarray, np.ndarray]], bool]:
        """One shard's answer with up to one hedged retry.
        Returns ((ids, dists) | None, retried)."""
        client = self.clients[shard]
        with self._lock:
            self._tel["shard_attempts"] += 1
        try:
            return client.search(query, k, corpus=corpus,
                                 deadline_s=self.shard_deadline_s), False
        except ShardUnavailableError:
            with self._lock:
                self._tel["shard_failures"] += 1
            if not self.hedge_retry:
                return None, False
        # hedged retry: re-resolve the endpoint first — the supervisor
        # may have respawned the worker since the failed attempt
        if self.endpoints_fn is not None:
            eps = self.endpoints_fn()
            ep = eps[shard] if shard < len(eps) else None
            if ep is None:
                return None, False     # shard is known-down: don't knock
            if isinstance(client, SocketShardClient) \
                    and ep != client.socket_path:
                client.socket_path = ep
            client.reset()
        with self._lock:
            self._tel["retries"] += 1
            self._tel["shard_attempts"] += 1
        try:
            out = client.search(query, k, corpus=corpus,
                                deadline_s=self.shard_deadline_s)
            with self._lock:
                self._tel["retry_successes"] += 1
            return out, True
        except ShardUnavailableError:
            with self._lock:
                self._tel["shard_failures"] += 1
            return None, True

    # -- public API ----------------------------------------------------------
    def search(self, query: np.ndarray, k: int, *,
               corpus: str = "default") -> RouterResult:
        """Scatter `query` to every shard, gather within the per-shard
        deadline, merge.  Raises DegradedServiceError below quorum."""
        t0 = time.perf_counter()
        with self._lock:
            self._tel["queries"] += 1
        futs = [self._pool.submit(self._ask, s, query, k, corpus)
                for s in range(len(self.clients))]
        parts_ids: List[np.ndarray] = []
        parts_dists: List[np.ndarray] = []
        failed: List[int] = []
        retried: List[int] = []
        for s, f in enumerate(futs):
            out, did_retry = f.result()   # _ask never raises; bounded by
            if did_retry:                 # 2x shard deadline + connect
                retried.append(s)
            if out is None:
                failed.append(s)
            else:
                parts_ids.append(out[0])
                parts_dists.append(out[1])
        answered = len(self.clients) - len(failed)
        if answered < self.min_shards:
            with self._lock:
                self._tel["rejected"] += 1
            raise DegradedServiceError(answered, len(self.clients),
                                       self.min_shards)
        ids, dists = merge_topk(parts_ids, parts_dists, k)
        partial = bool(failed)
        with self._lock:
            self._tel["partial" if partial else "full"] += 1
        return RouterResult(ids=ids, dists=dists, partial=partial,
                            shards_answered=answered,
                            shards_failed=len(failed),
                            failed_shards=failed, retried_shards=retried,
                            latency_s=time.perf_counter() - t0)

    def stats(self) -> dict:
        with self._lock:
            return dict(self._tel)

    def close(self):
        self._pool.shutdown(wait=False)
        for c in self.clients:
            c.close()
