"""Scatter-gather shard router with explicit partial-result degradation.

One query fans out to every shard, each shard answers its local top-k
with EXACT distances, and `core.shard_math.merge_topk` folds the partial
lists into a global top-k — the same merge the device mesh performs with
all-gather + `lax.top_k`, so a full-coverage routed answer is
bit-identical to a single-process reference over the same shards.

Failure is a first-class outcome, with a strict contract:

  * NEVER HANG — every shard attempt carries `shard_deadline_s`; a
    worker that doesn't answer in time counts as failed for this query,
  * NEVER SILENTLY SHORT — a result that lacks any shard's coverage is
    flagged `partial=True` with `shards_answered`/`shards_failed`
    telemetry; the caller decides whether a partial answer is
    acceptable, the router never passes one off as complete,
  * one HEDGED RETRY — a failed shard gets exactly one more attempt
    against a freshly resolved endpoint (the supervisor may have
    respawned the worker since the first try); retry storms are capped
    by construction,
  * QUORUM — fewer than `min_shards` answers raises the typed
    `DegradedServiceError` (a clean rejection, distinguishable from
    both success and partial success).

`ShardClient` is the transport abstraction: `SocketShardClient` speaks
the CRC-framed protocol to cluster workers (one connection per router
thread — connections are not multiplexed, parallelism comes from
threads); `LocalShardClient` wraps any in-process callable, which is how
the single-process reference for drills and the DEVICE-tier per-shard
search mount under the same router.
"""
from __future__ import annotations

import socket
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.shard_math import merge_topk
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import Tracer
from repro.serving import protocol as proto

__all__ = ["ShardUnavailableError", "DegradedServiceError", "ShardClient",
           "SocketShardClient", "LocalShardClient", "RouterResult",
           "ShardRouter"]


class ShardUnavailableError(RuntimeError):
    """One shard attempt failed (connect/timeout/protocol/worker error).
    Router-internal: surfaces to callers only in aggregate, as partial
    results or DegradedServiceError."""


class DegradedServiceError(RuntimeError):
    """Fewer than `min_shards` shards answered — the router rejects the
    query cleanly rather than return an answer below quorum."""

    def __init__(self, answered: int, total: int, min_shards: int):
        super().__init__(
            f"only {answered}/{total} shards answered "
            f"(quorum min_shards={min_shards})")
        self.answered = answered
        self.total = total
        self.min_shards = min_shards


class ShardClient:
    """Transport to one shard: `search` returns (ids, dists) or raises
    ShardUnavailableError.  Implementations must be thread-safe."""

    def search(self, query: np.ndarray, k: int, *, corpus: str = "default",
               deadline_s: Optional[float] = None,
               trace: Optional[dict] = None
               ) -> Tuple[np.ndarray, np.ndarray]:
        """`trace` is an obs span context dict ({tid, sid}); a transport
        that propagates it appends the remote side's finished spans to
        trace["spans"]."""
        raise NotImplementedError

    def reset(self):
        """Drop cached transport state (e.g. reconnect after a respawn)."""

    def close(self):
        pass


class SocketShardClient(ShardClient):
    """CRC-framed protocol client over a Unix socket.

    Connections are per-thread (`threading.local`): the worker serves
    one connection sequentially, so router-side parallelism maps each
    scatter thread to its own connection.  Any transport or protocol
    failure closes the connection (a framed stream cannot resync past
    corruption) and raises ShardUnavailableError; the next call
    reconnects — which is exactly what a hedged retry to a respawned
    worker needs."""

    def __init__(self, socket_path: str, *,
                 connect_timeout_s: float = 1.0):
        self.socket_path = socket_path
        self.connect_timeout_s = connect_timeout_s
        self._tls = threading.local()
        self._epoch = 0                # bumped by reset(): force reconnect
        self._next_id = 0
        self._id_lock = threading.Lock()

    def _conn(self, deadline_s: Optional[float]) -> socket.socket:
        tls = self._tls
        if getattr(tls, "sock", None) is None \
                or getattr(tls, "epoch", -1) != self._epoch:
            self._drop()
            s = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
            s.settimeout(self.connect_timeout_s)
            s.connect(self.socket_path)
            tls.sock = s
            tls.epoch = self._epoch
        tls.sock.settimeout(deadline_s)
        return tls.sock

    def _drop(self):
        s = getattr(self._tls, "sock", None)
        if s is not None:
            try:
                s.close()
            except OSError:
                pass
        self._tls.sock = None

    def _req_id(self) -> int:
        with self._id_lock:
            self._next_id += 1
            return self._next_id

    def search(self, query, k, *, corpus="default", deadline_s=None,
               trace=None):
        rid = self._req_id()
        try:
            sock = self._conn(deadline_s)
            h, b = proto.encode_query(np.asarray(query), corpus=corpus,
                                      k=k, req_id=rid,
                                      deadline_s=deadline_s, trace=trace)
            proto.send_frame(sock, proto.T_SEARCH, h, b)
            rtype, header, blob = proto.recv_frame(sock)
        except (proto.ProtocolError, OSError, socket.timeout) as e:
            self._drop()
            raise ShardUnavailableError(
                f"{self.socket_path}: {type(e).__name__}: {e}") from e
        if rtype == proto.T_ERROR:
            # worker answered with a typed rejection — the connection is
            # still good, only this request failed
            raise ShardUnavailableError(
                f"{self.socket_path}: worker error "
                f"{header.get('etype')}: {header.get('msg')}")
        if rtype != proto.T_RESULT or header.get("req_id") != rid:
            self._drop()               # desynchronized: poison the conn
            raise ShardUnavailableError(
                f"{self.socket_path}: unexpected frame type {rtype}")
        if trace is not None and isinstance(header.get("spans"), list):
            # the worker's finished spans for this trace ride the result
            # header; hand them to the caller for tracer ingestion
            trace.setdefault("spans", []).extend(header["spans"])
        try:
            return proto.decode_result(header, blob)
        except proto.ProtocolError as e:
            self._drop()
            raise ShardUnavailableError(str(e)) from e

    def reset(self):
        self._epoch += 1               # every thread reconnects lazily

    def close(self):
        self._drop()


class LocalShardClient(ShardClient):
    """In-process shard: wraps `fn(query, k) -> (ids, dists)`.

    Mounts anything callable under the router — the single-process
    reference in drills, a device-tier per-shard search, a stub in
    tests.  Exceptions map to ShardUnavailableError like a dead
    worker's socket would."""

    def __init__(self, fn: Callable, name: str = "local"):
        self.fn = fn
        self.name = name

    def search(self, query, k, *, corpus="default", deadline_s=None,
               trace=None):
        try:
            ids, dists = self.fn(np.asarray(query), k)
            return np.asarray(ids, np.int64), np.asarray(dists, np.float32)
        except Exception as e:         # noqa: BLE001 — any local failure
            raise ShardUnavailableError(
                f"{self.name}: {type(e).__name__}: {e}") from e


@dataclass
class RouterResult:
    """One routed answer with its coverage telemetry."""
    ids: np.ndarray                    # (k,) global labels, -1 padding
    dists: np.ndarray                  # (k,) exact f32, +inf padding
    partial: bool                      # True: >=1 shard missing
    shards_answered: int
    shards_failed: int
    failed_shards: List[int] = field(default_factory=list)
    retried_shards: List[int] = field(default_factory=list)
    latency_s: float = 0.0


class ShardRouter:
    """Scatter-gather over a fixed shard set.

    `clients`: one ShardClient per shard (index = shard id).
    `endpoints_fn`: optional `() -> [socket_path | None per shard]`
    (e.g. `ShardCluster.endpoints`) consulted before the hedged retry so
    the retry targets the CURRENT worker, not the corpse the first
    attempt hit; shards currently reported None skip their retry (no
    point knocking on a quarantined door).
    """

    def __init__(self, clients: Sequence[ShardClient], *,
                 min_shards: int = 1,
                 shard_deadline_s: float = 2.0,
                 hedge_retry: bool = True,
                 endpoints_fn: Optional[Callable[[], List[Optional[str]]]]
                 = None,
                 tracer: Optional[Tracer] = None,
                 registry: Optional[MetricsRegistry] = None):
        if not clients:
            raise ValueError("router needs at least one shard client")
        self.clients = list(clients)
        self.min_shards = int(min_shards)
        if not 1 <= self.min_shards <= len(self.clients):
            raise ValueError(
                f"min_shards={min_shards} outside [1, {len(self.clients)}]")
        self.shard_deadline_s = float(shard_deadline_s)
        self.hedge_retry = hedge_retry
        self.endpoints_fn = endpoints_fn
        self.tracer = tracer
        self.registry = registry or MetricsRegistry()
        reg = self.registry
        self._c_queries = reg.counter(
            "router_queries_total", help="queries accepted by the router")
        self._c_answers = {
            o: reg.counter("router_answers_total",
                           help="routed answers by outcome",
                           labels={"outcome": o})
            for o in ("full", "partial", "rejected")}
        self._c_attempts = {
            a: reg.counter("router_shard_attempts_total",
                           help="per-shard attempts by kind",
                           labels={"attempt": a})
            for a in ("first", "hedge")}
        self._c_failures = {
            a: reg.counter("router_shard_failures_total",
                           help="failed per-shard attempts by kind",
                           labels={"attempt": a})
            for a in ("first", "hedge")}
        self._c_retry_ok = reg.counter(
            "router_retry_success_total",
            help="hedged retries that produced an answer")
        self._h_latency = reg.histogram(
            "router_latency_seconds", unit="s",
            help="end-to-end routed query latency")
        self._h_attempt = {
            a: reg.histogram("router_attempt_latency_seconds", unit="s",
                             help="per-shard attempt latency by kind "
                                  "(hedge vs first shows hedge payoff)",
                             labels={"attempt": a})
            for a in ("first", "hedge")}
        self._pool = ThreadPoolExecutor(
            max_workers=max(2, len(self.clients)),
            thread_name_prefix="router-scatter")

    # -- per-shard attempt ---------------------------------------------------
    def _attempt(self, shard: int, query, k, corpus, kind: str,
                 root_span=None):
        """One timed transport attempt ('first' | 'hedge').  Returns
        (ids, dists) or None; never raises."""
        client = self.clients[shard]
        self._c_attempts[kind].inc()
        ctx = None
        sp = None
        if root_span is not None:
            sp = root_span.tracer.start_span(
                f"router.shard{shard}", parent=root_span,
                annotations=dict(shard=shard, attempt=kind, corpus=corpus))
            ctx = root_span.tracer.context(sp)
        t0 = time.perf_counter()
        try:
            out = client.search(query, k, corpus=corpus,
                                deadline_s=self.shard_deadline_s,
                                trace=ctx)
            self._h_attempt[kind].observe(time.perf_counter() - t0)
            if sp is not None:
                sp.annotate(ok=True)
            return out
        except ShardUnavailableError as e:
            self._h_attempt[kind].observe(time.perf_counter() - t0)
            self._c_failures[kind].inc()
            if sp is not None:
                sp.annotate(ok=False, error=str(e))
            return None
        finally:
            if sp is not None:
                sp.end()
                if ctx and ctx.get("spans"):
                    root_span.tracer.ingest(ctx["spans"])

    def _ask(self, shard: int, query, k, corpus, root_span=None
             ) -> Tuple[Optional[Tuple[np.ndarray, np.ndarray]], bool]:
        """One shard's answer with up to one hedged retry.
        Returns ((ids, dists) | None, retried)."""
        out = self._attempt(shard, query, k, corpus, "first",
                            root_span=root_span)
        if out is not None:
            return out, False
        if not self.hedge_retry:
            return None, False
        # hedged retry: re-resolve the endpoint first — the supervisor
        # may have respawned the worker since the failed attempt
        client = self.clients[shard]
        if self.endpoints_fn is not None:
            eps = self.endpoints_fn()
            ep = eps[shard] if shard < len(eps) else None
            if ep is None:
                return None, False     # shard is known-down: don't knock
            if isinstance(client, SocketShardClient) \
                    and ep != client.socket_path:
                client.socket_path = ep
            client.reset()
        out = self._attempt(shard, query, k, corpus, "hedge",
                            root_span=root_span)
        if out is not None:
            self._c_retry_ok.inc()
        return out, True

    # -- public API ----------------------------------------------------------
    def search(self, query: np.ndarray, k: int, *,
               corpus: str = "default") -> RouterResult:
        """Scatter `query` to every shard, gather within the per-shard
        deadline, merge.  Raises DegradedServiceError below quorum."""
        t0 = time.perf_counter()
        self._c_queries.inc()
        root = None
        if self.tracer is not None and self.tracer.sampled():
            root = self.tracer.start_span(
                "router.search",
                annotations=dict(corpus=corpus, k=int(k),
                                 shards=len(self.clients)))
        try:
            futs = [self._pool.submit(self._ask, s, query, k, corpus, root)
                    for s in range(len(self.clients))]
            parts_ids: List[np.ndarray] = []
            parts_dists: List[np.ndarray] = []
            failed: List[int] = []
            retried: List[int] = []
            for s, f in enumerate(futs):
                out, did_retry = f.result()  # _ask never raises; bounded by
                if did_retry:                # 2x shard deadline + connect
                    retried.append(s)
                if out is None:
                    failed.append(s)
                else:
                    parts_ids.append(out[0])
                    parts_dists.append(out[1])
            answered = len(self.clients) - len(failed)
            if answered < self.min_shards:
                self._c_answers["rejected"].inc()
                if root is not None:
                    root.annotate(outcome="rejected", answered=answered)
                raise DegradedServiceError(answered, len(self.clients),
                                           self.min_shards)
            ids, dists = merge_topk(parts_ids, parts_dists, k)
            partial = bool(failed)
            self._c_answers["partial" if partial else "full"].inc()
            lat = time.perf_counter() - t0
            self._h_latency.observe(lat)
            if root is not None:
                root.annotate(outcome="partial" if partial else "full",
                              answered=answered, failed=len(failed))
            return RouterResult(ids=ids, dists=dists, partial=partial,
                                shards_answered=answered,
                                shards_failed=len(failed),
                                failed_shards=failed,
                                retried_shards=retried,
                                latency_s=lat)
        finally:
            if root is not None:
                root.end()

    def stats(self) -> dict:
        """Compat view over the registry: the historical flat-counter
        shape plus the first/hedge latency split and a full snapshot."""
        first = self._h_attempt["first"]
        hedge = self._h_attempt["hedge"]

        def _lat(h):
            if not h.count:
                return None
            return dict(count=int(h.count),
                        mean_ms=h.sum / h.count * 1e3,
                        p50_ms=(h.quantile(0.50) or 0.0) * 1e3,
                        p99_ms=(h.quantile(0.99) or 0.0) * 1e3)

        out = dict(
            queries=int(self._c_queries.value),
            full=int(self._c_answers["full"].value),
            partial=int(self._c_answers["partial"].value),
            rejected=int(self._c_answers["rejected"].value),
            shard_attempts=int(self._c_attempts["first"].value
                               + self._c_attempts["hedge"].value),
            shard_failures=int(self._c_failures["first"].value
                               + self._c_failures["hedge"].value),
            retries=int(self._c_attempts["hedge"].value),
            retry_successes=int(self._c_retry_ok.value),
            attempt_latency=dict(first=_lat(first), hedge=_lat(hedge)),
        )
        out["registry"] = self.registry.snapshot()
        return out

    def close(self):
        self._pool.shutdown(wait=False)
        for c in self.clients:
            c.close()
