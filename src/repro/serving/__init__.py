"""Serving layer: batching engine, warm-index pool, multi-tenant service,
and the multi-process cluster tier.

  engine   — `ServingEngine` (single-loop batching + hedging) and the
             `make_host_search_fn` / `make_device_search_fn` /
             `make_host_search_dist_fn` factories
  pool     — `WarmIndexPool`, the byte-budgeted LRU of open HostIndex
             handles with shared-centroid dedup and pin/unpin
  service  — `RetrievalService`, per-corpus queues + concurrent workers +
             admission control over a pool
  protocol — length-prefixed CRC-framed wire format (Unix sockets)
  cluster  — `ShardCluster`, a supervisor spawning one worker process
             per shard with heartbeats / backoff respawn / quarantine
  router   — `ShardRouter`, scatter-gather with partial-result
             degradation over `ShardClient` transports

This package's import chain is deliberately jax-free so spawned cluster
workers start in fractions of a second; `cluster`/`router` are imported
lazily here for the same reason plus to keep optional deps optional.
"""
from repro.serving.engine import (Request, ServingEngine,
                                  exact_distances, make_device_search_fn,
                                  make_host_search_dist_fn,
                                  make_host_search_fn)
from repro.serving.pool import CorpusUnhealthyError, WarmIndexPool
from repro.serving.service import (BackpressureError, RetrievalService,
                                   ServiceClosedError)

__all__ = ["Request", "ServingEngine", "make_device_search_fn",
           "make_host_search_fn", "make_host_search_dist_fn",
           "exact_distances", "WarmIndexPool", "BackpressureError",
           "CorpusUnhealthyError", "ServiceClosedError",
           "RetrievalService"]
