"""Serving layer: batching engine, warm-index pool, multi-tenant service.

  engine   — `ServingEngine` (single-loop batching + hedging) and the
             `make_host_search_fn` / `make_device_search_fn` factories
  pool     — `WarmIndexPool`, the byte-budgeted LRU of open HostIndex
             handles with shared-centroid dedup and pin/unpin
  service  — `RetrievalService`, per-corpus queues + concurrent workers +
             admission control over a pool
"""
from repro.serving.engine import (Request, ServingEngine,
                                  make_device_search_fn, make_host_search_fn)
from repro.serving.pool import CorpusUnhealthyError, WarmIndexPool
from repro.serving.service import BackpressureError, RetrievalService

__all__ = ["Request", "ServingEngine", "make_device_search_fn",
           "make_host_search_fn", "WarmIndexPool", "BackpressureError",
           "CorpusUnhealthyError", "RetrievalService"]
