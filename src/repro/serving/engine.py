"""Batched query-serving engine with hedged requests (straggler mitigation).

The paper's serving story (RAG retriever): requests arrive for possibly
different corpora; the engine batches per-corpus, switches indices (AiSAQ
makes that ms-order), and runs the search backend. `hedge=2` issues each
batch to two replicas and takes the first SUCCESSFUL completion — the
classic tail-latency-at-scale mitigation for the multi-server tier; work
the losing replicas still performed is accounted in `hedge_stats`.

This engine serializes every corpus through one loop thread; the
multi-tenant layer that serves corpora concurrently from a warm-index
pool is `serving.service.RetrievalService` + `serving.pool.WarmIndexPool`.
"""
from __future__ import annotations

import queue
import threading
import time
from collections import deque
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor, wait
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import numpy as np


def make_device_search_fn(index, layout, *, metric: str = "l2", L: int = 48,
                          w: int = 4, max_hops: int = 128,
                          backend: str = "auto", adc_dtype: str = "f32",
                          rerank: int = 0):
    """Wrap the device beam search into the `(queries, k) -> ids` callable
    `ServingEngine` consumes. `adc_dtype="int8"` serves via the int8
    fused-hop ADC kernel (2x MXU rate) — the public serving entry point for
    the quantized hot path.

    `rerank=r` (r > 0) adds the exact rerank tier: beam search returns its
    top-max(r, k) pool, their full-precision vectors are gathered from the
    HBM chunk table, and `kernels.rerank` (tiled Pallas matmul-with-epilogue
    on TPU, jnp ref elsewhere) rescores them exactly before the final
    top-k."""
    import jax
    import jax.numpy as jnp
    from repro.core.device_index import beam_search_device
    from repro.kernels import ops

    def _gather_vecs(ids: "jax.Array") -> "jax.Array":
        """Candidate full-precision vectors, bitcast out of the packed HBM
        chunk rows ON DEMAND — only (nq*r) rows per call ever materialize,
        never an (N, d) resident copy of the corpus."""
        rows = index.chunk_words[ids.reshape(-1)]     # (nq*r, stride/4) i32
        by = jax.lax.bitcast_convert_type(
            rows, jnp.uint8).reshape(rows.shape[0], -1)
        vb = by[:, :layout.b_full]
        if layout.data_dtype == "uint8":
            return vb.astype(jnp.float32)
        return jax.lax.bitcast_convert_type(
            vb.reshape(rows.shape[0], layout.dim, 4), jnp.float32)

    def search(queries: np.ndarray, k: int) -> np.ndarray:
        qj = jnp.asarray(queries)
        if not rerank:
            ids, _, _ = beam_search_device(
                index, qj, k=k, L=max(L, k), w=w, max_hops=max_hops,
                layout=layout, metric=metric, backend=backend,
                adc_dtype=adc_dtype)
            return np.asarray(ids)
        r = max(int(rerank), k)
        ids, _, _ = beam_search_device(
            index, qj, k=r, L=max(L, r), w=w, max_hops=max_hops,
            layout=layout, metric=metric, backend=backend,
            adc_dtype=adc_dtype)
        nq = ids.shape[0]
        qf = qj.astype(jnp.float32)
        cand = _gather_vecs(jnp.clip(ids, 0, index.n - 1)) \
            .reshape(nq, r, -1)
        # one kernel call per query (identical shapes -> one compile): the
        # candidate sets are per-query, so a single (nq, nq*r) call would
        # compute nq-times redundant distances
        d = jnp.stack([ops.rerank(qf[i], cand[i], metric=metric,
                                  backend=backend)
                       for i in range(nq)])                     # (nq, r)
        d = jnp.where(ids >= 0, d, jnp.inf)
        top = jnp.argsort(d, axis=1)[:, :k]
        return np.asarray(jnp.take_along_axis(ids, top, axis=1))

    return search


def make_host_search_fn(host_index, *, L: int = 48, w: int = 4,
                        prefetch: int = 0, adc_dtype: str = "f32",
                        rerank: Optional[int] = None,
                        pipeline: Optional[bool] = None,
                        gap=None, entry: str = "auto"):
    """Wrap `HostIndex.search_batch` (the vectorized storage-backed path)
    into the `(queries, k) -> ids` callable `ServingEngine` consumes.
    `prefetch` enables speculative next-hop block reads off the demand
    path; `pipeline` (None = auto: on iff prefetch > 0) keeps two hops in
    flight so traversal ADC overlaps the background reads (the
    `core.traversal` two-hop discipline); `gap` tunes readahead
    coalescing (None = prefetch depth, "auto" = histogram-tuned);
    `adc_dtype="int8"` serves via the quantized host ADC twin;
    `rerank` selects the result tier (None = traversal pool, 0 = PQ-only,
    r > 0 = exact rerank of the top-r candidates — the beam width is
    widened to r so the full depth exists, matching the device tier);
    `entry` selects the seeding ("auto" = per-query nav entry vertices
    iff the index carries a navigation tier, see `core.nav`)."""
    def search(queries: np.ndarray, k: int) -> np.ndarray:
        ids, _ = host_index.search_batch(queries, k,
                                         L=max(L, k, rerank or 0), w=w,
                                         prefetch=prefetch,
                                         adc_dtype=adc_dtype, rerank=rerank,
                                         pipeline=pipeline, gap=gap,
                                         entry=entry)
        return ids

    return search


def exact_distances(host_index, queries: np.ndarray, ids: np.ndarray
                    ) -> np.ndarray:
    """Exact f32 distances (metric from meta.json) for result LABELS.

    The cluster's scatter-gather merge needs scores comparable across
    shards; per-shard PQ-approximate distances are not (each shard has
    its own traversal state), so shard workers rescore their answers
    exactly.  Same formula as the exact rerank tail
    (``core.traversal._rerank_tail_ref``) — cluster answers and
    single-process references score candidates bit-identically.
    Padding ids (< 0) map to +inf.
    """
    from repro.core.chunk_layout import parse_chunk
    from repro.core.traversal import SearchStats

    lut = getattr(host_index, "_label_to_storage", None)
    if lut is None:
        n2o = host_index.new_to_old
        lut = {} if n2o is None else \
            {int(lab): i for i, lab in enumerate(n2o)}
        host_index._label_to_storage = lut  # memoized; index is immutable
    metric = host_index.meta["metric"]
    st = SearchStats()
    ids = np.asarray(ids)
    out = np.full(ids.shape, np.inf, dtype=np.float32)
    for i in range(ids.shape[0]):
        qf = np.asarray(queries[i], dtype=np.float32)
        for j in range(ids.shape[1]):
            lab = int(ids[i, j])
            if lab < 0:
                continue
            node = lut.get(lab, lab) if lut else lab
            raw = host_index._read_chunk(node, st)
            vec, _, _ = parse_chunk(raw, host_index.layout)
            vf = vec.astype(np.float32)
            out[i, j] = -(vf @ qf) if metric == "mips" \
                else ((vf - qf) ** 2).sum()
    return out


def make_host_search_dist_fn(host_index, *, L: int = 48, w: int = 4,
                             prefetch: int = 0, adc_dtype: str = "f32",
                             rerank: Optional[int] = None,
                             pipeline: Optional[bool] = None,
                             gap=None, entry: str = "auto"):
    """`(queries, k) -> (ids, dists)` twin of `make_host_search_fn`: the
    same search plus exact distances for the cross-shard merge.  This is
    the search callable cluster shard workers install on their
    `RetrievalService` (whose `_serve` accepts tuple returns)."""
    base = make_host_search_fn(host_index, L=L, w=w, prefetch=prefetch,
                               adc_dtype=adc_dtype, rerank=rerank,
                               pipeline=pipeline, gap=gap, entry=entry)

    def search(queries: np.ndarray, k: int):
        ids = base(queries, k)
        return ids, exact_distances(host_index, queries, ids)

    return search


@dataclass
class Request:
    query: np.ndarray
    corpus: str = "default"
    k: int = 10
    t_submit: float = field(default_factory=time.perf_counter)
    result: Optional[np.ndarray] = None
    # exact distances for `result`, set when the search_fn returns an
    # (ids, dists) pair (cluster shard workers do: the scatter-gather
    # merge needs comparable scores across shards)
    dists: Optional[np.ndarray] = None
    t_done: float = 0.0
    event: threading.Event = field(default_factory=threading.Event)
    error: Optional[Exception] = None    # set instead of result on failure
    # absolute perf_counter deadline; a worker assembling a batch drops
    # the request (TimeoutError, `expired` telemetry) once it has passed —
    # an abandoned submit_wait must not burn search capacity
    deadline: Optional[float] = None
    # obs.trace.Span this request belongs to (None for untraced traffic);
    # the service activates it around the batch so traversal-hop and
    # block-cache spans parent onto the query's trace
    span: Optional[object] = None

    @property
    def latency_s(self) -> float:
        return self.t_done - self.t_submit

    def expired(self, now: Optional[float] = None) -> bool:
        if self.deadline is None:
            return False
        return (time.perf_counter() if now is None else now) > self.deadline


class ServingEngine:
    """search_fns: corpus -> fn(queries (B,d), k) -> ids (B,k).

    Multiple entries in `replicas` enable hedging; `switch_fn(corpus)` is
    called when the batch's corpus differs from the active one (the paper's
    index-switch path)."""

    def __init__(self, search_fns: Dict[str, Callable], *,
                 max_batch: int = 32, max_wait_ms: float = 2.0,
                 hedge: int = 1, replicas: Optional[List[Callable]] = None,
                 switch_fn: Optional[Callable[[str], float]] = None):
        self.search_fns = search_fns
        self.max_batch = max_batch
        self.max_wait = max_wait_ms / 1e3
        self.hedge = hedge
        self.replicas = replicas
        self.switch_fn = switch_fn
        self.q: "queue.Queue[Request]" = queue.Queue()
        self._held: "deque[Request]" = deque()   # other-corpus holdover
        self.metrics: List[float] = []
        self.switch_times: List[float] = []
        # hedge accounting: wasted = replicas that ran but lost the race,
        # failed = replicas that raised (the winner is the first SUCCESS)
        self.hedge_stats: Dict[str, int] = dict(batches=0, wasted=0, failed=0)
        self._hedge_lock = threading.Lock()
        # guards the _stop flag vs stop()'s queue drain: a submit racing a
        # concurrent stop() must either raise or have its request drained
        self._submit_lock = threading.Lock()
        self._active_corpus: Optional[str] = None
        self._stop = False
        self._pool = ThreadPoolExecutor(max_workers=max(2, hedge * 2))
        self._t = threading.Thread(target=self._loop, daemon=True)
        self._t.start()

    # -- client API ----------------------------------------------------------
    def submit(self, query: np.ndarray, corpus: str = "default", k: int = 10
               ) -> Request:
        with self._submit_lock:
            if self._stop:
                raise RuntimeError("engine stopped")
            r = Request(query=query, corpus=corpus, k=k)
            self.q.put(r)
            return r

    def submit_wait(self, query, corpus="default", k=10, timeout=30.0):
        r = self.submit(query, corpus, k)
        r.event.wait(timeout)
        return r

    # -- engine loop ----------------------------------------------------------
    def _collect_batch(self) -> List[Request]:
        """Corpus-pure batch with FIFO-preserving holdover: a request for a
        DIFFERENT corpus encountered while collecting is parked in `_held`
        (never re-queued to the back of the FIFO, which would reorder it
        behind later arrivals and starve it under sustained foreign load);
        the next batch starts from the holdover before touching the
        queue."""
        if self._held:
            first = self._held.popleft()
        else:
            try:
                first = self.q.get(timeout=0.1)
            except queue.Empty:
                return []
        batch = [first]
        # same-corpus requests already held keep their relative order
        for r in list(self._held):
            if len(batch) >= self.max_batch:
                break
            if r.corpus == first.corpus:
                try:
                    self._held.remove(r)
                except ValueError:
                    continue             # a concurrent stop() drained it
                batch.append(r)
        deadline = time.perf_counter() + self.max_wait
        while len(batch) < self.max_batch:
            left = deadline - time.perf_counter()
            if left <= 0:
                break
            try:
                r = self.q.get(timeout=left)
            except queue.Empty:
                break
            if r.corpus != first.corpus:      # keep batches corpus-pure
                self._held.append(r)          # served at the NEXT batch head
                continue
            batch.append(r)
        return batch

    def _run_search(self, fn, queries, k):
        return fn(queries, k)

    def _count_hedge_loser(self, fut):
        """done-callback for replicas that lost the race: work that ran to
        completion for nothing is wasted; cancelled-before-running is
        free."""
        with self._hedge_lock:
            if fut.cancelled():
                return
            if fut.exception() is not None:
                self.hedge_stats["failed"] += 1
            else:
                self.hedge_stats["wasted"] += 1

    def _run_hedged(self, queries, k):
        """First SUCCESSFUL replica wins. `Future.cancel()` cannot stop an
        already-running thread, so losing replicas are accounted (wasted /
        failed) via done-callbacks rather than assumed dead."""
        futs = [self._pool.submit(self._run_search, rep, queries, k)
                for rep in self.replicas[:self.hedge]]
        with self._hedge_lock:
            self.hedge_stats["batches"] += 1
        pending = set(futs)
        ids = err = None
        while pending:
            done, pending = wait(pending, return_when=FIRST_COMPLETED)
            for f in done:
                e = f.exception()
                if e is None and ids is None:
                    ids = f.result()
                else:
                    with self._hedge_lock:
                        if e is not None:
                            self.hedge_stats["failed"] += 1
                        else:
                            self.hedge_stats["wasted"] += 1
                    err = e if e is not None else err
            if ids is not None:
                break
        for p in pending:                 # losers still in flight
            p.cancel()
            p.add_done_callback(self._count_hedge_loser)
        if ids is None:                   # every replica failed
            raise err if err is not None else RuntimeError("hedge failed")
        return ids

    def _loop(self):
        try:
            self._loop_inner()
        finally:
            # the loop thread drains its own leftovers on exit: requests
            # it moved into _held after stop()'s drain ran would hang
            self._drain(RuntimeError("engine stopped"))

    def _loop_inner(self):
        while not self._stop:
            batch = self._collect_batch()
            if not batch:
                continue
            if self._stop:               # stopped mid-collect: fail the
                self._held.extend(batch)  # batch via the exit drain
                break
            corpus = batch[0].corpus
            err = None
            try:
                if self.switch_fn is not None \
                        and corpus != self._active_corpus:
                    self.switch_times.append(self.switch_fn(corpus))
                    self._active_corpus = corpus
                queries = np.stack([r.query for r in batch])
                k = max(r.k for r in batch)
                fn = self.search_fns[corpus]
                if self.hedge > 1 and self.replicas:
                    ids = self._run_hedged(queries, k)
                else:
                    ids = fn(queries, k)
                ids = np.asarray(ids)     # malformed returns fail the batch
                if ids.ndim != 2 or ids.shape[0] != len(batch):
                    raise ValueError(
                        f"search fn returned shape {ids.shape}, expected "
                        f"({len(batch)}, k)")
            except Exception as e:        # noqa: BLE001 — fail the batch,
                err = e                   # never kill the engine thread
            now = time.perf_counter()
            for i, r in enumerate(batch):
                r.t_done = now
                if err is not None:
                    r.error = err
                else:
                    r.result = ids[i, :r.k]
                    self.metrics.append(r.latency_s)
                r.event.set()

    def _drain(self, err: Exception):
        """Fail every request still parked in the holdover deque or the
        queue.  Safe to run from both the loop thread (on exit) and
        stop(): deque/queue pops are atomic, each request drains once."""
        leftovers = []
        while self._held:
            try:
                leftovers.append(self._held.popleft())
            except IndexError:
                break
        while True:
            try:
                leftovers.append(self.q.get_nowait())
            except queue.Empty:
                break
        for r in leftovers:
            r.error = err
            r.event.set()

    # -- stats ----------------------------------------------------------------
    def latency_percentiles(self):
        if not self.metrics:
            return {}
        a = np.array(self.metrics)
        return {"p50_ms": float(np.percentile(a, 50) * 1e3),
                "p95_ms": float(np.percentile(a, 95) * 1e3),
                "p99_ms": float(np.percentile(a, 99) * 1e3),
                "n": len(a)}

    def stop(self):
        with self._submit_lock:
            self._stop = True
        self._t.join(timeout=2.0)
        self._pool.shutdown(wait=False)
        # fail whatever never made it into a batch (queue + holdover) so
        # submit_wait callers see an error instead of a silent timeout;
        # under _submit_lock no new request can slip in behind the drain.
        # The loop thread ALSO drains on its own exit, covering requests
        # it re-parks after this drain when join() timed out mid-collect.
        with self._submit_lock:
            self._drain(RuntimeError("engine stopped"))
