"""Batched query-serving engine with hedged requests (straggler mitigation).

The paper's serving story (RAG retriever): requests arrive for possibly
different corpora; the engine batches per-corpus, switches indices (AiSAQ
makes that ms-order), and runs the search backend. `hedge=2` issues each
batch to two replicas and takes the first completion — the classic
tail-latency-at-scale mitigation for the multi-server tier.
"""
from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor, wait
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import numpy as np


def make_device_search_fn(index, layout, *, metric: str = "l2", L: int = 48,
                          w: int = 4, max_hops: int = 128,
                          backend: str = "auto", adc_dtype: str = "f32"):
    """Wrap the device beam search into the `(queries, k) -> ids` callable
    `ServingEngine` consumes. `adc_dtype="int8"` serves via the int8
    fused-hop ADC kernel (2x MXU rate) — the public serving entry point for
    the quantized hot path."""
    import jax.numpy as jnp
    from repro.core.device_index import beam_search_device

    def search(queries: np.ndarray, k: int) -> np.ndarray:
        ids, _, _ = beam_search_device(
            index, jnp.asarray(queries), k=k, L=max(L, k), w=w,
            max_hops=max_hops, layout=layout, metric=metric,
            backend=backend, adc_dtype=adc_dtype)
        return np.asarray(ids)

    return search


def make_host_search_fn(host_index, *, L: int = 48, w: int = 4,
                        prefetch: int = 0, adc_dtype: str = "f32"):
    """Wrap `HostIndex.search_batch` (the vectorized storage-backed path)
    into the `(queries, k) -> ids` callable `ServingEngine` consumes.
    `prefetch` enables speculative next-hop block reads off the demand
    path; `adc_dtype="int8"` serves via the quantized host ADC twin."""
    def search(queries: np.ndarray, k: int) -> np.ndarray:
        ids, _ = host_index.search_batch(queries, k, L=max(L, k), w=w,
                                         prefetch=prefetch,
                                         adc_dtype=adc_dtype)
        return ids

    return search


@dataclass
class Request:
    query: np.ndarray
    corpus: str = "default"
    k: int = 10
    t_submit: float = field(default_factory=time.perf_counter)
    result: Optional[np.ndarray] = None
    t_done: float = 0.0
    event: threading.Event = field(default_factory=threading.Event)

    @property
    def latency_s(self) -> float:
        return self.t_done - self.t_submit


class ServingEngine:
    """search_fns: corpus -> fn(queries (B,d), k) -> ids (B,k).

    Multiple entries in `replicas` enable hedging; `switch_fn(corpus)` is
    called when the batch's corpus differs from the active one (the paper's
    index-switch path)."""

    def __init__(self, search_fns: Dict[str, Callable], *,
                 max_batch: int = 32, max_wait_ms: float = 2.0,
                 hedge: int = 1, replicas: Optional[List[Callable]] = None,
                 switch_fn: Optional[Callable[[str], float]] = None):
        self.search_fns = search_fns
        self.max_batch = max_batch
        self.max_wait = max_wait_ms / 1e3
        self.hedge = hedge
        self.replicas = replicas
        self.switch_fn = switch_fn
        self.q: "queue.Queue[Request]" = queue.Queue()
        self.metrics: List[float] = []
        self.switch_times: List[float] = []
        self._active_corpus: Optional[str] = None
        self._stop = False
        self._pool = ThreadPoolExecutor(max_workers=max(2, hedge * 2))
        self._t = threading.Thread(target=self._loop, daemon=True)
        self._t.start()

    # -- client API ----------------------------------------------------------
    def submit(self, query: np.ndarray, corpus: str = "default", k: int = 10
               ) -> Request:
        r = Request(query=query, corpus=corpus, k=k)
        self.q.put(r)
        return r

    def submit_wait(self, query, corpus="default", k=10, timeout=30.0):
        r = self.submit(query, corpus, k)
        r.event.wait(timeout)
        return r

    # -- engine loop ----------------------------------------------------------
    def _collect_batch(self) -> List[Request]:
        try:
            first = self.q.get(timeout=0.1)
        except queue.Empty:
            return []
        batch = [first]
        deadline = time.perf_counter() + self.max_wait
        while len(batch) < self.max_batch:
            left = deadline - time.perf_counter()
            if left <= 0:
                break
            try:
                r = self.q.get(timeout=left)
            except queue.Empty:
                break
            if r.corpus != first.corpus:      # keep batches corpus-pure
                self.q.put(r)
                break
            batch.append(r)
        return batch

    def _run_search(self, fn, queries, k):
        return fn(queries, k)

    def _loop(self):
        while not self._stop:
            batch = self._collect_batch()
            if not batch:
                continue
            corpus = batch[0].corpus
            if self.switch_fn is not None and corpus != self._active_corpus:
                self.switch_times.append(self.switch_fn(corpus))
                self._active_corpus = corpus
            queries = np.stack([r.query for r in batch])
            k = max(r.k for r in batch)
            fn = self.search_fns[corpus]
            if self.hedge > 1 and self.replicas:
                futs = [self._pool.submit(self._run_search, rep, queries, k)
                        for rep in self.replicas[:self.hedge]]
                done, pending = wait(futs, return_when=FIRST_COMPLETED)
                ids = list(done)[0].result()
                for p in pending:
                    p.cancel()
            else:
                ids = fn(queries, k)
            now = time.perf_counter()
            for i, r in enumerate(batch):
                r.result = ids[i, :r.k]
                r.t_done = now
                self.metrics.append(r.latency_s)
                r.event.set()

    # -- stats ----------------------------------------------------------------
    def latency_percentiles(self):
        if not self.metrics:
            return {}
        a = np.array(self.metrics)
        return {"p50_ms": float(np.percentile(a, 50) * 1e3),
                "p95_ms": float(np.percentile(a, 95) * 1e3),
                "p99_ms": float(np.percentile(a, 99) * 1e3),
                "n": len(a)}

    def stop(self):
        self._stop = True
        self._t.join(timeout=2.0)
        self._pool.shutdown(wait=False)
