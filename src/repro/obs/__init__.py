"""Observability substrate: metrics registry + cross-process tracing.

Deliberately jax-free AND numpy-free — this package sits on the
`repro.serving` import chain that spawned cluster workers pay at
startup, and on the `core.traversal`/`core.block_cache` hot path.

  metrics — thread-safe counters/gauges/fixed-bucket histograms with
            derived p50/p95/p99, labeled series, cross-process
            `merge_snapshots`, JSON + Prometheus-text exposition
  trace   — per-query span trees propagated router -> frame header ->
            worker -> traversal hops -> block-cache reads; Chrome
            trace-event export; sampling knob; slow-query log

See docs/observability.md for the metric tables and span hierarchy.
"""
from repro.obs.metrics import (COUNT_BUCKETS, DEFAULT_LATENCY_BUCKETS_S,
                               Counter, Gauge, Histogram, MetricsRegistry,
                               SearchMetrics, bucket_quantile,
                               merge_snapshots, to_prometheus_text)
from repro.obs.trace import (Span, Tracer, activate, current_span, enabled,
                             set_enabled, span)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "SearchMetrics",
    "DEFAULT_LATENCY_BUCKETS_S", "COUNT_BUCKETS", "bucket_quantile",
    "merge_snapshots", "to_prometheus_text",
    "Span", "Tracer", "activate", "current_span", "span",
    "enabled", "set_enabled",
]
