"""Per-query span tracing across threads and processes.

One routed query produces one TRACE: a tree of SPANS — router scatter,
per-shard attempts, the worker's serve/batch stages, every traversal
hop, every block-cache read.  The propagation path:

    ShardRouter (root span, head-based sampling decision)
      -> trace context {tid, sid} rides the T_SEARCH frame header
      -> worker builds a remote-parented span, activates it around the
         service batch (thread-local span stack)
      -> `core.traversal` opens a span per hop, `BlockCache.fetch` a
         span per read — both keyed off the ACTIVE span, zero setup
      -> the worker's finished spans ride back in the T_RESULT header
         and the router ingests them into its own tracer

so `Tracer.export_chrome()` yields ONE Chrome trace-event JSON
(loadable in Perfetto / chrome://tracing) with the full cross-process
chain.  Span timestamps are wall-clock (`time.time`) so spans from
different processes land on one timeline; durations come from
`perf_counter` deltas.

Disabled-by-default cost: instrumented code calls `current_span()` —
one thread-local attribute read — and skips everything when no span is
active.  The module-level `set_enabled(False)` kill switch short-
circuits even that check (the <2% hot-path gate in
`bench_search.py --quick` compares the two).

Slow-query log: a Tracer built with `slow_threshold_s` dumps the full
span tree of any ROOT span that finishes over the threshold — to the
bounded `slow_queries` deque always, and as one JSON line per query to
`slow_log_path` when given.
"""
from __future__ import annotations

import json
import os
import threading
import time
from collections import deque
from contextlib import contextmanager
from typing import Dict, List, Optional, Sequence

__all__ = ["Span", "Tracer", "current_span", "span", "activate",
           "set_enabled", "enabled"]

_tls = threading.local()
_ENABLED = True      # global kill switch; see set_enabled()


def set_enabled(flag: bool):
    """Global tracing kill switch.  When False, `span()`/`current_span()`
    short-circuit before touching thread-local state — the zero-cost
    baseline the disabled-overhead gate compares against."""
    global _ENABLED
    _ENABLED = bool(flag)


def enabled() -> bool:
    return _ENABLED


def current_span() -> Optional["Span"]:
    """The innermost active span on this thread, or None."""
    if not _ENABLED:
        return None
    st = getattr(_tls, "stack", None)
    return st[-1] if st else None


@contextmanager
def activate(sp: Optional["Span"]):
    """Push `sp` as this thread's active span for the block (no-op when
    None).  Does NOT end the span — the creator owns its lifetime."""
    if sp is None:
        yield None
        return
    st = getattr(_tls, "stack", None)
    if st is None:
        st = _tls.stack = []
    st.append(sp)
    try:
        yield sp
    finally:
        st.pop()


@contextmanager
def span(name: str, **annotations):
    """Open a child of the current span for the block; no-op (yields
    None) when tracing is off or no span is active on this thread."""
    parent = current_span()
    if parent is None:
        yield None
        return
    sp = parent.tracer.start_span(name, parent=parent,
                                  annotations=annotations or None)
    st = _tls.stack
    st.append(sp)
    try:
        yield sp
    finally:
        st.pop()
        sp.end()


def begin(name: str, **annotations) -> Optional["Span"]:
    """Start (without activating) a child of the current span; None when
    inactive.  The caller must `end()` it — the explicit form hot loops
    use to keep the disabled path to one branch."""
    parent = current_span()
    if parent is None:
        return None
    return parent.tracer.start_span(name, parent=parent,
                                    annotations=annotations or None)


def _gen_id(nbytes: int) -> str:
    return os.urandom(nbytes).hex()


class Span:
    """One timed operation.  `trace_id` groups a query's spans across
    processes; `parent_id` builds the tree; annotations are free-form
    JSON-safe keyvals."""

    __slots__ = ("tracer", "trace_id", "span_id", "parent_id", "name",
                 "t_start", "duration_s", "annotations", "pid", "tid",
                 "_t0", "_done")

    def __init__(self, tracer: "Tracer", name: str, trace_id: str,
                 parent_id: Optional[str],
                 annotations: Optional[dict] = None):
        self.tracer = tracer
        self.name = name
        self.trace_id = trace_id
        self.span_id = _gen_id(4)
        self.parent_id = parent_id
        self.t_start = time.time()
        self._t0 = time.perf_counter()
        self.duration_s = 0.0
        self.annotations = dict(annotations) if annotations else {}
        self.pid = os.getpid()
        self.tid = threading.get_ident()
        self._done = False

    def annotate(self, **kw):
        self.annotations.update(kw)
        return self

    def end(self):
        if self._done:
            return
        self._done = True
        self.duration_s = time.perf_counter() - self._t0
        self.tracer._on_end(self)

    def to_dict(self) -> dict:
        return dict(trace_id=self.trace_id, span_id=self.span_id,
                    parent_id=self.parent_id, name=self.name,
                    t_start=self.t_start, duration_s=self.duration_s,
                    pid=self.pid, tid=self.tid,
                    annotations=dict(self.annotations))


class Tracer:
    """Owns sampling, the finished-span buffer, exports, and the
    slow-query log.  Thread-safe; one per process side (router-side and
    worker-side tracers meet through span ingestion)."""

    def __init__(self, sample: float = 1.0, *, max_spans: int = 8192,
                 slow_threshold_s: Optional[float] = None,
                 slow_log_path: Optional[str] = None,
                 max_slow: int = 64):
        self.sample = float(sample)
        self.slow_threshold_s = slow_threshold_s
        self.slow_log_path = slow_log_path
        self._lock = threading.Lock()
        self._spans: deque = deque(maxlen=max_spans)   # finished, as dicts
        self.slow_queries: deque = deque(maxlen=max_slow)
        self._n = 0              # sampling counter
        self.dropped = 0         # spans evicted from the bounded buffer

    # -- sampling ------------------------------------------------------------
    def sampled(self) -> bool:
        """Deterministic counter-based head sampling: over any window of
        N decisions, floor(N * sample) say yes — no RNG, reproducible."""
        if self.sample <= 0.0:
            return False
        if self.sample >= 1.0:
            return True
        with self._lock:
            self._n += 1
            n = self._n
        return int(n * self.sample) > int((n - 1) * self.sample)

    # -- span creation -------------------------------------------------------
    def start_span(self, name: str, *, parent: Optional[Span] = None,
                   trace_id: Optional[str] = None,
                   parent_id: Optional[str] = None,
                   annotations: Optional[dict] = None) -> Span:
        if parent is not None:
            trace_id = parent.trace_id
            parent_id = parent.span_id
        elif trace_id is None:
            trace_id = _gen_id(8)
        return Span(self, name, trace_id, parent_id, annotations)

    def start_remote(self, name: str, ctx: dict,
                     annotations: Optional[dict] = None) -> Span:
        """Continue a trace that arrived over the wire: `ctx` is the
        {tid, sid} dict a T_SEARCH frame header carries."""
        return Span(self, name, str(ctx["tid"]), str(ctx["sid"]),
                    annotations)

    def context(self, sp: Span) -> dict:
        """The wire form of a span: what encode_query puts in the frame
        header for the worker to parent onto."""
        return dict(tid=sp.trace_id, sid=sp.span_id)

    # -- finished-span plumbing ----------------------------------------------
    def _on_end(self, sp: Span):
        d = sp.to_dict()
        with self._lock:
            if len(self._spans) == self._spans.maxlen:
                self.dropped += 1
            self._spans.append(d)
        if self.slow_threshold_s is not None and sp.parent_id is None \
                and sp.duration_s >= self.slow_threshold_s:
            self._log_slow(d)

    def ingest(self, span_dicts: Sequence[dict]):
        """Adopt spans finished elsewhere (a worker's T_RESULT payload)
        into this tracer's buffer."""
        with self._lock:
            for d in span_dicts:
                if len(self._spans) == self._spans.maxlen:
                    self.dropped += 1
                self._spans.append(dict(d))

    def take(self, trace_id: str) -> List[dict]:
        """Pop every finished span of one trace — what a worker ships
        back in the result frame."""
        with self._lock:
            keep, out = [], []
            for d in self._spans:
                (out if d["trace_id"] == trace_id else keep).append(d)
            self._spans.clear()
            self._spans.extend(keep)
        return out

    def finished(self) -> List[dict]:
        with self._lock:
            return list(self._spans)

    def clear(self):
        with self._lock:
            self._spans.clear()

    # -- slow-query log ------------------------------------------------------
    def _log_slow(self, root: dict):
        tree = self.span_tree(root["trace_id"])
        entry = dict(trace_id=root["trace_id"], name=root["name"],
                     duration_s=root["duration_s"], t_start=root["t_start"],
                     tree=tree)
        self.slow_queries.append(entry)
        if self.slow_log_path:
            try:
                with open(self.slow_log_path, "a") as f:
                    f.write(json.dumps(entry) + "\n")
            except OSError:
                pass             # telemetry must never fail the query

    def span_tree(self, trace_id: str) -> List[dict]:
        """The trace's spans as a nested tree (children under
        "children"), roots first."""
        spans = [d for d in self.finished() if d["trace_id"] == trace_id]
        nodes = {d["span_id"]: dict(d, children=[]) for d in spans}
        roots = []
        for d in spans:
            node = nodes[d["span_id"]]
            parent = nodes.get(d["parent_id"]) if d["parent_id"] else None
            if parent is not None:
                parent["children"].append(node)
            else:
                roots.append(node)
        for n in nodes.values():
            n["children"].sort(key=lambda c: c["t_start"])
        roots.sort(key=lambda c: c["t_start"])
        return roots

    # -- exports -------------------------------------------------------------
    def export_chrome(self, path: Optional[str] = None,
                      trace_id: Optional[str] = None) -> dict:
        """Chrome trace-event JSON (Perfetto / chrome://tracing).  Each
        span becomes one complete ("X") event; ts/dur are microseconds
        on the wall clock so cross-process spans share a timeline."""
        spans = self.finished()
        if trace_id is not None:
            spans = [d for d in spans if d["trace_id"] == trace_id]
        events = []
        for d in spans:
            args = dict(d["annotations"])
            args["trace_id"] = d["trace_id"]
            args["span_id"] = d["span_id"]
            if d["parent_id"]:
                args["parent_id"] = d["parent_id"]
            events.append(dict(
                name=d["name"], ph="X", cat="repro",
                ts=d["t_start"] * 1e6, dur=max(d["duration_s"], 1e-7) * 1e6,
                pid=d["pid"], tid=d["tid"], args=args))
        doc = dict(traceEvents=events, displayTimeUnit="ms")
        if path is not None:
            with open(path, "w") as f:
                json.dump(doc, f)
        return doc
