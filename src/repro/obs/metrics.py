"""Low-overhead, thread-safe metrics registry: counters, gauges, and
fixed-bucket latency histograms with derived percentiles.

Design constraints, in order:

  * JAX-FREE AND NUMPY-FREE — this module sits on the `repro.serving`
    import chain, which must stay lean so spawned cluster workers start
    in fractions of a second,
  * CHEAP ON THE HOT PATH — a call site holds the series handle
    (`Counter`/`Gauge`/`Histogram` object) and pays one small lock plus
    one bisect per observation; no string formatting, no dict lookups,
  * MERGEABLE ACROSS PROCESSES — `snapshot()` emits a plain JSON-safe
    dict, and `merge_snapshots` folds any number of them (counters and
    gauges sum, histogram buckets add elementwise) so the supervisor can
    present one cluster-wide view from per-worker T_STATS payloads.
    Merging is ASSOCIATIVE and COMMUTATIVE by construction — the
    property tests in `tests/test_obs.py` pin this,
  * TWO EXPOSITIONS — the snapshot dict itself (JSON) and a
    Prometheus-text rendering (`to_prometheus_text`) with cumulative
    `_bucket{le=...}` / `_sum` / `_count` histogram series.

Histogram percentiles use linear interpolation inside the containing
bucket (lower bound of the first bucket is 0, values past the last
finite bound clamp to it), which keeps `quantile(q)` monotone in `q`
and a pure function of the bucket counts — so percentiles derived from
a merged snapshot are exactly the percentiles of the merged histogram.
"""
from __future__ import annotations

import json
import threading
from bisect import bisect_left
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = [
    "DEFAULT_LATENCY_BUCKETS_S", "COUNT_BUCKETS",
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "bucket_quantile", "merge_snapshots", "to_prometheus_text",
    "SearchMetrics",
]

#: Default latency bucket upper bounds (seconds): 100 µs .. 10 s, roughly
#: geometric.  An implicit +inf overflow bucket always follows the last
#: finite bound.
DEFAULT_LATENCY_BUCKETS_S: Tuple[float, ...] = (
    0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025,
    0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0)

#: Power-of-two-ish bounds for small-count histograms (hops, batch size).
COUNT_BUCKETS: Tuple[float, ...] = (
    1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024)


def _label_key(labels: Optional[Dict[str, str]]) -> Tuple[Tuple[str, str], ...]:
    if not labels:
        return ()
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class Counter:
    """Monotone counter series.  `inc` only; read via `.value`."""

    __slots__ = ("labels", "_value", "_lock")
    kind = "counter"

    def __init__(self, labels: Dict[str, str]):
        self.labels = labels
        self._value = 0.0
        self._lock = threading.Lock()

    def inc(self, amount: float = 1.0):
        with self._lock:
            self._value += amount

    @property
    def value(self) -> float:
        return self._value

    def _series(self) -> dict:
        return dict(labels=dict(self.labels), value=self._value)


class Gauge(Counter):
    """Point-in-time value series; `set` replaces, `inc` adjusts."""

    __slots__ = ()
    kind = "gauge"

    def set(self, value: float):
        with self._lock:
            self._value = float(value)


class Histogram:
    """Fixed-bucket histogram series with derived quantiles.

    Bucket i counts observations v with bounds[i-1] < v <= bounds[i]
    (Prometheus `le` semantics); one extra overflow bucket counts
    v > bounds[-1].
    """

    __slots__ = ("labels", "bounds", "counts", "sum", "count", "_lock")
    kind = "histogram"

    def __init__(self, labels: Dict[str, str],
                 buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS_S):
        bounds = tuple(float(b) for b in buckets)
        if not bounds or any(b2 <= b1 for b1, b2 in zip(bounds, bounds[1:])):
            raise ValueError(f"bucket bounds must strictly increase: {bounds}")
        self.labels = labels
        self.bounds = bounds
        self.counts = [0] * (len(bounds) + 1)
        self.sum = 0.0
        self.count = 0
        self._lock = threading.Lock()

    def observe(self, v: float):
        i = bisect_left(self.bounds, v)
        with self._lock:
            self.counts[i] += 1
            self.sum += v
            self.count += 1

    def quantile(self, q: float) -> Optional[float]:
        with self._lock:
            counts = list(self.counts)
        return bucket_quantile(self.bounds, counts, q)

    def _series(self) -> dict:
        with self._lock:
            counts = list(self.counts)
            s, n = self.sum, self.count
        out = dict(labels=dict(self.labels), bounds=list(self.bounds),
                   counts=counts, sum=s, count=n)
        for name, q in (("p50", 0.50), ("p95", 0.95), ("p99", 0.99)):
            out[name] = bucket_quantile(self.bounds, counts, q)
        return out


def bucket_quantile(bounds: Sequence[float], counts: Sequence[int],
                    q: float) -> Optional[float]:
    """q-quantile of a bucketed distribution; None when empty.

    Linear interpolation inside the containing bucket (first bucket's
    lower bound is 0; the overflow bucket clamps to the last finite
    bound).  Monotone in q, pure in (bounds, counts) — merged snapshots
    recompute percentiles with this same function.
    """
    total = sum(counts)
    if total == 0:
        return None
    rank = max(q, 0.0) * total
    cum = 0
    for i, c in enumerate(counts):
        cum += c
        if cum >= rank and c > 0:
            if i >= len(bounds):            # overflow: clamp, no upper bound
                return float(bounds[-1])
            lo = float(bounds[i - 1]) if i > 0 else 0.0
            hi = float(bounds[i])
            frac = (rank - (cum - c)) / c
            return lo + (hi - lo) * frac
    return float(bounds[-1])


class MetricsRegistry:
    """Families of labeled series.  `counter/gauge/histogram` are
    idempotent: the same (name, labels) returns the same handle, so call
    sites fetch once at setup and then pay only the series update."""

    def __init__(self):
        self._lock = threading.Lock()
        # name -> (kind, help, unit, {label_key: series})
        self._families: Dict[str, list] = {}

    def _get(self, name: str, kind: str, labels, factory, help_: str,
             unit: str):
        key = _label_key(labels)
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = self._families[name] = [kind, help_, unit, {}]
            if fam[0] != kind:
                raise ValueError(
                    f"metric {name!r} is a {fam[0]}, not a {kind}")
            series = fam[3].get(key)
            if series is None:
                series = fam[3][key] = factory(dict(key))
            return series

    def counter(self, name: str, labels: Optional[Dict[str, str]] = None,
                *, help: str = "", unit: str = "") -> Counter:
        return self._get(name, "counter", labels, Counter, help, unit)

    def gauge(self, name: str, labels: Optional[Dict[str, str]] = None,
              *, help: str = "", unit: str = "") -> Gauge:
        return self._get(name, "gauge", labels, Gauge, help, unit)

    def histogram(self, name: str, labels: Optional[Dict[str, str]] = None,
                  *, buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS_S,
                  help: str = "", unit: str = "") -> Histogram:
        return self._get(name, "histogram", labels,
                         lambda lb: Histogram(lb, buckets), help, unit)

    # -- exposition ----------------------------------------------------------
    def snapshot(self) -> dict:
        """One JSON-safe dict of every family and series.  Histogram
        series carry raw bucket counts (mergeable) plus derived
        p50/p95/p99 (recomputed after any merge)."""
        with self._lock:
            fams = {n: (f[0], f[1], f[2], list(f[3].values()))
                    for n, f in self._families.items()}
        return {name: dict(type=kind, help=h, unit=u,
                           series=[s._series() for s in series])
                for name, (kind, h, u, series) in fams.items()}

    def to_json(self) -> str:
        return json.dumps(self.snapshot(), sort_keys=True)

    def to_prometheus(self) -> str:
        return to_prometheus_text(self.snapshot())

    @staticmethod
    def merge_snapshots(snaps: Sequence[dict]) -> dict:
        return merge_snapshots(snaps)


def merge_snapshots(snaps: Sequence[dict]) -> dict:
    """Fold snapshot dicts into one cluster-wide view.

    Counters and gauges SUM across snapshots (a merged gauge is the
    cluster total — queue depths and open-handle counts add); histogram
    buckets add elementwise and percentiles are recomputed from the
    merged counts.  Associative and commutative.  Raises ValueError on
    a kind or bucket-bounds conflict — merging those would silently
    produce garbage.
    """
    out: dict = {}
    for snap in snaps:
        for name, fam in snap.items():
            dst = out.get(name)
            if dst is None:
                out[name] = dict(
                    type=fam["type"], help=fam.get("help", ""),
                    unit=fam.get("unit", ""),
                    series=[dict(s) for s in fam["series"]])
                continue
            if dst["type"] != fam["type"]:
                raise ValueError(
                    f"metric {name!r}: kind conflict "
                    f"{dst['type']!r} vs {fam['type']!r}")
            by_key = {_label_key(s["labels"]): s for s in dst["series"]}
            for s in fam["series"]:
                d = by_key.get(_label_key(s["labels"]))
                if d is None:
                    dst["series"].append(dict(s))
                    continue
                if fam["type"] == "histogram":
                    if list(d["bounds"]) != list(s["bounds"]):
                        raise ValueError(
                            f"metric {name!r}: bucket bounds conflict")
                    d["counts"] = [a + b for a, b
                                   in zip(d["counts"], s["counts"])]
                    d["sum"] = d["sum"] + s["sum"]
                    d["count"] = d["count"] + s["count"]
                else:
                    d["value"] = d["value"] + s["value"]
    for fam in out.values():
        if fam["type"] == "histogram":
            for s in fam["series"]:
                for pname, q in (("p50", .50), ("p95", .95), ("p99", .99)):
                    s[pname] = bucket_quantile(s["bounds"], s["counts"], q)
    return out


def _fmt_labels(labels: Dict[str, str], extra: str = "") -> str:
    parts = [f'{k}="{v}"' for k, v in sorted(labels.items())]
    if extra:
        parts.append(extra)
    return "{" + ",".join(parts) + "}" if parts else ""


def to_prometheus_text(snapshot: dict) -> str:
    """Prometheus text exposition of a snapshot (or merged snapshot)."""
    lines: List[str] = []
    for name in sorted(snapshot):
        fam = snapshot[name]
        if fam.get("help"):
            lines.append(f"# HELP {name} {fam['help']}")
        lines.append(f"# TYPE {name} {fam['type']}")
        for s in fam["series"]:
            if fam["type"] == "histogram":
                cum = 0
                for bound, c in zip(s["bounds"], s["counts"]):
                    cum += c
                    le = 'le="%s"' % bound
                    lines.append(
                        f"{name}_bucket{_fmt_labels(s['labels'], le)} {cum}")
                le_inf = 'le="+Inf"'
                lines.append(
                    f"{name}_bucket{_fmt_labels(s['labels'], le_inf)} "
                    f"{s['count']}")
                lines.append(
                    f"{name}_sum{_fmt_labels(s['labels'])} {s['sum']}")
                lines.append(
                    f"{name}_count{_fmt_labels(s['labels'])} {s['count']}")
            else:
                lines.append(
                    f"{name}{_fmt_labels(s['labels'])} {s['value']}")
    return "\n".join(lines) + "\n"


def merged_quantile(hists: Sequence[Histogram], q: float) -> Optional[float]:
    """Quantile over several same-bounds histogram series combined —
    the all-corpora view `RetrievalService.stats()` reports."""
    hists = [h for h in hists if h.count]
    if not hists:
        return None
    bounds = hists[0].bounds
    counts = [0] * (len(bounds) + 1)
    for h in hists:
        if h.bounds != bounds:
            raise ValueError("cannot combine histograms with differing "
                             "bucket bounds")
        with h._lock:
            for i, c in enumerate(h.counts):
                counts[i] += c
    return bucket_quantile(bounds, counts, q)


class SearchMetrics:
    """The histogram bundle a `HostIndex` publishes per `search_batch`
    call — `SearchStats` distributions instead of means-only fields.
    `WarmIndexPool` attaches one per open handle (`index.metrics`);
    `core.traversal` feeds it when present, and skips a single attribute
    check when not."""

    __slots__ = ("latency", "hops", "conv_hops", "nav_hops", "ios",
                 "blocked", "compute")

    def __init__(self, registry: MetricsRegistry, corpus: str):
        lbl = {"corpus": corpus}
        self.latency = registry.histogram(
            "search_batch_latency_seconds", lbl,
            help="wall time of one search_batch call", unit="seconds")
        self.hops = registry.histogram(
            "traversal_hops", lbl, buckets=COUNT_BUCKETS,
            help="on-disk beam-traversal hops per query")
        self.conv_hops = registry.histogram(
            "traversal_convergence_hops", lbl, buckets=COUNT_BUCKETS,
            help="hops until the returned top-k stopped changing")
        self.nav_hops = registry.histogram(
            "nav_beam_hops", lbl, buckets=COUNT_BUCKETS,
            help="in-RAM navigation-tier beam hops per query "
                 "(only observed when the nav tier seeded the search)")
        self.ios = registry.histogram(
            "search_ios", lbl, buckets=COUNT_BUCKETS,
            help="I/O requests per query")
        self.blocked = registry.histogram(
            "search_blocked_wait_seconds", lbl,
            help="per-batch wall time blocked on storage reads",
            unit="seconds")
        self.compute = registry.histogram(
            "search_compute_seconds", lbl,
            help="per-batch wall time in LUT/ADC compute", unit="seconds")

    def observe_batch(self, stats: Sequence, wall_s: float,
                      blocked_s: float, compute_s: float):
        for s in stats:
            self.hops.observe(s.hops)
            self.conv_hops.observe(s.convergence_hop)
            if s.nav_dists > 0:
                self.nav_hops.observe(s.nav_hops)
            self.ios.observe(s.ios)
        self.latency.observe(wall_s)
        self.blocked.observe(blocked_s)
        self.compute.observe(compute_s)
