"""Training launcher: mesh setup, sharded state init, checkpoint/restart,
heartbeats, deterministic data resume.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
        --shape train_4k --steps 100 --ckpt-dir /tmp/ckpt [--scale tiny]

On real clusters this binary runs per-host under the cluster manager; here
it also backs examples/train_lm.py and the fault-tolerance tests.
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint.ckpt import AsyncCheckpointer, latest_step, restore
from repro.configs import get_arch
from repro.distributed import sharding as SH
from repro.distributed.act_sharding import set_policy
from repro.distributed.fault_tolerance import Heartbeat, WorkerFailure
from repro.distributed.train_step import (TrainState, default_optimizer,
                                          make_train_step)


def tiny_lm(cfg):
    return cfg.scaled(n_layers=4, d_model=128, n_heads=4, n_kv_heads=2,
                      head_dim=32, d_ff=256, vocab_size=2048,
                      window=min(cfg.window, 64) if cfg.window else 0,
                      moe=None, dtype="float32")


def build_trainer(arch_id: str, shape_name: str, *, mesh=None,
                  scale: str = "tiny", microbatches: int = 1,
                  lr: float = 3e-3, steps: int = 100):
    """Returns (state_init_fn, jit_step, data_gen, shardings)."""
    arch = get_arch(arch_id)
    shape = arch.shape(shape_name)
    if scale == "tiny":
        if arch.family == "lm":
            arch = dataclasses.replace(arch, model=tiny_lm(arch.model))
            shape = dataclasses.replace(shape, seq_len=128,
                                        global_batch=max(4, mesh.shape.get(
                                            "data", 1) if mesh else 4))
        elif arch.family == "gnn":
            arch = dataclasses.replace(
                arch, model=arch.model.scaled(d_hidden=32, n_classes=8))
            shape = dataclasses.replace(shape, n_nodes=256, n_edges=2048,
                                        d_feat=32)
        elif arch.family == "recsys":
            # keep embed_dim (DLRM ties it to bot_mlp[-1]); shrink tables
            arch = dataclasses.replace(arch, model=arch.model.scaled(
                vocab_sizes=tuple(min(v, 2000) for v in
                                  arch.model.vocab_sizes)))
            shape = dataclasses.replace(shape, batch=min(shape.batch, 64))
    set_policy(mesh)
    from repro.launch.inputs import _make_init
    init_fn = _make_init(arch, shape, mesh or _FakeMesh())
    opt = default_optimizer(total_steps=steps, base_lr=lr)
    opt_init, _ = opt
    step_fn = make_train_step(arch, shape, optimizer=opt,
                              microbatches=microbatches)

    if arch.family == "lm":
        from repro.data.pipeline import TokenStream
        ds = TokenStream(arch.model.vocab_size, shape.seq_len,
                         shape.global_batch)
        data_gen = ds.batch_at
    elif arch.family == "recsys":
        from repro.data.pipeline import ClickStream, SasrecStream
        ds = (SasrecStream(arch.model, shape.batch)
              if arch.model.kind == "sasrec"
              else ClickStream(arch.model, shape.batch))
        data_gen = ds.batch_at
    else:
        from repro.data.pipeline import make_graph
        g = make_graph(shape.n_nodes, max(2, shape.n_edges // shape.n_nodes),
                       shape.d_feat, arch.model.n_classes)
        data_gen = lambda step: g

    shardings = None
    if mesh is not None:
        rule = {"lm": SH.lm_param_rule, "gnn": SH.gnn_param_rule,
                "recsys": SH.rec_param_rule}[arch.family](mesh)
        p_shapes = jax.eval_shape(init_fn, jax.random.PRNGKey(0))
        p_specs = SH.spec_tree(p_shapes, rule)
        o_shapes = jax.eval_shape(opt_init, p_shapes)
        o_specs = SH.opt_state_specs(p_specs, p_shapes, o_shapes)
        state_specs = TrainState(p_specs, o_specs)
        shardings = jax.tree.map(lambda s: NamedSharding(mesh, s),
                                 state_specs,
                                 is_leaf=lambda x: isinstance(x, P))

    def state_init(rng=None):
        params = init_fn(rng if rng is not None else jax.random.PRNGKey(0))
        st = TrainState(params, opt_init(params))
        if shardings is not None:
            st = jax.tree.map(lambda x, s: jax.device_put(x, s), st,
                              shardings)
        return st

    jit_step = jax.jit(step_fn, donate_argnums=(0,)) if mesh is None else \
        jax.jit(step_fn, in_shardings=(shardings, None),
                out_shardings=(shardings, None), donate_argnums=(0,))
    return arch, state_init, jit_step, data_gen, shardings


class _FakeMesh:
    shape = {"model": 1}
    axis_names = ()


def train_loop(arch_id: str, shape_name: str, *, steps: int,
               ckpt_dir: Optional[str] = None, ckpt_every: int = 20,
               mesh=None, scale: str = "tiny", resume: bool = True,
               fail_at_step: Optional[int] = None, verbose: bool = True,
               lr: float = 3e-3):
    """Run training with checkpoint/restart support. Returns history dict.

    `fail_at_step` injects a WorkerFailure (fault-tolerance tests/demos)."""
    arch, state_init, jit_step, data_gen, shardings = build_trainer(
        arch_id, shape_name, mesh=mesh, scale=scale, steps=steps, lr=lr)
    start = 0
    state = state_init()
    if ckpt_dir and resume and latest_step(ckpt_dir) is not None:
        start = latest_step(ckpt_dir)
        state = restore(ckpt_dir, state, shardings=shardings)
        if verbose:
            print(f"[train] resumed from step {start}")
    ck = AsyncCheckpointer()
    hb = Heartbeat(ckpt_dir, 0) if ckpt_dir else None
    losses = []
    t0 = time.time()
    for step in range(start, steps):
        batch = {k: jnp.asarray(v) for k, v in data_gen(step).items()}
        if fail_at_step is not None and step == fail_at_step:
            err = WorkerFailure(f"injected failure at step {step}")
            err.last_step = step
            raise err
        state, metrics = jit_step(state, batch)
        losses.append(float(metrics["loss"]))
        if hb:
            hb.beat(step)
        if ckpt_dir and (step + 1) % ckpt_every == 0:
            ck.save(ckpt_dir, state, step=step + 1)
        if verbose and step % 10 == 0:
            print(f"[train] step {step} loss {losses[-1]:.4f} "
                  f"({(time.time() - t0):.1f}s)", flush=True)
    ck.wait()
    if ckpt_dir:
        ck.save(ckpt_dir, state, step=steps)
        ck.wait()
    return {"losses": losses, "final_step": steps,
            "wall_s": time.time() - t0}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--ckpt-dir")
    ap.add_argument("--scale", default="tiny", choices=["tiny", "full"])
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args(argv)
    hist = train_loop(args.arch, args.shape, steps=args.steps,
                      ckpt_dir=args.ckpt_dir, scale=args.scale, lr=args.lr)
    print(f"final loss {hist['losses'][-1]:.4f} after {args.steps} steps "
          f"in {hist['wall_s']:.1f}s")


if __name__ == "__main__":
    main()
