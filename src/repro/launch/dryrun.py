import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()
# ^ MUST precede any jax import: jax locks the device count at first init.

"""Multi-pod dry-run: lower + compile every (arch x shape) on the production
meshes; record memory analysis, HLO cost analysis, and collective bytes.

    PYTHONPATH=src python -m repro.launch.dryrun [--arch a --shape s]
        [--multi-pod] [--force] [--out benchmarks/artifacts/dryrun]

Each cell writes one JSON artifact; benchmarks/roofline.py consumes them.
"""
import argparse
import json
import re
import sys
import time
import traceback

import jax
import numpy as np

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "opaque": 0,
}

COLLECTIVE_RE = re.compile(
    r"=\s*((?:\([^)]*\)|\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.I)
SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in SHAPE_RE.findall(shape_str):
        b = DTYPE_BYTES.get(dt, 4)
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * b
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum output bytes of every collective op in the partitioned HLO.

    Convention (EXPERIMENTS.md §Roofline): bytes = op OUTPUT size per device
    per occurrence; `-done` ops are skipped (their `-start` was counted).
    """
    out = {}
    for m in COLLECTIVE_RE.finditer(hlo_text):
        shape_str, op = m.group(1), m.group(2).lower()
        out.setdefault(op, [0, 0])
        out[op][0] += 1
        out[op][1] += _shape_bytes(shape_str)
    return {k: {"count": v[0], "bytes": v[1]} for k, v in out.items()}


def run_cell(arch_id: str, shape_name: str, *, multi_pod: bool,
             verbose: bool = True, cp_attn: bool = False) -> dict:
    from repro.distributed.act_sharding import set_policy
    from repro.launch.inputs import build_cell
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=multi_pod)
    set_policy(mesh, cp_attention=cp_attn)
    t0 = time.time()
    cell = build_cell(arch_id, shape_name, mesh)
    jfn = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                  out_shardings=cell.out_shardings,
                  donate_argnums=cell.donate)
    lowered = jfn.lower(*cell.args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    rec = {"arch": arch_id, "shape": shape_name,
           "mesh": list(mesh.shape.values()),
           "mesh_axes": list(mesh.axis_names),
           "n_devices": int(np.prod(list(mesh.shape.values()))),
           "t_lower_s": round(t_lower, 1), "t_compile_s": round(t_compile, 1),
           "meta": {k: v for k, v in cell.meta.items()}}

    try:
        ma = compiled.memory_analysis()
        rec["memory"] = {
            k: int(getattr(ma, k)) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "generated_code_size_in_bytes",
             "alias_size_in_bytes")
            if hasattr(ma, k)}
        print(f"  memory_analysis: {rec['memory']}")
    except Exception as e:  # pragma: no cover
        rec["memory"] = {"error": str(e)}

    try:
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, (list, tuple)) else ca
        rec["cost"] = {k: float(v) for k, v in ca.items()
                       if k in ("flops", "bytes accessed", "transcendentals",
                                "optimal_seconds")
                       or k.startswith("bytes accessed")}
        print(f"  cost_analysis flops={rec['cost'].get('flops'):.3e} "
              f"bytes={rec['cost'].get('bytes accessed', 0):.3e}")
    except Exception as e:  # pragma: no cover
        rec["cost"] = {"error": str(e)}

    try:
        hlo = compiled.as_text()
        rec["collectives"] = collective_bytes(hlo)
        rec["hlo_bytes"] = len(hlo)
        tot = sum(v["bytes"] for v in rec["collectives"].values())
        print(f"  collectives: {tot/1e6:.1f} MB "
              f"{ {k: v['count'] for k, v in rec['collectives'].items()} }")
    except Exception as e:  # pragma: no cover
        rec["collectives"] = {"error": str(e)}
    return rec


ALL_CELLS = None


def list_cells():
    from repro.configs.registry import ASSIGNED_ARCHS, get_arch
    cells = []
    for aid in ASSIGNED_ARCHS:
        arch = get_arch(aid)
        for s in arch.shapes:
            cells.append((aid, s.name, s.name in arch.skip_shapes))
    # the paper's own architecture as extra cells
    for aid in ("aisaq-sift1m", "aisaq-sift1b", "aisaq-kilt-e5"):
        arch = get_arch(aid)
        for s in arch.shapes:
            cells.append((aid, s.name, False))
    return cells


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--cp-attn", action="store_true",
                    help="context-parallel attention (perf config)")
    ap.add_argument("--tag", default="",
                    help="artifact filename suffix (perf iterations)")
    ap.add_argument("--out", default="benchmarks/artifacts/dryrun")
    args = ap.parse_args(argv)

    os.makedirs(args.out, exist_ok=True)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    cells = list_cells()
    if args.arch:
        cells = [c for c in cells if c[0] == args.arch]
    if args.shape:
        cells = [c for c in cells if c[1] == args.shape]

    failures = []
    for multi_pod in meshes:
        tag = ("pod2" if multi_pod else "pod1") + \
            (f"__{args.tag}" if args.tag else "")
        for arch_id, shape_name, skipped in cells:
            path = os.path.join(args.out, f"{arch_id}__{shape_name}__{tag}.json")
            if skipped:
                from repro.configs.registry import get_arch
                rec = {"arch": arch_id, "shape": shape_name, "mesh_tag": tag,
                       "skipped": True,
                       "reason": get_arch(arch_id).skip_reason}
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                print(f"[skip] {arch_id} x {shape_name}: documented skip")
                continue
            if os.path.exists(path) and not args.force:
                print(f"[cached] {arch_id} x {shape_name} ({tag})")
                continue
            print(f"[dryrun] {arch_id} x {shape_name} ({tag}) ...", flush=True)
            try:
                rec = run_cell(arch_id, shape_name, multi_pod=multi_pod,
                               cp_attn=args.cp_attn)
                rec["mesh_tag"] = tag
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                print(f"  OK lower={rec['t_lower_s']}s "
                      f"compile={rec['t_compile_s']}s", flush=True)
            except Exception as e:
                failures.append((arch_id, shape_name, tag, str(e)))
                traceback.print_exc()
                print(f"  FAIL {arch_id} x {shape_name}: {e}", flush=True)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f[:3], f[3][:200])
        sys.exit(1)
    print("\nall dry-run cells OK")


if __name__ == "__main__":
    main()
