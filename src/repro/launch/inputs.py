"""Dry-run cell construction: (arch x shape x mesh) -> lowerable jit + specs.

`input_specs(arch, shape)` returns ShapeDtypeStruct stand-ins for every model
input (weak-type-correct, shardable, no device allocation); `build_cell`
bundles them with the step function and in/out NamedShardings.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, IndexConfig, ShapeConfig
from repro.configs.registry import get_arch
from repro.distributed import sharding as SH
from repro.distributed.train_step import (TrainState, default_optimizer,
                                          make_serve_step, make_train_step)

SDS = jax.ShapeDtypeStruct


class Cell(NamedTuple):
    arch_id: str
    shape_name: str
    fn: Callable                  # fn(*args)
    args: tuple                   # tree of ShapeDtypeStruct
    in_shardings: tuple
    out_shardings: Any
    meta: dict                    # model_flops, bytes estimates, notes
    donate: tuple = ()            # donated arg indices (state/cache alias)


def _ns(mesh, tree_of_specs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_of_specs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# per-family batch ShapeDtypeStructs
# ---------------------------------------------------------------------------


def lm_batch_sds(arch: ArchConfig, shape: ShapeConfig) -> dict:
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "lm_train":
        return {"tokens": SDS((B, S), jnp.int32),
                "labels": SDS((B, S), jnp.int32)}
    if shape.kind == "lm_prefill":
        return {"tokens": SDS((B, S), jnp.int32)}
    if shape.kind == "lm_decode":
        return {"token": SDS((B,), jnp.int32), "pos": SDS((), jnp.int32)}
    raise ValueError(shape.kind)


def gnn_batch_sds(arch: ArchConfig, shape: ShapeConfig, ways: int = 512
                  ) -> dict:
    import os
    f32, i32 = jnp.float32, jnp.int32
    if shape.kind == "gnn_full":
        if os.environ.get("REPRO_GNN") == "sharded":
            # pre-partitioned by destination shard (gnn_sharded.partition_edges)
            e_pad = -(-int(shape.n_edges * 1.25) // ways // 8) * 8
            return {"feats": SDS((shape.n_nodes, shape.d_feat), f32),
                    "edges": SDS((ways, e_pad, 2), i32),
                    "labels": SDS((shape.n_nodes,), i32),
                    "mask": SDS((shape.n_nodes,), f32)}
        # edge list padded to a shardable multiple (gnn.pad_edges no-ops)
        ne = -(-shape.n_edges // 512) * 512
        return {"feats": SDS((shape.n_nodes, shape.d_feat), f32),
                "edges": SDS((ne, 2), i32),
                "labels": SDS((shape.n_nodes,), i32),
                "mask": SDS((shape.n_nodes,), f32)}
    if shape.kind == "gnn_minibatch":
        B, (f1, f2), F = shape.batch_nodes, shape.fanout, shape.d_feat
        return {"seed_feats": SDS((B, F), f32),
                "nbr1_feats": SDS((B, f1, F), f32),
                "nbr2_feats": SDS((B, f1, f2, F), f32),
                "labels": SDS((B,), i32)}
    if shape.kind == "gnn_batched":
        G = shape.batch_graphs
        return {"feats": SDS((G, shape.n_nodes, shape.d_feat), f32),
                "edges": SDS((G, shape.n_edges, 2), i32),
                "labels": SDS((G,), i32)}
    raise ValueError(shape.kind)


def rec_batch_sds(arch: ArchConfig, shape: ShapeConfig) -> dict:
    cfg = arch.model
    f32, i32 = jnp.float32, jnp.int32
    B = shape.batch
    if cfg.kind == "sasrec":
        S = cfg.seq_len
        b = {"seq": SDS((B, S), i32)}
        if shape.kind == "rec_train":
            b.update({"pos_items": SDS((B, S), i32),
                      "neg_items": SDS((B, S), i32),
                      "seq_mask": SDS((B, S), f32)})
        elif shape.kind == "rec_serve":
            b["target"] = SDS((B,), i32)
        elif shape.kind == "rec_retrieval":
            b = {"seq": SDS((1, S), i32),
                 "cand_ids": SDS((shape.n_candidates,), i32)}
        return b
    hot = cfg.multi_hot
    b = {"sparse": SDS((B, cfg.n_sparse, hot), i32)}
    if cfg.n_dense:
        b["dense"] = SDS((B, cfg.n_dense), f32)
    if shape.kind == "rec_train":
        b["label"] = SDS((B,), i32)
    if shape.kind == "rec_retrieval":
        b = {"sparse": SDS((1, cfg.n_sparse, hot), i32),
             "cand_ids": SDS((shape.n_candidates,), i32)}
        if cfg.n_dense:
            b["dense"] = SDS((1, cfg.n_dense), f32)
    return b


def input_specs(arch: ArchConfig, shape: ShapeConfig) -> dict:
    if arch.family == "lm":
        return lm_batch_sds(arch, shape)
    if arch.family == "gnn":
        return gnn_batch_sds(arch, shape)
    if arch.family == "recsys":
        return rec_batch_sds(arch, shape)
    if arch.family == "ann":
        cfg: IndexConfig = arch.model
        return {"queries": SDS((shape.batch, cfg.dim), jnp.float32)}
    raise ValueError(arch.family)


# ---------------------------------------------------------------------------
# model FLOPs (the "useful work" yardstick for §Roofline)
# ---------------------------------------------------------------------------


def model_flops(arch: ArchConfig, shape: ShapeConfig) -> float:
    if arch.family == "lm":
        cfg = arch.model
        n_act = cfg.n_active_params()
        if shape.kind == "lm_train":
            return 6.0 * n_act * shape.global_batch * shape.seq_len
        if shape.kind == "lm_prefill":
            return 2.0 * n_act * shape.global_batch * shape.seq_len
        return 2.0 * n_act * shape.global_batch        # decode: per token
    if arch.family == "gnn":
        cfg = arch.model
        H = cfg.d_hidden
        if shape.kind == "gnn_full":
            per_layer = 2 * shape.n_edges * H + 4 * shape.n_nodes * H * H
            fwd = cfg.n_layers * per_layer + 2 * shape.n_nodes * shape.d_feat * H
            return 3.0 * fwd
        if shape.kind == "gnn_minibatch":
            B, (f1, f2) = shape.batch_nodes, shape.fanout
            nodes = B * (1 + f1 + f1 * f2)
            return 3.0 * (4 * nodes * shape.d_feat * H + 4 * B * H * H)
        nodes = shape.batch_graphs * shape.n_nodes
        return 3.0 * cfg.n_layers * 4 * nodes * cfg.d_hidden * shape.d_feat
    if arch.family == "recsys":
        cfg = arch.model
        B = shape.batch
        if shape.kind == "rec_retrieval":
            return 2.0 * shape.n_candidates * cfg.embed_dim
        dims = []
        if cfg.kind == "dlrm":
            dims = list(zip((cfg.n_dense,) + cfg.bot_mlp[:-1], cfg.bot_mlp))
            f = cfg.n_sparse + 1
            d_int = f * (f - 1) // 2 + cfg.bot_mlp[-1]
            dims += list(zip((d_int,) + cfg.top_mlp[:-1], cfg.top_mlp))
            dims += [(f * cfg.embed_dim, f)]          # interaction
        elif cfg.kind in ("dcnv2", "widedeep"):
            d0 = cfg.n_dense + cfg.n_sparse * cfg.embed_dim
            dims = list(zip((d0,) + cfg.mlp, cfg.mlp + (1,)))
            dims += [(d0, d0)] * cfg.n_cross_layers
        else:  # sasrec
            S, D = cfg.seq_len, cfg.embed_dim
            per_tok = 4 * D * D + 2 * S * D + 2 * D * D
            dims = [(S * per_tok // 2, 1)]
        mults = sum(a * b for a, b in dims)
        fac = 6.0 if shape.kind == "rec_train" else 2.0
        return fac * B * mults
    if arch.family == "ann":
        cfg = arch.model
        # per query: ~hops * w * (R * m ADC adds + exact dist) + LUT
        hops, w = 64, cfg.beamwidth
        per_q = hops * w * (cfg.R * cfg.pq_m * 2 + 2 * cfg.dim) \
            + 2 * cfg.dim * cfg.pq_ks
        return float(shape.batch * per_q)
    raise ValueError(arch.family)


# ---------------------------------------------------------------------------
# cell builder
# ---------------------------------------------------------------------------


def build_cell(arch_id: str, shape_name: str, mesh: Mesh) -> Cell:
    arch = get_arch(arch_id)
    shape = arch.shape(shape_name)
    fam = arch.family
    meta = {"model_flops": model_flops(arch, shape)}

    if fam == "ann":
        return _build_ann_cell(arch, shape, mesh, meta)

    # ---- parameter/optimizer shapes + specs (abstract, no allocation) ----
    train_kind = shape.kind in ("lm_train", "gnn_full", "gnn_minibatch",
                                "gnn_batched", "rec_train")
    if fam == "recsys":
        # table-wise replication is serve-only (§Perf "tablewise")
        rule = SH.rec_param_rule(mesh, tablewise=not train_kind)
    else:
        rule = {"lm": SH.lm_param_rule,
                "gnn": SH.gnn_param_rule}[fam](mesh)
    init_fn = _make_init(arch, shape, mesh)
    p_shapes = jax.eval_shape(init_fn, jax.random.PRNGKey(0))
    p_specs = SH.spec_tree(p_shapes, rule)
    import os as _os
    gnn_sharded = (_os.environ.get("REPRO_GNN") == "sharded"
                   and shape.kind == "gnn_full")
    n_dev = 1
    for a in mesh.axis_names:
        n_dev *= mesh.shape[a]
    if gnn_sharded:
        batch_sds = gnn_batch_sds(arch, shape, ways=n_dev)
    else:
        batch_sds = input_specs(arch, shape)
    bspec_all = SH.batch_specs(shape.kind, mesh)
    b_specs = {k: bspec_all[k] for k in batch_sds}
    if gnn_sharded:
        b_specs["edges"] = P(tuple(mesh.axis_names), None, None)
    train = shape.kind in ("lm_train", "gnn_full", "gnn_minibatch",
                           "gnn_batched", "rec_train")

    if train:
        opt_init, _ = default_optimizer()
        o_shapes = jax.eval_shape(opt_init, p_shapes)
        o_specs = SH.opt_state_specs(p_specs, p_shapes, o_shapes)
        state_sds = TrainState(p_shapes, o_shapes)
        state_specs = TrainState(p_specs, o_specs)
        # microbatch LM training so layer-scan residuals (L x B_mb x S x D
        # bf16) stay under the budget; fewer microbatches = fewer FSDP
        # weight re-gathers (REPRO_MB_BUDGET_GB tunes the tradeoff, §Perf)
        n_mb = 1
        if shape.kind == "lm_train":
            budget = float(_os.environ.get("REPRO_MB_BUDGET_GB", "4")) * 1e9
            dp = 1
            for a in SH.dp_axes(mesh):
                dp *= mesh.shape[a]
            b_local = shape.global_batch // dp
            cfg = arch.model
            resid_per_seq = 2 * cfg.n_layers * shape.seq_len * cfg.d_model
            b_mb_max = max(1, int(budget // resid_per_seq))
            n_mb = max(1, -(-b_local // b_mb_max))
            while b_local % n_mb:
                n_mb += 1
        fn = make_train_step(arch, shape, microbatches=n_mb)
        meta["microbatches"] = n_mb
        args = (state_sds, batch_sds)
        in_sh = (_ns(mesh, state_specs), _ns(mesh, b_specs))
        out_sh = (_ns(mesh, state_specs), None)
        meta["params"] = _tree_bytes(p_shapes)
        return Cell(arch.arch_id, shape.name, fn, args, in_sh, out_sh, meta,
                    donate=(0,))   # state buffers alias across steps

    # ---- serve cells ------------------------------------------------------
    fn0 = make_serve_step(arch, shape)
    if shape.kind == "lm_decode":
        from repro.models.transformer import init_cache
        cfg = arch.model
        cache_sds = jax.eval_shape(
            lambda: init_cache(cfg, shape.global_batch, shape.seq_len))
        # batch shards over dp only when divisible (long_500k has B=1:
        # replicate batch, shard the KV sequence dim over `model` — SP decode)
        dp = SH.dp_axes(mesh)
        dp_size = 1
        for a in dp:
            dp_size *= mesh.shape[a]
        bax = dp if shape.global_batch % dp_size == 0 else ()
        cspec = P(None, bax if bax else None, "model", None, None)
        cache_spec = jax.tree.map(lambda _: cspec, cache_sds)
        b_specs = {"token": P(bax if bax else None), "pos": P()}
        args = (p_shapes, cache_sds, batch_sds)
        in_sh = (_ns(mesh, p_specs), _ns(mesh, cache_spec), _ns(mesh, b_specs))
        out_sh = (None, _ns(mesh, cache_spec))
        return Cell(arch.arch_id, shape.name, fn0, args, in_sh, out_sh, meta,
                    donate=(1,))   # KV cache aliases in place
    args = (p_shapes, batch_sds)
    in_sh = (_ns(mesh, p_specs), _ns(mesh, b_specs))
    return Cell(arch.arch_id, shape.name, fn0, args, in_sh, None, meta)


def _make_init(arch: ArchConfig, shape: ShapeConfig, mesh: Mesh):
    if arch.family == "lm":
        from repro.models.transformer import init_lm
        ep = mesh.shape.get("model", 1)
        return functools.partial(init_lm, cfg=arch.model, ep=ep)
    if arch.family == "gnn":
        from repro.models.gnn import init_gnn
        return functools.partial(init_gnn, cfg=arch.model, d_feat=shape.d_feat)
    from repro.models.recsys import init_recsys
    return functools.partial(init_recsys, cfg=arch.model)


def _tree_bytes(shapes) -> int:
    return int(jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda s: int(s.size) * s.dtype.itemsize, shapes), 0))


# ---------------------------------------------------------------------------
# ANN cells (the paper's own architecture)
# ---------------------------------------------------------------------------


def _build_ann_cell(arch: ArchConfig, shape: ShapeConfig, mesh: Mesh,
                    meta: dict) -> Cell:
    from repro.core.chunk_layout import layout_for
    from repro.core.sharded_search import ShardedIndexArrays, sharded_search_fn

    cfg: IndexConfig = arch.model
    layout = layout_for(cfg, "aisaq")
    W = layout.device_stride // 4
    total_chunk_gb = cfg.n_vectors * layout.device_stride / 1e9
    per_dev_budget = 8.0     # GB of HBM we allow the chunk table per device
    # mode A: index shards over `model` only, queries over dp;
    # mode B: index shards over EVERY axis, queries replicated + chunked.
    mode_b = total_chunk_gb / mesh.shape["model"] > per_dev_budget
    if mode_b:
        shard_axes = tuple(mesh.axis_names)
        query_axes: tuple = ()
    else:
        shard_axes = ("model",)
        query_axes = SH.dp_axes(mesh)
    n_shards = 1
    for a in shard_axes:
        n_shards *= mesh.shape[a]
    N_s = -(-cfg.n_vectors // n_shards)
    m, ks = cfg.pq_m, cfg.pq_ks
    dsub = cfg.dim // m
    arrays = ShardedIndexArrays(
        chunk_words=SDS((n_shards, N_s, W), jnp.int32),
        centroids=SDS((m, ks, dsub), jnp.float32),
        ep_ids=SDS((n_shards, cfg.n_ep), jnp.int32),
        ep_codes=SDS((n_shards, cfg.n_ep, m), jnp.int32),
        offsets=SDS((n_shards,), jnp.int32))
    queries = SDS((shape.batch, cfg.dim), jnp.float32)
    # packed visited bitmask (N_s/32 u32 per query) allows 4x larger query
    # chunks at the same working set (§Perf "bitmask")
    qchunk = 128 if (mode_b and shape.batch > 128) else 0
    search = sharded_search_fn(
        mesh, k=10, L=128, w=cfg.beamwidth, max_hops=cfg.max_hops,
        layout=layout, metric=cfg.metric, backend="ref",
        query_axes=query_axes, shard_axes=shard_axes, query_chunk=qchunk)
    sspec = P(shard_axes, None, None)
    arr_specs = ShardedIndexArrays(
        chunk_words=sspec, centroids=P(),
        ep_ids=P(shard_axes, None), ep_codes=P(shard_axes, None, None),
        offsets=P(shard_axes))
    qspec = P(query_axes, None) if query_axes else P(None, None)
    in_sh = (_ns(mesh, arr_specs), NamedSharding(mesh, qspec))
    meta.update(mode="B" if mode_b else "A", n_shards=n_shards,
                chunk_gb_per_dev=total_chunk_gb / n_shards)
    return Cell(arch.arch_id, shape.name,
                lambda a, q: search(a, q), (arrays, queries), in_sh, None,
                meta)
