"""Analytic per-device FLOPs / HBM bytes / collective bytes, per cell.

Why analytic: XLA's HloCostAnalysis counts while/scan bodies ONCE (verified
empirically — see EXPERIMENTS.md §Roofline "loop caveat"), so cost_analysis
under-reports any scanned computation by its trip count. We control every
einsum in this codebase, so the estimator below reconstructs the loop-true
totals from the model configs + the ACTUAL sharding/remat strategy (e.g.
attention compute is replicated over the `model` axis in the baseline — the
estimator charges it accordingly, which is exactly what the roofline's
"useful ratio" is meant to expose).

Coefficient conventions (documented in EXPERIMENTS.md):
  matmul train cost = 4x fwd   (fwd + remat recompute + 2x bwd)
  flash-vjp train   = 4.5x fwd (fwd + remat + recompute-s + 2.5x bwd)
  serve cost        = 1x fwd
All FLOPs are 2*MACs. Block-level attention accounting uses the real
(block_q, block_kv) pair counts of the band mask.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

from repro.configs.base import ArchConfig, IndexConfig, ShapeConfig

# hardware constants (TPU v5e per chip)
PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

BQ, BK = 512, 1024          # flash block sizes (models/layers.py defaults)


def attn_block_pairs(S: int, *, causal: bool, window: int, chunked: bool,
                     bq: int = BQ, bk: int = BK) -> int:
    """Number of computed (q-block, kv-block) pairs under band skipping."""
    nq, nk = -(-S // bq), -(-S // bk)
    total = 0
    for qi in range(nq):
        hi = min((qi * bq + bq + bk - 1) // bk, nk) if causal else nk
        lo = 0
        if window > 0 and not chunked:
            lo = max(0, (qi * bq - (window - 1)) // bk)
        if window > 0 and chunked:
            lo = (qi * bq) // window * window // bk
        total += max(0, hi - lo)
    return total


def _lm_layer_flops(cfg, S: int, *, decode_T: int = 0) -> Dict[str, float]:
    """Per-layer fwd FLOPs for ONE sequence (or one decode token)."""
    D, Hhd, KVhd, hd, H = (cfg.d_model, cfg.q_dim, cfg.kv_dim, cfg.head_dim,
                           cfg.n_heads)
    out = {}
    ntok = 1 if decode_T else S
    out["proj"] = 2.0 * ntok * (D * (Hhd + 2 * KVhd) + Hhd * D)
    if decode_T:
        eff = decode_T if not cfg.window else min(cfg.window, decode_T)
        if cfg.attention == "chunked_global":
            n_glob = cfg.n_layers // cfg.global_every
            frac_glob = n_glob / cfg.n_layers
            eff = frac_glob * decode_T + (1 - frac_glob) * min(cfg.window,
                                                               decode_T)
        out["attn"] = 4.0 * eff * H * hd
    else:
        if cfg.attention == "full":
            pairs = attn_block_pairs(S, causal=True, window=0, chunked=False)
        elif cfg.attention == "sliding":
            pairs = attn_block_pairs(S, causal=True, window=cfg.window,
                                     chunked=False)
        else:
            p_loc = attn_block_pairs(S, causal=True, window=cfg.window,
                                     chunked=True)
            p_glob = attn_block_pairs(S, causal=True, window=0, chunked=False)
            n_glob = cfg.n_layers // cfg.global_every
            pairs = (p_glob * n_glob + p_loc * (cfg.n_layers - n_glob)) \
                / cfg.n_layers
        out["attn"] = 4.0 * pairs * BQ * BK * H * hd
    if cfg.moe is None:
        out["ffn"] = 6.0 * ntok * D * cfg.d_ff
    else:
        m = cfg.moe
        # capacity-factored routed einsums run on E*C slots (global dispatch)
        out["ffn"] = 6.0 * ntok * m.capacity_factor * m.top_k * D * m.d_expert
        out["ffn"] += 6.0 * ntok * D * m.d_shared + 2.0 * ntok * D * m.n_experts
    return out


def lm_cell_terms(arch: ArchConfig, shape: ShapeConfig, chips: int,
                  model_ways: int, dp_ways: int, *,
                  naive_flash: bool = False, cp_attention: bool = False,
                  mb_budget: float = 4e9) -> Dict[str, float]:
    cfg = arch.model
    train = shape.kind == "lm_train"
    decode = shape.kind == "lm_decode"
    B, S = shape.global_batch, shape.seq_len
    lf = _lm_layer_flops(cfg, S, decode_T=S if decode else 0)
    if naive_flash and not decode:
        # baseline masked-scan flash: NO band skipping -> full nq x nk pairs
        nq, nk = -(-S // BQ), -(-S // BK)
        lf["attn"] = 4.0 * nq * nk * BQ * BK * cfg.n_heads * cfg.head_dim
    L = cfg.n_layers
    ntok_total = B * (1 if decode else S)
    cm = 4.0 if train else 1.0            # matmul multiplier
    ca = 4.5 if train else 1.0            # flash-vjp multiplier
    # matmuls/MoE/logits shard over (dp x model); attention compute is
    # replicated over `model` UNLESS context-parallel (§Perf "cp-attn")
    attn_ways = chips if cp_attention else dp_ways
    flops_mm = cm * B * L * (lf["proj"] + lf["ffn"]) / chips
    flops_attn = ca * B * L * lf["attn"] / attn_ways
    flops_logits = cm * 2.0 * ntok_total * cfg.d_model * cfg.vocab_size / chips
    flops = flops_mm + flops_attn + flops_logits

    # HBM bytes/device: params read 3x (fwd+remat+bwd) as bf16 + opt fp32
    # rw (train) OR params 1x (serve); activations ~12 B/elem-layer rw;
    # decode reads the KV cache once per token.
    p_bytes = arch.model.n_params() * 2 / chips
    if train:
        bytes_params = 3 * p_bytes + 2 * 12 * arch.model.n_params() / chips
        bytes_act = 12.0 * ntok_total * cfg.d_model * L / chips
    else:
        bytes_params = (cfg.n_active_params() if cfg.moe else
                        cfg.n_params()) * 2 / chips
        bytes_act = 6.0 * ntok_total * cfg.d_model * L / chips
    bytes_kv = 0.0
    if decode:
        eff = S if not cfg.window else min(cfg.window, S)
        if cfg.attention == "chunked_global":
            n_glob = L // cfg.global_every
            eff_tot = n_glob * S + (L - n_glob) * min(cfg.window, S)
        else:
            eff_tot = L * (S if cfg.attention == "full" else eff)
        bytes_kv = B * eff_tot * cfg.kv_dim * 2 * 2 / chips
    hbm = bytes_params + bytes_act + bytes_kv

    # collectives/device: FSDP layer all-gathers (bf16 params over `data`)
    # x (fwd [+ remat + bwd gathers] ~3x) + partial-grad reduce-scatter
    # + logits-loss psum of d_hidden + MoE dispatch gathers.
    layer_bytes = (arch.model.n_params()
                   - cfg.vocab_size * cfg.d_model
                   * (1 if cfg.tie_embeddings else 2)) * 2 / L
    resid_per_seq = 2 * L * S * cfg.d_model
    n_mb = max(1, (B // dp_ways) // max(1, int(mb_budget // resid_per_seq))) \
        if train else 1
    coll = 0.0
    if train:
        # FSDP weight all-gathers: per layer, per microbatch, x3 (fwd +
        # remat recompute + bwd)
        coll += 3 * layer_bytes * L * n_mb * (dp_ways - 1) / dp_ways / model_ways
        # gradient reduce-scatter over `data` (once per step, bf16 partials)
        coll += (cfg.n_params() * 2 / model_ways) * (dp_ways - 1) / dp_ways
        coll += ntok_total * cfg.d_model * 4 / chips   # dlogits psum
        if cfg.moe:
            coll += 2 * 3 * ntok_total * cfg.d_model * 2 / dp_ways  # dispatch
    else:
        coll += layer_bytes * L * (dp_ways - 1) / dp_ways / model_ways
        if decode:
            coll += L * B * cfg.q_dim * 4 / dp_ways    # attn partial psum
    return dict(flops=flops, hbm_bytes=hbm, coll_bytes=coll)


def gnn_cell_terms(arch, shape, chips, model_ways, dp_ways):
    cfg = arch.model
    H = cfg.d_hidden
    train = 3.0
    if shape.kind == "gnn_full":
        E, N, F = shape.n_edges, shape.n_nodes, shape.d_feat
        flops = train * (cfg.n_layers * (2 * E * H + 4 * N * H * H)
                         + 2 * N * F * H) / dp_ways
        hbm = train * (E * (F + H) * 4 + N * F * 4 * 2) / dp_ways
        coll = cfg.n_layers * train * N * H * 4   # partial-agg psum (repl out)
    elif shape.kind == "gnn_minibatch":
        B, (f1, f2), F = shape.batch_nodes, shape.fanout, shape.d_feat
        nodes = B * (1 + f1 + f1 * f2)
        flops = train * (4 * nodes * F * H + 4 * B * H * H) / chips
        hbm = train * nodes * F * 4 / chips
        coll = B * H * 4 / chips
    else:
        nodes = shape.batch_graphs * shape.n_nodes
        flops = train * cfg.n_layers * (4 * nodes * shape.d_feat * H) / chips
        hbm = train * nodes * shape.d_feat * 4 / chips
        coll = shape.batch_graphs * 4 / chips
    return dict(flops=flops, hbm_bytes=hbm, coll_bytes=coll)


def rec_cell_terms(arch, shape, chips, model_ways, dp_ways):
    from repro.launch.inputs import model_flops
    cfg = arch.model
    B = shape.batch
    flops_total = model_flops(arch, shape)
    if shape.kind == "rec_retrieval":
        C, D = shape.n_candidates, cfg.embed_dim
        flops = 2.0 * C * D / chips + 2 * C * D * D / chips  # score + proj
        hbm = C * D * 4 / chips
        coll = C * 4 / chips                                  # topk merge
        return dict(flops=flops, hbm_bytes=hbm, coll_bytes=coll)
    flops = flops_total / dp_ways      # dense interaction replicated on model
    hot = cfg.multi_hot
    row_traffic = B * cfg.n_sparse * hot * cfg.embed_dim * 4
    fac = 3.0 if shape.kind == "rec_train" else 1.0
    hbm = fac * row_traffic / chips + flops / 50  # mlp act traffic, coarse
    # gathered rows cross the model axis (tables row-sharded)
    coll = fac * row_traffic * (model_ways - 1) / model_ways / dp_ways
    return dict(flops=flops, hbm_bytes=hbm, coll_bytes=coll)


def ann_cell_terms(arch, shape, chips, model_ways, dp_ways, *, mode_b,
                   hops: int = 64, L: int = 128, w: int = 4,
                   int8_adc: bool = False):
    from repro.core.chunk_layout import layout_for
    cfg: IndexConfig = arch.model
    lay = layout_for(cfg, "aisaq")
    nq = shape.batch
    # every shard searches every query in mode B; in mode A queries split dp
    q_per_dev = nq if mode_b else max(1, nq // dp_ways)
    # ADC as one-hot MXU matmuls (kernels/chunk_adc.py): R*m*ks MACs per hop;
    # int8 ADC (§Perf "adc-int8") runs at 2x the bf16 MXU rate -> charge
    # those MACs at half cost
    adc_rate = 0.5 if int8_adc else 1.0
    per_hop = adc_rate * 2.0 * cfg.R * cfg.pq_m * cfg.pq_ks + 2.0 * cfg.dim
    flops = q_per_dev * hops * w * per_hop \
        + q_per_dev * 2.0 * cfg.dim * cfg.pq_ks * cfg.pq_m  # LUT
    hbm = q_per_dev * hops * w * lay.device_stride           # chunk DMAs
    k = 10
    coll = q_per_dev * k * 8 * (chips if mode_b else model_ways)  # topk gather
    return dict(flops=flops, hbm_bytes=hbm, coll_bytes=coll)


def cell_terms(arch: ArchConfig, shape: ShapeConfig, *, chips: int = 256,
               model_ways: int = 16, dp_ways: int = 16,
               mode_b: bool = False, **opts) -> Dict[str, float]:
    fam = arch.family
    f = {"lm": lm_cell_terms, "gnn": gnn_cell_terms,
         "recsys": rec_cell_terms}.get(fam)
    if fam == "ann":
        t = ann_cell_terms(arch, shape, chips, model_ways, dp_ways,
                           mode_b=mode_b, **opts)
    elif fam == "lm":
        t = f(arch, shape, chips, model_ways, dp_ways, **opts)
    else:
        t = f(arch, shape, chips, model_ways, dp_ways)
    t["t_compute"] = t["flops"] / PEAK_FLOPS
    t["t_memory"] = t["hbm_bytes"] / HBM_BW
    t["t_collective"] = t["coll_bytes"] / LINK_BW
    t["bottleneck"] = max(("t_compute", "t_memory", "t_collective"),
                          key=lambda k: t[k])
    return t
