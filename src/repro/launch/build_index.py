"""Index-construction launcher (the offline/"training" phase of the paper).

    PYTHONPATH=src python -m repro.launch.build_index --out /tmp/idx \
        [--n 20000 --dim 96 --mode aisaq --R 24 --pq-m 16] \
        [--shards 4] [--metric l2|mips]

Builds synthetic corpora by default; pass --data <file.npy> for real
vectors. With --shards > 1 builds the per-shard sub-indices of the paper's
Fig.-5 multi-server layout.
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", required=True)
    ap.add_argument("--data", help=".npy of vectors (else synthetic)")
    ap.add_argument("--n", type=int, default=20000)
    ap.add_argument("--dim", type=int, default=96)
    ap.add_argument("--mode", default="aisaq", choices=["aisaq", "diskann"])
    ap.add_argument("--metric", default="l2", choices=["l2", "mips"])
    ap.add_argument("--R", type=int, default=24)
    ap.add_argument("--pq-m", type=int, default=16)
    ap.add_argument("--build-L", type=int, default=40)
    ap.add_argument("--shards", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.configs.base import IndexConfig
    from repro.core.build import build_index
    from repro.data.vectors import make_clustered

    if args.data:
        vectors = np.load(args.data)
    else:
        vectors = make_clustered(args.n, args.dim, seed=args.seed)
    n, dim = vectors.shape
    cfg = IndexConfig(name=os.path.basename(args.out), n_vectors=n, dim=dim,
                      metric=args.metric, R=args.R, pq_m=args.pq_m,
                      build_L=args.build_L, mode=args.mode)
    t0 = time.time()
    if args.shards == 1:
        meta = build_index(args.out, vectors, cfg, seed=args.seed,
                           verbose=True)
        print(f"built {args.out}: chunk={meta['chunk_bytes']}B "
              f"io/hop={meta['io_bytes']}B in {time.time()-t0:.0f}s")
    else:
        bounds = np.linspace(0, n, args.shards + 1).astype(int)
        for s in range(args.shards):
            sub = vectors[bounds[s]:bounds[s + 1]]
            scfg = cfg.scaled(n_vectors=sub.shape[0])
            build_index(os.path.join(args.out, f"shard{s}"), sub, scfg,
                        seed=args.seed + s, verbose=True)
        print(f"built {args.shards} shard indices under {args.out} "
              f"in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
