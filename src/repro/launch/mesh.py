"""Production mesh construction (functions only — importing this module
never touches jax device state)."""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 (512 chips, 2 pods).

    `pod`  — DCN tier: pure DP (LM), extra index shards (ANN)
    `data` — ICI: batch DP + FSDP
    `model`— ICI: TP / EP / index shards
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_test_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh for CPU multi-device tests (8 virtual devices)."""
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))
