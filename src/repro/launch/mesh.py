"""Production mesh construction (functions only — importing this module
never touches jax device state).

``make_mesh_compat`` is the version-compat shim: newer JAX exposes
``jax.sharding.AxisType`` and ``jax.make_mesh(..., axis_types=...)``;
older releases (<= 0.4.x) have neither. All mesh construction in this
repo (and in the subprocess-driven distributed tests) goes through the
shim so the same code runs on both.
"""
from __future__ import annotations

import jax


def make_mesh_compat(shape, axes):
    """jax.make_mesh with AxisType.Auto on JAX versions that support it,
    plain jax.make_mesh elsewhere."""
    try:
        from jax.sharding import AxisType
    except ImportError:
        return jax.make_mesh(shape, axes)
    try:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    except TypeError:
        return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 (512 chips, 2 pods).

    `pod`  — DCN tier: pure DP (LM), extra index shards (ANN)
    `data` — ICI: batch DP + FSDP
    `model`— ICI: TP / EP / index shards
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_test_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh for CPU multi-device tests (8 virtual devices)."""
    return make_mesh_compat(shape, axes)
