"""Serving launcher: host-backend AiSAQ retrieval service with batching,
multi-corpus switching and latency reporting.

    PYTHONPATH=src python -m repro.launch.serve --index-dir <dir> \
        [--corpora a=path1 b=path2] [--queries 200] [--L 48] [--hedge 2]

If no index is given, builds a demo corpus first (same as quickstart).
"""
from __future__ import annotations

import argparse
import os
import tempfile
import time

import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--corpora", nargs="*", default=None,
                    help="name=path pairs of index dirs")
    ap.add_argument("--queries", type=int, default=200)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--L", type=int, default=48)
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--hedge", type=int, default=1)
    args = ap.parse_args(argv)

    from repro.core.index_switch import IndexManager
    from repro.serving.engine import ServingEngine
    from repro.data.vectors import make_clustered, make_queries

    if args.corpora:
        paths = dict(c.split("=", 1) for c in args.corpora)
        import json
        any_meta = json.load(open(os.path.join(
            next(iter(paths.values())), "meta.json")))
        dim = any_meta["dim"]
        base = None
    else:
        print("no corpora given — building a demo index ...")
        from repro.configs.base import IndexConfig
        from repro.core.build import build_index
        dim = 64
        base = make_clustered(4000, dim, seed=0)
        cfg = IndexConfig(name="demo", n_vectors=4000, dim=dim, R=24,
                          pq_m=16, build_L=48)
        root = tempfile.mkdtemp(prefix="serve_")
        build_index(os.path.join(root, "demo"), base, cfg, mode="aisaq")
        paths = {"demo": os.path.join(root, "demo")}

    mgr = IndexManager(paths)

    def search(queries, k):
        ids, _ = mgr.search_batch(queries, k, L=max(args.L, k))
        return ids

    eng = ServingEngine({c: search for c in paths}, switch_fn=mgr.switch,
                        max_batch=args.max_batch, hedge=args.hedge,
                        replicas=[search] * max(1, args.hedge))
    if base is not None:
        queries = make_queries(args.queries, base, seed=2)
    else:
        rng = np.random.default_rng(0)
        queries = rng.normal(size=(args.queries, dim)).astype(np.float32)
    corpora = list(paths)
    t0 = time.time()
    reqs = [eng.submit(queries[i], corpus=corpora[i % len(corpora)],
                       k=args.k) for i in range(args.queries)]
    for r in reqs:
        r.event.wait(30)
    wall = time.time() - t0
    print(f"served {args.queries} queries in {wall:.2f}s "
          f"({args.queries / wall:.0f} qps)")
    print("latency:", eng.latency_percentiles())
    if eng.switch_times:
        print(f"index switches: {len(eng.switch_times)}, median "
              f"{np.median(eng.switch_times)*1e3:.2f} ms")
    print(f"resident: {mgr.resident_bytes()/1e3:.1f} KB")
    eng.stop()
    mgr.close()


if __name__ == "__main__":
    main()
