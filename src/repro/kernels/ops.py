"""jit'd public wrappers over the Pallas kernels with backend dispatch.

backend:
  "ref"               pure-jnp oracle (fast under XLA:CPU; default off-TPU)
  "pallas_interpret"  Pallas kernel body executed in interpret mode (CPU
                      validation — used by tests/test_kernels.py)
  "pallas"            compiled Pallas (TPU target)
  "auto"              pallas on TPU, ref elsewhere
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.core.chunk_layout import ChunkLayout
from repro.kernels import ref as _ref
from repro.kernels.chunk_adc import fused_hop as _fused_hop_pallas, \
    quantize_lut
from repro.kernels.pq_adc import pq_adc as _pq_adc_pallas
from repro.kernels.pq_lut import pq_lut as _pq_lut_pallas
from repro.kernels.rerank import rerank as _rerank_pallas


def default_backend() -> str:
    env = os.environ.get("REPRO_KERNEL_BACKEND")
    if env:
        return env
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _resolve(backend: str) -> str:
    return default_backend() if backend == "auto" else backend


def build_lut(queries: jax.Array, centroids: jax.Array, *, metric: str = "l2",
              backend: str = "auto") -> jax.Array:
    b = _resolve(backend)
    if b == "ref":
        return _ref.pq_lut_ref(queries, centroids, metric=metric)
    return _pq_lut_pallas(queries, centroids, metric=metric,
                          interpret=(b == "pallas_interpret"))


def adc(lut: jax.Array, codes: jax.Array, *, backend: str = "auto"
        ) -> jax.Array:
    """lut (nq, m, ks) or (m, ks); codes (n, m) -> (nq, n) or (n,)."""
    b = _resolve(backend)
    if b == "ref":
        if lut.ndim == 2:
            return _ref.pq_adc_ref(lut, codes)
        return jax.vmap(lambda l: _ref.pq_adc_ref(l, codes))(lut)
    return _pq_adc_pallas(lut, codes, interpret=(b == "pallas_interpret"))


def fused_hop(chunk_words: jax.Array, frontier_ids: jax.Array, lut: jax.Array,
              queries: jax.Array, *, layout: ChunkLayout, metric: str = "l2",
              backend: str = "auto", adc_dtype: str = "f32"):
    """Batched AiSAQ hop. frontier_ids (nq, w) -> see chunk_adc.fused_hop.

    adc_dtype="int8" runs the §Perf adc-int8 path: per-query symmetric LUT
    quantization, s8xs8->s32 one-hot contraction at 2x MXU rate. The ref
    backend emulates the identical numerics (quantize + dequantize the LUT)
    so recall-parity tests run anywhere.
    """
    assert adc_dtype in ("f32", "int8"), adc_dtype
    b = _resolve(backend)
    if b == "ref":
        if adc_dtype == "int8":
            lut_q8, scale = quantize_lut(lut)
            lut = lut_q8.astype(jnp.float32) * (scale / 127.0)[:, None, None]
        fn = functools.partial(_ref.fused_hop_ref, chunk_words,
                               layout=layout, metric=metric)
        return jax.vmap(fn)(frontier_ids, lut, queries)
    return _fused_hop_pallas(chunk_words, frontier_ids, lut, queries,
                             layout=layout, metric=metric,
                             quantized=(adc_dtype == "int8"),
                             interpret=(b == "pallas_interpret"))


def rerank(queries: jax.Array, cand: jax.Array, *, metric: str = "l2",
           backend: str = "auto") -> jax.Array:
    b = _resolve(backend)
    if b == "ref":
        if queries.ndim == 1:
            return _ref.rerank_ref(queries, cand, metric=metric)
        return jax.vmap(lambda q: _ref.rerank_ref(q, cand, metric=metric)
                        )(queries)
    return _rerank_pallas(queries, cand, metric=metric,
                          interpret=(b == "pallas_interpret"))
