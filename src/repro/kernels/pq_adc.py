"""Pallas TPU kernel: bulk asymmetric distance computation (ADC).

dist[n] = sum_j lut[j, codes[n, j]] for a tile of n codes.

TPU adaptation (DESIGN.md §2): the CPU implementation is a scalar gather per
(n, j); gathers serialize on the VPU, so we reformulate as a one-hot matmul —
for each group of G subquantizers build the (bn, G, ks) one-hot of the codes
and contract with the (G, ks) LUT slab on the MXU. ks=256 keeps lanes full.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _adc_kernel(codes_ref, lut_ref, out_ref, *, group: int):
    codes = codes_ref[...].astype(jnp.int32)          # (bn, m)
    lut = lut_ref[0]                                  # (m, ks)
    m, ks = lut.shape
    bn = codes.shape[0]
    acc = jnp.zeros((bn,), jnp.float32)
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, 1, ks), 2)
    for g0 in range(0, m, group):                     # static unroll over m/G
        cg = codes[:, g0:g0 + group]                  # (bn, G)
        oh = (cg[:, :, None] == iota).astype(jnp.float32)   # (bn, G, ks)
        lg = lut[g0:g0 + group]                       # (G, ks)
        acc = acc + jax.lax.dot_general(
            oh.reshape(bn, group * ks), lg.reshape(group * ks),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
    out_ref[0, :] = acc


def _adc_q8_kernel(codes_ref, lut_ref, scale_ref, out_ref, *, group: int):
    """int8 ADC (§Perf "adc-int8"): one-hot s8 x LUT s8 -> s32 accumulate.

    s8 x s8 -> s32 contractions run at 2x the bf16 MXU rate on TPU; the LUT
    is symmetric-quantized per query against its global max-abs (scale in
    SMEM-like scalar block), dequantized once per output tile."""
    codes = codes_ref[...].astype(jnp.int32)          # (bn, m)
    lut = lut_ref[0]                                  # (m, ks) int8
    m, ks = lut.shape
    bn = codes.shape[0]
    acc = jnp.zeros((bn,), jnp.int32)
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, 1, ks), 2)
    for g0 in range(0, m, group):
        cg = codes[:, g0:g0 + group]
        oh = (cg[:, :, None] == iota).astype(jnp.int8)      # (bn, G, ks)
        lg = lut[g0:g0 + group]
        acc = acc + jax.lax.dot_general(
            oh.reshape(bn, group * ks), lg.reshape(group * ks),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
    out_ref[0, :] = acc.astype(jnp.float32) * scale_ref[0, 0]


@functools.partial(jax.jit,
                   static_argnames=("block_n", "group", "interpret"))
def pq_adc_q8(lut: jax.Array, codes: jax.Array, *, block_n: int = 512,
              group: int = 8, interpret: bool = False) -> jax.Array:
    """int8-quantized ADC. lut (nq, m, ks) f32 -> distances (nq, n) f32.

    Absolute error bound per distance: m * max|lut| / 127 (symmetric
    per-query quantization); re-ranking with full-precision vectors absorbs
    it (validated in tests/test_kernels.py + bench recall parity)."""
    squeeze = lut.ndim == 2
    if squeeze:
        lut = lut[None]
    nq, m, ks = lut.shape
    n = codes.shape[0]
    bn = min(block_n, n)
    group = min(group, m)
    scale = jnp.max(jnp.abs(lut), axis=(1, 2))               # (nq,)
    lut_q = jnp.clip(jnp.round(lut / jnp.maximum(
        scale[:, None, None], 1e-20) * 127.0), -127, 127).astype(jnp.int8)
    out = pl.pallas_call(
        functools.partial(_adc_q8_kernel, group=group),
        grid=(nq, pl.cdiv(n, bn)),
        in_specs=[
            pl.BlockSpec((bn, m), lambda q, i: (i, 0)),
            pl.BlockSpec((1, m, ks), lambda q, i: (q, 0, 0)),
            pl.BlockSpec((1, 1), lambda q, i: (q, 0)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda q, i: (q, i)),
        out_shape=jax.ShapeDtypeStruct((nq, n), jnp.float32),
        interpret=interpret,
    )(codes.astype(jnp.int32), lut_q, (scale / 127.0)[:, None])
    return out[0] if squeeze else out


@functools.partial(jax.jit,
                   static_argnames=("block_n", "group", "interpret"))
def pq_adc(lut: jax.Array, codes: jax.Array, *, block_n: int = 512,
           group: int = 8, interpret: bool = False) -> jax.Array:
    """lut (nq, m, ks) f32, codes (n, m) u8/i32 -> (nq, n) f32."""
    squeeze = lut.ndim == 2
    if squeeze:
        lut = lut[None]
    nq, m, ks = lut.shape
    n = codes.shape[0]
    bn = min(block_n, n)
    group = min(group, m)
    assert m % group == 0
    out = pl.pallas_call(
        functools.partial(_adc_kernel, group=group),
        grid=(nq, pl.cdiv(n, bn)),
        in_specs=[
            pl.BlockSpec((bn, m), lambda q, i: (i, 0)),
            pl.BlockSpec((1, m, ks), lambda q, i: (q, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda q, i: (q, i)),
        out_shape=jax.ShapeDtypeStruct((nq, n), jnp.float32),
        interpret=interpret,
    )(codes.astype(jnp.int32), lut)
    return out[0] if squeeze else out
