"""Pure-jnp oracles for every Pallas kernel in this package.

Device-side chunks are handled as int32 *words* (stride/4 per row): 4-byte
aligned field offsets mean id/float fields are single words and uint8 fields
unpack with shifts — all TPU-lowerable ops (no sub-word memory ops needed).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.chunk_layout import ChunkLayout


# ---------------------------------------------------------------------------
# word-level parsing helpers
# ---------------------------------------------------------------------------


def unpack_u8(words: jax.Array) -> jax.Array:
    """int32 (..., W) -> (..., W*4) values in [0,255] (little-endian)."""
    shifts = jnp.array([0, 8, 16, 24], dtype=jnp.int32)
    b = jnp.right_shift(words[..., None], shifts) & 0xFF
    return b.reshape(words.shape[:-1] + (words.shape[-1] * 4,))


def parse_chunks_words(words: jax.Array, layout: ChunkLayout):
    """words: (w, stride/4) int32 rows gathered from the chunk array.

    Returns (vec_f32 (w, dim), deg (w,), ids (w, R) i32, codes (w, R, m) i32).
    codes is None for diskann-mode layouts.
    """
    w = words.shape[0]
    d, R, m = layout.dim, layout.R, layout.pq_m
    if layout.data_dtype == "uint8":
        nw = (d + 3) // 4
        vec = unpack_u8(words[:, :nw])[:, :d].astype(jnp.float32)
    else:
        vec = jax.lax.bitcast_convert_type(words[:, :d], jnp.float32)
    deg = words[:, layout.dev_off_deg // 4]
    o = layout.dev_off_ids // 4
    ids = words[:, o:o + R]
    codes = None
    if layout.mode == "aisaq":
        o = layout.dev_off_pq // 4
        assert m % 4 == 0, "pq_m must be a multiple of 4 for word layout"
        codes = unpack_u8(words[:, o:o + R * m // 4]).reshape(w, R, m)
    return vec, deg, ids, codes


# ---------------------------------------------------------------------------
# kernel oracles
# ---------------------------------------------------------------------------


def pq_lut_ref(queries: jax.Array, centroids: jax.Array, *, metric: str
               ) -> jax.Array:
    """(q, d), (m, ks, dsub) -> (q, m, ks) f32."""
    q = queries.shape[0]
    m, ks, dsub = centroids.shape
    qs = queries.astype(jnp.float32).reshape(q, m, dsub)
    if metric == "mips":
        return -jnp.einsum("qmd,mkd->qmk", qs, centroids)
    qn = jnp.sum(qs * qs, axis=-1)                        # (q, m)
    cn = jnp.sum(centroids * centroids, axis=-1)          # (m, ks)
    cross = jnp.einsum("qmd,mkd->qmk", qs, centroids)
    return qn[:, :, None] - 2.0 * cross + cn[None, :, :]


def pq_adc_ref(lut: jax.Array, codes: jax.Array) -> jax.Array:
    """lut (m, ks) f32, codes (n, m) int -> (n,) f32 (gather semantics)."""
    m, ks = lut.shape
    idx = codes.astype(jnp.int32) + jnp.arange(m, dtype=jnp.int32) * ks
    return jnp.take(lut.reshape(-1), idx).sum(axis=-1)


def fused_hop_ref(chunk_words: jax.Array, frontier_ids: jax.Array,
                  lut: jax.Array, query: jax.Array, layout: ChunkLayout, *,
                  metric: str):
    """One AiSAQ beam-search hop given gathered chunk rows.

    chunk_words: (N, stride/4) int32 full chunk table (the HBM 'storage').
    frontier_ids: (w,) int32 node ids to expand (may contain -1 padding).
    lut: (m, ks) f32 for this query. query: (d,) f32.

    Returns (exact_d (w,), nbr_ids (w, R) i32, nbr_d (w, R) f32).
    Invalid frontier rows / neighbor slots get +inf distances and id -1.
    """
    w = frontier_ids.shape[0]
    safe = jnp.clip(frontier_ids, 0, chunk_words.shape[0] - 1)
    rows = chunk_words[safe]                              # gather (w, S)
    vec, deg, ids, codes = parse_chunks_words(rows, layout)
    fvalid = frontier_ids >= 0
    if metric == "mips":
        exact = -(vec @ query.astype(jnp.float32))
    else:
        diff = vec - query.astype(jnp.float32)[None, :]
        exact = jnp.einsum("wd,wd->w", diff, diff)
    exact = jnp.where(fvalid, exact, jnp.inf)
    nvalid = (ids >= 0) & fvalid[:, None]
    if layout.mode == "aisaq":
        d = pq_adc_ref(lut, codes.reshape(w * layout.R, layout.pq_m))
        d = d.reshape(w, layout.R)
    else:
        d = None  # diskann device mode resolves codes outside (RAM table)
    if d is not None:
        d = jnp.where(nvalid, d, jnp.inf)
    ids = jnp.where(nvalid, ids, -1)
    return exact, ids, d


def rerank_ref(query: jax.Array, cand: jax.Array, *, metric: str) -> jax.Array:
    """(d,), (c, d) -> (c,) exact distances."""
    cand = cand.astype(jnp.float32)
    q = query.astype(jnp.float32)
    if metric == "mips":
        return -(cand @ q)
    diff = cand - q[None, :]
    return jnp.einsum("cd,cd->c", diff, diff)
