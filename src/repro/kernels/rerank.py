"""Pallas TPU kernel: full-precision re-rank distances (query x candidates).

Plain tiled matmul-with-epilogue; the contraction dim is the vector dim d.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rerank_kernel(q_ref, c_ref, out_ref, *, metric: str):
    q = q_ref[...].astype(jnp.float32)                 # (1, d)
    c = c_ref[...].astype(jnp.float32)                 # (bc, d)
    cross = jax.lax.dot_general(c, q, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)[:, 0]
    if metric == "mips":
        out_ref[0, :] = -cross
    else:
        qn = jnp.sum(q * q)
        cn = jnp.sum(c * c, axis=-1)
        out_ref[0, :] = cn - 2.0 * cross + qn


@functools.partial(jax.jit, static_argnames=("metric", "block_c", "interpret"))
def rerank(queries: jax.Array, cand: jax.Array, *, metric: str = "l2",
           block_c: int = 1024, interpret: bool = False) -> jax.Array:
    """(nq, d) x (c, d) -> (nq, c) f32 exact distances."""
    squeeze = queries.ndim == 1
    if squeeze:
        queries = queries[None]
    nq, d = queries.shape
    c = cand.shape[0]
    bc = min(block_c, c)
    out = pl.pallas_call(
        functools.partial(_rerank_kernel, metric=metric),
        grid=(nq, pl.cdiv(c, bc)),
        in_specs=[
            pl.BlockSpec((1, d), lambda q, i: (q, 0)),
            pl.BlockSpec((bc, d), lambda q, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, bc), lambda q, i: (q, i)),
        out_shape=jax.ShapeDtypeStruct((nq, c), jnp.float32),
        interpret=interpret,
    )(queries.astype(jnp.float32), cand.astype(jnp.float32))
    return out[0] if squeeze else out
