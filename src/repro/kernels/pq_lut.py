"""Pallas TPU kernel: per-query PQ distance LUT construction.

Grid: (m, ceil(Q/bq)). Each program computes the (bq, ks) LUT tile for one
subquantizer from a (bq, dsub) query slab and the (ks, dsub) centroid table —
an MXU matmul with a norm epilogue. ks=256 is two native 128-lanes, and dsub
(d/m) is the contraction dim.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lut_kernel(q_ref, c_ref, out_ref, *, metric: str):
    q = q_ref[:, 0, :].astype(jnp.float32)        # (bq, dsub)
    c = c_ref[0].astype(jnp.float32)              # (ks, dsub)
    cross = jax.lax.dot_general(q, c, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    if metric == "mips":
        out_ref[:, 0, :] = -cross
    else:
        qn = jnp.sum(q * q, axis=-1, keepdims=True)       # (bq, 1)
        cn = jnp.sum(c * c, axis=-1)[None, :]             # (1, ks)
        out_ref[:, 0, :] = qn - 2.0 * cross + cn


@functools.partial(jax.jit,
                   static_argnames=("metric", "block_q", "interpret"))
def pq_lut(queries: jax.Array, centroids: jax.Array, *, metric: str = "l2",
           block_q: int = 128, interpret: bool = False) -> jax.Array:
    """(q, d) x (m, ks, dsub) -> (q, m, ks) f32 LUT."""
    nq, d = queries.shape
    m, ks, dsub = centroids.shape
    assert m * dsub == d
    bq = min(block_q, nq)
    grid = (m, pl.cdiv(nq, bq))
    # view queries as (q, m, dsub) so the j-th program reads its subspace slab
    qs = queries.reshape(nq, m, dsub)
    return pl.pallas_call(
        functools.partial(_lut_kernel, metric=metric),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, 1, dsub), lambda j, i: (i, j, 0)),
            pl.BlockSpec((1, ks, dsub), lambda j, i: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bq, 1, ks), lambda j, i: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((nq, m, ks), jnp.float32),
        interpret=interpret,
    )(qs.reshape(nq, m, dsub), centroids).reshape(nq, m, ks)
