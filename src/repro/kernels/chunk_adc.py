"""Pallas TPU kernel: the fused AiSAQ hop — THE paper's hot loop on TPU.

For each (query q, beam slot i) the kernel:
  1. DMAs node chunk row ``chunks[ids[q, i]]`` HBM->VMEM via scalar-prefetch
     block indexing (the paged-attention-style indirection; this is the TPU
     analogue of the paper's single 4 KiB LBA read per hop),
  2. parses the chunk *in VMEM*: full-precision vector, neighbor ids, and the
     INLINE neighbor PQ codes (AiSAQ's contribution — nothing N-sized is ever
     resident in the fast tier),
  3. emits the exact query<->node distance (re-rank pool) and all R neighbor
     ADC distances via grouped one-hot MXU matmuls.

Chunk rows are int32 words (layout.device_stride/4 per row, fields 4-byte
aligned) so parsing is shifts/bitcasts — no sub-word loads.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.chunk_layout import ChunkLayout


def quantize_lut(lut: jax.Array):
    """Symmetric per-query int8 LUT quantization (§Perf adc-int8).

    lut (nq, m, ks) f32 -> (lut_q8 (nq, m, ks) int8, scale (nq,) f32);
    dequantization is lut_q8 * (scale / 127). The single source of truth
    for the recipe — the Pallas kernel and the ref-backend emulation in
    kernels.ops must stay numerically identical.
    """
    scale = jnp.max(jnp.abs(lut), axis=(1, 2))
    lut_q8 = jnp.clip(jnp.round(lut / jnp.maximum(
        scale[:, None, None], 1e-20) * 127.0), -127, 127).astype(jnp.int8)
    return lut_q8, scale


def _unpack_u8(words: jax.Array) -> jax.Array:
    # no captured consts allowed in pallas kernels: build shifts via iota
    shifts = jax.lax.broadcasted_iota(jnp.int32, (1, 4), 1) * 8
    b = jnp.right_shift(words[..., None], shifts) & 0xFF
    return b.reshape(words.shape[:-1] + (words.shape[-1] * 4,))


def _hop_kernel(ids_ref, chunk_ref, lut_ref, q_ref, exact_ref, ids_out_ref,
                d_out_ref, *, layout: ChunkLayout, metric: str, group: int,
                quantized: bool = False, scale_ref=None):
    qi = pl.program_id(0)
    wi = pl.program_id(1)
    node = ids_ref[qi, wi]
    valid = node >= 0
    words = chunk_ref[0]                                   # (S,) int32
    d, R, m = layout.dim, layout.R, layout.pq_m
    # ---- full-precision vector + exact distance ---------------------------
    if layout.data_dtype == "uint8":
        nw = (d + 3) // 4
        vec = _unpack_u8(words[:nw].reshape(1, nw))[:, :d].astype(jnp.float32)
    else:
        vec = jax.lax.bitcast_convert_type(words[:d], jnp.float32).reshape(1, d)
    q = q_ref[...].astype(jnp.float32)                     # (1, d)
    if metric == "mips":
        exact = -jnp.sum(vec * q)
    else:
        diff = vec - q
        exact = jnp.sum(diff * diff)
    exact_ref[0, 0] = jnp.where(valid, exact, jnp.inf)
    # ---- neighbor ids ------------------------------------------------------
    o = layout.dev_off_ids // 4
    nbr = words[o:o + R].reshape(1, R)
    nvalid = (nbr >= 0) & valid
    ids_out_ref[0, 0, :] = jnp.where(nvalid, nbr, -1)[0]
    # ---- inline-PQ ADC (grouped one-hot MXU matmul) ------------------------
    o = layout.dev_off_pq // 4
    codes = _unpack_u8(words[o:o + R * m // 4].reshape(R, m // 4))  # (R, m)
    lut = lut_ref[0]                                       # (m, ks)
    ks = lut.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, 1, ks), 2)
    if quantized:
        # §Perf adc-int8: s8 one-hot x s8 LUT -> s32 at 2x MXU rate
        acc_i = jnp.zeros((R,), jnp.int32)
        for g0 in range(0, m, group):
            cg = codes[:, g0:g0 + group]
            oh = (cg[:, :, None] == iota).astype(jnp.int8)
            lg = lut[g0:g0 + group]
            acc_i = acc_i + jax.lax.dot_general(
                oh.reshape(R, group * ks), lg.reshape(group * ks),
                (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)
        acc = acc_i.astype(jnp.float32) * scale_ref[0, 0]
    else:
        acc = jnp.zeros((R,), jnp.float32)
        for g0 in range(0, m, group):
            cg = codes[:, g0:g0 + group]
            oh = (cg[:, :, None] == iota).astype(jnp.float32)  # (R, G, ks)
            lg = lut[g0:g0 + group]
            acc = acc + jax.lax.dot_general(
                oh.reshape(R, group * ks), lg.reshape(group * ks),
                (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    d_out_ref[0, 0, :] = jnp.where(nvalid[0], acc, jnp.inf)


@functools.partial(jax.jit, static_argnames=("layout", "metric", "group",
                                             "interpret", "quantized"))
def fused_hop(chunk_words: jax.Array, frontier_ids: jax.Array,
              lut: jax.Array, queries: jax.Array, *, layout: ChunkLayout,
              metric: str = "l2", group: int = 8, interpret: bool = False,
              quantized: bool = False):
    """chunk_words (N, S) i32; frontier_ids (nq, w) i32; lut (nq, m, ks);
    queries (nq, d). Returns (exact (nq,w), ids (nq,w,R), nbr_d (nq,w,R)).

    quantized=True runs the §Perf adc-int8 path: the LUT is symmetric-
    quantized per query and the one-hot contraction runs s8xs8->s32."""
    assert layout.mode == "aisaq", "fused_hop needs inline codes"
    nq, w = frontier_ids.shape
    N, S = chunk_words.shape
    R, m, ks = layout.R, layout.pq_m, lut.shape[-1]
    group = min(group, m)
    in_specs = [
        pl.BlockSpec((1, S), lambda q, i, ids: (jnp.maximum(ids[q, i], 0), 0)),
        pl.BlockSpec((1, m, ks), lambda q, i, ids: (q, 0, 0)),
        pl.BlockSpec((1, layout.dim), lambda q, i, ids: (q, 0)),
    ]
    args = [frontier_ids, chunk_words]
    if quantized:
        lut_in, scale = quantize_lut(lut)
        in_specs.append(pl.BlockSpec((1, 1), lambda q, i, ids: (q, 0)))
        args += [lut_in, queries.astype(jnp.float32),
                 (scale / 127.0)[:, None]]
        kernel = functools.partial(_hop_kernel_q8, layout=layout,
                                   metric=metric, group=group)
    else:
        args += [lut, queries.astype(jnp.float32)]
        kernel = functools.partial(_hop_kernel, layout=layout, metric=metric,
                                   group=group)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nq, w),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, 1), lambda q, i, ids: (q, i)),
            pl.BlockSpec((1, 1, R), lambda q, i, ids: (q, i, 0)),
            pl.BlockSpec((1, 1, R), lambda q, i, ids: (q, i, 0)),
        ],
    )
    exact, ids, nbr_d = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((nq, w), jnp.float32),
            jax.ShapeDtypeStruct((nq, w, R), jnp.int32),
            jax.ShapeDtypeStruct((nq, w, R), jnp.float32),
        ],
        interpret=interpret,
    )(*args)
    return exact, ids, nbr_d


def _hop_kernel_q8(ids_ref, chunk_ref, lut_ref, q_ref, scale_ref, exact_ref,
                   ids_out_ref, d_out_ref, *, layout, metric, group):
    _hop_kernel(ids_ref, chunk_ref, lut_ref, q_ref, exact_ref, ids_out_ref,
                d_out_ref, layout=layout, metric=metric, group=group,
                quantized=True, scale_ref=scale_ref)
