"""Sharded, topology-agnostic checkpointing with async save + resharding.

Format: one .npy per leaf (flattened tree path) + manifest.json. Arrays are
materialized to host per-leaf (on multi-host deployments each process writes
its addressable shards; the manifest records the logical shape so restore
can re-place onto ANY mesh — this is what makes elastic re-scaling work:
save on 256 chips, restore on 64).

Fault-tolerance contract (launch/train.py): save every K steps under
step_NNNNNN/, atomically renamed from a .tmp dir; restore picks the newest
complete step.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Callable, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for kp, leaf in leaves:
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)
        out[name] = leaf
    return out, treedef


def save(path: str, tree: Any, *, step: int,
         extra_meta: Optional[dict] = None) -> str:
    """Synchronous sharded save. Returns the final step dir."""
    final = os.path.join(path, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    leaves, _ = _flatten(tree)
    manifest = {"step": step, "time": time.time(),
                "leaves": {}, **(extra_meta or {})}
    for name, leaf in leaves.items():
        arr = np.asarray(jax.device_get(leaf))
        fn = name.replace("/", "__") + ".npy"
        logical = str(arr.dtype)
        if arr.dtype.kind == "V" or logical == "bfloat16":
            # np.save can't serialize ml_dtypes (bfloat16 etc.): store the
            # raw bits and record the logical dtype in the manifest
            np.save(os.path.join(tmp, fn),
                    arr.view(np.dtype(f"u{arr.dtype.itemsize}")))
            logical = "bfloat16"
        else:
            np.save(os.path.join(tmp, fn), arr)
        manifest["leaves"][name] = {"file": fn, "shape": list(arr.shape),
                                    "dtype": logical}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)           # atomic publish
    return final


class AsyncCheckpointer:
    """Overlaps checkpoint writes with training (one in flight)."""

    def __init__(self):
        self._thread: Optional[threading.Thread] = None
        self.last_path: Optional[str] = None

    def save(self, path: str, tree: Any, *, step: int, **kw):
        self.wait()
        # snapshot to host BEFORE returning control (device buffers may be
        # donated/overwritten by the next step)
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            self.last_path = save(path, host_tree, step=step, **kw)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(path: str) -> Optional[int]:
    if not os.path.isdir(path):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(path)
             if d.startswith("step_") and not d.endswith(".tmp")
             and os.path.exists(os.path.join(path, d, "manifest.json"))]
    return max(steps) if steps else None


def restore(path: str, like: Any, *, step: Optional[int] = None,
            shardings: Any = None) -> Any:
    """Restore into the structure of `like` (tree of arrays or SDS).

    `shardings`: optional tree of NamedSharding for direct sharded
    placement on the (possibly different) current mesh.
    """
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {path}")
    d = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    names, treedef = _flatten(like)
    sh_map = None
    if shardings is not None:
        sh_map, _ = _flatten(shardings)
    out = {}
    for name in names:
        info = manifest["leaves"][name]
        arr = np.load(os.path.join(d, info["file"]))
        if info["dtype"] == "bfloat16":
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        if sh_map is not None:
            out[name] = jax.device_put(arr, sh_map[name])
        else:
            out[name] = jax.numpy.asarray(arr)
    leaves = [out[n] for n in names]
    return jax.tree_util.tree_unflatten(treedef, leaves)
