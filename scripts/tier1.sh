#!/usr/bin/env bash
# Canonical tier-1 verification entry point (ROADMAP "Tier-1 verify").
# CI and builders should run THIS script rather than hand-rolling the
# pytest incantation, so the command stays in one place.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -x -q "$@"
