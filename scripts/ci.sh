#!/usr/bin/env bash
# CI entry point: tier-1 tests + hot-path and serving benchmark smoke runs.
#
# The smoke invocations build tiny corpora from scratch in tempdirs and
# assert the invariants loudly (batched == scalar reference across
# {relabel} x {prefetch} x {adc_dtype} x {rerank} x {pipeline}, int8
# recall parity, pool eviction correctness, admission control, rerank
# recall dominance).  bench_search --quick additionally guards the
# pipelined traversal engine: cold-path mean latency and blocked wait of
# the pipelined path must not regress past the serial path (median-of-3,
# noise-tolerant) — an overlap regression fails CI here.
# They deliberately do NOT touch benchmarks/artifacts/bench_idx — CI has
# no artifact cache and must never pay the 20k-corpus index build; the
# cached artifacts are only for full local bench runs.
set -euo pipefail
cd "$(dirname "$0")/.."

bash scripts/tier1.sh

PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
    python benchmarks/bench_search.py --quick

PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
    python benchmarks/bench_serving.py --quick

# fault drill: seeded EIO + a transiently corrupt block against the full
# serving stack — asserts zero worker deaths, 100% completion-or-clean-
# rejection, quarantine + half-open recovery, and bit-identical answers
PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
    python benchmarks/bench_faults.py --quick

# ingest drill: concurrent insert+search, a zero-downtime compaction swap
# under load, and the kill-at-every-journal-offset crash drill — asserts
# 100% recovery to oracle-identical search results at every crash point
PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
    python benchmarks/bench_ingest.py --quick
