#!/usr/bin/env bash
# CI entry point: tier-1 tests + a hot-path benchmark smoke run.
#
# The smoke invocation rebuilds a tiny corpus from scratch and asserts the
# search hot-path invariants (batched == scalar reference across
# {relabel} x {prefetch} x {adc_dtype}, int8 recall parity), so a hot-path
# regression fails CI loudly even when no unit test covers the exact
# combination that broke.
set -euo pipefail
cd "$(dirname "$0")/.."

bash scripts/tier1.sh

PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
    python benchmarks/bench_search.py --quick
