#!/usr/bin/env bash
# CI entry point: tier-1 tests + hot-path and serving benchmark smoke runs.
#
# The smoke invocations build tiny corpora from scratch in tempdirs and
# assert the invariants loudly (batched == scalar reference across
# {entry} x {relabel} x {prefetch} x {adc_dtype} x {rerank} x
# {pipeline}, int8 recall parity, pool eviction correctness, admission
# control, rerank recall dominance).  bench_search --quick additionally
# guards the pipelined traversal engine: cold-path mean latency and
# blocked wait of the pipelined path must not regress past the serial
# path (median-of-3, noise-tolerant) — an overlap regression fails CI
# here.  It also gates the navigation tier: on a tempdir nav index,
# nav-seeded median hops and hops-to-convergence must not exceed the
# medoid-seeded medians (hop counts are deterministic per index, so the
# bound is exact rather than statistical).
# They deliberately do NOT touch benchmarks/artifacts/bench_idx — CI has
# no artifact cache and must never pay the 20k-corpus index build; the
# cached artifacts are only for full local bench runs.
#
# Every bench smoke runs under a HARD wall-clock timeout: a hung drill
# (a wedged worker process, a lost socket frame) must fail fast and
# loudly, not eat the job-level budget.  The workflow mirrors this with
# per-step timeout-minutes.
set -euo pipefail
cd "$(dirname "$0")/.."

QUICK_TIMEOUT="${QUICK_TIMEOUT:-600}"   # seconds per bench smoke

run_quick() {
    PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
        timeout --signal=TERM --kill-after=30 "$QUICK_TIMEOUT" \
        python "$1" --quick
}

bash scripts/tier1.sh

run_quick benchmarks/bench_search.py

run_quick benchmarks/bench_serving.py

# fault drill: seeded EIO + a transiently corrupt block against the full
# serving stack — asserts zero worker deaths, 100% completion-or-clean-
# rejection, quarantine + half-open recovery, and bit-identical answers
run_quick benchmarks/bench_faults.py

# ingest drill: concurrent insert+search, a zero-downtime compaction swap
# under load, and the kill-at-every-journal-offset crash drill — asserts
# 100% recovery to oracle-identical search results at every crash point
run_quick benchmarks/bench_ingest.py

# cluster drill: SIGKILL a shard worker process mid-traffic — asserts
# zero hung requests, exact outcome accounting, completed answers
# bit-identical to single-process references over the answering shards,
# and supervisor respawn restoring full coverage
run_quick benchmarks/bench_cluster.py

# tracing smoke: one traced query through router -> socket -> worker ->
# traversal -> block cache must export a valid Chrome trace-event JSON
# with the full connected span chain (TRACE_query.json, uploaded as a
# workflow artifact), and the merged cluster registry must carry
# per-corpus latency percentiles
run_quick benchmarks/trace_smoke.py

# benchmark regression summary vs the committed BENCH_*.json artifacts —
# informational only (never fails the build), shows which headline
# metrics moved and by how much
PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
    python benchmarks/report.py || true
