"""Direct BlockCache unit tests: eviction order, invalidate, clear,
hit_rate edge cases, readahead (gap) coalescing, and the async prefetcher
(previously only covered indirectly through test_search_hotpath)."""
import os

import numpy as np
import pytest

from repro.core.block_cache import BlockCache

IO = 4096


@pytest.fixture()
def blockfile(tmp_path):
    """A file of 64 distinct 4 KiB blocks + an open fd."""
    data = np.arange(64, dtype=np.uint8).repeat(IO)
    p = tmp_path / "blocks.bin"
    p.write_bytes(data.tobytes())
    fd = os.open(p, os.O_RDONLY)
    yield fd
    os.close(fd)


def offs(*blocks):
    return np.asarray(blocks, dtype=np.int64) * IO


# ---------------------------------------------------------------------------
# hit_rate / counters
# ---------------------------------------------------------------------------


def test_hit_rate_no_fetches_is_zero_not_nan(blockfile):
    cache = BlockCache(blockfile, IO, capacity_bytes=4 * IO)
    assert cache.hit_rate() == 0.0           # no division error on empty


def test_hit_rate_counts_only_demand_path(blockfile):
    cache = BlockCache(blockfile, IO, capacity_bytes=8 * IO)
    cache.prefetch_async(offs(0, 1))
    cache.wait_prefetch()
    assert cache.hit_rate() == 0.0           # prefetch is not a demand hit
    _, hm, n_sys = cache.fetch(offs(0, 1))
    assert hm.all() and n_sys == 0
    assert cache.hit_rate() == 1.0
    cache.stop()


# ---------------------------------------------------------------------------
# eviction order
# ---------------------------------------------------------------------------


def resident(cache):
    with cache._cond:
        return sorted(k // IO for k in cache._blocks)


def test_eviction_is_lru_order(blockfile):
    cache = BlockCache(blockfile, IO, capacity_bytes=3 * IO)
    cache.fetch(offs(0))
    cache.fetch(offs(1))
    cache.fetch(offs(2))
    cache.fetch(offs(0))          # refresh 0: LRU order now 1, 2, 0
    cache.fetch(offs(3))          # evicts 1 (least recently used)
    assert cache.counters.evictions == 1
    assert resident(cache) == [0, 2, 3]
    cache.fetch(offs(1))          # evicts 2 (next LRU)
    assert resident(cache) == [0, 1, 3]


def test_eviction_budget_respected_under_oversized_fetch(blockfile):
    cache = BlockCache(blockfile, IO, capacity_bytes=2 * IO)
    out, _, _ = cache.fetch(offs(*range(10)))
    assert (out[:, 0] == np.arange(10)).all()   # data correct regardless
    assert cache.used_bytes <= 2 * IO


# ---------------------------------------------------------------------------
# invalidate
# ---------------------------------------------------------------------------


def test_invalidate_exact_block(blockfile):
    cache = BlockCache(blockfile, IO, capacity_bytes=8 * IO)
    cache.fetch(offs(0, 1, 2))
    cache.invalidate(IO, IO)                  # exactly block 1
    _, hm, _ = cache.fetch(offs(0, 1, 2))
    assert hm.tolist() == [True, False, True]


def test_invalidate_range_straddling_block_boundary(blockfile):
    cache = BlockCache(blockfile, IO, capacity_bytes=8 * IO)
    cache.fetch(offs(0, 1, 2, 3))
    # [IO - 10, IO + 90) touches blocks 0 AND 1
    cache.invalidate(IO - 10, 100)
    _, hm, _ = cache.fetch(offs(0, 1, 2, 3))
    assert hm.tolist() == [False, False, True, True]


def test_invalidate_multiblock_range_drops_partial_last_block(blockfile):
    cache = BlockCache(blockfile, IO, capacity_bytes=8 * IO)
    cache.fetch(offs(0, 1, 2, 3))
    # [IO + 1, 3*IO + 1) touches blocks 1, 2 and (one byte of) 3
    cache.invalidate(IO + 1, 2 * IO)
    _, hm, _ = cache.fetch(offs(0, 1, 2, 3))
    assert hm.tolist() == [True, False, False, False]


def test_invalidate_zero_or_negative_bytes_is_noop(blockfile):
    cache = BlockCache(blockfile, IO, capacity_bytes=8 * IO)
    cache.fetch(offs(0, 1))
    cache.invalidate(0, 0)
    cache.invalidate(IO, -5)
    _, hm, _ = cache.fetch(offs(0, 1))
    assert hm.all()


# ---------------------------------------------------------------------------
# clear
# ---------------------------------------------------------------------------


def test_clear_empties_cache_but_keeps_counters(blockfile):
    cache = BlockCache(blockfile, IO, capacity_bytes=8 * IO)
    cache.fetch(offs(0, 1, 2))
    before = cache.counters.misses
    cache.clear()
    assert cache.used_bytes == 0
    assert cache.counters.misses == before    # history survives clear
    _, hm, _ = cache.fetch(offs(0))
    assert not hm.any()                       # truly gone


def test_counters_reset(blockfile):
    cache = BlockCache(blockfile, IO, capacity_bytes=8 * IO)
    cache.fetch(offs(0, 1))
    cache.counters.reset()
    assert cache.counters.snapshot() == tuple(
        0 for _ in cache.counters.snapshot())


# ---------------------------------------------------------------------------
# readahead (gap) coalescing
# ---------------------------------------------------------------------------


def test_gap_zero_keeps_exact_runs(blockfile):
    cache = BlockCache(blockfile, IO, capacity_bytes=32 * IO)
    _, _, n_sys = cache.fetch(offs(0, 1, 5, 6, 7))
    assert n_sys == 2                          # [0,1] and [5,6,7]


def test_gap_coalesces_runs_and_lands_holes_as_prefetched(blockfile):
    cache = BlockCache(blockfile, IO, capacity_bytes=32 * IO)
    out, _, n_sys = cache.fetch(offs(0, 1, 5, 6, 7), gap=3)
    assert n_sys == 1                          # one preadv spans the hole
    assert (out[:, 0] == np.array([0, 1, 5, 6, 7])).all()
    c = cache.counters
    assert c.prefetch_issued == 3              # holes 2, 3, 4 landed
    assert c.bytes_read == 8 * IO              # honest: holes are counted
    _, hm, n_sys2 = cache.fetch(offs(2, 3, 4))
    assert hm.all() and n_sys2 == 0            # readahead served them
    assert c.prefetch_hits == 3


def test_gap_holes_skipped_under_zero_retention(blockfile):
    cache = BlockCache(blockfile, IO, capacity_bytes=0)
    out, _, n_sys = cache.fetch(offs(0, 2), gap=1)
    assert n_sys == 1 and (out[:, 0] == np.array([0, 2])).all()
    c = cache.counters
    # an unretainable hole is not speculation: no issued count, and the
    # bookkeeping sets stay empty (no unbounded growth in serving loops)
    assert c.prefetch_issued == 0
    with cache._cond:
        assert not cache._prefetched and not cache._inflight


def test_gap_hole_cancels_inflight_prefetch_of_same_block(blockfile):
    cache = BlockCache(blockfile, IO, capacity_bytes=16 * IO)
    with cache._cond:            # simulate a queued-but-unread prefetch
        cache._inflight.add(1 * IO)
    cache.fetch(offs(0, 2), gap=1)             # hole 1 lands via readahead
    with cache._cond:            # the demand read covered it: cancelled
        assert 1 * IO not in cache._inflight
        assert 1 * IO in cache._blocks


def test_gap_hole_eviction_counts_as_wasted(blockfile):
    cache = BlockCache(blockfile, IO, capacity_bytes=3 * IO)
    cache.fetch(offs(0, 2), gap=1)             # hole 1 lands speculatively
    cache.fetch(offs(8))
    cache.fetch(offs(9))
    cache.fetch(offs(10))                      # budget 3: hole 1 evicted
    assert cache.counters.prefetch_wasted >= 1


# ---------------------------------------------------------------------------
# async prefetcher
# ---------------------------------------------------------------------------


def test_prefetch_lands_blocks_and_demand_hits(blockfile):
    cache = BlockCache(blockfile, IO, capacity_bytes=8 * IO)
    queued = cache.prefetch_async(offs(3, 4, 5))
    assert queued == 3
    cache.wait_prefetch()
    c = cache.counters
    assert c.prefetch_issued == 3 and c.prefetch_syscalls == 1
    assert c.syscalls == 0                     # demand path untouched
    out, hm, n_sys = cache.fetch(offs(3, 4, 5))
    assert hm.all() and n_sys == 0
    assert (out[:, 0] == np.array([3, 4, 5])).all()
    assert c.prefetch_hits == 3
    cache.stop()


def test_prefetch_skips_resident_and_duplicate_offsets(blockfile):
    cache = BlockCache(blockfile, IO, capacity_bytes=8 * IO)
    cache.fetch(offs(0))
    assert cache.prefetch_async(offs(0)) == 0          # already resident
    assert cache.prefetch_async(offs(1, 1, 1)) == 1    # deduped
    cache.wait_prefetch()
    assert cache.counters.prefetch_issued == 1
    cache.stop()


def test_prefetch_zero_budget_noop(blockfile):
    cache = BlockCache(blockfile, IO, capacity_bytes=0)
    assert cache.prefetch_async(offs(0, 1)) == 0
    assert cache.counters.prefetch_issued == 0


def test_demand_fetch_waits_for_inflight_instead_of_rereading(blockfile):
    cache = BlockCache(blockfile, IO, capacity_bytes=16 * IO)
    cache.prefetch_async(offs(*range(10)))
    out, hm, n_sys = cache.fetch(offs(*range(10)))     # may race the worker
    cache.wait_prefetch()
    assert (out[:, 0] == np.arange(10)).all()
    c = cache.counters
    # every block was read from storage, and at most twice: once is the
    # design (demand waits on in-flight prefetches); twice only via the
    # _PENDING_WAIT_S timeout fallback, which a descheduled worker on a
    # loaded CI box can legitimately trigger
    assert 10 * IO <= c.prefetch_bytes + c.bytes_read <= 20 * IO
    assert hm.sum() == 10 - c.misses
    cache.stop()


def test_prefetch_unused_blocks_counted_wasted_on_clear(blockfile):
    cache = BlockCache(blockfile, IO, capacity_bytes=8 * IO)
    cache.prefetch_async(offs(6, 7))
    cache.wait_prefetch()
    cache.clear()
    assert cache.counters.prefetch_wasted == 2
    cache.stop()


def test_invalidate_cancels_inflight_prefetch(blockfile):
    cache = BlockCache(blockfile, IO, capacity_bytes=8 * IO)
    cache.prefetch_async(offs(5))
    cache.invalidate(5 * IO, 1)               # may cancel before the read
    cache.wait_prefetch()
    # either it was cancelled mid-flight (never landed) or it landed and
    # was dropped+counted; in NO case may stale block 5 sit resident
    with cache._cond:
        assert 5 * IO not in cache._blocks
    cache.stop()


# ---------------------------------------------------------------------------
# demand-miss histograms + gap="auto" (readahead autotuning)
# ---------------------------------------------------------------------------


def test_miss_histograms_record_runs_and_holes(blockfile):
    cache = BlockCache(blockfile, IO, capacity_bytes=0)
    # runs [0,1] [4] [7,8,9] -> lengths {2:1, 1:1, 3:1}, holes {2:2}
    cache.fetch(offs(0, 1, 4, 7, 8, 9))
    assert cache.miss_run_hist == {2: 1, 1: 1, 3: 1}
    assert cache.miss_gap_hist == {2: 2}


def test_auto_gap_zero_without_enough_observations(blockfile):
    cache = BlockCache(blockfile, IO, capacity_bytes=0)
    cache.fetch(offs(0, 2, 4))                 # only 2 holes observed
    assert cache.auto_gap() == 0
    _, _, n_sys = cache.fetch(offs(0, 2, 4), gap="auto")
    assert cache.counters.auto_gap == 0
    assert n_sys == 3                          # no blind coalescing


def test_auto_gap_picks_median_hole_and_coalesces(blockfile):
    cache = BlockCache(blockfile, IO, capacity_bytes=0)
    pattern = offs(*[b for b in range(0, 30) if b % 3 != 2])  # 1-holes
    for _ in range(2):                         # >= 8 holes observed
        cache.fetch(pattern)
    assert cache.auto_gap() == 1
    _, _, n_plain = cache.fetch(pattern, gap=0)
    _, _, n_auto = cache.fetch(pattern, gap="auto")
    assert cache.counters.auto_gap == 1
    assert n_auto < n_plain


def test_auto_gap_refuses_scattered_misses(blockfile):
    cache = BlockCache(blockfile, IO, capacity_bytes=0)
    # holes of 11 blocks dominate: far beyond the clamp, auto must pick 0
    for _ in range(4):
        cache.fetch(offs(0, 12, 24, 36))
    assert cache.auto_gap() == 0


# ---------------------------------------------------------------------------
# background-read fault robustness (the pipeline degradation contract)
# ---------------------------------------------------------------------------


def test_failing_background_read_unclaims_inflight(blockfile, monkeypatch):
    cache = BlockCache(blockfile, IO, capacity_bytes=16 * IO)

    def broken(self, batch, gap=0):
        raise OSError("injected background failure")

    monkeypatch.setattr(BlockCache, "_pf_read", broken)
    assert cache.prefetch_async(offs(0, 1, 2)) == 3
    cache.wait_prefetch()
    assert cache.counters.prefetch_errors == 1
    with cache._cond:
        assert not cache._inflight             # un-claimed, not leaked
    # demand path still serves the blocks (direct read, no 0.5 s stall)
    out, hm, n_sys = cache.fetch(offs(0, 1, 2))
    assert (out[:, 0] == np.array([0, 1, 2])).all()
    assert n_sys >= 1
    cache.stop()


def test_worker_survives_background_failure(blockfile, monkeypatch):
    """The prefetch worker must keep serving batches queued AFTER one
    failed (a dead thread would strand every later in-flight claim)."""
    cache = BlockCache(blockfile, IO, capacity_bytes=16 * IO)
    orig = BlockCache._pf_read
    calls = {"n": 0}

    def flaky(self, batch, gap=0):
        calls["n"] += 1
        if calls["n"] == 1:
            raise OSError("injected first-batch failure")
        return orig(self, batch, gap)

    monkeypatch.setattr(BlockCache, "_pf_read", flaky)
    cache.prefetch_async(offs(0, 1))
    cache.wait_prefetch()
    cache.prefetch_async(offs(4, 5))
    cache.wait_prefetch()
    assert cache.counters.prefetch_errors == 1
    _, hm, _ = cache.fetch(offs(4, 5))
    assert hm.all()                            # second batch landed
    cache.stop()


def test_invalidate_blocks_stale_gap_hole_from_background(tmp_path):
    """Regression: a gap-coalesced HOLE buffer read by the background
    thread BEFORE an in-place write must never land in the cache after
    invalidate() — holes carry no _inflight claim, so the invalidation
    epoch must gate them."""
    import threading
    p = tmp_path / "f.bin"
    p.write_bytes(b"A" * (4 * IO))
    fd = os.open(p, os.O_RDWR)
    try:
        cache = BlockCache(fd, IO, capacity_bytes=8 * IO)
        read_done = threading.Event()
        release = threading.Event()
        orig = BlockCache._iter_read_runs

        def gated(self, offs, gap):
            for run in orig(self, offs, gap):
                read_done.set()         # buffers hold PRE-write bytes now
                release.wait(5.0)       # writer invalidates in this window
                yield run

        BlockCache._iter_read_runs = gated
        try:
            cache.prefetch_async(offs(0, 2), gap=1)  # hole: block 1
            assert read_done.wait(5.0)
            os.pwrite(fd, b"B" * IO, IO)             # rewrite block 1
            cache.invalidate(IO, IO)
            release.set()
            cache.wait_prefetch()
        finally:
            BlockCache._iter_read_runs = orig
        out, hm, _ = cache.fetch(offs(1))
        assert out[0, 0] == ord("B"), "stale pre-write hole served"
    finally:
        os.close(fd)
