"""Dynamic insertion / deletion / filtered search (beyond-paper: the
capabilities the paper's conclusion says AiSAQ enables)."""
import numpy as np
import pytest

from repro.configs.base import IndexConfig
from repro.core import pq
from repro.core.build import build_index
from repro.core.dynamic import DynamicHostIndex
from repro.core.index_io import recall_at
from repro.data.vectors import make_clustered, make_queries


@pytest.fixture()
def dyn_index(tmp_path):
    base = make_clustered(900, 48, seed=7)
    cfg = IndexConfig(name="dyn", n_vectors=700, dim=48, R=16, pq_m=12,
                      build_L=32)
    p = str(tmp_path / "dyn")
    build_index(p, base[:700], cfg, mode="aisaq", seed=0)
    return p, base


def test_insert_makes_new_vectors_findable(dyn_index):
    p, base = dyn_index
    idx = DynamicHostIndex.load(p)
    new_ids = [idx.insert(base[700 + i]) for i in range(60)]
    assert new_ids == list(range(700, 760))
    # query exactly at inserted points: each must find itself at rank 1
    hits = 0
    for i in range(0, 60, 5):
        ids, _ = idx.search(base[700 + i].astype(np.float32), 1, L=48)
        hits += int(ids[0] == 700 + i)
    assert hits >= 10  # ≥ 10/12 self-recall
    # and recall over the GROWN corpus stays high
    q = make_queries(10, base[:760], seed=9)
    gt = np.asarray(pq.groundtruth(q, base[:760], 5))
    got = np.stack([idx.search(q[i], 5, L=48)[0] for i in range(10)])
    assert recall_at(got, gt, 5) >= 0.7
    idx.flush()
    idx.close()


def test_insert_survives_reload(dyn_index):
    p, base = dyn_index
    idx = DynamicHostIndex.load(p)
    nid = idx.insert(base[700])
    idx.flush()
    idx.close()
    idx2 = DynamicHostIndex.load(p)
    assert idx2.meta["n"] == 701
    ids, _ = idx2.search(base[700].astype(np.float32), 1, L=48)
    assert int(ids[0]) == nid
    idx2.close()


def test_delete_tombstones(dyn_index):
    p, base = dyn_index
    idx = DynamicHostIndex.load(p)
    q = base[5].astype(np.float32)
    ids, _ = idx.search(q, 3, L=48)
    victim = int(ids[0])
    idx.delete(victim)
    ids2, _ = idx.search(q, 3, L=48)
    assert victim not in set(int(i) for i in ids2)
    assert len(ids2) == 3              # widened search refills the pool
    idx.flush()
    idx.close()
    idx3 = DynamicHostIndex.load(p)    # tombstones persist
    ids4, _ = idx3.search(q, 3, L=48)
    assert victim not in set(int(i) for i in ids4)
    idx3.close()


def test_filtered_search(dyn_index):
    p, base = dyn_index
    idx = DynamicHostIndex.load(p)
    q = base[10].astype(np.float32)
    even = lambda i: i % 2 == 0
    ids, _ = idx.search(q, 5, L=48, predicate=even)
    assert all(int(i) % 2 == 0 for i in ids)
    assert len(ids) == 5
    idx.close()
