"""Dynamic insertion / deletion / filtered search (beyond-paper: the
capabilities the paper's conclusion says AiSAQ enables), plus the
crash-safety layer: journaled inserts, recovery, crash-safe flush,
compaction, and search-under-mutation."""
import os
import shutil
import threading

import numpy as np
import pytest

from repro.configs.base import IndexConfig
from repro.core import pq
from repro.core.build import build_index
from repro.core.dynamic import DynamicHostIndex, DynamicIndexError
from repro.core.faults import CrashPoint, KillSwitch
from repro.core.index_io import CorruptIndexError, HostIndex, recall_at
from repro.data.vectors import make_clustered, make_queries


@pytest.fixture()
def dyn_index(tmp_path):
    base = make_clustered(900, 48, seed=7)
    cfg = IndexConfig(name="dyn", n_vectors=700, dim=48, R=16, pq_m=12,
                      build_L=32)
    p = str(tmp_path / "dyn")
    build_index(p, base[:700], cfg, mode="aisaq", seed=0)
    return p, base


def test_insert_makes_new_vectors_findable(dyn_index):
    p, base = dyn_index
    idx = DynamicHostIndex.load(p)
    new_ids = [idx.insert(base[700 + i]) for i in range(60)]
    assert new_ids == list(range(700, 760))
    # query exactly at inserted points: each must find itself at rank 1
    hits = 0
    for i in range(0, 60, 5):
        ids, _ = idx.search(base[700 + i].astype(np.float32), 1, L=48)
        hits += int(ids[0] == 700 + i)
    assert hits >= 10  # ≥ 10/12 self-recall
    # and recall over the GROWN corpus stays high
    q = make_queries(10, base[:760], seed=9)
    gt = np.asarray(pq.groundtruth(q, base[:760], 5))
    got = np.stack([idx.search(q[i], 5, L=48)[0] for i in range(10)])
    assert recall_at(got, gt, 5) >= 0.7
    idx.flush()
    idx.close()


def test_insert_survives_reload(dyn_index):
    p, base = dyn_index
    idx = DynamicHostIndex.load(p)
    nid = idx.insert(base[700])
    idx.flush()
    idx.close()
    idx2 = DynamicHostIndex.load(p)
    assert idx2.meta["n"] == 701
    ids, _ = idx2.search(base[700].astype(np.float32), 1, L=48)
    assert int(ids[0]) == nid
    idx2.close()


def test_delete_tombstones(dyn_index):
    p, base = dyn_index
    idx = DynamicHostIndex.load(p)
    q = base[5].astype(np.float32)
    ids, _ = idx.search(q, 3, L=48)
    victim = int(ids[0])
    idx.delete(victim)
    ids2, _ = idx.search(q, 3, L=48)
    assert victim not in set(int(i) for i in ids2)
    assert len(ids2) == 3              # widened search refills the pool
    idx.flush()
    idx.close()
    idx3 = DynamicHostIndex.load(p)    # tombstones persist
    ids4, _ = idx3.search(q, 3, L=48)
    assert victim not in set(int(i) for i in ids4)
    idx3.close()


def test_filtered_search(dyn_index):
    p, base = dyn_index
    idx = DynamicHostIndex.load(p)
    q = base[10].astype(np.float32)
    even = lambda i: i % 2 == 0
    ids, _ = idx.search(q, 5, L=48, predicate=even)
    assert all(int(i) % 2 == 0 for i in ids)
    assert len(ids) == 5
    idx.close()


# -- crash-safety layer ------------------------------------------------------
# a small pristine build, copied per test (crash drills mutate the dir)

@pytest.fixture(scope="module")
def small_built(tmp_path_factory):
    base = make_clustered(260, 16, seed=3)
    cfg = IndexConfig(name="small", n_vectors=200, dim=16, R=8, pq_m=8,
                      build_L=24)
    p = str(tmp_path_factory.mktemp("small") / "idx")
    build_index(p, base[:200], cfg, mode="aisaq", seed=0)
    return p, base


def _copy(small_built, tmp_path):
    src, base = small_built
    dst = str(tmp_path / "work")
    shutil.copytree(src, dst)
    return dst, base


def test_load_rejects_non_aisaq_mode(tmp_path):
    base = make_clustered(120, 16, seed=5)
    cfg = IndexConfig(name="dk", n_vectors=120, dim=16, R=8, pq_m=8,
                      build_L=24)
    p = str(tmp_path / "dk")
    build_index(p, base, cfg, mode="diskann", seed=0)
    with pytest.raises(DynamicIndexError, match="aisaq"):
        DynamicHostIndex.load(p)


def test_static_load_refuses_pending_journal(small_built, tmp_path):
    p, base = _copy(small_built, tmp_path)
    with open(os.path.join(p, "wal.log"), "wb") as f:
        f.write(b"\x01" * 7)             # garbage = torn unrecovered tail
    with pytest.raises(CorruptIndexError, match="journal"):
        HostIndex.load(p)
    # the dynamic loader recovers (truncates the torn tail) and from then
    # on the dir loads statically again
    idx = DynamicHostIndex.load(p)
    assert idx.recovery["journaled"] == 0 and idx.recovery["torn"]
    idx.close()
    HostIndex.load(p).close()


def test_insert_is_journaled_and_commit_clears_nothing(small_built,
                                                       tmp_path):
    p, base = _copy(small_built, tmp_path)
    idx = DynamicHostIndex.load(p)
    idx.insert(base[200])
    # journal holds BEGIN+COMMIT until the flush checkpoint truncates it
    assert idx.wal.size > 0
    idx.flush()
    assert idx.wal.size == 0
    idx.close()


def test_recovery_after_kill_at_every_point(small_built, tmp_path):
    """Mini crash drill: kill the writer at EVERY injection point of one
    insert; every crash must recover to a consistent index equal to the
    pre- or post-insert oracle (the benchmark scales this to a multi-op
    workload)."""
    src, base = small_built
    vec = base[205]
    # enumeration pass: count the ticks of one full insert
    p0, _ = _copy(small_built, tmp_path / "enum")
    ks = KillSwitch()
    idx = DynamicHostIndex.load(p0, kill=ks)
    idx.insert(vec)
    idx.flush()
    idx.close()
    total = ks.count
    assert total > 10                    # wal + chunks + sync + flush ticks
    for at in range(1, total + 1):
        d = str(tmp_path / f"k{at}")
        shutil.copytree(src, d)
        k = KillSwitch(at=at)
        h = DynamicHostIndex.load(d, kill=k)
        committed = False
        try:
            h.insert(vec)
            committed = True
            h.flush()
            committed = True
        except CrashPoint:
            pass
        h.abandon()                       # nothing in RAM survives
        r = DynamicHostIndex.load(d)      # recovery runs here
        n = r.meta["n"]
        assert n in (200, 201), f"at={at}: n={n}"
        if committed:
            assert n == 201, f"at={at}: committed insert lost"
        # graph consistency: every edge of every node is in-range
        for node in range(n):
            _, nbrs, _ = r._read_node(node)
            live = nbrs[nbrs >= 0]
            assert (live < n).all(), f"at={at}: dangling edge"
        # the index is searchable and CRC-clean
        ids, _ = r.search(vec.astype(np.float32), 3, L=24)
        assert len(ids) == 3
        if n == 201:                      # rolled forward: findable
            ids1, _ = r.search(vec.astype(np.float32), 1, L=24)
            assert int(ids1[0]) == 200, f"at={at}"
        assert r.cache.counters.crc_mismatches == 0
        assert r.wal.size == 0            # checkpointed
        r.close()
        shutil.rmtree(d)


def test_journaled_delete_survives_crash(small_built, tmp_path):
    p, base = _copy(small_built, tmp_path)
    idx = DynamicHostIndex.load(p)
    idx.delete(7)
    idx.abandon()                         # crash before any flush
    r = DynamicHostIndex.load(p)
    assert 7 in r.tombstones
    ids, _ = r.search(base[7].astype(np.float32), 3, L=24)
    assert 7 not in set(int(i) for i in ids)
    r.close()


def test_flush_is_crash_atomic(small_built, tmp_path):
    """Killing flush between any two stages must leave a recoverable dir:
    the journal re-derives whatever the flush had not yet persisted."""
    src, base = small_built
    for stage in range(1, 7):             # flush has 6 tick points
        d = str(tmp_path / f"f{stage}")
        shutil.copytree(src, d)
        h = DynamicHostIndex.load(d)
        h.insert(base[210])
        h.delete(3)
        h.kill = KillSwitch(at=stage)     # arm AFTER the insert
        with pytest.raises(CrashPoint):
            h.flush()
        h.abandon()
        r = DynamicHostIndex.load(d)
        assert r.meta["n"] == 201
        assert 3 in r.tombstones
        ids, _ = r.search(base[210].astype(np.float32), 1, L=24)
        assert int(ids[0]) == 200
        assert r.wal.size == 0
        r.close()
        shutil.rmtree(d)


def test_compaction_reclaims_and_preserves_labels(small_built, tmp_path):
    p, base = _copy(small_built, tmp_path)
    idx = DynamicHostIndex.load(p)
    new_labels = [idx.insert(base[200 + i]) for i in range(8)]
    assert new_labels == list(range(200, 208))
    idx.delete(5)
    idx.delete(new_labels[0])             # delete one old, one new
    dst = str(tmp_path / "v2")
    meta = idx.compact(dst, relabel=True)
    idx.close()
    assert meta["n"] == 200 + 8 - 2
    c = DynamicHostIndex.load(dst)        # compacted dirs stay dynamic
    assert c.meta["n"] == 206
    # tombstoned labels are GONE (not just filtered)
    assert 5 not in set(int(l) for l in c.new_to_old)
    # surviving inserted labels still findable under their OLD labels
    for i in (1, 3, 7):
        ids, _ = c.search(base[200 + i].astype(np.float32), 1, L=24)
        assert int(ids[0]) == 200 + i
    # and ingest continues on the compacted dir: labels keep counting up
    nxt = c.insert(base[220])
    assert nxt == 208                     # next_label survived compaction
    ids, _ = c.search(base[220].astype(np.float32), 1, L=24)
    assert int(ids[0]) == nxt
    c.flush()
    c.close()


def test_concurrent_search_during_insert(small_built, tmp_path):
    """Readers race the writer: no torn chunk, no out-of-range result,
    no CRC mismatch — the RW lock + n-snapshot clamp contract."""
    p, base = _copy(small_built, tmp_path)
    idx = DynamicHostIndex.load(p)
    stop = threading.Event()
    errors = []
    q = make_queries(4, base[:200], seed=1).astype(np.float32)

    def reader():
        rng = np.random.default_rng(0)
        while not stop.is_set():
            try:
                n_snap = int(idx.meta["n"])
                ids, _ = idx.search(q[rng.integers(0, 4)], 5, L=24)
                for i in ids:
                    # labels == ids on this dir; results must never point
                    # past the n the search could have seen
                    assert 0 <= int(i) < idx.n + 1, int(i)
                assert len(ids) == 5
                assert n_snap <= int(idx.meta["n"])
            except Exception as e:        # pragma: no cover - failure path
                errors.append(e)
                return

    threads = [threading.Thread(target=reader) for _ in range(3)]
    for t in threads:
        t.start()
    try:
        for i in range(40):
            idx.insert(base[200 + (i % 50)])
    finally:
        stop.set()
        for t in threads:
            t.join(timeout=10)
    assert not errors, errors[0]
    assert idx.cache.counters.crc_mismatches == 0
    assert idx.meta["n"] == 240
    idx.flush()
    idx.close()
    # post-race reload is CRC-clean and consistent
    r = DynamicHostIndex.load(p)
    assert r.recovery["journaled"] == 0
    ids, _ = r.search(base[201].astype(np.float32), 1, L=24)
    assert len(ids) == 1
    r.close()
