import os
import sys

# tests must see exactly ONE device (dry-run owns the 512-device env)
os.environ.pop("XLA_FLAGS", None)

import jax
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture(scope="session")
def small_corpus():
    """Clustered vectors + queries + groundtruth shared across tests."""
    from repro.core import pq
    from repro.data.vectors import make_clustered, make_queries
    base = make_clustered(1500, 48, seed=0)
    q = make_queries(12, base, seed=1)
    gt = pq.groundtruth(q, base, 10)
    return base, q, np.asarray(gt)


@pytest.fixture(scope="session")
def built_graph(small_corpus):
    from repro.core.vamana import build_vamana
    base, _, _ = small_corpus
    return build_vamana(base, R=20, L=40, seed=0)


@pytest.fixture(scope="session")
def pq_artifacts(small_corpus):
    from repro.core import pq
    base, _, _ = small_corpus
    cb = pq.train_codebooks(jax.random.PRNGKey(0), base, m=12, iters=8)
    codes = np.asarray(pq.encode(cb, base))
    return np.asarray(cb.centroids), codes


@pytest.fixture(scope="session")
def index_dirs(tmp_path_factory, small_corpus, built_graph, pq_artifacts):
    """One AiSAQ-mode and one DiskANN-mode index over the same build."""
    from repro.core.index_io import write_index
    base, _, _ = small_corpus
    cents, codes = pq_artifacts
    root = tmp_path_factory.mktemp("indices")
    paths = {}
    for mode in ("aisaq", "diskann"):
        p = str(root / mode)
        write_index(p, vectors=base, graph=built_graph, centroids=cents,
                    codes=codes, metric="l2", mode=mode)
        paths[mode] = p
    return paths
