import numpy as np

from repro.configs import get_arch
from repro.data.pipeline import (ClickStream, Prefetcher, SasrecStream,
                                 TokenStream, host_slice, make_graph)


def test_token_stream_deterministic_resume():
    """Fault-tolerance contract: batch_at(step) is pure in (seed, step)."""
    ds = TokenStream(1000, 32, 8, seed=3)
    a = ds.batch_at(17)
    b = TokenStream(1000, 32, 8, seed=3).batch_at(17)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = ds.batch_at(18)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


def test_clickstream_learnable():
    cfg = get_arch("dcn-v2").model.scaled(
        vocab_sizes=tuple(min(v, 500) for v in get_arch("dcn-v2")
                          .model.vocab_sizes))
    ds = ClickStream(cfg, 256, seed=0)
    b = ds.batch_at(0)
    assert b["sparse"].shape == (256, cfg.n_sparse, 1)
    assert 0.2 < b["label"].mean() < 0.8          # non-degenerate labels


def test_sasrec_stream_shapes():
    cfg = get_arch("sasrec").model
    ds = SasrecStream(cfg, 16, seed=0)
    b = ds.batch_at(2)
    assert b["seq"].shape == (16, cfg.seq_len)
    assert (b["seq"] >= 0).all() and (b["seq"] < cfg.vocab_sizes[0]).all()
    # pos_items are the shifted sequence continuation
    np.testing.assert_array_equal(b["seq"][:, 1:], b["pos_items"][:, :-1])


def test_graph_generator_homophily():
    g = make_graph(400, 8, 16, 4, seed=0)
    same = (g["labels"][g["edges"][:, 0]] ==
            g["labels"][g["edges"][:, 1]]).mean()
    assert same > 0.35                            # homophilous by design
    assert g["edges"].max() < 400


def test_host_slice():
    batch = {"x": np.arange(16).reshape(8, 2)}
    s0 = host_slice(batch, process_index=0, process_count=4)
    s3 = host_slice(batch, process_index=3, process_count=4)
    assert s0["x"].shape == (2, 2)
    np.testing.assert_array_equal(s3["x"], batch["x"][6:8])


def test_prefetcher_orders_batches():
    ds = TokenStream(100, 8, 2, seed=0)
    pf = Prefetcher(ds.batch_at, depth=2)
    b0 = next(pf)
    b1 = next(pf)
    np.testing.assert_array_equal(b0["tokens"], ds.batch_at(0)["tokens"])
    np.testing.assert_array_equal(b1["tokens"], ds.batch_at(1)["tokens"])
    pf.stop()
