"""Multi-device behaviour on 8 virtual CPU devices (subprocess-isolated so
the main test session keeps exactly one device)."""
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(script: str, devices: int = 8, timeout: int = 520):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    return r.stdout


def test_sharded_search_recall():
    run_py("""
import numpy as np, jax, jax.numpy as jnp
from repro.data.vectors import make_clustered, make_queries
from repro.core import pq
from repro.core.vamana import build_sharded
from repro.core.chunk_layout import ChunkLayout
from repro.core.sharded_search import stack_shards, sharded_search_fn, input_sharding
from repro.core.index_io import recall_at
base = make_clustered(1600, 32, seed=0); q = make_queries(8, base)
gt = pq.groundtruth(q, base, 10)
cb = pq.train_codebooks(jax.random.PRNGKey(0), base, m=8, iters=6)
cents = np.asarray(cb.centroids); codes = np.asarray(pq.encode(cb, base))
lay = ChunkLayout('aisaq', 32, 'float32', 16, 8)
shards = build_sharded(base, 4, R=16, L=32, seed=0)
arrays = stack_shards(shards, cents, codes, lay)
from repro.launch.mesh import make_mesh_compat
mesh = make_mesh_compat((2, 4), ('data', 'model'))
search = sharded_search_fn(mesh, k=10, L=48, w=4, max_hops=64, layout=lay, metric='l2', backend='ref')
ash, qsh = input_sharding(mesh)
arrays = jax.tree.map(lambda a, s: jax.device_put(a, s), arrays, ash)
ids, dd = jax.jit(search)(arrays, jax.device_put(jnp.asarray(q), qsh))
r1 = recall_at(np.asarray(ids), gt, 1); r10 = recall_at(np.asarray(ids), gt, 10)
assert r1 >= 0.9 and r10 >= 0.85, (r1, r10)
print('sharded recall OK', r1, r10)
""")


def test_dp_training_matches_single_device():
    """Loss trajectory on a (2,4) mesh == single-device trajectory."""
    out = run_py("""
import numpy as np, jax
from repro.launch.train import train_loop
from repro.launch.mesh import make_test_mesh
mesh = make_test_mesh((2, 4))
h = train_loop('qwen3-1.7b', 'train_4k', steps=6, mesh=mesh, verbose=False)
print('LOSSES', ','.join(f'{l:.5f}' for l in h['losses']))
""")
    losses_dp = [float(x) for x in
                 out.split("LOSSES ")[1].strip().split(",")]
    out1 = run_py("""
import numpy as np
from repro.launch.train import train_loop
h = train_loop('qwen3-1.7b', 'train_4k', steps=6, verbose=False)
print('LOSSES', ','.join(f'{l:.5f}' for l in h['losses']))
""", devices=1)
    losses_1 = [float(x) for x in
                out1.split("LOSSES ")[1].strip().split(",")]
    assert abs(losses_dp[-1] - losses_1[-1]) < 0.05, (losses_dp, losses_1)


def test_elastic_checkpoint_reshard():
    """Save sharded state on a (2,4) mesh, restore onto (4,2) AND onto a
    single device — topology-agnostic checkpoints (elastic scaling)."""
    run_py("""
import jax, numpy as np, tempfile
from repro.launch.train import train_loop, build_trainer
from repro.launch.mesh import make_test_mesh
from repro.checkpoint.ckpt import restore, latest_step
d = tempfile.mkdtemp()
mesh = make_test_mesh((2, 4))
h = train_loop('qwen3-1.7b', 'train_4k', steps=4, mesh=mesh, ckpt_dir=d, ckpt_every=2, verbose=False)
mesh2 = make_test_mesh((4, 2))
arch, state_init, jit_step, data_gen, sh2 = build_trainer('qwen3-1.7b', 'train_4k', mesh=mesh2)
st = restore(d, state_init(), shardings=sh2)
import jax.numpy as jnp
batch = {k: jnp.asarray(v) for k, v in data_gen(4).items()}
st2, m = jit_step(st, batch)
assert np.isfinite(float(m['loss']))
print('resharded restore OK, loss', float(m['loss']))
""")


def test_pipeline_parallel_matches_sequential():
    run_py("""
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.pipeline import make_pp_mesh, pipeline_forward
S, M, mb, d = 4, 8, 2, 16
mesh = make_pp_mesh(S, 2)
rng = np.random.default_rng(0)
W = jnp.asarray(rng.normal(size=(8, d, d)).astype(np.float32)) * 0.3  # 8 layers
x = jnp.asarray(rng.normal(size=(M * mb, d)).astype(np.float32))
def stage_fn(params, xb):
    def body(h, w):
        return jnp.tanh(h @ w), None
    h, _ = jax.lax.scan(body, xb, params)
    return h
pipe = pipeline_forward(mesh, stage_fn, M)
xp = x.reshape(M, mb, d)
out = jax.jit(pipe)(W.reshape(S, 2, d, d), xp)
ref = stage_fn(W, x).reshape(M, mb, d)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)
print('pipeline OK')
""")


def test_compressed_grad_allreduce():
    run_py("""
import jax, jax.numpy as jnp, numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
from repro.distributed.compression import compressed_psum
from repro.launch.mesh import make_mesh_compat
mesh = make_mesh_compat((8,), ('data',))
rng = np.random.default_rng(0)
g = jnp.asarray(rng.normal(size=(8, 4096)).astype(np.float32))
def local(gs):
    return compressed_psum({'g': gs[0]}, 'data')['g']
out = shard_map(local, mesh=mesh, in_specs=(P('data', None),), out_specs=P(None), check_rep=False)(g)
ref = g.mean(0)
rel = float(jnp.abs(out - ref).max() / (jnp.abs(ref).max() + 1e-9))
assert rel < 0.02, rel      # int8 grade
print('compressed psum OK rel', rel)
""")


def test_cp_attention_matches_reference():
    """Context-parallel attention (§Perf cp-attn): loss + grads match the
    single-device reference bit-near-exactly."""
    run_py("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import LMConfig
from repro.models import transformer as T
from repro.distributed.act_sharding import set_policy
from repro.launch.mesh import make_test_mesh
cfg = LMConfig(name='t', n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
               d_ff=128, vocab_size=512, attention='sliding', window=256, dtype='float32')
p = T.init_lm(jax.random.PRNGKey(0), cfg)
toks = jax.random.randint(jax.random.PRNGKey(1), (4, 1024), 0, 512)
batch = {'tokens': toks, 'labels': jnp.roll(toks, -1, 1)}
set_policy(None)
l_ref = jax.jit(lambda p, b: T.lm_loss(p, b, cfg)[0])(p, batch)
g_ref = jax.jit(jax.grad(lambda p: T.lm_loss(p, batch, cfg)[0]))(p)
set_policy(make_test_mesh((2, 4)), cp_attention=True)
l_cp = jax.jit(lambda p, b: T.lm_loss(p, b, cfg)[0])(p, batch)
g_cp = jax.jit(jax.grad(lambda p: T.lm_loss(p, batch, cfg)[0]))(p)
set_policy(None)
m = max(jax.tree.leaves(jax.tree.map(
    lambda a, b: float(jnp.abs(a-b).max()/(jnp.abs(a).max()+1e-9)), g_ref, g_cp)))
assert abs(float(l_ref) - float(l_cp)) < 1e-4 and m < 5e-3, (float(l_ref), float(l_cp), m)
print('cp attention OK', m)
""")


def test_gnn_sharded_matches_reference():
    """Partitioned GNN aggregation (§Perf gnn-part) == replicated baseline."""
    run_py("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import GNNConfig
from repro.models import gnn as G
from repro.models.gnn_sharded import partition_edges, sharded_full_loss_fn
from repro.launch.mesh import make_test_mesh
from repro.data.pipeline import make_graph
cfg = GNNConfig(name='t', n_layers=2, d_hidden=32, n_classes=7)
g = make_graph(200, 6, 24, 7, seed=0)
p = G.init_gnn(jax.random.PRNGKey(0), cfg, d_feat=24)
batch = {k: jnp.asarray(v) for k, v in g.items()}
l_ref, _ = jax.jit(lambda p, b: G.gnn_full_loss(p, b, cfg))(p, batch)
mesh = make_test_mesh((2, 4))
pe, _ = partition_edges(g['edges'], 200, 8)
batch2 = dict(batch); batch2['edges'] = jnp.asarray(pe)
loss_fn = sharded_full_loss_fn(mesh, cfg, 200, wire_dtype=jnp.float32)
l_sh, _ = jax.jit(loss_fn)(p, batch2)
g_ref = jax.jit(jax.grad(lambda p: G.gnn_full_loss(p, batch, cfg)[0]))(p)
g_sh = jax.jit(jax.grad(lambda p: loss_fn(p, batch2)[0]))(p)
m = max(jax.tree.leaves(jax.tree.map(
    lambda a, b: float(jnp.abs(a-b).max()/(jnp.abs(a).max()+1e-9)), g_ref, g_sh)))
assert abs(float(l_ref) - float(l_sh)) < 1e-4 and m < 1e-3
print('sharded gnn OK', m)
""")


def test_moe_ep_matches_global_dispatch():
    """shard_map EP MoE (§Perf moe-ep) == GSPMD global dispatch."""
    run_py("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import MoEConfig
from repro.models.moe import init_moe, moe_apply, moe_apply_ep
from repro.distributed.act_sharding import set_policy
from repro.launch.mesh import make_test_mesh
mc = MoEConfig(n_experts=8, top_k=2, d_expert=32, capacity_factor=16.0,
               n_shared_experts=1, d_shared=32)
p = init_moe(jax.random.PRNGKey(0), 48, mc, jnp.float32)
x = jnp.asarray(np.random.default_rng(0).normal(size=(64, 48)), jnp.float32)
set_policy(None)
out_ref, _ = jax.jit(lambda p, x: moe_apply(p, x, mc))(p, x)
g_ref = jax.jit(jax.grad(lambda p: (moe_apply(p, x, mc)[0]**2).sum()))(p)
set_policy(make_test_mesh((2, 4)))
out_ep, _ = jax.jit(lambda p, x: moe_apply_ep(p, x, mc))(p, x)
g_ep = jax.jit(jax.grad(lambda p: (moe_apply_ep(p, x, mc)[0]**2).sum()))(p)
set_policy(None)
err = float(jnp.abs(out_ref - out_ep).max()/(jnp.abs(out_ref).max()+1e-9))
gerr = max(jax.tree.leaves(jax.tree.map(
    lambda a, b: float(jnp.abs(a-b).max()/(jnp.abs(a).max()+1e-9)), g_ref, g_ep)))
assert err < 1e-5 and gerr < 1e-4, (err, gerr)
print('moe ep OK', err, gerr)
""")


def test_ann_cell_runs_small_mesh():
    """Execute (not just compile) the dry-run ANN search program shape on
    8 devices with a real small index."""
    run_py("""
import numpy as np, jax, jax.numpy as jnp
from repro.data.vectors import make_clustered, make_queries
from repro.core import pq
from repro.core.vamana import build_sharded
from repro.core.chunk_layout import ChunkLayout
from repro.core.sharded_search import stack_shards, sharded_search_fn, input_sharding
from repro.core.index_io import recall_at
# mode B: shards over EVERY axis, queries replicated + chunked
base = make_clustered(1600, 32, seed=0); q = make_queries(16, base)
gt = pq.groundtruth(q, base, 10)
cb = pq.train_codebooks(jax.random.PRNGKey(0), base, m=8, iters=6)
cents = np.asarray(cb.centroids); codes = np.asarray(pq.encode(cb, base))
lay = ChunkLayout('aisaq', 32, 'float32', 16, 8)
shards = build_sharded(base, 8, R=16, L=32, seed=0)
arrays = stack_shards(shards, cents, codes, lay)
from repro.launch.mesh import make_mesh_compat
mesh = make_mesh_compat((2, 4), ('data', 'model'))
search = sharded_search_fn(mesh, k=10, L=48, w=4, max_hops=64, layout=lay,
                           metric='l2', backend='ref', query_axes=(),
                           shard_axes=('data', 'model'), query_chunk=8)
ash, qsh = input_sharding(mesh, query_axes=(None,), shard_axes=('data', 'model'))
from jax.sharding import NamedSharding, PartitionSpec as P
arrays = jax.tree.map(lambda a, s: jax.device_put(a, s), arrays, ash)
ids, dd = jax.jit(search)(arrays, jnp.asarray(q))
r1 = recall_at(np.asarray(ids), gt, 1)
assert r1 >= 0.85, r1
print('mode-B sharded search OK', r1)
""")
