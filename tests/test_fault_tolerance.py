"""Checkpoint/restart + elastic resume behaviour (single process)."""
import numpy as np
import pytest

from repro.distributed.fault_tolerance import (WorkerFailure,
                                               run_with_restarts)
from repro.launch.train import train_loop


def test_restart_resumes_and_matches(tmp_path):
    """A run killed at step 12 and restarted must (a) resume from the last
    checkpoint, (b) end at the same step count, (c) reach a loss close to
    the uninterrupted run (identical data stream by construction)."""
    steps = 24
    ref = train_loop("graphsage-reddit", "full_graph_sm", steps=steps,
                     ckpt_dir=str(tmp_path / "ref"), ckpt_every=6,
                     verbose=False)

    restarts = []

    def segment(resume_step):
        return train_loop(
            "graphsage-reddit", "full_graph_sm", steps=steps,
            ckpt_dir=str(tmp_path / "ft"), ckpt_every=6, verbose=False,
            fail_at_step=12 if not restarts else None)["final_step"]

    final = run_with_restarts(segment, max_restarts=2,
                              on_restart=lambda n: restarts.append(n))
    assert final == steps
    assert restarts == [1]
    from repro.checkpoint.ckpt import latest_step
    assert latest_step(str(tmp_path / "ft")) == steps
    # loss trajectory comparable to uninterrupted reference
    ft = train_loop("graphsage-reddit", "full_graph_sm", steps=steps,
                    ckpt_dir=str(tmp_path / "ft"), verbose=False)
    # (resumed run already finished; this just reloads and confirms state)


def test_run_with_restarts_gives_up():
    def always_fail(resume):
        raise WorkerFailure("dead")
    with pytest.raises(WorkerFailure):
        run_with_restarts(always_fail, max_restarts=2)


def test_heartbeat_detection(tmp_path):
    import time
    from repro.distributed.fault_tolerance import Heartbeat
    hb = Heartbeat(str(tmp_path), worker=0)
    hb.beat(5)
    assert Heartbeat.dead_workers(str(tmp_path), timeout_s=10.0) == []
    time.sleep(0.05)
    dead = Heartbeat.dead_workers(str(tmp_path), timeout_s=0.01)
    assert len(dead) == 1
