"""Navigation tier (PR 10): pivot selection, the in-RAM nav beam, the
``entry=`` knob, sidecar compatibility/corruption handling, and budget
accounting.

Invariants under test:

  * nav-seeded batched search is bit-identical to the nav-seeded scalar
    Algorithm-1 oracle across {adc_dtype} x {prefetch, pipeline} x
    {relabel} (the same discipline every prior traversal knob obeys),
  * pivot selection is seed-stable (same inputs -> same pivots),
  * dirs without the sidecar (v1/v2 format) load and serve with the
    tier DISABLED; a corrupt/truncated/missing sidecar degrades the
    same way with a RuntimeWarning — ``CorruptIndexError`` stays
    reserved for core-index damage (docs/failure_model.md),
  * nav residency is charged into ``resident_bytes`` and hence the
    ``WarmIndexPool`` budget, and surfaces in ``pool.stats()``.
"""
import json
import os
import warnings

import numpy as np
import pytest

from repro.core import nav as navmod
from repro.core.index_io import HostIndex, write_index
from repro.core.traversal import recall_at


@pytest.fixture(scope="module")
def nav_dirs(tmp_path_factory, small_corpus, built_graph, pq_artifacts):
    """{relabel: path} nav-enabled indices + a nav-less twin."""
    base, _, _ = small_corpus
    cents, codes = pq_artifacts
    root = tmp_path_factory.mktemp("nav_idx")
    paths = {}
    for relabel in (False, True):
        p = str(root / f"nav_rl{int(relabel)}")
        write_index(p, vectors=base, graph=built_graph, centroids=cents,
                    codes=codes, metric="l2", mode="aisaq",
                    relabel=relabel, nav=True, nav_fraction=0.03)
        paths[relabel] = p
    p = str(root / "plain")
    write_index(p, vectors=base, graph=built_graph, centroids=cents,
                codes=codes, metric="l2", mode="aisaq")
    paths["plain"] = p
    return paths


# ---------------------------------------------------------------------------
# pivot selection
# ---------------------------------------------------------------------------


def test_select_pivots_seed_stable(small_corpus):
    base, _, _ = small_corpus
    a = navmod.select_pivots(base, fraction=0.03, seed=7)
    b = navmod.select_pivots(base, fraction=0.03, seed=7)
    np.testing.assert_array_equal(a, b)
    c = navmod.select_pivots(base, fraction=0.03, seed=8)
    assert not np.array_equal(a, c)
    # sorted unique valid ids, ~fraction * n of them
    assert a.dtype == np.int64 and (np.diff(a) > 0).all()
    assert 0 <= a.min() and a.max() < len(base)
    assert a.size == max(1, round(0.03 * len(base)))
    r = navmod.select_pivots(base, fraction=0.03, seed=7, method="random")
    assert r.size == a.size and (np.diff(r) > 0).all()
    with pytest.raises(ValueError, match="method"):
        navmod.select_pivots(base, method="bogus")


def test_build_nav_deterministic(small_corpus, pq_artifacts):
    base, _, _ = small_corpus
    _, codes = pq_artifacts
    a = navmod.build_nav(base, codes, fraction=0.03, seed=3)
    b = navmod.build_nav(base, codes, fraction=0.03, seed=3)
    np.testing.assert_array_equal(a.pivot_ids, b.pivot_ids)
    np.testing.assert_array_equal(a.graph, b.graph)
    np.testing.assert_array_equal(a.codes, b.codes)
    assert a.params == b.params
    assert a.resident_nbytes() > 0


# ---------------------------------------------------------------------------
# bit-identity: nav-seeded batch == nav-seeded scalar oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("relabel", [False, True])
def test_nav_parity_grid(nav_dirs, small_corpus, relabel):
    base, q, gt = small_corpus
    idx = HostIndex.load(nav_dirs[relabel])
    assert idx.nav is not None
    try:
        for entry in ("nav", "medoid", "auto"):
            for adc in ("f32", "int8"):
                ref_ids, ref_st = idx.search_batch_ref(
                    q, 10, L=32, w=4, adc_dtype=adc, entry=entry)
                for pf, pl in ((0, False), (4, False), (4, True)):
                    idx.cache.wait_prefetch()
                    idx.cache.clear()
                    ids, st = idx.search_batch(
                        q, 10, L=32, w=4, prefetch=pf, adc_dtype=adc,
                        pipeline=pl, entry=entry)
                    tag = f"entry={entry} adc={adc} pf={pf} pl={pl}"
                    assert np.array_equal(ids, ref_ids), tag
                    # hop accounting matches the oracle per query
                    assert [s.hops for s in st] \
                        == [s.hops for s in ref_st], tag
                    assert [s.convergence_hop for s in st] \
                        == [s.convergence_hop for s in ref_st], tag
                assert recall_at(ids, gt, 10) > 0.6
    finally:
        idx.close()


def test_nav_rerank_parity(nav_dirs, small_corpus):
    base, q, _ = small_corpus
    idx = HostIndex.load(nav_dirs[True])
    try:
        ref_ids, _ = idx.search_batch_ref(q, 10, L=32, w=4, rerank=20,
                                          entry="nav")
        ids, _ = idx.search_batch(q, 10, L=32, w=4, rerank=20, entry="nav")
        np.testing.assert_array_equal(ids, ref_ids)
    finally:
        idx.close()


def test_nav_seed_batch_row_independent(nav_dirs, small_corpus,
                                        pq_artifacts):
    """A batch of one computes bit-identical rows to the full batch —
    the property the scalar-oracle guarantee rests on."""
    from repro.core.adc import np_build_lut_batch
    base, q, _ = small_corpus
    cents, _ = pq_artifacts
    idx = HostIndex.load(nav_dirs[False])
    try:
        lut = np_build_lut_batch(idx.centroids, q, "l2")
        ids_b, d_b, hops_b, evals_b = navmod.nav_seed_batch(
            idx.nav, lut, None, 4)
        for i in range(len(q)):
            ids_1, d_1, hops_1, evals_1 = navmod.nav_seed_batch(
                idx.nav, lut[i:i + 1], None, 4)
            np.testing.assert_array_equal(ids_1[0], ids_b[i])
            np.testing.assert_array_equal(d_1[0], d_b[i])
            assert hops_1[0] == hops_b[i] and evals_1[0] == evals_b[i]
        # seeds are storage-space ids drawn from the pivot set
        valid = ids_b[ids_b >= 0]
        assert np.isin(valid, idx.nav.pivot_ids).all()
    finally:
        idx.close()


def test_nav_stats_fields(nav_dirs, small_corpus):
    base, q, _ = small_corpus
    idx = HostIndex.load(nav_dirs[False])
    try:
        _, st_nav = idx.search_batch(q, 10, L=32, w=4, entry="nav")
        _, st_med = idx.search_batch(q, 10, L=32, w=4, entry="medoid")
        assert all(s.nav_dists > 0 and s.nav_hops >= 0 for s in st_nav)
        assert all(s.nav_dists == 0 and s.nav_hops == 0 for s in st_med)
        assert all(0 < s.convergence_hop <= s.hops for s in st_nav)
        assert all(np.isfinite(s.entry_dist) for s in st_nav)
        # nav beam cost is accounted but does ZERO storage I/O: medoid
        # and nav runs read from the same cache state
        assert st_nav[0].nav_s >= 0.0
    finally:
        idx.close()


# ---------------------------------------------------------------------------
# entry= knob semantics
# ---------------------------------------------------------------------------


def test_entry_auto_and_errors(nav_dirs, small_corpus):
    base, q, _ = small_corpus
    idx = HostIndex.load(nav_dirs[False])
    plain = HostIndex.load(nav_dirs["plain"])
    try:
        ids_auto, _ = idx.search_batch(q, 10, L=32, w=4, entry="auto")
        ids_nav, _ = idx.search_batch(q, 10, L=32, w=4, entry="nav")
        np.testing.assert_array_equal(ids_auto, ids_nav)  # auto -> nav
        assert plain.nav is None
        ids_p, _ = plain.search_batch(q, 10, L=32, w=4, entry="auto")
        ids_m, _ = plain.search_batch(q, 10, L=32, w=4, entry="medoid")
        np.testing.assert_array_equal(ids_p, ids_m)       # auto -> medoid
        with pytest.raises(ValueError, match="navigation tier"):
            plain.search_batch(q, 10, L=32, w=4, entry="nav")
        with pytest.raises(ValueError, match="entry"):
            idx.search_batch(q, 10, L=32, w=4, entry="bogus")
    finally:
        idx.close()
        plain.close()


# ---------------------------------------------------------------------------
# sidecar compatibility + corruption
# ---------------------------------------------------------------------------


def test_pre_nav_dir_loads_disabled(index_dirs, small_corpus):
    """A dir written without nav (same layout as a v1/v2 dir: no ``nav``
    meta key, no sidecar) loads cleanly, serves, and reports no tier."""
    base, q, _ = small_corpus
    with warnings.catch_warnings():
        warnings.simplefilter("error")          # no warning on clean dirs
        idx = HostIndex.load(index_dirs["aisaq"])
    try:
        assert idx.nav is None
        ids, _ = idx.search_batch(q, 10, L=32, w=4)
        assert ids.shape == (len(q), 10)
    finally:
        idx.close()


def _load_expect_disabled(path):
    with pytest.warns(RuntimeWarning, match="navigation sidecar"):
        idx = HostIndex.load(path)
    try:
        assert idx.nav is None
        # auto falls back; explicit nav is a usage error
        idx.search_batch(np.zeros((1, idx.meta["dim"]), np.float32),
                         5, L=16, w=4, entry="auto")
        with pytest.raises(ValueError):
            idx.search_batch(np.zeros((1, idx.meta["dim"]), np.float32),
                             5, L=16, w=4, entry="nav")
    finally:
        idx.close()


@pytest.mark.parametrize("damage", ["missing", "truncated", "garbage",
                                    "bad_ids", "meta_mismatch"])
def test_sidecar_damage_degrades_not_fails(nav_dirs, tmp_path, damage,
                                           small_corpus):
    import shutil
    src = nav_dirs[False]
    p = str(tmp_path / f"dmg_{damage}")
    shutil.copytree(src, p)
    side = os.path.join(p, navmod.NAV_SIDECAR)
    if damage == "missing":
        os.remove(side)
    elif damage == "truncated":
        blob = open(side, "rb").read()
        open(side, "wb").write(blob[:len(blob) // 2])
    elif damage == "garbage":
        open(side, "wb").write(b"\x00" * 128)
    elif damage == "bad_ids":
        with np.load(side) as z:
            arrs = dict(z)
        arrs["pivot_ids"] = arrs["pivot_ids"] + 10 ** 9   # out of range
        with open(side, "wb") as f:
            np.savez(f, **arrs)
    elif damage == "meta_mismatch":
        mp = os.path.join(p, "meta.json")
        meta = json.load(open(mp))
        meta["nav"]["pivots"] = meta["nav"]["pivots"] + 1
        json.dump(meta, open(mp, "w"))
    _load_expect_disabled(p)


def test_core_damage_still_raises(nav_dirs, tmp_path):
    """Nav tolerance must NOT soften core-index integrity: damaging
    meta.json still raises CorruptIndexError."""
    import shutil
    from repro.core.integrity import CorruptIndexError
    p = str(tmp_path / "core_dmg")
    shutil.copytree(nav_dirs[False], p)
    open(os.path.join(p, "meta.json"), "w").write("{not json")
    with pytest.raises(CorruptIndexError):
        HostIndex.load(p)


# ---------------------------------------------------------------------------
# budget accounting
# ---------------------------------------------------------------------------


def test_nav_bytes_charged(nav_dirs):
    idx = HostIndex.load(nav_dirs[False])
    plain = HostIndex.load(nav_dirs["plain"])
    try:
        assert idx.resident_bytes() \
            == plain.resident_bytes() + idx.nav.resident_nbytes()
    finally:
        idx.close()
        plain.close()


def test_pool_charges_and_reports_nav(nav_dirs):
    from repro.serving.pool import WarmIndexPool
    pool = WarmIndexPool({"navc": nav_dirs[False],
                          "plain": nav_dirs["plain"]},
                         cache_bytes=128 << 10)
    try:
        with pool.lease("navc") as (idx, _):
            nav_nb = idx.nav.resident_nbytes()
            assert pool.entry_bytes("navc") \
                >= idx.resident_bytes()          # nav included in charge
        with pool.lease("plain"):
            pass
        st = pool.stats()
        assert st["nav_bytes"] == {"navc": nav_nb}
        assert st["nav_bytes_total"] == nav_nb
        assert st["used_bytes"] >= nav_nb
    finally:
        pool.close()


def test_service_reports_hop_percentiles(nav_dirs, small_corpus):
    from repro.serving.pool import WarmIndexPool
    from repro.serving.service import RetrievalService
    base, q, _ = small_corpus
    pool = WarmIndexPool({"navc": nav_dirs[False]}, cache_bytes=128 << 10)
    svc = RetrievalService(pool, num_workers=1, max_batch=8,
                           max_wait_ms=1.0, L=32, entry="auto")
    try:
        rs = [svc.submit(q[i % len(q)], corpus="navc", k=5)
              for i in range(8)]
        for r in rs:
            r.event.wait(10.0)
            assert r.error is None
        st = svc.stats()["corpora"]["navc"]
        assert st["hops_p50"] > 0
        assert st["hops_p99"] >= st["hops_p50"]
        assert st["convergence_hops_p50"] > 0
        reg = svc.stats()["registry"]
        assert reg["traversal_hops"]["series"][0]["count"] >= 8
        assert reg["nav_beam_hops"]["series"][0]["count"] >= 8
    finally:
        svc.stop()
        pool.close()
