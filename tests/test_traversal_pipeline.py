"""The pipelined traversal engine (core.traversal):

  * bit-identical parity vs the scalar Algorithm-1 oracle across the FULL
    knob grid {adc_dtype} x {relabel} x {prefetch} x {rerank} x {pipeline},
  * overlap observability (SearchStats.blocked_wait_s / compute_s),
  * fault injection: a slow or FAILING background read degrades the
    pipeline to the serial path — same results, no deadlock,
  * readahead gap autotuning (gap="auto" from the miss histograms).
"""
import threading
import time

import numpy as np
import pytest

from repro.core.block_cache import BlockCache
from repro.core.index_io import HostIndex, recall_at, write_index


@pytest.fixture(scope="module")
def rl_index_dir(tmp_path_factory, small_corpus, built_graph, pq_artifacts):
    """A graph-locality-relabeled AiSAQ index (the cold-path layout)."""
    base, _, _ = small_corpus
    cents, codes = pq_artifacts
    p = str(tmp_path_factory.mktemp("pipe") / "rl")
    write_index(p, vectors=base, graph=built_graph, centroids=cents,
                codes=codes, metric="l2", mode="aisaq", relabel=True)
    return p


# ---------------------------------------------------------------------------
# full-grid parity vs the scalar oracle
# ---------------------------------------------------------------------------


def test_pipeline_full_knob_grid_parity(index_dirs, rl_index_dir,
                                        small_corpus):
    """The tentpole invariant: the pipelined engine returns EXACTLY the
    scalar oracle's ids over {adc_dtype} x {relabel} x {prefetch} x
    {rerank}, pipeline forced ON wherever prefetch > 0."""
    base, q, gt = small_corpus
    for path in (index_dirs["aisaq"], rl_index_dir):
        idx = HostIndex.load(path)
        for adc in ("f32", "int8"):
            for rerank in (None, 0, 20):
                ref_ids, ref_st = idx.search_batch_ref(
                    q, 10, L=40, adc_dtype=adc, rerank=rerank)
                for pf in (0, 2, 4):
                    idx.cache.wait_prefetch()
                    idx.cache.clear()
                    ids, st = idx.search_batch(
                        q, 10, L=40, prefetch=pf, adc_dtype=adc,
                        rerank=rerank, pipeline=pf > 0)
                    np.testing.assert_array_equal(
                        ids, ref_ids,
                        err_msg=f"adc={adc} rerank={rerank} pf={pf}")
                    # logical I/O identical too — speculation never
                    # changes what traversal reads, only when
                    assert [s.ios for s in st] == \
                        [s.ios for s in ref_st]
        idx.close()


def test_pipeline_defaults_on_with_prefetch(index_dirs, small_corpus):
    """pipeline=None resolves to ON iff prefetch > 0; the flag is
    reported in SearchStats."""
    base, q, gt = small_corpus
    idx = HostIndex.load(index_dirs["aisaq"])
    _, st = idx.search_batch(q, 10, L=40, prefetch=4)
    assert st[0].pipelined == 1
    idx.cache.wait_prefetch(), idx.cache.clear()
    _, st = idx.search_batch(q, 10, L=40)            # prefetch=0
    assert st[0].pipelined == 0
    idx.cache.wait_prefetch(), idx.cache.clear()
    _, st = idx.search_batch(q, 10, L=40, prefetch=4, pipeline=False)
    assert st[0].pipelined == 0
    idx.close()


def test_overlap_is_observable_in_stats(index_dirs, small_corpus):
    """blocked_wait_s / compute_s land on the lead query and partition the
    hop-loop time sanely (never negative, bounded by the batch wall)."""
    base, q, gt = small_corpus
    idx = HostIndex.load(index_dirs["aisaq"])
    _, st = idx.search_batch(q, 10, L=40, prefetch=4, pipeline=True)
    wall = sum(s.latency_s for s in st)
    assert st[0].blocked_wait_s >= 0.0
    assert st[0].compute_s > 0.0
    assert st[0].blocked_wait_s + st[0].compute_s <= wall * 1.05 + 1e-3
    # non-lead queries carry no batch-level overlap accounting
    assert all(s.blocked_wait_s == 0.0 for s in st[1:])
    idx.close()


def test_single_query_search_accepts_pipeline(index_dirs, small_corpus):
    base, q, gt = small_corpus
    idx = HostIndex.load(index_dirs["aisaq"])
    a, _ = idx.search_ref(q[0], 10, L=40)
    b, st = idx.search(q[0], 10, L=40, prefetch=4, pipeline=True)
    np.testing.assert_array_equal(a, b)
    assert st.pipelined == 1
    idx.close()


# ---------------------------------------------------------------------------
# fault injection: the pipeline must DEGRADE, never corrupt or deadlock
# ---------------------------------------------------------------------------


def _run_with_timeout(fn, seconds=30.0):
    """Run fn on a worker thread; fail the test instead of hanging CI if
    the pipeline deadlocks."""
    out: dict = {}

    def target():
        try:
            out["result"] = fn()
        except BaseException as e:     # noqa: BLE001 — surfaced below
            out["error"] = e

    t = threading.Thread(target=target, daemon=True)
    t.start()
    t.join(seconds)
    assert not t.is_alive(), f"deadlock: search did not finish in {seconds}s"
    if "error" in out:
        raise out["error"]
    return out["result"]


def test_slow_background_read_keeps_results(index_dirs, small_corpus,
                                            monkeypatch):
    """A crawling prefetch thread: demand fetches wait (bounded) on
    in-flight blocks or read them directly — results stay oracle-exact."""
    base, q, gt = small_corpus
    idx = HostIndex.load(index_dirs["aisaq"])
    ref_ids, _ = idx.search_batch_ref(q, 10, L=40)
    orig = BlockCache._pf_read

    def slow_read(self, batch, gap=0):
        time.sleep(0.05)
        return orig(self, batch, gap)

    monkeypatch.setattr(BlockCache, "_pf_read", slow_read)
    ids, st = _run_with_timeout(
        lambda: idx.search_batch(q, 10, L=40, prefetch=4, pipeline=True))
    np.testing.assert_array_equal(ids, ref_ids)
    idx.close()


def test_failing_background_read_degrades_to_serial(index_dirs,
                                                    small_corpus,
                                                    monkeypatch):
    """EVERY background read raises: the worker must survive, un-claim its
    in-flight blocks (so demand fetches stop waiting for reads that will
    never land), count the failures, and the search must still match the
    oracle — the serial-path degradation promise."""
    base, q, gt = small_corpus
    idx = HostIndex.load(index_dirs["aisaq"])
    ref_ids, _ = idx.search_batch_ref(q, 10, L=40)

    def broken_read(self, batch, gap=0):
        raise OSError("injected: background preadv failed")

    monkeypatch.setattr(BlockCache, "_pf_read", broken_read)
    # gap=0 disables demand-path readahead so ALL speculation would have
    # to come from the (broken) background thread
    ids, st = _run_with_timeout(
        lambda: idx.search_batch(q, 10, L=40, prefetch=4, pipeline=True,
                                 gap=0))
    np.testing.assert_array_equal(ids, ref_ids)
    c = idx.cache.counters
    assert c.prefetch_errors > 0
    # nothing speculative ever landed; all I/O fell back to the demand path
    assert c.prefetch_issued == 0
    assert recall_at(ids, gt, 10) == recall_at(ref_ids, gt, 10)
    idx.close()


def test_flaky_background_read_no_duplicate_or_lost_blocks(
        index_dirs, small_corpus, monkeypatch):
    """Alternating background success/failure: results exact and every
    block is read at least once, with failed batches retried on the
    demand path (no lost reads)."""
    base, q, gt = small_corpus
    idx = HostIndex.load(index_dirs["aisaq"])
    ref_ids, _ = idx.search_batch_ref(q, 10, L=40)
    orig = BlockCache._pf_read
    calls = {"n": 0}

    def flaky(self, batch, gap=0):
        calls["n"] += 1
        if calls["n"] % 2:
            raise OSError("injected flake")
        return orig(self, batch, gap)

    monkeypatch.setattr(BlockCache, "_pf_read", flaky)
    ids, st = _run_with_timeout(
        lambda: idx.search_batch(q, 10, L=40, prefetch=4, pipeline=True))
    np.testing.assert_array_equal(ids, ref_ids)
    assert calls["n"] > 1
    idx.close()


# ---------------------------------------------------------------------------
# readahead gap autotuning
# ---------------------------------------------------------------------------


def test_gap_auto_matches_oracle_and_reports_choice(rl_index_dir,
                                                    small_corpus):
    base, q, gt = small_corpus
    idx = HostIndex.load(rl_index_dir)
    ref_ids, _ = idx.search_batch_ref(q, 10, L=40)
    ids, st = idx.search_batch(q, 10, L=40, prefetch=4, gap="auto")
    np.testing.assert_array_equal(ids, ref_ids)
    # the histograms were populated and a (possibly zero) gap was chosen
    assert sum(idx.cache.miss_run_hist.values()) > 0
    assert idx.cache.counters.auto_gap == idx.cache.auto_gap()
    assert 0 <= idx.cache.counters.auto_gap <= 8
    idx.close()


def test_gap_auto_needs_observations(tmp_path):
    """Before enough holes are observed, auto falls back to gap=0 (no
    blind readahead)."""
    io = 4096
    p = tmp_path / "f.bin"
    p.write_bytes(bytes(64 * io))
    import os
    fd = os.open(p, os.O_RDONLY)
    try:
        cache = BlockCache(fd, io, capacity_bytes=32 * io)
        assert cache.auto_gap() == 0
        out, hm, n_sys = cache.fetch(np.array([0, 2 * io]), gap="auto")
        assert cache.counters.auto_gap == 0
        assert n_sys == 2                       # no blind coalescing yet
    finally:
        os.close(fd)


def test_gap_auto_learns_small_holes(tmp_path):
    """A workload whose misses are runs separated by 1-block holes teaches
    auto to coalesce them: later fetches merge runs (fewer syscalls)."""
    import os
    io = 4096
    p = tmp_path / "f.bin"
    p.write_bytes(bytes(256 * io))
    fd = os.open(p, os.O_RDONLY)
    try:
        cache = BlockCache(fd, io, capacity_bytes=0)   # no retention:
        # every fetch is a fresh miss pattern, isolating the gap logic
        # pattern: blocks {0,1, 3,4, 6,7, ...} — holes of exactly 1
        offs = np.array([b * io for b in range(0, 40)
                         if b % 3 != 2], dtype=np.int64)
        cache.fetch(offs)                       # teach the histogram
        assert cache.auto_gap() == 1
        _, _, n_plain = cache.fetch(offs, gap=0)
        _, _, n_auto = cache.fetch(offs, gap="auto")
        assert cache.counters.auto_gap == 1
        assert n_auto < n_plain                 # coalesced through holes
    finally:
        os.close(fd)
