import time

import numpy as np

from repro.serving.engine import ServingEngine


def _search_fn(delay_s=0.0):
    def fn(queries, k):
        if delay_s:
            time.sleep(delay_s)
        # deterministic fake ids
        return np.tile(np.arange(k)[None], (queries.shape[0], 1))
    return fn


def test_engine_batches_and_answers():
    eng = ServingEngine({"default": _search_fn()}, max_batch=8,
                        max_wait_ms=5.0)
    reqs = [eng.submit(np.ones(8, np.float32) * i) for i in range(20)]
    for r in reqs:
        r.event.wait(5.0)
        assert r.result is not None and r.result.shape == (10,)
    pct = eng.latency_percentiles()
    assert pct["n"] == 20
    eng.stop()


def test_hedging_beats_straggler():
    fast, slow = _search_fn(0.002), _search_fn(0.25)
    hedged = ServingEngine({"default": slow}, hedge=2,
                           replicas=[slow, fast], max_wait_ms=1.0)
    r = hedged.submit_wait(np.ones(4, np.float32))
    assert r.latency_s < 0.2          # fast replica won the hedge
    hedged.stop()
    unhedged = ServingEngine({"default": slow}, max_wait_ms=1.0)
    r2 = unhedged.submit_wait(np.ones(4, np.float32))
    assert r2.latency_s >= 0.2
    unhedged.stop()


def test_corpus_switch_called():
    calls = []
    eng = ServingEngine({"a": _search_fn(), "b": _search_fn()},
                        switch_fn=lambda c: calls.append(c) or 0.001,
                        max_wait_ms=1.0)
    eng.submit_wait(np.ones(4, np.float32), corpus="a")
    eng.submit_wait(np.ones(4, np.float32), corpus="b")
    eng.submit_wait(np.ones(4, np.float32), corpus="b")  # no switch
    assert calls == ["a", "b"]
    assert len(eng.switch_times) == 2
    eng.stop()
