import time

import numpy as np
import pytest

from repro.serving.engine import ServingEngine


def _search_fn(delay_s=0.0):
    def fn(queries, k):
        if delay_s:
            time.sleep(delay_s)
        # deterministic fake ids
        return np.tile(np.arange(k)[None], (queries.shape[0], 1))
    return fn


def test_engine_batches_and_answers():
    eng = ServingEngine({"default": _search_fn()}, max_batch=8,
                        max_wait_ms=5.0)
    reqs = [eng.submit(np.ones(8, np.float32) * i) for i in range(20)]
    for r in reqs:
        r.event.wait(5.0)
        assert r.result is not None and r.result.shape == (10,)
    pct = eng.latency_percentiles()
    assert pct["n"] == 20
    eng.stop()


def test_hedging_beats_straggler():
    fast, slow = _search_fn(0.002), _search_fn(0.25)
    hedged = ServingEngine({"default": slow}, hedge=2,
                           replicas=[slow, fast], max_wait_ms=1.0)
    r = hedged.submit_wait(np.ones(4, np.float32))
    assert r.latency_s < 0.2          # fast replica won the hedge
    hedged.stop()
    unhedged = ServingEngine({"default": slow}, max_wait_ms=1.0)
    r2 = unhedged.submit_wait(np.ones(4, np.float32))
    assert r2.latency_s >= 0.2
    unhedged.stop()


def test_corpus_switch_called():
    calls = []
    eng = ServingEngine({"a": _search_fn(), "b": _search_fn()},
                        switch_fn=lambda c: calls.append(c) or 0.001,
                        max_wait_ms=1.0)
    eng.submit_wait(np.ones(4, np.float32), corpus="a")
    eng.submit_wait(np.ones(4, np.float32), corpus="b")
    eng.submit_wait(np.ones(4, np.float32), corpus="b")  # no switch
    assert calls == ["a", "b"]
    assert len(eng.switch_times) == 2
    eng.stop()


# ---------------------------------------------------------------------------
# hedging fix: first SUCCESSFUL completion wins; wasted work is accounted
# ---------------------------------------------------------------------------


def _failing_fn(delay_s=0.0):
    def fn(queries, k):
        if delay_s:
            time.sleep(delay_s)
        raise ValueError("replica down")
    return fn


def test_hedge_skips_failed_replica():
    """A fast-failing replica must NOT win the hedge race (the old code
    took `list(done)[0].result()`, which could pick the failure)."""
    fail, good = _failing_fn(), _search_fn(0.02)
    for _ in range(5):                    # old bug was racy: hammer it
        eng = ServingEngine({"default": good}, hedge=2,
                            replicas=[fail, good], max_wait_ms=1.0)
        r = eng.submit_wait(np.ones(4, np.float32))
        assert r.error is None
        assert r.result is not None and r.result.shape == (10,)
        assert eng.hedge_stats["failed"] >= 1
        eng.stop()


def test_hedge_all_replicas_fail_sets_error():
    eng = ServingEngine({"default": _failing_fn()}, hedge=2,
                        replicas=[_failing_fn(), _failing_fn(0.01)],
                        max_wait_ms=1.0)
    r = eng.submit_wait(np.ones(4, np.float32))
    assert r.result is None
    assert isinstance(r.error, ValueError)
    assert eng.hedge_stats["failed"] == 2
    eng.stop()


def test_hedge_wasted_work_accounted():
    """Both replicas succeed; the loser's completed work counts as wasted
    (Future.cancel() on a running thread is a no-op — the engine must not
    pretend the work disappeared)."""
    fast, slow = _search_fn(0.005), _search_fn(0.08)
    eng = ServingEngine({"default": fast}, hedge=2,
                        replicas=[slow, fast], max_wait_ms=1.0)
    r = eng.submit_wait(np.ones(4, np.float32))
    assert r.result is not None
    time.sleep(0.15)                      # let the slow loser finish
    assert eng.hedge_stats["batches"] == 1
    assert eng.hedge_stats["wasted"] == 1
    eng.stop()


# ---------------------------------------------------------------------------
# _collect_batch holdover fix (regression for the re-queue starvation bug)
# ---------------------------------------------------------------------------


def test_foreign_corpus_request_not_starved():
    """Old bug: a different-corpus request was pushed to the BACK of the
    FIFO, so sustained load on corpus `a` could starve a `b` request
    indefinitely. With the holdover deque, `b` is served at the next batch
    head — before `a` requests that arrived after it."""
    eng = ServingEngine({"a": _search_fn(0.01), "b": _search_fn(0.01)},
                        max_batch=4, max_wait_ms=20.0)
    head = [eng.submit(np.ones(4, np.float32), corpus="a")
            for _ in range(3)]
    rb = eng.submit(np.ones(4, np.float32), corpus="b")
    tail = [eng.submit(np.ones(4, np.float32), corpus="a")
            for _ in range(8)]
    for r in head + [rb] + tail:
        r.event.wait(10.0)
        assert r.result is not None
    # b (submitted before the tail) must complete before the LAST tail
    # request — under the old re-queue-to-back it would finish dead last
    assert rb.t_done <= tail[-1].t_done
    assert eng.latency_percentiles()["n"] == 12
    eng.stop()


def test_stop_fails_parked_requests():
    """stop() must error out requests still sitting in the queue or the
    holdover deque — a submit_wait caller must not hang to its timeout."""
    eng = ServingEngine({"a": _search_fn(0.2), "b": _search_fn(0.2)},
                        max_batch=2, max_wait_ms=1.0)
    ra = eng.submit(np.ones(4, np.float32), corpus="a")
    parked = [eng.submit(np.ones(4, np.float32), corpus="b")
              for _ in range(3)]
    ra.event.wait(5.0)                    # first a-batch in flight/done
    eng.stop()
    for r in parked:
        assert r.event.wait(1.0)
        assert r.result is not None or r.error is not None
    eng.stop()                            # idempotent
    with pytest.raises(RuntimeError):     # dead loop accepts no work
        eng.submit(np.ones(4, np.float32))


def test_held_requests_preserve_per_corpus_fifo():
    eng = ServingEngine({"a": _search_fn(0.01), "b": _search_fn(0.01)},
                        max_batch=2, max_wait_ms=10.0)
    rs = []
    for corpus in ("a", "b", "a", "b", "b", "a"):
        rs.append((corpus, eng.submit(np.ones(4, np.float32),
                                      corpus=corpus)))
    for _, r in rs:
        r.event.wait(10.0)
        assert r.result is not None
    for corpus in ("a", "b"):
        done = [r.t_done for c, r in rs if c == corpus]
        assert done == sorted(done)       # FIFO within each corpus
    eng.stop()


# ---------------------------------------------------------------------------
# RetrievalService: per-corpus queues, concurrency, admission control
# ---------------------------------------------------------------------------


@pytest.fixture()
def service_pool(tmp_path, small_corpus, pq_artifacts):
    from repro.core.index_io import write_index
    from repro.core.vamana import build_vamana
    from repro.serving.pool import WarmIndexPool
    base, _, _ = small_corpus
    cents, codes = pq_artifacts
    paths = {}
    for i in range(2):
        sl = slice(i * 700, (i + 1) * 700)
        g = build_vamana(base[sl], R=12, L=24, seed=i)
        p = str(tmp_path / f"t{i}")
        write_index(p, vectors=base[sl], graph=g, centroids=cents,
                    codes=codes[sl], metric="l2", mode="aisaq")
        paths[f"t{i}"] = p
    pool = WarmIndexPool(paths, cache_bytes=256 << 10)
    yield pool
    pool.close()


def test_retrieval_service_multicorpus_integration(service_pool,
                                                   small_corpus):
    from repro.core.index_io import HostIndex
    from repro.serving.service import RetrievalService
    base, q, _ = small_corpus
    refs = {}
    for name, path in service_pool.paths.items():
        idx = HostIndex.load(path)
        refs[name], _ = idx.search_batch(q, 5, L=24)
        idx.close()
    svc = RetrievalService(service_pool, num_workers=2, max_batch=4,
                           max_wait_ms=1.0, L=24)
    reqs = [(f"t{i % 2}", i % len(q),
             svc.submit(q[i % len(q)], corpus=f"t{i % 2}", k=5))
            for i in range(16)]
    for name, qi, r in reqs:
        r.event.wait(10.0)
        assert r.error is None and r.result is not None
        np.testing.assert_array_equal(r.result, refs[name][qi])
    st = svc.stats()
    assert st["total_completed"] == 16
    for name in ("t0", "t1"):
        c = st["corpora"][name]
        assert c["completed"] == 8 and c["switches"] == 1
        assert c["p99_ms"] >= c["p50_ms"] > 0
        assert c["qps"] > 0
    assert st["pool"]["misses"] == 2      # one load per corpus, ever
    svc.stop()


def test_service_corpora_serve_concurrently():
    """Two corpora, two workers, a deliberately slow search: total wall
    time must be closer to ONE search than two (the ServingEngine this
    replaces serialized every corpus through one loop thread)."""
    from repro.serving.pool import WarmIndexPool
    from repro.serving.service import RetrievalService
    delay = 0.3

    def slow_fn(idx, queries, k):
        time.sleep(delay)
        return np.tile(np.arange(k)[None], (queries.shape[0], 1))

    pool = WarmIndexPool({"a": "/nonexistent-a", "b": "/nonexistent-b"})
    pool.pin = lambda name, share_centroids=True: (None, 0.0)  # no disk
    pool.unpin = lambda name, index=None: None
    svc = RetrievalService(pool, num_workers=2, max_wait_ms=1.0,
                           search_fn=slow_fn)
    t0 = time.perf_counter()
    ra = svc.submit(np.ones(4, np.float32), corpus="a", k=5)
    rb = svc.submit(np.ones(4, np.float32), corpus="b", k=5)
    ra.event.wait(5.0), rb.event.wait(5.0)
    wall = time.perf_counter() - t0
    assert ra.result is not None and rb.result is not None
    assert wall < 1.8 * delay             # overlapped, not serialized
    svc.stop()


def test_service_admission_control_rejects(service_pool, small_corpus):
    from repro.serving.service import BackpressureError, RetrievalService
    base, q, _ = small_corpus

    def stall(idx, queries, k):
        time.sleep(0.2)
        return np.zeros((queries.shape[0], k), np.int64)

    svc = RetrievalService(service_pool, num_workers=1, max_queue_depth=2,
                           max_wait_ms=0.5, search_fn=stall)
    rejected = 0
    for _ in range(12):
        try:
            svc.submit(q[0], corpus="t0", k=5)
        except BackpressureError as e:
            rejected += 1
            assert e.corpus == "t0" and e.limit == 2
    assert rejected > 0
    assert svc.stats()["total_rejected"] == rejected
    assert svc.stats()["corpora"]["t0"]["rejected"] == rejected
    svc.stop()


def test_service_unknown_corpus_and_stop_drains(service_pool, small_corpus):
    from repro.serving.service import RetrievalService
    base, q, _ = small_corpus
    svc = RetrievalService(service_pool, num_workers=1, max_wait_ms=0.5)
    with pytest.raises(KeyError, match="unknown corpus"):
        svc.submit(q[0], corpus="nope")
    svc.stop()
    with pytest.raises(RuntimeError):
        svc.submit(q[0], corpus="t0")


def test_service_submit_wait_timeout_raises(service_pool, small_corpus):
    from repro.serving.service import RetrievalService
    base, q, _ = small_corpus

    def stall(idx, queries, k):
        time.sleep(0.5)
        return np.zeros((queries.shape[0], k), np.int64)

    svc = RetrievalService(service_pool, num_workers=1, max_wait_ms=0.5,
                           search_fn=stall)
    with pytest.raises(TimeoutError):
        svc.submit_wait(q[0], corpus="t0", timeout=0.05)
    svc.stop()


# ---------------------------------------------------------------------------
# graceful close: drain, then typed rejection
# ---------------------------------------------------------------------------


def test_service_close_drains_then_rejects_typed(service_pool,
                                                 small_corpus):
    from repro.serving.service import RetrievalService, ServiceClosedError
    base, q, _ = small_corpus

    def slowish(idx, queries, k):
        time.sleep(0.05)
        return np.tile(np.arange(k)[None], (queries.shape[0], 1))

    svc = RetrievalService(service_pool, num_workers=1, max_batch=2,
                           max_wait_ms=0.5, search_fn=slowish)
    reqs = [svc.submit(q[0], corpus="t0", k=5) for _ in range(6)]
    svc.close(drain_s=10.0)
    # everything queued before close() COMPLETED (drained, not dropped)
    for r in reqs:
        assert r.event.is_set()
        assert r.error is None and r.result is not None
    # submits after close fail with the typed error, which subclasses
    # RuntimeError so existing except-RuntimeError callers still catch it
    with pytest.raises(ServiceClosedError):
        svc.submit(q[0], corpus="t0", k=5)
    assert issubclass(ServiceClosedError, RuntimeError)


def test_service_stop_fails_queued_with_typed_error(service_pool,
                                                    small_corpus):
    from repro.serving.service import RetrievalService, ServiceClosedError
    base, q, _ = small_corpus

    def stall(idx, queries, k):
        time.sleep(0.3)
        return np.zeros((queries.shape[0], k), np.int64)

    svc = RetrievalService(service_pool, num_workers=1, max_batch=1,
                           max_wait_ms=0.5, search_fn=stall)
    reqs = [svc.submit(q[0], corpus="t0", k=5) for _ in range(4)]
    svc.stop(timeout=1.0)
    failed = [r for r in reqs if r.error is not None]
    assert failed, "stop() left queued requests silently unresolved"
    for r in failed:
        assert isinstance(r.error, ServiceClosedError)


def test_service_stats_one_snapshot_with_pool(service_pool, small_corpus):
    """stats() returns ONE consistent snapshot: totals equal the sum of
    the per-corpus rows taken under the same lock hold, and the pool
    section (taken outside the service lock — the service never holds
    both) carries the journal-recovery map."""
    from repro.serving.service import RetrievalService
    base, q, _ = small_corpus
    svc = RetrievalService(service_pool, num_workers=2, max_wait_ms=0.5,
                           L=24)
    for i in range(8):
        svc.submit_wait(q[i % len(q)], corpus=f"t{i % 2}", k=5,
                        timeout=10.0)
    st = svc.stats()
    assert st["total_completed"] == sum(
        c["completed"] for c in st["corpora"].values()) == 8
    assert "recoveries" in st["pool"]       # clean boot: empty map
    assert st["pool"]["recoveries"] == {}
    svc.stop()
