import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pq


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(0)
    return rng.normal(size=(2000, 32)).astype(np.float32)


def test_kmeans_reduces_distortion(data):
    cb4 = pq.train_codebooks(jax.random.PRNGKey(0), data, m=4, iters=1)
    cb4b = pq.train_codebooks(jax.random.PRNGKey(0), data, m=4, iters=10)
    for cb_few, cb_more in [(cb4, cb4b)]:
        e1 = np.mean((np.asarray(pq.decode(cb_few, pq.encode(cb_few, data)))
                      - data) ** 2)
        e2 = np.mean((np.asarray(pq.decode(cb_more, pq.encode(cb_more, data)))
                      - data) ** 2)
        assert e2 <= e1 + 1e-6


def test_more_subquantizers_less_error(data):
    errs = []
    for m in (2, 8, 16):
        cb = pq.train_codebooks(jax.random.PRNGKey(0), data, m=m, iters=8)
        rec = np.asarray(pq.decode(cb, pq.encode(cb, data)))
        errs.append(np.mean((rec - data) ** 2))
    assert errs[0] > errs[1] > errs[2]


def test_adc_equals_exact_distance_to_decoded(data):
    """ADC(q, code) must EXACTLY equal ||q - decode(code)||^2 (l2)."""
    cb = pq.train_codebooks(jax.random.PRNGKey(0), data, m=8, iters=4)
    codes = pq.encode(cb, data[:100])
    q = data[500:503]
    lut = pq.build_lut(cb, q, metric="l2")
    d_adc = np.asarray(pq.adc(lut, codes))
    rec = np.asarray(pq.decode(cb, codes))
    d_exact = np.asarray(pq.exact_distances(q, rec, metric="l2"))
    np.testing.assert_allclose(d_adc, d_exact, rtol=2e-4, atol=1e-3)


def test_adc_mips(data):
    cb = pq.train_codebooks(jax.random.PRNGKey(1), data, m=8, iters=4)
    codes = pq.encode(cb, data[:64])
    q = data[100:102]
    lut = pq.build_lut(cb, q, metric="mips")
    d_adc = np.asarray(pq.adc(lut, codes))
    rec = np.asarray(pq.decode(cb, codes))
    np.testing.assert_allclose(d_adc, -(np.asarray(q) @ rec.T), rtol=1e-4,
                               atol=1e-3)


def test_adc_onehot_matches_gather(data):
    cb = pq.train_codebooks(jax.random.PRNGKey(0), data, m=8, iters=2)
    codes = pq.encode(cb, data[:50])
    lut = pq.build_lut(cb, data[:3], metric="l2")
    a = np.asarray(pq.adc(lut, codes))
    b = np.asarray(pq.adc_onehot(lut, codes))
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-4)


def test_groundtruth_bruteforce(data):
    gt = pq.groundtruth(data[:5], data[:200], 3)
    d = ((data[:5][:, None] - data[None, :200]) ** 2).sum(-1)
    expect = np.argsort(d, axis=1)[:, :3]
    assert (gt == expect).mean() > 0.99
