"""Multi-process serving tier: wire protocol, shard math, supervisor,
and the scatter-gather router's degradation contract."""
import os
import signal
import socket
import struct
import subprocess
import tempfile
import time

import numpy as np
import pytest

from repro.core import shard_math as SM
from repro.core.faults import FlakySocket, ProcessKiller, SocketFaultPlan
from repro.serving import protocol as proto
from repro.serving.router import (DegradedServiceError, LocalShardClient,
                                  ShardRouter, ShardUnavailableError,
                                  SocketShardClient)

# ---------------------------------------------------------------------------
# wire protocol
# ---------------------------------------------------------------------------


def test_frame_roundtrip():
    a, b = socket.socketpair()
    try:
        proto.send_frame(a, proto.T_SEARCH, {"k": 5, "corpus": "x"},
                         b"\x00\x01\xfe payload")
        rtype, header, blob = proto.recv_frame(b)
        assert rtype == proto.T_SEARCH
        assert header == {"k": 5, "corpus": "x"}
        assert blob == b"\x00\x01\xfe payload"
    finally:
        a.close(), b.close()


def test_query_and_result_roundtrip():
    q = np.random.default_rng(0).standard_normal(48).astype(np.float32)
    h, blob = proto.encode_query(q, corpus="c", k=7, req_id=3,
                                 deadline_s=1.5)
    q2 = proto.decode_query(h, blob)
    np.testing.assert_array_equal(q, q2)
    assert (h["corpus"], h["k"], h["req_id"]) == ("c", 7, 3)
    ids = np.array([5, -1, 9], np.int64)
    dists = np.array([0.25, np.inf, 1.5], np.float32)
    h2, b2 = proto.encode_result(ids, dists, req_id=3)
    ids2, dists2 = proto.decode_result(h2, b2)
    np.testing.assert_array_equal(ids, ids2)
    np.testing.assert_array_equal(dists, dists2)
    assert ids2.dtype == np.int64 and dists2.dtype == np.float32


def test_corrupt_byte_poisons_frame():
    raw = bytearray(proto.pack_frame(proto.T_RESULT, {"req_id": 1},
                                     b"x" * 64))
    raw[len(raw) // 2] ^= 0x40          # one flipped bit mid-frame
    a, b = socket.socketpair()
    try:
        a.sendall(bytes(raw))
        with pytest.raises(proto.ProtocolError):
            proto.recv_frame(b)
    finally:
        a.close(), b.close()


def test_closed_peer_raises_connection_closed():
    a, b = socket.socketpair()
    a.close()
    try:
        with pytest.raises(proto.ConnectionClosed):
            proto.recv_frame(b)
    finally:
        b.close()


def test_oversized_length_field_rejected_before_allocation():
    a, b = socket.socketpair()
    try:
        # a header CLAIMING a 1 GB payload must be rejected from the
        # length field alone — never trusted into an allocation
        a.sendall(struct.pack("<IBII", 0x31515341, proto.T_SEARCH,
                              0, 1 << 30))
        with pytest.raises(proto.ProtocolError, match="corrupt length"):
            proto.recv_frame(b)
    finally:
        a.close(), b.close()


def test_flaky_socket_corruption_caught_by_crc():
    """Every bit flip the wire shim injects must surface as a typed
    ProtocolError — never as silently wrong data."""
    a, b = socket.socketpair()
    flaky = FlakySocket(a, SocketFaultPlan(seed=3, corrupt_rate=1.0,
                                           max_faults=1))
    try:
        proto.send_frame(flaky, proto.T_SEARCH, {"k": 1}, b"z" * 256)
        with pytest.raises(proto.ProtocolError):
            proto.recv_frame(b)
        assert flaky.injected_corrupt == 1
    finally:
        a.close(), b.close()


# ---------------------------------------------------------------------------
# shard math (host twin of the device all-gather merge)
# ---------------------------------------------------------------------------


def test_contiguous_shards_matches_array_split():
    for n, s in ((10, 3), (7, 7), (20000, 4), (5, 1)):
        asn = SM.contiguous_shards(n, s)
        sizes = [len(part) for part in np.array_split(np.arange(n), s)]
        assert list(asn.counts) == sizes
        assert asn.n == n and asn.n_shards == s
        lo = 0
        for sh in range(s):
            b = asn.bounds(sh)
            assert b == (lo, lo + sizes[sh])
            lo += sizes[sh]
        for sh in range(s):
            blo, bhi = asn.bounds(sh)
            assert asn.shard_of(blo) == sh
            assert asn.shard_of(bhi - 1) == sh


def test_merge_topk_matches_global_sort():
    rng = np.random.default_rng(1)
    parts_ids = [rng.permutation(100)[:8] + 100 * s for s in range(3)]
    parts_dists = [rng.standard_normal(8).astype(np.float32)
                   for _ in range(3)]
    ids, dists = SM.merge_topk(parts_ids, parts_dists, 10)
    all_ids = np.concatenate(parts_ids)
    all_d = np.concatenate(parts_dists)
    order = np.lexsort((all_ids, all_d))[:10]
    np.testing.assert_array_equal(ids, all_ids[order])
    np.testing.assert_array_equal(dists, all_d[order])


def test_merge_topk_pads_and_drops_invalid():
    ids, dists = SM.merge_topk([np.array([3, -1])],
                               [np.array([0.5, 0.1], np.float32)], 4)
    np.testing.assert_array_equal(ids, [3, -1, -1, -1])
    assert dists[0] == np.float32(0.5) and np.isinf(dists[1:]).all()


# ---------------------------------------------------------------------------
# router degradation over in-process shards
# ---------------------------------------------------------------------------


def _const_client(ids, dists, name="c"):
    return LocalShardClient(
        lambda q, k, i=np.asarray(ids), d=np.asarray(dists): (i, d), name)


def _failing_client(name="dead"):
    def fn(q, k):
        raise OSError("shard is on fire")
    return LocalShardClient(fn, name)


def test_router_full_coverage_merges_exactly():
    c0 = _const_client([1, 3], [0.1, 0.3])
    c1 = _const_client([2, 4], [0.2, 0.4])
    r = ShardRouter([c0, c1], min_shards=1)
    out = r.search(np.zeros(4, np.float32), 3)
    assert not out.partial and out.shards_answered == 2
    np.testing.assert_array_equal(out.ids, [1, 2, 3])
    st = r.stats()
    assert st["queries"] == 1 and st["full"] == 1 and st["partial"] == 0
    r.close()


def test_router_partial_on_one_dead_shard():
    c0 = _const_client([1, 3], [0.1, 0.3])
    r = ShardRouter([c0, _failing_client()], min_shards=1,
                    hedge_retry=False)
    out = r.search(np.zeros(4, np.float32), 3)
    assert out.partial and out.failed_shards == [1]
    np.testing.assert_array_equal(out.ids, [1, 3, -1])
    assert r.stats()["shard_failures"] == 1
    r.close()


def test_router_quorum_rejects_cleanly():
    r = ShardRouter([_failing_client("a"), _failing_client("b")],
                    min_shards=1, hedge_retry=False)
    with pytest.raises(DegradedServiceError) as ei:
        r.search(np.zeros(4, np.float32), 3)
    assert ei.value.answered == 0 and ei.value.min_shards == 1
    assert r.stats()["rejected"] == 1
    r.close()

    r2 = ShardRouter([_const_client([1], [0.1]), _failing_client()],
                     min_shards=2, hedge_retry=False)
    with pytest.raises(DegradedServiceError):
        r2.search(np.zeros(4, np.float32), 3)
    r2.close()


def test_router_hedged_retry_skips_shards_reported_down():
    calls = []

    def fn(q, k):
        calls.append(1)
        raise OSError("nope")

    r = ShardRouter([_const_client([1], [0.1]),
                     LocalShardClient(fn, "down")],
                    min_shards=1, hedge_retry=True,
                    endpoints_fn=lambda: ["/ok", None])
    out = r.search(np.zeros(4, np.float32), 2)
    assert out.partial and len(calls) == 1      # no knock on a known corpse
    assert r.stats()["retries"] == 0
    r.close()


def test_local_client_wraps_errors_as_unavailable():
    with pytest.raises(ShardUnavailableError, match="on fire"):
        _failing_client().search(np.zeros(2, np.float32), 1)


# ---------------------------------------------------------------------------
# ProcessKiller drill primitive
# ---------------------------------------------------------------------------


def test_process_killer_fires_exactly_once_at_tick():
    p = subprocess.Popen(["sleep", "60"])
    try:
        k = ProcessKiller(at=3).arm(p.pid)
        assert not k.tick() and not k.tick()
        assert p.poll() is None
        assert k.tick()                 # third tick fires
        assert k.killed_pid == p.pid
        assert not k.tick()             # never fires twice
        assert p.wait(5.0) == -signal.SIGKILL
    finally:
        if p.poll() is None:
            p.kill()
            p.wait()


# ---------------------------------------------------------------------------
# cluster end-to-end: spawn, serve, kill, respawn
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def shard_dirs(tmp_path_factory, small_corpus, pq_artifacts):
    """Two global-label shards over the shared test corpus's prefix —
    the cluster twin of the pool fixture's sub-corpora."""
    from repro.core.index_io import write_index
    from repro.core.vamana import build_vamana
    base, _, _ = small_corpus
    cents, codes = pq_artifacts
    asn = SM.contiguous_shards(1000, 2)
    root = tmp_path_factory.mktemp("cluster_shards")
    shards = []
    for s in range(2):
        lo, hi = asn.bounds(s)
        g = build_vamana(base[lo:hi], R=12, L=24, seed=s)
        p = str(root / f"shard{s}")
        write_index(p, vectors=base[lo:hi], graph=g, centroids=cents,
                    codes=codes[lo:hi], metric="l2", mode="aisaq",
                    labels=np.arange(lo, hi, dtype=np.int64))
        shards.append({"default": p})
    return shards


def _refs(shards, queries, k):
    from repro.core.index_io import HostIndex
    from repro.serving.engine import make_host_search_dist_fn
    ids, dists = [], []
    for corpora in shards:
        idx = HostIndex.load(corpora["default"], cache_bytes=1 << 20)
        i, d = make_host_search_dist_fn(idx, L=24, w=4)(queries, k)
        ids.append(i), dists.append(d)
        idx.close()
    return ids, dists


def test_cluster_kill_respawn_end_to_end(shard_dirs, small_corpus):
    """The kill-a-worker drill in miniature: full-coverage answers are
    bit-identical to single-process references, a SIGKILLed worker
    degrades the router to clean partials over the survivor, and the
    supervisor's respawn restores bit-identical full coverage."""
    from repro.serving.cluster import ShardCluster
    _, q, _ = small_corpus
    q, k = q[:6], 5
    ref_ids, ref_dists = _refs(shard_dirs, q, k)
    sd = tempfile.mkdtemp(prefix="clus-test")
    cluster = ShardCluster(shard_dirs, socket_dir=sd, L=24, w=4,
                           cache_bytes=1 << 20, heartbeat_s=0.1,
                           backoff_s=0.2, stable_s=1.0)
    cluster.start()
    router = ShardRouter([SocketShardClient(p)
                          for p in cluster.endpoints()],
                         min_shards=1, shard_deadline_s=3.0,
                         endpoints_fn=cluster.endpoints)
    try:
        # full coverage: bit-identical to the merged references
        for qi in range(len(q)):
            out = router.search(q[qi], k)
            assert not out.partial
            eids, edists = SM.merge_topk(
                [ref_ids[s][qi] for s in (0, 1)],
                [ref_dists[s][qi] for s in (0, 1)], k)
            np.testing.assert_array_equal(out.ids, eids)
            np.testing.assert_array_equal(out.dists, edists)

        # SIGKILL shard 1 mid-service: requests must RESOLVE — full
        # (hedge won the race with the respawn) or clean partial —
        # and a partial must appear before recovery completes
        os.kill(cluster.pid(1), signal.SIGKILL)
        saw_partial, deadline = None, time.monotonic() + 10.0
        while saw_partial is None and time.monotonic() < deadline:
            out = router.search(q[0], k)
            if out.partial:
                saw_partial = out
        assert saw_partial is not None, "kill never degraded coverage"
        assert saw_partial.failed_shards == [1]
        eids, edists = SM.merge_topk([ref_ids[0][0]], [ref_dists[0][0]],
                                     k)
        np.testing.assert_array_equal(saw_partial.ids, eids)
        np.testing.assert_array_equal(saw_partial.dists, edists)

        # respawn restores bit-identical full coverage
        assert cluster.wait_healthy(20.0)
        deadline = time.monotonic() + 10.0
        out = router.search(q[1], k)
        while out.partial and time.monotonic() < deadline:
            out = router.search(q[1], k)
        assert not out.partial
        eids, edists = SM.merge_topk(
            [ref_ids[s][1] for s in (0, 1)],
            [ref_dists[s][1] for s in (0, 1)], k)
        np.testing.assert_array_equal(out.ids, eids)
        np.testing.assert_array_equal(out.dists, edists)
        assert cluster.stats()["shards"][1]["restarts"] >= 1
    finally:
        router.close()
        cluster.stop()


def test_cluster_worker_stats_over_the_wire(shard_dirs, small_corpus):
    from repro.serving.cluster import ShardCluster
    _, q, _ = small_corpus
    sd = tempfile.mkdtemp(prefix="clus-stats")
    cluster = ShardCluster(shard_dirs[:1], socket_dir=sd, L=24, w=4,
                           cache_bytes=1 << 20)
    cluster.start()
    try:
        router = ShardRouter([SocketShardClient(cluster.endpoints()[0])],
                             endpoints_fn=cluster.endpoints)
        router.search(q[0], 5)
        st = cluster.worker_stats(0)
        assert st is not None and st["total_completed"] >= 1
        assert "pool" in st and "recoveries" in st["pool"]
        router.close()
        top = cluster.stats()
        assert top["serving"] == 1 and top["quarantined"] == 0
    finally:
        cluster.stop()
