"""Hypothesis property tests on the system's core invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.chunk_layout import B_NUM, ChunkLayout


@settings(max_examples=60, deadline=None)
@given(dim=st.integers(4, 512).map(lambda x: x * 4),
       R=st.integers(1, 128),
       m=st.integers(1, 64).map(lambda x: x * 4),
       dt=st.sampled_from(["float32", "uint8"]))
def test_layout_invariants(dim, R, m, dt):
    d = ChunkLayout("diskann", dim, dt, R, m)
    a = ChunkLayout("aisaq", dim, dt, R, m)
    # paper formulas hold for ALL parameterizations
    assert a.chunk_bytes == d.chunk_bytes + R * m
    assert d.chunk_bytes == d.b_full + B_NUM * (R + 1)
    # a chunk never straddles a block boundary
    for i in (0, 1, 17):
        off = a.file_offset(i)
        if a.chunk_bytes <= a.block_bytes:
            assert off // a.block_bytes == \
                (off + a.chunk_bytes - 1) // a.block_bytes
        else:
            assert off % a.block_bytes == 0
    # io_bytes covers the chunk and is block-aligned
    assert a.io_bytes >= a.chunk_bytes or a.nodes_per_block > 0
    assert a.io_bytes % a.block_bytes == 0
    # device strides lane-aligned, fields word-aligned
    assert a.device_stride % 128 == 0
    assert a.dev_off_ids % 4 == 0 and a.dev_off_pq % 4 == 0
    assert a.device_stride >= a.chunk_bytes


@settings(max_examples=20, deadline=None)
@given(n=st.integers(8, 64), m=st.integers(1, 8),
       seed=st.integers(0, 2 ** 16))
def test_adc_identity(n, m, seed):
    """ADC distance == exact distance to the decoded vector — exact PQ
    decomposition property (any codes, any codebooks)."""
    from repro.core import pq
    rng = np.random.default_rng(seed)
    dsub = 4
    cents = rng.normal(size=(m, 256, dsub)).astype(np.float32)
    codes = rng.integers(0, 256, (n, m)).astype(np.uint8)
    q = rng.normal(size=(1, m * dsub)).astype(np.float32)
    cb = pq.PQCodebooks(jnp.asarray(cents))
    lut = pq.build_lut(cb, jnp.asarray(q), metric="l2")
    d_adc = np.asarray(pq.adc(lut, jnp.asarray(codes)))[0]
    rec = np.asarray(pq.decode(cb, jnp.asarray(codes)))
    d_exact = ((rec - q) ** 2).sum(-1)
    np.testing.assert_allclose(d_adc, d_exact, rtol=5e-4, atol=5e-4)


@settings(max_examples=20, deadline=None)
@given(e=st.integers(1, 200), n=st.integers(2, 50),
       mult=st.sampled_from([8, 32, 512]), seed=st.integers(0, 999))
def test_edge_padding_is_noop(e, n, mult, seed):
    """pad_edges dummies must not change GNN aggregation (exactness of the
    out-of-range-drop trick)."""
    from repro.models.gnn import pad_edges, _aggregate
    rng = np.random.default_rng(seed)
    edges = rng.integers(0, n, (e, 2)).astype(np.int32)
    x = rng.normal(size=(n, 5)).astype(np.float32)
    padded = pad_edges(edges, mult, n)
    assert padded.shape[0] % mult == 0
    a1 = np.asarray(_aggregate(jnp.asarray(x)[edges[:, 0]],
                               jnp.asarray(edges[:, 1]), n, "sum"))
    xp = jnp.asarray(x)[jnp.clip(jnp.asarray(padded[:, 0]), 0, n - 1)]
    a2 = np.asarray(_aggregate(xp, jnp.asarray(padded[:, 1]), n, "sum"))
    np.testing.assert_allclose(a1, a2, rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(b=st.integers(1, 4), s=st.integers(2, 40), seed=st.integers(0, 99))
def test_flash_attention_rowstochastic(b, s, seed):
    """Attention output rows are convex combinations of V rows: outputs lie
    within [min(V), max(V)] per feature."""
    from repro.models.layers import flash_attention
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, s, 2, 8)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, 2, 8)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, 2, 8)), jnp.float32)
    out = np.asarray(flash_attention(q, k, v, causal=True, block_q=16,
                                     block_kv=16))
    lo = np.asarray(v).min() - 1e-4
    hi = np.asarray(v).max() + 1e-4
    assert (out >= lo).all() and (out <= hi).all()


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 2048), seed=st.integers(0, 999))
def test_int8_grad_compression_error_bound(n, seed):
    from repro.distributed.compression import dequantize_int8, quantize_int8
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n,)).astype(np.float32)) * 10
    scale = jnp.max(jnp.abs(x))
    y = dequantize_int8(quantize_int8(x, scale), scale)
    assert float(jnp.abs(y - x).max()) <= float(scale) / 127 + 1e-5
