import jax
import jax.numpy as jnp
import numpy as np

from repro.core.device_index import beam_search_device, from_arrays
from repro.core.index_io import HostIndex, recall_at


def _device_search(small_corpus, built_graph, pq_artifacts, mode):
    base, q, gt = small_corpus
    cents, codes = pq_artifacts
    idx, lay = from_arrays(base, built_graph, cents, codes, mode=mode)
    ids, d, hops = beam_search_device(idx, jnp.asarray(q), k=10, L=40,
                                      layout=lay, metric="l2")
    return idx, np.asarray(ids), int(hops)


def test_device_recall_both_modes(small_corpus, built_graph, pq_artifacts):
    base, q, gt = small_corpus
    for mode in ("aisaq", "diskann"):
        _, ids, hops = _device_search(small_corpus, built_graph,
                                      pq_artifacts, mode)
        assert recall_at(ids, gt, 1) >= 0.9, mode
        assert recall_at(ids, gt, 10) >= 0.8, mode
        assert 0 < hops


def test_device_matches_host_results(small_corpus, built_graph, pq_artifacts,
                                     index_dirs):
    """Device while-loop search finds (nearly) the same neighbors as the
    faithful host implementation of Algorithm 1."""
    base, q, gt = small_corpus
    host = HostIndex.load(index_dirs["aisaq"])
    h_ids, _ = host.search_batch(q, 10, L=40)
    host.close()
    _, d_ids, _ = _device_search(small_corpus, built_graph, pq_artifacts,
                                 "aisaq")
    overlap = np.mean([len(set(a) & set(b)) / 10.0
                       for a, b in zip(h_ids, d_ids)])
    assert overlap >= 0.9


def test_fast_tier_residency_invariant(small_corpus, built_graph,
                                       pq_artifacts):
    """The paper's invariant, tier-shifted: AiSAQ fast-tier bytes are
    independent of N; DiskANN's grow with N (the (N, m) code table)."""
    base, q, _ = small_corpus
    cents, codes = pq_artifacts
    idx_a, _ = from_arrays(base, built_graph, cents, codes, mode="aisaq")
    idx_d, _ = from_arrays(base, built_graph, cents, codes, mode="diskann")
    n, m = codes.shape
    fa = idx_a.fast_tier_bytes(1, 40)
    fd = idx_d.fast_tier_bytes(1, 40)
    assert fd - fa == n * m * codes.dtype.itemsize
    # halving N halves only the DiskANN side
    half = n // 2
    g = np.clip(built_graph[:half], -1, half - 1)
    idx_a2, _ = from_arrays(base[:half], g, cents, codes[:half], mode="aisaq")
    assert idx_a2.fast_tier_bytes(1, 40) == fa
