"""Compat-shim and layering guarantees for the three-layer core split.

The PR that decomposed `core/index_io.py` into `core/adc.py` (numerics),
`core/traversal.py` (beam engine) and a slimmed `core/index_io.py`
(format + lifecycle) promises external users of the old monolith that
every pre-split import path keeps resolving — and that the new layering
introduced no import cycles inside `repro.core`.
"""
import ast
import importlib
import os
import pkgutil

import pytest

_OLD_MONOLITH_SYMBOLS = [
    # ADC numerics (now core.adc)
    "np_build_lut", "np_build_lut_batch", "np_adc",
    "np_quantize_lut", "np_adc_int8", "np_host_lut_int8",
    # engine surface (now core.traversal)
    "SearchStats", "recall_at",
    # never moved
    "HostIndex", "write_index",
]


def test_index_io_reexports_every_monolith_symbol():
    """`from repro.core.index_io import np_* / SearchStats / ...` — the
    pre-split import paths — must all still resolve."""
    index_io = importlib.import_module("repro.core.index_io")
    for name in _OLD_MONOLITH_SYMBOLS:
        assert hasattr(index_io, name), f"index_io lost {name}"


def test_reexports_are_the_same_objects():
    """The shim re-exports the REAL objects, not copies: isinstance checks
    and monkeypatching through either path stay coherent."""
    from repro.core import adc, index_io, traversal
    for name in ("np_build_lut", "np_build_lut_batch", "np_adc",
                 "np_quantize_lut", "np_adc_int8", "np_host_lut_int8"):
        assert getattr(index_io, name) is getattr(adc, name), name
    assert index_io.SearchStats is traversal.SearchStats
    assert index_io.recall_at is traversal.recall_at


def test_dynamic_reexports_survive():
    """core.dynamic's public surface (monolith era) still imports."""
    from repro.core.dynamic import np_adc, np_build_lut  # noqa: F401
    from repro.core.dynamic import SearchStats  # noqa: F401


def _core_import_graph():
    """Module-level intra-package import edges of repro.core, via ast (no
    execution): module -> set of repro.core siblings it imports."""
    import repro.core as core_pkg
    pkg_dir = os.path.dirname(core_pkg.__file__)
    names = {m.name for m in pkgutil.iter_modules([pkg_dir])}
    graph = {}
    for name in names:
        with open(os.path.join(pkg_dir, f"{name}.py")) as f:
            tree = ast.parse(f.read())
        deps = set()
        for node in ast.walk(tree):
            # only MODULE-LEVEL imports create import-time cycles; imports
            # inside functions are lazy and explicitly allowed
            if not isinstance(node, (ast.Import, ast.ImportFrom)):
                continue
            if any(not isinstance(p, ast.Module)
                   for p in _parents(tree, node)):
                continue
            mods = []
            if isinstance(node, ast.Import):
                mods = [a.name for a in node.names]
            elif node.module:
                mods = [node.module]
            for mod in mods:
                parts = mod.split(".")
                if parts[:2] == ["repro", "core"] and len(parts) > 2 \
                        and parts[2] in names:
                    deps.add(parts[2])
        graph[name] = deps - {name}
    return graph


def _parents(tree, target):
    """Chain of ancestor nodes of `target` inside `tree`."""
    chain = []

    def walk(node, path):
        if node is target:
            chain.extend(path)
            return True
        for child in ast.iter_child_nodes(node):
            if walk(child, path + [node]):
                return True
        return False

    walk(tree, [])
    return chain


def test_core_has_no_import_cycles():
    """DFS over the module-level import graph of repro.core: any cycle
    (e.g. index_io <-> traversal importing each other eagerly) would make
    the split's import order fragile for external users."""
    graph = _core_import_graph()
    # sanity: the expected layering edges exist at all
    assert "adc" in graph["traversal"]
    assert {"adc", "traversal"} <= graph["index_io"]

    WHITE, GREY, BLACK = 0, 1, 2
    color = {n: WHITE for n in graph}
    stack_trace = []

    def dfs(n):
        color[n] = GREY
        stack_trace.append(n)
        for d in sorted(graph.get(n, ())):
            if color[d] == GREY:
                cycle = stack_trace[stack_trace.index(d):] + [d]
                pytest.fail("import cycle in repro.core: "
                            + " -> ".join(cycle))
            if color[d] == WHITE:
                dfs(d)
        stack_trace.pop()
        color[n] = BLACK

    for n in sorted(graph):
        if color[n] == WHITE:
            dfs(n)


def test_every_core_module_imports_cleanly():
    """Each repro.core module imports on its own (no hidden ordering
    dependence introduced by the split)."""
    import repro.core as core_pkg
    pkg_dir = os.path.dirname(core_pkg.__file__)
    for m in pkgutil.iter_modules([pkg_dir]):
        importlib.import_module(f"repro.core.{m.name}")
