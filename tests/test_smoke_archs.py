"""Required deliverable (f): REDUCED-config smoke test per assigned arch —
one forward/train step on CPU, asserting output shapes + no NaNs.

The reduction shrinks depth/width/experts/tables/graphs but preserves every
structural feature (GQA ratios, qk-norm, SWA, chunked-global, shared+routed
experts, cross layers, multi-hot, fanouts...).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, ASSIGNED_ARCHS
from repro.configs.base import MoEConfig

RNG = np.random.default_rng(0)


def reduced_lm(cfg):
    moe = cfg.moe
    if moe is not None:
        moe = dataclasses.replace(moe, n_experts=min(8, moe.n_experts),
                                  d_expert=64,
                                  d_shared=64 if moe.d_shared else 0)
    return cfg.scaled(n_layers=4 if cfg.attention == "chunked_global" else 2,
                      d_model=64,
                      n_heads=max(2, cfg.n_heads // 8),
                      n_kv_heads=max(1, cfg.n_kv_heads // 8),
                      head_dim=16, d_ff=96, vocab_size=512,
                      window=min(cfg.window, 32) if cfg.window else 0,
                      moe=moe, dtype="float32")


def reduced_rec(cfg):
    emb = min(cfg.embed_dim, 16)
    bot = tuple(min(x, 32) for x in cfg.bot_mlp)
    if bot:
        bot = bot[:-1] + (emb,)     # DLRM invariant: bot_mlp[-1] == embed_dim
    return cfg.scaled(vocab_sizes=tuple(min(v, 1000) for v in
                                        cfg.vocab_sizes[:6]),
                      embed_dim=emb,
                      bot_mlp=bot,
                      top_mlp=tuple(min(x, 32) for x in cfg.top_mlp),
                      mlp=tuple(min(x, 32) for x in cfg.mlp),
                      seq_len=min(cfg.seq_len, 16) if cfg.seq_len else 0)


def _finite(x):
    return bool(jnp.isfinite(x.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch_id", [a for a in ASSIGNED_ARCHS
                                     if get_arch(a).family == "lm"])
def test_lm_arch_smoke(arch_id):
    from repro.models import transformer as T
    arch = get_arch(arch_id)
    cfg = reduced_lm(arch.model)
    p = T.init_lm(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(RNG.integers(0, cfg.vocab_size, (2, 64)), jnp.int32)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    loss, mets = jax.jit(lambda p, b: T.lm_loss(p, b, cfg))(p, batch)
    assert _finite(loss) and float(loss) > 0
    # decode one token against a warm cache
    cache = T.init_cache(cfg, 2, 96)
    logits, cache2 = T.lm_decode_step(p, cache, toks[:, 0], jnp.int32(3), cfg)
    assert logits.shape == (2, cfg.vocab_size) and _finite(logits)


def test_gnn_arch_smoke():
    from repro.models import gnn as G
    arch = get_arch("graphsage-reddit")
    cfg = arch.model.scaled(d_hidden=32, n_classes=7)
    p = G.init_gnn(jax.random.PRNGKey(0), cfg, d_feat=24)
    n, e = 80, 300
    batch = {"feats": jnp.asarray(RNG.normal(size=(n, 24)), jnp.float32),
             "edges": jnp.asarray(RNG.integers(0, n, (e, 2)), jnp.int32),
             "labels": jnp.asarray(RNG.integers(0, 7, (n,)), jnp.int32),
             "mask": jnp.ones((n,), jnp.float32)}
    loss, mets = jax.jit(lambda p, b: G.gnn_full_loss(p, b, cfg))(p, batch)
    assert _finite(loss)
    logits = G.gnn_full_forward(p, batch["feats"], batch["edges"], cfg)
    assert logits.shape == (n, 7) and _finite(logits)
    # minibatch path with the real sampler
    samp = G.NeighborSampler.from_edges(np.asarray(batch["edges"]), n)
    blocks = samp.sample_blocks(np.arange(8), arch.model.sample_sizes[:2],
                                np.asarray(batch["feats"]))
    out = G.gnn_minibatch_forward(p, blocks, cfg)
    assert out.shape == (8, 7) and _finite(out)


@pytest.mark.parametrize("arch_id", [a for a in ASSIGNED_ARCHS
                                     if get_arch(a).family == "recsys"])
def test_recsys_arch_smoke(arch_id):
    from repro.models import recsys as R
    arch = get_arch(arch_id)
    cfg = reduced_rec(arch.model)
    p = R.init_recsys(jax.random.PRNGKey(0), cfg)
    B = 8
    if cfg.kind == "sasrec":
        V, S = cfg.vocab_sizes[0], cfg.seq_len
        batch = {"seq": jnp.asarray(RNG.integers(0, V, (B, S)), jnp.int32),
                 "pos_items": jnp.asarray(RNG.integers(0, V, (B, S)), jnp.int32),
                 "neg_items": jnp.asarray(RNG.integers(0, V, (B, S)), jnp.int32),
                 "seq_mask": jnp.ones((B, S), jnp.float32),
                 "target": jnp.asarray(RNG.integers(0, V, (B,)), jnp.int32)}
    else:
        batch = {"sparse": jnp.asarray(
            RNG.integers(0, 99, (B, cfg.n_sparse, cfg.multi_hot)), jnp.int32),
            "label": jnp.asarray(RNG.integers(0, 2, (B,)), jnp.int32)}
        if cfg.n_dense:
            batch["dense"] = jnp.asarray(RNG.normal(size=(B, cfg.n_dense)),
                                         jnp.float32)
    loss, mets = jax.jit(lambda p, b: R.rec_loss(p, b, cfg))(p, batch)
    assert _finite(loss)
    rb = {**batch, "cand_ids": jnp.arange(100, dtype=jnp.int32)}
    ids, vals = R.retrieval_topk(p, rb, cfg, k=10)
    assert ids.shape == (B if cfg.kind != "sasrec" else B, 10)
    assert _finite(vals)


def test_all_assigned_archs_registered():
    assert len(ASSIGNED_ARCHS) == 10
    for a in ASSIGNED_ARCHS:
        arch = get_arch(a)
        assert len(arch.shapes) == 4
        assert arch.source
