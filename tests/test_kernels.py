"""Per-kernel interpret-mode validation against the pure-jnp oracles,
sweeping shapes and dtypes (required deliverable (c))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.chunk_layout import ChunkLayout, pack_chunks_device
from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("nq,d,m,metric", [
    (1, 32, 4, "l2"), (3, 64, 16, "l2"), (5, 128, 32, "mips"),
    (2, 96, 8, "l2"), (4, 256, 64, "mips"),
])
def test_pq_lut_sweep(nq, d, m, metric):
    q = RNG.normal(size=(nq, d)).astype(np.float32)
    cents = RNG.normal(size=(m, 256, d // m)).astype(np.float32)
    a = np.asarray(ops.build_lut(q, cents, metric=metric,
                                 backend="pallas_interpret"))
    b = np.asarray(ref.pq_lut_ref(jnp.asarray(q), jnp.asarray(cents),
                                  metric=metric))
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("nq,n,m,code_dt", [
    (1, 100, 8, np.uint8), (2, 700, 16, np.uint8), (3, 64, 32, np.int32),
    (1, 1500, 4, np.uint8),
])
def test_pq_adc_sweep(nq, n, m, code_dt):
    lut = RNG.random(size=(nq, m, 256)).astype(np.float32)
    codes = RNG.integers(0, 256, size=(n, m)).astype(code_dt)
    a = np.asarray(ops.adc(jnp.asarray(lut), jnp.asarray(codes),
                           backend="pallas_interpret"))
    b = np.asarray(ops.adc(jnp.asarray(lut), jnp.asarray(codes),
                           backend="ref"))
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("dt,metric,R,m,dim", [
    ("float32", "l2", 8, 8, 32), ("float32", "mips", 24, 16, 64),
    ("uint8", "l2", 12, 8, 48), ("uint8", "l2", 52, 32, 128),
])
def test_fused_hop_sweep(dt, metric, R, m, dim):
    N = 100
    lay = ChunkLayout("aisaq", dim, dt, R, m)
    if dt == "uint8":
        vecs = RNG.integers(0, 255, (N, dim)).astype(np.uint8)
    else:
        vecs = RNG.normal(size=(N, dim)).astype(np.float32)
    adj = RNG.integers(-1, N, (N, R)).astype(np.int32)
    codes = RNG.integers(0, 256, (N, m)).astype(np.uint8)
    words = jnp.asarray(np.ascontiguousarray(
        pack_chunks_device(vecs, adj, codes, lay)).view(np.int32)
        .reshape(N, -1))
    fids = jnp.asarray(RNG.integers(-1, N, (2, 4)).astype(np.int32))
    qs = jnp.asarray(RNG.normal(size=(2, dim)).astype(np.float32))
    cents = jnp.asarray(RNG.normal(size=(m, 256, dim // m))
                        .astype(np.float32))
    lut = ref.pq_lut_ref(qs, cents, metric=metric)
    e1, i1, d1 = ops.fused_hop(words, fids, lut, qs, layout=lay,
                               metric=metric, backend="pallas_interpret")
    e2, i2, d2 = ops.fused_hop(words, fids, lut, qs, layout=lay,
                               metric=metric, backend="ref")
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    for a, b in ((e1, e2), (d1, d2)):
        a, b = np.asarray(a), np.asarray(b)
        fin = np.isfinite(a)
        assert (fin == np.isfinite(b)).all()
        scale = np.abs(b[fin]).max() + 1e-6
        np.testing.assert_allclose(a[fin] / scale, b[fin] / scale, atol=2e-6)


@pytest.mark.parametrize("nq,c,d,metric", [
    (1, 64, 32, "l2"), (3, 1000, 128, "l2"), (2, 500, 64, "mips"),
])
def test_rerank_sweep(nq, c, d, metric):
    q = RNG.normal(size=(nq, d)).astype(np.float32)
    cand = RNG.normal(size=(c, d)).astype(np.float32)
    a = np.asarray(ops.rerank(q, cand, metric=metric,
                              backend="pallas_interpret"))
    b = np.asarray(ops.rerank(q, cand, metric=metric, backend="ref"))
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("nq,n,m", [(2, 500, 16), (1, 200, 32)])
def test_pq_adc_int8_error_bound(nq, n, m):
    """§Perf adc-int8: |err| <= m*max|lut|/127 and top-k ranking preserved."""
    from repro.kernels.pq_adc import pq_adc_q8
    lut = RNG.random((nq, m, 256)).astype(np.float32) * 3
    codes = RNG.integers(0, 256, (n, m)).astype(np.uint8)
    a = np.asarray(pq_adc_q8(jnp.asarray(lut), jnp.asarray(codes),
                             interpret=True))
    b = np.asarray(ops.adc(jnp.asarray(lut), jnp.asarray(codes),
                           backend="ref"))
    bound = m * np.abs(lut).max() / 127
    assert np.abs(a - b).max() <= bound + 1e-3
    top_a = set(np.argsort(a[0])[:10].tolist())
    top_b = set(np.argsort(b[0])[:10].tolist())
    assert len(top_a & top_b) >= 9


def test_fused_hop_int8_variant():
    """§Perf adc-int8 in the fused hop kernel: error bound + identical ids."""
    from repro.core.chunk_layout import ChunkLayout, pack_chunks_device
    from repro.kernels.chunk_adc import fused_hop
    N, d, R, m = 150, 64, 24, 16
    lay = ChunkLayout("aisaq", d, "float32", R, m)
    vecs = RNG.normal(size=(N, d)).astype(np.float32)
    adj = RNG.integers(-1, N, (N, R)).astype(np.int32)
    codes = RNG.integers(0, 256, (N, m)).astype(np.uint8)
    words = jnp.asarray(np.ascontiguousarray(
        pack_chunks_device(vecs, adj, codes, lay)).view(np.int32)
        .reshape(N, -1))
    fids = jnp.asarray(RNG.integers(-1, N, (2, 4)).astype(np.int32))
    qs = jnp.asarray(RNG.normal(size=(2, d)).astype(np.float32))
    cents = jnp.asarray(RNG.normal(size=(m, 256, d // m)).astype(np.float32))
    lut = ref.pq_lut_ref(qs, cents, metric="l2")
    _, i1, d1 = fused_hop(words, fids, lut, qs, layout=lay, metric="l2",
                          interpret=True, quantized=True)
    _, i2, d2 = fused_hop(words, fids, lut, qs, layout=lay, metric="l2",
                          interpret=True, quantized=False)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    fin = np.isfinite(np.asarray(d2))
    err = np.abs(np.asarray(d1)[fin] - np.asarray(d2)[fin]).max()
    assert err <= m * float(jnp.abs(lut).max()) / 127 + 1e-3


def test_ref_matches_numpy_twin():
    """jnp refs vs the numpy host implementations (pq.np_* twins)."""
    from repro.core.index_io import np_adc, np_build_lut
    q = RNG.normal(size=(48,)).astype(np.float32)
    cents = RNG.normal(size=(12, 256, 4)).astype(np.float32)
    codes = RNG.integers(0, 256, (20, 12)).astype(np.uint8)
    lut_np = np_build_lut(cents, q, "l2")
    lut_j = np.asarray(ref.pq_lut_ref(jnp.asarray(q[None]),
                                      jnp.asarray(cents), metric="l2"))[0]
    np.testing.assert_allclose(lut_np, lut_j, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(
        np_adc(lut_np, codes),
        np.asarray(ref.pq_adc_ref(jnp.asarray(lut_np), jnp.asarray(codes))),
        rtol=1e-5, atol=1e-4)
