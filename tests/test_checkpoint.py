import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.ckpt import (AsyncCheckpointer, latest_step, restore,
                                   save)


def _tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "nested": {"b": jnp.ones((5,), jnp.bfloat16),
                       "c": [jnp.zeros((2, 2)), jnp.asarray(3)]}}


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    save(str(tmp_path), t, step=7)
    assert latest_step(str(tmp_path)) == 7
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    r = restore(str(tmp_path), like)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), t, r)


def test_latest_step_picks_newest(tmp_path):
    t = _tree()
    save(str(tmp_path), t, step=3)
    save(str(tmp_path), t, step=12)
    assert latest_step(str(tmp_path)) == 12
    r = restore(str(tmp_path), t, step=3)        # explicit older step works
    assert r["a"].shape == (3, 4)


def test_async_checkpointer_overlap(tmp_path):
    ck = AsyncCheckpointer()
    t = {"w": jnp.ones((512, 512))}
    ck.save(str(tmp_path), t, step=1)
    ck.wait()
    assert latest_step(str(tmp_path)) == 1
    # value snapshotted at save() call even if "training" continues
    t2 = restore(str(tmp_path), t)
    np.testing.assert_array_equal(np.asarray(t2["w"]), np.ones((512, 512)))


def test_atomic_publish_no_partial(tmp_path):
    t = _tree()
    p = save(str(tmp_path), t, step=5)
    assert p.endswith("step_00000005")
    import os
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))
