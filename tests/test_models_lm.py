import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LMConfig, MoEConfig
from repro.models import layers as L
from repro.models import transformer as T

CFG = LMConfig(name="tiny", n_layers=2, d_model=48, n_heads=4, n_kv_heads=2,
               head_dim=12, d_ff=96, vocab_size=256, qk_norm=True,
               tie_embeddings=True, dtype="float32")


def test_decode_matches_prefill():
    """Autoregressive consistency: decoding t tokens step-by-step must give
    the same final logits as a full prefill — validates cache, rope
    positions and masking in one shot."""
    p = T.init_lm(jax.random.PRNGKey(0), CFG)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 9), 0, 256)
    pre = T.lm_prefill(p, toks, CFG)                 # logits after last tok
    cache = T.init_cache(CFG, 2, 16)
    for t in range(toks.shape[1]):
        logits, cache = T.lm_decode_step(p, cache, toks[:, t],
                                         jnp.int32(t), CFG)
    np.testing.assert_allclose(np.asarray(pre), np.asarray(logits),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("attn,window", [("sliding", 6),
                                         ("chunked_global", 8)])
def test_decode_matches_prefill_windowed(attn, window):
    cfg = CFG.scaled(attention=attn, window=window, global_every=2,
                     qk_norm=False)
    p = T.init_lm(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 13), 0, 256)
    pre = T.lm_prefill(p, toks, cfg)
    cache = T.init_cache(cfg, 1, 16)
    for t in range(toks.shape[1]):
        logits, cache = T.lm_decode_step(p, cache, toks[:, t],
                                         jnp.int32(t), cfg)
    np.testing.assert_allclose(np.asarray(pre), np.asarray(logits),
                               rtol=2e-3, atol=2e-3)


def test_flash_matches_dense_attention():
    B, S, H, KVH, hd = 2, 65, 4, 2, 16
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32) * 0.3
    k = jnp.asarray(rng.normal(size=(B, S, KVH, hd)), jnp.float32) * 0.3
    v = jnp.asarray(rng.normal(size=(B, S, KVH, hd)), jnp.float32)
    out = L.flash_attention(q, k, v, causal=True, block_q=16, block_kv=32)
    # dense reference
    G = H // KVH
    qr = q.reshape(B, S, KVH, G, hd)
    s = jnp.einsum("bqkgd,btkd->bkgqt", qr, k) * hd ** -0.5
    mask = jnp.tril(jnp.ones((S, S), bool))
    s = jnp.where(mask[None, None, None], s, -1e30)
    ref = jnp.einsum("bkgqt,btkd->bqkgd", jax.nn.softmax(s, -1), v)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.reshape(B, S, H, hd)),
                               rtol=2e-4, atol=2e-4)


def test_flash_vjp_matches_naive_grads():
    B, S, H, KVH, hd = 1, 48, 2, 2, 8
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KVH, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KVH, hd)), jnp.float32)
    f1 = lambda *a: (L.flash_attention(*a, causal=True, window=16, block_q=16,
                                       block_kv=16, skip_blocks=False) ** 2).sum()
    f2 = lambda *a: (L.flash_attention_vjp(*a, jnp.int32(0), True, 16, False,
                                           16, 16) ** 2).sum()
    g1 = jax.grad(f1, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                                   atol=1e-4)


def test_training_reduces_loss():
    """End-to-end: a few AdamW steps on a repeating pattern must cut loss."""
    from repro.optim.adamw import make_optimizer
    cfg = CFG.scaled(n_layers=2)
    p = T.init_lm(jax.random.PRNGKey(0), cfg)
    opt_init, opt_update = make_optimizer(lambda s: 1e-2, weight_decay=0.0)
    st = opt_init(p)
    toks = jnp.tile(jnp.arange(16, dtype=jnp.int32)[None], (4, 4))
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    @jax.jit
    def step(p, st):
        (l, _), g = jax.value_and_grad(lambda p: T.lm_loss(p, batch, cfg),
                                       has_aux=True)(p)
        p2, st2, _ = opt_update(g, st, p)
        return p2, st2, l

    losses = []
    for _ in range(12):
        p, st, l = step(p, st)
        losses.append(float(l))
    assert losses[-1] < losses[0] * 0.7


def test_moe_balance_and_shapes():
    from repro.models.moe import init_moe, moe_apply
    mc = MoEConfig(n_experts=6, top_k=2, d_expert=32, n_shared_experts=1,
                   d_shared=32)
    p = init_moe(jax.random.PRNGKey(0), 48, mc, jnp.float32,
                 n_pad_experts=2)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(64, 48)),
                    jnp.float32)
    out, aux = moe_apply(p, x, mc, n_pad_experts=2)
    assert out.shape == x.shape and jnp.isfinite(out).all()
    assert float(aux) >= 0
    # padding experts must never receive tokens: router logits -inf
    logits = x @ p["router"]
    probs = jax.nn.softmax(jnp.where(jnp.arange(8) >= 6, -1e30, logits))
    assert float(probs[:, 6:].max()) < 1e-6


def test_moe_capacity_drop_is_bounded():
    from repro.models.moe import init_moe, moe_apply
    mc = MoEConfig(n_experts=4, top_k=1, d_expert=16, capacity_factor=8.0)
    p = init_moe(jax.random.PRNGKey(0), 16, mc, jnp.float32)
    x = jnp.asarray(np.random.default_rng(1).normal(size=(32, 16)),
                    jnp.float32)
    # gigantic capacity => nothing dropped => output must be nonzero for
    # every token (each token got its expert)
    out, _ = moe_apply(p, x, mc)
    assert (jnp.abs(out).sum(-1) > 0).all()
