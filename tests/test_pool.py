"""WarmIndexPool: byte-budgeted LRU of open HostIndex handles.

Covers the multi-tenant serving PR's pool invariants: budget-driven
eviction, pin/unpin under concurrent searches, shared-centroid dedup
accounting, and the IndexManager budget-for-one compat wrapper.
"""
import threading

import numpy as np
import pytest

from repro.core.index_io import HostIndex
from repro.serving.pool import WarmIndexPool

CACHE = 256 << 10      # small per-handle block-cache budget for tests


@pytest.fixture(scope="module")
def corpora_dirs(tmp_path_factory, small_corpus, pq_artifacts):
    """Three sub-corpora sharing ONE PQ-centroid set (paper Table 4)."""
    from repro.core.index_io import write_index
    from repro.core.vamana import build_vamana
    base, _, _ = small_corpus
    cents, codes = pq_artifacts
    root = tmp_path_factory.mktemp("pool_corpora")
    paths = {}
    for i in range(3):
        sl = slice(i * 500, (i + 1) * 500)
        g = build_vamana(base[sl], R=12, L=24, seed=i)
        p = str(root / f"c{i}")
        write_index(p, vectors=base[sl], graph=g, centroids=cents,
                    codes=codes[sl], metric="l2", mode="aisaq")
        paths[f"c{i}"] = p
    return paths


def _budget_for(paths, n_slots):
    """Byte budget that fits exactly `n_slots` handles + shared centroids."""
    pool = WarmIndexPool(paths, cache_bytes=CACHE)
    pool.ensure("c0")
    per = pool.entry_bytes("c0")
    cent = pool.centroid_bytes()
    pool.close()
    return cent + n_slots * per + per // 2


def test_pool_lru_eviction_under_budget(corpora_dirs):
    pool = WarmIndexPool(corpora_dirs, cache_bytes=CACHE,
                         budget_bytes=_budget_for(corpora_dirs, 2))
    pool.ensure("c0")
    pool.ensure("c1")
    assert pool.stats()["evictions"] == 0
    pool.ensure("c2")                       # c0 is LRU -> evicted
    assert pool.open_corpora() == ["c1", "c2"]
    s = pool.stats()
    assert s["evictions"] == 1 and s["misses"] == 3 and s["hits"] == 0
    # touching c1 protects it: c2 becomes the next victim
    pool.ensure("c1")
    assert pool.stats()["hits"] == 1
    pool.ensure("c0")
    assert pool.open_corpora() == ["c1", "c0"]
    assert pool.used_bytes() <= pool.budget_bytes
    pool.close()


def test_pool_pin_blocks_eviction(corpora_dirs):
    pool = WarmIndexPool(corpora_dirs, cache_bytes=CACHE,
                         budget_bytes=_budget_for(corpora_dirs, 1))
    idx0, load_s = pool.pin("c0")
    assert load_s > 0
    pool.ensure("c1")                       # over budget, but c0 is pinned
    assert "c0" in pool.open_corpora()      # survived: pinned handles stay
    assert pool.stats()["budget_overflow"] >= 1
    # the pinned handle is still usable (fd open, cache alive)
    assert idx0.resident_bytes() > 0 and idx0.fd >= 0
    pool.unpin("c0")                        # deferred eviction fires now
    assert pool.open_corpora() == ["c1"]
    assert pool.used_bytes() <= pool.budget_bytes
    pool.close()


def test_pool_shared_centroid_dedup(corpora_dirs):
    pool = WarmIndexPool(corpora_dirs, cache_bytes=CACHE)
    pool.ensure("c0")
    u1 = pool.used_bytes()
    pool.ensure("c1")
    pool.ensure("c2")
    # all three share ONE centroid array object...
    c0 = pool.peek("c0").centroids
    assert pool.peek("c1").centroids is c0
    assert pool.peek("c2").centroids is c0
    assert pool.stats()["centroid_shares"] == 2
    # ...and the pool charges it once: 3 handles cost far less than 3x
    assert pool.used_bytes() < 3 * u1
    assert pool.used_bytes() == u1 + 2 * pool.entry_bytes("c1")
    pool.close()


def test_pool_unknown_corpus_keyerror(corpora_dirs):
    pool = WarmIndexPool(corpora_dirs, cache_bytes=CACHE)
    with pytest.raises(KeyError, match=r"unknown corpus 'nope'.*c0.*c1.*c2"):
        pool.ensure("nope")
    with pytest.raises(KeyError, match="known corpora"):
        pool.pin("also-nope")
    pool.close()


def test_pool_concurrent_searches_with_eviction_pressure(corpora_dirs,
                                                         small_corpus):
    """Threads lease+search different corpora while the budget only fits
    two handles: every search must complete on a live handle (pins make
    eviction of in-flight indices impossible) and results must match a
    freshly-loaded reference."""
    base, q, _ = small_corpus
    refs = {}
    for name, path in corpora_dirs.items():
        idx = HostIndex.load(path)
        refs[name], _ = idx.search_batch(q, 5, L=24)
        idx.close()
    pool = WarmIndexPool(corpora_dirs, cache_bytes=CACHE,
                         budget_bytes=_budget_for(corpora_dirs, 2))
    errors = []

    def hammer(name):
        try:
            for _ in range(6):
                with pool.lease(name) as (idx, _load):
                    ids, _ = idx.search_batch(q, 5, L=24)
                    np.testing.assert_array_equal(ids, refs[name])
        except Exception as e:            # noqa: BLE001
            errors.append((name, e))

    threads = [threading.Thread(target=hammer, args=(n,))
               for n in corpora_dirs for _ in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(30)
    assert not errors, errors
    s = pool.stats()
    assert s["evictions"] > 0             # pressure was real
    assert not s["pinned"]                # every lease released its pin
    pool.close()


def test_pool_concurrent_same_corpus_single_flight(corpora_dirs):
    """Two threads pinning the same COLD corpus must trigger exactly one
    load (the second waits on the in-flight claim instead of duplicating
    the disk I/O)."""
    pool = WarmIndexPool(corpora_dirs, cache_bytes=CACHE)
    out = []
    barrier = threading.Barrier(2)

    def grab():
        barrier.wait()
        out.append(pool.pin("c0"))

    ts = [threading.Thread(target=grab) for _ in range(2)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(10)
    assert len(out) == 2
    assert out[0][0] is out[1][0]         # one handle, two pins
    s = pool.stats()
    assert s["misses"] == 1 and s["hits"] == 1
    assert pool.pinned("c0") == 2
    pool.unpin("c0"), pool.unpin("c0")
    pool.close()


# ---------------------------------------------------------------------------
# IndexManager compat wrapper (budget-for-one pool)
# ---------------------------------------------------------------------------


def test_index_manager_is_budget_for_one_pool(corpora_dirs, small_corpus):
    from repro.core.index_switch import IndexManager
    base, q, _ = small_corpus
    mgr = IndexManager(corpora_dirs)
    t0 = mgr.switch("c0")
    assert t0 > 0
    assert mgr.switch("c0") == 0.0        # already active
    ids, stats = mgr.search(q[0], 5, L=24)
    assert ids.shape == (5,)
    mgr.switch("c1")
    # budget-for-one: the pool never holds two handles
    assert mgr.pool.open_corpora() == ["c1"]
    assert mgr.active is mgr.pool.peek("c1")
    assert mgr.resident_bytes() > 0
    mgr.close()
    assert mgr.active is None


def test_index_manager_unknown_corpus_keyerror(corpora_dirs):
    from repro.core.index_switch import IndexManager
    mgr = IndexManager(corpora_dirs)
    with pytest.raises(KeyError, match=r"unknown corpus 'wiki'.*known "
                                       r"corpora.*c0"):
        mgr.switch("wiki")
    mgr.close()


def test_index_switch_module_has_no_function_local_imports():
    """Satellite: the old `switch()` hid `import json, os` in its body; the
    meta peek now lives in pool.py behind module-level imports."""
    import inspect

    from repro.core import index_switch
    from repro.serving import pool as pool_mod
    assert "import json" not in inspect.getsource(index_switch.IndexManager)
    src = inspect.getsource(pool_mod)
    body_src = inspect.getsource(pool_mod.WarmIndexPool)
    assert "import json" in src.split("class WarmIndexPool")[0]
    assert "import json" not in body_src


# -- zero-downtime swap ------------------------------------------------------

def test_swap_repoints_and_closes_idle_old(corpora_dirs):
    pool = WarmIndexPool({"live": corpora_dirs["c0"]}, cache_bytes=CACHE)
    pool.ensure("live")
    old = pool.peek("live")
    load_s = pool.swap("live", corpora_dirs["c1"])
    assert load_s > 0
    new = pool.peek("live")
    assert new is not old and new.path == corpora_dirs["c1"]
    assert old.fd == -1                      # idle old handle closed now
    s = pool.stats()
    assert s["swaps"] == 1 and s["retired"] == 0 and s["open"] == 1
    pool.close()


def test_swap_drains_inflight_lease_on_old_version(corpora_dirs):
    """A lease taken before the swap keeps its (old) handle alive and
    usable until IT releases; release closes the retired handle."""
    pool = WarmIndexPool({"live": corpora_dirs["c0"]}, cache_bytes=CACHE)
    old_idx, _ = pool.pin("live")
    pool.swap("live", corpora_dirs["c1"])
    assert pool.stats()["retired"] == 1
    assert old_idx.fd >= 0                   # still open for its reader
    q = np.zeros(old_idx.meta["dim"], np.float32)
    ids, _ = old_idx.search(q, 3, L=16)      # old version still serves
    assert len(ids) == 3
    # new leases meanwhile land on the new version
    with pool.lease("live") as (idx2, _):
        assert idx2 is not old_idx
    # identity-keyed release: the retired handle closes with its reader
    pool.unpin("live", index=old_idx)
    assert old_idx.fd == -1
    assert pool.stats()["retired"] == 0
    assert pool.peek("live").fd >= 0         # successor untouched
    pool.close()


def test_swap_shares_centroids_with_old_version(corpora_dirs):
    """c0 and c1 share a centroid hash: the swapped-in handle must reuse
    the pooled array, and retiring the old one must NOT drop it."""
    pool = WarmIndexPool({"live": corpora_dirs["c0"]}, cache_bytes=CACHE)
    pool.ensure("live")
    cents_before = pool.centroid_bytes()
    pool.swap("live", corpora_dirs["c1"])
    assert pool.centroid_bytes() == cents_before
    assert pool.stats()["centroid_shares"] >= 1
    # the live handle's centroids are usable (not a dangling buffer)
    q = np.zeros(pool.peek("live").meta["dim"], np.float32)
    ids, _ = pool.peek("live").search(q, 3, L=16)
    assert len(ids) == 3
    pool.close()


def test_swap_zero_dropped_requests(corpora_dirs):
    """Searches hammer the corpus across repeated swaps: every request
    completes with a full result set, none error or observe a closed
    handle (the acceptance drill for the serving layer)."""
    pool = WarmIndexPool({"live": corpora_dirs["c0"]}, cache_bytes=CACHE)
    pool.ensure("live")
    stop = threading.Event()
    errors, served = [], [0] * 4

    def hammer(slot):
        rng = np.random.default_rng(slot)
        while not stop.is_set():
            try:
                with pool.lease("live") as (idx, _):
                    q = rng.standard_normal(
                        idx.meta["dim"]).astype(np.float32)
                    ids, _ = idx.search(q, 5, L=24)
                    assert len(ids) == 5
                    served[slot] += 1
            except Exception as e:           # pragma: no cover
                errors.append(e)
                return

    threads = [threading.Thread(target=hammer, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    try:
        for i in range(6):                   # ping-pong c0 <-> c1
            pool.swap("live", corpora_dirs["c1" if i % 2 == 0 else "c0"])
    finally:
        stop.set()
        for t in threads:
            t.join(timeout=20)
    assert not errors, errors[0]
    assert sum(served) > 0
    s = pool.stats()
    assert s["swaps"] == 6
    pool.close()
    assert pool.stats()["retired"] == 0      # every reader drained


# ---------------------------------------------------------------------------
# journal-recovery surfacing + snapshot consistency
# ---------------------------------------------------------------------------


def test_pool_surfaces_journal_recovery_in_stats(corpora_dirs, tmp_path):
    """A corpus directory left with a non-empty WAL (previous writer
    crashed) must be routed through journal recovery at pool-load time,
    and the outcome — including how many torn-tail bytes were truncated
    — must appear in stats()["recoveries"] for serving telemetry."""
    import os
    import shutil

    from repro.core.wal import WAL_NAME
    crashed = str(tmp_path / "crashed")
    shutil.copytree(corpora_dirs["c0"], crashed)
    garbage = b"\xde\xad\xbe\xef" + b"\x00" * 33   # half a torn frame
    with open(os.path.join(crashed, WAL_NAME), "wb") as f:
        f.write(garbage)
    pool = WarmIndexPool({"crashed": crashed, "clean": corpora_dirs["c1"]},
                         cache_bytes=CACHE)
    pool.ensure("crashed")
    pool.ensure("clean")
    rec = pool.stats()["recoveries"]
    assert set(rec) == {"crashed"}          # clean corpora don't report
    assert rec["crashed"]["truncated_bytes"] == len(garbage)
    assert rec["crashed"]["rolled_back"] == 0
    assert rec["crashed"]["rolled_forward"] == 0
    # recovery truncated the journal on disk: the NEXT open is clean
    assert os.path.getsize(os.path.join(crashed, WAL_NAME)) == 0
    pool.close()


def test_pool_stats_is_one_consistent_snapshot(corpora_dirs):
    """Counters for each open handle come from ONE atomic snapshot and
    the aggregate rows are sums of exactly the per-corpus rows."""
    pool = WarmIndexPool(corpora_dirs, cache_bytes=CACHE)
    q = np.random.default_rng(0).standard_normal(48).astype(np.float32)
    for name in corpora_dirs:
        idx, _ = pool.pin(name)
        idx.search_batch(q[None], 3, L=24)
        pool.unpin(name, idx)
    s = pool.stats()
    assert set(s["caches"]) == set(corpora_dirs)
    for row in s["caches"].values():
        for key in ("read_retries", "crc_mismatches", "crc_rereads",
                    "demand_syscalls", "hit_rate"):
            assert key in row
    assert s["open"] == len(corpora_dirs)
    assert s["used_bytes"] > 0
    assert "recoveries" in s
    pool.close()
