"""Storage fault-tolerance layer: deterministic injection, CRC integrity,
retrying reads, traversal degradation, health-aware serving, crash-safe
index writes."""
import errno
import json
import os
import threading
import time

import numpy as np
import pytest

from repro.core.block_cache import BlockCache, RetryPolicy
from repro.core.faults import FaultInjector, FaultPlan
from repro.core.index_io import HostIndex, write_index
from repro.core.integrity import (CRC_SIDECAR, FORMAT_VERSION,
                                  CorruptBlockError, CorruptIndexError,
                                  block_checksums, _crc32)
from repro.core.traversal import search_batch, search_batch_ref
from repro.serving.pool import CorpusUnhealthyError, WarmIndexPool
from repro.serving.service import RetrievalService

# fast retries: tests should not sleep through production backoff
FAST_RETRY = RetryPolicy(attempts=6, backoff_s=1e-4, backoff_max_s=1e-3)


@pytest.fixture(scope="module")
def faulty_fixture(tmp_path_factory):
    """One small index + queries + entry-block coordinates, shared by the
    injection tests (each test opens its own handle/injector)."""
    from repro.core import pq
    from repro.core.vamana import build_vamana
    from repro.data.vectors import make_clustered, make_queries
    import jax
    base = make_clustered(900, 48, seed=3)
    q = make_queries(10, base, seed=4)
    g = build_vamana(base, R=16, L=32, seed=0)
    cb = pq.train_codebooks(jax.random.PRNGKey(0), base, m=12, iters=6)
    p = str(tmp_path_factory.mktemp("faulty") / "idx")
    write_index(p, vectors=base, graph=g, centroids=np.asarray(cb.centroids),
                codes=np.asarray(pq.encode(cb, base)), metric="l2",
                mode="aisaq")
    idx = HostIndex.load(p)
    ep = int(idx.meta["entry_points"][0])
    ep_block = idx.layout.file_offset(ep) // idx.layout.io_bytes
    io_bytes = idx.layout.io_bytes
    ref, _ = idx.search_batch(q, 5, L=24)
    idx.close()
    return p, q, ref, ep_block, io_bytes


# ---------------------------------------------------------------------------
# FaultInjector determinism
# ---------------------------------------------------------------------------


def test_injector_schedule_is_deterministic(tmp_path):
    f = tmp_path / "blob.bin"
    f.write_bytes(os.urandom(16 * 512))
    plan = dict(seed=11, eio_rate=0.3, eagain_rate=0.2, short_read_rate=0.2,
                corrupt_blocks={2: 3})

    def run():
        inj = FaultInjector(FaultPlan(**plan))
        fd = os.open(str(f), os.O_RDONLY)
        log = []
        try:
            for off in [0, 512, 1024, 0, 512, 1024, 2048, 1024]:
                buf = bytearray(512)
                try:
                    got = inj.preadv(fd, [buf], off)
                    log.append(("ok", got, bytes(buf)))
                except OSError as e:
                    log.append(("err", e.errno))
        finally:
            os.close(fd)
        return log, inj.stats()

    log1, st1 = run()
    log2, st2 = run()
    assert log1 == log2
    assert st1 == st2
    assert st1["calls"] == 8


def test_injector_retry_is_a_fresh_draw(tmp_path):
    """eio_rate=1.0 with max_faults=1: the first read fails, the retry of
    the SAME offset is a new draw past the budget and succeeds."""
    f = tmp_path / "blob.bin"
    payload = os.urandom(4 * 512)
    f.write_bytes(payload)
    inj = FaultInjector(FaultPlan(seed=0, eio_rate=1.0, max_faults=1))
    fd = os.open(str(f), os.O_RDONLY)
    try:
        buf = bytearray(512)
        with pytest.raises(OSError):
            inj.preadv(fd, [buf], 0)
        assert inj.preadv(fd, [buf], 0) == 512
        assert bytes(buf) == payload[:512]
    finally:
        os.close(fd)
    assert inj.stats()["injected_eio"] == 1


# ---------------------------------------------------------------------------
# Retry + CRC through the real read path
# ---------------------------------------------------------------------------


def test_retry_absorbs_transient_eio(faulty_fixture):
    p, q, ref, _, _ = faulty_fixture
    inj = FaultInjector(FaultPlan(seed=5, eio_rate=1.0, max_faults=1))
    idx = HostIndex.load(p, preadv=inj, retry=FAST_RETRY)
    ids, _ = idx.search_batch(q, 5, L=24)
    assert np.array_equal(ids, ref)
    assert inj.stats()["injected_eio"] == 1
    assert idx.cache.counters.read_retries >= 1
    idx.close()


def test_retry_gives_up_on_persistent_eio(faulty_fixture):
    p, q, _, _, _ = faulty_fixture
    inj = FaultInjector(FaultPlan(seed=5, eio_rate=1.0))   # every attempt
    idx = HostIndex.load(p, preadv=inj,
                         retry=RetryPolicy(attempts=2, backoff_s=1e-4))
    with pytest.raises(OSError) as ei:
        idx.search_batch(q, 5, L=24)
    assert ei.value.errno == errno.EIO
    idx.close()


def test_transient_corruption_healed_by_one_reread(faulty_fixture):
    p, q, ref, ep_block, _ = faulty_fixture
    inj = FaultInjector(FaultPlan(seed=5, corrupt_blocks={ep_block: 1}))
    idx = HostIndex.load(p, preadv=inj, retry=FAST_RETRY)
    ids, _ = idx.search_batch(q, 5, L=24)
    assert np.array_equal(ids, ref)
    c = idx.cache.counters
    assert c.crc_mismatches == 1 and c.crc_rereads == 1
    assert inj.stats()["injected_corrupt"] == 1
    idx.close()


def test_persistent_corruption_raises_corrupt_block(faulty_fixture):
    p, q, _, ep_block, _ = faulty_fixture
    inj = FaultInjector(FaultPlan(seed=5, corrupt_blocks={ep_block: -1}))
    idx = HostIndex.load(p, preadv=inj, retry=FAST_RETRY)
    with pytest.raises(CorruptBlockError) as ei:
        idx.search_batch(q, 5, L=24)
    assert isinstance(ei.value, OSError) and ei.value.errno == errno.EIO
    assert idx.cache.counters.crc_mismatches >= 1
    idx.close()


def test_on_disk_bitrot_detected(faulty_fixture, tmp_path):
    """Actual bytes flipped ON STORAGE (not in flight): the reread reads
    the same bad bytes, so the mismatch is persistent."""
    import shutil
    p, q, _, ep_block, io_bytes = faulty_fixture
    p2 = str(tmp_path / "rot")
    shutil.copytree(p, p2)
    cbin = os.path.join(p2, "chunks.bin")
    with open(cbin, "r+b") as f:
        f.seek(ep_block * io_bytes + 7)
        b = f.read(1)
        f.seek(ep_block * io_bytes + 7)
        f.write(bytes([b[0] ^ 0x40]))
    idx = HostIndex.load(p2)
    with pytest.raises(CorruptBlockError):
        idx.search_batch(q, 5, L=24)
    idx.close()
    # verification off: the rot is served silently (the legacy behavior)
    idx = HostIndex.load(p2, verify_checksums=False)
    idx.search_batch(q, 5, L=24)
    idx.close()


# ---------------------------------------------------------------------------
# Checksummed format + crash-safe writes
# ---------------------------------------------------------------------------


def test_checksummed_format_v3(faulty_fixture):
    p, _, _, _, io_bytes = faulty_fixture
    meta = json.load(open(os.path.join(p, "meta.json")))
    assert meta["format_version"] == FORMAT_VERSION == 3
    assert meta["crc_algo"] in ("crc32", "crc32c")
    crc = np.load(os.path.join(p, CRC_SIDECAR))
    payload = np.fromfile(os.path.join(p, "chunks.bin"), np.uint8)
    assert payload.size % io_bytes == 0
    assert np.array_equal(crc, block_checksums(payload, io_bytes, _crc32))
    assert not os.path.exists(p + ".tmp")
    assert not os.path.exists(p + ".old")


def test_legacy_dir_loads_without_verification(faulty_fixture, tmp_path):
    import shutil
    p, q, ref, _, _ = faulty_fixture
    p2 = str(tmp_path / "legacy")
    shutil.copytree(p, p2)
    os.remove(os.path.join(p2, CRC_SIDECAR))
    mp = os.path.join(p2, "meta.json")
    meta = json.load(open(mp))
    meta.pop("format_version"), meta.pop("crc_algo")
    json.dump(meta, open(mp, "w"))
    idx = HostIndex.load(p2)                     # auto: no sidecar, no CRC
    assert idx.cache.block_crc is None
    ids, _ = idx.search_batch(q, 5, L=24)
    assert np.array_equal(ids, ref)
    idx.close()
    with pytest.raises(CorruptIndexError):       # explicit demand fails
        HostIndex.load(p2, verify_checksums=True)


@pytest.mark.parametrize("damage", ["missing_meta", "truncated_meta",
                                    "future_version", "missing_chunks",
                                    "truncated_chunks"])
def test_loader_rejects_damaged_dirs(faulty_fixture, tmp_path, damage):
    import shutil
    p = faulty_fixture[0]
    p2 = str(tmp_path / damage)
    shutil.copytree(p, p2)
    mp = os.path.join(p2, "meta.json")
    if damage == "missing_meta":
        os.remove(mp)
    elif damage == "truncated_meta":
        raw = open(mp, "rb").read()
        open(mp, "wb").write(raw[:len(raw) // 2])
    elif damage == "future_version":
        meta = json.load(open(mp))
        meta["format_version"] = FORMAT_VERSION + 1
        json.dump(meta, open(mp, "w"))
    elif damage == "missing_chunks":
        os.remove(os.path.join(p2, "chunks.bin"))
    elif damage == "truncated_chunks":
        cbin = os.path.join(p2, "chunks.bin")
        with open(cbin, "r+b") as f:
            f.truncate(os.path.getsize(cbin) // 2)
    with pytest.raises(CorruptIndexError):
        HostIndex.load(p2)


def test_write_index_overwrite_is_atomic(faulty_fixture, tmp_path):
    """Rewriting an existing dir must leave no .tmp/.old residue and the
    new index must be complete and verified."""
    from repro.core import pq
    from repro.core.vamana import build_vamana
    from repro.data.vectors import make_clustered
    import jax
    base = make_clustered(300, 16, seed=9)
    g = build_vamana(base, R=8, L=16, seed=0)
    cb = pq.train_codebooks(jax.random.PRNGKey(1), base, m=4, iters=4)
    cents, codes = np.asarray(cb.centroids), np.asarray(pq.encode(cb, base))
    p = str(tmp_path / "twice")
    for _ in range(2):
        write_index(p, vectors=base, graph=g, centroids=cents, codes=codes,
                    metric="l2", mode="aisaq")
    assert not os.path.exists(p + ".tmp") and not os.path.exists(p + ".old")
    idx = HostIndex.load(p)
    assert idx.cache.block_crc is not None
    idx.search_batch(base[:4], 3, L=16)
    idx.close()


def test_dynamic_mutation_keeps_crc_coherent(tmp_path):
    """In-place writes + appends re-anchor the sidecar: reload after flush
    verifies every block, and searches on the mutated index pass CRC."""
    from repro.configs.base import IndexConfig
    from repro.core.build import build_index
    from repro.core.dynamic import DynamicHostIndex
    from repro.data.vectors import make_clustered
    base = make_clustered(500, 24, seed=7)
    cfg = IndexConfig(name="dyn", n_vectors=400, dim=24, R=12, pq_m=8,
                      build_L=24)
    p = str(tmp_path / "dyn")
    build_index(p, base[:400], cfg, mode="aisaq", seed=0)
    idx = DynamicHostIndex.load(p)
    assert idx.cache.block_crc is not None
    for i in range(30):
        idx.insert(base[400 + i])
    ids, _ = idx.search(base[410], 3, L=24)      # reads mutated blocks: CRC
    idx.flush()
    idx.close()
    idx2 = DynamicHostIndex.load(p)
    assert idx2.cache.block_crc is not None
    ids2, _ = idx2.search(base[410], 3, L=24)
    assert idx2.cache.counters.crc_mismatches == 0
    assert np.array_equal(ids, ids2)
    idx2.close()


# ---------------------------------------------------------------------------
# Oracle parity under injected faults
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("relabel", [False, True])
@pytest.mark.parametrize("adc_dtype", ["f32", "int8"])
@pytest.mark.parametrize("prefetch,pipeline", [(0, False), (4, False),
                                               (4, True)])
def test_faulty_parity_grid(small_corpus, built_graph, pq_artifacts,
                            tmp_path_factory, relabel, adc_dtype,
                            prefetch, pipeline):
    """Transient EIO + short reads absorbed by retries must leave every
    host configuration bit-identical to the fault-free scalar oracle."""
    base, q, _ = small_corpus
    cents, codes = pq_artifacts
    p = str(tmp_path_factory.mktemp("grid") / f"rl{int(relabel)}")
    write_index(p, vectors=base, graph=built_graph, centroids=cents,
                codes=codes, metric="l2", mode="aisaq", relabel=relabel)
    clean = HostIndex.load(p)
    ref, _ = search_batch_ref(clean, q, 5, L=24, adc_dtype=adc_dtype)
    clean.close()
    inj = FaultInjector(FaultPlan(seed=13, eio_rate=0.05,
                                  short_read_rate=0.05))
    idx = HostIndex.load(p, preadv=inj, retry=FAST_RETRY)
    ids, stats = search_batch(idx, q, 5, L=24, adc_dtype=adc_dtype,
                              prefetch=prefetch, pipeline=pipeline)
    assert np.array_equal(ids, ref)
    idx.close()


# ---------------------------------------------------------------------------
# Prefetch failure: degradation + waiter wakeup
# ---------------------------------------------------------------------------


def test_traversal_degrades_on_persistent_prefetch_failure(faulty_fixture):
    """Every background batch raising must flip the search to the serial
    demand path (SearchStats.degraded) without changing its answer."""
    p, q, ref, _, _ = faulty_fixture
    idx = HostIndex.load(p)

    def boom(batch, gap=0):
        raise RuntimeError("injected background failure")

    idx.cache._pf_read = boom
    ids, stats = search_batch(idx, q[:4], 5, L=24, prefetch=4, pipeline=True)
    assert np.array_equal(ids, ref[:4])
    # the joint batched traversal degrades as a whole; the flag (like
    # `pipelined`) is batch-level and reported on stats[0]
    assert stats[0].degraded == 1 and stats[0].pipelined == 1
    assert idx.cache.counters.prefetch_errors >= 1
    idx.close()


def test_stop_during_failed_prefetch_wakes_demand_waiters(faulty_fixture):
    """stop() racing a failing in-flight background read must not strand a
    demand fetch in its pending-wait: the waiter falls back to a direct
    read well before the bounded wait expires."""
    p, _, _, _, io_bytes = faulty_fixture
    fsize = os.path.getsize(os.path.join(p, "chunks.bin"))
    idx = HostIndex.load(p)
    cache = idx.cache
    real_pf_read = cache._pf_read

    def slow_boom(batch, gap=0):
        time.sleep(0.15)
        raise RuntimeError("injected slow background failure")

    cache._pf_read = slow_boom
    off = (min(4, fsize // io_bytes - 1)) * io_bytes
    assert cache.prefetch_async(np.asarray([off])) == 1
    expect = np.fromfile(os.path.join(p, "chunks.bin"), np.uint8,
                         count=io_bytes, offset=off)
    result = {}

    def demand():
        t0 = time.perf_counter()
        data, _, _ = cache.fetch(np.asarray([off]))
        result["wall"] = time.perf_counter() - t0
        result["data"] = data[0]

    t = threading.Thread(target=demand)
    t.start()
    time.sleep(0.02)                 # let the fetch enter its pending-wait
    cache.stop()                     # joins the worker; clears in-flight
    t.join(timeout=2.0)
    assert not t.is_alive(), "demand fetch stranded after stop()"
    assert np.array_equal(result["data"], expect)
    assert result["wall"] < 0.45     # woke before the bounded wait expired
    assert cache.counters.prefetch_errors == 1
    cache._pf_read = real_pf_read
    idx.close()


# ---------------------------------------------------------------------------
# Health-aware serving
# ---------------------------------------------------------------------------


def test_pool_circuit_breaker_lifecycle(tmp_path):
    pool = WarmIndexPool({"c": str(tmp_path)}, quarantine_after=3,
                         quarantine_cooldown_s=0.05,
                         quarantine_cooldown_max_s=0.5)
    pool.admit("c")                              # healthy passes
    for _ in range(2):
        pool.record_io_failure("c")
    pool.admit("c")                              # still below the threshold
    pool.record_io_failure("c")                  # third consecutive: opens
    assert pool.health("c")["state"] == "quarantined"
    with pytest.raises(CorpusUnhealthyError) as ei:
        pool.admit("c")
    assert ei.value.corpus == "c" and ei.value.retry_in_s >= 0
    time.sleep(0.06)
    pool.admit("c")                              # cooldown over: the probe
    assert pool.health("c")["state"] == "probing"
    with pytest.raises(CorpusUnhealthyError):    # only ONE probe admitted
        pool.admit("c")
    pool.record_io_failure("c")                  # probe failed: back off x2
    h = pool.health("c")
    assert h["state"] == "quarantined" and h["quarantines"] == 2
    assert h["cooldown_s"] == pytest.approx(0.1)
    time.sleep(0.11)
    pool.admit("c")
    pool.record_success("c")                     # probe succeeded: closed
    h = pool.health("c")
    assert h["state"] == "healthy" and h["recoveries"] == 1
    assert h["cooldown_s"] == pytest.approx(0.05)
    pool.admit("c")


def test_probe_timeout_rearms(tmp_path):
    pool = WarmIndexPool({"c": str(tmp_path)}, quarantine_after=1,
                         quarantine_cooldown_s=0.01, probe_timeout_s=0.05)
    pool.record_io_failure("c")
    time.sleep(0.02)
    pool.admit("c")                              # probe #1... then vanishes
    time.sleep(0.06)
    pool.admit("c")                              # stale probe re-armed
    pool.record_success("c")
    assert pool.health("c")["state"] == "healthy"


def test_service_quarantines_on_io_failures(faulty_fixture, tmp_path):
    """End-to-end: persistent corruption -> failed batches -> quarantine ->
    fail-fast submits -> half-open recovery once the region heals."""
    import shutil
    p, q, ref, ep_block, _ = faulty_fixture
    p2 = str(tmp_path / "served")
    shutil.copytree(p, p2)
    inj = FaultInjector(FaultPlan(seed=5, corrupt_blocks={ep_block: 4}))
    pool = WarmIndexPool({"c": p2}, preadv_factory=lambda n: inj,
                         quarantine_after=2, quarantine_cooldown_s=0.2)
    svc = RetrievalService(pool, num_workers=1, max_batch=4, L=24, w=4)
    errs = 0
    for i in range(2):                           # 2 failures x 2 reads each
        with pytest.raises(OSError):
            svc.submit_wait(q[0], corpus="c", k=5, timeout=10.0)
        errs += 1
    assert pool.health("c")["state"] == "quarantined"
    with pytest.raises(CorpusUnhealthyError):
        svc.submit_wait(q[0], corpus="c", k=5, timeout=10.0)
    assert svc.stats()["corpora"]["c"]["unhealthy_rejected"] == 1
    time.sleep(0.25)                             # cooldown; block healed
    r = svc.submit_wait(q[0], corpus="c", k=5, timeout=10.0)
    assert np.array_equal(np.asarray(r.result), ref[0, :5])
    h = pool.health("c")
    assert h["state"] == "healthy" and h["recoveries"] == 1
    st = svc.stats()["corpora"]["c"]
    assert st["errors"] == errs and st["completed"] == 1
    svc.stop()
    pool.close()


def test_request_deadline_expires_unserved(faulty_fixture):
    """A request whose deadline passes while queued is dropped at batch
    assembly (TimeoutError + `expired` telemetry), not served into the
    void and counted completed."""
    p, q, _, _, _ = faulty_fixture
    pool = WarmIndexPool({"c": p})

    def stall(idx, Q, k):
        time.sleep(0.3)
        return np.zeros((Q.shape[0], k), np.int64)

    svc = RetrievalService(pool, num_workers=1, max_batch=1,
                           max_wait_ms=0.1, search_fn=stall)
    a = svc.submit(q[0], corpus="c", k=5)        # occupies the one worker
    time.sleep(0.05)
    b = svc.submit(q[1], corpus="c", k=5, deadline_s=0.05)
    assert b.event.wait(5.0)
    assert isinstance(b.error, TimeoutError)
    a.event.wait(5.0)
    assert a.error is None
    st = svc.stats()
    assert st["corpora"]["c"]["expired"] == 1
    assert st["corpora"]["c"]["completed"] == 1
    assert st["total_expired"] == 1
    svc.stop()
    pool.close()
