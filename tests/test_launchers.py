"""CLI smoke tests for the launchers (build_index / serve / dryrun list)."""
import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_cli(args, timeout=520, env_extra=None):
    env = dict(os.environ, PYTHONPATH=SRC)
    env.update(env_extra or {})
    r = subprocess.run([sys.executable, "-m"] + args, env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, r.stdout + r.stderr[-2000:]
    return r.stdout


def test_build_index_cli(tmp_path):
    out = run_cli(["repro.launch.build_index", "--out", str(tmp_path / "i"),
                   "--n", "600", "--dim", "32", "--R", "12", "--pq-m", "8",
                   "--build-L", "24"])
    assert "built" in out
    assert os.path.exists(tmp_path / "i" / "meta.json")


def test_build_index_sharded_cli(tmp_path):
    out = run_cli(["repro.launch.build_index", "--out", str(tmp_path / "s"),
                   "--n", "600", "--dim", "32", "--R", "12", "--pq-m", "8",
                   "--build-L", "24", "--shards", "2"])
    assert "2 shard indices" in out
    assert os.path.exists(tmp_path / "s" / "shard1" / "meta.json")


def test_serve_cli_demo():
    out = run_cli(["repro.launch.serve", "--queries", "24",
                   "--max-batch", "8"])
    assert "qps" in out and "p99" in out


def test_train_cli():
    out = run_cli(["repro.launch.train", "--arch", "dcn-v2",
                   "--shape", "train_batch", "--steps", "8"])
    assert "final loss" in out
