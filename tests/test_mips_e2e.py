"""End-to-end MIPS (KILT-E5 regime, paper Table 1 column 3) + ablations."""
import numpy as np
import pytest

from repro.configs.base import IndexConfig
from repro.core import pq
from repro.core.build import build_index
from repro.core.index_io import HostIndex, recall_at
from repro.data.vectors import make_clustered, make_queries


@pytest.fixture(scope="module")
def mips_index(tmp_path_factory):
    base = make_clustered(1200, 64, seed=3)
    # KILT-E5 regime (paper Table 1): e5 embeddings are L2-normalized, so
    # MIPS == cosine on the unit sphere — normalize like the real corpus
    base = base / np.linalg.norm(base, axis=1, keepdims=True)
    q = make_queries(10, base, seed=4)
    gt = pq.groundtruth(q, base, 10, metric="mips")
    cfg = IndexConfig(name="mips", n_vectors=1200, dim=64, metric="mips",
                      R=20, pq_m=16, build_L=40)
    p = str(tmp_path_factory.mktemp("mips") / "idx")
    build_index(p, base, cfg, mode="aisaq", seed=0)
    return p, base, q, np.asarray(gt)


def test_mips_host_search(mips_index):
    p, base, q, gt = mips_index
    idx = HostIndex.load(p)
    # MIPS is non-metric: graph navigability is weaker than L2 (the paper
    # compensates with larger L on KILT-E5) — use L=96 and softer floors
    ids, stats = idx.search_batch(q, 10, L=96)
    assert recall_at(ids, gt, 1) >= 0.7
    assert recall_at(ids, gt, 10) >= 0.6
    idx.close()


def test_mips_device_matches_host(mips_index):
    import jax.numpy as jnp
    from repro.core.device_index import load_device_index, beam_search_device
    p, base, q, gt = mips_index
    didx, lay, metric = load_device_index(p)
    assert metric == "mips"
    ids, d, hops = beam_search_device(didx, jnp.asarray(q), k=10, L=96,
                                      layout=lay, metric="mips")
    assert recall_at(np.asarray(ids), gt, 1) >= 0.7


def test_beamwidth_ablation(mips_index):
    """Paper fixes w=4; hops should drop monotonically-ish with w while
    recall holds (beam search ablation)."""
    p, base, q, gt = mips_index
    idx = HostIndex.load(p)
    hops, recalls = [], []
    for w in (1, 2, 4, 8):
        ids, stats = idx.search_batch(q, 10, L=40, w=w)
        hops.append(np.mean([s.hops for s in stats]))
        recalls.append(recall_at(ids, gt, 10))
    assert hops[-1] < hops[0]
    assert min(recalls) >= max(recalls) - 0.1
    idx.close()
