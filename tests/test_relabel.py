"""Graph-locality relabeling: permutation invariants + full round-trip.

The tentpole invariant: build -> relabel -> write -> load -> search must
return the SAME original ids (and therefore identical recall) as the
unrelabeled index — the permutation only moves bytes on disk. Property
tests over random graphs run when hypothesis is installed (same policy as
test_property.py); the deterministic round-trip tests always run.
"""
import json
import os

import numpy as np
import pytest

from repro.core.index_io import HostIndex, recall_at, write_index
from repro.core.relabel import (apply_permutation, block_locality_score,
                                invert_permutation, locality_permutation)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # pragma: no cover - mirrors test_property.py
    HAVE_HYPOTHESIS = False


def _random_graph(rng, n, R):
    g = rng.integers(0, n, size=(n, R)).astype(np.int32)
    g[rng.random(size=g.shape) < 0.2] = -1      # ragged degrees
    return g


# ---------------------------------------------------------------------------
# permutation invariants
# ---------------------------------------------------------------------------


def test_locality_permutation_is_a_permutation():
    rng = np.random.default_rng(0)
    g = _random_graph(rng, 500, 8)
    o2n = locality_permutation(g, 4, entry_points=np.array([17]))
    assert sorted(o2n.tolist()) == list(range(500))
    n2o = invert_permutation(o2n)
    np.testing.assert_array_equal(n2o[o2n], np.arange(500))


def test_locality_permutation_improves_block_locality(built_graph):
    for npb in (2, 4, 8):
        o2n = locality_permutation(built_graph, npb, np.array([0]))
        before = block_locality_score(built_graph, None, npb)
        after = block_locality_score(built_graph, o2n, npb)
        assert after > before, f"npb={npb}: {after} <= {before}"


def test_locality_permutation_handles_disconnected_nodes():
    g = np.full((20, 3), -1, dtype=np.int32)    # fully disconnected
    o2n = locality_permutation(g, 4)
    assert sorted(o2n.tolist()) == list(range(20))


def test_apply_permutation_preserves_graph_semantics():
    rng = np.random.default_rng(1)
    n, R, d, m = 64, 6, 8, 4
    g = _random_graph(rng, n, R)
    vecs = rng.normal(size=(n, d)).astype(np.float32)
    codes = rng.integers(0, 256, size=(n, m)).astype(np.uint8)
    eps = np.array([3, 11])
    o2n = locality_permutation(g, 4, eps)
    vp, gp, cp, ep = apply_permutation(o2n, vecs, g, codes, eps)
    n2o = invert_permutation(o2n)
    for new in range(n):
        old = n2o[new]
        np.testing.assert_array_equal(vp[new], vecs[old])
        np.testing.assert_array_equal(cp[new], codes[old])
        # neighbor lists map edge-for-edge (order preserved, -1 kept)
        for j in range(R):
            if g[old, j] < 0:
                assert gp[new, j] == -1
            else:
                assert n2o[gp[new, j]] == g[old, j]
    np.testing.assert_array_equal(n2o[ep], eps)


# ---------------------------------------------------------------------------
# full round-trip: relabeled index == original index, in original labels
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def relabeled_dirs(tmp_path_factory, small_corpus, built_graph,
                   pq_artifacts):
    base, _, _ = small_corpus
    cents, codes = pq_artifacts
    root = tmp_path_factory.mktemp("relabeled")
    paths = {}
    for mode in ("aisaq", "diskann"):
        for relabel in (False, True):
            p = str(root / f"{mode}_{'rl' if relabel else 'plain'}")
            write_index(p, vectors=base, graph=built_graph, centroids=cents,
                        codes=codes, metric="l2", mode=mode, relabel=relabel)
            paths[(mode, relabel)] = p
    return paths


def test_relabeled_meta_records_id_map(relabeled_dirs, small_corpus):
    base, _, _ = small_corpus
    rl_dir = relabeled_dirs[("aisaq", True)]
    meta = json.load(open(os.path.join(rl_dir, "meta.json")))
    assert meta["relabeled"] is True
    id_map = np.load(os.path.join(rl_dir, "id_map.npy"))
    assert sorted(id_map.tolist()) == list(range(len(base)))
    # the O(N) map lives in the sidecar, NOT meta.json — the ~4 KiB
    # meta.json fast-index-switch property (paper §4.4) must survive
    assert os.path.getsize(os.path.join(rl_dir, "meta.json")) < 4096
    plain_dir = relabeled_dirs[("aisaq", False)]
    plain = json.load(open(os.path.join(plain_dir, "meta.json")))
    assert "relabeled" not in plain
    assert not os.path.exists(os.path.join(plain_dir, "id_map.npy"))


def test_relabeled_search_returns_original_ids(relabeled_dirs, small_corpus):
    """Both modes, batch + ref paths: relabeled results are bit-identical
    to the unrelabeled index once mapped back — relabeling is invisible."""
    base, q, gt = small_corpus
    for mode in ("aisaq", "diskann"):
        plain = HostIndex.load(relabeled_dirs[(mode, False)])
        rl = HostIndex.load(relabeled_dirs[(mode, True)])
        assert rl.new_to_old is not None and plain.new_to_old is None
        ids_p, _ = plain.search_batch(q, 10, L=40)
        ids_r, _ = rl.search_batch(q, 10, L=40)
        np.testing.assert_array_equal(ids_p, ids_r)
        ref_r, _ = rl.search_batch_ref(q, 10, L=40)
        np.testing.assert_array_equal(ids_r, ref_r)
        assert recall_at(ids_r, gt, 10) == recall_at(ids_p, gt, 10)
        plain.close(), rl.close()


def test_relabeled_search_with_prefetch_identical(relabeled_dirs,
                                                  small_corpus):
    base, q, gt = small_corpus
    rl = HostIndex.load(relabeled_dirs[("aisaq", True)])
    ids0, _ = rl.search_batch(q, 10, L=40)
    rl.cache.wait_prefetch()
    rl.cache.clear()
    ids1, stats = rl.search_batch(q, 10, L=40, prefetch=4)
    rl.cache.wait_prefetch()
    np.testing.assert_array_equal(ids0, ids1)
    assert stats[0].prefetch_issued > 0       # speculation actually ran
    rl.close()


def test_relabeled_device_loader_restores_original_space(relabeled_dirs):
    """load_device_index undoes the permutation: device arrays (and hence
    device search ids) are bit-identical to loading the plain index."""
    from repro.core.device_index import load_device_index
    idx_p, lay_p, met_p = load_device_index(relabeled_dirs[("aisaq", False)])
    idx_r, lay_r, met_r = load_device_index(relabeled_dirs[("aisaq", True)])
    assert lay_p == lay_r and met_p == met_r
    np.testing.assert_array_equal(np.asarray(idx_p.chunk_words),
                                  np.asarray(idx_r.chunk_words))


def test_dynamic_index_accepts_relabeled(relabeled_dirs, tmp_path,
                                         small_corpus):
    """Streaming ingest understands relabeled dirs: inserts append fresh
    labels past the original space and stay findable under them."""
    import shutil
    from repro.core.dynamic import DynamicHostIndex
    base, _, _ = small_corpus
    dst = str(tmp_path / "rl_dyn")
    shutil.copytree(relabeled_dirs[("aisaq", True)], dst)
    idx = DynamicHostIndex.load(dst)
    try:
        assert idx.new_to_old is not None
        n0 = idx.meta["n"]
        rng = np.random.default_rng(0)
        v = (base[0] + 0.05 * rng.standard_normal(base.shape[1])
             ).astype(np.float32)
        label = idx.insert(v)
        assert label == n0                 # fresh, past the permutation
        ids, _ = idx.search(v, 5, L=40)
        assert label in ids.tolist()
        idx.flush()
    finally:
        idx.close()


# ---------------------------------------------------------------------------
# property-style over random graphs (skipped without hypothesis)
# ---------------------------------------------------------------------------


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(n=st.integers(16, 300), R=st.integers(1, 12),
           npb=st.integers(0, 9), seed=st.integers(0, 2 ** 16))
    def test_property_permutation_bijective_any_graph(n, R, npb, seed):
        rng = np.random.default_rng(seed)
        g = _random_graph(rng, n, R)
        eps = rng.integers(0, n, size=rng.integers(1, 4))
        o2n = locality_permutation(g, npb, eps)
        assert sorted(o2n.tolist()) == list(range(n))
        # applying + inverting is the identity on every array
        vecs = rng.normal(size=(n, 4)).astype(np.float32)
        codes = rng.integers(0, 256, size=(n, 2)).astype(np.uint8)
        vp, gp, cp, ep = apply_permutation(o2n, vecs, g, codes, eps)
        n2o = invert_permutation(o2n)
        np.testing.assert_array_equal(vp[o2n], vecs)
        np.testing.assert_array_equal(cp[o2n], codes)
        back = np.where(gp >= 0, n2o[np.where(gp >= 0, gp, 0)], -1)
        np.testing.assert_array_equal(back[o2n], g)
else:                        # pragma: no cover
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_property_permutation_bijective_any_graph():
        pass
