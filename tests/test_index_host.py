import numpy as np
import pytest

from repro.core.index_io import HostIndex, recall_at
from repro.core.index_switch import IndexManager


def test_recall_and_identity(index_dirs, small_corpus):
    """Paper's central claims at this scale: high recall, and AiSAQ results
    == DiskANN results (same topology, same search params)."""
    base, q, gt = small_corpus
    out = {}
    for mode, path in index_dirs.items():
        idx = HostIndex.load(path)
        ids, stats = idx.search_batch(q, 10, L=40)
        out[mode] = ids
        assert recall_at(ids, gt, 1) >= 0.9, mode
        assert recall_at(ids, gt, 10) >= 0.8, mode
        assert stats[0].ios > 0 and stats[0].hops > 0
        idx.close()
    np.testing.assert_array_equal(out["aisaq"], out["diskann"])


def test_memory_residency_ordering(index_dirs, small_corpus):
    """Table 2: AiSAQ residency excludes the (N, m) code table."""
    base = small_corpus[0]
    a = HostIndex.load(index_dirs["aisaq"])
    d = HostIndex.load(index_dirs["diskann"])
    n, m = base.shape[0], a.meta["pq_m"]
    assert d.resident_bytes() - a.resident_bytes() == n * m
    # AiSAQ residency is independent of N: only centroids + ep codes
    assert a.resident_bytes() == a.centroids.nbytes + a.ep_codes.nbytes
    a.close(), d.close()


def test_load_time_ordering(index_dirs):
    a = HostIndex.load(index_dirs["aisaq"])
    d = HostIndex.load(index_dirs["diskann"])
    # Table 3: aisaq load strictly cheaper (no N-sized file read)
    assert a.load_time_s < d.load_time_s * 1.5 + 0.05
    a.close(), d.close()


def test_recall_improves_with_L(index_dirs, small_corpus):
    """Fig. 3's mechanism: larger candidate list -> higher recall."""
    base, q, gt = small_corpus
    idx = HostIndex.load(index_dirs["aisaq"])
    r = []
    for L in (10, 25, 60):
        ids, _ = idx.search_batch(q, 10, L=L)
        r.append(recall_at(ids, gt, 10))
    assert r[-1] >= r[0]
    idx.close()


def test_index_switch_shared_centroids(tmp_path, small_corpus, pq_artifacts):
    """Table 4: switching with shared PQ centroids skips the centroid load."""
    from repro.configs.base import IndexConfig
    from repro.core.build import build_index
    base, q, _ = small_corpus
    cents, _ = pq_artifacts
    cfg = IndexConfig(name="sub", n_vectors=400, dim=base.shape[1], R=12,
                      pq_m=12, build_L=24)
    paths = {}
    for i in range(3):
        sub = base[i * 400:(i + 1) * 400]
        p = str(tmp_path / f"sub{i}")
        build_index(p, sub, cfg, mode="aisaq", shared_centroids=cents)
        paths[f"c{i}"] = p
    mgr = IndexManager(paths)
    t_first = mgr.switch("c0")
    cents_c0 = mgr.active.centroids
    t_shared = mgr.switch("c1")
    ids, stats = mgr.search(q[0], 5, L=24)
    assert ids.shape == (5,)
    assert t_shared > 0
    # shared-centroid switch must not reload pq_centroids.npy: verify the
    # newly-active index reuses c0's very array object (pool dedup)
    assert mgr.active.centroids is cents_c0
    mgr2 = IndexManager(paths)
    mgr2.switch("c0")
    c0 = mgr2.active.centroids
    mgr2.switch("c1", share_centroids=True)
    assert mgr2.active.centroids is c0          # no reload happened
    mgr.close(), mgr2.close()


def test_beamwidth_reduces_hops(index_dirs, small_corpus):
    base, q, gt = small_corpus
    idx = HostIndex.load(index_dirs["aisaq"])
    _, s1 = idx.search(q[0], 5, L=40, w=1)
    _, s4 = idx.search(q[0], 5, L=40, w=4)
    assert s4.hops <= s1.hops
    idx.close()
