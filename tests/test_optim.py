import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.adamw import (global_norm, make_optimizer, warmup_cosine)


def test_adamw_converges_quadratic():
    opt_init, opt_update = make_optimizer(lambda s: 0.1, weight_decay=0.0)
    p = {"w": jnp.asarray([3.0, -2.0, 5.0])}
    st = opt_init(p)
    target = jnp.asarray([1.0, 1.0, 1.0])
    for _ in range(150):
        g = jax.tree.map(lambda w: 2 * (w - target), p)
        p, st, _ = opt_update(g, st, p)
    np.testing.assert_allclose(np.asarray(p["w"]), np.asarray(target),
                               atol=1e-2)


def test_mixed_precision_master_copy():
    opt_init, opt_update = make_optimizer(lambda s: 1e-3)
    p = {"w": jnp.ones((4,), jnp.bfloat16)}
    st = opt_init(p)
    assert st.master["w"].dtype == jnp.float32
    g = {"w": jnp.full((4,), 1e-4, jnp.bfloat16)}
    p2, st2, _ = opt_update(g, st, p)
    assert p2["w"].dtype == jnp.bfloat16
    # master moved even though bf16 param may round
    assert float(jnp.abs(st2.master["w"] - st.master["w"]).max()) > 0


def test_row_adagrad_for_embeddings():
    opt_init, opt_update = make_optimizer(lambda s: 0.1)
    p = {"tables": [jnp.ones((16, 4), jnp.float32)]}
    st = opt_init(p)
    assert st.v["tables"][0].shape == (16,)      # rowwise accumulator
    assert st.m["tables"][0].shape == (1,)       # no 1st moment
    g = {"tables": [jnp.zeros((16, 4)).at[3].set(1.0)]}
    p2, st2, _ = opt_update(g, st, p)
    delta = np.asarray(jnp.abs(p2["tables"][0] - p["tables"][0]).sum(-1))
    assert delta[3] > 0 and delta[0] == 0        # only touched rows move


def test_grad_clipping():
    opt_init, opt_update = make_optimizer(lambda s: 1.0, clip_norm=1.0,
                                          weight_decay=0.0)
    p = {"w": jnp.zeros((3,))}
    st = opt_init(p)
    g = {"w": jnp.asarray([100.0, 0.0, 0.0])}
    p2, _, stats = opt_update(g, st, p)
    assert float(stats["grad_norm"]) > 99
    assert float(jnp.abs(p2["w"]).max()) < 1.2   # clipped step ~ lr * 1.0


def test_warmup_cosine_shape():
    lr = warmup_cosine(1.0, warmup=10, total=100)
    assert float(lr(jnp.int32(0))) == 0.0
    assert abs(float(lr(jnp.int32(10))) - 1.0) < 0.11
    assert float(lr(jnp.int32(100))) < 0.2


def test_int8_compression_roundtrip():
    from repro.distributed.compression import dequantize_int8, quantize_int8
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1000,)).astype(np.float32))
    scale = jnp.max(jnp.abs(x))
    err = jnp.abs(dequantize_int8(quantize_int8(x, scale), scale) - x)
    assert float(err.max()) <= float(scale) / 127.0 + 1e-6
