import numpy as np
import pytest

from repro.core.chunk_layout import (B_NUM, ChunkLayout, pack_chunks_device,
                                     pack_chunks_file, parse_chunk)


def test_paper_formulas():
    """B_DiskANN = b_full + b_num(R+1); B_AiSAQ = B_DiskANN + R*b_pq (§3.1)."""
    for dim, dt, R, m in [(128, "float32", 56, 128), (128, "uint8", 52, 32),
                          (1024, "float32", 69, 128)]:
        d = ChunkLayout("diskann", dim, dt, R, m)
        a = ChunkLayout("aisaq", dim, dt, R, m)
        b_full = dim * (1 if dt == "uint8" else 4)
        assert d.chunk_bytes == b_full + B_NUM * (R + 1)
        assert a.chunk_bytes == d.chunk_bytes + R * m


def test_paper_table1_block_fit():
    """SIFT1B (Table 1): both modes fit one 4 KiB block -> same IO size,
    which is why AiSAQ is latency-neutral-or-better there (§4.3)."""
    d = ChunkLayout("diskann", 128, "uint8", 52, 32)
    a = ChunkLayout("aisaq", 128, "uint8", 52, 32)
    assert d.io_bytes == a.io_bytes == 4096
    # SIFT1M fp32 with b_pq=128: AiSAQ needs more blocks than DiskANN
    d1 = ChunkLayout("diskann", 128, "float32", 56, 128)
    a1 = ChunkLayout("aisaq", 128, "float32", 56, 128)
    assert a1.io_bytes >= d1.io_bytes


def test_block_alignment_no_straddle():
    lay = ChunkLayout("aisaq", 32, "float32", 8, 8)
    assert lay.chunk_bytes <= lay.block_bytes
    npb = lay.nodes_per_block
    for i in range(100):
        off = lay.file_offset(i)
        blk = off // lay.block_bytes
        assert off + lay.chunk_bytes <= (blk + 1) * lay.block_bytes
    # multi-block chunks start block-aligned
    lay2 = ChunkLayout("aisaq", 1024, "float32", 69, 128)
    assert lay2.chunk_bytes > lay2.block_bytes
    for i in range(10):
        assert lay2.file_offset(i) % lay2.block_bytes == 0


def test_device_stride_alignment():
    for dim, dt, R, m in [(48, "float32", 20, 12), (128, "uint8", 52, 32)]:
        lay = ChunkLayout("aisaq", dim, dt, R, m)
        assert lay.device_stride % 128 == 0
        assert lay.dev_off_ids % 4 == 0 and lay.dev_off_pq % 4 == 0


@pytest.mark.parametrize("mode", ["aisaq", "diskann"])
@pytest.mark.parametrize("dt", ["float32", "uint8"])
def test_pack_parse_roundtrip(mode, dt):
    rng = np.random.default_rng(0)
    n, dim, R, m = 50, 24, 10, 8
    if dt == "uint8":
        vecs = rng.integers(0, 255, (n, dim)).astype(np.uint8)
    else:
        vecs = rng.normal(size=(n, dim)).astype(np.float32)
    adj = rng.integers(-1, n, (n, R)).astype(np.int32)
    codes = rng.integers(0, 256, (n, m)).astype(np.uint8)
    lay = ChunkLayout(mode, dim, dt, R, m)
    buf = np.frombuffer(pack_chunks_file(vecs, adj, codes, lay), np.uint8)
    for i in (0, 7, n - 1):
        off = lay.file_offset(i)
        vec, ids, pq = parse_chunk(buf[off:off + lay.chunk_bytes], lay)
        np.testing.assert_array_equal(vec, vecs[i])
        np.testing.assert_array_equal(ids, adj[i])
        if mode == "aisaq":
            valid = adj[i] >= 0
            np.testing.assert_array_equal(pq[valid],
                                          codes[adj[i][valid]])


def test_device_pack_matches_ref_parse():
    from repro.kernels.ref import parse_chunks_words
    import jax.numpy as jnp
    rng = np.random.default_rng(1)
    n, dim, R, m = 30, 16, 6, 8
    vecs = rng.normal(size=(n, dim)).astype(np.float32)
    adj = rng.integers(-1, n, (n, R)).astype(np.int32)
    codes = rng.integers(0, 256, (n, m)).astype(np.uint8)
    lay = ChunkLayout("aisaq", dim, "float32", R, m)
    dev = pack_chunks_device(vecs, adj, codes, lay)
    words = jnp.asarray(np.ascontiguousarray(dev).view(np.int32)
                        .reshape(n, -1))
    vec, deg, ids, pqc = parse_chunks_words(words[:5], lay)
    np.testing.assert_allclose(np.asarray(vec), vecs[:5], rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(ids), adj[:5])
    np.testing.assert_array_equal(np.asarray(deg), (adj[:5] >= 0).sum(1))
