"""Observability layer: histogram bucket math, registry exposition and
merging, span tracing, and trace-context propagation through the framed
wire protocol (including corrupted-frame paths)."""
import json
import socket
import time

import numpy as np
import pytest

from repro.obs import metrics as M
from repro.obs import trace as T
from repro.serving import protocol as proto

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:              # container has no hypothesis: skip the
    HAVE_HYPOTHESIS = False      # property test, keep the deterministic ones


# ---------------------------------------------------------------------------
# histogram bucket math
# ---------------------------------------------------------------------------


def _hist(values, buckets=M.DEFAULT_LATENCY_BUCKETS_S):
    h = M.Histogram({}, buckets)
    for v in values:
        h.observe(v)
    return h


def test_empty_histogram_has_no_quantiles():
    h = _hist([])
    assert h.quantile(0.5) is None
    assert h._series()["p99"] is None
    assert M.bucket_quantile(h.bounds, h.counts, 0.99) is None


def test_observations_land_in_le_buckets():
    # Prometheus `le` semantics: v == bound counts in that bucket
    h = _hist([0.0001, 0.00025, 0.0005], buckets=(0.0001, 0.00025, 0.0005))
    assert h.counts == [1, 1, 1, 0]
    h2 = _hist([100.0], buckets=(0.001, 1.0))
    assert h2.counts == [0, 0, 1]           # overflow bucket


def test_overflow_quantile_clamps_to_last_finite_bound():
    h = _hist([100.0, 200.0], buckets=(0.001, 1.0))
    assert h.quantile(0.5) == 1.0
    assert h.quantile(0.99) == 1.0


def test_percentile_monotone_in_q():
    rng = np.random.default_rng(0)
    h = _hist(rng.lognormal(-6, 2, size=500).tolist())
    qs = [0.01, 0.1, 0.25, 0.5, 0.75, 0.9, 0.95, 0.99, 0.999]
    vals = [h.quantile(q) for q in qs]
    assert all(a <= b for a, b in zip(vals, vals[1:]))


def test_quantile_interpolates_within_bucket():
    # 10 observations all in (0.001, 0.002]: p50 lands mid-bucket
    h = _hist([0.0015] * 10, buckets=(0.001, 0.002, 0.004))
    v = h.quantile(0.5)
    assert 0.001 < v <= 0.002
    assert h.quantile(1.0) == pytest.approx(0.002)


def _snap_of(values, labels=None):
    reg = M.MetricsRegistry()
    h = reg.histogram("h", labels, buckets=(0.001, 0.01, 0.1))
    for v in values:
        h.observe(v)
    return reg.snapshot()


def test_merge_is_associative_and_commutative():
    a = _snap_of([0.0005, 0.05])
    b = _snap_of([0.005, 5.0])
    c = _snap_of([0.02])
    ab_c = M.merge_snapshots([M.merge_snapshots([a, b]), c])
    a_bc = M.merge_snapshots([a, M.merge_snapshots([b, c])])
    assert ab_c == a_bc
    assert M.merge_snapshots([a, b]) == M.merge_snapshots([b, a])
    s = ab_c["h"]["series"][0]
    assert s["count"] == 5 and sum(s["counts"]) == 5


def test_merge_recomputes_percentiles_from_merged_counts():
    a, b = _snap_of([0.0005] * 3), _snap_of([0.05] * 3)
    m = M.merge_snapshots([a, b])["h"]["series"][0]
    direct = _snap_of([0.0005] * 3 + [0.05] * 3)["h"]["series"][0]
    assert m["counts"] == direct["counts"]
    assert m["p50"] == direct["p50"] and m["p99"] == direct["p99"]


def test_merge_sums_counters_and_gauges_keeps_label_series_apart():
    def snap(n, corpus):
        reg = M.MetricsRegistry()
        reg.counter("c", {"corpus": corpus}).inc(n)
        reg.gauge("g").set(n)
        return reg.snapshot()
    m = M.merge_snapshots([snap(2, "a"), snap(3, "a"), snap(5, "b")])
    by = {tuple(sorted(s["labels"].items())): s["value"]
          for s in m["c"]["series"]}
    assert by[(("corpus", "a"),)] == 5 and by[(("corpus", "b"),)] == 5
    assert m["g"]["series"][0]["value"] == 10   # gauges sum: cluster total


def test_merge_conflicts_raise():
    reg1, reg2 = M.MetricsRegistry(), M.MetricsRegistry()
    reg1.counter("x").inc()
    reg2.gauge("x").set(1)
    with pytest.raises(ValueError, match="kind conflict"):
        M.merge_snapshots([reg1.snapshot(), reg2.snapshot()])
    with pytest.raises(ValueError, match="bounds conflict"):
        M.merge_snapshots([_snap_of([1.0]),
                           {"h": {"type": "histogram", "series": [dict(
                               labels={}, bounds=[1.0, 2.0], counts=[0, 0, 1],
                               sum=3.0, count=1)]}}])


def test_merge_survives_json_roundtrip():
    # worker snapshots arrive through T_STATS as parsed JSON (tuples
    # became lists, label keys are strings) — merging must not care
    a = json.loads(json.dumps(_snap_of([0.0005], labels={"corpus": "x"})))
    b = _snap_of([0.05], labels={"corpus": "x"})
    m = M.merge_snapshots([a, b])["h"]["series"][0]
    assert m["count"] == 2


if HAVE_HYPOTHESIS:
    @settings(max_examples=100, deadline=None)
    @given(st.lists(st.floats(min_value=0, max_value=100,
                              allow_nan=False), max_size=60),
           st.lists(st.floats(min_value=0, max_value=100,
                              allow_nan=False), max_size=60),
           st.lists(st.floats(min_value=0, max_value=100,
                              allow_nan=False), max_size=60))
    def test_property_merge_equals_direct(xs, ys, zs):
        """merge(snap(xs), snap(ys), snap(zs)) has exactly the buckets,
        sums, and percentiles of observing xs+ys+zs directly, however
        the merge is associated."""
        parts = [_snap_of(v) for v in (xs, ys, zs)]
        left = M.merge_snapshots(
            [M.merge_snapshots(parts[:2]), parts[2]])
        right = M.merge_snapshots(
            [parts[0], M.merge_snapshots(parts[1:])])
        direct = _snap_of(list(xs) + list(ys) + list(zs))
        for m in (left, right):
            s, d = m["h"]["series"][0], direct["h"]["series"][0]
            assert s["counts"] == d["counts"]
            assert s["count"] == d["count"]
            assert s["sum"] == pytest.approx(d["sum"])
            for p in ("p50", "p95", "p99"):
                if d[p] is None:
                    assert s[p] is None
                else:
                    assert s[p] == pytest.approx(d[p])
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_property_merge_equals_direct():
        pass


# ---------------------------------------------------------------------------
# registry + exposition
# ---------------------------------------------------------------------------


def test_registry_handles_are_idempotent_and_kind_checked():
    reg = M.MetricsRegistry()
    c1 = reg.counter("req", {"corpus": "a"})
    c2 = reg.counter("req", {"corpus": "a"})
    assert c1 is c2
    c1.inc(), c2.inc(2)
    assert c1.value == 3
    assert reg.counter("req", {"corpus": "b"}) is not c1
    with pytest.raises(ValueError, match="is a counter"):
        reg.gauge("req")


def test_prometheus_text_exposition():
    reg = M.MetricsRegistry()
    reg.counter("req_total", {"corpus": "a"}, help="requests").inc(4)
    h = reg.histogram("lat", buckets=(0.001, 0.01))
    h.observe(0.0005), h.observe(5.0)
    text = reg.to_prometheus()
    assert '# TYPE req_total counter' in text
    assert 'req_total{corpus="a"} 4.0' in text
    assert 'lat_bucket{le="0.001"} 1' in text
    assert 'lat_bucket{le="+Inf"} 2' in text
    assert 'lat_count 2' in text
    json.loads(reg.to_json())          # JSON exposition stays valid


# ---------------------------------------------------------------------------
# tracing
# ---------------------------------------------------------------------------


def test_span_tree_and_chrome_export(tmp_path):
    tr = T.Tracer()
    root = tr.start_span("router.search", annotations=dict(k=5))
    with T.activate(root):
        with T.span("child", shard=1):
            with T.span("grandchild"):
                pass
    root.end()
    tree = tr.span_tree(root.trace_id)
    assert [t["name"] for t in tree] == ["router.search"]
    assert tree[0]["children"][0]["name"] == "child"
    assert tree[0]["children"][0]["children"][0]["name"] == "grandchild"
    dest = tmp_path / "trace.json"
    doc = tr.export_chrome(str(dest), trace_id=root.trace_id)
    on_disk = json.loads(dest.read_text())
    assert on_disk == doc
    evs = {e["name"]: e for e in doc["traceEvents"]}
    assert evs["router.search"]["args"]["k"] == 5
    assert evs["child"]["args"]["parent_id"] == root.span_id
    assert all(e["ph"] == "X" and e["dur"] > 0 for e in evs.values())


def test_spans_noop_without_active_parent_or_when_disabled():
    tr = T.Tracer()
    assert T.current_span() is None
    with T.span("orphan") as sp:
        assert sp is None
    assert T.begin("orphan") is None
    root = tr.start_span("r")
    try:
        T.set_enabled(False)
        with T.activate(root):
            assert T.current_span() is None    # kill switch wins
    finally:
        T.set_enabled(True)
    root.end()


def test_deterministic_sampling_rate():
    tr = T.Tracer(sample=0.25)
    assert sum(tr.sampled() for _ in range(100)) == 25
    assert all(T.Tracer(sample=1.0).sampled() for _ in range(5))
    assert not any(T.Tracer(sample=0.0).sampled() for _ in range(5))


def test_take_pops_only_the_requested_trace():
    tr = T.Tracer()
    a, b = tr.start_span("a"), tr.start_span("b")
    a.end(), b.end()
    got = tr.take(a.trace_id)
    assert [d["name"] for d in got] == ["a"]
    assert [d["name"] for d in tr.finished()] == ["b"]


def test_slow_query_log(tmp_path):
    log = tmp_path / "slow.jsonl"
    tr = T.Tracer(slow_threshold_s=0.01, slow_log_path=str(log))
    fast = tr.start_span("fast")
    fast.end()
    slow = tr.start_span("slow")
    with T.activate(slow):
        with T.span("inner"):
            time.sleep(0.02)
    slow.end()
    assert len(tr.slow_queries) == 1
    entry = tr.slow_queries[0]
    assert entry["name"] == "slow" and entry["duration_s"] >= 0.01
    assert entry["tree"][0]["children"][0]["name"] == "inner"
    lines = [json.loads(ln) for ln in log.read_text().splitlines()]
    assert len(lines) == 1 and lines[0]["trace_id"] == slow.trace_id


# ---------------------------------------------------------------------------
# trace context through the wire protocol
# ---------------------------------------------------------------------------


def test_trace_context_roundtrips_through_query_frame():
    tr = T.Tracer()
    sp = tr.start_span("router.shard0")
    q = np.zeros(8, np.float32)
    h, b = proto.encode_query(q, corpus="c", k=3, req_id=1,
                              deadline_s=None, trace=tr.context(sp))
    a, bsock = socket.socketpair()
    try:
        proto.send_frame(a, proto.T_SEARCH, h, b)
        _, h2, _ = proto.recv_frame(bsock)
    finally:
        a.close(), bsock.close()
    ctx = proto.trace_context(h2)
    assert ctx == {"tid": sp.trace_id, "sid": sp.span_id}
    # the worker-side remote span parents onto the router-side span
    wtr = T.Tracer()
    wsp = wtr.start_remote("worker.serve", ctx)
    assert wsp.trace_id == sp.trace_id and wsp.parent_id == sp.span_id
    sp.end()


def test_untraced_query_frame_has_no_context():
    h, _ = proto.encode_query(np.zeros(4, np.float32), corpus="c", k=1,
                              req_id=1, deadline_s=None)
    assert "trace" not in h and proto.trace_context(h) is None


@pytest.mark.parametrize("bad", [
    "not-a-dict", {"tid": "x"}, {"sid": "y"}, {"tid": "", "sid": "y"},
    {"tid": 7, "sid": "y"}, {"tid": None, "sid": None}, [], 3,
])
def test_malformed_trace_context_degrades_to_untraced(bad):
    assert proto.trace_context({"trace": bad, "k": 1}) is None


def test_result_frame_carries_spans_back():
    tr = T.Tracer()
    sp = tr.start_span("worker.serve")
    sp.end()
    spans = tr.take(sp.trace_id)
    ids = np.array([1, 2], np.int64)
    dists = np.array([0.1, 0.2], np.float32)
    h, b = proto.encode_result(ids, dists, req_id=9, spans=spans)
    a, bsock = socket.socketpair()
    try:
        proto.send_frame(a, proto.T_RESULT, h, b)
        _, h2, b2 = proto.recv_frame(bsock)
    finally:
        a.close(), bsock.close()
    assert h2["spans"][0]["span_id"] == sp.span_id
    i2, d2 = proto.decode_result(h2, b2)
    np.testing.assert_array_equal(i2, ids)
    # untraced results stay lean
    h3, _ = proto.encode_result(ids, dists, req_id=9)
    assert "spans" not in h3


def test_corrupted_traced_frame_still_fails_crc():
    tr = T.Tracer()
    sp = tr.start_span("s")
    h, b = proto.encode_query(np.zeros(8, np.float32), corpus="c", k=3,
                              req_id=1, deadline_s=None,
                              trace=tr.context(sp))
    raw = bytearray(proto.pack_frame(proto.T_SEARCH, h, b))
    raw[len(raw) // 2] ^= 0x10
    a, bsock = socket.socketpair()
    try:
        a.sendall(bytes(raw))
        with pytest.raises(proto.ProtocolError):
            proto.recv_frame(bsock)
    finally:
        a.close(), bsock.close()
    sp.end()


# ---------------------------------------------------------------------------
# router telemetry: first-attempt vs hedge split
# ---------------------------------------------------------------------------


def test_router_splits_first_vs_hedge_latency():
    from repro.serving.router import LocalShardClient, ShardRouter

    calls = {"n": 0}

    def flaky(q, k):
        calls["n"] += 1
        if calls["n"] == 1:            # first attempt fails, hedge lands
            raise RuntimeError("boom")
        return (np.arange(k, dtype=np.int64),
                np.arange(k, dtype=np.float32))

    r = ShardRouter([LocalShardClient(flaky)], min_shards=1)
    try:
        out = r.search(np.zeros(4, np.float32), 3)
        assert not out.partial and out.retried_shards == [0]
        s = r.stats()
        assert s["queries"] == 1 and s["full"] == 1
        assert s["shard_attempts"] == 2 and s["shard_failures"] == 1
        assert s["retries"] == 1 and s["retry_successes"] == 1
        al = s["attempt_latency"]
        assert al["first"]["count"] == 1 and al["hedge"]["count"] == 1
        assert al["hedge"]["p50_ms"] >= 0.0
        fam = s["registry"]["router_attempt_latency_seconds"]
        kinds = {s_["labels"]["attempt"] for s_ in fam["series"]}
        assert kinds == {"first", "hedge"}
    finally:
        r.close()


def test_router_traces_local_shards():
    from repro.serving.router import LocalShardClient, ShardRouter

    def ok(q, k):
        return (np.arange(k, dtype=np.int64),
                np.arange(k, dtype=np.float32))

    tr = T.Tracer(sample=1.0)
    r = ShardRouter([LocalShardClient(ok), LocalShardClient(ok)],
                    min_shards=2, tracer=tr)
    try:
        r.search(np.zeros(4, np.float32), 3)
        names = sorted(d["name"] for d in tr.finished())
        assert names == ["router.search", "router.shard0", "router.shard1"]
        roots = [d for d in tr.finished() if d["parent_id"] is None]
        assert len(roots) == 1 and roots[0]["name"] == "router.search"
        assert roots[0]["annotations"]["outcome"] == "full"
    finally:
        r.close()


# ---------------------------------------------------------------------------
# service registry
# ---------------------------------------------------------------------------


def test_service_stats_expose_registry_snapshot(index_dirs):
    from repro.serving.pool import WarmIndexPool
    from repro.serving.service import RetrievalService

    pool = WarmIndexPool({"a": index_dirs["aisaq"]}, cache_bytes=1 << 20)
    svc = RetrievalService(pool, num_workers=1, L=24, w=4)
    try:
        q = np.zeros((48,), np.float32)
        r = svc.submit(q, corpus="a", k=3)
        assert r.event.wait(10.0) and r.error is None
        st = svc.stats()
        ca = st["corpora"]["a"]
        assert ca["completed"] == 1
        assert ca["p99_ms"] >= ca["p50_ms"] > 0
        reg = st["registry"]
        lat = reg["service_latency_seconds"]["series"][0]
        assert lat["count"] == 1 and lat["p50"] is not None
        # the search-path distributions reached the same registry
        assert reg["traversal_hops"]["series"][0]["count"] >= 1
        assert reg["search_batch_latency_seconds"]["series"][0]["count"] == 1
    finally:
        svc.close()
        pool.close()
