"""Write-ahead journal: framing, torn-tail handling, CRC rejection."""
import os

import numpy as np
import pytest

from repro.core import wal as W


def _open(tmp_path, **kw):
    return W.WriteAheadLog(str(tmp_path / "wal.log"), **kw)


def test_roundtrip(tmp_path):
    wal = _open(tmp_path)
    blob = bytes(range(64))
    wal.append(W.T_INSERT_BEGIN, dict(id=7, chosen=[1, 2, 3]), blob)
    wal.append(W.T_INSERT_COMMIT, dict(id=7))
    wal.append(W.T_DELETE, dict(label=3))
    records, end, torn = wal.scan()
    assert [r.rtype for r in records] == \
        [W.T_INSERT_BEGIN, W.T_INSERT_COMMIT, W.T_DELETE]
    assert records[0].header == dict(id=7, chosen=[1, 2, 3])
    assert records[0].blob == blob
    assert records[1].blob == b""
    assert end == wal.size and not torn
    wal.close()


def test_empty_journal(tmp_path):
    wal = _open(tmp_path)
    records, end, torn = wal.scan()
    assert records == [] and end == 0 and not torn
    wal.close()


def test_torn_tail_is_truncated(tmp_path):
    wal = _open(tmp_path)
    wal.append(W.T_INSERT_BEGIN, dict(id=0), b"x" * 32)
    keep = wal.size
    wal.append(W.T_INSERT_COMMIT, dict(id=0))
    # tear the second frame: chop its last byte (the CRC is now short)
    os.ftruncate(wal.fd, wal.size - 1)
    records, end, torn = wal.scan()
    assert len(records) == 1 and end == keep and torn
    wal.truncate(end)
    records2, end2, torn2 = wal.scan()
    assert len(records2) == 1 and not torn2
    wal.close()


def test_bitrot_stops_scan(tmp_path):
    wal = _open(tmp_path)
    off0 = wal.append(W.T_INSERT_BEGIN, dict(id=0), b"a" * 16)
    off1 = wal.append(W.T_INSERT_COMMIT, dict(id=0))
    wal.append(W.T_DELETE, dict(label=9))
    # flip one blob byte inside the FIRST frame: nothing after it is
    # trustworthy (offsets downstream depend on its self-delimiting)
    raw = os.pread(wal.fd, wal.size, 0)
    hit = off0 + W._HDR.size + len(b'{"id":0}')
    os.pwrite(wal.fd, bytes([raw[hit] ^ 0xFF]), hit)
    records, end, torn = wal.scan()
    assert records == [] and end == 0 and torn
    assert off1 > 0  # silence unused warning
    wal.close()


def test_garbage_magic_stops_scan(tmp_path):
    wal = _open(tmp_path)
    wal.append(W.T_DELETE, dict(label=1))
    good = wal.size
    os.pwrite(wal.fd, b"\xde\xad\xbe\xef" + b"\x00" * 16, good)
    records, end, torn = wal.scan()
    assert len(records) == 1 and end == good and torn
    wal.close()


def test_append_returns_offsets(tmp_path):
    wal = _open(tmp_path)
    offs = [wal.append(W.T_DELETE, dict(label=i)) for i in range(5)]
    assert offs == sorted(offs) and offs[0] == 0
    records, _, _ = wal.scan()
    assert [r.offset for r in records] == offs
    wal.close()


def test_kill_switch_mid_frame_is_torn(tmp_path):
    from repro.core.faults import CrashPoint, KillSwitch
    # count the ticks of one append, then kill at the mid-frame tick
    ks = KillSwitch()
    wal = _open(tmp_path, kill=ks)
    wal.append(W.T_INSERT_BEGIN, dict(id=1, chosen=[0]), b"z" * 128)
    assert "wal.mid.1" in ks.labels
    mid = ks.labels.index("wal.mid.1") + 1
    wal.close()

    ks2 = KillSwitch(at=mid)
    wal2 = W.WriteAheadLog(str(tmp_path / "wal2.log"), kill=ks2)
    with pytest.raises(CrashPoint):
        wal2.append(W.T_INSERT_BEGIN, dict(id=1, chosen=[0]), b"z" * 128)
    records, end, torn = wal2.scan()
    assert records == [] and end == 0 and torn   # half a frame on disk
    assert wal2.size > 0
    wal2.close()


def test_blob_roundtrip_binary_safety(tmp_path):
    wal = _open(tmp_path)
    rng = np.random.default_rng(3)
    blob = rng.integers(0, 256, 4096, dtype=np.uint8).tobytes()
    wal.append(W.T_INSERT_BEGIN, dict(id=2), blob)
    records, _, torn = wal.scan()
    assert records[0].blob == blob and not torn
    wal.close()


class TestScanOnArbitraryCorruption:
    """Property: `scan` over an ARBITRARILY truncated / bit-flipped
    journal (1) never raises and (2) never yields a frame at or past the
    first corrupted byte — recovery's safety depends on both, and the
    split-point tests above only cover hand-picked damage."""

    @staticmethod
    def _build(tmp_path, n_records: int, blob_len: int):
        wal = _open(tmp_path)
        bounds = []                     # frame end offsets, in order
        for i in range(n_records):
            blob = (bytes(range(256)) * (blob_len // 256 + 1))[:blob_len]
            wal.append(W.T_INSERT_BEGIN, dict(id=i, chosen=[i, i + 1]),
                       blob)
            bounds.append(wal.size)
        return wal, bounds

    @staticmethod
    def _check(wal, bounds, first_bad: int):
        """Scan must neither raise nor return any frame whose bytes
        overlap [first_bad, ...); valid_end must not pass first_bad."""
        records, end, _torn = wal.scan()
        intact = sum(1 for b in bounds if b <= first_bad)
        assert len(records) <= intact
        assert end <= first_bad or intact == len(bounds)
        for r, b in zip(records, bounds):
            assert b <= first_bad       # only fully-pre-damage frames

    def test_property_truncation_and_bitflips(self, tmp_path):
        pytest.importorskip("hypothesis")
        from hypothesis import given, settings, strategies as st

        outer = self

        @settings(max_examples=60, deadline=None)
        @given(data=st.data(),
               n_records=st.integers(1, 6),
               blob_len=st.integers(0, 300))
        def prop(data, n_records, blob_len):
            import tempfile
            from pathlib import Path
            with tempfile.TemporaryDirectory() as d:
                wal, bounds = outer._build(Path(d), n_records, blob_len)
                size = wal.size
                # arbitrary torn tail ...
                cut = data.draw(st.integers(0, size), label="cut")
                os.ftruncate(wal.fd, cut)
                first_bad = cut
                # ... plus up to 3 arbitrary bit flips in what remains
                if cut:
                    flips = data.draw(
                        st.lists(st.tuples(st.integers(0, cut - 1),
                                           st.integers(0, 7)),
                                 max_size=3), label="flips")
                    raw = os.pread(wal.fd, cut, 0)
                    for pos, bit in flips:
                        os.pwrite(wal.fd, bytes([raw[pos] ^ (1 << bit)]),
                                  pos)
                        first_bad = min(first_bad, pos)
                outer._check(wal, bounds, first_bad)
                wal.close()

        prop()
