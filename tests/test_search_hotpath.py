"""The batched cache-aware search hot path (perf-opt PR deliverables):

  * BlockCache: LRU eviction under a byte budget, hit/miss/syscall
    accounting, coalesced preadv runs,
  * vectorized `HostIndex.search` / `search_batch` == `search_ref`
    bit-for-bit,
  * int8 device ADC (`adc_dtype="int8"`) recall parity vs the f32 path,
  * the vectorized `chunk_matrix` / `recall_at` helpers.
"""
import os

import numpy as np
import pytest

from repro.core.block_cache import BlockCache
from repro.core.index_io import HostIndex, recall_at


# ---------------------------------------------------------------------------
# BlockCache unit behaviour
# ---------------------------------------------------------------------------


@pytest.fixture()
def blockfile(tmp_path):
    """A file of 64 distinct 4 KiB blocks + an open fd."""
    io = 4096
    data = np.arange(64, dtype=np.uint8).repeat(io)
    p = tmp_path / "blocks.bin"
    p.write_bytes(data.tobytes())
    fd = os.open(p, os.O_RDONLY)
    yield fd, io
    os.close(fd)


def test_cache_hit_miss_accounting(blockfile):
    fd, io = blockfile
    cache = BlockCache(fd, io, capacity_bytes=8 * io)
    offs = np.array([0, io, 2 * io]) * 1
    out, hit_mask, n_sys = cache.fetch(offs)
    assert out.shape == (3, io)
    assert (out[1] == 1).all() and (out[2] == 2).all()
    assert not hit_mask.any() and cache.counters.misses == 3
    # contiguous run of 3 blocks -> ONE preadv syscall
    assert n_sys == 1 and cache.counters.syscalls == 1
    out2, hit_mask2, n_sys2 = cache.fetch(offs)
    assert hit_mask2.all() and n_sys2 == 0
    assert cache.counters.hits == 3
    assert cache.hit_rate() == 0.5
    assert cache.counters.bytes_read == 3 * io


def test_cache_coalesces_discontiguous_runs(blockfile):
    fd, io = blockfile
    cache = BlockCache(fd, io, capacity_bytes=32 * io)
    # two contiguous runs [0,1] and [5,6,7] -> exactly 2 syscalls
    offs = np.array([0, io, 5 * io, 6 * io, 7 * io])
    out, hit_mask, n_sys = cache.fetch(offs)
    assert n_sys == 2
    assert (out[:, 0] == np.array([0, 1, 5, 6, 7])).all()
    # repeated offsets within one fetch count as ONE unique block
    out3, hm, ns = cache.fetch(np.array([0, 0, io]))
    assert out3.shape[0] == 3 and hm.all() and ns == 0


def test_cache_lru_eviction_budget(blockfile):
    fd, io = blockfile
    cache = BlockCache(fd, io, capacity_bytes=4 * io)   # 4-block budget
    for b in range(6):
        cache.fetch(np.array([b * io]))
    assert cache.used_bytes == 4 * io                   # budget respected
    assert cache.counters.evictions == 2
    # blocks 0,1 evicted (LRU); 2..5 resident
    _, hm, _ = cache.fetch(np.array([0]))
    assert not hm.any()
    _, hm, _ = cache.fetch(np.array([5 * io]))
    assert hm.all()
    # touching an old block protects it from the next eviction
    cache.fetch(np.array([2 * io]))                     # refresh 2
    cache.fetch(np.array([1 * io]))                     # evicts LRU (not 2)
    _, hm, _ = cache.fetch(np.array([2 * io]))
    assert hm.all()


def test_cache_zero_budget_still_batches(blockfile):
    fd, io = blockfile
    cache = BlockCache(fd, io, capacity_bytes=0)
    offs = np.array([0, io, 2 * io])
    out, hit_mask, n_sys = cache.fetch(offs)
    assert n_sys == 1 and not hit_mask.any()
    assert (out[:, 0] == np.array([0, 1, 2])).all()
    assert cache.used_bytes == 0
    _, hm, _ = cache.fetch(offs)                        # never retained
    assert not hm.any()


def test_cache_larger_than_batch_eviction_consistency(blockfile):
    fd, io = blockfile
    cache = BlockCache(fd, io, capacity_bytes=2 * io)
    # one fetch larger than the whole budget must still return correct data
    offs = np.arange(8) * io
    out, _, _ = cache.fetch(offs)
    assert (out[:, 0] == np.arange(8)).all()
    assert cache.used_bytes <= 2 * io


# ---------------------------------------------------------------------------
# vectorized host search == scalar reference
# ---------------------------------------------------------------------------


def test_search_matches_ref_bitexact(index_dirs, small_corpus):
    """The tentpole invariant: the vectorized hot path returns EXACTLY the
    ids of the faithful scalar Algorithm 1, in both placement modes."""
    base, q, gt = small_corpus
    for mode, path in index_dirs.items():
        idx = HostIndex.load(path)
        for L, w in ((40, 4), (25, 2), (60, 8)):
            ref_ids, ref_stats = idx.search_batch_ref(q, 10, L=L, w=w)
            new_ids, new_stats = idx.search_batch(q, 10, L=L, w=w)
            np.testing.assert_array_equal(ref_ids, new_ids)
            # logical I/O and hop counts agree query-by-query
            assert [s.hops for s in ref_stats] == [s.hops for s in new_stats]
            assert [s.ios for s in ref_stats] == [s.ios for s in new_stats]
        idx.close()


def test_search_single_query_matches_ref(index_dirs, small_corpus):
    base, q, gt = small_corpus
    idx = HostIndex.load(index_dirs["aisaq"])
    for i in range(len(q)):
        a, sa = idx.search_ref(q[i], 10, L=40)
        b, sb = idx.search(q[i], 10, L=40)
        np.testing.assert_array_equal(a, b)
        assert (sa.hops, sa.ios, sa.pq_dists) == (sb.hops, sb.ios, sb.pq_dists)
    idx.close()


def test_batched_search_fewer_syscalls(index_dirs, small_corpus):
    """Hop-batched preadv + cache: far fewer syscalls than the one-pread-
    per-node reference, for identical logical I/O."""
    base, q, gt = small_corpus
    idx = HostIndex.load(index_dirs["aisaq"])
    ref_ids, ref_stats = idx.search_batch_ref(q, 10, L=40)
    idx.cache.clear()
    new_ids, new_stats = idx.search_batch(q, 10, L=40)
    ref_sys = sum(s.syscalls for s in ref_stats)
    new_sys = sum(s.syscalls for s in new_stats)
    assert sum(s.ios for s in new_stats) == sum(s.ios for s in ref_stats)
    assert new_sys < ref_sys / 2
    # cache accounting is consistent: hits + misses == unique blocks touched
    c = idx.cache.counters
    assert c.hits + c.misses >= c.misses > 0
    assert sum(s.cache_misses for s in new_stats) <= c.misses
    idx.close()


def test_search_cache_disabled_matches(index_dirs, small_corpus):
    base, q, gt = small_corpus
    idx0 = HostIndex.load(index_dirs["aisaq"], cache_bytes=0)
    idx1 = HostIndex.load(index_dirs["aisaq"])
    i0, _ = idx0.search_batch(q, 10, L=40)
    i1, _ = idx1.search_batch(q, 10, L=40)
    np.testing.assert_array_equal(i0, i1)
    assert idx0.cache_bytes_used() == 0
    assert 0 < idx1.cache_bytes_used() <= 10 << 20
    idx0.close(), idx1.close()


# ---------------------------------------------------------------------------
# int8 device ADC parity
# ---------------------------------------------------------------------------


def test_device_int8_adc_recall_parity(small_corpus, built_graph,
                                       pq_artifacts):
    import jax.numpy as jnp
    from repro.core.device_index import beam_search_device, from_arrays
    base, q, gt = small_corpus
    cents, codes = pq_artifacts
    idx, lay = from_arrays(base, built_graph, cents, codes, mode="aisaq")
    r = {}
    for adc in ("f32", "int8"):
        ids, _, hops = beam_search_device(idx, jnp.asarray(q), k=10, L=40,
                                          layout=lay, metric="l2",
                                          adc_dtype=adc)
        r[adc] = recall_at(np.asarray(ids), gt, 10)
        assert hops > 0
    assert abs(r["f32"] - r["int8"]) <= 0.01
    assert r["int8"] >= 0.8


def test_sharded_search_accepts_adc_dtype(small_corpus):
    """adc_dtype threads through sharded_search_fn's signature (the actual
    multi-device execution is covered by test_distributed)."""
    import inspect
    from repro.core.sharded_search import sharded_search_fn
    assert "adc_dtype" in inspect.signature(sharded_search_fn).parameters


def test_serving_engine_device_int8_fn(small_corpus, built_graph,
                                       pq_artifacts):
    from repro.core.device_index import from_arrays
    from repro.serving.engine import ServingEngine, make_device_search_fn
    base, q, gt = small_corpus
    cents, codes = pq_artifacts
    idx, lay = from_arrays(base, built_graph, cents, codes, mode="aisaq")
    fn = make_device_search_fn(idx, lay, metric="l2", L=40, backend="ref",
                               adc_dtype="int8")
    eng = ServingEngine({"default": fn}, max_wait_ms=1.0)
    r = eng.submit_wait(q[0])
    assert r.result is not None and r.result.shape == (10,)
    eng.stop()


# ---------------------------------------------------------------------------
# host int8 ADC (numpy twin of the device quantized path)
# ---------------------------------------------------------------------------


def test_np_quantize_lut_matches_device_recipe():
    """The numpy twin and kernels.chunk_adc.quantize_lut must stay
    numerically identical — one shared scale recipe (§Perf adc-int8)."""
    import jax.numpy as jnp
    from repro.core.index_io import np_quantize_lut
    from repro.kernels.chunk_adc import quantize_lut
    lut = np.random.default_rng(0).normal(
        size=(3, 8, 16)).astype(np.float32) * 7.5
    q_np, s_np = np_quantize_lut(lut)
    q_dev, s_dev = quantize_lut(jnp.asarray(lut))
    np.testing.assert_array_equal(q_np, np.asarray(q_dev))
    np.testing.assert_allclose(s_np, np.asarray(s_dev), rtol=1e-6)


def test_np_adc_int8_scalar_scale_matches_device_numerics():
    """Scalar-scale np_adc_int8 == dequantize-then-sum (the ref-backend
    emulation in kernels.ops) up to f32 summation order."""
    from repro.core.index_io import np_adc_int8, np_quantize_lut
    rng = np.random.default_rng(1)
    lut = rng.normal(size=(6, 16)).astype(np.float32) * 3
    codes = rng.integers(0, 16, size=(40, 6))
    q8, scale = np_quantize_lut(lut)
    got = np_adc_int8(q8, scale, codes)
    deq = q8.astype(np.float32) * (scale / 127.0)
    want = deq[np.arange(6), codes].sum(-1)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_host_int8_batch_matches_int8_ref(index_dirs, small_corpus):
    """The int8 hot path has its own scalar oracle: bit-identical ids."""
    base, q, gt = small_corpus
    for mode, path in index_dirs.items():
        idx = HostIndex.load(path)
        ids_b, _ = idx.search_batch(q, 10, L=40, adc_dtype="int8")
        ids_r, _ = idx.search_batch_ref(q, 10, L=40, adc_dtype="int8")
        np.testing.assert_array_equal(ids_b, ids_r)
        idx.close()


def test_host_int8_adc_recall_parity(index_dirs, small_corpus):
    """Acceptance: host int8 recall within 0.01 of float32."""
    base, q, gt = small_corpus
    idx = HostIndex.load(index_dirs["aisaq"])
    r = {}
    for adc in ("f32", "int8"):
        ids, _ = idx.search_batch(q, 10, L=40, adc_dtype=adc)
        r[adc] = recall_at(ids, gt, 10)
    assert abs(r["f32"] - r["int8"]) <= 0.01
    assert r["int8"] >= 0.8
    idx.close()


# ---------------------------------------------------------------------------
# async next-hop prefetch on the host path
# ---------------------------------------------------------------------------


def test_search_with_prefetch_identical_results(index_dirs, small_corpus):
    base, q, gt = small_corpus
    idx = HostIndex.load(index_dirs["aisaq"])
    ids0, _ = idx.search_batch(q, 10, L=40)
    for pf in (2, 4, 8):
        idx.cache.wait_prefetch()
        idx.cache.clear()
        ids, stats = idx.search_batch(q, 10, L=40, prefetch=pf)
        np.testing.assert_array_equal(ids0, ids)
    idx.cache.wait_prefetch()
    # prefetch counters surface in SearchStats (lead-query attribution)
    c = idx.cache.counters
    assert c.prefetch_issued > 0
    assert c.prefetch_hits > 0
    idx.close()


def test_prefetch_moves_io_off_demand_path(index_dirs, small_corpus):
    """With exact next-frontier prefetch, cold demand syscalls collapse
    while total storage reads stay conserved (no duplicated I/O)."""
    base, q, gt = small_corpus
    idx0 = HostIndex.load(index_dirs["aisaq"])
    _, s0 = idx0.search_batch(q, 10, L=40)
    base_sys = sum(s.syscalls for s in s0)
    base_bytes = idx0.cache.counters.bytes_read
    idx0.close()
    idx1 = HostIndex.load(index_dirs["aisaq"])
    _, s1 = idx1.search_batch(q, 10, L=40, prefetch=4)
    idx1.cache.wait_prefetch()
    c = idx1.cache.counters
    assert sum(s.syscalls for s in s1) < base_sys
    # conserved I/O: demand + background ~ baseline demand (readahead
    # holes may add a little; duplicates would roughly double it)
    assert c.bytes_read + c.prefetch_bytes < 1.5 * base_bytes
    idx1.close()


def test_serving_host_fn_accepts_prefetch_and_adc(index_dirs, small_corpus):
    from repro.serving.engine import make_host_search_fn
    base, q, gt = small_corpus
    idx = HostIndex.load(index_dirs["aisaq"])
    fn = make_host_search_fn(idx, L=40, prefetch=4, adc_dtype="int8")
    ids = fn(q[:4], 10)
    assert ids.shape == (4, 10)
    ref, _ = idx.search_batch(q[:4], 10, L=40, adc_dtype="int8")
    np.testing.assert_array_equal(ids, ref)
    idx.close()


# ---------------------------------------------------------------------------
# exact rerank tier (rerank= knob; multi-tenant serving PR)
# ---------------------------------------------------------------------------


def test_rerank_matches_ref_bitexact(index_dirs, small_corpus):
    """Every rerank tier (PQ-only, shallow, deep) returns EXACTLY the ids
    of the extended scalar oracle, in both placement modes and both ADC
    dtypes — including the rerank-I/O accounting."""
    base, q, gt = small_corpus
    for mode, path in index_dirs.items():
        idx = HostIndex.load(path)
        for rr in (0, 10, 25, 60):
            for adc in ("f32", "int8"):
                ids_b, st_b = idx.search_batch(q, 10, L=40, rerank=rr,
                                               adc_dtype=adc)
                ids_r, st_r = idx.search_batch_ref(q, 10, L=40, rerank=rr,
                                                   adc_dtype=adc)
                np.testing.assert_array_equal(ids_b, ids_r)
                assert [s.rerank_ios for s in st_b] == \
                    [s.rerank_ios for s in st_r]
                assert [s.ios for s in st_b] == [s.ios for s in st_r]
        idx.close()


def test_rerank_recall_at_least_pq_only(index_dirs, small_corpus):
    """Acceptance: exact rescoring of the top-r candidates can only improve
    on the PQ-only ranking of the same list (provably per query: the
    groundtruth is the exact metric's top-k)."""
    base, q, gt = small_corpus
    idx = HostIndex.load(index_dirs["aisaq"])
    rec = {}
    for rr in (0, 40):
        ids, _ = idx.search_batch(q, 10, L=40, rerank=rr)
        rec[rr] = recall_at(ids, gt, 10)
    assert rec[40] >= rec[0]
    assert rec[40] >= 0.8
    idx.close()


def test_rerank_reuses_traversal_chunks(index_dirs, small_corpus):
    """Candidates that were expanded during traversal must NOT be fetched
    again: rerank I/O only covers the unexpanded tail of the candidate
    list (and is bounded by it)."""
    base, q, gt = small_corpus
    idx = HostIndex.load(index_dirs["aisaq"])
    ids, stats = idx.search_batch(q, 10, L=40, rerank=40)
    _, stats0 = idx.search_batch(q, 10, L=40)
    for s, s0 in zip(stats, stats0):
        assert s.rerank_ios <= 40
        # traversal I/O unchanged; rerank adds only the tail fetches
        assert s.ios == s0.ios + s.rerank_ios
    idx.close()


def test_rerank_single_query_and_relabel(tmp_path, small_corpus, built_graph,
                                         pq_artifacts):
    """rerank= threads through `search`, and survives graph-locality
    relabeling (candidate ids live in storage space until _map_out)."""
    from repro.core.index_io import write_index
    base, q, gt = small_corpus
    cents, codes = pq_artifacts
    p = str(tmp_path / "rl")
    write_index(p, vectors=base, graph=built_graph, centroids=cents,
                codes=codes, metric="l2", mode="aisaq", relabel=True)
    idx = HostIndex.load(p)
    for rr in (0, 30):
        a, _ = idx.search(q[0], 10, L=40, rerank=rr)
        b, _ = idx.search_ref(q[0], 10, L=40, rerank=rr)
        np.testing.assert_array_equal(a, b)
        assert set(map(int, a)) <= set(range(len(base)))  # original labels
    ids, _ = idx.search_batch(q, 10, L=40, rerank=40)
    assert recall_at(ids, gt, 10) >= 0.8
    idx.close()


def test_serving_fns_accept_rerank(index_dirs, small_corpus, built_graph,
                                   pq_artifacts):
    """Both serving-tier factories expose the rerank knob; the device tier
    rescoring runs through kernels.rerank (ref backend off-TPU)."""
    from repro.core.device_index import from_arrays
    from repro.serving.engine import make_device_search_fn, \
        make_host_search_fn
    base, q, gt = small_corpus
    idx = HostIndex.load(index_dirs["aisaq"])
    fn = make_host_search_fn(idx, L=40, rerank=40)
    ids = fn(q[:4], 10)
    ref, _ = idx.search_batch(q[:4], 10, L=40, rerank=40)
    np.testing.assert_array_equal(ids, ref)
    idx.close()
    cents, codes = pq_artifacts
    didx, lay = from_arrays(base, built_graph, cents, codes, mode="aisaq")
    dfn = make_device_search_fn(didx, lay, metric="l2", L=40, backend="ref",
                                rerank=32)
    dids = dfn(q[:4], 10)
    assert dids.shape == (4, 10)
    assert recall_at(dids, gt[:4], 10) >= 0.8


# ---------------------------------------------------------------------------
# vectorized helpers
# ---------------------------------------------------------------------------


def test_chunk_matrix_matches_parse_chunk(index_dirs):
    from repro.core.chunk_layout import ChunkLayout, chunk_matrix, parse_chunk
    import json
    path = index_dirs["aisaq"]
    meta = json.load(open(os.path.join(path, "meta.json")))
    lay = ChunkLayout(mode=meta["mode"], dim=meta["dim"],
                      data_dtype=meta["data_dtype"], R=meta["R"],
                      pq_m=meta["pq_m"], block_bytes=meta["block_bytes"])
    raw = np.fromfile(os.path.join(path, "chunks.bin"), dtype=np.uint8)
    n = meta["n"]
    chunks = chunk_matrix(raw, lay, n)
    assert chunks.shape == (n, lay.chunk_bytes)
    for i in (0, 1, n // 2, n - 1):
        ref = raw[lay.file_offset(i):lay.file_offset(i) + lay.chunk_bytes]
        np.testing.assert_array_equal(chunks[i], ref)
        v, ids, pq = parse_chunk(ref, lay)
        np.testing.assert_array_equal(
            np.ascontiguousarray(chunks[i, lay.off_ids:lay.off_ids + lay.R * 4]
                                 ).view(np.int32), ids)


def test_load_device_index_vectorized(index_dirs, small_corpus, built_graph,
                                      pq_artifacts):
    """Vectorized loader reconstructs the same device arrays as building
    straight from the source arrays."""
    import jax.numpy as jnp
    from repro.core.device_index import from_arrays, load_device_index
    base, _, _ = small_corpus
    cents, codes = pq_artifacts
    didx, lay, metric = load_device_index(index_dirs["aisaq"])
    ref_idx, ref_lay = from_arrays(base, built_graph, cents, codes,
                                   mode="aisaq")
    assert metric == "l2" and lay == ref_lay
    np.testing.assert_array_equal(np.asarray(didx.chunk_words),
                                  np.asarray(ref_idx.chunk_words))


def test_recall_at_vectorized_semantics():
    ids = np.array([[1, 2, 3], [4, 5, 6]])
    gt = np.array([[3, 2, 9], [9, 8, 7]])
    assert recall_at(ids, gt, 3) == pytest.approx(2 / 6)
    # duplicate predictions fall back to exact set-intersection semantics
    dup = np.array([[2, 2, 3]])
    assert recall_at(dup, gt[:1], 3) == pytest.approx(2 / 3)
    big = np.random.default_rng(0).integers(0, 50, (20, 10))
    gt2 = np.random.default_rng(1).integers(0, 50, (20, 10))
    slow = sum(len(set(map(int, p)) & set(map(int, g)))
               for p, g in zip(big, gt2)) / 200
    assert recall_at(big, gt2, 10) == pytest.approx(slow)
