"""End-to-end behaviour tests for the paper's system."""
import numpy as np

from repro.core.index_io import HostIndex, recall_at
from repro.core.index_switch import IndexManager
from repro.serving.engine import ServingEngine


def test_end_to_end_serving_with_switch(index_dirs, small_corpus):
    """Full serving path: engine + index manager + AiSAQ host search,
    switching corpora mid-stream (the paper's RAG scenario)."""
    base, q, gt = small_corpus
    mgr = IndexManager({"wiki": index_dirs["aisaq"],
                        "news": index_dirs["aisaq"]})

    def search(queries, k):
        out = np.zeros((queries.shape[0], k), np.int64)
        for i in range(queries.shape[0]):
            out[i], _ = mgr.search(queries[i], k, L=40)
        return out

    eng = ServingEngine({"wiki": search, "news": search},
                        switch_fn=mgr.switch, max_wait_ms=1.0)
    results = []
    for i in range(8):
        corpus = "wiki" if i % 2 == 0 else "news"
        r = eng.submit_wait(q[i], corpus=corpus)
        results.append(r.result)
    ids = np.stack(results)
    assert recall_at(ids, gt[:8], 10) >= 0.8
    assert len(eng.switch_times) >= 2          # switched back and forth
    # AiSAQ switches are ms-order even at this scale
    assert max(eng.switch_times[1:]) < 0.2
    eng.stop()
    mgr.close()


def test_end_to_end_training_recsys():
    from repro.launch.train import train_loop
    h = train_loop("dcn-v2", "train_batch", steps=25, verbose=False, lr=1e-2)
    assert h["losses"][-1] < h["losses"][0]


def test_end_to_end_training_gnn_accuracy():
    from repro.launch.train import train_loop
    h = train_loop("graphsage-reddit", "full_graph_sm", steps=30,
                   verbose=False, lr=1e-2)
    assert h["losses"][-1] < h["losses"][0] * 0.8
