"""CI smoke for cross-process query tracing.

Builds two tiny global-label shard indices in a tempdir, serves them
through a real `ShardCluster` (spawned worker processes + Unix-socket
protocol), routes traced queries through `ShardRouter`, and asserts the
exported Chrome trace-event JSON holds ONE connected span chain:

    router.search -> router.shard{N} -> worker.serve -> service.batch
                  -> traversal.hop (>=1) -> cache.fetch (>=1)

i.e. the trace context survived the frame header out, the worker's
spans survived the result header back, and the hot path opened spans
under the active batch span.  The exported file (``TRACE_query.json``
at the repo root) is uploaded as a CI artifact so a failing run can be
opened directly in Perfetto.

Exit 0 on success, 1 with a reason on any broken link.
"""
from __future__ import annotations

import json
import os
import sys
import tempfile
import time

import numpy as np

K, L, W = 5, 24, 4
N, DIM, NSHARDS = 1000, 48, 2


def build_shards(root: str):
    """Two tiny AiSAQ shards with global labels and one shared codebook
    (the test-suite cluster fixture's shape, self-contained)."""
    import jax

    from repro.core import pq
    from repro.core.index_io import write_index
    from repro.core.shard_math import contiguous_shards
    from repro.core.vamana import build_vamana
    from repro.data.vectors import make_clustered, make_queries

    base = make_clustered(N, DIM, seed=0)
    queries = make_queries(8, base, seed=1)
    cb = pq.train_codebooks(jax.random.PRNGKey(0), base, m=12, iters=4)
    cents = np.asarray(cb.centroids)
    codes = np.asarray(pq.encode(cb, base))
    asn = contiguous_shards(N, NSHARDS)
    shards = []
    for s in range(NSHARDS):
        lo, hi = asn.bounds(s)
        g = build_vamana(base[lo:hi], R=12, L=24, seed=s)
        p = os.path.join(root, f"shard{s}")
        write_index(p, vectors=base[lo:hi], graph=g, centroids=cents,
                    codes=codes[lo:hi], metric="l2", mode="aisaq",
                    labels=np.arange(lo, hi, dtype=np.int64))
        shards.append({"default": p})
    return shards, queries


def chain_failures(doc: dict) -> list:
    """Validate the exported Chrome trace: every expected link present,
    every span parented inside the same trace."""
    fails = []
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        return ["trace has no events"]
    by_id = {}
    for ev in events:
        args = ev.get("args", {})
        sid = args.get("span_id")
        if ev.get("ph") != "X" or not sid:
            fails.append(f"malformed event: {ev.get('name')}")
            continue
        by_id[sid] = ev

    def named(prefix):
        return [e for e in by_id.values()
                if e["name"].startswith(prefix)]

    def parent_of(ev):
        return by_id.get(ev["args"].get("parent_id"))

    roots = named("router.search")
    if len(roots) != 1:
        fails.append(f"expected exactly 1 router.search root, "
                     f"got {len(roots)}")
        return fails
    root = roots[0]
    tid = root["args"]["trace_id"]
    for ev in by_id.values():
        if ev["args"].get("trace_id") != tid:
            fails.append(f"span {ev['name']} has foreign trace_id")

    expect = [("router.shard", "router.search"),
              ("worker.serve", "router.shard"),
              ("service.batch", "worker.serve"),
              ("traversal.hop", "service.batch"),
              ("cache.fetch", "traversal.")]   # hop or rerank parent
    for child_prefix, parent_prefix in expect:
        children = named(child_prefix)
        if not children:
            fails.append(f"no {child_prefix}* span in trace")
            continue
        linked = [c for c in children
                  if (parent_of(c) or {}).get("name", "")
                  .startswith(parent_prefix)]
        if not linked:
            fails.append(f"no {child_prefix}* span parented under a "
                         f"{parent_prefix}* span")
    # both shards must appear in a full-coverage answer
    shards_seen = {e["args"].get("shard") for e in named("worker.serve")}
    if len(shards_seen) < NSHARDS:
        fails.append(f"worker.serve spans cover shards {shards_seen}, "
                     f"expected all {NSHARDS}")
    return fails


def main(argv=None) -> int:
    # --quick is accepted for ci.sh uniformity; the smoke is already tiny
    args = list(sys.argv[1:] if argv is None else argv)
    if args and args not in (["--quick"],):
        print(f"usage: trace_smoke.py [--quick] (got {args})",
              file=sys.stderr)
        return 2

    from repro.obs.trace import Tracer
    from repro.serving.cluster import ShardCluster
    from repro.serving.router import ShardRouter, SocketShardClient

    dest = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                        "TRACE_query.json"))
    t0 = time.perf_counter()
    with tempfile.TemporaryDirectory(prefix="trace-smoke") as td:
        shards, queries = build_shards(td)
        cluster = ShardCluster(shards, socket_dir=os.path.join(td, "sock"),
                               L=L, w=W, cache_bytes=1 << 20)
        cluster.start()
        tracer = Tracer(sample=1.0)
        router = ShardRouter([SocketShardClient(p)
                              for p in cluster.endpoints()],
                             min_shards=NSHARDS, shard_deadline_s=10.0,
                             endpoints_fn=cluster.endpoints,
                             tracer=tracer)
        try:
            out = router.search(queries[0], K)
            assert not out.partial, "smoke query came back partial"
            trace_id = tracer.finished()[-1]["trace_id"]
            doc = tracer.export_chrome(dest, trace_id=trace_id)

            # the merged cluster-wide registry must carry latency
            # histograms with derived percentiles per corpus
            reg = cluster.stats()["registry"]
            lat = (reg or {}).get("service_latency_seconds", {})
            series = lat.get("series", [])
            pct_ok = any(s.get("count") and s.get("p50") is not None
                         and s.get("p99") is not None for s in series)
        finally:
            router.close()
            cluster.stop()

    with open(dest) as f:
        doc = json.load(f)             # must be valid JSON ON DISK
    fails = chain_failures(doc)
    if not pct_ok:
        fails.append("cluster.stats()['registry'] lacks per-corpus "
                     "latency percentiles")
    wall = time.perf_counter() - t0
    if fails:
        for msg in fails:
            print(f"[trace_smoke] FAIL: {msg}", file=sys.stderr)
        return 1
    print(f"[trace_smoke] ok ({wall:.1f}s): "
          f"{len(doc['traceEvents'])} spans in one connected chain, "
          f"wrote {dest}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
