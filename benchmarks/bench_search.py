"""Hot-path + cold-path search benchmark.

Warm path (PR 2): vectorized batched beam search vs the scalar Algorithm-1
reference, per cache budget — QPS, speedup, parity, syscalls/hop, hit rate.

Cold path (PR 3): the regime AiSAQ actually targets — every hop hits the
SSD. Measures, at the paper's 10 MB budget with a freshly-loaded (empty)
cache, the {no-relabel, relabel} x {prefetch off/on} x {pipeline} grid:
  * demand syscalls per hop iteration (the blocking reads beam search
    waits on — the headline acceptance metric),
  * background prefetch I/O reported separately (speculation is NOT free
    and is never hidden: prefetch_syscalls / issued / hits / wasted),
  * QPS, result parity vs the scalar reference, recall (ids are mapped
    back to original labels on relabeled indices, so groundtruth applies
    unchanged), and the block-locality score of each layout.

Pipeline overlap (PR 5): the two-hop in-flight traversal engine
(core.traversal) — per-hop BLOCKED WAIT (time the traversal thread spent
inside demand fetches) vs compute for serial and pipelined runs at the
10 MB budget, with total I/O conserved and reported.

Cache counters are explicitly reset at every phase boundary so each cell
of the report is attributable to exactly one run. BENCH_search.json
carries `schema_version` so the perf trajectory stays comparable across
PRs.

    PYTHONPATH=src:. python benchmarks/bench_search.py          # full
    PYTHONPATH=src:. python benchmarks/bench_search.py --quick  # CI smoke
"""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

from benchmarks import common as C
from repro.core.index_io import HostIndex, recall_at

SCHEMA_VERSION = 5          # 2 = PR 2 (warm path only); 3 adds cold_path;
                            # 4 adds the pipeline column + overlap section;
                            # 5 adds the nav_entry section (hops-to-
                            # convergence, cold p99 nav vs medoid)
K, L, W = 10, 40, 4
BUDGETS = (0, 10 << 20, 64 << 20)     # paper's ~10 MB knob + off + roomy
COLD_BUDGET = 10 << 20
PREFETCH = 4                # next-hop depth per query; == w is the exact
                            # next frontier (zero mis-speculation)


def _stats_sum(stats, field):
    return int(sum(getattr(s, field) for s in stats))


def _run_phase(idx, q, ref_ids, gt, *, prefetch=0, adc_dtype="f32",
               pipeline=None, gap=None, entry="auto"):
    """One measured search_batch pass with counters reset at entry."""
    idx.cache.wait_prefetch()           # nothing from a prior phase leaks
    idx.cache.counters.reset()
    t0 = time.perf_counter()
    ids, stats = idx.search_batch(q, K, L=L, w=W, prefetch=prefetch,
                                  adc_dtype=adc_dtype, pipeline=pipeline,
                                  gap=gap, entry=entry)
    wall = time.perf_counter() - t0
    idx.cache.wait_prefetch()           # land stragglers before reading
    c = idx.cache.counters
    hop_iters = max(s.hops for s in stats)
    # whole-batch overlap totals live on the lead query (see SearchStats)
    blocked_s = stats[0].blocked_wait_s
    compute_s = stats[0].compute_s
    out = dict(
        wall_s=wall, qps=len(q) / wall,
        identical_to_ref=bool(np.array_equal(ids, ref_ids)),
        recall10=recall_at(ids, gt, 10),
        hop_iters=hop_iters,
        # per-query hop distributions: total hops carry an ~L/w
        # verification tail shared by every entry strategy, so the
        # travel phase is isolated by hops-to-convergence (the hop at
        # which the returned top-k stopped changing)
        hops_median=float(np.median([s.hops for s in stats])),
        convergence_median=float(np.median([s.convergence_hop
                                            for s in stats])),
        entry_dist_mean=float(np.mean([s.entry_dist for s in stats])),
        nav_hops_mean=float(np.mean([s.nav_hops for s in stats])),
        total_io_bytes=int(c.bytes_read + c.prefetch_bytes),
        fetch_batches_per_hop=c.fetch_calls / hop_iters,
        syscalls=c.syscalls,
        syscalls_per_hop=c.syscalls / hop_iters,
        # demand + background: speculation moves I/O off the critical
        # path, it does not hide it
        syscalls_per_hop_total=(c.syscalls + c.prefetch_syscalls)
        / hop_iters,
        cache_hit_rate=idx.cache.hit_rate(),
        bytes_read=c.bytes_read,
        cache_bytes_used=idx.cache_bytes_used(),
        pipelined=bool(stats[0].pipelined),
        blocked_wait_s=blocked_s,
        blocked_wait_per_hop_ms=blocked_s / hop_iters * 1e3,
        compute_s=compute_s,
        prefetch=dict(depth=prefetch, syscalls=c.prefetch_syscalls,
                      bytes=c.prefetch_bytes, issued=c.prefetch_issued,
                      hits=c.prefetch_hits, wasted=c.prefetch_wasted,
                      errors=c.prefetch_errors))
    return ids, out


def bench_mode(mode: str, m: int = C.DEFAULT_M) -> dict:
    paths = C.ensure_indices(ms=(m,))
    base, q, gt = C.corpus()
    path = paths[(mode, m)]
    out: dict = {"mode": mode, "pq_m": m, "n": C.N, "nq": len(q),
                 "k": K, "L": L, "w": W}

    idx = HostIndex.load(path)
    t0 = time.perf_counter()
    ref_ids, ref_stats = idx.search_batch_ref(q, K, L=L, w=W)
    t_ref = time.perf_counter() - t0
    hops_per_query = _stats_sum(ref_stats, "hops") / len(q)
    out["ref"] = dict(
        wall_s=t_ref, qps=len(q) / t_ref,
        recall10=recall_at(ref_ids, gt, 10),
        syscalls=_stats_sum(ref_stats, "syscalls"),
        syscalls_per_hop=_stats_sum(ref_stats, "syscalls")
        / _stats_sum(ref_stats, "hops"),
        hops_per_query=hops_per_query)
    idx.close()

    out["batched"] = {}
    for budget in BUDGETS:
        idx = HostIndex.load(path, cache_bytes=budget)
        runs = {}
        for phase in ("cold", "warm"):
            _, r = _run_phase(idx, q, ref_ids, gt)
            r["speedup"] = t_ref / r["wall_s"]
            runs[phase] = r
        out["batched"][str(budget)] = runs
        idx.close()
    return out


# cold-path grid cells: (prefetch, pipeline).  The pipeline column only
# exists where prefetch > 0 (with no background reads there is nothing to
# keep in flight); pf0 is the fully serial demand-path baseline.
COLD_CELLS = ((0, False), (PREFETCH, False), (PREFETCH, True))


def _cell_name(pf: int, pl: bool) -> str:
    return f"prefetch_{pf}" + ("_pipelined" if pl else "")


def bench_cold_path(m: int = C.DEFAULT_M) -> dict:
    """The {relabel} x {prefetch} x {pipeline} grid, each cell on a
    freshly-loaded (empty-cache) index at the 10 MB budget — the
    all-in-storage regime."""
    from repro.core.relabel import block_locality_score
    base, q, gt = C.corpus()
    g = C.graph(base)
    section: dict = {"budget": COLD_BUDGET, "prefetch_depth": PREFETCH,
                     "k": K, "L": L, "w": W, "variants": {}}
    for relabel in (False, True):
        paths = C.ensure_indices(ms=(m,), modes=("aisaq",), relabel=relabel)
        path = paths[("aisaq", m)]
        # the scalar oracle bypasses the cache entirely (direct preads),
        # so running it first cannot warm anything
        idx = HostIndex.load(path, cache_bytes=COLD_BUDGET)
        ref_ids, _ = idx.search_batch_ref(q, K, L=L, w=W)
        npb = idx.layout.nodes_per_block
        o2n = np.load(os.path.join(path, "id_map.npy")) if relabel else None
        idx.close()
        vname = "relabel" if relabel else "no_relabel"
        section["variants"][vname] = {
            "nodes_per_block": npb,
            "block_locality": block_locality_score(g, o2n, npb)}
        for pf, pl in COLD_CELLS:
            idx = HostIndex.load(path, cache_bytes=COLD_BUDGET)  # cold cache
            _, r = _run_phase(idx, q, ref_ids, gt, prefetch=pf, pipeline=pl)
            section["variants"][vname][_cell_name(pf, pl)] = r
            idx.close()
    base_r = section["variants"]["no_relabel"]["prefetch_0"]
    best_r = section["variants"]["relabel"][
        _cell_name(PREFETCH, True)]
    section["headline"] = dict(
        baseline_syscalls_per_hop=base_r["syscalls_per_hop"],
        best_syscalls_per_hop=best_r["syscalls_per_hop"],
        reduction_x=base_r["syscalls_per_hop"]
        / max(best_r["syscalls_per_hop"], 1e-9),
        best_syscalls_per_hop_total=best_r["syscalls_per_hop_total"],
        reduction_total_x=base_r["syscalls_per_hop_total"]
        / max(best_r["syscalls_per_hop_total"], 1e-9),
        qps_baseline=base_r["qps"], qps_best=best_r["qps"],
        identical_to_ref=all(
            v[_cell_name(pf, pl)]["identical_to_ref"]
            for v in section["variants"].values() for pf, pl in COLD_CELLS),
        recall10=best_r["recall10"])
    return section


def bench_pipeline_overlap(m: int = C.DEFAULT_M) -> dict:
    """The pipelined-traversal acceptance section: serial vs two-hop
    in-flight runs on the relabeled layout at the 10 MB budget, cold cache
    each.  Reports per-hop blocked wait (time inside demand fetches) and
    compute, plus total storage I/O (demand + background) to show the
    pipeline CONSERVES I/O while moving it off the critical path."""
    base, q, gt = C.corpus()
    paths = C.ensure_indices(ms=(m,), modes=("aisaq",), relabel=True)
    path = paths[("aisaq", m)]
    idx = HostIndex.load(path, cache_bytes=COLD_BUDGET)
    ref_ids, _ = idx.search_batch_ref(q, K, L=L, w=W)
    idx.close()
    reps = 5
    section: dict = {"budget": COLD_BUDGET, "prefetch_depth": PREFETCH,
                     "relabel": True, "reps": reps, "runs": {}}
    # blocked wait is thread-scheduling sensitive: one-shot cells flip
    # sign run-to-run on a shared box.  Interleave the configs and take
    # per-metric MEDIANS over `reps` cold runs each.
    samples: dict = {name: [] for name in
                     ("serial_no_prefetch", "serial_prefetch", "pipelined")}
    cfg = dict(serial_no_prefetch=(0, False),
               serial_prefetch=(PREFETCH, False),
               pipelined=(PREFETCH, True))
    for _ in range(reps):
        for name, (pf, pl) in cfg.items():
            idx = HostIndex.load(path, cache_bytes=COLD_BUDGET)  # cold cache
            _, r = _run_phase(idx, q, ref_ids, gt, prefetch=pf, pipeline=pl)
            c = idx.cache.counters
            r["total_io_bytes"] = c.bytes_read + c.prefetch_bytes
            samples[name].append(r)
            idx.close()
    for name, runs in samples.items():
        med = dict(runs[-1])             # counters/flags from the last rep
        for key in ("wall_s", "qps", "blocked_wait_per_hop_ms",
                    "blocked_wait_s", "compute_s", "total_io_bytes"):
            med[key] = float(np.median([r[key] for r in runs]))
        med["identical_to_ref"] = all(r["identical_to_ref"] for r in runs)
        section["runs"][name] = med
    runs = section["runs"]
    pl_r, s_r = runs["pipelined"], runs["serial_prefetch"]
    s0_r = runs["serial_no_prefetch"]
    # the acceptance comparison is KNOB-CONTROLLED: pipeline on vs off at
    # equal prefetch — that isolates the two-hop in-flight discipline.
    # The no-prefetch run is reported for context (on page-cache-backed
    # dev boxes inline preadv is near-free, so prefetch itself trades
    # wall time for demand-syscall elimination — the metric that models
    # the real-SSD regime; see the cold_path section).
    section["headline"] = dict(
        blocked_wait_per_hop_ms_serial=s0_r["blocked_wait_per_hop_ms"],
        blocked_wait_per_hop_ms_serial_prefetch=s_r
        ["blocked_wait_per_hop_ms"],
        blocked_wait_per_hop_ms_pipelined=pl_r["blocked_wait_per_hop_ms"],
        blocked_wait_reduction_x=s_r["blocked_wait_per_hop_ms"]
        / max(pl_r["blocked_wait_per_hop_ms"], 1e-9),
        compute_s_pipelined=pl_r["compute_s"],
        # conserved I/O: speculation may add wasted blocks but must stay
        # in the same ballpark as the serial demand reads
        total_io_bytes_serial=s0_r["total_io_bytes"],
        total_io_bytes_serial_prefetch=s_r["total_io_bytes"],
        total_io_bytes_pipelined=pl_r["total_io_bytes"],
        io_overhead_x=pl_r["total_io_bytes"]
        / max(s0_r["total_io_bytes"], 1),
        identical_to_ref=all(r["identical_to_ref"]
                             for r in runs.values()),
        qps_serial=s0_r["qps"], qps_serial_prefetch=s_r["qps"],
        qps_pipelined=pl_r["qps"])
    return section


def bench_nav_entry(m: int = C.DEFAULT_M) -> dict:
    """Navigation-tier acceptance section (PR 10): nav-seeded vs
    medoid-seeded entry on the relabeled AiSAQ layout at an EQUAL total
    DRAM budget — algorithmic residency (pivot graph included on the nav
    twin) plus block-cache capacity sum to the paper's 10 MB on both
    sides, so the nav tier pays for its own bytes out of cache capacity.

    Headline: median hops-to-convergence (the travel phase; total hops
    carry an L/w verification tail both variants share), cold-start
    sequential p99, recall, total I/O, and bit-identity against the
    identically-seeded scalar oracle."""
    base, q, gt = C.corpus()
    med_path = C.ensure_indices(ms=(m,), modes=("aisaq",),
                                relabel=True)[("aisaq", m)]
    nav_path = C.ensure_indices(ms=(m,), modes=("aisaq",), relabel=True,
                                nav=True)[("aisaq", m)]
    section: dict = {"total_budget": COLD_BUDGET, "k": K, "L": L, "w": W,
                     "nav_fraction": C.NAV_FRACTION,
                     "nav_degree": C.NAV_DEGREE, "nav_seed": C.NAV_SEED,
                     "variants": {}}
    for entry, path in (("medoid", med_path), ("nav", nav_path)):
        probe = HostIndex.load(path, cache_bytes=0)
        resident = probe.resident_bytes()
        nav_bytes = probe.nav.resident_nbytes() if probe.nav else 0
        probe.close()
        cache_bytes = max(COLD_BUDGET - int(resident), 1 << 20)
        idx = HostIndex.load(path, cache_bytes=cache_bytes)
        ref_ids, _ = idx.search_batch_ref(q, K, L=L, w=W, entry=entry)
        _, r = _run_phase(idx, q, ref_ids, gt, entry=entry)
        idx.close()
        # cold-start sequential pass: fresh load, one query at a time —
        # the first-touch serving regime the nav tier targets (a batch
        # amortizes entry cost across queries; a lone query cannot)
        idx = HostIndex.load(path, cache_bytes=cache_bytes)
        lats = []
        for i in range(len(q)):
            t1 = time.perf_counter()
            idx.search_batch(q[i:i + 1], K, L=L, w=W, entry=entry)
            lats.append(time.perf_counter() - t1)
        idx.close()
        r.update(resident_bytes=int(resident), nav_bytes=int(nav_bytes),
                 cache_bytes=int(cache_bytes),
                 cold_seq_p50_ms=float(np.percentile(lats, 50) * 1e3),
                 cold_seq_p99_ms=float(np.percentile(lats, 99) * 1e3))
        section["variants"][entry] = r
    nv, md = section["variants"]["nav"], section["variants"]["medoid"]
    section["headline"] = dict(
        medoid_convergence_hops=md["convergence_median"],
        nav_convergence_hops=nv["convergence_median"],
        convergence_reduction_pct=100.0 * (
            1.0 - nv["convergence_median"]
            / max(md["convergence_median"], 1e-9)),
        medoid_hops=md["hops_median"], nav_hops=nv["hops_median"],
        nav_medoid_hops_ratio=nv["hops_median"]
        / max(md["hops_median"], 1e-9),
        cold_p99_ms_medoid=md["cold_seq_p99_ms"],
        cold_p99_ms_nav=nv["cold_seq_p99_ms"],
        recall10_medoid=md["recall10"], recall10_nav=nv["recall10"],
        total_io_bytes_medoid=md["total_io_bytes"],
        total_io_bytes_nav=nv["total_io_bytes"],
        nav_resident_bytes=nv["nav_bytes"],
        identical_to_ref=md["identical_to_ref"]
        and nv["identical_to_ref"])
    return section


def bench_host_int8(m: int = C.DEFAULT_M) -> dict:
    """Host int8 ADC recall parity vs f32 (numpy twin of the device path)."""
    paths = C.ensure_indices(ms=(m,), modes=("aisaq",))
    base, q, gt = C.corpus()
    idx = HostIndex.load(paths[("aisaq", m)])
    out = {}
    for adc in ("f32", "int8"):
        ids, stats = idx.search_batch(q, K, L=L, w=W, adc_dtype=adc)
        ref_ids, _ = idx.search_batch_ref(q, K, L=L, w=W, adc_dtype=adc)
        out[adc] = dict(recall10=recall_at(ids, gt, 10),
                        identical_to_ref=bool(np.array_equal(ids, ref_ids)))
    out["recall_gap"] = abs(out["f32"]["recall10"] - out["int8"]["recall10"])
    idx.close()
    return out


def all_benchmarks():
    rows = []
    report = {"schema_version": SCHEMA_VERSION,
              "corpus": dict(n=C.N, dim=C.DIM, nq=C.NQ, R=C.R)}
    for mode in ("aisaq", "diskann"):
        r = bench_mode(mode)
        report[mode] = r
        rows.append((f"search_{mode}_ref_qps", r["ref"]["qps"],
                     f"recall10={r['ref']['recall10']:.3f}"))
        for budget, runs in r["batched"].items():
            wm = runs["warm"]
            rows.append((
                f"search_{mode}_batched_b{int(budget)//(1<<20)}MB_qps",
                wm["qps"],
                f"speedup={wm['speedup']:.1f}x_hit={wm['cache_hit_rate']:.2f}"
                f"_sys/hop={wm['syscalls_per_hop']:.2f}"
                f"_identical={wm['identical_to_ref']}"))
    report["cold_path"] = cold = bench_cold_path()
    for vname, v in cold["variants"].items():
        for pf, pl in COLD_CELLS:
            r = v[_cell_name(pf, pl)]
            rows.append((
                f"cold_{vname}_pf{pf}{'_pl' if pl else ''}_syscalls_per_hop",
                r["syscalls_per_hop"],
                f"qps={r['qps']:.0f}_pfhits={r['prefetch']['hits']}"
                f"_blocked/hop={r['blocked_wait_per_hop_ms']:.3f}ms"
                f"_identical={r['identical_to_ref']}"))
    rows.append(("cold_syscalls_per_hop_reduction",
                 cold["headline"]["reduction_x"],
                 f"identical={cold['headline']['identical_to_ref']}"))
    report["pipeline_overlap"] = po = bench_pipeline_overlap()
    rows.append(("pipeline_blocked_wait_reduction",
                 po["headline"]["blocked_wait_reduction_x"],
                 f"blocked/hop={po['headline']['blocked_wait_per_hop_ms_pipelined']:.3f}ms"
                 f"_io_overhead={po['headline']['io_overhead_x']:.2f}x"
                 f"_identical={po['headline']['identical_to_ref']}"))
    report["nav_entry"] = ne = bench_nav_entry()
    nh = ne["headline"]
    rows.append(("nav_convergence_hops_reduction_pct",
                 nh["convergence_reduction_pct"],
                 f"nav={nh['nav_convergence_hops']:.1f}"
                 f"_medoid={nh['medoid_convergence_hops']:.1f}"
                 f"_recall={nh['recall10_nav']:.3f}"
                 f"_identical={nh['identical_to_ref']}"))
    rows.append(("nav_cold_seq_p99_ms", nh["cold_p99_ms_nav"],
                 f"medoid_p99={nh['cold_p99_ms_medoid']:.2f}ms"
                 f"_hops_ratio={nh['nav_medoid_hops_ratio']:.2f}"))
    report["host_int8"] = h8 = bench_host_int8()
    rows.append(("host_int8_recall_gap", h8["recall_gap"],
                 f"int8_recall={h8['int8']['recall10']:.3f}"))
    # headline acceptance numbers: paper-budget (10 MB) config
    a = report["aisaq"]["batched"][str(10 << 20)]
    report["headline"] = dict(
        speedup_cold=a["cold"]["speedup"], speedup_warm=a["warm"]["speedup"],
        identical_to_ref=a["cold"]["identical_to_ref"]
        and a["warm"]["identical_to_ref"],
        recall10=a["warm"]["recall10"],
        fetch_batches_per_hop=a["warm"]["fetch_batches_per_hop"],
        syscalls_per_hop_warm=a["warm"]["syscalls_per_hop"],
        cache_hit_rate_warm=a["warm"]["cache_hit_rate"],
        cold_syscalls_per_hop_baseline=cold["headline"]
        ["baseline_syscalls_per_hop"],
        cold_syscalls_per_hop_best=cold["headline"]["best_syscalls_per_hop"],
        cold_syscalls_reduction_x=cold["headline"]["reduction_x"],
        pipeline_blocked_wait_per_hop_ms=po["headline"]
        ["blocked_wait_per_hop_ms_pipelined"],
        pipeline_blocked_wait_reduction_x=po["headline"]
        ["blocked_wait_reduction_x"],
        pipeline_io_overhead_x=po["headline"]["io_overhead_x"],
        host_int8_recall_gap=h8["recall_gap"],
        nav_convergence_hops=nh["nav_convergence_hops"],
        medoid_convergence_hops=nh["medoid_convergence_hops"],
        nav_convergence_reduction_pct=nh["convergence_reduction_pct"],
        nav_medoid_hops_ratio=nh["nav_medoid_hops_ratio"],
        nav_cold_p99_ms=nh["cold_p99_ms_nav"],
        medoid_cold_p99_ms=nh["cold_p99_ms_medoid"],
        nav_recall10=nh["recall10_nav"],
        nav_identical_to_ref=nh["identical_to_ref"])
    report["provenance"] = C.provenance("search")
    dest = os.path.join(os.path.dirname(__file__), "..", "BENCH_search.json")
    with open(os.path.abspath(dest), "w") as f:
        json.dump(report, f, indent=1)
    print(f"[bench_search] wrote {os.path.abspath(dest)}")
    return rows


def quick_smoke() -> int:
    """CI smoke: tiny corpus built on the fly, every hot-path invariant
    asserted. Exits non-zero on any regression; writes no report."""
    import tempfile

    import jax
    from repro.core import pq
    from repro.core.index_io import write_index
    from repro.core.vamana import build_vamana
    from repro.data.vectors import make_clustered, make_queries

    t0 = time.perf_counter()
    base = make_clustered(2000, 48, seed=0)
    q = make_queries(24, base, seed=1)
    gt = np.asarray(pq.groundtruth(q, base, K))
    g = build_vamana(base, R=16, L=32, seed=0)
    cb = pq.train_codebooks(jax.random.PRNGKey(0), base, m=12, iters=6)
    cents, codes = np.asarray(cb.centroids), np.asarray(pq.encode(cb, base))
    failures = []
    with tempfile.TemporaryDirectory() as td:
        for relabel in (False, True):
            p = os.path.join(td, f"idx_rl{int(relabel)}")
            write_index(p, vectors=base, graph=g, centroids=cents,
                        codes=codes, metric="l2", mode="aisaq",
                        relabel=relabel)
            idx = HostIndex.load(p)
            ref_ids, _ = idx.search_batch_ref(q, K, L=L, w=W)
            for pf, adc, pl in ((0, "f32", False), (PREFETCH, "f32", False),
                                (0, "int8", False), (PREFETCH, "int8", False),
                                (PREFETCH, "f32", True),
                                (PREFETCH, "int8", True)):
                if adc == "int8":
                    ref_ids_a, _ = idx.search_batch_ref(q, K, L=L, w=W,
                                                        adc_dtype=adc)
                else:
                    ref_ids_a = ref_ids
                idx.cache.wait_prefetch()
                idx.cache.clear()
                ids, _ = idx.search_batch(q, K, L=L, w=W, prefetch=pf,
                                          adc_dtype=adc, pipeline=pl)
                tag = f"relabel={relabel} pf={pf} adc={adc} pl={pl}"
                if not np.array_equal(ids, ref_ids_a):
                    failures.append(f"{tag}: batched != scalar reference")
                rec = recall_at(ids, gt, K)
                if rec < 0.5:
                    failures.append(f"{tag}: recall collapsed ({rec:.3f})")
            f32_ids, _ = idx.search_batch(q, K, L=L, w=W)
            i8_ids, _ = idx.search_batch(q, K, L=L, w=W, adc_dtype="int8")
            gap = abs(recall_at(f32_ids, gt, K) - recall_at(i8_ids, gt, K))
            # 0.02 (not the 0.01 acceptance bound): with 24x10 result
            # slots one flipped hit is 0.0042, so 0.02 tolerates sampling
            # noise while still catching a real quantization regression;
            # the exact bound is enforced on full-size corpora by
            # tests/test_search_hotpath.py and the BENCH report
            if gap > 0.02:
                failures.append(f"relabel={relabel}: int8 recall gap {gap}")
            idx.close()
        # -- navigation-tier gate (PR 10 acceptance): on a nav-enabled
        # twin of the relabeled index, (a) nav-seeded batched search is
        # bit-identical to the nav-seeded scalar oracle across the adc x
        # {prefetch,pipeline} sample, and (b) the median hop counts with
        # nav entry do not exceed the medoid-seeded medians — a noise-
        # tolerant "nav never navigates worse" bound (hop counts are
        # deterministic per index, so <= is exact, not statistical).
        pnav = os.path.join(td, "idx_nav")
        write_index(pnav, vectors=base, graph=g, centroids=cents,
                    codes=codes, metric="l2", mode="aisaq", relabel=True,
                    nav=True)
        idx = HostIndex.load(pnav)
        med = {}
        for entry in ("medoid", "nav"):
            ref_ids_e, ref_st = idx.search_batch_ref(q, K, L=L, w=W,
                                                     entry=entry)
            for pf, adc, pl in ((0, "f32", False), (PREFETCH, "f32", True),
                                (0, "int8", False), (PREFETCH, "int8", True)):
                if adc == "int8":
                    ref_cmp, _ = idx.search_batch_ref(q, K, L=L, w=W,
                                                      adc_dtype=adc,
                                                      entry=entry)
                else:
                    ref_cmp = ref_ids_e
                idx.cache.wait_prefetch()
                idx.cache.clear()
                ids, st = idx.search_batch(q, K, L=L, w=W, prefetch=pf,
                                           adc_dtype=adc, pipeline=pl,
                                           entry=entry)
                tag = f"entry={entry} pf={pf} adc={adc} pl={pl}"
                if not np.array_equal(ids, ref_cmp):
                    failures.append(f"{tag}: batched != scalar reference")
            med[entry] = dict(
                hops=float(np.median([s.hops for s in st])),
                conv=float(np.median([s.convergence_hop for s in st])))
        idx.close()
        if med["nav"]["conv"] > med["medoid"]["conv"]:
            failures.append(
                f"nav median convergence hops {med['nav']['conv']} worse "
                f"than medoid {med['medoid']['conv']}")
        if med["nav"]["hops"] > med["medoid"]["hops"]:
            failures.append(
                f"nav median total hops {med['nav']['hops']} worse than "
                f"medoid {med['medoid']['hops']}")
        print(f"[bench_search --quick] nav gate: conv "
              f"{med['nav']['conv']:.0f} vs medoid "
              f"{med['medoid']['conv']:.0f}, hops {med['nav']['hops']:.0f}"
              f" vs {med['medoid']['hops']:.0f}")
        # -- pipeline overlap guard (CI acceptance): cold-path mean latency
        # of the pipelined engine must not regress past the serial path,
        # and the blocked wait it exists to shrink must not grow.  Medians
        # over alternating repeats + a noise margin keep this robust on
        # shared CI runners (QPS noise), while still catching a real
        # overlap regression (those show up as 2x+, not 20%).
        p = os.path.join(td, "idx_rl1")
        reps = 3
        lat = {False: [], True: []}
        blocked = {False: [], True: []}
        for _ in range(reps):
            for pl in (False, True):
                idx = HostIndex.load(p)          # cold cache each run
                t1 = time.perf_counter()
                ids, st = idx.search_batch(q, K, L=L, w=W,
                                           prefetch=PREFETCH, pipeline=pl)
                lat[pl].append((time.perf_counter() - t1) / len(q))
                blocked[pl].append(st[0].blocked_wait_s)
                idx.close()
        lat_s = float(np.median(lat[False]))
        lat_p = float(np.median(lat[True]))
        blk_s = float(np.median(blocked[False]))
        blk_p = float(np.median(blocked[True]))
        if lat_p > lat_s * 1.25 + 2e-3:
            failures.append(
                f"pipelined cold-path mean latency regressed: "
                f"{lat_p*1e3:.2f}ms vs serial {lat_s*1e3:.2f}ms")
        if blk_p > blk_s * 1.25 + 2e-3:
            failures.append(
                f"pipelined blocked wait regressed: {blk_p*1e3:.2f}ms "
                f"vs serial {blk_s*1e3:.2f}ms")
        # -- tracing disabled-overhead gate (ISSUE 9 acceptance): with no
        # span active, instrumentation costs one thread-local read + one
        # branch per hop; the warm hot path with tracing at its DEFAULT
        # (enabled globally, nothing sampled) must stay within 2% of the
        # set_enabled(False) kill switch.  Median over alternating
        # repeats + an absolute epsilon absorb shared-runner noise on a
        # sub-ms per-query path.
        from repro.obs import trace as obs_trace
        idx = HostIndex.load(p)
        idx.search_batch(q, K, L=L, w=W)          # warm the cache
        reps, t_def, t_off = 9, [], []
        try:
            for _ in range(reps):
                for flag, acc in ((True, t_def), (False, t_off)):
                    obs_trace.set_enabled(flag)
                    t1 = time.perf_counter()
                    idx.search_batch(q, K, L=L, w=W)
                    acc.append((time.perf_counter() - t1) / len(q))
        finally:
            obs_trace.set_enabled(True)
        idx.close()
        # min-of-reps: scheduler noise only ever ADDS latency, so the
        # minimum is the cleanest view of a few-branches-per-hop cost
        td_def = float(np.min(t_def))
        td_off = float(np.min(t_off))
        overhead = (td_def - td_off) / td_off if td_off else 0.0
        if td_def > td_off * 1.02 + 50e-6:
            failures.append(
                f"tracing-disabled hot-path overhead {overhead*100:.2f}% "
                f"exceeds 2% ({td_def*1e6:.0f}us vs {td_off*1e6:.0f}us "
                "per query)")
        else:
            print(f"[bench_search --quick] tracing-disabled overhead "
                  f"{overhead*100:+.2f}% "
                  f"({td_def*1e6:.0f}us vs {td_off*1e6:.0f}us/query)")
    wall = time.perf_counter() - t0
    if failures:
        for msg in failures:
            print(f"[bench_search --quick] FAIL: {msg}", file=sys.stderr)
        return 1
    print(f"[bench_search --quick] all hot-path invariants hold "
          f"({wall:.1f}s)")
    return 0


if __name__ == "__main__":
    if "--quick" in sys.argv[1:]:
        sys.exit(quick_smoke())
    for name, val, extra in all_benchmarks():
        print(f"{name},{val:.2f},{extra}")
