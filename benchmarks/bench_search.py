"""Hot-path search benchmark: vectorized batched beam search vs the scalar
Algorithm-1 reference, on the N=20k bench corpus.

Measures, per cache budget:
  * QPS + speedup over `search_ref` (cold cache and warm cache),
  * result parity (the vectorized path must return identical ids),
  * I/O batching: read syscalls per hop iteration (the reference pays one
    pread per node expansion = w per hop; the batched path coalesces each
    hop's frontier into ONE fetch whose misses are read with run-coalesced
    preadv calls — fully cache-resident hops take zero),
  * block-cache hit rate under the explicit DRAM byte budget.

Writes BENCH_search.json next to this file and prints a CSV-ish summary.

    PYTHONPATH=src:. python benchmarks/bench_search.py
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks import common as C
from repro.core.index_io import HostIndex, recall_at

K, L, W = 10, 40, 4
BUDGETS = (0, 10 << 20, 64 << 20)     # paper's ~10 MB knob + off + roomy


def _stats_sum(stats, field):
    return int(sum(getattr(s, field) for s in stats))


def bench_mode(mode: str, m: int = C.DEFAULT_M) -> dict:
    paths = C.ensure_indices(ms=(m,))
    base, q, gt = C.corpus()
    path = paths[(mode, m)]
    out: dict = {"mode": mode, "pq_m": m, "n": C.N, "nq": len(q),
                 "k": K, "L": L, "w": W}

    idx = HostIndex.load(path)
    t0 = time.perf_counter()
    ref_ids, ref_stats = idx.search_batch_ref(q, K, L=L, w=W)
    t_ref = time.perf_counter() - t0
    hops_per_query = _stats_sum(ref_stats, "hops") / len(q)
    out["ref"] = dict(
        wall_s=t_ref, qps=len(q) / t_ref,
        recall10=recall_at(ref_ids, gt, 10),
        syscalls=_stats_sum(ref_stats, "syscalls"),
        syscalls_per_hop=_stats_sum(ref_stats, "syscalls")
        / _stats_sum(ref_stats, "hops"),
        hops_per_query=hops_per_query)
    idx.close()

    out["batched"] = {}
    for budget in BUDGETS:
        idx = HostIndex.load(path, cache_bytes=budget)
        runs = {}
        for phase in ("cold", "warm"):
            before = idx.cache.counters.snapshot()
            t0 = time.perf_counter()
            ids, stats = idx.search_batch(q, K, L=L, w=W)
            wall = time.perf_counter() - t0
            after = idx.cache.counters.snapshot()
            hits, misses, _, syscalls, bytes_read, fetches = \
                (a - b for a, b in zip(after, before))
            hop_iters = max(s.hops for s in stats)   # batched hop iterations
            runs[phase] = dict(
                wall_s=wall, qps=len(q) / wall, speedup=t_ref / wall,
                identical_to_ref=bool(np.array_equal(ids, ref_ids)),
                recall10=recall_at(ids, gt, 10),
                hop_iters=hop_iters,
                fetch_batches_per_hop=fetches / hop_iters,
                syscalls=syscalls,
                syscalls_per_hop=syscalls / hop_iters,
                cache_hit_rate=hits / max(1, hits + misses),
                bytes_read=bytes_read,
                cache_bytes_used=idx.cache_bytes_used())
        out["batched"][str(budget)] = runs
        idx.close()
    return out


def all_benchmarks():
    rows = []
    report = {"corpus": dict(n=C.N, dim=C.DIM, nq=C.NQ, R=C.R)}
    for mode in ("aisaq", "diskann"):
        r = bench_mode(mode)
        report[mode] = r
        rows.append((f"search_{mode}_ref_qps", r["ref"]["qps"],
                     f"recall10={r['ref']['recall10']:.3f}"))
        for budget, runs in r["batched"].items():
            wm = runs["warm"]
            rows.append((
                f"search_{mode}_batched_b{int(budget)//(1<<20)}MB_qps",
                wm["qps"],
                f"speedup={wm['speedup']:.1f}x_hit={wm['cache_hit_rate']:.2f}"
                f"_sys/hop={wm['syscalls_per_hop']:.2f}"
                f"_identical={wm['identical_to_ref']}"))
    # headline acceptance numbers: paper-budget (10 MB) config
    a = report["aisaq"]["batched"][str(10 << 20)]
    report["headline"] = dict(
        speedup_cold=a["cold"]["speedup"], speedup_warm=a["warm"]["speedup"],
        identical_to_ref=a["cold"]["identical_to_ref"]
        and a["warm"]["identical_to_ref"],
        recall10=a["warm"]["recall10"],
        fetch_batches_per_hop=a["warm"]["fetch_batches_per_hop"],
        syscalls_per_hop_warm=a["warm"]["syscalls_per_hop"],
        cache_hit_rate_warm=a["warm"]["cache_hit_rate"])
    dest = os.path.join(os.path.dirname(__file__), "..", "BENCH_search.json")
    with open(os.path.abspath(dest), "w") as f:
        json.dump(report, f, indent=1)
    print(f"[bench_search] wrote {os.path.abspath(dest)}")
    return rows


if __name__ == "__main__":
    for name, val, extra in all_benchmarks():
        print(f"{name},{val:.2f},{extra}")
