"""Regression summary over the benchmark report artifacts.

Reads every ``BENCH_*.json`` at the repo root, pulls each report's
``headline`` dict, and diffs its numeric entries against the previous
committed artifact (``git show HEAD:BENCH_x.json``) so a CI run shows
at a glance which key metrics moved and by how much.

Informational by design: exits 0 regardless of deltas (benchmarks on
shared CI boxes are too noisy to gate on), missing baselines are shown
as NEW, and unreadable files are reported rather than fatal.  The
``provenance`` header stamped by ``benchmarks/common.provenance`` tells
the reader which commit/host produced each side of the diff.

Usage: ``python benchmarks/report.py [--root DIR] [--ref GITREF]``
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import subprocess
import sys

__all__ = ["collect", "diff_headlines", "render"]


def _load(path: str):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError) as e:
        return {"_error": f"{type(e).__name__}: {e}"}


def _load_ref(root: str, name: str, ref: str):
    """The previously committed artifact, or None when it has none."""
    try:
        out = subprocess.run(
            ["git", "show", f"{ref}:{name}"], capture_output=True,
            text=True, cwd=root, timeout=10)
        if out.returncode != 0:
            return None
        return json.loads(out.stdout)
    except (OSError, ValueError, subprocess.SubprocessError):
        return None


def diff_headlines(cur: dict, prev) -> list:
    """Rows of (metric, current, previous, pct_delta | None).

    Non-numeric headline entries (bools count as numeric-ish but are
    compared for equality) diff as changed/unchanged; missing previous
    values show as NEW.
    """
    rows = []
    head = cur.get("headline") if isinstance(cur, dict) else None
    if not isinstance(head, dict):
        return rows
    phead = prev.get("headline", {}) if isinstance(prev, dict) else {}
    if not isinstance(phead, dict):
        phead = {}
    for key in sorted(head):
        val = head[key]
        old = phead.get(key)
        if isinstance(val, bool) or not isinstance(val, (int, float)):
            rows.append((key, val, old, None))
            continue
        if isinstance(old, bool) or not isinstance(old, (int, float)):
            rows.append((key, val, None, None))
            continue
        pct = ((val - old) / abs(old) * 100.0) if old else None
        rows.append((key, val, old, pct))
    return rows


def collect(root: str, ref: str = "HEAD") -> list:
    """(name, current_report, previous_report | None) per artifact."""
    out = []
    for path in sorted(glob.glob(os.path.join(root, "BENCH_*.json"))):
        name = os.path.basename(path)
        out.append((name, _load(path), _load_ref(root, name, ref)))
    return out


#: Headline keys starred in the rendering — the per-PR acceptance
#: metrics a reviewer checks first (everything else still prints).
KEY_METRICS = frozenset((
    "speedup_warm", "cold_syscalls_reduction_x",
    "pipeline_blocked_wait_reduction_x", "host_int8_recall_gap",
    # navigation tier (schema 5): travel-phase hop reduction, the
    # cold-start latency it buys, and the total-hops ratio
    "nav_convergence_reduction_pct", "nav_cold_p99_ms",
    "medoid_cold_p99_ms", "nav_medoid_hops_ratio", "nav_recall10",
))


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def render(reports: list, ref: str) -> str:
    lines = []
    for name, cur, prev in reports:
        if "_error" in cur:
            lines.append(f"{name}: UNREADABLE ({cur['_error']})")
            continue
        prov = cur.get("provenance") or {}
        commit = (prov.get("git_commit") or "?")[:12]
        stamp = prov.get("timestamp", "?")
        lines.append(f"{name}  (commit {commit}, {stamp})")
        if prev is None:
            lines.append(f"  no {ref} baseline — all metrics NEW")
        rows = diff_headlines(cur, prev)
        if not rows:
            lines.append("  no headline dict")
            continue
        for key, val, old, pct in rows:
            star = "*" if key in KEY_METRICS else " "
            if pct is not None:
                arrow = "+" if pct >= 0 else ""
                lines.append(f" {star}{key:<44} {_fmt(val):>12}  "
                             f"(prev {_fmt(old)}, {arrow}{pct:.1f}%)")
            elif old is None:
                lines.append(f" {star}{key:<44} {_fmt(val):>12}  (NEW)")
            elif val == old:
                lines.append(f" {star}{key:<44} {_fmt(val):>12}  "
                             "(unchanged)")
            else:
                lines.append(f" {star}{key:<44} {_fmt(val):>12}  "
                             f"(prev {_fmt(old)}, CHANGED)")
    if not reports:
        lines.append("no BENCH_*.json artifacts found")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--root", default=os.path.join(
        os.path.dirname(os.path.abspath(__file__)), ".."))
    ap.add_argument("--ref", default="HEAD",
                    help="git ref holding the baseline artifacts")
    args = ap.parse_args(argv)
    root = os.path.abspath(args.root)
    print(f"[report] benchmark regression summary vs {args.ref}")
    print(render(collect(root, args.ref), args.ref))
    return 0               # informational: never fails the build


if __name__ == "__main__":
    sys.exit(main())
